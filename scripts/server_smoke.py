#!/usr/bin/env python3
"""End-to-end smoke test for the `plan-server` subcommand (stdlib only).

Drives a release binary over loopback TCP through the whole protocol
surface and the robustness contract:

1. health / plan / simulate / stats / shutdown round-trips;
2. malformed and oversized requests get structured errors while the
   process keeps serving;
3. a tight deadline yields a tagged `degraded` response whose plans are
   still complete;
4. kill-free warm restart: a second process on the same `--state-dir`
   answers the same plan fully from the strategy cache (zero anneal
   iterations, zero store misses);
5. determinism: a cold plan on a fresh state dir is byte-identical to the
   first process's cold plan.

Exit code 0 on success, 1 with a diagnostic on the first violated check.

Usage: python scripts/server_smoke.py [--binary target/release/convoffload]
"""

import argparse
import json
import os
import shutil
import socket
import subprocess
import sys
import tempfile
import time


class Client:
    """One line-delimited JSON connection."""

    def __init__(self, addr):
        host, port = addr.rsplit(":", 1)
        self.sock = socket.create_connection((host, int(port)), timeout=120)
        self.rfile = self.sock.makefile("rb")

    def send_raw(self, line: bytes):
        self.sock.sendall(line + b"\n")

    def recv_raw(self) -> bytes:
        line = self.rfile.readline()
        if not line:
            raise AssertionError("server closed the connection unexpectedly")
        return line.rstrip(b"\n")

    def roundtrip(self, request: dict) -> dict:
        self.send_raw(json.dumps(request).encode())
        return json.loads(self.recv_raw())

    def close(self):
        self.rfile.close()
        self.sock.close()


class Server:
    """A plan-server subprocess bound to an ephemeral port."""

    def __init__(self, binary, state_dir, extra=()):
        self.proc = subprocess.Popen(
            [
                binary, "plan-server",
                "--addr", "127.0.0.1:0",
                "--state-dir", state_dir,
                "--iters", "2000",
                "--starts", "2",
                "--group", "4",
                "--seed", "2026",
                "--max-request-kb", "16",
                *extra,
            ],
            stdout=subprocess.PIPE,
            stderr=subprocess.STDOUT,
            text=True,
        )
        banner = self.proc.stdout.readline().strip()
        prefix = "plan-server listening on "
        if not banner.startswith(prefix):
            self.proc.kill()
            raise AssertionError(f"unexpected banner: {banner!r}")
        self.addr = banner[len(prefix):]

    def shutdown(self):
        c = Client(self.addr)
        resp = c.roundtrip({"op": "shutdown"})
        check(resp.get("ok") is True and resp.get("stopping") is True,
              f"shutdown response: {resp}")
        c.close()
        code = self.proc.wait(timeout=120)
        check(code == 0, f"server exited with code {code}")


def check(cond, msg):
    if not cond:
        print(f"FAIL: {msg}", file=sys.stderr)
        sys.exit(1)


def plan_stats(resp):
    return resp["report"]["stats"]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--binary", default="target/release/convoffload")
    args = ap.parse_args()
    if shutil.which(args.binary) is None and not os.path.exists(args.binary):
        print(f"FAIL: binary not found: {args.binary}", file=sys.stderr)
        return 1

    tmp = tempfile.mkdtemp(prefix="plan-server-smoke-")
    warm_dir = f"{tmp}/warm"
    fresh_dir = f"{tmp}/fresh"

    # --- process 1: cold start, full protocol surface -------------------
    srv = Server(args.binary, warm_dir)
    print(f"[smoke] server 1 on {srv.addr}")
    c = Client(srv.addr)

    resp = c.roundtrip({"op": "health"})
    check(resp.get("ok") is True and resp.get("queue_depth") == 0,
          f"health: {resp}")

    c.send_raw(json.dumps({"op": "plan", "networks": ["lenet5"]}).encode())
    cold_plan_bytes = c.recv_raw()
    cold = json.loads(cold_plan_bytes)
    check(cold.get("ok") is True and "degraded" not in cold,
          f"cold plan must be ok and untagged: {list(cold)}")
    check(plan_stats(cold)["anneal_iters_run"] > 0,
          "cold plan must actually search")
    print("[smoke] cold plan ok "
          f"(anneal_iters_run={plan_stats(cold)['anneal_iters_run']})")

    resp = c.roundtrip(
        {"op": "simulate", "layer": "lenet5-conv1", "strategy": "zigzag"}
    )
    check(resp.get("ok") is True and resp.get("n_steps", 0) > 0,
          f"simulate: {resp}")

    # malformed requests: structured error, connection survives
    for bad in (b"not json", b'{"op":"warp"}', b'{"networks":["lenet5"]}',
                b'{"op":"plan","networks":[]}',
                b'{"op":"plan","networks":["vgg99"]}'):
        c.send_raw(bad)
        resp = json.loads(c.recv_raw())
        check(resp.get("ok") is False
              and resp["error"]["kind"] == "malformed",
              f"malformed {bad!r}: {resp}")
    print("[smoke] malformed inputs rejected, connection survives")

    # oversized request: too-large, connection is dropped (framing lost)
    big = Client(srv.addr)
    big.send_raw(b'{"op":"health","pad":"' + b"x" * (20 * 1024) + b'"}')
    resp = json.loads(big.recv_raw())
    check(resp.get("ok") is False and resp["error"]["kind"] == "too-large",
          f"oversized: {resp}")
    big.close()

    # tight deadline: degraded tag, plans still complete
    resp = c.roundtrip(
        {"op": "plan", "networks": ["lenet5"], "deadline_ms": 50}
    )
    check(resp.get("ok") is True, f"deadline plan: {resp}")
    tag = resp.get("degraded")
    check(tag is not None and tag["rung"] in
          ("reduced", "heuristic", "cache-only"),
          f"deadline plan must be tagged degraded: {resp.get('degraded')}")
    check(all(p["layers"] for p in resp["report"]["plans"]),
          "degraded plan must still cover every stage")
    print(f"[smoke] deadline plan degraded to rung={tag['rung']}")

    resp = c.roundtrip({"op": "stats"})
    counters = resp["stats"]
    check(counters["rejected_malformed"] >= 6, f"stats counters: {counters}")
    check(counters["accepted"] >= 2, f"stats counters: {counters}")
    c.close()
    srv.shutdown()
    print("[smoke] clean shutdown (cache flushed, journal compacted)")

    # --- process 2: warm restart on the same state dir ------------------
    srv = Server(args.binary, warm_dir)
    print(f"[smoke] server 2 (warm) on {srv.addr}")
    c = Client(srv.addr)
    warm = c.roundtrip({"op": "plan", "networks": ["lenet5"]})
    check(warm.get("ok") is True, f"warm plan: {warm}")
    check(plan_stats(warm)["anneal_iters_run"] == 0,
          f"warm plan must not search: {plan_stats(warm)}")
    check(plan_stats(warm)["store_misses"] == 0,
          f"warm plan must hit the cache: {plan_stats(warm)}")
    c.close()
    srv.shutdown()
    print("[smoke] warm restart served the plan fully from cache")

    # --- process 3: fresh state dir, cold-plan determinism --------------
    srv = Server(args.binary, fresh_dir)
    print(f"[smoke] server 3 (fresh) on {srv.addr}")
    c = Client(srv.addr)
    c.send_raw(json.dumps({"op": "plan", "networks": ["lenet5"]}).encode())
    fresh_bytes = c.recv_raw()
    check(fresh_bytes == cold_plan_bytes,
          "cold plans must be byte-identical across fresh processes")
    c.close()
    srv.shutdown()
    print("[smoke] cold plan byte-identical across processes")

    shutil.rmtree(tmp, ignore_errors=True)
    print("[smoke] all checks passed")
    return 0


if __name__ == "__main__":
    start = time.monotonic()
    rc = main()
    print(f"[smoke] {time.monotonic() - start:.1f}s")
    sys.exit(rc)

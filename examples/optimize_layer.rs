//! Optimize a layer's offloading strategy (the §5 problem, full pipeline).
//!
//! ```bash
//! cargo run --release --example optimize_layer
//! ```
//!
//! Demonstrates the three-engine optimizer on two §7.1 sweep layers:
//! * a small one, solved **exactly** (specialized branch & bound, and — to
//!   show the ILP substrate — the verbatim §5 MILP solved with the in-tree
//!   simplex/B&B, both agreeing);
//! * a larger one, solved by the heuristic-seeded annealing polish (the
//!   paper's MIP-start + solution-polishing regime).

use std::time::Duration;

use convoffload::config::presets::paper_sweep_layer;
use convoffload::optimizer::{
    build_s1_model, decode_solution, grouping_duration, grouping_loads,
    model_builder::encode_mip_start, OptimizeOptions, Optimizer,
};
use convoffload::platform::Accelerator;
use convoffload::solver::{solve_milp, BranchBoundOptions};
use convoffload::strategy;

fn main() {
    // ---- exact regime: 4×4 input → 4 patches, group 2 → K_min = 2 ----
    let layer = paper_sweep_layer(4);
    let group = 2;
    let acc = Accelerator::for_group_size(&layer, group);
    println!("== exact regime: {layer}");

    let row = strategy::row_by_row(&layer, group);
    let zig = strategy::zigzag(&layer, group);
    println!("row-by-row δ = {}", grouping_duration(&layer, &acc, &row.groups));
    println!("zigzag     δ = {}", grouping_duration(&layer, &acc, &zig.groups));

    // (a) the verbatim §5 ILP through the generic MILP solver
    let k = acc.k_min(&layer);
    let (model, info) = build_s1_model(&layer, &acc, k, 4);
    println!("ILP model: {}", model.dims());
    let mip_start = encode_mip_start(&layer, &info, &row.groups, model.n_vars());
    let sol = solve_milp(
        &model,
        &BranchBoundOptions {
            mip_start: Some(mip_start),
            time_budget: Duration::from_secs(120),
            node_budget: 1_000_000,
            ..Default::default()
        },
    );
    println!("MILP status: {:?} after {} nodes", sol.status, sol.nodes);
    let ilp_strategy = decode_solution(&info, &sol.assignment);
    let ilp_loads = grouping_loads(&layer, &ilp_strategy.groups);
    println!("MILP optimum loads = {ilp_loads}");

    // (b) the optimizer facade (uses the specialized exact engine here)
    let opt = Optimizer::new(OptimizeOptions { group_size: group, ..Default::default() });
    let res = opt.optimize(&layer, &acc);
    println!(
        "optimizer: method {:?}, δ = {} (heuristic {}), gain {:.1}%",
        res.method,
        res.duration,
        res.mip_start_duration,
        res.gain_over_heuristics() * 100.0
    );
    assert_eq!(
        grouping_loads(&layer, &res.strategy.groups),
        ilp_loads,
        "both exact engines must agree"
    );

    // ---- polish regime: 12×12 input → 100 patches ----
    let layer = paper_sweep_layer(12);
    let group = 4;
    let acc = Accelerator::for_group_size(&layer, group);
    println!("\n== polish regime: {layer}");
    let opt = Optimizer::new(OptimizeOptions {
        group_size: group,
        anneal_iters: 150_000,
        seed: 2026,
        ..Default::default()
    });
    let res = opt.optimize(&layer, &acc);
    println!(
        "optimizer: method {:?}, δ = {} (best heuristic {}), gain {:.1}%",
        res.method,
        res.duration,
        res.mip_start_duration,
        res.gain_over_heuristics() * 100.0
    );

    // Export the strategy in the simulator's CSV format and read it back.
    let csv = strategy::strategy_to_csv(&res.strategy);
    let reread = strategy::strategy_from_csv("opl", &csv).expect("round-trip");
    assert_eq!(reread.groups, res.strategy.groups);
    println!(
        "strategy CSV round-trip OK ({} steps, first row: {})",
        reread.n_steps(),
        csv.lines().nth(1).unwrap_or("")
    );
    println!("optimize_layer OK");
}

//! Quickstart: model a layer + accelerator, compare offloading strategies.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```
//!
//! Walks the public API end to end: define a convolution layer (Example 1 of
//! the paper), derive the accelerator from a group-size budget, build the
//! built-in strategies, simulate each, and print the duration/memory report.

use convoffload::prelude::*;
use convoffload::sim::summary_line;
use convoffload::strategy;

fn main() {
    // The layer of the paper's Examples 1–2: 2×5×5 input, two 3×3 kernels.
    let layer = ConvLayer::new(2, 5, 5, 3, 3, 2, 1, 1).expect("valid layer");
    println!("layer: {layer}");
    println!("patches |X| = {}, ops/patch = {}", layer.n_patches(), layer.ops_per_patch());

    // Accelerator able to process 2 patches per step (Example 2's setting),
    // with the §7.1 memory assumption (kernels + group + outputs fit).
    let group = 2;
    let acc = Accelerator::for_group_size(&layer, group);
    println!(
        "accelerator: nbop_PE={}, size_MEM={}, t_l={}, t_w={}, t_acc={}",
        acc.nbop_pe, acc.size_mem, acc.t_l, acc.t_w, acc.t_acc
    );
    println!(
        "K_min = {}, K_max = {}\n",
        acc.k_min(&layer),
        acc.k_max(&layer)
    );

    let sim = Simulator::new(layer, Platform::new(acc));

    // Compare every built-in strategy.
    let strategies = [
        strategy::s1_baseline(&layer),
        strategy::row_by_row(&layer, group),
        strategy::zigzag(&layer, group),
        strategy::hilbert(&layer, group),
        strategy::diagonal(&layer, group),
    ];
    for s in &strategies {
        let report = sim.run(s).expect("strategy must simulate");
        println!("{}", summary_line(&report, &acc));
    }

    // Validate a strategy against the §2.3 assumptions.
    let zig = strategy::zigzag(&layer, group);
    let check = strategy::validate(&layer, &acc, &zig, layer.h_k as u32);
    println!(
        "\nzigzag validation: {} (peak occupancy {} / {} elements)",
        if check.is_valid() { "OK" } else { "violations found" },
        check.peak_occupancy,
        acc.size_mem
    );

    // Functional check: the stepwise offload computes the true convolution.
    let input = convoffload::conv::reference::synth_tensor(layer.input_dims().len(), 1);
    let kernels = convoffload::conv::reference::synth_tensor(layer.kernel_elements(), 2);
    let mut backend = convoffload::sim::RustOracleBackend;
    let report = sim
        .run_functional(&zig, &input, &kernels, &mut backend)
        .expect("functional run");
    println!(
        "functional check (rust oracle): max |err| = {:.2e}",
        report.max_abs_error.unwrap()
    );
    assert_eq!(report.functional_ok(1e-5), Some(true));
    println!("quickstart OK");
}

//! Design-space exploration: how accelerator capacity (`nbop_PE`, i.e. the
//! group size) and memory trade off against offload duration across layers.
//!
//! ```bash
//! cargo run --release --example accelerator_sweep
//! ```
//!
//! For each preset layer, sweeps the group size, reports δ for every
//! heuristic plus the polished optimizer, and prints the paper's derived
//! quantities (K_min, on-chip footprint). This is the “help designers deploy
//! convolution layers” use-case of §1.3, plus the write-back-policy ablation
//! from DESIGN.md §8.

use convoffload::config::list_presets;
use convoffload::optimizer::{grouping_duration, OptimizeOptions, Optimizer};
use convoffload::platform::{Accelerator, Platform};
use convoffload::sim::Simulator;
use convoffload::strategy::{self, WritebackPolicy};

fn main() {
    let groups = [1usize, 2, 4, 8];

    for preset in list_presets() {
        let layer = preset.layer;
        // keep the sweep fast on the big layers
        if layer.n_patches() > 1000 {
            continue;
        }
        println!("== {} : {layer}", preset.name);
        println!(
            "{:>6} {:>7} {:>12} {:>12} {:>12} {:>12} {:>12} {:>9}",
            "group", "K_min", "s1-baseline", "row-by-row", "zigzag", "hilbert", "opl", "mem(el)"
        );
        for &g in &groups {
            let acc = Accelerator::for_group_size(&layer, g);
            let base = grouping_duration(&layer, &acc, &strategy::s1_baseline(&layer).groups);
            let row = grouping_duration(&layer, &acc, &strategy::row_by_row(&layer, g).groups);
            let zig = grouping_duration(&layer, &acc, &strategy::zigzag(&layer, g).groups);
            let hil = grouping_duration(&layer, &acc, &strategy::hilbert(&layer, g).groups);
            let opt = Optimizer::new(OptimizeOptions {
                group_size: g,
                anneal_iters: 40_000,
                ..Default::default()
            });
            let res = opt.optimize(&layer, &acc);
            println!(
                "{:>6} {:>7} {:>12} {:>12} {:>12} {:>12} {:>12} {:>9}",
                g,
                acc.k_min(&layer),
                base,
                row,
                zig,
                hil,
                res.duration,
                acc.size_mem
            );
        }

        // Write-back policy ablation (S1-baseline leaves W_i unspecified;
        // we quantify both choices on the zigzag strategy, group 4).
        let g = 4;
        let acc = Accelerator {
            // deferred write-back keeps all outputs on chip: size the memory
            // for the worst case so both policies simulate
            size_mem: Accelerator::for_group_size(&layer, g).size_mem
                + (layer.n_patches() * layer.c_out()) as u64,
            t_w: 1, // charge writes so the policies differ in cost model too
            ..Accelerator::for_group_size(&layer, g)
        };
        let sim = Simulator::new(layer, Platform::new(acc));
        let mut every = strategy::zigzag(&layer, g);
        every.writeback = WritebackPolicy::EveryStep;
        let mut at_end = strategy::zigzag(&layer, g);
        at_end.writeback = WritebackPolicy::AtEnd;
        let r1 = sim.run(&every).expect("every-step policy");
        let r2 = sim.run(&at_end).expect("at-end policy");
        println!(
            "   write-back ablation (zigzag g=4, t_w=1): every-step δ={} peak={} | at-end δ={} peak={}",
            r1.duration, r1.peak_occupancy, r2.duration, r2.peak_occupancy
        );
        assert_eq!(
            r1.duration, r2.duration,
            "same elements written in total → same δ; only the peak differs"
        );
        assert!(r2.peak_occupancy >= r1.peak_occupancy);
        println!();
    }
    println!("accelerator_sweep OK");
}

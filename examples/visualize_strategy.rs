//! Reproduce Figure 9: side-by-side step visualisation of the Row-by-Row
//! and ZigZag strategies on the Example-2 layer.
//!
//! ```bash
//! cargo run --release --example visualize_strategy
//! ```
//!
//! Prints the ASCII grids for both strategies and writes the SVG versions
//! (`figures/fig9_row.svg`, `figures/fig9_zigzag.svg`). Also dumps the
//! exact step-2 sets the paper's Example 2 lists, so the correspondence is
//! visible in the terminal.

use convoffload::conv::ConvLayer;
use convoffload::strategy;
use convoffload::viz;

fn main() {
    let layer = ConvLayer::new(2, 5, 5, 3, 3, 2, 1, 1).expect("example layer");
    let group = 2;

    std::fs::create_dir_all("figures").expect("mkdir figures");

    for (name, s) in [
        ("row", strategy::row_by_row(&layer, group)),
        ("zigzag", strategy::zigzag(&layer, group)),
    ] {
        let steps = s.compile(&layer);
        println!("================ {} ================", s.name);
        println!("{}", viz::render_strategy_ascii(&layer, &steps));

        let svg = viz::render_strategy_svg(&layer, &steps, &format!("{} (Fig. 9)", s.name));
        let path = format!("figures/fig9_{name}.svg");
        std::fs::write(&path, svg).expect("write svg");
        println!("wrote {path}\n");

        // Example-2 correspondence: the step-2 sets.
        let s2 = &steps[1];
        println!(
            "step 2 sets — |F^inp| = {} px, |I^slice| = {} px, |W| = {} patches",
            s2.free_inp.len(),
            s2.load_inp.len(),
            s2.write.len()
        );
        println!("  F_2^inp pixels (spatial ids): {:?}", s2.free_inp.to_vec());
        println!("  I_2^slice pixels            : {:?}", s2.load_inp.to_vec());
        println!("  W_2 patches                 : {:?}\n", s2.write.to_vec());
    }

    // The paper's Example-2 numbers (in elements = pixels × C_in):
    // Row: M_2^inp = 32; ZigZag: M_2^inp = 24.
    let sim = convoffload::sim::Simulator::new(
        layer,
        convoffload::platform::Platform::new(
            convoffload::platform::Accelerator::for_group_size(&layer, group),
        ),
    );
    let row = sim.run(&strategy::row_by_row(&layer, group)).unwrap();
    let zig = sim.run(&strategy::zigzag(&layer, group)).unwrap();
    println!(
        "Example 2 check: M_2^inp row = {} el (paper: 32), zigzag = {} el (paper: 24)",
        row.steps[1].resident_input_elements, zig.steps[1].resident_input_elements
    );
    assert_eq!(row.steps[1].resident_input_elements, 32);
    assert_eq!(zig.steps[1].resident_input_elements, 24);
    println!("visualize_strategy OK");
}

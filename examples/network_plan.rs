//! Network-level planning demo: plan LeNet-5, ResNet-8 and the
//! depthwise-separable mobilenet_slim trunk with the portfolio race, then
//! re-plan to show the strategy cache taking over, and finally plan LeNet-5
//! under the double-buffered duration model to show hidden transfer time.
//!
//! Run with: `cargo run --release --example network_plan`

use convoffload::config::network_preset;
use convoffload::planner::{
    format_plan_table, AcceleratorSpec, NetworkPlanner, PlanOptions, StrategyCache,
};
use convoffload::platform::OverlapMode;

fn main() {
    let cache_dir = std::env::temp_dir().join(format!(
        "convoffload-network-plan-example-{}",
        std::process::id()
    ));
    let options = PlanOptions {
        accelerator: AcceleratorSpec::PerLayerGroup(4),
        seed: 2026,
        anneal_iters: 20_000,
        anneal_starts: 2,
        threads: 0,
        overlap: OverlapMode::Sequential,
    };
    let planner = NetworkPlanner::with_cache(
        options,
        StrategyCache::open(&cache_dir).expect("cache dir"),
    );

    for name in ["lenet5", "resnet8", "mobilenet_slim"] {
        let preset = network_preset(name).expect("preset");
        let plan = planner.plan(&preset).expect("plan");
        print!("{}", format_plan_table(&plan));
        println!();
    }

    // Second pass: every shape is served from the cache — zero anneal work.
    let lenet = network_preset("lenet5").unwrap();
    let again = planner.plan(&lenet).expect("plan");
    println!(
        "re-planned {}: {} hits / {} misses, anneal iterations run: {}",
        again.network, again.cache_hits, again.cache_misses, again.anneal_iters_run
    );

    // Overlapped offloading: same network, double-buffered DMA — the race
    // switches to the makespan objective and the report shows how much
    // transfer time the timeline hides behind compute.
    let db = NetworkPlanner::new(PlanOptions {
        overlap: OverlapMode::DoubleBuffered,
        anneal_iters: 20_000,
        anneal_starts: 2,
        ..PlanOptions::default()
    })
    .plan(&lenet)
    .expect("plan");
    println!(
        "\nlenet5 double-buffered: {} cycles (sequential {}, {} hidden)",
        db.total_duration,
        db.total_sequential_duration,
        db.total_sequential_duration - db.total_duration
    );
    let _ = std::fs::remove_dir_all(&cache_dir);
}

//! End-to-end driver: offload BOTH LeNet-5 convolution layers step by step
//! through the full three-layer stack, with the per-step compute running on
//! the AOT-compiled XLA executables via PJRT.
//!
//! ```bash
//! make artifacts && cargo run --release --example lenet_pipeline
//! ```
//!
//! This is the repo's end-to-end validation (DESIGN.md §5): for every layer
//! it (1) picks a strategy per the accelerator's capacity, (2) validates it,
//! (3) runs the *functional* simulation where every step's MACs execute on
//! the PJRT CPU client (falling back to the Rust oracle when artifacts are
//! absent), (4) checks the assembled output against the whole-layer
//! reference — and also against the whole-layer AOT artifact — and
//! (5) reports δ, bandwidth and memory, plus wall-clock throughput.
//! Results are recorded in EXPERIMENTS.md §End-to-end.

use std::time::Instant;

use convoffload::config::layer_preset;
use convoffload::conv::reference;
use convoffload::optimizer::{OptimizeOptions, Optimizer};
use convoffload::platform::{Accelerator, Platform};
use convoffload::runtime::{artifacts_available, PjrtBackend, Runtime};
use convoffload::sim::{ComputeBackend, RustOracleBackend, Simulator};
use convoffload::strategy;

fn main() {
    let use_pjrt = artifacts_available();
    if !use_pjrt {
        println!("NOTE: artifacts/ missing — compute falls back to the rust oracle.");
        println!("      run `make artifacts` for the full PJRT path.\n");
    }

    let mut total_macs = 0u64;
    let mut total_wall = 0.0f64;

    for (preset_name, group) in [("lenet5-conv1", 4), ("lenet5-conv2", 4)] {
        let preset = layer_preset(preset_name).expect("preset exists");
        let layer = preset.layer;
        let acc = Accelerator::for_group_size(&layer, group);
        println!("== {preset_name}: {layer}");
        println!(
            "   accelerator: nbop_PE={} size_MEM={} → K_min={} steps",
            acc.nbop_pe,
            acc.size_mem,
            acc.k_min(&layer)
        );

        // Strategy: polished optimizer output for conv2 (small |X|),
        // zigzag for conv1 (784 patches — heuristic regime, like the paper).
        let strat = if layer.n_patches() <= 144 {
            let opt = Optimizer::new(OptimizeOptions {
                group_size: group,
                anneal_iters: 100_000,
                ..Default::default()
            });
            let res = opt.optimize(&layer, &acc);
            println!(
                "   strategy: {} (method {:?}, gain over heuristics {:.1}%)",
                res.strategy.name,
                res.method,
                res.gain_over_heuristics() * 100.0
            );
            res.strategy
        } else {
            let s = strategy::zigzag(&layer, group);
            println!("   strategy: {}", s.name);
            s
        };

        // Validate against the formalism (reload bound = H_K for scans).
        let check = strategy::validate(&layer, &acc, &strat, layer.h_k as u32);
        assert!(check.is_valid(), "strategy must validate: {:?}", check.violations);

        // Synthetic input/weights (deterministic).
        let input = reference::synth_tensor(layer.input_dims().len(), 42);
        let kernels = reference::synth_tensor(layer.kernel_elements(), 43);

        // Functional run on the selected backend.
        let sim = Simulator::new(layer, Platform::new(acc));
        let t0 = Instant::now();
        let report = if use_pjrt {
            let mut backend = PjrtBackend::from_default_dir().expect("runtime");
            sim.run_functional(&strat, &input, &kernels, &mut backend)
        } else {
            let mut backend = RustOracleBackend;
            sim.run_functional(&strat, &input, &kernels, &mut backend)
        }
        .expect("functional simulation");
        let wall = t0.elapsed().as_secs_f64();

        let err = report.max_abs_error.unwrap();
        assert!(
            report.functional_ok(1e-3).unwrap(),
            "stepwise output must match the reference (err {err:.2e})"
        );
        println!(
            "   δ = {} cycles | loads {} el | peak mem {}/{} el | {} compute steps",
            report.duration,
            report.total_loaded(),
            report.peak_occupancy,
            acc.size_mem,
            report.n_compute_steps()
        );
        println!(
            "   functional: max |err| = {err:.2e} vs reference conv ({})",
            if use_pjrt { "PJRT backend" } else { "rust oracle" }
        );

        // Cross-check against the whole-layer AOT artifact when available.
        if use_pjrt {
            let mut rt = Runtime::from_default_dir().expect("runtime");
            if let Some(v) = rt
                .manifest
                .find_layer(layer.c_in, layer.h_in, layer.w_in, layer.n_kernels, layer.h_k)
                .cloned()
            {
                let out = rt
                    .execute_f32(
                        &v.file,
                        &[
                            (&input, &[v.c_in, v.h_in, v.w_in]),
                            (&kernels, &[v.n, v.c_in, v.h_k, v.w_k]),
                        ],
                    )
                    .expect("layer artifact executes");
                let stepwise = report.output.as_ref().unwrap();
                let max_err = out
                    .iter()
                    .zip(stepwise)
                    .map(|(a, b)| (a - b).abs())
                    .fold(0f32, f32::max);
                println!("   whole-layer AOT artifact agreement: max |err| = {max_err:.2e}");
                assert!(max_err < 1e-3);
            }
        }

        let macs = report.totals.total.macs;
        total_macs += macs;
        total_wall += wall;
        println!(
            "   wall {:.3}s → {:.2} MMAC/s through the {} backend\n",
            wall,
            macs as f64 / wall / 1e6,
            if use_pjrt { "pjrt" } else { "oracle" }
        );
    }

    println!(
        "pipeline total: {:.2} MMACs in {:.3}s ({:.2} MMAC/s)",
        total_macs as f64 / 1e6,
        total_wall,
        total_macs as f64 / total_wall / 1e6
    );
    println!("lenet_pipeline OK");
}

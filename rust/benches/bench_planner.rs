//! Planner benchmarks: portfolio lanes, cold whole-network planning, and the
//! cached re-planning path (which must be dominated by cache-file reads).

use convoffload::config::network_preset;
use convoffload::config::presets::paper_sweep_layer;
use convoffload::planner::{
    portfolio_entries, run_entry, AcceleratorSpec, NetworkPlanner, PlanOptions,
    StrategyCache,
};
use convoffload::util::bench::BenchSuite;

fn quick_plan_options() -> PlanOptions {
    PlanOptions {
        accelerator: AcceleratorSpec::PerLayerGroup(4),
        seed: 2026,
        anneal_iters: 2_000,
        anneal_starts: 1,
        threads: 0,
    }
}

fn main() {
    let mut suite = BenchSuite::new("planner");

    // Single lanes on the 12x12 sweep layer (100 patches, k = 25).
    {
        let layer = paper_sweep_layer(12);
        let entries = portfolio_entries(2026, 5_000, 1);
        suite.bench("portfolio_lane_zigzag_12x12_g4", move || {
            run_entry(&layer, 4, 25, &entries[1]).loaded_pixels
        });
    }
    {
        let layer = paper_sweep_layer(12);
        let entries = portfolio_entries(2026, 5_000, 1);
        suite.bench("portfolio_lane_anneal5k_12x12_g4", move || {
            run_entry(&layer, 4, 25, &entries[5]).loaded_pixels
        });
    }

    // Whole-network planning, cold — what one `plan-network lenet5` costs.
    {
        let preset = network_preset("lenet5").expect("preset");
        let planner = NetworkPlanner::new(quick_plan_options());
        suite.bench("plan_lenet5_cold_anneal2k", move || {
            planner.plan(&preset).expect("plan").total_duration
        });
    }

    // Warm cache: repeated planning of the same network.
    {
        let preset = network_preset("lenet5").expect("preset");
        let dir = std::env::temp_dir().join(format!(
            "convoffload-bench-planner-{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        let planner = NetworkPlanner::with_cache(
            quick_plan_options(),
            StrategyCache::open(&dir).expect("cache"),
        );
        planner.plan(&preset).expect("warm-up plan");
        suite.bench("plan_lenet5_cached", move || {
            planner.plan(&preset).expect("plan").total_duration
        });
    }

    suite.run();
}

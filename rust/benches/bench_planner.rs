//! Planner benchmarks: portfolio lanes, per-layer anneal iteration
//! throughput, cold whole-network planning, and the cached re-planning path
//! (which must be dominated by cache-file reads).
//!
//! `--json [path]` (or `CONVOFFLOAD_BENCH_JSON=<path>`) additionally writes
//! a machine-readable report — default `BENCH_planner.json` — with the raw
//! measurements plus two derived sections:
//!
//! * `"anneal"` — per network layer, annealing iterations/second (the
//!   delta-evaluation speedup metric tracked since PR 2; the acceptance bar
//!   was ≥ 3× on the lenet5/conv1 geometry);
//! * `"plan"`   — end-to-end `plan-network` wall time per network;
//! * `"batch"`  — the `plan-batch` zoo (lenet5 ×2, resnet8, mobilenet_slim):
//!   cold vs warm-cache wall time plus the cross-network dedup ratio.
//!
//! CI runs `cargo bench --bench bench_planner -- --quick --json` and uploads
//! the file as a workflow artifact, so the repo's perf trajectory is
//! machine-readable from every commit (EXPERIMENTS.md §Perf).

use convoffload::config::presets::paper_sweep_layer;
use convoffload::config::{network_preset, NetworkPreset};
use convoffload::optimizer::search;
use convoffload::planner::{
    portfolio_entries, run_entry, AcceleratorSpec, BatchPlanner, BatchStats,
    NetworkPlanner, PlanOptions, ShardedStrategyCache, StrategyCache,
};
use convoffload::platform::Accelerator;
use convoffload::strategy;
use convoffload::util::bench::{
    json_output_path, quick_mode, write_json_report, BenchSuite, Measurement,
};
use convoffload::util::json::Json;

fn quick_plan_options() -> PlanOptions {
    PlanOptions {
        accelerator: AcceleratorSpec::PerLayerGroup(4),
        seed: 2026,
        anneal_iters: 2_000,
        anneal_starts: 1,
        threads: 0,
        overlap: convoffload::platform::OverlapMode::Sequential,
        dma_channels: 1,
        compute_units: 1,
    }
}

/// One anneal-throughput probe: a fixed-budget `search::anneal` run on a
/// named layer geometry at group bound 4 (the §7.1 planning convention).
struct AnnealProbe {
    /// `network/layer` label as it appears in EXPERIMENTS.md tables.
    layer_label: &'static str,
    /// Bench name (also the measurement key in the JSON report).
    bench_name: &'static str,
    iters: u64,
}

fn anneal_probes(quick: bool) -> Vec<AnnealProbe> {
    // Budgets keep one bench call in the tens-of-milliseconds range; the
    // iterations/second figure is budget-independent.
    let iters = if quick { 500 } else { 2_000 };
    vec![
        AnnealProbe {
            layer_label: "lenet5/conv1",
            bench_name: "anneal_iters_lenet5_conv1_g4",
            iters,
        },
        AnnealProbe {
            layer_label: "lenet5/conv2",
            bench_name: "anneal_iters_lenet5_conv2_g4",
            iters,
        },
        AnnealProbe {
            layer_label: "resnet8/conv1",
            bench_name: "anneal_iters_resnet8_conv1_g4",
            iters,
        },
        AnnealProbe {
            layer_label: "resnet8/conv2a",
            bench_name: "anneal_iters_resnet8_conv2a_g4",
            iters,
        },
    ]
}

/// The batch-bench workload: the EXPERIMENTS.md zoo, with lenet5 twice so
/// the cold pass exercises cross-network dedup.
fn zoo() -> Vec<NetworkPreset> {
    ["lenet5", "lenet5", "resnet8", "mobilenet_slim"]
        .iter()
        .map(|n| network_preset(n).expect("zoo preset"))
        .collect()
}

/// Resolve a `network/layer` label to its preset `ConvLayer`.
fn probe_layer(label: &str) -> convoffload::conv::ConvLayer {
    let (net, stage) = label.split_once('/').expect("label is network/stage");
    let preset = network_preset(net).expect("network preset");
    preset
        .stages
        .iter()
        .find(|s| s.name == stage)
        .expect("stage in preset")
        .layer
}

fn main() {
    let quick = quick_mode();
    let mut suite = BenchSuite::new("planner");

    // Single lanes on the 12x12 sweep layer (100 patches, k = 25).
    {
        let layer = paper_sweep_layer(12);
        let acc = Accelerator::for_group_size(&layer, 4);
        let entries = portfolio_entries(2026, 5_000, 1);
        suite.bench("portfolio_lane_zigzag_12x12_g4", move || {
            run_entry(&layer, &acc, 4, 25, &entries[1]).loaded_pixels
        });
    }
    {
        let layer = paper_sweep_layer(12);
        let acc = Accelerator::for_group_size(&layer, 4);
        let entries = portfolio_entries(2026, 5_000, 1);
        suite.bench("portfolio_lane_anneal5k_12x12_g4", move || {
            run_entry(&layer, &acc, 4, 25, &entries[5]).loaded_pixels
        });
    }

    // Anneal iteration throughput on the real network-layer geometries —
    // the delta-evaluation speedup metric. The MIP start is precomputed so
    // the closure times the annealing loop itself (plus one eval build).
    for probe in anneal_probes(quick) {
        let layer = probe_layer(probe.layer_label);
        let g = 4usize;
        let acc = Accelerator::for_group_size(&layer, g);
        let k = acc.k_min(&layer);
        let start = strategy::zigzag(&layer, g).groups;
        let iters = probe.iters;
        suite.bench(probe.bench_name, move || {
            search::anneal(&layer, g, k, &start, iters, 2026)
                .iter()
                .map(|gr| gr.len() as u64)
                .sum()
        });
    }

    // Whole-network planning, cold — what one `plan-network <net>` costs.
    {
        let preset = network_preset("lenet5").expect("preset");
        let planner = NetworkPlanner::new(quick_plan_options());
        suite.bench("plan_lenet5_cold_anneal2k", move || {
            planner.plan(&preset).expect("plan").total_duration
        });
    }
    {
        let preset = network_preset("resnet8").expect("preset");
        let planner = NetworkPlanner::new(quick_plan_options());
        suite.bench("plan_resnet8_cold_anneal2k", move || {
            planner.plan(&preset).expect("plan").total_duration
        });
    }

    // Warm cache: repeated planning of the same network.
    {
        let preset = network_preset("lenet5").expect("preset");
        let dir = std::env::temp_dir().join(format!(
            "convoffload-bench-planner-{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        let planner = NetworkPlanner::with_cache(
            quick_plan_options(),
            StrategyCache::open(&dir).expect("cache"),
        );
        planner.plan(&preset).expect("warm-up plan");
        suite.bench("plan_lenet5_cached", move || {
            planner.plan(&preset).expect("plan").total_duration
        });
    }

    // Batch planning, cold — the zoo through `plan-batch` with no cache, so
    // the only reuse is in-batch dedup (10 stages -> 7 unique problems).
    {
        let presets = zoo();
        let planner = BatchPlanner::new(quick_plan_options());
        suite.bench("plan_batch_zoo_cold_anneal2k", move || {
            let report = planner.plan_batch(&presets).expect("batch plan");
            report.plans.iter().map(|p| p.total_duration).sum::<u64>()
        });
    }

    // Batch planning, warm — same zoo against a pre-warmed sharded cache;
    // every stage must resolve as a store hit with zero anneal iterations.
    {
        let presets = zoo();
        let dir = std::env::temp_dir().join(format!(
            "convoffload-bench-batch-{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        let planner = BatchPlanner::with_cache(
            quick_plan_options(),
            ShardedStrategyCache::open(&dir).expect("sharded cache"),
        );
        planner.plan_batch(&presets).expect("warm-up batch");
        suite.bench("plan_batch_zoo_warm_cache", move || {
            let report = planner.plan_batch(&presets).expect("batch plan");
            assert_eq!(report.stats.anneal_iters_run, 0);
            report.plans.iter().map(|p| p.total_duration).sum::<u64>()
        });
    }

    // Dedup accounting is budget-independent, so probe it once with a tiny
    // anneal budget rather than timing it.
    let batch_stats = {
        let planner = BatchPlanner::new(PlanOptions {
            anneal_iters: 50,
            anneal_starts: 1,
            ..quick_plan_options()
        });
        planner.plan_batch(&zoo()).expect("stats probe").stats
    };

    let results = suite.run();

    if let Some(path) = json_output_path("BENCH_planner.json") {
        write_report(&path, &results, quick, &batch_stats);
    }
}

fn find<'a>(results: &'a [Measurement], name: &str) -> Option<&'a Measurement> {
    results.iter().find(|m| m.name == name)
}

/// Compose the derived sections and write the JSON report.
fn write_report(
    path: &std::path::Path,
    results: &[Measurement],
    quick: bool,
    batch_stats: &BatchStats,
) {
    let mut anneal_rows: Vec<Json> = Vec::new();
    for probe in anneal_probes(quick) {
        let Some(m) = find(results, probe.bench_name) else { continue };
        let secs = m.median.as_secs_f64();
        let iters_per_sec =
            if secs > 0.0 { probe.iters as f64 / secs } else { 0.0 };
        let layer = probe_layer(probe.layer_label);
        let mut row = Json::obj();
        row.set("layer", probe.layer_label)
            .set("geometry", format!("{layer}"))
            .set("group", 4u64)
            .set("iters_per_call", probe.iters)
            .set("median_ns", m.median.as_nanos() as u64)
            .set("iters_per_sec", iters_per_sec);
        anneal_rows.push(row);
    }

    let mut plan_rows: Vec<Json> = Vec::new();
    for (net, bench_name) in [
        ("lenet5", "plan_lenet5_cold_anneal2k"),
        ("resnet8", "plan_resnet8_cold_anneal2k"),
        ("lenet5-cached", "plan_lenet5_cached"),
    ] {
        let Some(m) = find(results, bench_name) else { continue };
        let mut row = Json::obj();
        row.set("network", net)
            .set("median_ns", m.median.as_nanos() as u64)
            .set("seconds", m.median.as_secs_f64());
        plan_rows.push(row);
    }

    // The plan-batch trajectory: cold vs warm-cache wall time plus the
    // (budget-independent) dedup accounting for the zoo workload.
    let mut batch = Json::obj();
    batch
        .set("networks", batch_stats.networks)
        .set("stages_total", batch_stats.stages_total)
        .set("unique_problems", batch_stats.unique_problems)
        .set("dedup_hits", batch_stats.dedup_hits)
        .set(
            "cross_network_dedup_hits",
            batch_stats.cross_network_dedup_hits,
        )
        .set(
            "dedup_ratio",
            if batch_stats.stages_total > 0 {
                batch_stats.dedup_hits as f64 / batch_stats.stages_total as f64
            } else {
                0.0
            },
        );
    if let Some(m) = find(results, "plan_batch_zoo_cold_anneal2k") {
        batch.set("cold_median_ns", m.median.as_nanos() as u64);
    }
    if let Some(m) = find(results, "plan_batch_zoo_warm_cache") {
        batch.set("warm_cache_median_ns", m.median.as_nanos() as u64);
    }
    if let (Some(cold), Some(warm)) = (
        find(results, "plan_batch_zoo_cold_anneal2k"),
        find(results, "plan_batch_zoo_warm_cache"),
    ) {
        let warm_ns = warm.median.as_nanos() as f64;
        if warm_ns > 0.0 {
            batch.set(
                "cold_over_warm_speedup",
                cold.median.as_nanos() as f64 / warm_ns,
            );
        }
    }

    let mut extra = Json::obj();
    extra
        .set("anneal", Json::Arr(anneal_rows))
        .set("plan", Json::Arr(plan_rows))
        .set("batch", batch);
    match write_json_report(path, "planner", results, extra) {
        Ok(()) => println!("wrote {}", path.display()),
        Err(e) => eprintln!("error: could not write {}: {e}", path.display()),
    }
}

//! Simulator benchmarks: step-transaction throughput on the paper's layers.
//!
//! These are the §Perf L3 tracking benches — the simulator's `run` is the
//! inner loop of every figure sweep and of the optimizer's objective, so its
//! throughput bounds the whole harness.

use convoffload::config::layer_preset;
use convoffload::optimizer::grouping_duration;
use convoffload::platform::{Accelerator, Platform};
use convoffload::sim::{RustOracleBackend, Simulator};
use convoffload::strategy;
use convoffload::util::bench::BenchSuite;

fn main() {
    let mut suite = BenchSuite::new("sim");

    // Logical simulation of the Example-2 layer (small).
    {
        let layer = layer_preset("example1").unwrap().layer;
        let acc = Accelerator::for_group_size(&layer, 2);
        let sim = Simulator::new(layer, Platform::new(acc));
        let s = strategy::zigzag(&layer, 2);
        suite.bench("sim_logical_example1_g2", move || {
            sim.run(&s).unwrap().duration
        });
    }

    // Logical simulation of LeNet-5 conv1 (784 patches → 196 steps).
    {
        let layer = layer_preset("lenet5-conv1").unwrap().layer;
        let acc = Accelerator::for_group_size(&layer, 4);
        let sim = Simulator::new(layer, Platform::new(acc));
        let s = strategy::zigzag(&layer, 4);
        suite.bench("sim_logical_lenet1_g4", move || {
            sim.run(&s).unwrap().duration
        });
    }

    // Strategy compile alone (set construction).
    {
        let layer = layer_preset("lenet5-conv1").unwrap().layer;
        let s = strategy::zigzag(&layer, 4);
        suite.bench("strategy_compile_lenet1_g4", move || {
            s.compile(&layer).len() as u64
        });
    }

    // The optimizer's fast objective (what annealing calls per move-batch).
    {
        let layer = layer_preset("lenet5-conv1").unwrap().layer;
        let acc = Accelerator::for_group_size(&layer, 4);
        let s = strategy::zigzag(&layer, 4);
        suite.bench("objective_eval_lenet1_g4", move || {
            grouping_duration(&layer, &acc, &s.groups)
        });
    }

    // Functional simulation with the Rust oracle (values move through the
    // modelled memories).
    {
        let layer = layer_preset("example1").unwrap().layer;
        let acc = Accelerator::for_group_size(&layer, 2);
        let sim = Simulator::new(layer, Platform::new(acc));
        let s = strategy::zigzag(&layer, 2);
        let input =
            convoffload::conv::reference::synth_tensor(layer.input_dims().len(), 1);
        let kernels =
            convoffload::conv::reference::synth_tensor(layer.kernel_elements(), 2);
        suite.bench("sim_functional_oracle_example1", move || {
            let mut b = RustOracleBackend;
            sim.run_functional(&s, &input, &kernels, &mut b)
                .unwrap()
                .duration
        });
    }

    suite.run();
}

//! Solver benchmarks: the §5 pipeline's three engines.
//!
//! The paper's solve budget was 0.5–5 h per point on a 24-core Xeon; these
//! benches document how far under that budget the reproduction runs.

use std::time::Duration;

use convoffload::config::presets::paper_sweep_layer;
use convoffload::ilp::{Cmp, LinExpr, Model};
use convoffload::optimizer::{build_s1_model, exact, search, OptimizeOptions, Optimizer};
use convoffload::platform::Accelerator;
use convoffload::solver::{solve_lp, solve_milp, BranchBoundOptions};
use convoffload::strategy;
use convoffload::util::bench::BenchSuite;

fn main() {
    let mut suite = BenchSuite::new("solver");

    // LP relaxation of the §5 model for the 4x4 layer.
    {
        let layer = paper_sweep_layer(4);
        let acc = Accelerator::for_group_size(&layer, 2);
        let (model, _info) = build_s1_model(&layer, &acc, 2, 4);
        suite.bench("lp_relaxation_s1_4x4", move || {
            match solve_lp(&model, &[]) {
                convoffload::solver::LpOutcome::Optimal { objective, .. } => {
                    objective as u64
                }
                _ => 0,
            }
        });
    }

    // Full MILP solve (exact §5) for the 4x4 layer.
    {
        let layer = paper_sweep_layer(4);
        let acc = Accelerator::for_group_size(&layer, 2);
        suite.bench("milp_s1_4x4_g2", move || {
            let (model, _) = build_s1_model(&layer, &acc, 2, 4);
            let sol = solve_milp(&model, &BranchBoundOptions::default());
            sol.nodes
        });
    }

    // Generic MILP on a knapsack (solver substrate sanity / regression).
    {
        suite.bench("milp_knapsack_12", || {
            let values = [4., 2., 10., 1., 2., 7., 8., 3., 6., 5., 9., 4.];
            let weights = [3., 1., 6., 1., 2., 5., 4., 2., 3., 4., 5., 3.];
            let mut m = Model::minimize();
            let vars: Vec<_> =
                (0..12).map(|i| m.bool_var(&format!("x{i}"))).collect();
            let mut w = LinExpr::new();
            let mut obj = LinExpr::new();
            for (i, v) in vars.iter().enumerate() {
                w.add(v.0, weights[i]);
                obj.add(v.0, -values[i]);
            }
            m.constrain(w, Cmp::Le, 15.0);
            m.set_objective(obj);
            solve_milp(&m, &BranchBoundOptions::default()).nodes
        });
    }

    // Specialized exact engine on the 5x5 layer (9 patches).
    {
        let layer = paper_sweep_layer(5);
        suite.bench("exact_dfs_5x5_g2", move || {
            let groups =
                exact::solve_exact(&layer, 2, 5, Duration::from_secs(60), None)
                    .expect("finishes");
            groups.len() as u64
        });
    }

    // Annealing polish on the 12x12 layer (100 patches), fixed iteration
    // count so the measurement is the per-iteration cost.
    {
        let layer = paper_sweep_layer(12);
        let start = strategy::zigzag(&layer, 4).groups;
        suite.bench("anneal_10k_iters_12x12_g4", move || {
            let groups = search::anneal(&layer, 4, 25, &start, 10_000, 99);
            groups.len() as u64
        });
    }

    // Whole-pipeline optimize call (what a Fig. 13 cell costs).
    {
        let layer = paper_sweep_layer(8);
        let acc = Accelerator::for_group_size(&layer, 4);
        suite.bench("optimize_fig13_cell_8x8_g4", move || {
            let opt = Optimizer::new(OptimizeOptions {
                group_size: 4,
                anneal_iters: 50_000,
                ..Default::default()
            });
            opt.optimize(&layer, &acc).duration
        });
    }

    suite.run();
}

//! PJRT runtime benchmarks: artifact execution latency on the request path.
//!
//! §Perf L3 target: the coordinator (gather + dispatch) must not dominate
//! the XLA executable's own compute time. Skipped (with a message) when
//! `make artifacts` has not produced the artifacts yet.

use convoffload::conv::{reference, ConvLayer};
use convoffload::runtime::{artifacts_available, PjrtBackend, Runtime};
use convoffload::sim::ComputeBackend;
use convoffload::util::bench::BenchSuite;

fn main() {
    if !artifacts_available() {
        println!("## bench suite: runtime");
        println!("skipped: artifacts/ not built (run `make artifacts`)");
        return;
    }
    let mut suite = BenchSuite::new("runtime");

    // Raw executable dispatch: the paper-sweep step kernel [8,9]@[9,1].
    {
        let mut rt = Runtime::from_default_dir().expect("runtime");
        let v = rt.manifest.find_step(9, 1, 8).expect("variant").clone();
        let patches: Vec<f32> = (0..8 * 9).map(|i| i as f32).collect();
        let kernels = vec![1f32; 9];
        // warm the compile cache outside the measurement
        rt.execute_f32(&v.file, &[(&patches, &[8, 9]), (&kernels, &[9, 1])])
            .unwrap();
        suite.bench("pjrt_execute_step_paper_g8", move || {
            rt.execute_f32(&v.file, &[(&patches, &[8, 9]), (&kernels, &[9, 1])])
                .unwrap()
                .len() as u64
        });
    }

    // Backend-level step compute (includes padding/chunking logic).
    {
        let layer = ConvLayer::new(2, 5, 5, 3, 3, 2, 1, 1).unwrap();
        let input = reference::synth_tensor(layer.input_dims().len(), 1);
        let kernels = reference::synth_tensor(layer.kernel_elements(), 2);
        let km = reference::kernel_matrix(&layer, &kernels);
        let group: Vec<u32> = vec![0, 1];
        let pm = reference::im2col_group(&layer, &input, &group);
        let mut backend = PjrtBackend::from_default_dir().expect("backend");
        // warm-up
        backend.step_compute(&layer, &pm, &km, 2).unwrap();
        suite.bench("pjrt_backend_step_example1_g2", move || {
            backend.step_compute(&layer, &pm, &km, 2).unwrap().len() as u64
        });
    }

    // Functional end-to-end simulation through PJRT (the e2e example body).
    {
        let layer = ConvLayer::new(2, 5, 5, 3, 3, 2, 1, 1).unwrap();
        let acc = convoffload::platform::Accelerator::for_group_size(&layer, 2);
        let sim = convoffload::sim::Simulator::new(
            layer,
            convoffload::platform::Platform::new(acc),
        );
        let s = convoffload::strategy::zigzag(&layer, 2);
        let input = reference::synth_tensor(layer.input_dims().len(), 1);
        let kernels = reference::synth_tensor(layer.kernel_elements(), 2);
        let mut backend = PjrtBackend::from_default_dir().expect("backend");
        suite.bench("pjrt_functional_example1_g2", move || {
            sim.run_functional(&s, &input, &kernels, &mut backend)
                .unwrap()
                .duration
        });
    }

    suite.run();
}

//! Ablation benches for the design choices DESIGN.md §8 calls out:
//! kernel-split multi-pass vs single-pass S1, direct-S1 vs GeMM-im2col
//! offload, write-back policies, and ordering heuristics head-to-head.
//!
//! Unlike the perf benches these also *print the modelled costs* (δ, traffic,
//! peak memory), so `cargo bench ablation` doubles as the ablation table
//! generator referenced in EXPERIMENTS.md.

use convoffload::conv::{gemm_offload, ConvLayer};
use convoffload::optimizer::grouping_duration;
use convoffload::platform::{Accelerator, Platform};
use convoffload::sim::Simulator;
use convoffload::strategy::{self, MultiPassStrategy, WritebackPolicy};
use convoffload::util::bench::BenchSuite;

fn main() {
    print_ablation_tables();

    let mut suite = BenchSuite::new("ablation");

    // Multi-pass execution cost (simulation of P passes).
    {
        let layer = ConvLayer::new(6, 14, 14, 5, 5, 16, 1, 1).unwrap();
        let sub = {
            let mut s = layer;
            s.n_kernels = 4;
            s
        };
        let acc = Accelerator::for_group_size(&sub, 4);
        let mp = MultiPassStrategy::new(&layer, 4, strategy::zigzag(&sub, 4)).unwrap();
        suite.bench("multipass_4x_lenet2_sim", move || {
            mp.run(&layer, &acc).unwrap().duration
        });
    }

    // GeMM tiling search.
    {
        let layer = ConvLayer::new(1, 12, 12, 3, 3, 4, 1, 1).unwrap();
        let acc = Accelerator::for_group_size(&layer, 4);
        suite.bench("gemm_best_tiling_12x12", move || {
            gemm_offload::best_tiling(&layer, &acc).unwrap().1.steps
        });
    }

    // Ordering heuristics on one mid-size layer.
    {
        let layer = ConvLayer::square(1, 12, 3, 1);
        let acc = Accelerator::for_group_size(&layer, 4);
        suite.bench("orderings_head_to_head_12x12", move || {
            let mut acc_sum = 0u64;
            for o in strategy::Ordering::all() {
                let s = strategy::order_to_groups(&layer, &o.order(&layer), 4);
                acc_sum += grouping_duration(&layer, &acc, &s.groups);
            }
            acc_sum
        });
    }

    suite.run();
}

fn print_ablation_tables() {
    println!("### Ablation 1 — kernel-split multi-pass (LeNet-5 conv2, zigzag g=4)");
    println!("kernels/pass | passes | δ | input loads (el) | peak mem (el) | kernel-mem saved");
    let layer = ConvLayer::new(6, 14, 14, 5, 5, 16, 1, 1).unwrap();
    for kpp in [16usize, 8, 4, 2] {
        let sub = {
            let mut s = layer;
            s.n_kernels = kpp;
            s
        };
        let acc = Accelerator::for_group_size(&sub, 4);
        let mp = MultiPassStrategy::new(&layer, kpp, strategy::zigzag(&sub, 4)).unwrap();
        let r = mp.run(&layer, &acc).unwrap();
        println!(
            "{kpp:>12} | {:>6} | {:>6} | {:>16} | {:>13} | {:>16}",
            mp.n_passes(),
            r.duration,
            r.totals.total.loaded_elements,
            r.peak_occupancy,
            mp.kernel_memory_saving(&layer),
        );
    }

    println!("\n### Ablation 2 — direct S1 vs GeMM-im2col offload (same machine)");
    println!("layer | S1 δ | GeMM δ | im2col input-traffic ratio");
    for (name, layer, g) in [
        ("12x12/3x3/N4", ConvLayer::new(1, 12, 12, 3, 3, 4, 1, 1).unwrap(), 4),
        ("lenet2", ConvLayer::new(6, 14, 14, 5, 5, 16, 1, 1).unwrap(), 4),
    ] {
        let acc = Accelerator::for_group_size(&layer, g);
        let s1 = strategy::zigzag(&layer, g);
        if let Some((gemm_dur, s1_dur, ratio)) =
            gemm_offload::compare_with_s1(&layer, &acc, &s1)
        {
            println!("{name} | {s1_dur} | {gemm_dur} | {ratio:.2}x");
        } else {
            println!("{name} | - | no feasible GeMM tiling | -");
        }
    }

    println!("\n### Ablation 3 — write-back policy (zigzag g=4, t_w=1)");
    println!("layer | policy | δ | peak mem (el)");
    for (name, layer) in [
        ("paper-12", ConvLayer::square(1, 12, 3, 1)),
        ("example1", ConvLayer::new(2, 5, 5, 3, 3, 2, 1, 1).unwrap()),
    ] {
        let base = Accelerator::for_group_size(&layer, 4);
        let acc = Accelerator {
            t_w: 1,
            size_mem: base.size_mem + (layer.n_patches() * layer.c_out()) as u64,
            ..base
        };
        let sim = Simulator::new(layer, Platform::new(acc));
        for policy in [WritebackPolicy::EveryStep, WritebackPolicy::AtEnd] {
            let mut s = strategy::zigzag(&layer, 4);
            s.writeback = policy;
            let r = sim.run(&s).unwrap();
            println!(
                "{name} | {} | {} | {}",
                policy.as_str(),
                r.duration,
                r.peak_occupancy
            );
        }
    }

    println!("\n### Ablation 4 — ordering heuristics (g=4, δ per layer)");
    println!("layer | row-by-row | zigzag | hilbert | diagonal");
    for (name, layer) in [
        ("8x8", ConvLayer::square(1, 8, 3, 1)),
        ("12x12", ConvLayer::square(1, 12, 3, 1)),
        ("lenet1", ConvLayer::new(1, 32, 32, 5, 5, 6, 1, 1).unwrap()),
    ] {
        let acc = Accelerator::for_group_size(&layer, 4);
        let d = |s: &convoffload::strategy::GroupedStrategy| {
            grouping_duration(&layer, &acc, &s.groups)
        };
        println!(
            "{name} | {} | {} | {} | {}",
            d(&strategy::row_by_row(&layer, 4)),
            d(&strategy::zigzag(&layer, 4)),
            d(&strategy::hilbert(&layer, 4)),
            d(&strategy::diagonal(&layer, 4)),
        );
    }
    println!();
}

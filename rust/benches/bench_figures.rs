//! One bench per paper table/figure: end-to-end regeneration cost.
//!
//! These wrap the same generators the `convoffload figures` CLI uses, on
//! reduced grids so a bench iteration stays sub-second; the full grids run
//! in the CLI (see EXPERIMENTS.md for the recorded outputs).

use convoffload::bench_harness as bh;
use convoffload::config::layer_preset;
use convoffload::util::bench::BenchSuite;

fn main() {
    let mut suite = BenchSuite::new("figures");

    // Fig. 11: LeNet-5 conv1, ZigZag vs Row-by-Row over group sizes.
    {
        let layer = layer_preset("lenet5-conv1").unwrap().layer;
        suite.bench("fig11_lenet1_g1_to_12", move || {
            let sizes: Vec<usize> = (1..=12).collect();
            let rows = bh::fig11(&layer, &sizes);
            rows.iter().map(|r| r.zigzag + r.row_by_row).sum()
        });
    }

    // Fig. 12: duration vs input size at group 4 (reduced grid 4..=6).
    suite.bench("fig12_inputs_4_to_6_g4", || {
        let rows = bh::fig12(&[4, 5, 6], 4, 1);
        rows.iter().map(|r| r.opl).sum()
    });

    // Fig. 13: gain heatmap (reduced 2x2 grid).
    suite.bench("fig13_grid_2x2", || {
        let cells = bh::fig13(&[4, 6], &[2, 4], 1);
        cells.iter().map(|c| c.opl).sum()
    });

    // Example 2 (Fig. 9) reproduction: simulate both strategies & compare.
    {
        let layer = convoffload::conv::ConvLayer::new(2, 5, 5, 3, 3, 2, 1, 1).unwrap();
        let acc = convoffload::platform::Accelerator::for_group_size(&layer, 2);
        let sim = convoffload::sim::Simulator::new(
            layer,
            convoffload::platform::Platform::new(acc),
        );
        suite.bench("example2_row_vs_zigzag", move || {
            let row = sim.run(&convoffload::strategy::row_by_row(&layer, 2)).unwrap();
            let zig = sim.run(&convoffload::strategy::zigzag(&layer, 2)).unwrap();
            row.steps[1].resident_input_elements + zig.steps[1].resident_input_elements
        });
    }

    suite.run();
}

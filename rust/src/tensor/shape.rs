//! Shape descriptors for the 3D tensors of Definitions 4–8.

/// Dimensions of a 3D tensor `(channels, height, width)` — Definition 6/8.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Dims3 {
    /// Channels.
    pub c: usize,
    /// Height (rows).
    pub h: usize,
    /// Width (columns).
    pub w: usize,
}

impl Dims3 {
    /// Dimensions `c × h × w`.
    pub fn new(c: usize, h: usize, w: usize) -> Self {
        Dims3 { c, h, w }
    }

    /// Number of scalar elements.
    pub fn len(&self) -> usize {
        self.c * self.h * self.w
    }

    /// True when any dimension is zero.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Number of spatial pixels (channel dimension dropped, Remark 6).
    pub fn spatial(&self) -> usize {
        self.h * self.w
    }
}

impl std::fmt::Display for Dims3 {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}x{}x{}", self.c, self.h, self.w)
    }
}

/// A half-open spatial rectangle `[h0, h1) × [w0, w1)`.
///
/// The spatial footprint of a patch (Definition 10) is a `Rect` of size
/// `H_K × W_K` anchored at `(s_h·i, s_w·j)`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Rect {
    /// First row (inclusive).
    pub h0: usize,
    /// Past-the-end row (exclusive).
    pub h1: usize,
    /// First column (inclusive).
    pub w0: usize,
    /// Past-the-end column (exclusive).
    pub w1: usize,
}

impl Rect {
    /// The rectangle `[h0, h1) × [w0, w1)` (bounds must be ordered).
    pub fn new(h0: usize, h1: usize, w0: usize, w1: usize) -> Self {
        debug_assert!(h0 <= h1 && w0 <= w1);
        Rect { h0, h1, w0, w1 }
    }

    /// Row count.
    pub fn height(&self) -> usize {
        self.h1 - self.h0
    }

    /// Column count.
    pub fn width(&self) -> usize {
        self.w1 - self.w0
    }

    /// Pixel count (`height × width`).
    pub fn area(&self) -> usize {
        self.height() * self.width()
    }

    /// True when `(h, w)` lies inside the rectangle.
    pub fn contains(&self, h: usize, w: usize) -> bool {
        h >= self.h0 && h < self.h1 && w >= self.w0 && w < self.w1
    }

    /// Intersection (possibly empty).
    pub fn intersect(&self, other: &Rect) -> Option<Rect> {
        let h0 = self.h0.max(other.h0);
        let h1 = self.h1.min(other.h1);
        let w0 = self.w0.max(other.w0);
        let w1 = self.w1.min(other.w1);
        if h0 < h1 && w0 < w1 {
            Some(Rect::new(h0, h1, w0, w1))
        } else {
            None
        }
    }

    /// Iterate spatial coordinates in row-major order.
    pub fn iter(&self) -> impl Iterator<Item = (usize, usize)> + '_ {
        (self.h0..self.h1)
            .flat_map(move |h| (self.w0..self.w1).map(move |w| (h, w)))
    }
}

/// A general k-D slice bound (Definition 9) restricted to 3D, kept for
/// completeness of the formalism: `[a, b]` inclusive per dimension.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SliceSpec {
    /// Channel bounds `[a, b]` (inclusive).
    pub c: (usize, usize),
    /// Row bounds `[a, b]` (inclusive).
    pub h: (usize, usize),
    /// Column bounds `[a, b]` (inclusive).
    pub w: (usize, usize),
}

impl SliceSpec {
    /// Element count of the slice.
    pub fn len(&self) -> usize {
        (self.c.1 - self.c.0 + 1)
            * (self.h.1 - self.h.0 + 1)
            * (self.w.1 - self.w.0 + 1)
    }

    /// Always false: inclusive bounds hold at least one element.
    pub fn is_empty(&self) -> bool {
        false // inclusive bounds always contain at least one element
    }

    /// Validate against tensor dims.
    pub fn fits(&self, dims: Dims3) -> bool {
        self.c.0 <= self.c.1
            && self.h.0 <= self.h.1
            && self.w.0 <= self.w.1
            && self.c.1 < dims.c
            && self.h.1 < dims.h
            && self.w.1 < dims.w
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dims_len() {
        let d = Dims3::new(2, 5, 5);
        assert_eq!(d.len(), 50);
        assert_eq!(d.spatial(), 25);
        assert_eq!(d.to_string(), "2x5x5");
    }

    #[test]
    fn rect_basics() {
        let r = Rect::new(1, 4, 2, 5);
        assert_eq!(r.area(), 9);
        assert!(r.contains(1, 2));
        assert!(!r.contains(4, 2));
        assert_eq!(r.iter().count(), 9);
        let first: Vec<_> = r.iter().take(3).collect();
        assert_eq!(first, vec![(1, 2), (1, 3), (1, 4)]);
    }

    #[test]
    fn rect_intersection() {
        let a = Rect::new(0, 3, 0, 3);
        let b = Rect::new(1, 4, 2, 6);
        assert_eq!(a.intersect(&b), Some(Rect::new(1, 3, 2, 3)));
        let c = Rect::new(3, 5, 0, 3);
        assert_eq!(a.intersect(&c), None); // touching edges don't overlap
    }

    #[test]
    fn slice_spec() {
        let d = Dims3::new(2, 5, 5);
        let s = SliceSpec { c: (0, 1), h: (1, 3), w: (2, 4) };
        assert!(s.fits(d));
        assert_eq!(s.len(), 2 * 3 * 3);
        let bad = SliceSpec { c: (0, 2), h: (0, 0), w: (0, 0) };
        assert!(!bad.fits(d));
    }
}

//! [`PixelSet`] — the bitset realizing Assumption 1.
//!
//! The paper abstracts the on-chip memory as a mathematical set with `∪`,
//! `∩`, `∖` and `|·|`. Every simulator transaction and every optimizer move
//! evaluates those operations on pixel sets, so they are the hot path; a
//! word-parallel bitset gives them `O(n/64)` cost and zero allocation for the
//! in-place variants.

use crate::tensor::PixelId;

/// A set of spatial pixel ids over a fixed universe `[0, nbits)`.
#[derive(Clone, PartialEq, Eq, Hash)]
pub struct PixelSet {
    words: Vec<u64>,
    nbits: usize,
}

impl PixelSet {
    /// Empty set over a universe of `nbits` pixels.
    pub fn empty(nbits: usize) -> Self {
        PixelSet { words: vec![0; nbits.div_ceil(64)], nbits }
    }

    /// Full set over the universe.
    pub fn full(nbits: usize) -> Self {
        let mut s = Self::empty(nbits);
        for i in 0..nbits {
            s.insert(i as PixelId);
        }
        s
    }

    /// Build from an iterator of ids.
    pub fn from_iter(nbits: usize, ids: impl IntoIterator<Item = PixelId>) -> Self {
        let mut s = Self::empty(nbits);
        for id in ids {
            s.insert(id);
        }
        s
    }

    /// Universe size.
    pub fn universe(&self) -> usize {
        self.nbits
    }

    #[inline]
    /// Add `index` to the set.
    pub fn insert(&mut self, id: PixelId) {
        debug_assert!((id as usize) < self.nbits, "pixel id out of universe");
        self.words[id as usize / 64] |= 1u64 << (id % 64);
    }

    #[inline]
    /// Remove `index` from the set.
    pub fn remove(&mut self, id: PixelId) {
        debug_assert!((id as usize) < self.nbits);
        self.words[id as usize / 64] &= !(1u64 << (id % 64));
    }

    #[inline]
    /// True when `index` is in the set.
    pub fn contains(&self, id: PixelId) -> bool {
        if (id as usize) >= self.nbits {
            return false;
        }
        self.words[id as usize / 64] >> (id % 64) & 1 == 1
    }

    /// Insert the contiguous id range `[start, end)` using word-level masks —
    /// the simulator/optimizer hot path inserts patch *rows*, which are
    /// contiguous, so this replaces up to 64 single-bit inserts with one
    /// mask OR per word (§Perf L3 optimization, see EXPERIMENTS.md).
    #[inline]
    pub fn insert_range(&mut self, start: u32, end: u32) {
        debug_assert!(end as usize <= self.nbits && start <= end);
        if start == end {
            return;
        }
        let (ws, we) = (start as usize / 64, (end as usize - 1) / 64);
        let lo_mask = !0u64 << (start % 64);
        let hi_mask = !0u64 >> (63 - ((end - 1) % 64));
        if ws == we {
            self.words[ws] |= lo_mask & hi_mask;
        } else {
            self.words[ws] |= lo_mask;
            for w in &mut self.words[ws + 1..we] {
                *w = !0;
            }
            self.words[we] |= hi_mask;
        }
    }

    /// True iff every id in `[start, end)` is present (word-masked; the
    /// allocation-free dual of [`PixelSet::insert_range`]).
    #[inline]
    pub fn contains_range(&self, start: u32, end: u32) -> bool {
        debug_assert!(end as usize <= self.nbits && start <= end);
        if start == end {
            return true;
        }
        let (ws, we) = (start as usize / 64, (end as usize - 1) / 64);
        let lo_mask = !0u64 << (start % 64);
        let hi_mask = !0u64 >> (63 - ((end - 1) % 64));
        if ws == we {
            let m = lo_mask & hi_mask;
            return self.words[ws] & m == m;
        }
        if self.words[ws] & lo_mask != lo_mask {
            return false;
        }
        if self.words[we] & hi_mask != hi_mask {
            return false;
        }
        self.words[ws + 1..we].iter().all(|&w| w == !0)
    }

    /// `|self ∩ [start, end)|` — population count over a contiguous id range,
    /// word-masked like [`PixelSet::insert_range`]. The optimizer's greedy
    /// construction uses it to intersect a patch rectangle row against a set
    /// without materializing the patch's own `PixelSet`.
    #[inline]
    pub fn count_range(&self, start: u32, end: u32) -> usize {
        debug_assert!(end as usize <= self.nbits && start <= end);
        if start == end {
            return 0;
        }
        let (ws, we) = (start as usize / 64, (end as usize - 1) / 64);
        let lo_mask = !0u64 << (start % 64);
        let hi_mask = !0u64 >> (63 - ((end - 1) % 64));
        if ws == we {
            return (self.words[ws] & lo_mask & hi_mask).count_ones() as usize;
        }
        let mut n = (self.words[ws] & lo_mask).count_ones() as usize;
        for &w in &self.words[ws + 1..we] {
            n += w.count_ones() as usize;
        }
        n + (self.words[we] & hi_mask).count_ones() as usize
    }

    /// Allocation-free clone: overwrite `self` with `other`'s contents.
    /// (`Clone::clone_from` would re-allocate the word vector; the annealer's
    /// scoring scratch buffers must not.)
    #[inline]
    pub fn copy_from(&mut self, other: &PixelSet) {
        self.check_same_universe(other);
        self.words.copy_from_slice(&other.words);
    }

    /// Cardinality `|·|`.
    #[inline]
    pub fn len(&self) -> usize {
        self.words.iter().map(|w| w.count_ones() as usize).sum()
    }

    /// True when no bit is set.
    pub fn is_empty(&self) -> bool {
        self.words.iter().all(|&w| w == 0)
    }

    /// Remove every element (universe size unchanged).
    pub fn clear(&mut self) {
        self.words.fill(0);
    }

    fn check_same_universe(&self, other: &PixelSet) {
        debug_assert_eq!(
            self.nbits, other.nbits,
            "PixelSet ops require identical universes"
        );
    }

    /// In-place union `self ∪= other`.
    pub fn union_with(&mut self, other: &PixelSet) {
        self.check_same_universe(other);
        for (a, b) in self.words.iter_mut().zip(&other.words) {
            *a |= b;
        }
    }

    /// In-place difference `self ∖= other`.
    pub fn subtract(&mut self, other: &PixelSet) {
        self.check_same_universe(other);
        for (a, b) in self.words.iter_mut().zip(&other.words) {
            *a &= !b;
        }
    }

    /// In-place intersection `self ∩= other`.
    pub fn intersect_with(&mut self, other: &PixelSet) {
        self.check_same_universe(other);
        for (a, b) in self.words.iter_mut().zip(&other.words) {
            *a &= b;
        }
    }

    /// `self ∪ other` (allocating).
    pub fn union(&self, other: &PixelSet) -> PixelSet {
        let mut out = self.clone();
        out.union_with(other);
        out
    }

    /// `self ∖ other` (allocating).
    pub fn difference(&self, other: &PixelSet) -> PixelSet {
        let mut out = self.clone();
        out.subtract(other);
        out
    }

    /// `self ∩ other` (allocating).
    pub fn intersection(&self, other: &PixelSet) -> PixelSet {
        let mut out = self.clone();
        out.intersect_with(other);
        out
    }

    /// `|self ∩ other|` without allocating.
    #[inline]
    pub fn intersection_len(&self, other: &PixelSet) -> usize {
        self.check_same_universe(other);
        self.words
            .iter()
            .zip(&other.words)
            .map(|(a, b)| (a & b).count_ones() as usize)
            .sum()
    }

    /// `|self ∪ other|` without allocating.
    #[inline]
    pub fn union_len(&self, other: &PixelSet) -> usize {
        self.check_same_universe(other);
        self.words
            .iter()
            .zip(&other.words)
            .map(|(a, b)| (a | b).count_ones() as usize)
            .sum()
    }

    /// `|self ∖ other|` without allocating.
    #[inline]
    pub fn difference_len(&self, other: &PixelSet) -> usize {
        self.check_same_universe(other);
        self.words
            .iter()
            .zip(&other.words)
            .map(|(a, b)| (a & !b).count_ones() as usize)
            .sum()
    }

    /// `self ⊆ other`.
    pub fn is_subset_of(&self, other: &PixelSet) -> bool {
        self.check_same_universe(other);
        self.words.iter().zip(&other.words).all(|(a, b)| a & !b == 0)
    }

    /// `self ∩ other = ∅`.
    pub fn is_disjoint_from(&self, other: &PixelSet) -> bool {
        self.intersection_len(other) == 0
    }

    /// Iterate set members in increasing id order.
    pub fn iter(&self) -> PixelSetIter<'_> {
        PixelSetIter { set: self, word_idx: 0, current: self.words.first().copied().unwrap_or(0) }
    }

    /// Members as a vector (convenience for tests / serialization).
    pub fn to_vec(&self) -> Vec<PixelId> {
        self.iter().collect()
    }
}

impl std::fmt::Debug for PixelSet {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "PixelSet{{|{}|: {:?}}}", self.len(), self.to_vec())
    }
}

/// Iterator over set bits, word at a time.
pub struct PixelSetIter<'a> {
    set: &'a PixelSet,
    word_idx: usize,
    current: u64,
}

impl Iterator for PixelSetIter<'_> {
    type Item = PixelId;

    #[inline]
    fn next(&mut self) -> Option<PixelId> {
        while self.current == 0 {
            self.word_idx += 1;
            if self.word_idx >= self.set.words.len() {
                return None;
            }
            self.current = self.set.words[self.word_idx];
        }
        let bit = self.current.trailing_zeros();
        self.current &= self.current - 1;
        Some((self.word_idx * 64) as PixelId + bit as PixelId)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn set(nbits: usize, ids: &[u32]) -> PixelSet {
        PixelSet::from_iter(nbits, ids.iter().copied())
    }

    #[test]
    fn insert_contains_remove() {
        let mut s = PixelSet::empty(130);
        assert!(s.is_empty());
        s.insert(0);
        s.insert(63);
        s.insert(64);
        s.insert(129);
        assert_eq!(s.len(), 4);
        assert!(s.contains(0) && s.contains(63) && s.contains(64) && s.contains(129));
        assert!(!s.contains(1));
        s.remove(63);
        assert!(!s.contains(63));
        assert_eq!(s.len(), 3);
    }

    #[test]
    fn set_algebra() {
        let a = set(100, &[1, 2, 3, 64, 65]);
        let b = set(100, &[2, 3, 4, 65, 99]);
        assert_eq!(a.union(&b).to_vec(), vec![1, 2, 3, 4, 64, 65, 99]);
        assert_eq!(a.intersection(&b).to_vec(), vec![2, 3, 65]);
        assert_eq!(a.difference(&b).to_vec(), vec![1, 64]);
        assert_eq!(a.union_len(&b), 7);
        assert_eq!(a.intersection_len(&b), 3);
        assert_eq!(a.difference_len(&b), 2);
    }

    #[test]
    fn in_place_matches_allocating() {
        let a = set(80, &[0, 10, 20, 70]);
        let b = set(80, &[10, 30, 70, 79]);
        let mut u = a.clone();
        u.union_with(&b);
        assert_eq!(u, a.union(&b));
        let mut d = a.clone();
        d.subtract(&b);
        assert_eq!(d, a.difference(&b));
        let mut i = a.clone();
        i.intersect_with(&b);
        assert_eq!(i, a.intersection(&b));
    }

    #[test]
    fn subset_and_disjoint() {
        let a = set(64, &[1, 2]);
        let b = set(64, &[1, 2, 3]);
        let c = set(64, &[10, 11]);
        assert!(a.is_subset_of(&b));
        assert!(!b.is_subset_of(&a));
        assert!(a.is_disjoint_from(&c));
        assert!(!a.is_disjoint_from(&b));
    }

    #[test]
    fn insert_range_matches_per_bit() {
        // property-style: random ranges against the single-insert reference
        let mut rng = crate::util::rng::Rng::new(77);
        for _ in 0..500 {
            let nbits = 1 + rng.index(300);
            let a = rng.index(nbits + 1) as u32;
            let b = rng.index(nbits + 1) as u32;
            let (start, end) = (a.min(b), a.max(b));
            let mut fast = PixelSet::empty(nbits);
            fast.insert_range(start, end);
            let mut slow = PixelSet::empty(nbits);
            for i in start..end {
                slow.insert(i);
            }
            assert_eq!(fast, slow, "nbits={nbits} range {start}..{end}");
        }
    }

    #[test]
    fn insert_range_word_boundaries() {
        for (start, end) in [(0u32, 64u32), (63, 65), (64, 128), (0, 1), (127, 128), (10, 10)] {
            let mut fast = PixelSet::empty(128);
            fast.insert_range(start, end);
            assert_eq!(fast.len(), (end - start) as usize);
            for i in start..end {
                assert!(fast.contains(i));
            }
        }
    }

    /// Satellite coverage: every word-boundary shape of `insert_range` —
    /// `start % 64 == 0`, `end % 64 == 0`, both, single-word interior,
    /// single-bit, empty at a boundary, and multi-word spans with full
    /// interior words — checked against the per-bit reference and against
    /// `count_range`/`contains_range` on the same masks.
    #[test]
    fn insert_range_word_boundary_cases() {
        let cases: &[(u32, u32)] = &[
            (0, 0),      // empty at word start
            (64, 64),    // empty at an interior word boundary
            (192, 192),  // empty at the last word boundary
            (0, 64),     // exactly one full word (start%64==0, end%64==0)
            (64, 128),   // full interior word
            (0, 192),    // several full words
            (64, 65),    // single bit at a word start
            (63, 64),    // single bit at a word end (end%64==0)
            (127, 129),  // straddles a boundary by one bit each side
            (64, 100),   // start%64==0, end interior
            (10, 128),   // start interior, end%64==0
            (65, 127),   // strictly interior to one word
            (1, 63),     // single-word, touches neither boundary
            (0, 200),    // whole universe, ragged final word
        ];
        for &(start, end) in cases {
            let mut fast = PixelSet::empty(200);
            fast.insert_range(start, end);
            let mut slow = PixelSet::empty(200);
            for i in start..end {
                slow.insert(i);
            }
            assert_eq!(fast, slow, "insert_range {start}..{end}");
            assert_eq!(fast.len(), (end - start) as usize, "{start}..{end}");
            assert!(fast.contains_range(start, end), "{start}..{end}");
            assert_eq!(
                fast.count_range(0, 200),
                (end - start) as usize,
                "count over universe, range {start}..{end}"
            );
        }
    }

    #[test]
    fn count_range_matches_per_bit_reference() {
        let mut rng = crate::util::rng::Rng::new(1234);
        for _ in 0..500 {
            let nbits = 1 + rng.index(300);
            let mut s = PixelSet::empty(nbits);
            for _ in 0..rng.index(nbits + 1) {
                s.insert(rng.index(nbits) as u32);
            }
            let a = rng.index(nbits + 1) as u32;
            let b = rng.index(nbits + 1) as u32;
            let (start, end) = (a.min(b), a.max(b));
            let slow = (start..end).filter(|&i| s.contains(i)).count();
            assert_eq!(s.count_range(start, end), slow, "nbits={nbits} {start}..{end}");
        }
    }

    #[test]
    fn count_range_word_boundaries() {
        let s = set(256, &[0, 63, 64, 127, 128, 191, 192, 255]);
        assert_eq!(s.count_range(0, 256), 8);
        assert_eq!(s.count_range(0, 64), 2);
        assert_eq!(s.count_range(64, 128), 2);
        assert_eq!(s.count_range(64, 64), 0);
        assert_eq!(s.count_range(63, 65), 2);
        assert_eq!(s.count_range(1, 63), 0);
        assert_eq!(s.count_range(128, 256), 4);
    }

    #[test]
    fn copy_from_overwrites_without_universe_change() {
        let a = set(100, &[1, 64, 99]);
        let mut b = PixelSet::full(100);
        b.copy_from(&a);
        assert_eq!(b, a);
        let empty = PixelSet::empty(100);
        b.copy_from(&empty);
        assert!(b.is_empty());
    }

    #[test]
    fn full_and_clear() {
        let mut s = PixelSet::full(70);
        assert_eq!(s.len(), 70);
        s.clear();
        assert!(s.is_empty());
    }

    #[test]
    fn iter_order() {
        let s = set(200, &[199, 0, 64, 128, 5]);
        assert_eq!(s.to_vec(), vec![0, 5, 64, 128, 199]);
    }

    #[test]
    fn iter_empty() {
        let s = PixelSet::empty(64);
        assert_eq!(s.iter().count(), 0);
        let s0 = PixelSet::empty(0);
        assert_eq!(s0.iter().count(), 0);
    }
}

//! Tensor shapes, index linearization and pixel sets.
//!
//! The paper manipulates the on-chip memory as a *mathematical set* of data
//! elements (Assumption 1). Two linearizations are fixed by the paper:
//! row-major for patches (Remark 4) and channel-major for pixels (Remark 5);
//! and per Remark 6 the optimization works on **2D (spatial) pixels** because
//! slicing never cuts the channel dimension. This module provides those
//! index maps plus [`PixelSet`], the bitset the whole simulator/optimizer hot
//! path runs on.

mod pixel_set;
mod shape;

pub use pixel_set::PixelSet;
pub use shape::{Dims3, Rect, SliceSpec};

/// Spatial pixel identifier: `h * W_in + w` (row-major over the 2D grid).
pub type PixelId = u32;

/// Linearize a spatial coordinate.
#[inline]
pub fn pixel_id(h: usize, w: usize, w_in: usize) -> PixelId {
    (h * w_in + w) as PixelId
}

/// Invert [`pixel_id`].
#[inline]
pub fn pixel_coords(id: PixelId, w_in: usize) -> (usize, usize) {
    let id = id as usize;
    (id / w_in, id % w_in)
}

/// Channel-major linearization of a full 3D element `(c, h, w)` (Remark 5):
/// `c * (H_in*W_in) + h * W_in + w`. Used when materializing actual tensor
/// values for the functional simulation.
#[inline]
pub fn element_id(c: usize, h: usize, w: usize, dims: Dims3) -> usize {
    c * dims.h * dims.w + h * dims.w + w
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pixel_id_roundtrip() {
        let w_in = 7;
        for h in 0..5 {
            for w in 0..w_in {
                let id = pixel_id(h, w, w_in);
                assert_eq!(pixel_coords(id, w_in), (h, w));
            }
        }
    }

    #[test]
    fn element_id_is_channel_major() {
        let d = Dims3 { c: 3, h: 4, w: 5 };
        // first element of channel 1 comes right after channel 0's block
        assert_eq!(element_id(1, 0, 0, d), 20);
        assert_eq!(element_id(0, 1, 0, d), 5);
        assert_eq!(element_id(0, 0, 1, d), 1);
        assert_eq!(element_id(2, 3, 4, d), 2 * 20 + 3 * 5 + 4);
    }
}

//! # convoffload
//!
//! A production-oriented reproduction of *“Convolutions Predictable Offloading
//! to an Accelerator: Formalization and Optimization”* (Husson, Belcaid, Carle,
//! Pagetti — CS.AR 2026).
//!
//! The library implements, in Rust, the paper's full system:
//!
//! * the **offloading formalism** — steps `s_i = (F_i^inp, F_i^ker, W_i,
//!   I_i^slice, K_i^sub)`, set-based on-chip-memory semantics, and the linear
//!   duration model (`step`, `platform`, `tensor`, `conv`);
//! * the **strategies** — S1-baseline (one patch per step, Siu et al.),
//!   grouped S1 with Row-by-Row / ZigZag / Hilbert / diagonal orderings, and
//!   arbitrary user strategies loaded from CSV/JSON (`strategy`);
//! * the **simulator** — the §6 orchestration loop with per-step metrics,
//!   trace recording, grid visualisation, and a *functional* mode in which the
//!   per-step compute runs on an AOT-compiled XLA executable via PJRT
//!   (`sim`, `viz`, `runtime`);
//! * the **optimization problem** — the §5 ILP built on an in-tree 0-1 MILP
//!   substrate (linearized ∧/∨/¬, dense simplex, branch & bound with MIP
//!   start) plus the structure-aware local-search “solution polishing” used
//!   for larger instances (`ilp`, `solver`, `optimizer`);
//! * the **network-level planner** — a portfolio race (orderings + greedy +
//!   seeded annealing, raced on scoped threads) over every layer of a network
//!   preset, with a content-addressed on-disk strategy cache and an
//!   end-to-end simulated-duration report (`planner`);
//! * the **experiment harness** regenerating every figure of the paper's
//!   evaluation (`bench_harness`), and a config system with LeNet-5 / ResNet-8
//!   layer *and* network presets (`config`).
//!
//! See `DESIGN.md` for the module inventory and the per-experiment index, and
//! `EXPERIMENTS.md` for reproduced-vs-paper results.

pub mod bench_harness;
pub mod config;
pub mod conv;
pub mod ilp;
pub mod metrics;
pub mod optimizer;
pub mod planner;
pub mod platform;
pub mod runtime;
pub mod sim;
pub mod solver;
pub mod step;
pub mod strategy;
pub mod tensor;
pub mod util;
pub mod viz;

/// Convenience re-exports of the types that form the public API surface.
pub mod prelude {
    pub use crate::conv::{ConvLayer, Patch, PatchId};
    pub use crate::planner::{
        AcceleratorSpec, NetworkPlan, NetworkPlanner, PlanOptions, StrategyCache,
    };
    pub use crate::platform::{Accelerator, OnChipMemory, Platform};
    pub use crate::sim::{FunctionalBackend, SimReport, Simulator};
    pub use crate::step::{Step, StepCost};
    pub use crate::strategy::{
        GroupedStrategy, Ordering, Strategy, WritebackPolicy,
    };
    pub use crate::tensor::PixelSet;
}

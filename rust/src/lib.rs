//! # convoffload
//!
//! A production-oriented reproduction of *“Convolutions Predictable Offloading
//! to an Accelerator: Formalization and Optimization”* (Husson, Belcaid, Carle,
//! Pagetti — CS.AR 2026).
//!
//! The library implements, in Rust, the paper's full system:
//!
//! * the **offloading formalism** — steps `s_i = (F_i^inp, F_i^ker, W_i,
//!   I_i^slice, K_i^sub)` (Definitions 1–3), set-based on-chip-memory
//!   semantics, and **two duration semantics**: the paper's sequential
//!   Definition-3 sum and the §3.7 double-buffered two-resource timeline
//!   ([`platform::OverlapMode`], [`step::OverlapTimeline`]), which hides
//!   transfer latency behind compute under a residency condition
//!   (`step`, `platform`, `tensor`, `conv`);
//! * the **strategies** — S1-baseline (one patch per step, Siu et al.),
//!   grouped S1 with Row-by-Row / ZigZag / Hilbert / diagonal orderings, and
//!   arbitrary user strategies loaded from CSV/JSON (`strategy`);
//! * the **simulator** — the §6 orchestration loop with per-step metrics,
//!   trace recording, grid visualisation, and a *functional* mode in which the
//!   per-step compute runs on an AOT-compiled XLA executable via PJRT
//!   (`sim`, `viz`, `runtime`);
//! * the **optimization problem** — the §5 ILP built on an in-tree 0-1 MILP
//!   substrate (linearized ∧/∨/¬, dense simplex, branch & bound with MIP
//!   start) plus the structure-aware local-search “solution polishing” used
//!   for larger instances, in either duration domain: loaded pixels
//!   (Eq. 15, Definition 3) or the overlapped makespan
//!   ([`optimizer::grouping_makespan`], [`optimizer::MakespanEval`])
//!   (`ilp`, `solver`, `optimizer`);
//! * the **network-level planner** — a portfolio race (orderings + greedy +
//!   seeded annealing, raced on scoped threads) over every layer of a network
//!   preset, with a content-addressed on-disk strategy cache and an
//!   end-to-end simulated-duration report; under a double-buffered
//!   accelerator the race optimizes the overlapped makespan (`planner`);
//! * the **batch planning service** — [`planner::BatchPlanner`] plans many
//!   networks in one call: identical (geometry, platform, overlap-mode)
//!   problems dedupe *across* requests before any search, the residual
//!   portfolio set races on one shared worker pool, and results persist in a
//!   sharded, lock-striped, crash-tolerant strategy cache
//!   ([`planner::ShardedStrategyCache`]) whose hit/miss/dedup/eviction
//!   counters surface through [`planner::BatchReport`] (`plan-batch`);
//! * **fault-tolerant offloading** — a seeded, replayable fault model
//!   ([`platform::FaultModel`]: transient DMA retries, bounded timing
//!   jitter, sticky `MemoryShrink` events) threaded through both duration
//!   semantics, an analytic k-fault WCET bound
//!   ([`platform::FaultModel::makespan_under_k_faults`]) that dominates
//!   every simulated trace, and degraded-mode replanning in the batch
//!   planner (panic-tolerant portfolio races, quarantined cache shards,
//!   shrink-driven re-grouping/re-racing) — `--faults` on `simulate` and
//!   `plan-batch`, `[faults]` in experiment TOML;
//! * the **experiment harness** regenerating every figure of the paper's
//!   evaluation (`bench_harness`), and a config system with LeNet-5 / ResNet-8
//!   layer *and* network presets (`config`).
//!
//! See `DESIGN.md` for the module inventory and the per-experiment index
//! (the overlapped timeline is §3.7 there), and `EXPERIMENTS.md` for
//! reproduced-vs-paper results and the overlap baselines.
//!
//! ## Example: sequential vs. double-buffered duration
//!
//! The paper's Definition-3 model (Def. 3) charges a strategy's loads,
//! writes and compute back to back; [`platform::OverlapMode::DoubleBuffered`]
//! schedules the same Definition-16 step stream (Def. 16) on two resources
//! and reports the critical-path makespan:
//!
//! ```
//! use convoffload::platform::OverlapMode;
//! use convoffload::prelude::*;
//! use convoffload::strategy;
//!
//! let layer = ConvLayer::new(1, 8, 8, 3, 3, 1, 1, 1).unwrap();
//! let strategy = strategy::zigzag(&layer, 2);
//! let acc = Accelerator::for_group_size(&layer, 2);
//!
//! let sequential = Simulator::new(layer, Platform::new(acc))
//!     .run(&strategy)
//!     .unwrap();
//! let overlapped = Simulator::new(
//!     layer,
//!     Platform::new(acc.with_overlap(OverlapMode::DoubleBuffered)),
//! )
//! .run(&strategy)
//! .unwrap();
//!
//! // Hiding transfer behind compute can only help, and never beats the
//! // busier resource's total:
//! assert!(overlapped.duration <= sequential.duration);
//! assert!(overlapped.duration >= overlapped.dma_busy.max(overlapped.compute_busy));
//! assert_eq!(overlapped.sequential_duration, sequential.duration);
//! ```

#![warn(missing_docs)]

pub mod bench_harness;
pub mod config;
pub mod conv;
pub mod ilp;
pub mod metrics;
pub mod optimizer;
pub mod planner;
pub mod platform;
pub mod runtime;
pub mod server;
pub mod sim;
pub mod solver;
pub mod step;
pub mod strategy;
pub mod tensor;
pub mod util;
pub mod viz;

/// Convenience re-exports of the types that form the public API surface.
pub mod prelude {
    pub use crate::conv::{ConvLayer, Patch, PatchId};
    pub use crate::planner::{
        AcceleratorSpec, BatchPlanner, BatchReport, BatchStats, ChaosSpec,
        NetworkPlan, NetworkPlanner, PlanOptions, ShardedStrategyCache,
        StrategyCache, StrategyStore,
    };
    pub use crate::platform::{
        Accelerator, FaultModel, OnChipMemory, OverlapMode, Platform, StepFaults,
    };
    pub use crate::server::{PlanServer, ServerConfig};
    pub use crate::sim::{FunctionalBackend, SimReport, Simulator};
    pub use crate::step::{OverlapTimeline, Step, StepCost, StepTiming};
    pub use crate::strategy::{
        GroupedStrategy, Ordering, Strategy, WritebackPolicy,
    };
    pub use crate::tensor::PixelSet;
}

//! The PJRT client wrapper: compile-once, execute-many.

use std::collections::HashMap;
use std::path::Path;

// Offline build: resolve `xla::` against the in-tree shim. Swap this alias
// for the real `xla` crate dependency to restore the PJRT hardware path.
use crate::runtime::xla_shim as xla;

use crate::runtime::ArtifactManifest;

/// Runtime failure.
#[derive(Debug)]
pub enum RuntimeError {
    /// PJRT / XLA error from the `xla` crate.
    Xla(String),
    /// No artifact variant matches the requested shapes.
    NoVariant { what: String },
    /// Manifest missing or malformed.
    Manifest(String),
    /// Bad input sizes for an executable.
    Shape(String),
}

impl std::fmt::Display for RuntimeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RuntimeError::Xla(e) => write!(f, "xla error: {e}"),
            RuntimeError::NoVariant { what } => {
                write!(f, "no AOT artifact variant for {what} (re-run `make artifacts`?)")
            }
            RuntimeError::Manifest(e) => write!(f, "manifest error: {e}"),
            RuntimeError::Shape(e) => write!(f, "shape error: {e}"),
        }
    }
}
impl std::error::Error for RuntimeError {}

impl From<xla::Error> for RuntimeError {
    fn from(e: xla::Error) -> Self {
        RuntimeError::Xla(e.to_string())
    }
}

/// A compiled executable plus its I/O shape signature.
struct LoadedExe {
    exe: xla::PjRtLoadedExecutable,
    /// Flattened input lengths, in argument order.
    input_lens: Vec<usize>,
}

/// The runtime: a PJRT CPU client with a cache of compiled artifacts.
pub struct Runtime {
    client: xla::PjRtClient,
    /// The parsed artifact manifest.
    pub manifest: ArtifactManifest,
    cache: HashMap<String, LoadedExe>,
}

impl Runtime {
    /// Create from an artifacts directory (must contain `manifest.json`).
    pub fn new(dir: &Path) -> Result<Self, RuntimeError> {
        let manifest = ArtifactManifest::load(dir).map_err(RuntimeError::Manifest)?;
        let client = xla::PjRtClient::cpu()?;
        Ok(Runtime { client, manifest, cache: HashMap::new() })
    }

    /// Create from the default artifacts directory.
    pub fn from_default_dir() -> Result<Self, RuntimeError> {
        Self::new(&crate::runtime::artifacts_dir())
    }

    /// PJRT platform string (diagnostics).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (or fetch from cache) the artifact `file` with the given
    /// flattened input lengths.
    fn load(&mut self, file: &str, input_lens: Vec<usize>) -> Result<&LoadedExe, RuntimeError> {
        if !self.cache.contains_key(file) {
            let path = self.manifest.path_of(file);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| RuntimeError::Manifest("non-utf8 path".into()))?,
            )?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self.client.compile(&comp)?;
            self.cache.insert(file.to_string(), LoadedExe { exe, input_lens });
        }
        Ok(&self.cache[file])
    }

    /// Execute an artifact on f32 buffers with static shapes.
    ///
    /// `inputs` are (data, dims) pairs; the single tuple output is returned
    /// flattened.
    pub fn execute_f32(
        &mut self,
        file: &str,
        inputs: &[(&[f32], &[usize])],
    ) -> Result<Vec<f32>, RuntimeError> {
        let lens: Vec<usize> = inputs.iter().map(|(d, _)| d.len()).collect();
        let loaded = self.load(file, lens)?;
        for ((data, dims), expect) in inputs.iter().zip(&loaded.input_lens) {
            let n: usize = dims.iter().product();
            if n != data.len() || data.len() != *expect {
                return Err(RuntimeError::Shape(format!(
                    "input length {} does not match dims {:?} (expect {expect})",
                    data.len(),
                    dims
                )));
            }
        }
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|(data, dims)| {
                let dims_i64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
                xla::Literal::vec1(data).reshape(&dims_i64)
            })
            .collect::<Result<_, _>>()?;
        let result = loaded.exe.execute::<xla::Literal>(&literals)?[0][0]
            .to_literal_sync()?;
        // aot.py lowers with return_tuple=True → unwrap the 1-tuple.
        let out = result.to_tuple1()?;
        Ok(out.to_vec::<f32>()?)
    }

    /// Number of compiled executables held in the cache.
    pub fn cached(&self) -> usize {
        self.cache.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::{artifacts_available, artifacts_dir};

    /// End-to-end PJRT smoke test against the real artifacts (skipped until
    /// `make artifacts` has produced them).
    #[test]
    fn executes_step_artifact() {
        if !artifacts_available() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let mut rt = Runtime::new(&artifacts_dir()).unwrap();
        let v = rt.manifest.find_step(9, 1, 8).expect("paper step variant").clone();
        // patches = identity-ish rows, kernels = ones → row sums
        let g = v.g_max;
        let patches: Vec<f32> = (0..g * 9).map(|i| (i % 7) as f32).collect();
        let kernels = vec![1f32; 9];
        let out = rt
            .execute_f32(&v.file, &[(&patches, &[g, 9]), (&kernels, &[9, 1])])
            .unwrap();
        assert_eq!(out.len(), g);
        for (r, o) in out.iter().enumerate() {
            let want: f32 = patches[r * 9..(r + 1) * 9].iter().sum();
            assert!((o - want).abs() < 1e-4, "row {r}: {o} vs {want}");
        }
        // compile cache: second call must not recompile
        let _ = rt
            .execute_f32(&v.file, &[(&patches, &[g, 9]), (&kernels, &[9, 1])])
            .unwrap();
        assert_eq!(rt.cached(), 1);
    }

    #[test]
    fn layer_artifact_matches_rust_oracle() {
        if !artifacts_available() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let mut rt = Runtime::new(&artifacts_dir()).unwrap();
        let v = rt.manifest.find_layer(2, 5, 5, 2, 3).expect("example1 layer").clone();
        let layer = crate::conv::ConvLayer::new(
            v.c_in, v.h_in, v.w_in, v.h_k, v.w_k, v.n, v.s_h, v.s_w,
        )
        .unwrap();
        let input = crate::conv::reference::synth_tensor(layer.input_dims().len(), 11);
        let kernels = crate::conv::reference::synth_tensor(layer.kernel_elements(), 12);
        let out = rt
            .execute_f32(
                &v.file,
                &[
                    (&input, &[v.c_in, v.h_in, v.w_in]),
                    (&kernels, &[v.n, v.c_in, v.h_k, v.w_k]),
                ],
            )
            .unwrap();
        let want = crate::conv::reference::conv2d(&layer, &input, &kernels);
        assert_eq!(out.len(), want.len());
        for (a, b) in out.iter().zip(&want) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
    }

    #[test]
    fn missing_artifact_dir_errors() {
        match Runtime::new(Path::new("/nonexistent-dir-xyz")) {
            Err(RuntimeError::Manifest(_)) => {}
            Err(other) => panic!("expected manifest error, got {other}"),
            Ok(_) => panic!("expected an error"),
        }
    }
}

//! Offline stand-in for the `xla` crate (PJRT C API bindings).
//!
//! The tree builds with zero external dependencies, so [`super::client`]
//! compiles against this shim (`use crate::runtime::xla_shim as xla;`)
//! instead of the real bindings. The shim reproduces exactly the API surface
//! the client uses; every entry point that would touch PJRT returns
//! [`Error`], so callers see a precise "PJRT support not compiled in" error
//! only when they actually request the hardware path (the artifact manifest
//! is parsed before the client is created, keeping manifest errors distinct).
//!
//! To restore the real runtime: add the `xla` crate to `Cargo.toml` and
//! replace the alias import in `client.rs` — no other code changes.

use std::fmt;

/// Mirrors the `xla::Error` surface the client uses (`Display` + `Error`).
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

fn unavailable() -> Error {
    Error(
        "PJRT support not compiled in (offline build; see rust/src/runtime/xla_shim.rs)"
            .to_string(),
    )
}

/// PJRT client handle. [`PjRtClient::cpu`] always fails in the shim.
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    /// Always fails in the shim (no PJRT compiled in).
    pub fn cpu() -> Result<PjRtClient, Error> {
        Err(unavailable())
    }

    /// `"unavailable"` in the shim.
    pub fn platform_name(&self) -> String {
        "unavailable".to_string()
    }

    /// Always fails in the shim.
    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        Err(unavailable())
    }
}

/// Parsed HLO module (never constructed in the shim).
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    /// Always fails in the shim.
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto, Error> {
        Err(unavailable())
    }
}

/// An XLA computation wrapping an HLO module.
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    /// Wrap a proto (inert in the shim).
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// A compiled executable (never constructed in the shim).
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    /// Always fails in the shim.
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        Err(unavailable())
    }
}

/// A device buffer returned by execution.
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    /// Always fails in the shim.
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        Err(unavailable())
    }
}

/// A host literal.
pub struct Literal {
    _private: (),
}

impl Literal {
    /// Wrap host data (inert in the shim).
    pub fn vec1(_data: &[f32]) -> Literal {
        Literal { _private: () }
    }

    /// Always fails in the shim.
    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal, Error> {
        Err(unavailable())
    }

    /// Always fails in the shim.
    pub fn to_tuple1(&self) -> Result<Literal, Error> {
        Err(unavailable())
    }

    /// Always fails in the shim.
    pub fn to_vec<T>(&self) -> Result<Vec<T>, Error> {
        Err(unavailable())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shim_paths_error_cleanly() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("x").is_err());
        let lit = Literal::vec1(&[1.0, 2.0]);
        assert!(lit.reshape(&[2]).is_err());
        assert!(lit.to_tuple1().is_err());
        assert!(lit.to_vec::<f32>().is_err());
        let err = unavailable();
        assert!(err.to_string().contains("PJRT support not compiled in"));
    }
}

//! [`PjrtBackend`] — the functional-simulation compute backend that runs
//! each step's `a_6` on the AOT-compiled XLA executable.

use crate::conv::ConvLayer;
use crate::runtime::{Runtime, RuntimeError};
use crate::sim::ComputeBackend;

/// Executes step computes through PJRT. Groups larger than the artifact's
/// static `g_max` are processed in chunks; smaller groups are zero-padded
/// (padded rows produce zero outputs that are discarded).
pub struct PjrtBackend {
    runtime: Runtime,
    name: String,
}

impl PjrtBackend {
    /// A backend over an opened runtime.
    pub fn new(runtime: Runtime) -> Self {
        let name = format!("pjrt({})", runtime.platform());
        PjrtBackend { runtime, name }
    }

    /// Convenience: open the default artifacts directory.
    pub fn from_default_dir() -> Result<Self, RuntimeError> {
        Ok(Self::new(Runtime::from_default_dir()?))
    }

    /// Mutable access to the underlying runtime (artifact cache).
    pub fn runtime_mut(&mut self) -> &mut Runtime {
        &mut self.runtime
    }
}

impl ComputeBackend for PjrtBackend {
    fn step_compute(
        &mut self,
        layer: &ConvLayer,
        patches: &[f32],
        kernel_matrix: &[f32],
        rows: usize,
    ) -> Result<Vec<f32>, String> {
        let d = layer.im2col_width();
        let n = layer.n_kernels;
        if patches.len() != rows * d {
            return Err(format!("patch matrix {} != {rows}x{d}", patches.len()));
        }
        if kernel_matrix.len() != d * n {
            return Err(format!("kernel matrix {} != {d}x{n}", kernel_matrix.len()));
        }
        let variant = self
            .runtime
            .manifest
            .find_step(d, n, rows.min(usize::MAX))
            .or_else(|| self.runtime.manifest.find_step(d, n, 1))
            .ok_or_else(|| format!("no step artifact for d={d} n={n}"))?
            .clone();
        let g_max = variant.g_max;

        let mut out = Vec::with_capacity(rows * n);
        let mut row = 0;
        while row < rows {
            let take = (rows - row).min(g_max);
            // Zero-pad the chunk to the static [g_max, d] shape.
            let mut buf = vec![0f32; g_max * d];
            buf[..take * d].copy_from_slice(&patches[row * d..(row + take) * d]);
            let result = self
                .runtime
                .execute_f32(
                    &variant.file,
                    &[(&buf, &[g_max, d]), (kernel_matrix, &[d, n])],
                )
                .map_err(|e| e.to_string())?;
            out.extend_from_slice(&result[..take * n]);
            row += take;
        }
        Ok(out)
    }

    fn name(&self) -> &str {
        &self.name
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::conv::reference;
    use crate::runtime::artifacts_available;

    fn backend() -> Option<PjrtBackend> {
        if !artifacts_available() {
            eprintln!("skipping: artifacts not built");
            return None;
        }
        Some(PjrtBackend::from_default_dir().unwrap())
    }

    #[test]
    fn matches_rust_oracle_padded_and_chunked() {
        let Some(mut b) = backend() else { return };
        let l = ConvLayer::new(2, 5, 5, 3, 3, 2, 1, 1).unwrap(); // d=18, n=2
        let input = reference::synth_tensor(l.input_dims().len(), 21);
        let kernels = reference::synth_tensor(l.kernel_elements(), 22);
        let km = reference::kernel_matrix(&l, &kernels);
        // group sizes: 1 (padded), 8 (exact), 9 (chunked: 8 + 1)
        for group_len in [1usize, 8, 9] {
            let group: Vec<u32> =
                (0..group_len as u32).map(|p| p % l.n_patches() as u32).collect();
            // avoid duplicate patches for im2col only (values identical anyway)
            let pm = reference::im2col_group(&l, &input, &group);
            let got = b.step_compute(&l, &pm, &km, group.len()).unwrap();
            let mut oracle = crate::sim::RustOracleBackend;
            let want = oracle.step_compute(&l, &pm, &km, group.len()).unwrap();
            assert_eq!(got.len(), want.len());
            for (a, c) in got.iter().zip(&want) {
                assert!((a - c).abs() < 1e-4, "g={group_len}: {a} vs {c}");
            }
        }
    }

    #[test]
    fn functional_simulation_through_pjrt() {
        let Some(mut b) = backend() else { return };
        let l = ConvLayer::new(2, 5, 5, 3, 3, 2, 1, 1).unwrap();
        let acc = crate::platform::Accelerator::for_group_size(&l, 2);
        let sim = crate::sim::Simulator::new(l, crate::platform::Platform::new(acc));
        let input = reference::synth_tensor(l.input_dims().len(), 31);
        let kernels = reference::synth_tensor(l.kernel_elements(), 32);
        let s = crate::strategy::zigzag(&l, 2);
        let report = sim.run_functional(&s, &input, &kernels, &mut b).unwrap();
        assert_eq!(report.functional_ok(1e-4), Some(true));
    }

    #[test]
    fn missing_variant_is_an_error() {
        let Some(mut b) = backend() else { return };
        let l = ConvLayer::new(7, 9, 9, 3, 3, 5, 1, 1).unwrap(); // d=63,n=5: no artifact
        let pm = vec![0f32; 63];
        let km = vec![0f32; 63 * 5];
        assert!(b.step_compute(&l, &pm, &km, 1).is_err());
    }
}

//! The artifact manifest written by `python/compile/aot.py`.

use std::path::{Path, PathBuf};

use crate::util::json::{self, Json};

/// A step-compute executable variant: `[g_max, d] @ [d, n] → [g_max, n]`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct StepVariant {
    /// Variant name (manifest key).
    pub name: String,
    /// HLO file name within the artifact directory.
    pub file: String,
    /// im2col row width `D`.
    pub d: usize,
    /// Kernel count `N`.
    pub n: usize,
    /// Maximum patches per step the executable accepts.
    pub g_max: usize,
}

/// A whole-layer forward executable variant.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LayerVariant {
    /// Variant name (manifest key).
    pub name: String,
    /// HLO file name within the artifact directory.
    pub file: String,
    /// Input channels.
    pub c_in: usize,
    /// Input height.
    pub h_in: usize,
    /// Input width.
    pub w_in: usize,
    /// Kernel count.
    pub n: usize,
    /// Kernel height.
    pub h_k: usize,
    /// Kernel width.
    pub w_k: usize,
    /// Vertical stride.
    pub s_h: usize,
    /// Horizontal stride.
    pub s_w: usize,
}

/// Parsed `manifest.json`.
#[derive(Debug, Clone, Default)]
pub struct ArtifactManifest {
    /// Artifact directory the manifest was read from.
    pub dir: PathBuf,
    /// Step-compute executables.
    pub steps: Vec<StepVariant>,
    /// Whole-layer executables.
    pub layers: Vec<LayerVariant>,
}

impl ArtifactManifest {
    /// Load from `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Self, String> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .map_err(|e| format!("read {}: {e}", path.display()))?;
        Self::parse(dir, &text)
    }

    /// Parse manifest text (separated out for tests).
    pub fn parse(dir: &Path, text: &str) -> Result<Self, String> {
        let v = json::parse(text).map_err(|e| e.to_string())?;
        let mut m = ArtifactManifest { dir: dir.to_path_buf(), ..Default::default() };

        let get = |o: &Json, k: &str| -> Result<usize, String> {
            o.get(k)
                .and_then(Json::as_usize)
                .ok_or(format!("manifest entry missing '{k}'"))
        };
        let get_str = |o: &Json, k: &str| -> Result<String, String> {
            Ok(o.get(k)
                .and_then(Json::as_str)
                .ok_or(format!("manifest entry missing '{k}'"))?
                .to_string())
        };

        for e in v.get("step").and_then(Json::as_arr).unwrap_or(&[]) {
            m.steps.push(StepVariant {
                name: get_str(e, "name")?,
                file: get_str(e, "file")?,
                d: get(e, "d")?,
                n: get(e, "n")?,
                g_max: get(e, "g_max")?,
            });
        }
        for e in v.get("layer").and_then(Json::as_arr).unwrap_or(&[]) {
            m.layers.push(LayerVariant {
                name: get_str(e, "name")?,
                file: get_str(e, "file")?,
                c_in: get(e, "c_in")?,
                h_in: get(e, "h_in")?,
                w_in: get(e, "w_in")?,
                n: get(e, "n")?,
                h_k: get(e, "h_k")?,
                w_k: get(e, "w_k")?,
                s_h: get(e, "s_h")?,
                s_w: get(e, "s_w")?,
            });
        }
        Ok(m)
    }

    /// Find a step variant able to run groups for a layer with `d`-long
    /// im2col rows, `n` kernels and groups of at most `group` patches.
    pub fn find_step(&self, d: usize, n: usize, group: usize) -> Option<&StepVariant> {
        self.steps
            .iter()
            .filter(|s| s.d == d && s.n == n && s.g_max >= group)
            .min_by_key(|s| s.g_max)
    }

    /// Find a whole-layer variant by exact dimensions.
    pub fn find_layer(
        &self,
        c_in: usize,
        h_in: usize,
        w_in: usize,
        n: usize,
        h_k: usize,
    ) -> Option<&LayerVariant> {
        self.layers.iter().find(|l| {
            l.c_in == c_in && l.h_in == h_in && l.w_in == w_in && l.n == n && l.h_k == h_k
        })
    }

    /// Absolute path of a manifest-relative file.
    pub fn path_of(&self, file: &str) -> PathBuf {
        self.dir.join(file)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "step": [
        {"name": "s1", "file": "s1.hlo.txt", "d": 9, "n": 1, "g_max": 8},
        {"name": "s2", "file": "s2.hlo.txt", "d": 9, "n": 1, "g_max": 16}
      ],
      "layer": [
        {"name": "l1", "file": "l1.hlo.txt", "c_in": 1, "h_in": 6, "w_in": 6,
         "n": 1, "h_k": 3, "w_k": 3, "s_h": 1, "s_w": 1, "h_out": 4, "w_out": 4}
      ]
    }"#;

    #[test]
    fn parses_sample() {
        let m = ArtifactManifest::parse(Path::new("/tmp/a"), SAMPLE).unwrap();
        assert_eq!(m.steps.len(), 2);
        assert_eq!(m.layers.len(), 1);
        assert_eq!(m.steps[0].g_max, 8);
        assert_eq!(m.layers[0].h_in, 6);
        assert_eq!(m.path_of("s1.hlo.txt"), PathBuf::from("/tmp/a/s1.hlo.txt"));
    }

    #[test]
    fn find_step_picks_smallest_sufficient() {
        let m = ArtifactManifest::parse(Path::new("."), SAMPLE).unwrap();
        assert_eq!(m.find_step(9, 1, 4).unwrap().name, "s1");
        assert_eq!(m.find_step(9, 1, 12).unwrap().name, "s2");
        assert!(m.find_step(9, 1, 32).is_none());
        assert!(m.find_step(10, 1, 4).is_none());
    }

    #[test]
    fn find_layer_exact_match() {
        let m = ArtifactManifest::parse(Path::new("."), SAMPLE).unwrap();
        assert!(m.find_layer(1, 6, 6, 1, 3).is_some());
        assert!(m.find_layer(1, 6, 6, 1, 5).is_none());
    }

    #[test]
    fn rejects_malformed() {
        assert!(ArtifactManifest::parse(Path::new("."), "{").is_err());
        assert!(ArtifactManifest::parse(
            Path::new("."),
            r#"{"step": [{"name": "x"}]}"#
        )
        .is_err());
    }

    #[test]
    fn parses_real_manifest_if_present() {
        let dir = crate::runtime::artifacts_dir();
        if dir.join("manifest.json").exists() {
            let m = ArtifactManifest::load(&dir).unwrap();
            assert!(!m.steps.is_empty());
            assert!(!m.layers.is_empty());
            for s in &m.steps {
                assert!(dir.join(&s.file).exists(), "{} missing", s.file);
            }
        }
    }
}

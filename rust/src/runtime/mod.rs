//! PJRT runtime: load and execute the AOT-compiled XLA artifacts.
//!
//! Wraps the `xla` crate (PJRT C API, CPU client): each `artifacts/*.hlo.txt`
//! is parsed from **HLO text** (the interchange format — serialized protos
//! from jax ≥ 0.5 use 64-bit ids that xla_extension 0.5.1 rejects), compiled
//! once, and cached per shape variant. Python never runs at simulation time:
//! the Rust request path calls straight into the compiled executables.

mod artifacts;
mod backend;
mod client;
mod xla_shim;

pub use artifacts::{ArtifactManifest, LayerVariant, StepVariant};
pub use backend::PjrtBackend;
pub use client::{Runtime, RuntimeError};

/// Default artifacts directory, overridable via `CONVOFFLOAD_ARTIFACTS`.
pub fn artifacts_dir() -> std::path::PathBuf {
    match std::env::var("CONVOFFLOAD_ARTIFACTS") {
        Ok(dir) => dir.into(),
        Err(_) => std::path::PathBuf::from("artifacts"),
    }
}

/// True when the artifacts directory (with a manifest) exists — used by
/// tests and examples to skip PJRT paths before `make artifacts` has run.
pub fn artifacts_available() -> bool {
    artifacts_dir().join("manifest.json").exists()
}

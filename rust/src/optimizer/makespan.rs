//! Delta-evaluated duration-domain objective: the §3.7/§3.10 overlapped
//! makespan as an annealing objective.
//!
//! [`MakespanEval`] mirrors [`crate::optimizer::objective::GroupingEval`]'s
//! propose-score-commit contract (§3.5) for the multi-resource timeline
//! (k DMA channels × m compute units; 1×1 is the paper's two-resource
//! recurrence): it keeps the per-position step parameters (footprint sizes,
//! boundary overlaps, group lengths — everything the recurrence consumes)
//! plus the flattened timeline state *after every position*, so scoring a
//! move replays the recurrence only from the first affected position and
//! stops as soon as every resource frontier has shifted by one uniform
//! offset — the (max, +) recurrence is translation-equivariant, so from
//! that point the whole suffix (and the makespan) shifts by the same
//! offset. Most annealing moves touch 1–2 boundary entries and converge
//! within a few positions.
//!
//! The caller drives both evaluators in lock-step: `GroupingEval` scores the
//! footprint math and stages its edits; [`MakespanEval::score`] restages the
//! same edits (via [`StagedEffect`]) on the timeline arrays and returns the
//! exact makespan delta; on accept both `commit`, on reject neither does —
//! a rejected move costs one bounded suffix replay and nothing else.

use crate::conv::{ConvLayer, PatchId};
use crate::optimizer::objective::StagedEffect;
use crate::platform::Accelerator;
use crate::step::OverlapTimeline;

/// A staged group-length override (content moves change group sizes).
#[derive(Debug, Clone, Copy)]
struct GlenEdit {
    pos: usize,
    new_len: u64,
}

/// A scored-but-uncommitted timeline update.
#[derive(Debug, Clone)]
struct PendingTimeline {
    effect: StagedEffect,
    glens: [Option<GlenEdit>; 2],
    /// First recomputed position.
    first: usize,
    /// Last recomputed position (inclusive; state rows live in the scratch).
    end: usize,
    /// Uniform shift of every state row after `end`.
    shift: i64,
    new_makespan: u64,
}

/// Incremental evaluator of the double-buffered makespan of a grouping
/// under the Definition-16 / every-step-write-back lowering (the protocol
/// the planner's strategies use). Bit-equal to
/// [`crate::optimizer::objective::grouping_makespan`] — and therefore to the
/// simulator — at every point of an annealing trajectory (pinned by the
/// 1000-move property test in `optimizer::search`).
#[derive(Debug, Clone)]
pub struct MakespanEval {
    t_l: u64,
    t_w: u64,
    t_acc: u64,
    size_mem: u64,
    c_in: u64,
    c_out: u64,
    kernel_elements: u64,
    /// Footprint sizes in visit order (spatial pixels).
    fp: Vec<u64>,
    /// Boundary overlaps in visit order (`ov[0]` unused = 0).
    ov: Vec<u64>,
    /// Group lengths in visit order.
    glen: Vec<u64>,
    /// Number of DMA channels (the leading `dma_channels` entries of a
    /// state row are their frontiers).
    dma_channels: usize,
    /// Row width of the flattened timeline state:
    /// [`OverlapTimeline::state_len`] of the accelerator's resource shape.
    stride: usize,
    /// Flattened timeline state after each position, `stride` entries per
    /// row (row `k` = after the flush).
    state: Vec<u64>,
    makespan: u64,
    /// Running state row reused across [`MakespanEval::score`] calls.
    cur: Vec<u64>,
    /// Recomputed state rows of the staged move (flattened, like `state`).
    scratch: Vec<u64>,
    pending: Option<PendingTimeline>,
}

impl MakespanEval {
    /// Build the evaluator for `groups` (in visit order) on `acc`.
    pub fn new(layer: &ConvLayer, acc: &Accelerator, groups: &[Vec<PatchId>]) -> Self {
        let k = groups.len();
        let mut fp = Vec::with_capacity(k);
        let mut ov = vec![0u64; k];
        let mut glen = Vec::with_capacity(k);
        let mut prev: Option<crate::tensor::PixelSet> = None;
        for (i, g) in groups.iter().enumerate() {
            let f = layer.group_pixels(g);
            if let Some(p) = &prev {
                ov[i] = p.intersection_len(&f) as u64;
            }
            fp.push(f.len() as u64);
            glen.push(g.len() as u64);
            prev = Some(f);
        }
        let dma_channels = acc.dma_channels.max(1);
        let stride =
            OverlapTimeline::state_len(dma_channels, acc.compute_units.max(1));
        let mut eval = MakespanEval {
            t_l: acc.t_l,
            t_w: acc.t_w,
            t_acc: acc.t_acc,
            size_mem: acc.size_mem,
            c_in: layer.c_in as u64,
            c_out: layer.c_out() as u64,
            kernel_elements: layer.kernel_elements() as u64,
            fp,
            ov,
            glen,
            dma_channels,
            stride,
            state: Vec::with_capacity((k + 1) * stride),
            makespan: 0,
            cur: vec![0; stride],
            scratch: Vec::with_capacity((k + 1) * stride),
            pending: None,
        };
        let mut cur = vec![0u64; stride];
        for p in 0..=k {
            eval.advance(p, &mut cur, None, &[None, None]);
            eval.state.extend_from_slice(&cur);
        }
        eval.makespan = cur[..stride - 1].iter().copied().max().unwrap_or(0);
        eval
    }

    /// Current makespan of the grouping (O(1)).
    pub fn makespan(&self) -> u64 {
        self.makespan
    }

    /// Number of compute positions `k` (the flush is position `k`).
    fn k(&self) -> usize {
        self.fp.len()
    }

    // -------------------------------------------------- staged-param views

    /// Footprint size at `position` under the staged effect (footprint
    /// overrides come solely from the effect, never from length edits).
    fn view_fp(&self, p: usize, effect: Option<&StagedEffect>) -> u64 {
        match effect {
            Some(StagedEffect::Edit2 { pos_a, pos_b, new_size_a, new_size_b, .. }) => {
                if p == *pos_a {
                    *new_size_a as u64
                } else if p == *pos_b {
                    *new_size_b as u64
                } else {
                    self.fp[p]
                }
            }
            Some(StagedEffect::SwapAdjacent { i, .. }) => {
                if p == *i {
                    self.fp[i + 1]
                } else if p == i + 1 {
                    self.fp[*i]
                } else {
                    self.fp[p]
                }
            }
            Some(StagedEffect::Reverse { a, b, .. }) => {
                if p >= *a && p <= *b {
                    self.fp[a + b - p]
                } else {
                    self.fp[p]
                }
            }
            None => self.fp[p],
        }
    }

    /// Boundary overlap entering `position` under the staged effect
    /// (0 at position 0 by definition).
    fn view_ov(&self, p: usize, effect: Option<&StagedEffect>) -> u64 {
        if p == 0 {
            return 0;
        }
        let edge_override = |edges: &[(usize, usize)]| {
            edges.iter().find(|&&(e, _)| e == p).map(|&(_, v)| v as u64)
        };
        match effect {
            Some(StagedEffect::Edit2 { edges, n_edges, .. }) => {
                edge_override(&edges[..*n_edges]).unwrap_or(self.ov[p])
            }
            Some(StagedEffect::SwapAdjacent { edges, n_edges, .. }) => {
                edge_override(&edges[..*n_edges]).unwrap_or(self.ov[p])
            }
            Some(StagedEffect::Reverse { a, b, edges, n_edges }) => {
                if let Some(v) = edge_override(&edges[..*n_edges]) {
                    v
                } else if p >= a + 1 && p <= *b {
                    // Interior edges are the same unordered pairs backwards.
                    self.ov[a + b + 1 - p]
                } else {
                    self.ov[p]
                }
            }
            None => self.ov[p],
        }
    }

    /// Group length at `position` under the staged effect + length edits.
    fn view_glen(
        &self,
        p: usize,
        effect: Option<&StagedEffect>,
        glens: &[Option<GlenEdit>; 2],
    ) -> u64 {
        for ge in glens.iter().flatten() {
            if ge.pos == p {
                return ge.new_len;
            }
        }
        match effect {
            Some(StagedEffect::SwapAdjacent { i, .. }) => {
                if p == *i {
                    self.glen[i + 1]
                } else if p == i + 1 {
                    self.glen[*i]
                } else {
                    self.glen[p]
                }
            }
            Some(StagedEffect::Reverse { a, b, .. }) => {
                if p >= *a && p <= *b {
                    self.glen[a + b - p]
                } else {
                    self.glen[p]
                }
            }
            _ => self.glen[p],
        }
    }

    /// One step of the recurrence: position `p`'s (load, write, compute,
    /// residency) under the staged view, advanced in place on the flattened
    /// `state` row through the shared [`OverlapTimeline::place_on`] rules
    /// (the k×m list scheduler; 1×1 is the §3.7 recurrence). Position `k`
    /// is the terminal flush.
    fn advance(
        &self,
        p: usize,
        state: &mut [u64],
        effect: Option<&StagedEffect>,
        glens: &[Option<GlenEdit>; 2],
    ) {
        let k = self.k();
        let (loaded, written, compute, prev_occ) = if p < k {
            let load_px = self.view_fp(p, effect).saturating_sub(self.view_ov(p, effect));
            let mut loaded = load_px * self.c_in;
            if p == 0 {
                loaded += self.kernel_elements;
            }
            let written =
                if p == 0 { 0 } else { self.view_glen(p - 1, effect, glens) * self.c_out };
            let compute =
                if self.view_glen(p, effect, glens) > 0 { self.t_acc } else { 0 };
            let prev_occ = if p == 0 {
                0
            } else {
                self.kernel_elements
                    + self.view_fp(p - 1, effect) * self.c_in
                    + self.view_glen(p - 1, effect, glens) * self.c_out
            };
            (loaded, written, compute, prev_occ)
        } else {
            let prev_occ = self.kernel_elements
                + self.view_fp(k - 1, effect) * self.c_in
                + self.view_glen(k - 1, effect, glens) * self.c_out;
            (0, self.view_glen(k - 1, effect, glens) * self.c_out, 0, prev_occ)
        };
        let can_prefetch = prev_occ + loaded <= self.size_mem;
        OverlapTimeline::place_on(
            state,
            self.dma_channels,
            loaded * self.t_l,
            written * self.t_w,
            compute,
            can_prefetch,
        );
    }

    // ------------------------------------------------------- score / commit

    /// Score the staged move: the exact makespan delta, computed by
    /// replaying the recurrence from the first affected position with
    /// uniform-shift early exit. `glen_a` / `glen_b` carry the group-length
    /// overrides of content moves (`(position, new length)`); order moves
    /// pass `None`. Nothing observable changes; commit with
    /// [`MakespanEval::commit`], or score the next move to discard.
    pub fn score(
        &mut self,
        effect: StagedEffect,
        glen_a: Option<(usize, u64)>,
        glen_b: Option<(usize, u64)>,
    ) -> i64 {
        let k = self.k();
        let glens = [
            glen_a.map(|(pos, new_len)| GlenEdit { pos, new_len }),
            glen_b.map(|(pos, new_len)| GlenEdit { pos, new_len }),
        ];
        // Affected position range: a changed size/length at `p` perturbs
        // steps `p` and `p + 1` (write + residency come from the
        // predecessor); a changed edge at `e` perturbs step `e`.
        let (mut lo, mut hi) = match &effect {
            StagedEffect::Edit2 { pos_a, pos_b, edges, n_edges, .. } => {
                let mut lo = *pos_a.min(pos_b);
                let mut hi = *pos_a.max(pos_b) + 1;
                for &(e, _) in &edges[..*n_edges] {
                    lo = lo.min(e);
                    hi = hi.max(e);
                }
                (lo, hi)
            }
            StagedEffect::SwapAdjacent { i, .. } => (*i, i + 2),
            StagedEffect::Reverse { a, b, .. } => (*a, b + 1),
        };
        for ge in glens.iter().flatten() {
            lo = lo.min(ge.pos);
            hi = hi.max(ge.pos + 1);
        }
        let hi = hi.min(k);

        let stride = self.stride;
        let mut cur = std::mem::take(&mut self.cur);
        if lo == 0 {
            cur.fill(0);
        } else {
            cur.copy_from_slice(&self.state[(lo - 1) * stride..lo * stride]);
        }
        self.scratch.clear();
        let mut end = k;
        let mut shift = 0i64;
        let mut converged = false;
        for p in lo..=k {
            self.advance(p, &mut cur, Some(&effect), &glens);
            self.scratch.extend_from_slice(&cur);
            if p >= hi && p < k {
                // Uniform-shift early exit: every resource frontier (and the
                // issue-order gate) moved by one common offset, so the whole
                // suffix translates — the recurrence is (max, +).
                let old = &self.state[p * stride..(p + 1) * stride];
                let sd = cur[0] as i64 - old[0] as i64;
                if cur.iter().zip(old).all(|(n, o)| *n as i64 - *o as i64 == sd) {
                    end = p;
                    shift = sd;
                    converged = true;
                    break;
                }
            }
        }
        let new_makespan = if converged {
            (self.makespan as i64 + shift) as u64
        } else {
            cur[..stride - 1].iter().copied().max().unwrap_or(0)
        };
        self.cur = cur;
        let delta = new_makespan as i64 - self.makespan as i64;
        self.pending = Some(PendingTimeline {
            effect,
            glens,
            first: lo,
            end,
            shift,
            new_makespan,
        });
        delta
    }

    /// Apply the staged move: parameter edits land, the recomputed state
    /// segment is copied in, and the converged suffix is shifted uniformly.
    /// Panics when nothing is staged.
    pub fn commit(&mut self) {
        let pend = self.pending.take().expect("MakespanEval::commit without a scored move");
        match pend.effect {
            StagedEffect::Edit2 {
                pos_a,
                pos_b,
                new_size_a,
                new_size_b,
                edges,
                n_edges,
            } => {
                self.fp[pos_a] = new_size_a as u64;
                self.fp[pos_b] = new_size_b as u64;
                for &(e, v) in &edges[..n_edges] {
                    self.ov[e] = v as u64;
                }
            }
            StagedEffect::SwapAdjacent { i, edges, n_edges } => {
                self.fp.swap(i, i + 1);
                self.glen.swap(i, i + 1);
                for &(e, v) in &edges[..n_edges] {
                    self.ov[e] = v as u64;
                }
            }
            StagedEffect::Reverse { a, b, edges, n_edges } => {
                self.fp[a..=b].reverse();
                self.glen[a..=b].reverse();
                self.ov[a + 1..=b].reverse();
                for &(e, v) in &edges[..n_edges] {
                    self.ov[e] = v as u64;
                }
            }
        }
        for ge in pend.glens.iter().flatten() {
            self.glen[ge.pos] = ge.new_len;
        }
        let stride = self.stride;
        for (off, p) in (pend.first..=pend.end).enumerate() {
            self.state[p * stride..(p + 1) * stride]
                .copy_from_slice(&self.scratch[off * stride..(off + 1) * stride]);
        }
        if pend.shift != 0 {
            for v in &mut self.state[(pend.end + 1) * stride..] {
                *v = (*v as i64 + pend.shift) as u64;
            }
        }
        self.makespan = pend.new_makespan;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::platform::{OverlapMode, Platform};
    use crate::sim::Simulator;
    use crate::strategy;

    fn acc_for(l: &ConvLayer, g: usize) -> Accelerator {
        Accelerator {
            t_acc: 4,
            t_w: 1,
            ..Accelerator::for_group_size(l, g)
        }
    }

    /// From-scratch construction must equal the simulator's double-buffered
    /// makespan for the same strategy — `MakespanEval` is the single Rust
    /// implementation of the §3.7 lowering (`grouping_makespan` delegates
    /// here), so this anchors it against the independent engine codepath.
    #[test]
    fn new_matches_the_simulator() {
        for (l, g) in [
            (ConvLayer::square(1, 8, 3, 1), 4usize),
            (ConvLayer::new(2, 9, 9, 3, 3, 2, 1, 1).unwrap().with_dilation(2, 2).unwrap(), 3),
        ] {
            let acc = acc_for(&l, g).with_overlap(OverlapMode::DoubleBuffered);
            let sim = Simulator::new(l, Platform::new(acc));
            for s in [strategy::row_by_row(&l, g), strategy::zigzag(&l, g)] {
                let eval = MakespanEval::new(&l, &acc, &s.groups);
                assert_eq!(
                    eval.makespan(),
                    sim.run(&s).unwrap().duration,
                    "{}",
                    s.name
                );
            }
        }
    }

    /// The generalized evaluator must agree with the engine's timeline on
    /// multi-resource accelerators too — same list scheduler, two codepaths.
    #[test]
    fn new_matches_the_simulator_multi_resource() {
        let l = ConvLayer::square(1, 8, 3, 1);
        let g = 4usize;
        for (k, m) in [(2, 1), (1, 2), (3, 2)] {
            let acc = acc_for(&l, g)
                .with_overlap(OverlapMode::DoubleBuffered)
                .with_channels(k, m);
            let sim = Simulator::new(l, Platform::new(acc));
            for s in [strategy::row_by_row(&l, g), strategy::zigzag(&l, g)] {
                let eval = MakespanEval::new(&l, &acc, &s.groups);
                assert_eq!(
                    eval.makespan(),
                    sim.run(&s).unwrap().duration,
                    "{} {k}x{m}",
                    s.name
                );
            }
        }
    }

    /// Roomier memory can only help: the makespan is monotone in
    /// `size_mem` (more prefetches succeed).
    #[test]
    fn makespan_is_monotone_in_memory() {
        let l = ConvLayer::square(1, 8, 3, 1);
        let base = acc_for(&l, 4);
        let s = strategy::row_by_row(&l, 4);
        let mut last = u64::MAX;
        for extra in [0u64, 8, 32, 128, 100_000] {
            let acc = Accelerator { size_mem: base.size_mem + extra, ..base };
            let m = MakespanEval::new(&l, &acc, &s.groups).makespan();
            assert!(m <= last, "mem+{extra}: {m} > {last}");
            last = m;
        }
    }
}

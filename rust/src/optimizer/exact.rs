//! Specialized exact branch & bound over ordered patch partitions.
//!
//! Searches the space of ordered partitions of `X` into `k` groups of ≤ `g`
//! patches, minimizing the Eq. 15 load total
//! `Σ_k |pix(g_k) ∖ pix(g_{k−1})|`. Pruning:
//!
//! * **incumbent** — seeded by the heuristic MIP start;
//! * **admissible lower bound** — every pixel used by a still-unassigned
//!   patch and not resident in the group under construction must be loaded
//!   at least once more: `bound = cost + |pix(unassigned) ∖ pix(current)|`;
//! * **within-group symmetry** — group members are chosen in increasing
//!   patch id (group contents are a set);
//! * **reversal symmetry** — a grouping and its reverse have the same cost,
//!   so the first group is required to contain a patch id no larger than the
//!   smallest id in the last group. (Enforced cheaply: patch 0 must appear
//!   in the first half of the groups.)

use std::time::{Duration, Instant};

use crate::conv::{ConvLayer, PatchId};
use crate::tensor::PixelSet;

/// Budget for [`solve_exact_with`]: a deterministic node cap (checked every
/// node, so two runs with the same cap visit the same prefix of the search
/// tree) plus a wall-clock safety net (checked sparsely).
#[derive(Debug, Clone, Copy)]
pub struct ExactLimits {
    /// Wall-clock safety net (coarse; the node cap is the reproducible one).
    pub time: Duration,
    /// Maximum DFS nodes to expand before giving up.
    pub nodes: u64,
}

impl Default for ExactLimits {
    fn default() -> Self {
        ExactLimits { time: Duration::from_secs(30), nodes: 2_000_000 }
    }
}

/// Outcome of a budgeted exact search.
#[derive(Debug, Clone)]
pub struct ExactSearch {
    /// Best grouping found (`None` when there is no incumbent: no MIP start
    /// and the budget expired before the first leaf, or the shape is
    /// infeasible).
    pub groups: Option<Vec<Vec<PatchId>>>,
    /// True iff the search space was exhausted — the result is *proven*
    /// optimal (or proven infeasible when `groups` is `None`).
    pub complete: bool,
    /// DFS nodes expanded.
    pub nodes: u64,
}

/// Exact solve. Returns `None` if the wall-clock budget is exhausted before
/// the search completes (caller falls back to polish).
pub fn solve_exact(
    layer: &ConvLayer,
    g: usize,
    k: usize,
    budget: Duration,
    mip_start: Option<&[Vec<PatchId>]>,
) -> Option<Vec<Vec<PatchId>>> {
    let limits = ExactLimits { time: budget, nodes: u64::MAX };
    let r = solve_exact_with(layer, g, k, limits, mip_start);
    if r.complete {
        r.groups
    } else {
        None
    }
}

/// Budgeted exact solve: like [`solve_exact`] but with a deterministic node
/// cap and a result that distinguishes "proven optimal" (`complete`) from
/// "best incumbent when the budget ran out". The certification path
/// ([`crate::planner::certify`]) only trusts `complete` results.
pub fn solve_exact_with(
    layer: &ConvLayer,
    g: usize,
    k: usize,
    limits: ExactLimits,
    mip_start: Option<&[Vec<PatchId>]>,
) -> ExactSearch {
    let n = layer.n_patches();
    if k * g < n || k > n {
        // Trivially exhausted: no ordered partition of this shape exists.
        return ExactSearch { groups: None, complete: true, nodes: 0 };
    }
    let patch_pixels: Vec<PixelSet> =
        (0..n as u32).map(|p| layer.patch_pixels(p)).collect();

    // Incumbent from MIP start.
    let mut best_cost = usize::MAX;
    let mut best: Option<Vec<Vec<PatchId>>> = None;
    if let Some(start) = mip_start {
        let start = crate::optimizer::search::normalize(start, g, k);
        let cost = grouping_cost(&patch_pixels, layer.n_pixels(), &start);
        best_cost = cost;
        best = Some(start);
    }

    let mut dfs = Dfs {
        layer_pixels: layer.n_pixels(),
        patch_pixels,
        g,
        k,
        best_cost,
        best: best.clone(),
        deadline: Instant::now() + limits.time,
        node_budget: limits.nodes,
        timed_out: false,
        nodes: 0,
    };

    let unassigned_all = PixelSet::full(n); // over patch ids
    let mut union_unassigned = PixelSet::empty(layer.n_pixels());
    for p in 0..n as u32 {
        union_unassigned.union_with(&dfs.patch_pixels[p as usize]);
    }
    let mut groups: Vec<Vec<PatchId>> = Vec::with_capacity(k);
    let empty = PixelSet::empty(layer.n_pixels());
    dfs.recurse(
        &mut groups,
        unassigned_all,
        union_unassigned,
        empty.clone(),
        empty,
        0,
        0,
    );

    ExactSearch { groups: dfs.best, complete: !dfs.timed_out, nodes: dfs.nodes }
}

/// Cost of a complete grouping (duplicated from `objective` on raw sets to
/// keep this module self-contained for testing).
fn grouping_cost(
    patch_pixels: &[PixelSet],
    n_pixels: usize,
    groups: &[Vec<PatchId>],
) -> usize {
    let mut prev = PixelSet::empty(n_pixels);
    let mut cost = 0;
    for g in groups {
        let mut fp = PixelSet::empty(n_pixels);
        for &p in g {
            fp.union_with(&patch_pixels[p as usize]);
        }
        cost += fp.difference_len(&prev);
        prev = fp;
    }
    cost
}

struct Dfs {
    layer_pixels: usize,
    patch_pixels: Vec<PixelSet>,
    g: usize,
    k: usize,
    best_cost: usize,
    best: Option<Vec<Vec<PatchId>>>,
    deadline: Instant,
    node_budget: u64,
    timed_out: bool,
    nodes: u64,
}

impl Dfs {
    /// Extend the partial grouping.
    ///
    /// * `groups` — closed groups so far;
    /// * `unassigned` — patch-id set not yet placed;
    /// * `union_unassigned` — pixel union of unassigned patches;
    /// * `prev_fp` — footprint of the last *closed* group;
    /// * `cur_fp` — footprint of the group under construction (`groups` does
    ///   NOT yet contain it; members are in `cur_members`-by-recursion);
    /// * `cost` — loads committed so far (closed groups + current partial).
    #[allow(clippy::too_many_arguments)]
    fn recurse(
        &mut self,
        groups: &mut Vec<Vec<PatchId>>,
        unassigned: PixelSet,
        union_unassigned: PixelSet,
        prev_fp: PixelSet,
        cur_fp: PixelSet,
        cur_cost: usize,
        cur_len: usize,
    ) {
        self.nodes += 1;
        // Node cap first (checked every node: reproducible across machines),
        // wall clock as a sparse safety net.
        if self.nodes > self.node_budget
            || (self.nodes % 4096 == 0 && Instant::now() > self.deadline)
        {
            self.timed_out = true;
        }
        if self.timed_out {
            return;
        }

        // `groups` includes the group under construction when cur_len > 0.
        if unassigned.is_empty() {
            // Complete iff exactly k non-empty groups were formed.
            if groups.len() == self.k && cur_cost < self.best_cost {
                self.best_cost = cur_cost;
                self.best = Some(groups.clone());
            }
            return;
        }

        // Admissible bound: every unassigned-patch pixel that is neither in
        // the open group's footprint nor reusable from the previous group's
        // (`I_k = pix(g_k) ∖ pix(g_{k−1})`) must be loaded at least once.
        let mut free = cur_fp.clone();
        free.union_with(&prev_fp);
        let remaining = union_unassigned.difference_len(&free);
        if cur_cost + remaining >= self.best_cost {
            return;
        }

        // Groups still to be opened after this point.
        let to_open = self.k - groups.len();
        let slots_left = to_open * self.g
            + if cur_len > 0 { self.g - cur_len } else { 0 };
        let un_count = unassigned.len();
        if un_count > slots_left || un_count < to_open {
            return; // cannot place everything / cannot fill every group
        }

        // Option A: close the current group (a later Option-B call opens the
        // next one). Requires at least one group left to open.
        if cur_len > 0 && to_open >= 1 {
            debug_assert_eq!(groups.last().map(Vec::len), Some(cur_len));
            self.recurse(
                groups,
                unassigned.clone(),
                union_unassigned.clone(),
                cur_fp.clone(),
                PixelSet::empty(self.layer_pixels),
                cur_cost,
                0,
            );
        }

        // Option B: extend the current group (or open a new one when
        // cur_len == 0, allowed only while groups remain to open).
        if cur_len < self.g && (cur_len > 0 || to_open >= 1) {
            // Within-group symmetry: only ids greater than the last member.
            let min_id = if cur_len > 0 {
                groups.last().unwrap().last().copied().unwrap() + 1
            } else {
                0
            };
            let candidates: Vec<PatchId> = unassigned
                .iter()
                .filter(|&p| p >= min_id)
                .collect();
            for p in candidates {
                // Reversal symmetry: patch 0 must be placed within the first
                // ⌈k/2⌉ groups.
                if p == 0 {
                    let group_idx = if cur_len > 0 { groups.len() - 1 } else { groups.len() };
                    if group_idx > (self.k - 1) / 2 {
                        continue;
                    }
                }
                let pp = &self.patch_pixels[p as usize];
                // Load increment: pixels of p not in current footprint and
                // not reused from the previous group's footprint.
                let mut new_pixels = pp.clone();
                new_pixels.subtract(&cur_fp);
                let mut loaded = new_pixels.clone();
                if cur_len == 0 {
                    // First member: reuse comes from the previous group.
                    loaded.subtract(&prev_fp);
                } else {
                    // Group footprint grows; pixels shared with prev_fp were
                    // already discounted when the first members joined only
                    // if they were in cur_fp; discount prev_fp overlap for
                    // the new pixels as well.
                    loaded.subtract(&prev_fp);
                }
                let inc = loaded.len();

                let mut next_unassigned = unassigned.clone();
                next_unassigned.remove(p);
                let mut next_union = PixelSet::empty(self.layer_pixels);
                for q in next_unassigned.iter() {
                    next_union.union_with(&self.patch_pixels[q as usize]);
                }
                let mut next_fp = cur_fp.clone();
                next_fp.union_with(pp);

                if cur_len == 0 {
                    groups.push(vec![p]);
                } else {
                    groups.last_mut().unwrap().push(p);
                }
                self.recurse(
                    groups,
                    next_unassigned,
                    next_union,
                    prev_fp.clone(),
                    next_fp,
                    cur_cost + inc,
                    cur_len + 1,
                );
                if cur_len == 0 {
                    groups.pop();
                } else {
                    groups.last_mut().unwrap().pop();
                }
                if self.timed_out {
                    return;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optimizer::objective::grouping_loads;
    use crate::strategy;

    fn brute_force(layer: &ConvLayer, g: usize, k: usize) -> usize {
        // Enumerate all ordered partitions via permutations + chunkings.
        // Feasible only for tiny n; used to validate the DFS pruning.
        fn perms(items: &[u32]) -> Vec<Vec<u32>> {
            if items.len() <= 1 {
                return vec![items.to_vec()];
            }
            let mut out = Vec::new();
            for (i, &x) in items.iter().enumerate() {
                let mut rest = items.to_vec();
                rest.remove(i);
                for mut p in perms(&rest) {
                    p.insert(0, x);
                    out.push(p);
                }
            }
            out
        }
        fn chunkings(order: &[u32], g: usize, k: usize) -> Vec<Vec<Vec<u32>>> {
            // all ways to split `order` into k contiguous non-empty chunks ≤ g
            fn rec(rest: &[u32], g: usize, k: usize) -> Vec<Vec<Vec<u32>>> {
                if k == 0 {
                    return if rest.is_empty() { vec![vec![]] } else { vec![] };
                }
                let mut out = Vec::new();
                for take in 1..=g.min(rest.len()) {
                    let (head, tail) = rest.split_at(take);
                    for mut rec_split in rec(tail, g, k - 1) {
                        rec_split.insert(0, head.to_vec());
                        out.push(rec_split);
                    }
                }
                out
            }
            rec(order, g, k)
        }
        let ids: Vec<u32> = layer.all_patches().collect();
        let mut best = usize::MAX;
        for perm in perms(&ids) {
            for split in chunkings(&perm, g, k) {
                best = best.min(grouping_loads(layer, &split) as usize);
            }
        }
        best
    }

    #[test]
    fn exact_matches_brute_force_tiny() {
        // 4x4 input, 3x3 kernel → 2x2 out = 4 patches
        let l = ConvLayer::square(1, 4, 3, 1);
        for (g, k) in [(1usize, 4usize), (2, 2), (2, 3)] {
            if k * g < l.n_patches() {
                continue;
            }
            let bf = brute_force(&l, g, k);
            let got = solve_exact(&l, g, k, Duration::from_secs(30), None)
                .expect("must finish");
            assert_eq!(
                grouping_loads(&l, &got) as usize,
                bf,
                "g={g} k={k}: {got:?}"
            );
        }
    }

    #[test]
    fn exact_matches_brute_force_5x5_g2() {
        // 5x5 input → 9 patches; brute force on g=3,k=3 would be huge, use
        // a 5x4 rectangle → 3x2 = 6 patches with g=3,k=2 (6!·splits ≈ small)
        let l = ConvLayer::new(1, 5, 4, 3, 3, 1, 1, 1).unwrap();
        assert_eq!(l.n_patches(), 6);
        let bf = brute_force(&l, 3, 2);
        let got = solve_exact(&l, 3, 2, Duration::from_secs(30), None).unwrap();
        assert_eq!(grouping_loads(&l, &got) as usize, bf);
    }

    #[test]
    fn exact_beats_or_matches_heuristics() {
        let l = ConvLayer::square(1, 5, 3, 1); // 9 patches
        for g in [2usize, 3] {
            let k = l.n_patches().div_ceil(g);
            let start = strategy::row_by_row(&l, g).groups;
            let got = solve_exact(&l, g, k, Duration::from_secs(30), Some(&start))
                .expect("should finish");
            let zig = grouping_loads(&l, &strategy::zigzag(&l, g).groups);
            let row = grouping_loads(&l, &start);
            let opt = grouping_loads(&l, &got);
            assert!(opt <= zig.min(row), "g={g}: {opt} vs {} {}", row, zig);
            // structure checks
            assert_eq!(got.len(), k);
            assert!(got.iter().all(|gr| !gr.is_empty() && gr.len() <= g));
            let mut all: Vec<u32> = got.iter().flatten().copied().collect();
            all.sort();
            assert_eq!(all, l.all_patches().collect::<Vec<_>>());
        }
    }

    #[test]
    fn infeasible_parameters_rejected() {
        let l = ConvLayer::square(1, 5, 3, 1);
        assert!(solve_exact(&l, 2, 2, Duration::from_secs(1), None).is_none()); // 4 < 9
        assert!(solve_exact(&l, 1, 10, Duration::from_secs(1), None).is_none()); // k > n
    }

    #[test]
    fn timeout_returns_none() {
        let l = ConvLayer::square(1, 8, 3, 1); // 36 patches — way too big
        let got = solve_exact(&l, 4, 9, Duration::from_millis(10), None);
        assert!(got.is_none());
    }

    #[test]
    fn node_budget_is_deterministic_and_keeps_the_incumbent() {
        let l = ConvLayer::square(1, 6, 3, 1); // 16 patches — needs pruning
        let start = strategy::row_by_row(&l, 4).groups;
        let limits = ExactLimits { time: Duration::from_secs(120), nodes: 500 };
        let a = solve_exact_with(&l, 4, 4, limits, Some(&start));
        let b = solve_exact_with(&l, 4, 4, limits, Some(&start));
        assert!(!a.complete, "500 nodes cannot exhaust 16 patches");
        assert_eq!(a.nodes, b.nodes, "node-capped search must be reproducible");
        assert_eq!(a.groups, b.groups);
        let got = a.groups.expect("MIP start guarantees an incumbent");
        assert!(grouping_loads(&l, &got) <= grouping_loads(&l, &start));
    }

    #[test]
    fn infeasible_shape_is_proven_complete() {
        let l = ConvLayer::square(1, 5, 3, 1); // 9 patches
        let r = solve_exact_with(&l, 2, 2, ExactLimits::default(), None);
        assert!(r.complete && r.groups.is_none());
        assert_eq!(r.nodes, 0);
    }

    #[test]
    fn budgeted_complete_run_matches_the_unbudgeted_path() {
        let l = ConvLayer::square(1, 4, 3, 1); // 4 patches
        let r = solve_exact_with(&l, 2, 2, ExactLimits::default(), None);
        assert!(r.complete);
        let plain = solve_exact(&l, 2, 2, Duration::from_secs(30), None).unwrap();
        assert_eq!(
            grouping_loads(&l, r.groups.as_ref().unwrap()),
            grouping_loads(&l, &plain)
        );
    }
}

//! Sparse patch-overlap graph.
//!
//! Two patches share input pixels iff their receptive-field rectangles
//! intersect, and a patch's rectangle only reaches a bounded neighborhood of
//! output coordinates: `P_{i,j}` and `P_{i',j'}` overlap exactly when
//! `|i − i'| · s_h < H_K` and `|j − j'| · s_w < W_K` (Definition 10). The
//! overlap size is then analytic — `(H_K − |Δi|·s_h) · (W_K − |Δj|·s_w)`
//! pixels — so the whole graph is O(|X| · deg) to build with **zero** pixel-set
//! operations, and `deg ≤ (2⌈H_K/s_h⌉ − 1)(2⌈W_K/s_w⌉ − 1) − 1` is a small
//! constant (24 for the paper's 3×3 stride-1 layers).
//!
//! The optimizer uses the graph two ways:
//! * the greedy construction scores only a new patch's neighbors instead of
//!   intersecting full `PixelSet`s against every unassigned patch
//!   (O(n²·pixels/64) → O(n² integer scan + n·deg) — see
//!   [`crate::optimizer::search::greedy`]);
//! * the annealer's optional neighbor-biased proposals
//!   ([`crate::optimizer::search::AnnealOptions`]) draw relocation targets
//!   from a patch's neighborhood, where moves are most likely to pay off.

use crate::conv::{ConvLayer, PatchId};

/// Compressed-sparse-row adjacency of spatially-overlapping patches with
/// cached pairwise overlap sizes.
#[derive(Debug, Clone)]
pub struct OverlapGraph {
    /// CSR row offsets, `n_patches + 1` entries.
    offsets: Vec<u32>,
    /// Concatenated `(neighbor id, overlap pixels)` rows; each row is sorted
    /// by neighbor id (the build order is lexicographic in `(Δi, Δj)`, which
    /// is id-monotone).
    neighbors: Vec<(PatchId, u32)>,
}

impl OverlapGraph {
    /// Build the graph for a layer. `O(|X| · deg)`, no pixel-set operations.
    pub fn build(layer: &ConvLayer) -> Self {
        let h_out = layer.h_out();
        let w_out = layer.w_out();
        let n = h_out * w_out;
        // Largest output-coordinate distance at which rectangles still meet.
        let dh_max = (layer.h_k - 1) / layer.s_h;
        let dw_max = (layer.w_k - 1) / layer.s_w;
        let max_deg = (2 * dh_max + 1) * (2 * dw_max + 1) - 1;

        let mut offsets = Vec::with_capacity(n + 1);
        let mut neighbors = Vec::with_capacity(n * max_deg);
        offsets.push(0u32);
        for i in 0..h_out {
            for j in 0..w_out {
                for di in -(dh_max as isize)..=dh_max as isize {
                    let ni = i as isize + di;
                    if ni < 0 || ni as usize >= h_out {
                        continue;
                    }
                    let rows = layer.h_k - di.unsigned_abs() * layer.s_h;
                    for dj in -(dw_max as isize)..=dw_max as isize {
                        if di == 0 && dj == 0 {
                            continue;
                        }
                        let nj = j as isize + dj;
                        if nj < 0 || nj as usize >= w_out {
                            continue;
                        }
                        let cols = layer.w_k - dj.unsigned_abs() * layer.s_w;
                        let id = (ni as usize * w_out + nj as usize) as PatchId;
                        neighbors.push((id, (rows * cols) as u32));
                    }
                }
                offsets.push(neighbors.len() as u32);
            }
        }
        OverlapGraph { offsets, neighbors }
    }

    /// Number of patches (graph nodes).
    pub fn n_patches(&self) -> usize {
        self.offsets.len() - 1
    }

    /// Neighbors of `p` with their overlap sizes, sorted by neighbor id.
    #[inline]
    pub fn neighbors(&self, p: PatchId) -> &[(PatchId, u32)] {
        let (a, b) = (self.offsets[p as usize], self.offsets[p as usize + 1]);
        &self.neighbors[a as usize..b as usize]
    }

    /// Degree of `p`.
    pub fn degree(&self, p: PatchId) -> usize {
        self.neighbors(p).len()
    }

    /// Largest degree in the graph (the `deg` of the complexity bounds).
    pub fn max_degree(&self) -> usize {
        (0..self.n_patches() as PatchId)
            .map(|p| self.degree(p))
            .max()
            .unwrap_or(0)
    }

    /// Total directed edge count.
    pub fn edge_count(&self) -> usize {
        self.neighbors.len()
    }

    /// Pairwise overlap in pixels; 0 when the patches are disjoint.
    /// Binary search in `a`'s sorted row — `O(log deg)`.
    pub fn overlap(&self, a: PatchId, b: PatchId) -> usize {
        let row = self.neighbors(a);
        match row.binary_search_by_key(&b, |&(id, _)| id) {
            Ok(idx) => row[idx].1 as usize,
            Err(_) => 0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn check_against_rects(layer: &ConvLayer) {
        let g = OverlapGraph::build(layer);
        assert_eq!(g.n_patches(), layer.n_patches());
        for a in layer.all_patches() {
            // Every listed edge matches the rectangle intersection…
            let mut prev_id = None;
            for &(b, size) in g.neighbors(a) {
                assert_ne!(a, b, "no self loops");
                assert_eq!(size as usize, layer.patch_overlap(a, b), "{a}-{b}");
                assert!(size > 0, "{a}-{b} listed but disjoint");
                if let Some(p) = prev_id {
                    assert!(p < b, "row of {a} not sorted");
                }
                prev_id = Some(b);
            }
            // …and every non-listed pair is disjoint.
            for b in layer.all_patches() {
                if a != b && g.overlap(a, b) == 0 {
                    assert_eq!(layer.patch_overlap(a, b), 0, "{a}-{b} missing");
                }
            }
        }
    }

    #[test]
    fn matches_rect_intersection_unit_stride() {
        check_against_rects(&ConvLayer::square(1, 7, 3, 1));
        check_against_rects(&ConvLayer::new(2, 5, 8, 3, 3, 2, 1, 1).unwrap());
        // 5×5 kernels: wider neighborhoods (LeNet family).
        check_against_rects(&ConvLayer::new(1, 12, 12, 5, 5, 1, 1, 1).unwrap());
    }

    #[test]
    fn matches_rect_intersection_strided() {
        // stride 2: overlap shrinks by 2 pixels per step of distance
        check_against_rects(&ConvLayer::new(1, 9, 9, 3, 3, 1, 2, 2).unwrap());
        // stride 3 with 3×3 kernels: fully disjoint patches, empty graph
        let l = ConvLayer::new(1, 9, 9, 3, 3, 1, 3, 3).unwrap();
        let g = OverlapGraph::build(&l);
        assert_eq!(g.edge_count(), 0);
        check_against_rects(&l);
        // anisotropic strides
        check_against_rects(&ConvLayer::new(1, 7, 9, 3, 3, 1, 2, 1).unwrap());
    }

    #[test]
    fn degree_is_bounded_and_symmetric() {
        let l = ConvLayer::square(1, 8, 3, 1); // 6×6 patches, 3×3 stride-1
        let g = OverlapGraph::build(&l);
        // interior patch: full 5×5 neighborhood minus itself
        assert_eq!(g.max_degree(), 24);
        // corner patch 0: 3×3 neighborhood minus itself
        assert_eq!(g.degree(0), 8);
        for a in l.all_patches() {
            for &(b, size) in g.neighbors(a) {
                assert_eq!(g.overlap(b, a), size as usize, "symmetry {a}-{b}");
            }
        }
    }

    #[test]
    fn edge_sizes_decay_with_distance() {
        let l = ConvLayer::square(1, 10, 3, 1); // 8×8 patches
        let g = OverlapGraph::build(&l);
        let center = l.patch_id(4, 4);
        assert_eq!(g.overlap(center, l.patch_id(4, 5)), 6); // 3×2
        assert_eq!(g.overlap(center, l.patch_id(5, 5)), 4); // 2×2
        assert_eq!(g.overlap(center, l.patch_id(4, 6)), 3); // 3×1
        assert_eq!(g.overlap(center, l.patch_id(6, 6)), 1); // 1×1
        assert_eq!(g.overlap(center, l.patch_id(4, 7)), 0); // beyond reach
    }
}

//! Sparse patch-overlap graph.
//!
//! Two patches share input pixels iff their dilated tap lattices intersect.
//! Along one axis, the taps of `P_i` and `P_{i'}` are arithmetic
//! progressions with step `d` and length `K`, offset by `δ = |i − i'|·s`;
//! they share taps iff `d | δ` **and** `δ/d < K`, and then exactly
//! `K − δ/d` of them. With `g = gcd(s, d)`, `t = d/g` and `u = s/g`, the
//! divisibility condition reads `Δ ≡ 0 (mod t)`, so neighbor offsets along
//! the axis are the multiples `Δ = m·t` with `|m| ≤ ⌈K/u⌉ − 1` — a closed
//! form that collapses to the dense rule (`|Δ|·s < K`, overlap `K − |Δ|·s`)
//! at `d = 1`. The overlap size is the product of the two axis counts, so
//! the whole graph is O(|X| · deg) to build with **zero** pixel-set
//! operations, and
//! `deg ≤ (2⌈H_K/u_h⌉ − 1)(2⌈W_K/u_w⌉ − 1) − 1` with `u = s/gcd(s, d)`
//! is a small constant (24 for the paper's 3×3 stride-1 dense layers; the
//! *same* 24 for a dilated 3×3 stride-1 layer, whose lattice holes thin the
//! offsets but `u_h = 1` admits every multiple of `t_h`).
//!
//! Channel groups never appear here: the spatial footprint of a patch is
//! group-independent (every group has kernels, so all `C_in` channels of a
//! footprint pixel load together — see [`crate::conv::ConvLayer`]).
//!
//! The optimizer uses the graph two ways:
//! * the greedy construction scores only a new patch's neighbors instead of
//!   intersecting full `PixelSet`s against every unassigned patch
//!   (O(n²·pixels/64) → O(n² integer scan + n·deg) — see
//!   [`crate::optimizer::search::greedy`]);
//! * the annealer's optional neighbor-biased proposals
//!   ([`crate::optimizer::search::AnnealOptions`]) draw relocation targets
//!   from a patch's neighborhood, where moves are most likely to pay off.

use crate::conv::{ConvLayer, PatchId};

/// Compressed-sparse-row adjacency of spatially-overlapping patches with
/// cached pairwise overlap sizes.
#[derive(Debug, Clone)]
pub struct OverlapGraph {
    /// CSR row offsets, `n_patches + 1` entries.
    offsets: Vec<u32>,
    /// Concatenated `(neighbor id, overlap pixels)` rows; each row is sorted
    /// by neighbor id (the build order is lexicographic in `(Δi, Δj)`, which
    /// is id-monotone).
    neighbors: Vec<(PatchId, u32)>,
}

fn gcd(a: usize, b: usize) -> usize {
    if b == 0 {
        a
    } else {
        gcd(b, a % b)
    }
}

/// Per-axis neighborhood parameters: offsets are `Δ = m·t` for
/// `|m| ≤ m_max`, with `m` taps of overlap `k − |m|·u`.
struct Axis {
    /// Offset step `t = d / gcd(s, d)` — only these `Δ` land on the lattice.
    t: usize,
    /// Overlap decrement per step `u = s / gcd(s, d)`.
    u: usize,
    /// `⌈K/u⌉ − 1` — the largest `|m|` with a positive overlap.
    m_max: usize,
}

impl Axis {
    fn new(k: usize, s: usize, d: usize) -> Axis {
        let g = gcd(s, d);
        let u = s / g;
        Axis { t: d / g, u, m_max: (k - 1) / u }
    }
}

impl OverlapGraph {
    /// Build the graph for a layer. `O(|X| · deg)`, no pixel-set operations.
    pub fn build(layer: &ConvLayer) -> Self {
        let h_out = layer.h_out();
        let w_out = layer.w_out();
        let n = h_out * w_out;
        let ax_h = Axis::new(layer.h_k, layer.s_h, layer.d_h);
        let ax_w = Axis::new(layer.w_k, layer.s_w, layer.d_w);
        let max_deg = (2 * ax_h.m_max + 1) * (2 * ax_w.m_max + 1) - 1;

        let mut offsets = Vec::with_capacity(n + 1);
        let mut neighbors = Vec::with_capacity(n * max_deg);
        offsets.push(0u32);
        for i in 0..h_out {
            for j in 0..w_out {
                for mi in -(ax_h.m_max as isize)..=ax_h.m_max as isize {
                    let ni = i as isize + mi * ax_h.t as isize;
                    if ni < 0 || ni as usize >= h_out {
                        continue;
                    }
                    let rows = layer.h_k - mi.unsigned_abs() * ax_h.u;
                    for mj in -(ax_w.m_max as isize)..=ax_w.m_max as isize {
                        if mi == 0 && mj == 0 {
                            continue;
                        }
                        let nj = j as isize + mj * ax_w.t as isize;
                        if nj < 0 || nj as usize >= w_out {
                            continue;
                        }
                        let cols = layer.w_k - mj.unsigned_abs() * ax_w.u;
                        let id = (ni as usize * w_out + nj as usize) as PatchId;
                        neighbors.push((id, (rows * cols) as u32));
                    }
                }
                offsets.push(neighbors.len() as u32);
            }
        }
        OverlapGraph { offsets, neighbors }
    }

    /// Number of patches (graph nodes).
    pub fn n_patches(&self) -> usize {
        self.offsets.len() - 1
    }

    /// Neighbors of `p` with their overlap sizes, sorted by neighbor id.
    #[inline]
    pub fn neighbors(&self, p: PatchId) -> &[(PatchId, u32)] {
        let (a, b) = (self.offsets[p as usize], self.offsets[p as usize + 1]);
        &self.neighbors[a as usize..b as usize]
    }

    /// Degree of `p`.
    pub fn degree(&self, p: PatchId) -> usize {
        self.neighbors(p).len()
    }

    /// Largest degree in the graph (the `deg` of the complexity bounds).
    pub fn max_degree(&self) -> usize {
        (0..self.n_patches() as PatchId)
            .map(|p| self.degree(p))
            .max()
            .unwrap_or(0)
    }

    /// The closed-form degree bound
    /// `(2⌈H_K/u_h⌉ − 1)(2⌈W_K/u_w⌉ − 1) − 1`, `u = s/gcd(s, d)` — what
    /// [`OverlapGraph::max_degree`] can never exceed.
    pub fn degree_bound(layer: &ConvLayer) -> usize {
        let ax_h = Axis::new(layer.h_k, layer.s_h, layer.d_h);
        let ax_w = Axis::new(layer.w_k, layer.s_w, layer.d_w);
        (2 * ax_h.m_max + 1) * (2 * ax_w.m_max + 1) - 1
    }

    /// Total directed edge count.
    pub fn edge_count(&self) -> usize {
        self.neighbors.len()
    }

    /// Pairwise overlap in pixels; 0 when the patches are disjoint.
    /// Binary search in `a`'s sorted row — `O(log deg)`.
    pub fn overlap(&self, a: PatchId, b: PatchId) -> usize {
        let row = self.neighbors(a);
        match row.binary_search_by_key(&b, |&(id, _)| id) {
            Ok(idx) => row[idx].1 as usize,
            Err(_) => 0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn check_against_layer(layer: &ConvLayer) {
        let g = OverlapGraph::build(layer);
        assert_eq!(g.n_patches(), layer.n_patches());
        assert!(
            g.max_degree() <= OverlapGraph::degree_bound(layer),
            "degree bound violated for {layer}"
        );
        for a in layer.all_patches() {
            // Every listed edge matches the analytic patch overlap **and**
            // the brute-force pixel-set intersection…
            let mut prev_id = None;
            for &(b, size) in g.neighbors(a) {
                assert_ne!(a, b, "no self loops");
                assert_eq!(size as usize, layer.patch_overlap(a, b), "{a}-{b}");
                assert_eq!(
                    size as usize,
                    layer.patch_pixels(a).intersection_len(&layer.patch_pixels(b)),
                    "{layer}: {a}-{b} vs brute force"
                );
                assert!(size > 0, "{a}-{b} listed but disjoint");
                if let Some(p) = prev_id {
                    assert!(p < b, "row of {a} not sorted");
                }
                prev_id = Some(b);
            }
            // …and every non-listed pair is disjoint.
            for b in layer.all_patches() {
                if a != b && g.overlap(a, b) == 0 {
                    assert_eq!(
                        layer
                            .patch_pixels(a)
                            .intersection_len(&layer.patch_pixels(b)),
                        0,
                        "{layer}: {a}-{b} missing"
                    );
                }
            }
        }
    }

    #[test]
    fn matches_rect_intersection_unit_stride() {
        check_against_layer(&ConvLayer::square(1, 7, 3, 1));
        check_against_layer(&ConvLayer::new(2, 5, 8, 3, 3, 2, 1, 1).unwrap());
        // 5×5 kernels: wider neighborhoods (LeNet family).
        check_against_layer(&ConvLayer::new(1, 12, 12, 5, 5, 1, 1, 1).unwrap());
    }

    #[test]
    fn matches_rect_intersection_strided() {
        // stride 2: overlap shrinks by 2 pixels per step of distance
        check_against_layer(&ConvLayer::new(1, 9, 9, 3, 3, 1, 2, 2).unwrap());
        // stride 3 with 3×3 kernels: fully disjoint patches, empty graph
        let l = ConvLayer::new(1, 9, 9, 3, 3, 1, 3, 3).unwrap();
        let g = OverlapGraph::build(&l);
        assert_eq!(g.edge_count(), 0);
        check_against_layer(&l);
        // anisotropic strides
        check_against_layer(&ConvLayer::new(1, 7, 9, 3, 3, 1, 2, 1).unwrap());
    }

    #[test]
    fn matches_brute_force_dilated() {
        // dilation 2, stride 1: offsets must be even to overlap
        check_against_layer(
            &ConvLayer::new(1, 9, 9, 3, 3, 1, 1, 1)
                .unwrap()
                .with_dilation(2, 2)
                .unwrap(),
        );
        // dilation 2, stride 2: every offset lands on the lattice (gcd = 2)
        check_against_layer(
            &ConvLayer::new(1, 11, 11, 3, 3, 1, 2, 2)
                .unwrap()
                .with_dilation(2, 2)
                .unwrap(),
        );
        // dilation 3, stride 2: gcd = 1 — only offsets divisible by 3
        check_against_layer(
            &ConvLayer::new(1, 13, 13, 3, 3, 1, 2, 2)
                .unwrap()
                .with_dilation(3, 3)
                .unwrap(),
        );
        // anisotropic: height dilated, width strided
        check_against_layer(
            &ConvLayer::new(1, 11, 9, 3, 3, 1, 1, 2)
                .unwrap()
                .with_dilation(2, 1)
                .unwrap(),
        );
    }

    /// Groups don't change the spatial graph at all.
    #[test]
    fn groups_do_not_change_the_graph() {
        let dense = ConvLayer::new(4, 8, 8, 3, 3, 4, 1, 1).unwrap();
        let grouped = dense.with_groups(4).unwrap();
        let a = OverlapGraph::build(&dense);
        let b = OverlapGraph::build(&grouped);
        assert_eq!(a.edge_count(), b.edge_count());
        for p in dense.all_patches() {
            assert_eq!(a.neighbors(p), b.neighbors(p));
        }
        check_against_layer(&grouped);
    }

    #[test]
    fn degree_is_bounded_and_symmetric() {
        let l = ConvLayer::square(1, 8, 3, 1); // 6×6 patches, 3×3 stride-1
        let g = OverlapGraph::build(&l);
        // interior patch: full 5×5 neighborhood minus itself
        assert_eq!(g.max_degree(), 24);
        assert_eq!(OverlapGraph::degree_bound(&l), 24);
        // corner patch 0: 3×3 neighborhood minus itself
        assert_eq!(g.degree(0), 8);
        for a in l.all_patches() {
            for &(b, size) in g.neighbors(a) {
                assert_eq!(g.overlap(b, a), size as usize, "symmetry {a}-{b}");
            }
        }
    }

    /// Dilated stride-1 3×3: u = 1 so the *count* bound stays 24, but the
    /// neighborhood is spread over offsets that are multiples of d.
    #[test]
    fn dilated_degree_bound() {
        let l = ConvLayer::new(1, 13, 13, 3, 3, 1, 1, 1)
            .unwrap()
            .with_dilation(2, 2)
            .unwrap(); // 9×9 patches
        assert_eq!(OverlapGraph::degree_bound(&l), 24);
        let g = OverlapGraph::build(&l);
        assert_eq!(g.max_degree(), 24); // interior patches reach ±4 in steps of 2
        let center = l.patch_id(4, 4);
        // offset 1 falls in a hole; offset 2 overlaps 2×3 taps
        assert_eq!(g.overlap(center, l.patch_id(4, 5)), 0);
        assert_eq!(g.overlap(center, l.patch_id(4, 6)), 6);
        // offset 4 overlaps 1×3 taps; offset 6 is beyond reach
        assert_eq!(g.overlap(center, l.patch_id(4, 8)), 3);
        assert_eq!(g.overlap(center, l.patch_id(4, 2)), 6);
    }

    #[test]
    fn edge_sizes_decay_with_distance() {
        let l = ConvLayer::square(1, 10, 3, 1); // 8×8 patches
        let g = OverlapGraph::build(&l);
        let center = l.patch_id(4, 4);
        assert_eq!(g.overlap(center, l.patch_id(4, 5)), 6); // 3×2
        assert_eq!(g.overlap(center, l.patch_id(5, 5)), 4); // 2×2
        assert_eq!(g.overlap(center, l.patch_id(4, 6)), 3); // 3×1
        assert_eq!(g.overlap(center, l.patch_id(6, 6)), 1); // 1×1
        assert_eq!(g.overlap(center, l.patch_id(4, 7)), 0); // beyond reach
    }
}

//! Fast objective evaluation for groupings.
//!
//! Under S1 with per-step write-back, the Eq. 15 objective reduces to
//! `δ = t_l · C_in · Σ_k |pix(g_k) ∖ pix(g_{k−1})| + n · t_acc` (+ the
//! uncharged write terms). Because `|A ∖ B| = |A| − |A ∩ B|` and `∩` is
//! symmetric, the total is `Σ_k |pix(g_k)| − Σ_k overlap(g_{k−1}, g_k)`:
//! node weights (group footprints) plus a path over symmetric edge weights
//! (consecutive-group overlaps). The search engines exploit exactly this
//! decomposition for O(1)-ish move deltas.

use crate::conv::{ConvLayer, PatchId};
use crate::platform::Accelerator;
use crate::tensor::PixelSet;

/// Cached evaluation state for a grouping.
#[derive(Debug, Clone)]
pub struct GroupingEval {
    /// Per-group spatial footprints.
    pub footprints: Vec<PixelSet>,
    /// Per-group footprint sizes (spatial pixels).
    pub sizes: Vec<usize>,
    /// `overlaps[k] = |pix(g_{k-1}) ∩ pix(g_k)|` (index 0 unused = 0).
    pub overlaps: Vec<usize>,
    /// Running `Σ sizes − Σ overlaps`, maintained incrementally so the
    /// annealer's objective read is O(1) (§Perf, EXPERIMENTS.md).
    total: i64,
}

impl GroupingEval {
    pub fn new(layer: &ConvLayer, groups: &[Vec<PatchId>]) -> Self {
        let footprints: Vec<PixelSet> =
            groups.iter().map(|g| layer.group_pixels(g)).collect();
        let sizes: Vec<usize> = footprints.iter().map(PixelSet::len).collect();
        let mut overlaps = vec![0usize; groups.len()];
        for k in 1..groups.len() {
            overlaps[k] = footprints[k - 1].intersection_len(&footprints[k]);
        }
        let total = sizes.iter().sum::<usize>() as i64
            - overlaps.iter().sum::<usize>() as i64;
        GroupingEval { footprints, sizes, overlaps, total }
    }

    /// Total spatial pixels loaded: `Σ sizes − Σ overlaps` (O(1)).
    pub fn loaded_pixels(&self) -> usize {
        debug_assert_eq!(
            self.total,
            self.sizes.iter().sum::<usize>() as i64
                - self.overlaps.iter().sum::<usize>() as i64
        );
        self.total as usize
    }

    /// Recompute group `k`'s footprint after its contents changed, fixing
    /// the adjacent overlap entries and the running total. Reuses the
    /// footprint buffer (allocation-free; annealer hot path).
    pub fn refresh_group(&mut self, layer: &ConvLayer, groups: &[Vec<PatchId>], k: usize) {
        layer.group_pixels_into(&mut self.footprints[k], &groups[k]);
        self.total -= self.sizes[k] as i64;
        self.sizes[k] = self.footprints[k].len();
        self.total += self.sizes[k] as i64;
        if k > 0 {
            self.total += self.overlaps[k] as i64;
            self.overlaps[k] =
                self.footprints[k - 1].intersection_len(&self.footprints[k]);
            self.total -= self.overlaps[k] as i64;
        }
        if k + 1 < self.footprints.len() {
            self.total += self.overlaps[k + 1] as i64;
            self.overlaps[k + 1] =
                self.footprints[k].intersection_len(&self.footprints[k + 1]);
            self.total -= self.overlaps[k + 1] as i64;
        }
    }
}

/// Total input pixels (spatial) loaded by the grouping.
pub fn grouping_loads(layer: &ConvLayer, groups: &[Vec<PatchId>]) -> u64 {
    GroupingEval::new(layer, groups).loaded_pixels() as u64
}

/// Duration in cycles under the paper's evaluation cost model
/// (Definition 3 with kernels preloaded and write-backs charged `t_w`):
/// `δ = t_l·C_in·Σ|I_k| + t_w·(written elements) + n·t_acc`.
pub fn grouping_duration(
    layer: &ConvLayer,
    acc: &Accelerator,
    groups: &[Vec<PatchId>],
) -> u64 {
    let loads = grouping_loads(layer, groups) * layer.c_in as u64;
    let writes = (layer.n_patches() * layer.c_out()) as u64;
    let n = groups.iter().filter(|g| !g.is_empty()).count() as u64;
    loads * acc.t_l + writes * acc.t_w + n * acc.t_acc
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::platform::Platform;
    use crate::sim::Simulator;
    use crate::strategy;

    /// The fast objective must agree with the full simulator on the
    /// input-load total and (modulo kernel-load cost, which Eq. 15 excludes)
    /// on the duration.
    #[test]
    fn objective_matches_simulator() {
        for h_in in [5usize, 7, 9] {
            for g in [1usize, 2, 4] {
                let l = ConvLayer::square(1, h_in, 3, 1);
                let acc = Accelerator::for_group_size(&l, g);
                let sim = Simulator::new(l, Platform::new(acc));
                for s in [strategy::row_by_row(&l, g), strategy::zigzag(&l, g)] {
                    let report = sim.run(&s).unwrap();
                    let fast_loads = grouping_loads(&l, &s.groups) * l.c_in as u64;
                    // simulator loads include the kernel load at step 1
                    let kernel_elements = l.kernel_elements() as u64;
                    assert_eq!(
                        report.total_loaded(),
                        fast_loads + kernel_elements,
                        "{} h{h_in} g{g}",
                        s.name
                    );
                    // §7.1 metric: t_l = t_acc = 1, t_w = 0 ⇒
                    // δ_paper = Σ|I| + n. The simulator additionally charges
                    // the kernel load; subtract it for the comparison.
                    let fast = grouping_duration(&l, &acc, &s.groups);
                    assert_eq!(report.duration - kernel_elements, fast);
                }
            }
        }
    }

    #[test]
    fn multichannel_loads_scale_by_c_in() {
        let l = ConvLayer::new(2, 5, 5, 3, 3, 2, 1, 1).unwrap();
        let acc = Accelerator::for_group_size(&l, 2);
        let s = strategy::row_by_row(&l, 2);
        let px = grouping_loads(&l, &s.groups);
        let dur = grouping_duration(&l, &acc, &s.groups);
        let n = s.groups.len() as u64;
        assert_eq!(dur, px * 2 * acc.t_l + n * acc.t_acc); // t_w = 0
    }

    #[test]
    fn refresh_group_is_consistent() {
        let l = ConvLayer::square(1, 6, 3, 1);
        let s = strategy::row_by_row(&l, 2);
        let mut groups = s.groups.clone();
        let mut eval = GroupingEval::new(&l, &groups);
        // move a patch between groups 0 and 3
        let p = groups[0].pop().unwrap();
        groups[3].push(p);
        eval.refresh_group(&l, &groups, 0);
        eval.refresh_group(&l, &groups, 3);
        let fresh = GroupingEval::new(&l, &groups);
        assert_eq!(eval.sizes, fresh.sizes);
        assert_eq!(eval.overlaps, fresh.overlaps);
        assert_eq!(eval.loaded_pixels(), fresh.loaded_pixels());
    }
}

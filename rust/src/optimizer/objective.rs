//! Fast objective evaluation for groupings.
//!
//! Under S1 with per-step write-back, the Eq. 15 objective reduces to
//! `δ = t_l · C_in · Σ_k |pix(g_k) ∖ pix(g_{k−1})| + n · t_acc` (+ the
//! uncharged write terms). Because `|A ∖ B| = |A| − |A ∩ B|` and `∩` is
//! symmetric, the total is `Σ_k |pix(g_k)| − Σ_k overlap(g_{k−1}, g_k)`:
//! node weights (group footprints) plus a path over symmetric edge weights
//! (consecutive-group overlaps). The search engines exploit exactly this
//! decomposition for O(1)/O(Δ) move deltas.
//!
//! # The delta-evaluation contract
//!
//! [`GroupingEval`] separates two identities that the old implementation
//! conflated:
//!
//! * a **slot** owns a group's *contents* (its footprint and size) — slots
//!   never move;
//! * a **position** is a place in the visit *order* — the permutation
//!   `order: position → slot` is the only thing order moves touch.
//!
//! Footprints depend on contents only, never on order, so the two
//! order-permuting moves (`swap adjacent`, `segment reverse`) recompute
//! **zero** footprints — only the 2–4 boundary overlap entries move, served
//! by a lazy `(slot, slot)` pairwise-overlap cache that is invalidated per
//! slot by generation counters when contents change. Content moves
//! (relocate, patch swap) score against candidate footprints built in two
//! reusable scratch buffers, and the scratch buffers are *swapped into*
//! the evaluator on commit, so an accepted move never rebuilds what scoring
//! already built and a rejected move costs nothing beyond its score.
//!
//! Every `score_*` method returns the **exact** integer objective delta the
//! move would cause and stages the recomputed entries in `pending`;
//! [`GroupingEval::commit`] applies them without recomputation. Scoring a
//! new move discards the previous pending state, so reject = do nothing.

use crate::conv::{ConvLayer, PatchId};
use crate::platform::Accelerator;
use crate::tensor::PixelSet;

/// Pairwise-overlap cache entry: the overlap value together with the content
/// generations of both slots at compute time (0 = never written).
#[derive(Debug, Clone, Copy)]
struct PairEntry {
    gen_lo: u32,
    gen_hi: u32,
    val: u32,
}

const PAIR_EMPTY: PairEntry = PairEntry { gen_lo: 0, gen_hi: 0, val: 0 };

/// Above this many `k × k` entries the pairwise cache is disabled (overlaps
/// are recomputed on demand); keeps worst-case memory bounded for huge
/// layers while every paper-scale instance (k ≤ 1024) stays cached.
const PAIR_CACHE_MAX_ENTRIES: usize = 1 << 20;

/// A staged (scored but not yet applied) move.
#[derive(Debug, Clone)]
enum Pending {
    /// Nothing staged.
    None,
    /// Content edit of the groups at two positions; candidate footprints
    /// live in the scratch buffers.
    Edit2 {
        pos_a: usize,
        pos_b: usize,
        new_size_a: usize,
        new_size_b: usize,
        /// `(edge position, new overlap value)`, `edges[..n_edges]` valid.
        edges: [(usize, usize); 4],
        n_edges: usize,
        delta: i64,
    },
    /// Swap of adjacent positions `i`, `i+1` in the order.
    SwapAdjacent {
        i: usize,
        edges: [(usize, usize); 2],
        n_edges: usize,
        delta: i64,
    },
    /// Reverse of the position segment `[a ..= b]`.
    Reverse {
        a: usize,
        b: usize,
        edges: [(usize, usize); 2],
        n_edges: usize,
        delta: i64,
    },
}

/// A scored-but-uncommitted move's observable consequences, in visit-order
/// terms — what a duration-domain objective layered on top of the pixel
/// objective (see [`crate::optimizer::makespan::MakespanEval`]) needs to
/// restage the same move on its own per-position state.
#[derive(Debug, Clone, Copy)]
pub enum StagedEffect {
    /// Content edit at two positions: their new footprint sizes plus the
    /// recomputed boundary-overlap entries (`edges[..n_edges]` valid,
    /// `(edge position, new overlap)`).
    Edit2 {
        /// First edited position and its candidate footprint size.
        pos_a: usize,
        /// Second edited position and its candidate footprint size.
        pos_b: usize,
        /// Candidate footprint size of the group at `pos_a`.
        new_size_a: usize,
        /// Candidate footprint size of the group at `pos_b`.
        new_size_b: usize,
        /// Recomputed boundary-overlap entries.
        edges: [(usize, usize); 4],
        /// How many entries of `edges` are valid.
        n_edges: usize,
    },
    /// Swap of adjacent positions `i`, `i + 1` (sizes permute, contents
    /// don't change).
    SwapAdjacent {
        /// Left position of the swapped pair.
        i: usize,
        /// Recomputed outer boundary overlaps.
        edges: [(usize, usize); 2],
        /// How many entries of `edges` are valid.
        n_edges: usize,
    },
    /// Reverse of the position segment `[a ..= b]` (interior overlaps
    /// reverse in place, the ≤ 2 boundary overlaps are recomputed).
    Reverse {
        /// Segment start position.
        a: usize,
        /// Segment end position (inclusive).
        b: usize,
        /// Recomputed boundary overlaps.
        edges: [(usize, usize); 2],
        /// How many entries of `edges` are valid.
        n_edges: usize,
    },
}

/// An edit of one group's contents, described against its current patch
/// list: optionally drop the element at `skip`, optionally append `add`.
/// Relocate = (drop) on the source + (append) on the target; patch swap =
/// (drop + append) on both. Expressing edits this way lets the evaluator
/// build candidate footprints without the caller allocating edited lists.
#[derive(Debug, Clone, Copy)]
pub struct GroupEdit<'a> {
    /// The group's *current* patches.
    pub patches: &'a [PatchId],
    /// Index into `patches` to leave out, if any.
    pub skip: Option<usize>,
    /// Patch to add, if any.
    pub add: Option<PatchId>,
}

/// Cached evaluation state for a grouping (see the module docs for the
/// slot/position split and the delta-evaluation contract).
#[derive(Debug, Clone)]
pub struct GroupingEval {
    /// Per-slot spatial footprints (content identity; order-invariant).
    footprints: Vec<PixelSet>,
    /// Per-slot footprint sizes in spatial pixels.
    sizes: Vec<usize>,
    /// Permutation: `order[position] = slot`.
    order: Vec<u32>,
    /// Inverse permutation: `pos_of[slot] = position`.
    pos_of: Vec<u32>,
    /// Position-indexed boundary overlaps:
    /// `overlaps[p] = |pix(order[p-1]) ∩ pix(order[p])|`; index 0 unused.
    overlaps: Vec<usize>,
    /// Running `Σ sizes − Σ overlaps`, maintained incrementally so the
    /// annealer's objective read is O(1) (§Perf, EXPERIMENTS.md).
    total: i64,
    /// Per-slot content generation (starts at 1; bumped on every content
    /// change) — validates pairwise cache entries.
    gen: Vec<u32>,
    /// Flat `k × k` pairwise-overlap cache (empty when disabled).
    pair_cache: Vec<PairEntry>,
    /// Scratch footprints for scoring content edits; swapped into
    /// `footprints` on commit.
    scratch_a: PixelSet,
    scratch_b: PixelSet,
    pending: Pending,
}

impl GroupingEval {
    /// Build the evaluator for `groups` (in visit order) on `layer`.
    pub fn new(layer: &ConvLayer, groups: &[Vec<PatchId>]) -> Self {
        let k = groups.len();
        let footprints: Vec<PixelSet> =
            groups.iter().map(|g| layer.group_pixels(g)).collect();
        let sizes: Vec<usize> = footprints.iter().map(PixelSet::len).collect();
        let mut overlaps = vec![0usize; k];
        for p in 1..k {
            overlaps[p] = footprints[p - 1].intersection_len(&footprints[p]);
        }
        let total = sizes.iter().sum::<usize>() as i64
            - overlaps.iter().sum::<usize>() as i64;
        let pair_cache = if k * k <= PAIR_CACHE_MAX_ENTRIES {
            vec![PAIR_EMPTY; k * k]
        } else {
            Vec::new()
        };
        let mut eval = GroupingEval {
            footprints,
            sizes,
            order: (0..k as u32).collect(),
            pos_of: (0..k as u32).collect(),
            overlaps,
            total,
            gen: vec![1; k],
            pair_cache,
            scratch_a: PixelSet::empty(layer.n_pixels()),
            scratch_b: PixelSet::empty(layer.n_pixels()),
            pending: Pending::None,
        };
        // Seed the pairwise cache with the consecutive overlaps just
        // computed (order = identity, so slot pair = position pair).
        for p in 1..k {
            let ov = eval.overlaps[p];
            eval.pair_store(p - 1, p, ov);
        }
        eval
    }

    /// Number of groups.
    pub fn n_groups(&self) -> usize {
        self.order.len()
    }

    /// Total spatial pixels loaded: `Σ sizes − Σ overlaps` (O(1)).
    pub fn loaded_pixels(&self) -> usize {
        debug_assert_eq!(
            self.total,
            self.sizes.iter().sum::<usize>() as i64
                - self.overlaps.iter().sum::<usize>() as i64
        );
        self.total as usize
    }

    /// Slot occupying `position`.
    #[inline]
    pub fn slot_at(&self, position: usize) -> usize {
        self.order[position] as usize
    }

    /// Position currently holding `slot`.
    #[inline]
    pub fn position_of(&self, slot: usize) -> usize {
        self.pos_of[slot] as usize
    }

    /// The permutation `position → slot`.
    pub fn order(&self) -> &[u32] {
        &self.order
    }

    /// Footprint of the group at `position`.
    pub fn footprint_at(&self, position: usize) -> &PixelSet {
        &self.footprints[self.order[position] as usize]
    }

    /// Footprint sizes in visit order (test/report convenience).
    pub fn sizes_in_order(&self) -> Vec<usize> {
        self.order.iter().map(|&s| self.sizes[s as usize]).collect()
    }

    /// Boundary overlaps in visit order (`[0]` unused = 0).
    pub fn overlaps_in_order(&self) -> &[usize] {
        &self.overlaps
    }

    // ------------------------------------------------------ pairwise cache

    #[inline]
    fn pair_idx(&self, slot_a: usize, slot_b: usize) -> usize {
        let (lo, hi) = if slot_a <= slot_b { (slot_a, slot_b) } else { (slot_b, slot_a) };
        lo * self.order.len() + hi
    }

    fn pair_store(&mut self, slot_a: usize, slot_b: usize, val: usize) {
        if self.pair_cache.is_empty() {
            return;
        }
        let idx = self.pair_idx(slot_a, slot_b);
        let (lo, hi) = if slot_a <= slot_b { (slot_a, slot_b) } else { (slot_b, slot_a) };
        self.pair_cache[idx] = PairEntry {
            gen_lo: self.gen[lo],
            gen_hi: self.gen[hi],
            val: val as u32,
        };
    }

    /// `|pix(slot_a) ∩ pix(slot_b)|`, cached until either slot's contents
    /// change. This is what makes the order-permuting moves footprint-free.
    fn pair_overlap(&mut self, slot_a: usize, slot_b: usize) -> usize {
        if !self.pair_cache.is_empty() {
            let idx = self.pair_idx(slot_a, slot_b);
            let (lo, hi) =
                if slot_a <= slot_b { (slot_a, slot_b) } else { (slot_b, slot_a) };
            let e = self.pair_cache[idx];
            if e.gen_lo == self.gen[lo] && e.gen_hi == self.gen[hi] {
                return e.val as usize;
            }
        }
        let val = self.footprints[slot_a].intersection_len(&self.footprints[slot_b]);
        self.pair_store(slot_a, slot_b, val);
        val
    }

    // ------------------------------------------------------- move scoring

    /// Footprint a position would have under the staged edit.
    #[inline]
    fn staged_footprint(&self, position: usize, pos_a: usize, pos_b: usize) -> &PixelSet {
        if position == pos_a {
            &self.scratch_a
        } else if position == pos_b {
            &self.scratch_b
        } else {
            &self.footprints[self.order[position] as usize]
        }
    }

    /// Score a simultaneous content edit of the groups at two distinct
    /// positions: the exact objective delta, computed **without mutating**
    /// any state the next reader observes. Commit with
    /// [`GroupingEval::commit`]; to reject, simply don't.
    pub fn score_edit2(
        &mut self,
        layer: &ConvLayer,
        pos_a: usize,
        edit_a: GroupEdit<'_>,
        pos_b: usize,
        edit_b: GroupEdit<'_>,
    ) -> i64 {
        debug_assert_ne!(pos_a, pos_b, "edit positions must differ");
        let k = self.order.len();
        let slot_a = self.order[pos_a] as usize;
        let slot_b = self.order[pos_b] as usize;
        build_edited_footprint(
            layer,
            &mut self.scratch_a,
            &self.footprints[slot_a],
            &edit_a,
        );
        build_edited_footprint(
            layer,
            &mut self.scratch_b,
            &self.footprints[slot_b],
            &edit_b,
        );
        let new_size_a = self.scratch_a.len();
        let new_size_b = self.scratch_b.len();
        let dsize = new_size_a as i64 - self.sizes[slot_a] as i64
            + new_size_b as i64
            - self.sizes[slot_b] as i64;

        // Boundary edges incident to either touched position. Edge `e`
        // connects positions `e-1` and `e` (valid for 1 ≤ e < k).
        let mut cand = [pos_a, pos_a + 1, pos_b, pos_b + 1];
        cand.sort_unstable();
        let mut edges = [(0usize, 0usize); 4];
        let mut n_edges = 0usize;
        let mut dov = 0i64;
        for (i, &e) in cand.iter().enumerate() {
            if e == 0 || e >= k || (i > 0 && cand[i - 1] == e) {
                continue;
            }
            let new_ov = self
                .staged_footprint(e - 1, pos_a, pos_b)
                .intersection_len(self.staged_footprint(e, pos_a, pos_b));
            dov += new_ov as i64 - self.overlaps[e] as i64;
            edges[n_edges] = (e, new_ov);
            n_edges += 1;
        }
        let delta = dsize - dov;
        self.pending = Pending::Edit2 {
            pos_a,
            pos_b,
            new_size_a,
            new_size_b,
            edges,
            n_edges,
            delta,
        };
        delta
    }

    /// Score swapping the groups at positions `i` and `i+1`. Footprint-free:
    /// the middle edge is unchanged (overlap is symmetric) and the ≤ 2 outer
    /// edges come from the pairwise cache.
    pub fn score_swap_adjacent(&mut self, i: usize) -> i64 {
        let k = self.order.len();
        debug_assert!(i + 1 < k);
        let slot_l = self.order[i] as usize;
        let slot_r = self.order[i + 1] as usize;
        let mut edges = [(0usize, 0usize); 2];
        let mut n_edges = 0usize;
        let mut dov = 0i64;
        if i >= 1 {
            let outer = self.order[i - 1] as usize;
            let old_ov = self.overlaps[i];
            // The current edge value is a known (outer, slot_l) overlap —
            // seed the cache so the reverse move can reuse it.
            self.pair_store(outer, slot_l, old_ov);
            let new_ov = self.pair_overlap(outer, slot_r);
            dov += new_ov as i64 - old_ov as i64;
            edges[n_edges] = (i, new_ov);
            n_edges += 1;
        }
        if i + 2 < k {
            let outer = self.order[i + 2] as usize;
            let old_ov = self.overlaps[i + 2];
            self.pair_store(slot_r, outer, old_ov);
            let new_ov = self.pair_overlap(slot_l, outer);
            dov += new_ov as i64 - old_ov as i64;
            edges[n_edges] = (i + 2, new_ov);
            n_edges += 1;
        }
        let delta = -dov; // sizes are untouched by order moves
        self.pending = Pending::SwapAdjacent { i, edges, n_edges, delta };
        delta
    }

    /// Score reversing the position segment `[a ..= b]` (2-opt). Footprint-
    /// free: interior edges are the same unordered pairs in reverse order,
    /// so only the ≤ 2 boundary edges are recomputed (cached).
    pub fn score_reverse(&mut self, a: usize, b: usize) -> i64 {
        let k = self.order.len();
        debug_assert!(a < b && b < k);
        let mut edges = [(0usize, 0usize); 2];
        let mut n_edges = 0usize;
        let mut dov = 0i64;
        if a >= 1 {
            let outer = self.order[a - 1] as usize;
            let (slot_front, slot_back) =
                (self.order[a] as usize, self.order[b] as usize);
            let old_ov = self.overlaps[a];
            self.pair_store(outer, slot_front, old_ov);
            let new_ov = self.pair_overlap(outer, slot_back);
            dov += new_ov as i64 - old_ov as i64;
            edges[n_edges] = (a, new_ov);
            n_edges += 1;
        }
        if b + 1 < k {
            let outer = self.order[b + 1] as usize;
            let (slot_front, slot_back) =
                (self.order[a] as usize, self.order[b] as usize);
            let old_ov = self.overlaps[b + 1];
            self.pair_store(slot_back, outer, old_ov);
            let new_ov = self.pair_overlap(slot_front, outer);
            dov += new_ov as i64 - old_ov as i64;
            edges[n_edges] = (b + 1, new_ov);
            n_edges += 1;
        }
        let delta = -dov;
        self.pending = Pending::Reverse { a, b, edges, n_edges, delta };
        delta
    }

    /// The currently staged move in visit-order terms (`None` when nothing
    /// is staged). Non-destructive: the move stays staged for
    /// [`GroupingEval::commit`]. Used by the duration-domain objective to
    /// restage the identical change on its per-position timeline state.
    pub fn pending_effect(&self) -> Option<StagedEffect> {
        match self.pending {
            Pending::None => None,
            Pending::Edit2 {
                pos_a,
                pos_b,
                new_size_a,
                new_size_b,
                edges,
                n_edges,
                ..
            } => Some(StagedEffect::Edit2 {
                pos_a,
                pos_b,
                new_size_a,
                new_size_b,
                edges,
                n_edges,
            }),
            Pending::SwapAdjacent { i, edges, n_edges, .. } => {
                Some(StagedEffect::SwapAdjacent { i, edges, n_edges })
            }
            Pending::Reverse { a, b, edges, n_edges, .. } => {
                Some(StagedEffect::Reverse { a, b, edges, n_edges })
            }
        }
    }

    /// Apply the staged move. The caller must mirror the same change on its
    /// own group storage (see `search::State::commit`). Panics when nothing
    /// is staged.
    pub fn commit(&mut self) {
        match std::mem::replace(&mut self.pending, Pending::None) {
            Pending::None => panic!("GroupingEval::commit without a scored move"),
            Pending::Edit2 {
                pos_a,
                pos_b,
                new_size_a,
                new_size_b,
                edges,
                n_edges,
                delta,
            } => {
                let slot_a = self.order[pos_a] as usize;
                let slot_b = self.order[pos_b] as usize;
                // The candidate footprints become current; the old ones
                // become scratch for the next score.
                std::mem::swap(&mut self.scratch_a, &mut self.footprints[slot_a]);
                std::mem::swap(&mut self.scratch_b, &mut self.footprints[slot_b]);
                self.sizes[slot_a] = new_size_a;
                self.sizes[slot_b] = new_size_b;
                self.gen[slot_a] = self.gen[slot_a].wrapping_add(1);
                self.gen[slot_b] = self.gen[slot_b].wrapping_add(1);
                for &(e, ov) in &edges[..n_edges] {
                    self.overlaps[e] = ov;
                }
                self.total += delta;
            }
            Pending::SwapAdjacent { i, edges, n_edges, delta } => {
                self.order.swap(i, i + 1);
                self.pos_of[self.order[i] as usize] = i as u32;
                self.pos_of[self.order[i + 1] as usize] = (i + 1) as u32;
                for &(e, ov) in &edges[..n_edges] {
                    self.overlaps[e] = ov;
                }
                self.total += delta;
            }
            Pending::Reverse { a, b, edges, n_edges, delta } => {
                self.order[a..=b].reverse();
                for p in a..=b {
                    self.pos_of[self.order[p] as usize] = p as u32;
                }
                // Interior edges are the same unordered pairs visited
                // backwards: new_overlaps[e] = old_overlaps[a + b + 1 − e].
                self.overlaps[a + 1..=b].reverse();
                for &(e, ov) in &edges[..n_edges] {
                    self.overlaps[e] = ov;
                }
                self.total += delta;
            }
        }
    }

    /// Recompute the footprint of the group at `position` after its contents
    /// changed externally, fixing the adjacent overlap entries and the
    /// running total. `groups` is the full grouping in **visit order** (the
    /// legacy protocol; the annealer uses `score_*` + `commit` instead).
    pub fn refresh_group(
        &mut self,
        layer: &ConvLayer,
        groups: &[Vec<PatchId>],
        position: usize,
    ) {
        self.pending = Pending::None; // anything staged is now stale
        let slot = self.order[position] as usize;
        layer.group_pixels_into(&mut self.footprints[slot], &groups[position]);
        self.gen[slot] = self.gen[slot].wrapping_add(1);
        self.total -= self.sizes[slot] as i64;
        self.sizes[slot] = self.footprints[slot].len();
        self.total += self.sizes[slot] as i64;
        if position > 0 {
            let prev = self.order[position - 1] as usize;
            self.total += self.overlaps[position] as i64;
            self.overlaps[position] =
                self.footprints[prev].intersection_len(&self.footprints[slot]);
            self.total -= self.overlaps[position] as i64;
        }
        if position + 1 < self.order.len() {
            let next = self.order[position + 1] as usize;
            self.total += self.overlaps[position + 1] as i64;
            self.overlaps[position + 1] =
                self.footprints[slot].intersection_len(&self.footprints[next]);
            self.total -= self.overlaps[position + 1] as i64;
        }
    }
}

/// Build the footprint a group would have under `edit`, into `out`.
/// Pure additions copy the current footprint and extend it (word copy +
/// one patch); removals rebuild from the edited patch list.
fn build_edited_footprint(
    layer: &ConvLayer,
    out: &mut PixelSet,
    current: &PixelSet,
    edit: &GroupEdit<'_>,
) {
    match edit.skip {
        None => out.copy_from(current),
        Some(skip) => {
            out.clear();
            for (i, &p) in edit.patches.iter().enumerate() {
                if i != skip {
                    layer.add_patch_pixels(out, p);
                }
            }
        }
    }
    if let Some(p) = edit.add {
        layer.add_patch_pixels(out, p);
    }
}

/// Total input pixels (spatial) loaded by the grouping.
pub fn grouping_loads(layer: &ConvLayer, groups: &[Vec<PatchId>]) -> u64 {
    GroupingEval::new(layer, groups).loaded_pixels() as u64
}

/// Duration in cycles under the paper's evaluation cost model
/// (Definition 3 with kernels preloaded and write-backs charged `t_w`):
/// `δ = t_l·C_in·Σ|I_k| + t_w·(written elements) + n·t_acc`.
pub fn grouping_duration(
    layer: &ConvLayer,
    acc: &Accelerator,
    groups: &[Vec<PatchId>],
) -> u64 {
    let loads = grouping_loads(layer, groups) * layer.c_in as u64;
    let writes = (layer.n_patches() * layer.c_out()) as u64;
    let n = groups.iter().filter(|g| !g.is_empty()).count() as u64;
    loads * acc.t_l + writes * acc.t_w + n * acc.t_acc
}

/// Duration of the grouping under the **double-buffered** two-resource
/// timeline (`DESIGN.md` §3.7): per-step loads/writes/compute are derived
/// from the Definition-16 lowering (kernels load with step 1, write-backs
/// follow the every-step policy, terminal flush), each step's prefetch is
/// gated by the residency condition `occ_{i−1} + |I_i| ≤ size_MEM`, and the
/// result is the critical-path makespan — bit-equal to what
/// [`crate::sim::Simulator`] reports for the same strategy on a
/// [`crate::platform::OverlapMode::DoubleBuffered`] accelerator
/// (pinned by `objective_matches_simulator_double_buffered`).
///
/// Delegates to [`crate::optimizer::makespan::MakespanEval`] so the
/// Definition-16 lowering exists exactly once on the Rust side (the Python
/// oracle keeps its independent copy by design).
pub fn grouping_makespan(
    layer: &ConvLayer,
    acc: &Accelerator,
    groups: &[Vec<PatchId>],
) -> u64 {
    crate::optimizer::makespan::MakespanEval::new(layer, acc, groups).makespan()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::platform::Platform;
    use crate::sim::Simulator;
    use crate::strategy;

    /// The fast objective must agree with the full simulator on the
    /// input-load total and (modulo kernel-load cost, which Eq. 15 excludes)
    /// on the duration.
    #[test]
    fn objective_matches_simulator() {
        for h_in in [5usize, 7, 9] {
            for g in [1usize, 2, 4] {
                let l = ConvLayer::square(1, h_in, 3, 1);
                let acc = Accelerator::for_group_size(&l, g);
                let sim = Simulator::new(l, Platform::new(acc));
                for s in [strategy::row_by_row(&l, g), strategy::zigzag(&l, g)] {
                    let report = sim.run(&s).unwrap();
                    let fast_loads = grouping_loads(&l, &s.groups) * l.c_in as u64;
                    // simulator loads include the kernel load at step 1
                    let kernel_elements = l.kernel_elements() as u64;
                    assert_eq!(
                        report.total_loaded(),
                        fast_loads + kernel_elements,
                        "{} h{h_in} g{g}",
                        s.name
                    );
                    // §7.1 metric: t_l = t_acc = 1, t_w = 0 ⇒
                    // δ_paper = Σ|I| + n. The simulator additionally charges
                    // the kernel load; subtract it for the comparison.
                    let fast = grouping_duration(&l, &acc, &s.groups);
                    assert_eq!(report.duration - kernel_elements, fast);
                }
            }
        }
    }

    /// Regression for the `c_in`-dense assumption audit: under channel
    /// groups the kernel-load term shrinks (each kernel stores C_in/G
    /// channels) but the *input*-load term still scales by the full C_in —
    /// every group has kernels, so all channels of a footprint pixel load
    /// together. The fast objective and the simulator must agree on both.
    #[test]
    fn grouped_layer_objective_matches_simulator() {
        let l = ConvLayer::new(4, 7, 7, 3, 3, 4, 1, 1)
            .unwrap()
            .with_groups(4)
            .unwrap(); // depthwise
        let acc = Accelerator::for_group_size(&l, 2);
        let sim = Simulator::new(l, Platform::new(acc));
        for s in [strategy::row_by_row(&l, 2), strategy::zigzag(&l, 2)] {
            let report = sim.run(&s).unwrap();
            let fast_loads = grouping_loads(&l, &s.groups) * l.c_in as u64;
            // depthwise kernels: 4 × 1×3×3 = 36 elements, not 4 × 4×3×3
            let kernel_elements = l.kernel_elements() as u64;
            assert_eq!(kernel_elements, 36);
            assert_eq!(report.total_loaded(), fast_loads + kernel_elements, "{}", s.name);
            let fast = grouping_duration(&l, &acc, &s.groups);
            assert_eq!(report.duration - kernel_elements, fast, "{}", s.name);
        }
    }

    /// Same contract on a dilated layer: footprints are hole-y lattices but
    /// the objective/simulator agreement is unchanged.
    #[test]
    fn dilated_layer_objective_matches_simulator() {
        let l = ConvLayer::new(2, 9, 9, 3, 3, 2, 1, 1)
            .unwrap()
            .with_dilation(2, 2)
            .unwrap();
        let acc = Accelerator::for_group_size(&l, 3);
        let sim = Simulator::new(l, Platform::new(acc));
        for s in [strategy::row_by_row(&l, 3), strategy::hilbert(&l, 3)] {
            let report = sim.run(&s).unwrap();
            let fast_loads = grouping_loads(&l, &s.groups) * l.c_in as u64;
            let kernel_elements = l.kernel_elements() as u64;
            assert_eq!(report.total_loaded(), fast_loads + kernel_elements, "{}", s.name);
            let fast = grouping_duration(&l, &acc, &s.groups);
            assert_eq!(report.duration - kernel_elements, fast, "{}", s.name);
        }
    }

    /// The analytic makespan must agree **bit-for-bit** with the simulator
    /// running the same strategy on a double-buffered accelerator — across
    /// dense, strided, dilated and grouped layers and several memory sizes
    /// (so both the prefetch and the serialization-fallback branches are
    /// exercised).
    #[test]
    fn objective_matches_simulator_double_buffered() {
        use crate::platform::OverlapMode;
        let layers = [
            (ConvLayer::new(2, 5, 5, 3, 3, 2, 1, 1).unwrap(), 2usize),
            (ConvLayer::new(1, 8, 8, 3, 3, 1, 1, 1).unwrap(), 4),
            (
                ConvLayer::new(2, 9, 9, 3, 3, 2, 1, 1)
                    .unwrap()
                    .with_dilation(2, 2)
                    .unwrap(),
                3,
            ),
            (
                ConvLayer::new(4, 7, 7, 3, 3, 4, 1, 1)
                    .unwrap()
                    .with_groups(4)
                    .unwrap(),
                2,
            ),
        ];
        for (l, g) in layers {
            let base = Accelerator { t_w: 1, t_acc: 3, ..Accelerator::for_group_size(&l, g) };
            for extra_mem in [0u64, 64, 100_000] {
                let acc = Accelerator {
                    size_mem: base.size_mem + extra_mem,
                    ..base
                }
                .with_overlap(OverlapMode::DoubleBuffered);
                let sim = Simulator::new(l, Platform::new(acc));
                for s in [strategy::row_by_row(&l, g), strategy::zigzag(&l, g)] {
                    let report = sim.run(&s).unwrap();
                    assert_eq!(
                        report.duration,
                        grouping_makespan(&l, &acc, &s.groups),
                        "{} {l} mem+{extra_mem}",
                        s.name
                    );
                }
            }
        }
    }

    /// Bounds of the makespan: never above the sequential Definition-3
    /// duration (plus the kernel-load term Eq. 15 excludes), never below
    /// either resource's busy total.
    #[test]
    fn makespan_bounds_vs_sequential_objective() {
        let l = ConvLayer::new(1, 10, 10, 3, 3, 1, 1, 1).unwrap();
        let acc = Accelerator { t_acc: 5, t_w: 1, ..Accelerator::for_group_size(&l, 4) };
        for s in [strategy::row_by_row(&l, 4), strategy::hilbert(&l, 4)] {
            let sequential = grouping_duration(&l, &acc, &s.groups)
                + l.kernel_elements() as u64 * acc.t_l;
            let makespan = grouping_makespan(&l, &acc, &s.groups);
            assert!(makespan <= sequential, "{}", s.name);
        }
    }

    #[test]
    fn multichannel_loads_scale_by_c_in() {
        let l = ConvLayer::new(2, 5, 5, 3, 3, 2, 1, 1).unwrap();
        let acc = Accelerator::for_group_size(&l, 2);
        let s = strategy::row_by_row(&l, 2);
        let px = grouping_loads(&l, &s.groups);
        let dur = grouping_duration(&l, &acc, &s.groups);
        let n = s.groups.len() as u64;
        assert_eq!(dur, px * 2 * acc.t_l + n * acc.t_acc); // t_w = 0
    }

    /// Materialize the eval's current grouping in visit order from a
    /// slot-indexed group list (what `search::State` stores).
    fn in_order(eval: &GroupingEval, slots: &[Vec<PatchId>]) -> Vec<Vec<PatchId>> {
        eval.order().iter().map(|&s| slots[s as usize].clone()).collect()
    }

    /// Incremental state must match a from-scratch rebuild.
    fn assert_matches_fresh(layer: &ConvLayer, eval: &GroupingEval, slots: &[Vec<PatchId>]) {
        let groups = in_order(eval, slots);
        let fresh = GroupingEval::new(layer, &groups);
        assert_eq!(eval.sizes_in_order(), fresh.sizes_in_order());
        assert_eq!(eval.overlaps_in_order(), fresh.overlaps_in_order());
        assert_eq!(eval.loaded_pixels(), fresh.loaded_pixels());
    }

    #[test]
    fn refresh_group_is_consistent() {
        let l = ConvLayer::square(1, 6, 3, 1);
        let s = strategy::row_by_row(&l, 2);
        let mut groups = s.groups.clone();
        let mut eval = GroupingEval::new(&l, &groups);
        // move a patch between groups 0 and 3
        let p = groups[0].pop().unwrap();
        groups[3].push(p);
        eval.refresh_group(&l, &groups, 0);
        eval.refresh_group(&l, &groups, 3);
        let fresh = GroupingEval::new(&l, &groups);
        assert_eq!(eval.sizes_in_order(), fresh.sizes_in_order());
        assert_eq!(eval.overlaps_in_order(), fresh.overlaps_in_order());
        assert_eq!(eval.loaded_pixels(), fresh.loaded_pixels());
    }

    /// score → commit must land exactly on the from-scratch state, and the
    /// returned delta must equal the observed objective change (relocate).
    #[test]
    fn score_edit2_relocate_matches_rebuild() {
        let l = ConvLayer::square(1, 6, 3, 1);
        let mut slots = strategy::row_by_row(&l, 2).groups;
        let mut eval = GroupingEval::new(&l, &slots);
        let before = eval.loaded_pixels() as i64;
        // relocate slots[0]'s last patch into slots[5]
        let p = *slots[0].last().unwrap();
        let skip = slots[0].len() - 1;
        let delta = eval.score_edit2(
            &l,
            0,
            GroupEdit { patches: &slots[0], skip: Some(skip), add: None },
            5,
            GroupEdit { patches: &slots[5], skip: None, add: Some(p) },
        );
        // nothing observable changed before commit
        assert_eq!(eval.loaded_pixels() as i64, before);
        eval.commit();
        slots[0].pop();
        slots[5].push(p);
        assert_eq!(eval.loaded_pixels() as i64, before + delta);
        assert_matches_fresh(&l, &eval, &slots);
    }

    /// Same contract for a patch swap between adjacent positions (the case
    /// where both staged footprints meet at one shared edge).
    #[test]
    fn score_edit2_swap_between_adjacent_positions() {
        let l = ConvLayer::square(1, 6, 3, 1);
        let mut slots = strategy::zigzag(&l, 2).groups;
        let mut eval = GroupingEval::new(&l, &slots);
        let before = eval.loaded_pixels() as i64;
        let (pa, pb) = (slots[2][0], slots[3][1]);
        let delta = eval.score_edit2(
            &l,
            2,
            GroupEdit { patches: &slots[2], skip: Some(0), add: Some(pb) },
            3,
            GroupEdit { patches: &slots[3], skip: Some(1), add: Some(pa) },
        );
        eval.commit();
        slots[2][0] = pb;
        slots[3][1] = pa;
        assert_eq!(eval.loaded_pixels() as i64, before + delta);
        assert_matches_fresh(&l, &eval, &slots);
    }

    /// Order moves must be footprint-free *and* exact: swap-adjacent and
    /// segment-reverse through the permutation layer land on the
    /// from-scratch state of the permuted grouping.
    #[test]
    fn order_moves_match_rebuild() {
        let l = ConvLayer::square(1, 7, 3, 1);
        let slots = strategy::row_by_row(&l, 3).groups;
        let k = slots.len();
        assert!(k >= 5, "need enough groups to exercise interior segments");
        let mut eval = GroupingEval::new(&l, &slots);

        let before = eval.loaded_pixels() as i64;
        let d1 = eval.score_swap_adjacent(0); // boundary at the front
        eval.commit();
        assert_eq!(eval.loaded_pixels() as i64, before + d1);
        assert_matches_fresh(&l, &eval, &slots);

        let before = eval.loaded_pixels() as i64;
        let d2 = eval.score_swap_adjacent(k - 2); // boundary at the back
        eval.commit();
        assert_eq!(eval.loaded_pixels() as i64, before + d2);
        assert_matches_fresh(&l, &eval, &slots);

        let before = eval.loaded_pixels() as i64;
        let d3 = eval.score_reverse(1, k - 2); // interior segment
        eval.commit();
        assert_eq!(eval.loaded_pixels() as i64, before + d3);
        assert_matches_fresh(&l, &eval, &slots);

        let before = eval.loaded_pixels() as i64;
        let d4 = eval.score_reverse(0, k - 1); // whole order
        eval.commit();
        assert_eq!(eval.loaded_pixels() as i64, before + d4);
        assert_matches_fresh(&l, &eval, &slots);
    }

    /// Scoring without committing is reject-for-free: repeated scored-and-
    /// dropped moves leave the evaluator bit-identical.
    #[test]
    fn uncommitted_scores_do_not_mutate() {
        let l = ConvLayer::square(1, 6, 3, 1);
        let slots = strategy::row_by_row(&l, 2).groups;
        let mut eval = GroupingEval::new(&l, &slots);
        let sizes0 = eval.sizes_in_order();
        let overlaps0 = eval.overlaps_in_order().to_vec();
        let total0 = eval.loaded_pixels();
        let p = slots[1][0];
        for _ in 0..10 {
            let _ = eval.score_edit2(
                &l,
                1,
                GroupEdit { patches: &slots[1], skip: Some(0), add: None },
                4,
                GroupEdit { patches: &slots[4], skip: None, add: Some(p) },
            );
            let _ = eval.score_swap_adjacent(2);
            let _ = eval.score_reverse(0, 3);
        }
        assert_eq!(eval.sizes_in_order(), sizes0);
        assert_eq!(eval.overlaps_in_order(), &overlaps0[..]);
        assert_eq!(eval.loaded_pixels(), total0);
        assert_matches_fresh(&l, &eval, &slots);
    }

    /// The pairwise cache must never serve stale values after a content
    /// change (generation invalidation).
    #[test]
    fn pair_cache_invalidates_on_content_change() {
        let l = ConvLayer::square(1, 6, 3, 1);
        let mut slots = strategy::row_by_row(&l, 2).groups;
        let mut eval = GroupingEval::new(&l, &slots);
        // Warm the cache on the (0, 2) pair via a reverse score.
        let _ = eval.score_reverse(0, 2);
        // Change slot 2's contents (relocate a patch from 2 into 5)…
        let p = slots[2][0];
        let d = eval.score_edit2(
            &l,
            2,
            GroupEdit { patches: &slots[2], skip: Some(0), add: None },
            5,
            GroupEdit { patches: &slots[5], skip: None, add: Some(p) },
        );
        eval.commit();
        slots[2].remove(0);
        slots[5].push(p);
        let _ = d;
        // …then a reverse touching slot 2 again must match from-scratch.
        let before = eval.loaded_pixels() as i64;
        let d2 = eval.score_reverse(0, 2);
        eval.commit();
        assert_eq!(eval.loaded_pixels() as i64, before + d2);
        assert_matches_fresh(&l, &eval, &slots);
    }
}

//! Simulated-annealing polish over groupings (the paper's “solution
//! polishing” phase — CPLEX switches to a genetic algorithm after 60 s; we
//! use deterministic annealing over the same solution space).
//!
//! State: an ordered partition of `X` into exactly `k` groups of size ≤ `g`.
//! Moves:
//! 1. **relocate** — move a patch to another group with slack;
//! 2. **swap** — exchange two patches between different groups;
//! 3. **adjacent-swap** — exchange whole groups `k` and `k+1` in the order;
//! 4. **segment-reverse** — reverse a run of groups (2-opt; footprints are
//!    unchanged, only the two boundary overlaps move, since overlap is
//!    symmetric).
//!
//! The objective is [`GroupingEval::loaded_pixels`] (Eq. 15 divided by
//! `t_l·C_in`, minus the constant `n·t_acc`).
//!
//! # Propose → score → commit
//!
//! Every move is **scored before any state changes**: the proposal draws
//! its random indices, [`GroupingEval`] computes the exact objective delta
//! from the ≤ 2 touched footprints (content moves) or the ≤ 2 boundary
//! overlap entries alone (order moves, which are footprint-free through the
//! evaluator's permutation layer), and only a Metropolis *accept* commits
//! anything. A rejected move — the vast majority at low temperature — costs
//! no footprint rebuild, no undo, nothing beyond its score. The RNG draw
//! sequence and the accepted trajectory are bit-identical to the historical
//! tentative-apply-then-undo implementation (deltas are exact integers), so
//! per-seed results are unchanged; see EXPERIMENTS.md §Perf.

use std::sync::atomic::{AtomicBool, Ordering};

use crate::conv::{ConvLayer, PatchId};
use crate::optimizer::makespan::MakespanEval;
use crate::optimizer::objective::{GroupEdit, GroupingEval};
use crate::optimizer::overlap::OverlapGraph;
use crate::platform::Accelerator;
use crate::util::rng::Rng;

/// How often the annealing loops poll their cancel flag: every
/// `CANCEL_CHECK_PERIOD` iterations (a power of two so the check is a mask).
/// The poll happens *before* any RNG draw of that iteration, so a run that is
/// never cancelled consumes exactly the same draw sequence as the plain
/// annealers — per-seed bit-identity is preserved by construction.
pub const CANCEL_CHECK_PERIOD: u64 = 1024;

#[inline]
fn cancelled_at(it: u64, cancel: Option<&AtomicBool>) -> bool {
    match cancel {
        Some(flag) => it & (CANCEL_CHECK_PERIOD - 1) == 0 && flag.load(Ordering::Relaxed),
        None => false,
    }
}

/// Knobs for [`anneal_with`]. The default reproduces [`anneal`] exactly.
#[derive(Debug, Clone)]
pub struct AnnealOptions {
    /// Probability of replacing a uniform proposal with one drawn from the
    /// sparse patch-overlap graph: relocate a patch into (or swap it with a
    /// member of) a spatial neighbor's group, where the objective is most
    /// sensitive. **Any value > 0 changes the RNG draw sequence** and
    /// therefore the per-seed trajectory; the planner keeps it at 0.0 so
    /// plans stay bit-identical per seed across releases. Opt in via
    /// `OptimizeOptions::neighbor_bias` (`optimize --neighbor-bias`).
    pub neighbor_bias: f64,
}

impl Default for AnnealOptions {
    fn default() -> Self {
        AnnealOptions { neighbor_bias: 0.0 }
    }
}

/// Anneal from `start` (the MIP start). Returns the best grouping found
/// (never worse than `start` re-chunked to `k` groups). Deterministic per
/// seed; bit-identical to the pre-delta-evaluation implementation.
pub fn anneal(
    layer: &ConvLayer,
    g: usize,
    k: usize,
    start: &[Vec<PatchId>],
    iters: u64,
    seed: u64,
) -> Vec<Vec<PatchId>> {
    anneal_with(layer, g, k, start, iters, seed, &AnnealOptions::default())
}

/// [`anneal`] with explicit [`AnnealOptions`].
pub fn anneal_with(
    layer: &ConvLayer,
    g: usize,
    k: usize,
    start: &[Vec<PatchId>],
    iters: u64,
    seed: u64,
    opts: &AnnealOptions,
) -> Vec<Vec<PatchId>> {
    anneal_with_cancel(layer, g, k, start, iters, seed, opts, None).0
}

/// Cooperatively-cancellable [`anneal`]: identical search, but a shared
/// `cancel` flag is polled every [`CANCEL_CHECK_PERIOD`] iterations and the
/// best-so-far grouping is returned as soon as it is observed set. Returns
/// `(best, iterations_run)`; an uncancelled run is bit-identical to
/// [`anneal`] and reports `iterations_run == iters`.
pub fn anneal_cancellable(
    layer: &ConvLayer,
    g: usize,
    k: usize,
    start: &[Vec<PatchId>],
    iters: u64,
    seed: u64,
    cancel: &AtomicBool,
) -> (Vec<Vec<PatchId>>, u64) {
    anneal_with_cancel(layer, g, k, start, iters, seed, &AnnealOptions::default(), Some(cancel))
}

#[allow(clippy::too_many_arguments)]
fn anneal_with_cancel(
    layer: &ConvLayer,
    g: usize,
    k: usize,
    start: &[Vec<PatchId>],
    iters: u64,
    seed: u64,
    opts: &AnnealOptions,
    cancel: Option<&AtomicBool>,
) -> (Vec<Vec<PatchId>>, u64) {
    let mut state = State::new(layer, normalize(start, g, k));
    let mut best = state.materialize();
    let mut best_cost = state.cost();

    // The graph only steers proposals when the bias is enabled; building it
    // lazily keeps the default (bit-identical) path allocation-identical.
    let graph =
        if opts.neighbor_bias > 0.0 { Some(OverlapGraph::build(layer)) } else { None };

    let mut rng = Rng::new(seed);
    // Temperature scale: a typical bad move costs O(one patch footprint).
    let t0 = (layer.h_k * layer.w_k) as f64;
    let t_end = 0.05;

    for it in 0..iters {
        if cancelled_at(it, cancel) {
            return (best, it);
        }
        let progress = it as f64 / iters.max(1) as f64;
        let temp = t0 * (t_end / t0).powf(progress);

        let proposal = match &graph {
            Some(graph) if rng.chance(opts.neighbor_bias) => {
                if rng.below(2) == 0 {
                    state.propose_neighbor_relocate(layer, &mut rng, graph, g)
                } else {
                    state.propose_neighbor_swap(layer, &mut rng, graph)
                }
            }
            _ => match rng.below(4) {
                0 => state.propose_relocate(layer, &mut rng, g),
                1 => state.propose_swap_patches(layer, &mut rng),
                2 => state.propose_swap_groups(&mut rng),
                _ => state.propose_reverse_segment(&mut rng),
            },
        };
        let Some((mv, delta)) = proposal else { continue };

        let keep = delta <= 0 || rng.chance((-(delta as f64) / temp).exp());
        if keep {
            state.commit(mv);
            if state.cost() < best_cost {
                best_cost = state.cost();
                best = state.materialize();
            }
        }
        // Rejected: nothing was mutated, nothing to undo.
    }
    (best, iters)
}

/// Anneal from `start` against the **duration-domain objective**: the §3.7
/// double-buffered makespan on `acc` instead of loaded pixels. Same solution
/// space, same four move kinds, deterministic per seed; every move is
/// scored exactly through the lock-stepped pair of incremental evaluators
/// ([`GroupingEval`] for the footprint math, [`MakespanEval`] for the
/// timeline suffix) before anything mutates — the §3.5 contract in the
/// duration domain. Never worse than the (normalized) start.
///
/// This is a *separate* annealer with its own RNG consumption pattern; the
/// pixel-objective [`anneal`] stream is untouched, so all sequential-mode
/// planner outputs remain bit-identical per seed.
pub fn anneal_duration(
    layer: &ConvLayer,
    acc: &Accelerator,
    g: usize,
    k: usize,
    start: &[Vec<PatchId>],
    iters: u64,
    seed: u64,
) -> Vec<Vec<PatchId>> {
    anneal_duration_cancel(layer, acc, g, k, start, iters, seed, None).0
}

/// Cooperatively-cancellable [`anneal_duration`]: same search, polling a
/// shared `cancel` flag every [`CANCEL_CHECK_PERIOD`] iterations (before any
/// RNG draw, so uncancelled runs stay bit-identical). Returns
/// `(best, iterations_run)`.
#[allow(clippy::too_many_arguments)]
pub fn anneal_duration_cancellable(
    layer: &ConvLayer,
    acc: &Accelerator,
    g: usize,
    k: usize,
    start: &[Vec<PatchId>],
    iters: u64,
    seed: u64,
    cancel: &AtomicBool,
) -> (Vec<Vec<PatchId>>, u64) {
    anneal_duration_cancel(layer, acc, g, k, start, iters, seed, Some(cancel))
}

#[allow(clippy::too_many_arguments)]
fn anneal_duration_cancel(
    layer: &ConvLayer,
    acc: &Accelerator,
    g: usize,
    k: usize,
    start: &[Vec<PatchId>],
    iters: u64,
    seed: u64,
    cancel: Option<&AtomicBool>,
) -> (Vec<Vec<PatchId>>, u64) {
    let mut state = State::new(layer, normalize(start, g, k));
    let mut mk = MakespanEval::new(layer, acc, &state.materialize());
    let mut best = state.materialize();
    let mut best_cost = mk.makespan();

    let mut rng = Rng::new(seed);
    // Temperature scale: a typical bad move costs O(one patch footprint) of
    // load cycles, or one compute slot when t_acc dominates.
    let t0 = (((layer.h_k * layer.w_k * layer.c_in) as u64 * acc.t_l.max(1))
        .max(acc.t_acc)
        .max(1)) as f64;
    let t_end = 0.05;

    for it in 0..iters {
        if cancelled_at(it, cancel) {
            return (best, it);
        }
        let progress = it as f64 / iters.max(1) as f64;
        let temp = t0 * (t_end / t0).powf(progress);

        let proposal = match rng.below(4) {
            0 => state.propose_relocate(layer, &mut rng, g),
            1 => state.propose_swap_patches(layer, &mut rng),
            2 => state.propose_swap_groups(&mut rng),
            _ => state.propose_reverse_segment(&mut rng),
        };
        let Some((mv, _pixel_delta)) = proposal else { continue };
        let effect = state.eval.pending_effect().expect("scored move is staged");
        // Content moves change group lengths; order moves don't.
        let (glen_a, glen_b) = match &mv {
            Move::Relocate { from_slot, to_slot, .. } => (
                Some((
                    state.eval.position_of(*from_slot),
                    state.groups[*from_slot].len() as u64 - 1,
                )),
                Some((
                    state.eval.position_of(*to_slot),
                    state.groups[*to_slot].len() as u64 + 1,
                )),
            ),
            _ => (None, None),
        };
        let delta = mk.score(effect, glen_a, glen_b);

        let keep = delta <= 0 || rng.chance((-(delta as f64) / temp).exp());
        if keep {
            state.commit(mv);
            mk.commit();
            if mk.makespan() < best_cost {
                best_cost = mk.makespan();
                best = state.materialize();
            }
        }
        // Rejected: both evaluators left untouched, nothing to undo.
    }
    (best, iters)
}

/// Greedy construction: repeatedly extend the current group with the
/// unassigned patch maximizing overlap with the group under construction
/// (falling back to row-major for ties/cold starts). A cheap alternative
/// MIP start used by tests, the `sweep` CLI and the planner's greedy lane.
///
/// Scoring is incremental over the sparse patch-overlap graph: adding patch
/// `p` to the group can only change the score of `p`'s spatial neighbors
/// (the pixels `p` contributes are a subset of `pix(p)`), so each addition
/// updates `O(deg)` cached scores with word-masked row popcounts instead of
/// re-intersecting full `PixelSet`s against every unassigned patch —
/// `O(n²·pixels/64)` set work becomes an `O(n²)` integer argmax scan plus
/// `O(n·deg·H_K)` popcounts, with selections (and tie-breaks) bit-identical
/// to the historical implementation.
pub fn greedy(layer: &ConvLayer, g: usize, k: usize) -> Vec<Vec<PatchId>> {
    let n = layer.n_patches();
    assert!(
        k * g >= n,
        "greedy: k={k} groups of <= {g} patches cannot hold {n} patches"
    );
    let sizes = group_sizes(n, k);
    debug_assert!(sizes.iter().all(|&s| s <= g));

    let graph = OverlapGraph::build(layer);
    let mut unassigned: Vec<PatchId> = layer.all_patches().collect();
    let mut groups: Vec<Vec<PatchId>> = Vec::with_capacity(k);
    // score_cur[p] = |pix(p) ∩ footprint(group under construction)|
    // score_prev[p] = |pix(p) ∩ footprint(previous group)|
    let mut score_cur: Vec<i64> = vec![0; n];
    let mut score_prev: Vec<i64> = vec![0; n];
    let mut footprint = crate::tensor::PixelSet::empty(layer.n_pixels());
    let mut fresh_pixels = crate::tensor::PixelSet::empty(layer.n_pixels());

    for &len in &sizes {
        // New group: the finished footprint becomes "previous"; its cached
        // per-patch overlaps become the prev-scores wholesale.
        std::mem::swap(&mut score_prev, &mut score_cur);
        score_cur.fill(0);
        footprint.clear();

        let mut group: Vec<PatchId> = Vec::with_capacity(len);
        for _ in 0..len {
            // pick the unassigned patch with max overlap with (current group
            // footprint, weighted 2×) + (previous group footprint); ties
            // break to the earliest entry in the work list, exactly like the
            // historical full-intersection scan.
            let mut best_idx = 0;
            let mut best_score = -1i64;
            for (idx, &p) in unassigned.iter().enumerate() {
                let score = 2 * score_cur[p as usize] + score_prev[p as usize];
                if score > best_score {
                    best_score = score;
                    best_idx = idx;
                }
            }
            let p = unassigned.swap_remove(best_idx);
            // Pixels p newly contributes: pix(p) ∖ footprint. Only p's
            // spatial neighbors can intersect them.
            fresh_pixels.clear();
            layer.add_patch_pixels(&mut fresh_pixels, p);
            fresh_pixels.subtract(&footprint);
            for &(q, _) in graph.neighbors(p) {
                score_cur[q as usize] +=
                    layer.patch_pixels_in(&fresh_pixels, q) as i64;
            }
            footprint.union_with(&fresh_pixels);
            group.push(p);
        }
        groups.push(group);
    }
    debug_assert!(unassigned.is_empty());
    debug_assert!(groups.iter().all(|gr| gr.len() <= g));
    groups
}

/// Re-chunk into exactly `k` groups of ≤ `g` patches (preserving order).
pub fn normalize(start: &[Vec<PatchId>], g: usize, k: usize) -> Vec<Vec<PatchId>> {
    let flat: Vec<PatchId> = start.iter().flatten().copied().collect();
    let n = flat.len();
    assert!(k * g >= n, "k={k} groups of <= {g} cannot hold {n} patches");
    assert!(k <= n, "more groups ({k}) than patches ({n})");
    let sizes = group_sizes(n, k);
    let mut groups = Vec::with_capacity(k);
    let mut idx = 0;
    for len in sizes {
        groups.push(flat[idx..idx + len].to_vec());
        idx += len;
    }
    groups
}

/// Balanced group sizes: `n` patches over `k` groups, sizes differing ≤ 1.
fn group_sizes(n: usize, k: usize) -> Vec<usize> {
    let base = n / k;
    let extra = n % k;
    (0..k).map(|i| base + usize::from(i < extra)).collect()
}

/// A scored move, ready to commit. Positions refer to the current visit
/// order; group indices in the payload are *slots* (see
/// [`GroupingEval`]'s permutation layer).
enum Move {
    /// Move `groups[from_slot][from_pos]` to the tail of `groups[to_slot]`
    /// (the source vacancy is closed with `swap_remove`, as the historical
    /// implementation did).
    Relocate { from_slot: usize, from_pos: usize, to_slot: usize },
    /// Exchange `groups[slot_a][ai]` and `groups[slot_b][bi]`.
    Swap { slot_a: usize, ai: usize, slot_b: usize, bi: usize },
    /// Swap positions `i` and `i+1` in the visit order.
    SwapGroups,
    /// Reverse positions `[a ..= b]` in the visit order.
    Reverse,
}

/// Annealer state: slot-indexed group contents plus the order-aware
/// incremental evaluator. The visit order lives *only* in the evaluator's
/// permutation layer; [`State::materialize`] renders it on demand.
struct State {
    /// Slot-indexed patch lists (contents move, slots don't).
    groups: Vec<Vec<PatchId>>,
    /// `patch_slot[p]` = slot currently holding patch `p` (kept in sync by
    /// [`State::commit`]) — O(1) patch lookup for the graph-guided
    /// proposals instead of scanning every group.
    patch_slot: Vec<u32>,
    eval: GroupingEval,
}

impl State {
    fn new(layer: &ConvLayer, groups: Vec<Vec<PatchId>>) -> Self {
        let eval = GroupingEval::new(layer, &groups);
        let mut patch_slot = vec![0u32; layer.n_patches()];
        for (slot, group) in groups.iter().enumerate() {
            for &p in group {
                patch_slot[p as usize] = slot as u32;
            }
        }
        State { groups, patch_slot, eval }
    }

    fn cost(&self) -> i64 {
        self.eval.loaded_pixels() as i64
    }

    fn k(&self) -> usize {
        self.groups.len()
    }

    /// The grouping in visit order (clones the patch lists).
    fn materialize(&self) -> Vec<Vec<PatchId>> {
        self.eval
            .order()
            .iter()
            .map(|&slot| self.groups[slot as usize].clone())
            .collect()
    }

    /// Propose moving a random patch from a group with ≥ 2 patches into a
    /// group with slack. Draws (and their order) match the historical
    /// implementation exactly; no state is mutated.
    fn propose_relocate(
        &mut self,
        layer: &ConvLayer,
        rng: &mut Rng,
        g: usize,
    ) -> Option<(Move, i64)> {
        let k = self.k();
        if k < 2 {
            return None;
        }
        let from = rng.index(k);
        let from_slot = self.eval.slot_at(from);
        if self.groups[from_slot].len() < 2 {
            return None;
        }
        let to = rng.index(k);
        let to_slot = self.eval.slot_at(to);
        if to == from || self.groups[to_slot].len() >= g {
            return None;
        }
        let from_pos = rng.index(self.groups[from_slot].len());
        Some(self.score_relocate(layer, from, from_pos, to))
    }

    /// Score a relocate described by order positions (shared by the uniform
    /// and the neighbor-biased proposal paths).
    fn score_relocate(
        &mut self,
        layer: &ConvLayer,
        from: usize,
        from_pos: usize,
        to: usize,
    ) -> (Move, i64) {
        let from_slot = self.eval.slot_at(from);
        let to_slot = self.eval.slot_at(to);
        let p = self.groups[from_slot][from_pos];
        let delta = self.eval.score_edit2(
            layer,
            from,
            GroupEdit { patches: &self.groups[from_slot], skip: Some(from_pos), add: None },
            to,
            GroupEdit { patches: &self.groups[to_slot], skip: None, add: Some(p) },
        );
        (Move::Relocate { from_slot, from_pos, to_slot }, delta)
    }

    /// Propose exchanging two random patches between two different groups.
    fn propose_swap_patches(
        &mut self,
        layer: &ConvLayer,
        rng: &mut Rng,
    ) -> Option<(Move, i64)> {
        let k = self.k();
        if k < 2 {
            return None;
        }
        let a = rng.index(k);
        let b = rng.index(k);
        if a == b {
            return None;
        }
        let slot_a = self.eval.slot_at(a);
        let slot_b = self.eval.slot_at(b);
        let ai = rng.index(self.groups[slot_a].len());
        let bi = rng.index(self.groups[slot_b].len());
        let (pa, pb) = (self.groups[slot_a][ai], self.groups[slot_b][bi]);
        let delta = self.eval.score_edit2(
            layer,
            a,
            GroupEdit { patches: &self.groups[slot_a], skip: Some(ai), add: Some(pb) },
            b,
            GroupEdit { patches: &self.groups[slot_b], skip: Some(bi), add: Some(pa) },
        );
        Some((Move::Swap { slot_a, ai, slot_b, bi }, delta))
    }

    /// Propose swapping two adjacent groups in the order. Footprint-free.
    fn propose_swap_groups(&mut self, rng: &mut Rng) -> Option<(Move, i64)> {
        let k = self.k();
        if k < 2 {
            return None;
        }
        let i = rng.index(k - 1);
        let delta = self.eval.score_swap_adjacent(i);
        Some((Move::SwapGroups, delta))
    }

    /// Propose reversing a random segment of the group order (2-opt).
    /// Footprint-free.
    fn propose_reverse_segment(&mut self, rng: &mut Rng) -> Option<(Move, i64)> {
        let k = self.k();
        if k < 3 {
            return None;
        }
        let a = rng.index(k - 1);
        let b = a + 1 + rng.index(k - a - 1);
        if b - a < 1 {
            return None;
        }
        let delta = self.eval.score_reverse(a, b);
        Some((Move::Reverse, delta))
    }

    /// Graph-guided relocate: pick a random patch, then one of its spatial
    /// neighbors, and propose moving the patch into the neighbor's group.
    /// Only reachable when `neighbor_bias > 0` (changes the RNG stream).
    fn propose_neighbor_relocate(
        &mut self,
        layer: &ConvLayer,
        rng: &mut Rng,
        graph: &OverlapGraph,
        g: usize,
    ) -> Option<(Move, i64)> {
        if self.k() < 2 {
            return None;
        }
        let p = rng.index(layer.n_patches()) as PatchId;
        let neighbors = graph.neighbors(p);
        if neighbors.is_empty() {
            return None;
        }
        let (q, _) = neighbors[rng.index(neighbors.len())];
        let (from_slot, from_pos) = self.locate(p);
        let (to_slot, _) = self.locate(q);
        if from_slot == to_slot
            || self.groups[from_slot].len() < 2
            || self.groups[to_slot].len() >= g
        {
            return None;
        }
        let from = self.eval.position_of(from_slot);
        let to = self.eval.position_of(to_slot);
        Some(self.score_relocate(layer, from, from_pos, to))
    }

    /// Graph-guided swap: pick a random patch and one of its spatial
    /// neighbors in a *different* group, and propose exchanging them.
    fn propose_neighbor_swap(
        &mut self,
        layer: &ConvLayer,
        rng: &mut Rng,
        graph: &OverlapGraph,
    ) -> Option<(Move, i64)> {
        if self.k() < 2 {
            return None;
        }
        let pa = rng.index(layer.n_patches()) as PatchId;
        let neighbors = graph.neighbors(pa);
        if neighbors.is_empty() {
            return None;
        }
        let (pb, _) = neighbors[rng.index(neighbors.len())];
        let (slot_a, ai) = self.locate(pa);
        let (slot_b, bi) = self.locate(pb);
        if slot_a == slot_b {
            return None;
        }
        let a = self.eval.position_of(slot_a);
        let b = self.eval.position_of(slot_b);
        let delta = self.eval.score_edit2(
            layer,
            a,
            GroupEdit { patches: &self.groups[slot_a], skip: Some(ai), add: Some(pb) },
            b,
            GroupEdit { patches: &self.groups[slot_b], skip: Some(bi), add: Some(pa) },
        );
        Some((Move::Swap { slot_a, ai, slot_b, bi }, delta))
    }

    /// (slot, index-within-slot) of a patch: O(1) slot lookup via
    /// `patch_slot`, then a scan bounded by the group-size cap `g`.
    fn locate(&self, p: PatchId) -> (usize, usize) {
        let slot = self.patch_slot[p as usize] as usize;
        let i = self.groups[slot]
            .iter()
            .position(|&x| x == p)
            .expect("patch_slot index out of sync");
        (slot, i)
    }

    /// Apply an accepted move: the evaluator replays its staged entries and
    /// the slot contents are updated to match.
    fn commit(&mut self, mv: Move) {
        self.eval.commit();
        match mv {
            Move::Relocate { from_slot, from_pos, to_slot } => {
                let p = self.groups[from_slot].swap_remove(from_pos);
                self.groups[to_slot].push(p);
                self.patch_slot[p as usize] = to_slot as u32;
            }
            Move::Swap { slot_a, ai, slot_b, bi } => {
                let (pa, pb) = (self.groups[slot_a][ai], self.groups[slot_b][bi]);
                self.groups[slot_a][ai] = pb;
                self.groups[slot_b][bi] = pa;
                self.patch_slot[pa as usize] = slot_b as u32;
                self.patch_slot[pb as usize] = slot_a as u32;
            }
            Move::SwapGroups | Move::Reverse => {
                // Order moves live entirely in the evaluator's permutation.
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optimizer::objective::grouping_loads;
    use crate::strategy;

    #[test]
    fn anneal_improves_or_matches_start() {
        let l = ConvLayer::square(1, 8, 3, 1); // 36 patches
        for g in [2usize, 4] {
            let k = l.n_patches().div_ceil(g);
            let start = strategy::row_by_row(&l, g).groups;
            let start_loads = grouping_loads(&l, &start);
            let result = anneal(&l, g, k, &start, 30_000, 7);
            let result_loads = grouping_loads(&l, &result);
            assert!(
                result_loads <= start_loads,
                "g={g}: {result_loads} > {start_loads}"
            );
            // structure: exactly k groups, sizes ≤ g, all patches once
            assert_eq!(result.len(), k);
            assert!(result.iter().all(|gr| gr.len() <= g && !gr.is_empty()));
            let mut all: Vec<u32> = result.iter().flatten().copied().collect();
            all.sort();
            assert_eq!(all, l.all_patches().collect::<Vec<_>>());
        }
    }

    #[test]
    fn anneal_is_deterministic_per_seed() {
        let l = ConvLayer::square(1, 6, 3, 1);
        let start = strategy::zigzag(&l, 2).groups;
        let a = anneal(&l, 2, 8, &start, 5_000, 42);
        let b = anneal(&l, 2, 8, &start, 5_000, 42);
        assert_eq!(a, b);
        let c = anneal(&l, 2, 8, &start, 5_000, 43);
        // different seeds usually find a different grouping (not guaranteed,
        // but extremely likely at this instance size); only check validity
        let mut all: Vec<u32> = c.iter().flatten().copied().collect();
        all.sort();
        assert_eq!(all, l.all_patches().collect::<Vec<_>>());
    }

    #[test]
    fn neighbor_bias_stays_valid_and_deterministic() {
        let l = ConvLayer::square(1, 7, 3, 1); // 25 patches
        let start = strategy::row_by_row(&l, 3).groups;
        let opts = AnnealOptions { neighbor_bias: 0.5 };
        let a = anneal_with(&l, 3, 9, &start, 8_000, 11, &opts);
        let b = anneal_with(&l, 3, 9, &start, 8_000, 11, &opts);
        assert_eq!(a, b, "biased annealing must stay deterministic per seed");
        // The annealer's guarantee is against its normalized (re-chunked)
        // start, not the raw chunking.
        assert!(
            grouping_loads(&l, &a) <= grouping_loads(&l, &normalize(&start, 3, 9)),
            "never worse than the normalized start"
        );
        assert_eq!(a.len(), 9);
        assert!(a.iter().all(|gr| gr.len() <= 3 && !gr.is_empty()));
        let mut all: Vec<u32> = a.iter().flatten().copied().collect();
        all.sort();
        assert_eq!(all, l.all_patches().collect::<Vec<_>>());
    }

    #[test]
    fn zero_bias_matches_plain_anneal_exactly() {
        // anneal_with(bias = 0) and anneal must share the RNG stream and so
        // the result, bit for bit — the planner's determinism rests on it.
        let l = ConvLayer::square(1, 7, 3, 1);
        let start = strategy::zigzag(&l, 2).groups;
        let a = anneal(&l, 2, 13, &start, 6_000, 5);
        let b = anneal_with(&l, 2, 13, &start, 6_000, 5, &AnnealOptions::default());
        assert_eq!(a, b);
    }

    #[test]
    fn uncancelled_cancellable_anneal_is_bit_identical() {
        let l = ConvLayer::square(1, 7, 3, 1);
        let start = strategy::zigzag(&l, 2).groups;
        let flag = AtomicBool::new(false);
        let (a, ran) = anneal_cancellable(&l, 2, 13, &start, 6_000, 5, &flag);
        assert_eq!(ran, 6_000);
        assert_eq!(a, anneal(&l, 2, 13, &start, 6_000, 5));
    }

    #[test]
    fn pre_cancelled_anneal_returns_normalized_start_after_zero_iters() {
        let l = ConvLayer::square(1, 7, 3, 1);
        let start = strategy::zigzag(&l, 2).groups;
        let flag = AtomicBool::new(true);
        let (a, ran) = anneal_cancellable(&l, 2, 13, &start, 6_000, 5, &flag);
        assert_eq!(ran, 0, "flag was set before the first iteration");
        assert_eq!(a, normalize(&start, 2, 13), "best-so-far is the start");
        // The degraded result is still a complete, valid partition.
        let mut all: Vec<u32> = a.iter().flatten().copied().collect();
        all.sort();
        assert_eq!(all, l.all_patches().collect::<Vec<_>>());
    }

    #[test]
    fn pre_cancelled_anneal_duration_returns_start() {
        let l = ConvLayer::square(1, 7, 3, 1);
        let acc = crate::platform::Accelerator::for_group_size(&l, 3);
        let start = strategy::row_by_row(&l, 3).groups;
        let flag = AtomicBool::new(true);
        let (a, ran) =
            anneal_duration_cancellable(&l, &acc, 3, 9, &start, 5_000, 7, &flag);
        assert_eq!(ran, 0);
        assert_eq!(a, normalize(&start, 3, 9));
        let flag = AtomicBool::new(false);
        let (b, ran) =
            anneal_duration_cancellable(&l, &acc, 3, 9, &start, 5_000, 7, &flag);
        assert_eq!(ran, 5_000);
        assert_eq!(b, anneal_duration(&l, &acc, 3, 9, &start, 5_000, 7));
    }

    #[test]
    fn normalize_balances_and_preserves() {
        let start = vec![vec![0u32, 1, 2, 3, 4, 5, 6]];
        let out = normalize(&start, 3, 3);
        assert_eq!(out.len(), 3);
        assert_eq!(out.iter().map(Vec::len).collect::<Vec<_>>(), vec![3, 2, 2]);
        let flat: Vec<u32> = out.iter().flatten().copied().collect();
        assert_eq!(flat, vec![0, 1, 2, 3, 4, 5, 6]);
    }

    #[test]
    #[should_panic(expected = "cannot hold")]
    fn normalize_rejects_impossible() {
        normalize(&[vec![0u32, 1, 2, 3]], 1, 3);
    }

    #[test]
    fn greedy_produces_valid_grouping() {
        let l = ConvLayer::square(1, 7, 3, 1); // 25 patches
        let k = 13;
        let groups = greedy(&l, 2, k);
        assert_eq!(groups.len(), k);
        let mut all: Vec<u32> = groups.iter().flatten().copied().collect();
        all.sort();
        assert_eq!(all, l.all_patches().collect::<Vec<_>>());
        // greedy should be no worse than random row-chunking for this size
        let row = strategy::row_by_row(&l, 2).groups;
        assert!(grouping_loads(&l, &groups) <= grouping_loads(&l, &row) + 10);
    }

    /// The incremental (graph-scored) greedy must agree with a direct
    /// full-intersection reimplementation of the historical scan — same
    /// selections, same tie-breaks, bit-identical groups.
    #[test]
    fn greedy_matches_full_intersection_reference() {
        fn reference_greedy(
            layer: &ConvLayer,
            k: usize,
        ) -> Vec<Vec<PatchId>> {
            let n = layer.n_patches();
            let sizes = group_sizes(n, k);
            let mut unassigned: Vec<PatchId> = layer.all_patches().collect();
            let mut groups = Vec::with_capacity(k);
            let mut prev = crate::tensor::PixelSet::empty(layer.n_pixels());
            for &len in &sizes {
                let mut group = Vec::with_capacity(len);
                let mut fp = crate::tensor::PixelSet::empty(layer.n_pixels());
                for _ in 0..len {
                    let mut best_idx = 0;
                    let mut best_score = -1i64;
                    for (idx, &p) in unassigned.iter().enumerate() {
                        let pp = layer.patch_pixels(p);
                        let score = pp.intersection_len(&fp) as i64 * 2
                            + pp.intersection_len(&prev) as i64;
                        if score > best_score {
                            best_score = score;
                            best_idx = idx;
                        }
                    }
                    let p = unassigned.swap_remove(best_idx);
                    fp.union_with(&layer.patch_pixels(p));
                    group.push(p);
                }
                prev = fp;
                groups.push(group);
            }
            groups
        }

        for (l, g, k) in [
            (ConvLayer::square(1, 7, 3, 1), 2usize, 13usize),
            (ConvLayer::square(1, 8, 3, 1), 4, 9),
            (ConvLayer::new(1, 9, 9, 3, 3, 1, 2, 2).unwrap(), 3, 6), // strided
            (ConvLayer::new(1, 12, 10, 5, 5, 1, 1, 1).unwrap(), 4, 12), // 5×5
            // dilated: the incremental graph scoring must stay exact when
            // patch lattices have holes (the mobilenet_slim dil3 shape)
            (
                ConvLayer::new(8, 12, 12, 3, 3, 8, 1, 1)
                    .unwrap()
                    .with_dilation(2, 2)
                    .unwrap(),
                4,
                16,
            ),
            // depthwise + stride (the mobilenet_slim dw3 shape)
            (
                ConvLayer::new(4, 18, 18, 3, 3, 4, 2, 2)
                    .unwrap()
                    .with_groups(4)
                    .unwrap(),
                4,
                16,
            ),
        ] {
            assert_eq!(
                greedy(&l, g, k),
                reference_greedy(&l, k),
                "layer {l} g={g} k={k}"
            );
        }
    }

    #[test]
    #[should_panic(expected = "cannot hold")]
    fn greedy_rejects_over_capacity() {
        let l = ConvLayer::square(1, 7, 3, 1); // 25 patches
        let _ = greedy(&l, 2, 12); // 12 × 2 = 24 < 25
    }

    #[test]
    fn anneal_duration_is_deterministic_and_never_worse() {
        use crate::optimizer::objective::grouping_makespan;
        let l = ConvLayer::square(1, 8, 3, 1); // 36 patches
        let g = 4;
        let k = l.n_patches().div_ceil(g);
        let acc = Accelerator {
            t_acc: 4,
            t_w: 1,
            ..Accelerator::for_group_size(&l, g)
        };
        let start = strategy::row_by_row(&l, g).groups;
        let a = anneal_duration(&l, &acc, g, k, &start, 8_000, 11);
        let b = anneal_duration(&l, &acc, g, k, &start, 8_000, 11);
        assert_eq!(a, b, "deterministic per seed");
        assert!(
            grouping_makespan(&l, &acc, &a)
                <= grouping_makespan(&l, &acc, &normalize(&start, g, k)),
            "never worse than the normalized start"
        );
        // structure: exactly k groups, sizes ≤ g, all patches once
        assert_eq!(a.len(), k);
        assert!(a.iter().all(|gr| gr.len() <= g && !gr.is_empty()));
        let mut all: Vec<u32> = a.iter().flatten().copied().collect();
        all.sort();
        assert_eq!(all, l.all_patches().collect::<Vec<_>>());
    }

    /// Duration-domain twin of the 1000-move test below: the lock-stepped
    /// [`MakespanEval`] must equal a from-scratch rebuild (and the analytic
    /// `grouping_makespan`) after arbitrary accept/reject interleavings of
    /// all four move kinds, with every accepted delta matching the observed
    /// makespan change.
    #[test]
    fn thousand_random_moves_match_from_scratch_makespan() {
        use crate::optimizer::objective::grouping_makespan;
        for (l, g, extra_mem) in [
            (ConvLayer::square(1, 6, 3, 1), 2usize, 0u64),
            (ConvLayer::square(1, 8, 3, 1), 4, 40),
            // strided + roomy memory: the prefetch branch dominates
            (ConvLayer::new(1, 9, 9, 3, 3, 1, 2, 2).unwrap(), 3, 100_000),
            // dilated: hole-y footprints through the timeline
            (
                ConvLayer::new(1, 11, 11, 3, 3, 1, 1, 1)
                    .unwrap()
                    .with_dilation(2, 2)
                    .unwrap(),
                3,
                0,
            ),
        ] {
            let base = Accelerator::for_group_size(&l, g);
            let acc = Accelerator {
                t_acc: 3,
                t_w: 1,
                size_mem: base.size_mem + extra_mem,
                ..base
            };
            let k = l.n_patches().div_ceil(g);
            let start = normalize(&strategy::row_by_row(&l, g).groups, g, k);
            let mut state = State::new(&l, start);
            let mut mk = MakespanEval::new(&l, &acc, &state.materialize());
            let mut rng = Rng::new(0x0E17A);
            let (mut accepted, mut rejected) = (0u32, 0u32);
            for it in 0..1_000 {
                let before = mk.makespan();
                let proposal = match rng.below(4) {
                    0 => state.propose_relocate(&l, &mut rng, g),
                    1 => state.propose_swap_patches(&l, &mut rng),
                    2 => state.propose_swap_groups(&mut rng),
                    _ => state.propose_reverse_segment(&mut rng),
                };
                let Some((mv, _)) = proposal else { continue };
                let effect = state.eval.pending_effect().unwrap();
                let (glen_a, glen_b) = match &mv {
                    Move::Relocate { from_slot, to_slot, .. } => (
                        Some((
                            state.eval.position_of(*from_slot),
                            state.groups[*from_slot].len() as u64 - 1,
                        )),
                        Some((
                            state.eval.position_of(*to_slot),
                            state.groups[*to_slot].len() as u64 + 1,
                        )),
                    ),
                    _ => (None, None),
                };
                let delta = mk.score(effect, glen_a, glen_b);
                assert_eq!(mk.makespan(), before, "score mutated state at {it}");
                if rng.chance(0.5) {
                    state.commit(mv);
                    mk.commit();
                    accepted += 1;
                    assert_eq!(
                        mk.makespan() as i64,
                        before as i64 + delta,
                        "delta mismatch at iteration {it}"
                    );
                } else {
                    rejected += 1;
                    assert_eq!(mk.makespan(), before);
                }
                if it % 97 == 0 {
                    assert_eq!(
                        mk.makespan(),
                        grouping_makespan(&l, &acc, &state.materialize()),
                        "incremental makespan diverged at {it}"
                    );
                }
            }
            assert_eq!(mk.makespan(), grouping_makespan(&l, &acc, &state.materialize()));
            assert!(accepted > 100 && rejected > 100, "both paths exercised");
        }
    }

    /// The delta-consistency property the whole PR rests on: after 1 000
    /// random proposals across all four move kinds — committing accepts and
    /// dropping rejects exactly like the annealer — the incremental
    /// evaluator equals a from-scratch [`GroupingEval::new`] on the
    /// materialized grouping, and every accepted delta matched the observed
    /// objective change.
    #[test]
    fn thousand_random_moves_match_from_scratch_eval() {
        for (l, g) in [
            (ConvLayer::square(1, 6, 3, 1), 2usize),
            (ConvLayer::square(1, 8, 3, 1), 4),
            (ConvLayer::new(1, 9, 9, 3, 3, 1, 2, 2).unwrap(), 3), // strided
            // dilated: delta evaluation over hole-y footprints
            (
                ConvLayer::new(1, 11, 11, 3, 3, 1, 1, 1)
                    .unwrap()
                    .with_dilation(2, 2)
                    .unwrap(),
                3,
            ),
        ] {
            let k = l.n_patches().div_ceil(g);
            let start = normalize(&strategy::row_by_row(&l, g).groups, g, k);
            let mut state = State::new(&l, start);
            let mut rng = Rng::new(0xDE17A);
            let mut accepted = 0u32;
            let mut rejected = 0u32;
            for it in 0..1_000 {
                let before = state.cost();
                let proposal = match rng.below(4) {
                    0 => state.propose_relocate(&l, &mut rng, g),
                    1 => state.propose_swap_patches(&l, &mut rng),
                    2 => state.propose_swap_groups(&mut rng),
                    _ => state.propose_reverse_segment(&mut rng),
                };
                let Some((mv, delta)) = proposal else { continue };
                // Scoring must not change anything observable.
                assert_eq!(state.cost(), before, "score mutated state at {it}");
                // Accept about half the proposals, independent of sign, so
                // both uphill commits and downhill rejects are exercised.
                if rng.chance(0.5) {
                    state.commit(mv);
                    accepted += 1;
                    assert_eq!(
                        state.cost(),
                        before + delta,
                        "delta mismatch at iteration {it}"
                    );
                } else {
                    rejected += 1;
                    assert_eq!(state.cost(), before);
                }
                if it % 97 == 0 {
                    let fresh = GroupingEval::new(&l, &state.materialize());
                    assert_eq!(
                        state.cost() as usize,
                        fresh.loaded_pixels(),
                        "incremental total diverged at {it}"
                    );
                }
            }
            // Final full cross-check.
            let materialized = state.materialize();
            let fresh = GroupingEval::new(&l, &materialized);
            assert_eq!(state.cost() as usize, fresh.loaded_pixels());
            assert!(accepted > 100 && rejected > 100, "both paths exercised");
            // Structure stayed a partition.
            let mut all: Vec<u32> = materialized.iter().flatten().copied().collect();
            all.sort();
            assert_eq!(all, l.all_patches().collect::<Vec<_>>());
        }
    }
}

//! Simulated-annealing polish over groupings (the paper's “solution
//! polishing” phase — CPLEX switches to a genetic algorithm after 60 s; we
//! use deterministic annealing over the same solution space).
//!
//! State: an ordered partition of `X` into exactly `k` groups of size ≤ `g`.
//! Moves:
//! 1. **relocate** — move a patch to another group with slack;
//! 2. **swap** — exchange two patches between different groups;
//! 3. **adjacent-swap** — exchange whole groups `k` and `k+1` in the order;
//! 4. **segment-reverse** — reverse a run of groups (2-opt; footprints are
//!    unchanged, only the two boundary overlaps move, since overlap is
//!    symmetric).
//!
//! The objective is [`GroupingEval::loaded_pixels`] (Eq. 15 divided by
//! `t_l·C_in`, minus the constant `n·t_acc`). Every move is applied
//! tentatively, scored, and undone when the Metropolis test rejects it.

use crate::conv::{ConvLayer, PatchId};
use crate::optimizer::objective::GroupingEval;
use crate::util::rng::Rng;

/// Anneal from `start` (the MIP start). Returns the best grouping found
/// (never worse than `start` re-chunked to `k` groups).
pub fn anneal(
    layer: &ConvLayer,
    g: usize,
    k: usize,
    start: &[Vec<PatchId>],
    iters: u64,
    seed: u64,
) -> Vec<Vec<PatchId>> {
    let mut state = State::new(layer, normalize(start, g, k));
    let mut best = state.groups.clone();
    let mut best_cost = state.cost();

    let mut rng = Rng::new(seed);
    // Temperature scale: a typical bad move costs O(one patch footprint).
    let t0 = (layer.h_k * layer.w_k) as f64;
    let t_end = 0.05;

    for it in 0..iters {
        let progress = it as f64 / iters.max(1) as f64;
        let temp = t0 * (t_end / t0).powf(progress);
        let before = state.cost();

        let undo = match rng.below(4) {
            0 => state.relocate(layer, &mut rng, g),
            1 => state.swap_patches(layer, &mut rng),
            2 => state.swap_groups(layer, &mut rng),
            _ => state.reverse_segment(layer, &mut rng),
        };
        let Some(undo) = undo else { continue };

        let delta = state.cost() - before;
        let keep = delta <= 0 || rng.chance((-(delta as f64) / temp).exp());
        if keep {
            if state.cost() < best_cost {
                best_cost = state.cost();
                best = state.groups.clone();
            }
        } else {
            state.apply_undo(layer, undo);
            debug_assert_eq!(state.cost(), before);
        }
    }
    best
}

/// Greedy construction: repeatedly extend the current group with the
/// unassigned patch maximizing overlap with the group under construction
/// (falling back to row-major for ties/cold starts). A cheap alternative
/// MIP start used by tests and the `sweep` CLI.
pub fn greedy(layer: &ConvLayer, g: usize, k: usize) -> Vec<Vec<PatchId>> {
    let n = layer.n_patches();
    let sizes = group_sizes(n, k);
    let mut unassigned: Vec<PatchId> = layer.all_patches().collect();
    let mut groups: Vec<Vec<PatchId>> = Vec::with_capacity(k);
    let mut prev_footprint = crate::tensor::PixelSet::empty(layer.n_pixels());

    for &len in &sizes {
        let mut group: Vec<PatchId> = Vec::with_capacity(len);
        let mut footprint = crate::tensor::PixelSet::empty(layer.n_pixels());
        for _ in 0..len {
            // pick the unassigned patch with max overlap with (current group
            // footprint ∪ previous group footprint), tie → smallest id
            let mut best_idx = 0;
            let mut best_score = -1i64;
            for (idx, &p) in unassigned.iter().enumerate() {
                let pp = layer.patch_pixels(p);
                let score = pp.intersection_len(&footprint) as i64 * 2
                    + pp.intersection_len(&prev_footprint) as i64;
                if score > best_score {
                    best_score = score;
                    best_idx = idx;
                }
            }
            let p = unassigned.swap_remove(best_idx);
            footprint.union_with(&layer.patch_pixels(p));
            group.push(p);
        }
        prev_footprint = footprint;
        groups.push(group);
    }
    debug_assert!(unassigned.is_empty());
    let _ = g;
    groups
}

/// Re-chunk into exactly `k` groups of ≤ `g` patches (preserving order).
pub fn normalize(start: &[Vec<PatchId>], g: usize, k: usize) -> Vec<Vec<PatchId>> {
    let flat: Vec<PatchId> = start.iter().flatten().copied().collect();
    let n = flat.len();
    assert!(k * g >= n, "k={k} groups of <= {g} cannot hold {n} patches");
    assert!(k <= n, "more groups ({k}) than patches ({n})");
    let sizes = group_sizes(n, k);
    let mut groups = Vec::with_capacity(k);
    let mut idx = 0;
    for len in sizes {
        groups.push(flat[idx..idx + len].to_vec());
        idx += len;
    }
    groups
}

/// Balanced group sizes: `n` patches over `k` groups, sizes differing ≤ 1.
fn group_sizes(n: usize, k: usize) -> Vec<usize> {
    let base = n / k;
    let extra = n % k;
    (0..k).map(|i| base + usize::from(i < extra)).collect()
}

/// Undo record for a tentatively applied move.
enum Undo {
    /// Move patch at `groups[to]`'s tail back to `from` at `from_pos`.
    Relocate { from: usize, from_pos: usize, to: usize },
    /// Swap back `groups[a][ai]` and `groups[b][bi]`.
    Swap { a: usize, ai: usize, b: usize, bi: usize },
    /// Swap groups `k` and `k+1` back.
    SwapGroups { k: usize },
    /// Reverse groups `[a..=b]` back.
    Reverse { a: usize, b: usize },
}

struct State {
    groups: Vec<Vec<PatchId>>,
    eval: GroupingEval,
}

impl State {
    fn new(layer: &ConvLayer, groups: Vec<Vec<PatchId>>) -> Self {
        let eval = GroupingEval::new(layer, &groups);
        State { groups, eval }
    }

    fn cost(&self) -> i64 {
        self.eval.loaded_pixels() as i64
    }

    fn k(&self) -> usize {
        self.groups.len()
    }

    /// Move a random patch from a group with ≥ 2 patches into a group with
    /// slack.
    fn relocate(&mut self, layer: &ConvLayer, rng: &mut Rng, g: usize) -> Option<Undo> {
        let k = self.k();
        if k < 2 {
            return None;
        }
        let from = rng.index(k);
        if self.groups[from].len() < 2 {
            return None;
        }
        let to = rng.index(k);
        if to == from || self.groups[to].len() >= g {
            return None;
        }
        let from_pos = rng.index(self.groups[from].len());
        let p = self.groups[from].swap_remove(from_pos);
        self.groups[to].push(p);
        self.eval.refresh_group(layer, &self.groups, from);
        self.eval.refresh_group(layer, &self.groups, to);
        Some(Undo::Relocate { from, from_pos, to })
    }

    /// Exchange two random patches between two different groups.
    fn swap_patches(&mut self, layer: &ConvLayer, rng: &mut Rng) -> Option<Undo> {
        let k = self.k();
        if k < 2 {
            return None;
        }
        let a = rng.index(k);
        let b = rng.index(k);
        if a == b {
            return None;
        }
        let ai = rng.index(self.groups[a].len());
        let bi = rng.index(self.groups[b].len());
        let (pa, pb) = (self.groups[a][ai], self.groups[b][bi]);
        self.groups[a][ai] = pb;
        self.groups[b][bi] = pa;
        self.eval.refresh_group(layer, &self.groups, a);
        self.eval.refresh_group(layer, &self.groups, b);
        Some(Undo::Swap { a, ai, b, bi })
    }

    /// Swap two adjacent groups in the order.
    fn swap_groups(&mut self, layer: &ConvLayer, rng: &mut Rng) -> Option<Undo> {
        let k = self.k();
        if k < 2 {
            return None;
        }
        let i = rng.index(k - 1);
        self.groups.swap(i, i + 1);
        self.eval.refresh_group(layer, &self.groups, i);
        self.eval.refresh_group(layer, &self.groups, i + 1);
        Some(Undo::SwapGroups { k: i })
    }

    /// Reverse a random segment of the group order (2-opt).
    fn reverse_segment(&mut self, layer: &ConvLayer, rng: &mut Rng) -> Option<Undo> {
        let k = self.k();
        if k < 3 {
            return None;
        }
        let a = rng.index(k - 1);
        let b = a + 1 + rng.index(k - a - 1);
        if b - a < 1 {
            return None;
        }
        self.groups[a..=b].reverse();
        self.refresh_range(layer, a, b);
        Some(Undo::Reverse { a, b })
    }

    fn refresh_range(&mut self, layer: &ConvLayer, a: usize, b: usize) {
        // Footprints move with the groups; rebuild the eval entries in the
        // touched range (+1 for the boundary overlap after `b`).
        for k in a..=b {
            self.eval.refresh_group(layer, &self.groups, k);
        }
        if b + 1 < self.groups.len() {
            self.eval.refresh_group(layer, &self.groups, b + 1);
        }
    }

    fn apply_undo(&mut self, layer: &ConvLayer, undo: Undo) {
        match undo {
            Undo::Relocate { from, from_pos, to } => {
                let p = self.groups[to].pop().expect("relocated patch present");
                let end = self.groups[from].len();
                self.groups[from].push(p);
                // invert the earlier swap_remove
                self.groups[from].swap(from_pos.min(end), end);
                self.eval.refresh_group(layer, &self.groups, from);
                self.eval.refresh_group(layer, &self.groups, to);
            }
            Undo::Swap { a, ai, b, bi } => {
                let (pa, pb) = (self.groups[a][ai], self.groups[b][bi]);
                self.groups[a][ai] = pb;
                self.groups[b][bi] = pa;
                self.eval.refresh_group(layer, &self.groups, a);
                self.eval.refresh_group(layer, &self.groups, b);
            }
            Undo::SwapGroups { k } => {
                self.groups.swap(k, k + 1);
                self.eval.refresh_group(layer, &self.groups, k);
                self.eval.refresh_group(layer, &self.groups, k + 1);
            }
            Undo::Reverse { a, b } => {
                self.groups[a..=b].reverse();
                self.refresh_range(layer, a, b);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optimizer::objective::grouping_loads;
    use crate::strategy;

    #[test]
    fn anneal_improves_or_matches_start() {
        let l = ConvLayer::square(1, 8, 3, 1); // 36 patches
        for g in [2usize, 4] {
            let k = l.n_patches().div_ceil(g);
            let start = strategy::row_by_row(&l, g).groups;
            let start_loads = grouping_loads(&l, &start);
            let result = anneal(&l, g, k, &start, 30_000, 7);
            let result_loads = grouping_loads(&l, &result);
            assert!(
                result_loads <= start_loads,
                "g={g}: {result_loads} > {start_loads}"
            );
            // structure: exactly k groups, sizes ≤ g, all patches once
            assert_eq!(result.len(), k);
            assert!(result.iter().all(|gr| gr.len() <= g && !gr.is_empty()));
            let mut all: Vec<u32> = result.iter().flatten().copied().collect();
            all.sort();
            assert_eq!(all, l.all_patches().collect::<Vec<_>>());
        }
    }

    #[test]
    fn anneal_is_deterministic_per_seed() {
        let l = ConvLayer::square(1, 6, 3, 1);
        let start = strategy::zigzag(&l, 2).groups;
        let a = anneal(&l, 2, 8, &start, 5_000, 42);
        let b = anneal(&l, 2, 8, &start, 5_000, 42);
        assert_eq!(a, b);
        let c = anneal(&l, 2, 8, &start, 5_000, 43);
        // different seeds usually find a different grouping (not guaranteed,
        // but extremely likely at this instance size); only check validity
        let mut all: Vec<u32> = c.iter().flatten().copied().collect();
        all.sort();
        assert_eq!(all, l.all_patches().collect::<Vec<_>>());
    }

    #[test]
    fn normalize_balances_and_preserves() {
        let start = vec![vec![0u32, 1, 2, 3, 4, 5, 6]];
        let out = normalize(&start, 3, 3);
        assert_eq!(out.len(), 3);
        assert_eq!(out.iter().map(Vec::len).collect::<Vec<_>>(), vec![3, 2, 2]);
        let flat: Vec<u32> = out.iter().flatten().copied().collect();
        assert_eq!(flat, vec![0, 1, 2, 3, 4, 5, 6]);
    }

    #[test]
    #[should_panic(expected = "cannot hold")]
    fn normalize_rejects_impossible() {
        normalize(&[vec![0u32, 1, 2, 3]], 1, 3);
    }

    #[test]
    fn greedy_produces_valid_grouping() {
        let l = ConvLayer::square(1, 7, 3, 1); // 25 patches
        let k = 13;
        let groups = greedy(&l, 2, k);
        assert_eq!(groups.len(), k);
        let mut all: Vec<u32> = groups.iter().flatten().copied().collect();
        all.sort();
        assert_eq!(all, l.all_patches().collect::<Vec<_>>());
        // greedy should be no worse than random row-chunking for this size
        let row = strategy::row_by_row(&l, 2).groups;
        assert!(grouping_loads(&l, &groups) <= grouping_loads(&l, &row) + 10);
    }

    /// Undo must restore both the groups and the cached eval exactly.
    #[test]
    fn moves_undo_cleanly() {
        let l = ConvLayer::square(1, 6, 3, 1);
        let groups = normalize(&strategy::row_by_row(&l, 2).groups, 2, 8);
        let mut state = State::new(&l, groups.clone());
        let mut rng = Rng::new(99);
        let cost0 = state.cost();
        for _ in 0..500 {
            let undo = match rng.below(4) {
                0 => state.relocate(&l, &mut rng, 2),
                1 => state.swap_patches(&l, &mut rng),
                2 => state.swap_groups(&l, &mut rng),
                _ => state.reverse_segment(&l, &mut rng),
            };
            if let Some(u) = undo {
                state.apply_undo(&l, u);
                assert_eq!(state.groups, groups, "undo must restore groups");
                assert_eq!(state.cost(), cost0);
            }
        }
    }
}

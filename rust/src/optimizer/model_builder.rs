//! The §5 ILP model, built verbatim over the [`crate::ilp`] substrate.
//!
//! Variables (Table 1):
//! * `P_g[i,k]` — patch `i` assigned to group `k` (Eq. 2);
//! * `pxl_g[j,k]` — pixel `j` present in group `k` (Eq. 5, induced via the
//!   OR of Eq. 6);
//! * `pxl_ovlp[j,k]` — pixel `j` in groups `k` and `k−1` (Eq. 7, AND);
//! * `pxl_I[j,k]` — pixel `j` loaded at step `k` (Eq. 8, `∧¬`).
//!
//! Constraints: assignment (Eq. 3), group capacity (Eq. 4), reload bound
//! (Eq. 9), and on-chip-memory capacity (Eq. 12). Objective: Eq. 15 —
//! minimize `t_l·Σ size(I_slice^k)` (the `n·t_acc` term is constant because
//! the paper fixes the group count to `K_min`, §7.1).
//!
//! As in the paper (Remark 6), pixels are 2D spatial: the channel dimension
//! multiplies sizes but never splits, so `pxl_*` variables range over
//! `H_in × W_in` and element counts scale by `C_in` in Eq. 12's terms.

use crate::conv::{ConvLayer, PatchId};
use crate::ilp::{
    linearize_and, linearize_and_not, linearize_or, BoolVar, Cmp, LinExpr, Model,
};
use crate::platform::Accelerator;
use crate::strategy::GroupedStrategy;

/// Handle mapping model variables back to the problem structure.
#[derive(Debug, Clone)]
pub struct S1ModelInfo {
    /// Number of patches `|X|`.
    pub n_patches: usize,
    /// Number of spatial input pixels.
    pub n_pixels: usize,
    /// Number of groups `k` the model schedules.
    pub k_groups: usize,
    /// `P_g[i][k]` variable ids.
    pub p_g: Vec<Vec<BoolVar>>,
    /// `pxl_g[j][k]`.
    pub pxl_g: Vec<Vec<BoolVar>>,
    /// `pxl_ovlp[j][k]` for `k ≥ 1` (index `k-1`).
    pub pxl_ovlp: Vec<Vec<BoolVar>>,
    /// `pxl_I[j][k]`.
    pub pxl_i: Vec<Vec<BoolVar>>,
}

/// Build the §5 model for `layer` on `acc` with `k_groups` groups and the
/// `nb_data_reload` bound (the paper fixes 2).
///
/// Model size is `K·(|X| + 3·H_in·W_in)` binaries (the paper's `N_var`
/// formula) — exact solves are reserved for small layers, as in the paper.
pub fn build_s1_model(
    layer: &ConvLayer,
    acc: &Accelerator,
    k_groups: usize,
    nb_data_reload: u32,
) -> (Model, S1ModelInfo) {
    let n = layer.n_patches();
    let npx = layer.n_pixels();
    let kk = k_groups;
    let group_cap = acc.max_patches_per_step(layer).max(1);

    let mut m = Model::minimize();

    // Variables.
    let p_g: Vec<Vec<BoolVar>> = (0..n)
        .map(|i| (0..kk).map(|k| m.bool_var(&format!("P_g[{i},{k}]"))).collect())
        .collect();
    let pxl_g: Vec<Vec<BoolVar>> = (0..npx)
        .map(|j| (0..kk).map(|k| m.bool_var(&format!("pxl_g[{j},{k}]"))).collect())
        .collect();
    let pxl_ovlp: Vec<Vec<BoolVar>> = (0..npx)
        .map(|j| {
            (1..kk)
                .map(|k| m.bool_var(&format!("pxl_ovlp[{j},{k}]")))
                .collect()
        })
        .collect();
    let pxl_i: Vec<Vec<BoolVar>> = (0..npx)
        .map(|j| (0..kk).map(|k| m.bool_var(&format!("pxl_I[{j},{k}]"))).collect())
        .collect();

    // pxl_in_P: patches containing each pixel (§5.1's constant set).
    let mut patches_of_pixel: Vec<Vec<usize>> = vec![Vec::new(); npx];
    for i in 0..n {
        for px in layer.patch_pixels(i as PatchId).iter() {
            patches_of_pixel[px as usize].push(i);
        }
    }

    // Eq. 3: each patch in exactly one group.
    for row in p_g.iter() {
        let mut e = LinExpr::new();
        for v in row {
            e.add(v.0, 1.0);
        }
        m.constrain(e, Cmp::Eq, 1.0);
    }

    // Eq. 4: group cardinality ≤ nb_patches_max_S1.
    for k in 0..kk {
        let mut e = LinExpr::new();
        for row in p_g.iter() {
            e.add(row[k].0, 1.0);
        }
        m.constrain(e, Cmp::Le, group_cap as f64);
    }

    // Eq. 6: pxl_g[j,k] = ∨_{i: j ∈ P_i} P_g[i,k].
    for j in 0..npx {
        for k in 0..kk {
            let inputs: Vec<BoolVar> =
                patches_of_pixel[j].iter().map(|&i| p_g[i][k]).collect();
            if inputs.is_empty() {
                // pixel in no patch (possible with stride > 1): force 0
                m.constrain(LinExpr::term(pxl_g[j][k].0, 1.0), Cmp::Eq, 0.0);
            } else {
                linearize_or(&mut m, pxl_g[j][k], &inputs);
            }
        }
    }

    // Eq. 7: pxl_ovlp[j,k] = pxl_g[j,k] ∧ pxl_g[j,k−1] (k ≥ 1).
    for j in 0..npx {
        for k in 1..kk {
            linearize_and(&mut m, pxl_ovlp[j][k - 1], pxl_g[j][k], pxl_g[j][k - 1]);
        }
    }

    // Eq. 8: pxl_I[j,k] = pxl_g[j,k] ∧ ¬pxl_ovlp[j,k]; for k = 0 the overlap
    // is identically 0, so pxl_I[j,0] = pxl_g[j,0].
    for j in 0..npx {
        let mut eq0 = LinExpr::new();
        eq0.add(pxl_i[j][0].0, 1.0);
        eq0.add(pxl_g[j][0].0, -1.0);
        m.constrain(eq0, Cmp::Eq, 0.0);
        for k in 1..kk {
            linearize_and_not(&mut m, pxl_i[j][k], pxl_g[j][k], pxl_ovlp[j][k - 1]);
        }
    }

    // Eq. 9: Σ_k pxl_I[j,k] ≤ nb_data_reload.
    for row in pxl_i.iter() {
        let mut e = LinExpr::new();
        for v in row {
            e.add(v.0, 1.0);
        }
        m.constrain(e, Cmp::Le, nb_data_reload as f64);
    }

    // Eq. 12: C_in·size_group_k + C_out·C_in·H_K·W_K + C_out·Σ_i P_g[i,k]
    //         ≤ size_MEM   (element counts; Remark 6's channel scaling).
    let kernel_elems = layer.kernel_elements() as f64;
    for k in 0..kk {
        let mut e = LinExpr::new();
        for pxl_row in pxl_g.iter() {
            e.add(pxl_row[k].0, layer.c_in as f64);
        }
        for row in p_g.iter() {
            e.add(row[k].0, layer.c_out() as f64);
        }
        m.constrain(e, Cmp::Le, acc.size_mem as f64 - kernel_elems);
    }

    // Eq. 15 objective: minimize Σ_{j,k} pxl_I[j,k] (scaled by t_l·C_in for
    // a faithful cycle count; the argmin is unchanged).
    let mut obj = LinExpr::new();
    for row in pxl_i.iter() {
        for v in row {
            obj.add(v.0, (acc.t_l * layer.c_in as u64) as f64);
        }
    }
    m.set_objective(obj);

    let info = S1ModelInfo {
        n_patches: n,
        n_pixels: npx,
        k_groups: kk,
        p_g,
        pxl_g,
        pxl_ovlp,
        pxl_i,
    };
    (m, info)
}

/// Decode a MILP assignment back into a strategy (groups ordered by `k`,
/// empty groups dropped).
pub fn decode_solution(info: &S1ModelInfo, assignment: &[f64]) -> GroupedStrategy {
    let mut groups: Vec<Vec<PatchId>> = vec![Vec::new(); info.k_groups];
    for i in 0..info.n_patches {
        for (k, group) in groups.iter_mut().enumerate() {
            if assignment[info.p_g[i][k].0 .0] > 0.5 {
                group.push(i as PatchId);
            }
        }
    }
    groups.retain(|g| !g.is_empty());
    GroupedStrategy::new("opl-ilp", groups)
}

/// Encode a grouping as a full MIP-start assignment for the model
/// (inverse of [`decode_solution`]; derived variables are set consistently).
pub fn encode_mip_start(
    layer: &ConvLayer,
    info: &S1ModelInfo,
    groups: &[Vec<PatchId>],
    n_model_vars: usize,
) -> Vec<f64> {
    assert!(groups.len() <= info.k_groups);
    let mut x = vec![0f64; n_model_vars];
    // P_g
    for (k, group) in groups.iter().enumerate() {
        for &p in group {
            x[info.p_g[p as usize][k].0 .0] = 1.0;
        }
    }
    // pxl_g from footprints
    let mut in_group = vec![vec![false; info.k_groups]; info.n_pixels];
    for (k, group) in groups.iter().enumerate() {
        for px in layer.group_pixels(group).iter() {
            x[info.pxl_g[px as usize][k].0 .0] = 1.0;
            in_group[px as usize][k] = true;
        }
    }
    // pxl_ovlp, pxl_I
    for j in 0..info.n_pixels {
        for k in 0..groups.len() {
            let g = in_group[j][k];
            let ovlp = k >= 1 && g && in_group[j][k - 1];
            if k >= 1 && ovlp {
                x[info.pxl_ovlp[j][k - 1].0 .0] = 1.0;
            }
            if g && !ovlp {
                x[info.pxl_i[j][k].0 .0] = 1.0;
            }
        }
    }
    x
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optimizer::objective::grouping_loads;
    use crate::solver::{solve_milp, BranchBoundOptions};
    use crate::strategy;

    fn tiny_layer() -> ConvLayer {
        // 4x4 input, 3x3 kernel → 4 patches, 16 pixels
        ConvLayer::square(1, 4, 3, 1)
    }

    #[test]
    fn model_dimensions_match_paper_formula() {
        let l = tiny_layer();
        let acc = Accelerator::for_group_size(&l, 2);
        let k = 2;
        let (m, info) = build_s1_model(&l, &acc, k, 2);
        // N_var = K·(3·(H_in·W_in) + H_out·W_out); pxl_ovlp only exists for
        // k ≥ 1, so ours is smaller by H_in·W_in.
        let paper_nvar = k * (3 * l.n_pixels() + l.n_patches());
        assert_eq!(m.n_vars(), paper_nvar - l.n_pixels());
        assert_eq!(info.p_g.len(), 4);
        assert_eq!(info.pxl_g.len(), 16);
    }

    #[test]
    fn heuristic_encoding_is_feasible_and_scores_correctly() {
        let l = tiny_layer();
        let acc = Accelerator::for_group_size(&l, 2);
        let (m, info) = build_s1_model(&l, &acc, 2, 4);
        let s = strategy::row_by_row(&l, 2);
        let x = encode_mip_start(&l, &info, &s.groups, m.n_vars());
        assert!(m.is_feasible(&x, 1e-9), "heuristic must satisfy the model");
        // objective = t_l·C_in·loads
        let loads = grouping_loads(&l, &s.groups) as f64;
        assert!((m.objective_value(&x) - loads).abs() < 1e-9);
    }

    #[test]
    fn milp_optimum_matches_exact_search() {
        let l = tiny_layer();
        let acc = Accelerator::for_group_size(&l, 2);
        let k = 2;
        let (m, info) = build_s1_model(&l, &acc, k, 4);
        let start = strategy::row_by_row(&l, 2);
        let x0 = encode_mip_start(&l, &info, &start.groups, m.n_vars());
        let opts = BranchBoundOptions {
            mip_start: Some(x0),
            time_budget: std::time::Duration::from_secs(120),
            node_budget: 2_000_000,
            ..Default::default()
        };
        let sol = solve_milp(&m, &opts);
        assert_eq!(sol.status, crate::ilp::SolveStatus::Optimal);
        let ilp_strategy = decode_solution(&info, &sol.assignment);
        let ilp_loads = grouping_loads(&l, &ilp_strategy.groups);
        // cross-validate against the specialized exact engine
        let exact = crate::optimizer::exact::solve_exact(
            &l,
            2,
            k,
            std::time::Duration::from_secs(60),
            None,
        )
        .expect("exact must finish on 4 patches");
        let exact_loads = grouping_loads(&l, &exact);
        assert_eq!(ilp_loads, exact_loads, "ILP {ilp_strategy:?} vs exact {exact:?}");
        // objective value consistent with decoded loads
        assert!((sol.objective - ilp_loads as f64).abs() < 1e-6);
    }

    #[test]
    fn reload_bound_infeasible_when_too_tight() {
        // With nb_data_reload = 0 no pixel may ever be loaded → infeasible.
        let l = tiny_layer();
        let acc = Accelerator::for_group_size(&l, 2);
        let (m, _) = build_s1_model(&l, &acc, 2, 0);
        let sol = solve_milp(&m, &BranchBoundOptions::default());
        assert_eq!(sol.status, crate::ilp::SolveStatus::Infeasible);
    }

    #[test]
    fn memory_constraint_binds() {
        // Shrink size_MEM below one group's needs → infeasible.
        let l = tiny_layer();
        let mut acc = Accelerator::for_group_size(&l, 2);
        acc.size_mem = l.kernel_elements() as u64 + 3; // can't fit any patch
        let (m, _) = build_s1_model(&l, &acc, 2, 4);
        let sol = solve_milp(&m, &BranchBoundOptions::default());
        assert_eq!(sol.status, crate::ilp::SolveStatus::Infeasible);
    }
}

//! The §5 optimization problem and its solvers.
//!
//! Three cooperating engines, mirroring the paper's CPLEX pipeline:
//!
//! * [`model_builder`] — builds the **exact §5 ILP** (Eqs. 2–15, with the
//!   Table-1 variables `P_g`, `pxl_g`, `pxl_ovlp`, `pxl_I`) over the generic
//!   [`crate::ilp`] substrate, and decodes a MILP solution back into a
//!   [`GroupedStrategy`]. Solvable exactly for small layers; used to validate
//!   the encodings and the search engines against proven optima.
//! * [`exact`] — a specialized branch & bound over ordered patch partitions
//!   with an admissible load-lower-bound; exact for mid-size instances
//!   (≈ ≤ 16 patches) at a fraction of the generic solver's cost.
//! * [`search`] — simulated-annealing local search over groupings, seeded
//!   with the best heuristic (the paper's *MIP start*) and playing the role
//!   of CPLEX's *solution polishing* genetic phase for large instances.
//!   Moves are delta-evaluated through [`objective::GroupingEval`]'s
//!   propose-score-commit protocol (rejected moves are free), and the
//!   greedy construction scores candidates over the sparse patch-overlap
//!   graph ([`overlap`]) instead of full pixel-set intersections.
//!
//! [`Optimizer`] is the facade the CLI/figure harness uses: it picks the
//! strongest engine the instance size affords, exactly like the paper's
//! timeout-guarded OPL runs.

pub mod exact;
pub mod makespan;
pub mod model_builder;
pub mod objective;
pub mod overlap;
pub mod search;

pub use makespan::MakespanEval;
pub use model_builder::{build_s1_model, decode_solution, S1ModelInfo};
pub use objective::{
    grouping_duration, grouping_loads, grouping_makespan, GroupEdit, GroupingEval,
    StagedEffect,
};
pub use overlap::OverlapGraph;
pub use search::AnnealOptions;

use std::time::Duration;

use crate::conv::ConvLayer;
use crate::platform::{Accelerator, OverlapMode};
use crate::strategy::{self, GroupedStrategy};

/// The robust-objective hook: the accelerator a plan must survive on after
/// a `MemoryShrink` fault removed `shrink_elements` elements of `size_MEM`.
///
/// The budget is floored at the §7.1 working set of a **single-patch** step
/// (`Accelerator::for_group_size(layer, 1).size_mem`): below that no
/// strategy for the layer is executable at all, so degraded-mode replanning
/// would be vacuous — the platform, not the plan, is broken.
pub fn degraded_accelerator(
    layer: &ConvLayer,
    acc: &Accelerator,
    shrink_elements: u64,
) -> Accelerator {
    let floor = Accelerator::for_group_size(layer, 1).size_mem;
    Accelerator {
        size_mem: acc.size_mem.saturating_sub(shrink_elements).max(floor),
        ..*acc
    }
}

/// Which engine produced the result.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Method {
    /// Generic MILP on the §5 model, proven optimal.
    IlpOptimal,
    /// Generic MILP, incumbent only (budget hit).
    IlpFeasible,
    /// Specialized exact branch & bound, proven optimal.
    Exact,
    /// Annealing polish from the heuristic MIP start.
    Polished,
}

/// Options for [`Optimizer`].
#[derive(Debug, Clone)]
pub struct OptimizeOptions {
    /// Group-size bound `nb_patches_max_S1`.
    pub group_size: usize,
    /// Number of groups; `None` = `K_min` (the paper's §7.1 choice).
    pub k_groups: Option<usize>,
    /// RNG seed for the polish phase (results are deterministic per seed).
    pub seed: u64,
    /// Annealing iteration budget.
    pub anneal_iters: u64,
    /// Use the specialized exact engine when `|X|` is at most this.
    pub exact_max_patches: usize,
    /// Wall-clock budget for the exact engine (falls back to polish).
    pub exact_budget: Duration,
    /// Probability of steering an annealing proposal along the sparse
    /// patch-overlap graph ([`search::AnnealOptions::neighbor_bias`]).
    /// Any value > 0 changes the per-seed trajectory; the default 0.0
    /// keeps results bit-identical to earlier releases. **Sequential
    /// objective only** — [`search::anneal_duration`] has no graph-guided
    /// proposal path, so the knob is inert under
    /// [`OverlapMode::DoubleBuffered`] (the CLI rejects the combination).
    pub neighbor_bias: f64,
}

impl Default for OptimizeOptions {
    fn default() -> Self {
        OptimizeOptions {
            group_size: 4,
            k_groups: None,
            seed: 0xA11CE,
            anneal_iters: 200_000,
            exact_max_patches: 12,
            exact_budget: Duration::from_secs(10),
            neighbor_bias: 0.0,
        }
    }
}

/// Result of an optimization run.
#[derive(Debug, Clone)]
pub struct OptimizeResult {
    /// The optimized strategy.
    pub strategy: GroupedStrategy,
    /// Strategy duration in cycles under the §7.1 cost model.
    pub duration: u64,
    /// Which engine produced it.
    pub method: Method,
    /// Duration of the best heuristic MIP start, for gain reporting.
    pub mip_start_duration: u64,
}

impl OptimizeResult {
    /// Performance gain over the best heuristic (Fig. 13's metric):
    /// `(best_heuristic − ours) / best_heuristic`.
    pub fn gain_over_heuristics(&self) -> f64 {
        if self.mip_start_duration == 0 {
            return 0.0;
        }
        (self.mip_start_duration as f64 - self.duration as f64)
            / self.mip_start_duration as f64
    }
}

/// The in-tree heuristic pool: the four patch orderings plus the greedy
/// construction, in a fixed order (Row-by-Row, ZigZag, Hilbert, diagonal,
/// greedy). [`Optimizer::optimize`]'s seed phase draws from it, and the
/// network planner's portfolio race enumerates the same five candidates as
/// its heuristic lanes — that equivalence is pinned by
/// `planner::portfolio`'s `first_lanes_match_the_optimizer_heuristic_pool`
/// test, so extending or reordering this pool fails loudly rather than
/// silently diverging the two.
pub fn heuristic_pool(layer: &ConvLayer, g: usize, k: usize) -> Vec<GroupedStrategy> {
    vec![
        strategy::row_by_row(layer, g),
        strategy::zigzag(layer, g),
        strategy::hilbert(layer, g),
        strategy::diagonal(layer, g),
        GroupedStrategy::new("greedy", search::greedy(layer, g, k)),
    ]
}

/// Facade: optimal-strategy search for a layer on an accelerator.
#[derive(Debug, Clone)]
pub struct Optimizer {
    /// Engine selection and search budgets.
    pub options: OptimizeOptions,
}

impl Optimizer {
    /// An optimizer with the given options.
    pub fn new(options: OptimizeOptions) -> Self {
        Optimizer { options }
    }

    /// Run the pipeline: heuristics → (exact | polish).
    ///
    /// The duration metric follows the accelerator's [`OverlapMode`]:
    /// sequential machines optimize the Definition-3 sum (Eq. 15's
    /// objective), double-buffered machines the §3.7 two-resource makespan
    /// — in the latter case the polish phase runs the duration-domain
    /// annealer ([`search::anneal_duration`]) and the exact engine is
    /// skipped (its admissible bound is a loaded-pixels bound, which proves
    /// nothing about makespans).
    pub fn optimize(&self, layer: &ConvLayer, acc: &Accelerator) -> OptimizeResult {
        let o = &self.options;
        let g = o.group_size.max(1);
        let k = o
            .k_groups
            .unwrap_or_else(|| layer.n_patches().div_ceil(g))
            .clamp(layer.n_patches().div_ceil(g), layer.n_patches());
        let overlapped = acc.overlap == OverlapMode::DoubleBuffered;
        let dur = |groups: &[Vec<crate::conv::PatchId>]| -> u64 {
            if overlapped {
                grouping_makespan(layer, acc, groups)
            } else {
                grouping_duration(layer, acc, groups)
            }
        };

        // The shared heuristic pool: Row-by-Row, ZigZag, Hilbert, diagonal,
        // greedy (in that order; see `heuristic_pool`).
        let evaluated: Vec<(GroupedStrategy, u64)> = heuristic_pool(layer, g, k)
            .into_iter()
            .map(|s| {
                let d = dur(&s.groups);
                (s, d)
            })
            .collect();

        // MIP start: best of Row-by-Row / ZigZag (the paper injects "either
        // the ZigZag or Row-by-Row strategy, depending on which was best for
        // the given convolution parameters"). Selected by name so the pool
        // can grow or reorder without silently changing the paper-faithful
        // gain denominator.
        let (mip_start, mip_dur) = evaluated
            .iter()
            .filter(|(s, _)| {
                s.name.starts_with("row-by-row") || s.name.starts_with("zigzag")
            })
            .map(|(s, d)| (s.clone(), *d))
            .min_by_key(|&(_, d)| d)
            .expect("pool contains the paper heuristics");

        // Seed pool for the polish phase: best of *all* in-tree heuristics
        // (the extension orderings + greedy construction can only improve
        // the optimized strategy; the Fig.-13 gain denominator stays the
        // paper-faithful `mip_dur` above).
        let (seed, _) = evaluated
            .into_iter()
            .min_by_key(|&(_, d)| d)
            .expect("at least one seed");

        // Exact engine for small instances (sequential objective only —
        // its lower bound is a loaded-pixels bound).
        if !overlapped && layer.n_patches() <= o.exact_max_patches {
            if let Some(groups) =
                exact::solve_exact(layer, g, k, o.exact_budget, Some(&seed.groups))
            {
                let duration = dur(&groups);
                let mut strategy = GroupedStrategy::new("opl-exact", groups);
                strategy.writeback = mip_start.writeback;
                return OptimizeResult {
                    duration,
                    strategy,
                    method: Method::Exact,
                    mip_start_duration: mip_dur,
                };
            }
        }

        // Polish phase (the paper's solution-polishing analogue), in the
        // metric the accelerator actually executes.
        let groups = if overlapped {
            search::anneal_duration(layer, acc, g, k, &seed.groups, o.anneal_iters, o.seed)
        } else {
            search::anneal_with(
                layer,
                g,
                k,
                &seed.groups,
                o.anneal_iters,
                o.seed,
                &search::AnnealOptions { neighbor_bias: o.neighbor_bias },
            )
        };
        let duration = dur(&groups);
        let mut strategy = GroupedStrategy::new("opl-polished", groups);
        strategy.writeback = mip_start.writeback;
        // Never return something worse than the best seed / MIP start.
        let seed_dur = dur(&seed.groups);
        if duration > seed_dur.min(mip_dur) {
            let (best, best_dur) =
                if seed_dur <= mip_dur { (seed, seed_dur) } else { (mip_start, mip_dur) };
            return OptimizeResult {
                strategy: best,
                duration: best_dur,
                method: Method::Polished,
                mip_start_duration: mip_dur,
            };
        }
        OptimizeResult {
            duration,
            strategy,
            method: Method::Polished,
            mip_start_duration: mip_dur,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The degraded accelerator shrinks only `size_MEM`, saturates instead
    /// of wrapping, and never drops below the single-patch working set.
    #[test]
    fn degraded_accelerator_shrinks_and_floors() {
        let l = ConvLayer::square(2, 6, 3, 2);
        let acc = Accelerator::for_group_size(&l, 4);
        let floor = Accelerator::for_group_size(&l, 1).size_mem;
        let d = degraded_accelerator(&l, &acc, 10);
        assert_eq!(d.size_mem, acc.size_mem - 10);
        assert_eq!(
            (d.nbop_pe, d.t_acc, d.t_l, d.t_w, d.overlap),
            (acc.nbop_pe, acc.t_acc, acc.t_l, acc.t_w, acc.overlap),
            "only the memory budget degrades"
        );
        assert_eq!(degraded_accelerator(&l, &acc, 0).size_mem, acc.size_mem);
        assert_eq!(degraded_accelerator(&l, &acc, u64::MAX).size_mem, floor);
        assert!(degraded_accelerator(&l, &acc, acc.size_mem).size_mem >= floor);
    }

    #[test]
    fn optimizer_never_worse_than_heuristics() {
        for h in [6usize, 8] {
            let l = ConvLayer::square(1, h, 3, 1);
            for g in [2usize, 3, 4] {
                let acc = Accelerator::for_group_size(&l, g);
                let opt = Optimizer::new(OptimizeOptions {
                    group_size: g,
                    anneal_iters: 20_000,
                    ..Default::default()
                });
                let res = opt.optimize(&l, &acc);
                assert!(res.gain_over_heuristics() >= 0.0);
                assert!(res.duration <= res.mip_start_duration);
                // strategy covers all patches exactly once
                let mut all: Vec<u32> =
                    res.strategy.groups.iter().flatten().copied().collect();
                all.sort();
                assert_eq!(all, l.all_patches().collect::<Vec<_>>());
            }
        }
    }

    /// Double-buffered accelerators switch the optimizer to the makespan
    /// metric: the result is scored by `grouping_makespan`, never worse
    /// than the heuristics in that metric, and the exact engine is skipped
    /// even on small instances (its bound only proves loaded pixels).
    #[test]
    fn optimizer_double_buffered_uses_the_makespan_metric() {
        for l in [ConvLayer::square(1, 5, 3, 1), ConvLayer::square(1, 8, 3, 1)] {
            let acc = Accelerator {
                t_acc: 4,
                ..Accelerator::for_group_size(&l, 2)
            }
            .with_overlap(OverlapMode::DoubleBuffered);
            let opt = Optimizer::new(OptimizeOptions {
                group_size: 2,
                anneal_iters: 10_000,
                ..Default::default()
            });
            let res = opt.optimize(&l, &acc);
            assert_eq!(res.method, Method::Polished, "exact engine must be skipped");
            assert!(res.duration <= res.mip_start_duration);
            assert_eq!(
                res.duration,
                grouping_makespan(&l, &acc, &res.strategy.groups),
                "result scored in the makespan metric"
            );
            let mut all: Vec<u32> =
                res.strategy.groups.iter().flatten().copied().collect();
            all.sort();
            assert_eq!(all, l.all_patches().collect::<Vec<_>>());
        }
    }

    #[test]
    fn exact_engine_used_for_small_instances() {
        let l = ConvLayer::square(1, 5, 3, 1); // 9 patches
        let acc = Accelerator::for_group_size(&l, 2);
        let opt = Optimizer::new(OptimizeOptions {
            group_size: 2,
            ..Default::default()
        });
        let res = opt.optimize(&l, &acc);
        assert_eq!(res.method, Method::Exact);
    }
}

//! Figure 12: duration vs input size (group size 4) for the OPL strategy,
//! ZigZag, Row-by-Row and S1-baseline.
//!
//! Paper claim reproduced: the solver's strategy minimizes δ at least as
//! well as every heuristic at every input size, and S1-baseline (one patch
//! per step) is far worse than all grouped strategies.

use crate::config::presets::paper_sweep_layer;
use crate::optimizer::{grouping_duration, OptimizeOptions, Optimizer};
use crate::platform::Accelerator;
use crate::strategy;
use crate::util::csv;

/// One sweep point (all durations in cycles, §7.1 cost model).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Fig12Row {
    /// Square input size `H_in = W_in` of this row.
    pub h_in: usize,
    /// Duration of the S1 baseline (one patch per step).
    pub s1_baseline: u64,
    /// Duration of the Row-by-Row strategy.
    pub row_by_row: u64,
    /// Duration of the ZigZag strategy.
    pub zigzag: u64,
    /// Duration of the optimized (OPL) strategy.
    pub opl: u64,
}

/// Sweep the §7.1 square layers (`H_in = W_in ∈ input_sizes`) at a fixed
/// group size (the paper's Fig. 12 uses 4). Points run in parallel.
pub fn fig12(input_sizes: &[usize], group: usize, seed: u64) -> Vec<Fig12Row> {
    crate::util::pool::parallel_map(
        input_sizes,
        crate::util::pool::default_threads(),
        |&h| {
            let layer = paper_sweep_layer(h);
            let acc = Accelerator::for_group_size(&layer, group);
            let baseline = strategy::s1_baseline(&layer);
            let row = strategy::row_by_row(&layer, group);
            let zig = strategy::zigzag(&layer, group);
            let opt = Optimizer::new(OptimizeOptions {
                group_size: group,
                seed,
                ..Default::default()
            });
            let res = opt.optimize(&layer, &acc);
            Fig12Row {
                h_in: h,
                s1_baseline: grouping_duration(&layer, &acc, &baseline.groups),
                row_by_row: grouping_duration(&layer, &acc, &row.groups),
                zigzag: grouping_duration(&layer, &acc, &zig.groups),
                opl: res.duration,
            }
        },
    )
}

/// CSV serialization.
pub fn to_csv(rows: &[Fig12Row]) -> String {
    let mut out = vec![vec![
        "h_in".to_string(),
        "s1_baseline".to_string(),
        "row_by_row".to_string(),
        "zigzag".to_string(),
        "opl".to_string(),
    ]];
    for r in rows {
        out.push(vec![
            r.h_in.to_string(),
            r.s1_baseline.to_string(),
            r.row_by_row.to_string(),
            r.zigzag.to_string(),
            r.opl.to_string(),
        ]);
    }
    csv::write(&out)
}

/// ASCII rendering.
pub fn to_ascii(group: usize, rows: &[Fig12Row]) -> String {
    let xs: Vec<u64> = rows.iter().map(|r| r.h_in as u64).collect();
    let series = vec![
        ("s1-baseline", rows.iter().map(|r| r.s1_baseline).collect::<Vec<_>>()),
        ("row-by-row", rows.iter().map(|r| r.row_by_row).collect()),
        ("zigzag", rows.iter().map(|r| r.zigzag).collect()),
        ("opl", rows.iter().map(|r| r.opl).collect()),
    ];
    crate::bench_harness::plot::line_chart(
        &format!("Fig 12 — duration δ vs input size (group size {group})"),
        "H_in = W_in",
        &xs,
        &series,
        16,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn opl_dominates_heuristics_and_baseline() {
        // small slice of the paper grid to keep test time in check
        let rows = fig12(&[4, 5, 6, 7], 4, 1);
        for r in &rows {
            assert!(r.opl <= r.row_by_row, "h={}: {:?}", r.h_in, r);
            assert!(r.opl <= r.zigzag, "h={}: {:?}", r.h_in, r);
            assert!(
                r.s1_baseline > r.opl,
                "baseline must be worst, h={}: {:?}",
                r.h_in,
                r
            );
        }
        // durations grow with input size for every series
        for w in rows.windows(2) {
            assert!(w[1].opl >= w[0].opl);
            assert!(w[1].s1_baseline > w[0].s1_baseline);
        }
    }

    #[test]
    fn csv_has_all_series() {
        let rows = fig12(&[4, 5], 4, 1);
        let text = to_csv(&rows);
        let parsed = crate::util::csv::parse(&text).unwrap();
        assert_eq!(parsed[0].len(), 5);
        assert_eq!(parsed.len(), 3);
    }
}

//! Figure 11: ZigZag vs Row-by-Row duration across group sizes.
//!
//! Paper claims reproduced here (§7.2):
//! * both curves share the same overall shape;
//! * ZigZag wins for small group sizes, Row-by-Row after a crossover;
//! * the two are identical when the group size is a multiple of `W_out`.

use crate::conv::ConvLayer;
use crate::optimizer::grouping_duration;
use crate::platform::Accelerator;
use crate::strategy;
use crate::util::csv;

/// One sweep point.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Fig11Row {
    /// Group size `nb_patches_max_S1` of this row.
    pub group_size: usize,
    /// Loaded elements under the ZigZag strategy.
    pub zigzag: u64,
    /// Loaded elements under the Row-by-Row strategy.
    pub row_by_row: u64,
}

/// Sweep group sizes on a layer (default: LeNet-5 conv1, like the paper).
pub fn fig11(layer: &ConvLayer, group_sizes: &[usize]) -> Vec<Fig11Row> {
    group_sizes
        .iter()
        .map(|&g| {
            let acc = Accelerator::for_group_size(layer, g);
            let zig = strategy::zigzag(layer, g);
            let row = strategy::row_by_row(layer, g);
            Fig11Row {
                group_size: g,
                zigzag: grouping_duration(layer, &acc, &zig.groups),
                row_by_row: grouping_duration(layer, &acc, &row.groups),
            }
        })
        .collect()
}

/// CSV serialization (`group_size,zigzag,row_by_row`).
pub fn to_csv(rows: &[Fig11Row]) -> String {
    let mut out = vec![vec![
        "group_size".to_string(),
        "zigzag".to_string(),
        "row_by_row".to_string(),
    ]];
    for r in rows {
        out.push(vec![
            r.group_size.to_string(),
            r.zigzag.to_string(),
            r.row_by_row.to_string(),
        ]);
    }
    csv::write(&out)
}

/// ASCII rendering.
pub fn to_ascii(layer: &ConvLayer, rows: &[Fig11Row]) -> String {
    let xs: Vec<u64> = rows.iter().map(|r| r.group_size as u64).collect();
    let series = vec![
        ("zigzag", rows.iter().map(|r| r.zigzag).collect::<Vec<_>>()),
        ("row-by-row", rows.iter().map(|r| r.row_by_row).collect()),
    ];
    crate::bench_harness::plot::line_chart(
        &format!("Fig 11 — duration δ vs group size ({layer})"),
        "group size",
        &xs,
        &series,
        16,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;

    /// The paper's qualitative claims on the LeNet-5 first layer.
    #[test]
    fn lenet1_shape_claims() {
        let layer = presets::layer_preset("lenet5-conv1").unwrap().layer;
        let w_out = layer.w_out(); // 28
        let sizes: Vec<usize> = (1..=w_out + 4).collect();
        let rows = fig11(&layer, &sizes);

        // (1) ZigZag strictly better somewhere in the small-group regime.
        assert!(
            rows.iter()
                .take(w_out / 2)
                .any(|r| r.zigzag < r.row_by_row),
            "zigzag should win for small groups"
        );
        // (2) identical whenever group size is a multiple of W_out
        for r in &rows {
            if r.group_size % w_out == 0 {
                assert_eq!(r.zigzag, r.row_by_row, "g={}", r.group_size);
            }
        }
        // (3) monotonically *decreasing overall trend* as groups grow
        // (larger groups load fewer redundant halos): compare endpoints.
        assert!(rows.last().unwrap().zigzag < rows[0].zigzag);
        assert!(rows.last().unwrap().row_by_row < rows[0].row_by_row);
    }

    #[test]
    fn csv_roundtrip_shape() {
        let layer = ConvLayer::square(1, 6, 3, 1);
        let rows = fig11(&layer, &[1, 2, 4]);
        let text = to_csv(&rows);
        let parsed = crate::util::csv::parse(&text).unwrap();
        assert_eq!(parsed.len(), 4); // header + 3
        assert_eq!(parsed[0][0], "group_size");
    }

    #[test]
    fn ascii_contains_series() {
        let layer = ConvLayer::square(1, 6, 3, 1);
        let rows = fig11(&layer, &[1, 2, 3, 4]);
        let text = to_ascii(&layer, &rows);
        assert!(text.contains("zigzag"));
        assert!(text.contains("row-by-row"));
    }
}

//! Figure 13: performance gain of the OPL strategy over the best of
//! ZigZag/Row-by-Row, across the (input size × group size) grid.
//!
//! Paper claims reproduced:
//! * upper-right region (group size ≥ patches per image) → 0 % gain, the
//!   heuristics are already optimal because one/few groups hold everything;
//! * lower-left region → positive gains, up to ≈ 30 %.

use crate::config::presets::paper_sweep_layer;
use crate::optimizer::{OptimizeOptions, Optimizer};
use crate::platform::Accelerator;
use crate::util::csv;

/// One grid cell.
#[derive(Debug, Clone, PartialEq)]
pub struct Fig13Cell {
    /// Square input size `H_in = W_in` of this cell.
    pub h_in: usize,
    /// Group size of this cell.
    pub group: usize,
    /// Best heuristic duration (the gain denominator).
    pub best_heuristic: u64,
    /// Optimized (OPL) duration.
    pub opl: u64,
    /// Gain in percent: `(best_heuristic − opl) / best_heuristic · 100`.
    pub gain_pct: f64,
}

/// Sweep the paper grid: `H_in ∈ input_sizes`, group ∈ `groups`.
/// Grid cells are independent and run in parallel.
pub fn fig13(input_sizes: &[usize], groups: &[usize], seed: u64) -> Vec<Fig13Cell> {
    let grid: Vec<(usize, usize)> = input_sizes
        .iter()
        .flat_map(|&h| groups.iter().map(move |&g| (h, g)))
        .collect();
    crate::util::pool::parallel_map(
        &grid,
        crate::util::pool::default_threads(),
        |&(h, g)| {
            let layer = paper_sweep_layer(h);
            let acc = Accelerator::for_group_size(&layer, g);
            let opt = Optimizer::new(OptimizeOptions {
                group_size: g,
                seed,
                ..Default::default()
            });
            let res = opt.optimize(&layer, &acc);
            Fig13Cell {
                h_in: h,
                group: g,
                best_heuristic: res.mip_start_duration,
                opl: res.duration,
                gain_pct: res.gain_over_heuristics() * 100.0,
            }
        },
    )
}

/// CSV serialization (long form).
pub fn to_csv(cells: &[Fig13Cell]) -> String {
    let mut out = vec![vec![
        "h_in".to_string(),
        "group_size".to_string(),
        "best_heuristic".to_string(),
        "opl".to_string(),
        "gain_pct".to_string(),
    ]];
    for c in cells {
        out.push(vec![
            c.h_in.to_string(),
            c.group.to_string(),
            c.best_heuristic.to_string(),
            c.opl.to_string(),
            format!("{:.2}", c.gain_pct),
        ]);
    }
    csv::write(&out)
}

/// ASCII heatmap (rows = input size, cols = group size).
pub fn to_ascii(input_sizes: &[usize], groups: &[usize], cells: &[Fig13Cell]) -> String {
    let values: Vec<Vec<f64>> = input_sizes
        .iter()
        .map(|&h| {
            groups
                .iter()
                .map(|&g| {
                    cells
                        .iter()
                        .find(|c| c.h_in == h && c.group == g)
                        .map(|c| c.gain_pct)
                        .unwrap_or(f64::NAN)
                })
                .collect()
        })
        .collect();
    crate::bench_harness::plot::heatmap(
        "Fig 13 — OPL gain over best heuristic (%)",
        "H_in",
        "group size",
        &input_sizes.iter().map(|&x| x as u64).collect::<Vec<_>>(),
        &groups.iter().map(|&x| x as u64).collect::<Vec<_>>(),
        &values,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gains_nonnegative_and_regions_match_paper() {
        // Sub-grid for test speed; the full grid runs via the CLI.
        let inputs = [4usize, 6, 8];
        let groups = [2usize, 4, 8];
        let cells = fig13(&inputs, &groups, 3);
        assert_eq!(cells.len(), 9);
        for c in &cells {
            assert!(c.gain_pct >= 0.0, "{c:?}");
            assert!(c.opl <= c.best_heuristic);
        }
        // upper-right: group 8 on a 4x4 input (4 patches) → single group →
        // heuristics already optimal → 0 gain
        let ur = cells
            .iter()
            .find(|c| c.h_in == 4 && c.group == 8)
            .unwrap();
        assert_eq!(ur.gain_pct, 0.0);
        // lower-left: small groups on the bigger input should find gains
        let ll = cells
            .iter()
            .find(|c| c.h_in == 8 && c.group == 2)
            .unwrap();
        assert!(
            ll.gain_pct > 0.0,
            "expected positive gain in the lower-left region: {ll:?}"
        );
    }

    #[test]
    fn ascii_heatmap_renders_grid() {
        let inputs = [4usize, 5];
        let groups = [2usize, 3];
        let cells = fig13(&inputs, &groups, 3);
        let text = to_ascii(&inputs, &groups, &cells);
        assert!(text.contains("Fig 13"));
        assert!(text.lines().count() >= 6);
    }
}

//! Tiny ASCII plotting: line series and heatmaps for the figure outputs.

/// Render multiple y-series over a shared x-axis as an ASCII chart.
///
/// `series` = (label, ys); all series must be as long as `xs`.
pub fn line_chart(
    title: &str,
    x_label: &str,
    xs: &[u64],
    series: &[(&str, Vec<u64>)],
    height: usize,
) -> String {
    assert!(!xs.is_empty());
    for (label, ys) in series {
        assert_eq!(ys.len(), xs.len(), "series '{label}' length mismatch");
    }
    let y_max = series
        .iter()
        .flat_map(|(_, ys)| ys.iter().copied())
        .max()
        .unwrap_or(1)
        .max(1);
    let y_min = series
        .iter()
        .flat_map(|(_, ys)| ys.iter().copied())
        .min()
        .unwrap_or(0);
    let glyphs = ['*', 'o', '+', 'x', '#', '@'];

    let width = xs.len();
    let mut grid = vec![vec![' '; width]; height];
    for (si, (_, ys)) in series.iter().enumerate() {
        let glyph = glyphs[si % glyphs.len()];
        for (xi, &y) in ys.iter().enumerate() {
            let span = (y_max - y_min).max(1) as f64;
            let frac = (y - y_min) as f64 / span;
            let row = ((height - 1) as f64 * frac).round() as usize;
            let cell = &mut grid[height - 1 - row][xi];
            // overlapping points show the later series' glyph + a marker
            *cell = if *cell == ' ' { glyph } else { '●' };
        }
    }

    let mut out = String::new();
    out.push_str(&format!("## {title}\n"));
    for (si, (label, _)) in series.iter().enumerate() {
        out.push_str(&format!("   {} {}\n", glyphs[si % glyphs.len()], label));
    }
    out.push_str(&format!("   ● overlapping points\n y: {y_min}..{y_max}\n"));
    for row in grid {
        out.push_str(" |");
        out.extend(row);
        out.push('\n');
    }
    out.push_str(" +");
    out.push_str(&"-".repeat(width));
    out.push('\n');
    out.push_str(&format!(
        "  {x_label}: {}..{} ({} points)\n",
        xs[0],
        xs[xs.len() - 1],
        xs.len()
    ));
    out
}

/// Render a percentage heatmap (rows × cols) with labels.
pub fn heatmap(
    title: &str,
    row_label: &str,
    col_label: &str,
    row_keys: &[u64],
    col_keys: &[u64],
    values_pct: &[Vec<f64>],
) -> String {
    assert_eq!(values_pct.len(), row_keys.len());
    let mut out = String::new();
    out.push_str(&format!("## {title}\n"));
    out.push_str(&format!("   rows: {row_label}, cols: {col_label}, cell: gain %\n\n"));
    out.push_str("       ");
    for c in col_keys {
        out.push_str(&format!("{c:>6}"));
    }
    out.push('\n');
    for (r, row) in row_keys.iter().zip(values_pct) {
        assert_eq!(row.len(), col_keys.len());
        out.push_str(&format!("{r:>6} |"));
        for v in row {
            out.push_str(&format!("{v:>5.1} "));
        }
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn line_chart_renders() {
        let xs = vec![1, 2, 3, 4];
        let s = vec![("a", vec![1, 2, 3, 4]), ("b", vec![4, 3, 2, 1])];
        let chart = line_chart("t", "x", &xs, &s, 5);
        assert!(chart.contains("## t"));
        assert!(chart.contains("* a"));
        assert!(chart.contains("o b"));
        assert!(chart.lines().count() > 8);
    }

    #[test]
    fn heatmap_renders() {
        let h = heatmap(
            "gain",
            "input",
            "group",
            &[4, 5],
            &[2, 3],
            &[vec![0.0, 1.5], vec![30.0, 12.25]],
        );
        assert!(h.contains("## gain"));
        assert!(h.contains(" 30.0"));
        assert!(h.contains("     4 |"));
    }

    #[test]
    #[should_panic(expected = "length mismatch")]
    fn line_chart_validates_lengths() {
        line_chart("t", "x", &[1, 2], &[("a", vec![1])], 3);
    }
}

//! Experiment harness: regenerates every figure of the paper's evaluation
//! (§7) as CSV series + ASCII plots under `figures/`.
//!
//! | Paper artefact | Generator | Output |
//! |---|---|---|
//! | Fig. 11 — ZigZag vs Row-by-Row duration vs group size (LeNet-5 conv1) | [`fig11`] | `figures/fig11.csv`, `.txt` |
//! | Fig. 12 — duration vs input size, group 4: OPL / ZigZag / Row / S1-baseline | [`fig12`] | `figures/fig12.csv`, `.txt` |
//! | Fig. 13 — OPL gain over best heuristic across (input × group) grid | [`fig13`] | `figures/fig13.csv`, `.txt` |
//!
//! Durations use the paper's §7.1 cost model: `t_l = t_acc = 1`, writes
//! uncharged, kernels preloaded — `δ = Σ|I_slice| + n`.

pub mod fig11;
pub mod fig12;
pub mod fig13;
pub mod plot;

pub use fig11::{fig11, Fig11Row};
pub use fig12::{fig12, Fig12Row};
pub use fig13::{fig13, Fig13Cell};

use std::path::Path;

/// Write a CSV + companion ASCII plot into `dir`, creating it if needed.
pub fn write_outputs(
    dir: &Path,
    stem: &str,
    csv_text: &str,
    ascii: &str,
) -> std::io::Result<()> {
    std::fs::create_dir_all(dir)?;
    std::fs::write(dir.join(format!("{stem}.csv")), csv_text)?;
    std::fs::write(dir.join(format!("{stem}.txt")), ascii)?;
    Ok(())
}

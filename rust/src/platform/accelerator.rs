//! The accelerator parameters of §2.1, plus the execution-overlap mode.

use crate::conv::ConvLayer;

/// How the accelerator's DMA channel and compute unit share time.
///
/// The paper's Definition-3 duration model charges every step's loads,
/// writes and compute back to back ([`OverlapMode::Sequential`]). Real
/// accelerators hide transfer latency behind compute with double buffering:
/// step *n*'s input loads stream in while step *n−1* computes, provided the
/// on-chip memory can hold both working sets at once
/// ([`OverlapMode::DoubleBuffered`]; see `DESIGN.md` §3.7 for the
/// two-resource makespan recurrence and the serialization fallback).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum OverlapMode {
    /// Definition 3 verbatim: `δ(s_i) = |I|·t_l + |W|·t_w + t_acc`, summed.
    /// The default — every pre-overlap baseline is bit-stable under it.
    #[default]
    Sequential,
    /// Two-resource timeline (one DMA channel, one compute unit): a step's
    /// loads may prefetch during the previous step's compute when the
    /// double-buffer residency condition holds, and the reported duration is
    /// the critical-path makespan over both resources.
    DoubleBuffered,
}

impl OverlapMode {
    /// Stable CLI / serialization name (`sequential`, `double-buffered`).
    pub fn as_str(&self) -> &'static str {
        match self {
            OverlapMode::Sequential => "sequential",
            OverlapMode::DoubleBuffered => "double-buffered",
        }
    }

    /// Parse a CLI / config value (accepts `db` as shorthand).
    pub fn from_str(s: &str) -> Result<Self, String> {
        match s {
            "sequential" | "seq" => Ok(OverlapMode::Sequential),
            "double-buffered" | "double_buffered" | "db" => {
                Ok(OverlapMode::DoubleBuffered)
            }
            other => Err(format!(
                "unknown overlap mode '{other}' (sequential | double-buffered)"
            )),
        }
    }
}

/// Accelerator description:
///
/// * performs `nbop_pe` MAC operations per `t_acc` cycles;
/// * has an on-chip memory of `size_mem` elements;
/// * loads one element from DRAM in `t_l` cycles, writes one back in `t_w`;
/// * executes steps under an [`OverlapMode`] (sequential by default).
///
/// All sizes are unit-less element counts and all durations are accelerator
/// cycles, exactly as in the paper.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Accelerator {
    /// MAC operations available per compute action (`nbop_PE`).
    pub nbop_pe: u64,
    /// Cycles per compute action (`t_acc`).
    pub t_acc: u64,
    /// On-chip memory size in elements (`size_MEM`).
    pub size_mem: u64,
    /// Cycles to load one element DRAM → on-chip (`t_l`).
    pub t_l: u64,
    /// Cycles to write one element on-chip → DRAM (`t_w`).
    pub t_w: u64,
    /// DMA/compute overlap semantics (`Sequential` reproduces Definition 3).
    pub overlap: OverlapMode,
    /// Number of DMA channels available to the overlap timeline (k ≥ 1;
    /// 1 reproduces the §3.7 recurrence bit-exactly).
    pub dma_channels: usize,
    /// Number of compute units available to the overlap timeline (m ≥ 1;
    /// extra units only pay off across batched images — within one image
    /// the steps form a dependency chain).
    pub compute_units: usize,
}

impl Accelerator {
    /// The §7.1 experimental configuration: `t_l = t_acc = 1` and writes not
    /// charged (the objective of Eq. 15 counts only input loads + steps).
    pub fn paper_eval(nbop_pe: u64, size_mem: u64) -> Self {
        Accelerator {
            nbop_pe,
            t_acc: 1,
            size_mem,
            t_l: 1,
            t_w: 0,
            overlap: OverlapMode::Sequential,
            dma_channels: 1,
            compute_units: 1,
        }
    }

    /// The same machine with a different [`OverlapMode`] (builder-style).
    pub fn with_overlap(self, overlap: OverlapMode) -> Self {
        Accelerator { overlap, ..self }
    }

    /// The same machine with a different resource shape (builder-style):
    /// `dma_channels` × `compute_units`, each clamped to ≥ 1.
    pub fn with_channels(self, dma_channels: usize, compute_units: usize) -> Self {
        Accelerator {
            dma_channels: dma_channels.max(1),
            compute_units: compute_units.max(1),
            ..self
        }
    }

    /// Maximum number of S1 patches processable in one step:
    /// `nb_patches_max_S1 = ⌊nbop_PE / (nb_op_value · C_out)⌋` (§4.2).
    pub fn max_patches_per_step(&self, layer: &ConvLayer) -> usize {
        (self.nbop_pe as usize) / layer.ops_per_patch()
    }

    /// Inverse helper: the smallest `nbop_PE` giving a wanted group size —
    /// used by the figure harness, which (like the paper §7.1) sweeps
    /// `nb_patches_max_S1` directly.
    pub fn for_group_size(layer: &ConvLayer, group: usize) -> Self {
        let nbop = (group * layer.ops_per_patch()) as u64;
        // On-chip memory sized per the paper's §7.1 memory assumption: all
        // kernels + `group` worth of input patches + their outputs fit. Input
        // sizing uses `input_elements_per_patch` (all C_in channels of the
        // footprint), which exceeds `ops_per_output_value` when groups > 1.
        let mem = layer.kernel_elements() as u64
            + (group * layer.input_elements_per_patch()) as u64
            + (group * layer.c_out()) as u64;
        Accelerator {
            nbop_pe: nbop,
            t_acc: 1,
            size_mem: mem,
            t_l: 1,
            t_w: 0,
            overlap: OverlapMode::Sequential,
            dma_channels: 1,
            compute_units: 1,
        }
    }

    /// Minimal number of steps `K_min = ⌈|X| / nb_patches_max_S1⌉`
    /// (Definition 14).
    pub fn k_min(&self, layer: &ConvLayer) -> usize {
        let g = self.max_patches_per_step(layer).max(1);
        layer.n_patches().div_ceil(g)
    }

    /// Maximal number of steps `K_max = |X|` (Definition 15).
    pub fn k_max(&self, layer: &ConvLayer) -> usize {
        layer.n_patches()
    }
}

/// A platform = an accelerator plus the (assumed-sufficient) DRAM.
///
/// The DRAM size is tracked only to honour the model's "DRAM is large enough"
/// assumption explicitly: the simulator checks it once against the layer.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Platform {
    /// The accelerator.
    pub accelerator: Accelerator,
    /// DRAM capacity in elements; `u64::MAX` means unbounded.
    pub dram_size: u64,
}

impl Platform {
    /// A platform with unbounded DRAM around `accelerator`.
    pub fn new(accelerator: Accelerator) -> Self {
        Platform { accelerator, dram_size: u64::MAX }
    }

    /// Check the DRAM can hold input + kernels + output of the layer.
    pub fn dram_fits(&self, layer: &ConvLayer) -> bool {
        let need = layer.input_dims().len() as u64
            + layer.kernel_elements() as u64
            + layer.output_dims().len() as u64;
        need <= self.dram_size
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn example_layer() -> ConvLayer {
        // Example 2: 2x5x5 input, two 3x3 kernels
        ConvLayer::new(2, 5, 5, 3, 3, 2, 1, 1).unwrap()
    }

    #[test]
    fn example2_group_size() {
        // nbop_PE = 120 → nb_patches_max_S1 = ⌊120 / (2·3·3·2)⌋ = 3 … wait:
        // ops_per_patch = C_in·H_K·W_K·C_out = 2·9·2 = 36; ⌊120/36⌋ = 3?
        // The paper says 2. Its §4.2 formula uses nb_op_value·C_out =
        // (2·3·3)·2 = 36 → ⌊120/36⌋ = 3. The paper's example states 2,
        // which corresponds to nbop_PE = 120 with the *next* full patch not
        // fitting: 3·36 = 108 ≤ 120 — so the formula yields 3; the paper's
        // example is internally inconsistent and we follow the formula but
        // pin the example's intent (group 2) via for_group_size below.
        let acc = Accelerator::paper_eval(120, 1_000);
        assert_eq!(acc.max_patches_per_step(&example_layer()), 3);
        let acc2 = Accelerator::for_group_size(&example_layer(), 2);
        assert_eq!(acc2.max_patches_per_step(&example_layer()), 2);
        assert_eq!(acc2.nbop_pe, 72);
    }

    #[test]
    fn k_min_k_max() {
        let l = example_layer(); // 9 patches
        let acc = Accelerator::for_group_size(&l, 2);
        assert_eq!(acc.k_min(&l), 5); // ⌈9/2⌉
        assert_eq!(acc.k_max(&l), 9);
        let acc4 = Accelerator::for_group_size(&l, 4);
        assert_eq!(acc4.k_min(&l), 3);
    }

    #[test]
    fn k_min_handles_degenerate_pe() {
        let l = example_layer();
        // Accelerator too small for even one patch: treat as group 1.
        let acc = Accelerator { nbop_pe: 1, t_w: 1, ..Accelerator::paper_eval(1, 100) };
        assert_eq!(acc.max_patches_per_step(&l), 0);
        assert_eq!(acc.k_min(&l), 9);
    }

    #[test]
    fn dram_check() {
        let l = example_layer();
        let mut p = Platform::new(Accelerator::paper_eval(120, 100));
        assert!(p.dram_fits(&l));
        p.dram_size = 10;
        assert!(!p.dram_fits(&l));
    }

    #[test]
    fn overlap_mode_defaults_and_roundtrips() {
        assert_eq!(Accelerator::paper_eval(1, 1).overlap, OverlapMode::Sequential);
        for m in [OverlapMode::Sequential, OverlapMode::DoubleBuffered] {
            assert_eq!(OverlapMode::from_str(m.as_str()), Ok(m));
        }
        assert_eq!(OverlapMode::from_str("db"), Ok(OverlapMode::DoubleBuffered));
        assert!(OverlapMode::from_str("bogus").is_err());
        let acc = Accelerator::paper_eval(1, 1).with_overlap(OverlapMode::DoubleBuffered);
        assert_eq!(acc.overlap, OverlapMode::DoubleBuffered);
        assert_eq!(acc.t_l, 1);
    }

    #[test]
    fn channel_defaults_and_builder() {
        let acc = Accelerator::paper_eval(1, 1);
        assert_eq!((acc.dma_channels, acc.compute_units), (1, 1));
        let wide = acc.with_channels(3, 2);
        assert_eq!((wide.dma_channels, wide.compute_units), (3, 2));
        assert_eq!(wide.t_l, acc.t_l);
        // degenerate shapes clamp to the §3.7 pair
        let clamped = acc.with_channels(0, 0);
        assert_eq!((clamped.dma_channels, clamped.compute_units), (1, 1));
    }

    #[test]
    fn for_group_size_memory_assumption() {
        let l = example_layer();
        let acc = Accelerator::for_group_size(&l, 2);
        // kernels (2·2·3·3=36) + 2 patches (2·18=36) + outputs (2·2=4)
        assert_eq!(acc.size_mem, 76);
    }
}

//! Platform model (§2.1): accelerator, DRAM and the on-chip memory state.

mod accelerator;
mod memory;

pub use accelerator::{Accelerator, OverlapMode, Platform};
pub use memory::{KernelSet, MemoryState, OnChipMemory, OutputSet};

//! Platform model (§2.1): accelerator, DRAM and the on-chip memory state —
//! plus the deterministic fault-injection layer ([`FaultModel`]).

mod accelerator;
mod fault;
mod memory;

pub use accelerator::{Accelerator, OverlapMode, Platform};
pub use fault::{FaultModel, StepFaults};
pub use memory::{KernelSet, MemoryState, OnChipMemory, OutputSet};

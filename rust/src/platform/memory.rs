//! On-chip memory state `M_i = [M_i^inp, M_i^ker, M_i^out]` (Definition 2).
//!
//! Granularities follow the paper:
//! * **input** — spatial pixels (Remark 6); element count = pixels × `C_in`;
//! * **kernels** — whole kernels (S1 never splits a kernel); element count =
//!   kernels × `C_in·H_K·W_K`;
//! * **output** — per-patch output columns (a step computes all `C_out`
//!   channels of each patch, Property 1); element count = patches × `C_out`.

use crate::conv::ConvLayer;
use crate::tensor::PixelSet;

/// Set of kernel indices `⊆ Λ` held on chip (bitset over `[0, N)`).
pub type KernelSet = PixelSet;

/// Set of *computed, not yet written back* output patches (bitset over
/// `[0, |X|)`; each member stands for the `C_out` values `O[·, i, j]`).
pub type OutputSet = PixelSet;

/// The on-chip memory contents at a step boundary.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct MemoryState {
    /// `M^inp` — resident input pixels (spatial).
    pub inp: PixelSet,
    /// `M^ker` — resident kernels.
    pub ker: KernelSet,
    /// `M^out` — computed, not-yet-written output patches.
    pub out: OutputSet,
}

impl MemoryState {
    /// `M_0 = [∅, ∅, ∅]` — the memory is initially empty (Definition 2).
    pub fn initial(layer: &ConvLayer) -> Self {
        MemoryState {
            inp: PixelSet::empty(layer.n_pixels()),
            ker: KernelSet::empty(layer.n_kernels),
            out: OutputSet::empty(layer.n_patches()),
        }
    }

    /// True when all three stores are empty.
    pub fn is_empty(&self) -> bool {
        self.inp.is_empty() && self.ker.is_empty() && self.out.is_empty()
    }

    /// Occupied elements: inputs + kernels + outputs.
    pub fn occupied_elements(&self, layer: &ConvLayer) -> u64 {
        (self.inp.len() * layer.c_in
            + self.ker.len() * layer.kernel_dims().len()
            + self.out.len() * layer.c_out()) as u64
    }
}

/// On-chip memory with capacity accounting.
///
/// Tracks the running state plus the *peak* element occupancy seen, which is
/// what the capacity constraint (Eq. 12) bounds:
/// `size_i^step = |M^inp ∪ I^slice| + |M^ker ∪ K^sub| + |M^out ∪ Out_i|`.
#[derive(Debug, Clone)]
pub struct OnChipMemory {
    /// The current memory contents.
    pub state: MemoryState,
    capacity: u64,
    peak: u64,
}

impl OnChipMemory {
    /// Empty on-chip memory with the given element capacity.
    pub fn new(layer: &ConvLayer, capacity: u64) -> Self {
        OnChipMemory { state: MemoryState::initial(layer), capacity, peak: 0 }
    }

    /// Element capacity (`size_MEM`).
    pub fn capacity(&self) -> u64 {
        self.capacity
    }

    /// Highest element occupancy observed so far.
    pub fn peak(&self) -> u64 {
        self.peak
    }

    /// Record the current occupancy into the peak tracker and check capacity.
    ///
    /// Returns `Err` with the overflowing size if the occupancy exceeds
    /// `size_MEM`.
    pub fn note_occupancy(&mut self, layer: &ConvLayer) -> Result<u64, u64> {
        let occ = self.state.occupied_elements(layer);
        self.peak = self.peak.max(occ);
        if occ > self.capacity {
            Err(occ)
        } else {
            Ok(occ)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn layer() -> ConvLayer {
        ConvLayer::new(2, 5, 5, 3, 3, 2, 1, 1).unwrap()
    }

    #[test]
    fn initial_state_empty() {
        let l = layer();
        let m = MemoryState::initial(&l);
        assert!(m.is_empty());
        assert_eq!(m.occupied_elements(&l), 0);
        assert_eq!(m.inp.universe(), 25);
        assert_eq!(m.ker.universe(), 2);
        assert_eq!(m.out.universe(), 9);
    }

    #[test]
    fn occupancy_counts_elements_not_pixels() {
        let l = layer();
        let mut m = MemoryState::initial(&l);
        m.inp = l.patch_pixels(0); // 9 pixels × 2 channels = 18 elements
        m.ker.insert(0); // 1 kernel × 18 = 18 elements
        m.out.insert(0); // 1 patch × C_out=2 = 2 elements
        assert_eq!(m.occupied_elements(&l), 18 + 18 + 2);
    }

    #[test]
    fn peak_tracking_and_overflow() {
        let l = layer();
        let mut mem = OnChipMemory::new(&l, 20);
        mem.state.inp = l.patch_pixels(0); // 18 elements
        assert_eq!(mem.note_occupancy(&l), Ok(18));
        mem.state.ker.insert(0); // +18 → 36 > 20
        assert_eq!(mem.note_occupancy(&l), Err(36));
        assert_eq!(mem.peak(), 36);
        // freeing brings occupancy down, peak stays
        mem.state.ker.clear();
        assert_eq!(mem.note_occupancy(&l), Ok(18));
        assert_eq!(mem.peak(), 36);
    }
}

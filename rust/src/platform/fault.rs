//! Deterministic fault injection for the offload timeline models.
//!
//! The paper's platform is fault-free; real deployments are not. This module
//! models three fault classes a predictable-offloading planner must survive
//! *and bound*:
//!
//! * **transient DMA failures** — a step's input load fails and replays at
//!   its full cost plus a fixed retry penalty (bounded retries per step);
//! * **timing jitter** — bounded per-step noise on the DMA phase and on
//!   `t_acc` (bus contention, DVFS wobble);
//! * **memory shrink** — an event that permanently reduces the *effective*
//!   `size_MEM` (e.g. a co-tenant claims SRAM). Functional execution is
//!   unaffected (the strategy was validated against the full budget); what
//!   shrink degrades is the §3.7 double-buffer residency condition, forcing
//!   prefetches back to the serialization fallback, and the planner's cached
//!   strategies, which [`crate::planner`] re-validates and degrades.
//!
//! Faults are drawn from a **stateless per-step stream**: step `i` seeds its
//! own [`Rng`] as `seed ^ i·GOLDEN`, so the fault sequence is a pure function
//! of `(fault seed, step index, step shape)` — independent of thread count,
//! replay order, or how many steps were simulated before. Multi-stage runs
//! additionally decorrelate stages through [`FaultModel::for_stage`], which
//! golden-ratio-*adds* the stage index into the seed (stage 0 is the
//! identity, so single-stage traces stay pinned). The Python oracle
//! (`python/oracle_sim.py`) mirrors both constructions bit-exactly.
//!
//! The zero model ([`FaultModel::none`]) is the *structural identity*: every
//! injected quantity is zero and every timeline recurrence reduces to the
//! fault-free one, so zero-fault runs are bit-identical to the pinned
//! baselines by construction, not by luck.

use crate::util::rng::Rng;

/// The SplitMix64 golden-ratio increment; decorrelates per-step seeds.
const GOLDEN: u64 = 0x9E3779B97F4A7C15;

/// A seeded, replayable fault stream (see the module docs).
///
/// All-zero rates/jitters ([`FaultModel::none`], the `Default`) inject
/// nothing and reproduce fault-free timelines bit-exactly.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultModel {
    /// Stream seed: same seed ⇒ same faults, everywhere, forever.
    pub seed: u64,
    /// Per-attempt probability that a step's input load fails and replays.
    pub dma_fail_rate: f64,
    /// Retry budget per step (attempts beyond the first); caps the replay
    /// count so the worst case stays bounded.
    pub max_retries: u32,
    /// Fixed extra cycles charged per replay (bus re-arbitration etc.).
    pub retry_penalty: u64,
    /// Max extra cycles of jitter on a step's DMA phase (uniform in
    /// `0..=dma_jitter`, drawn only for steps that move data).
    pub dma_jitter: u64,
    /// Max extra cycles of jitter on `t_acc` (uniform in `0..=t_acc_jitter`,
    /// drawn only for compute steps).
    pub t_acc_jitter: u64,
    /// Per-step probability of a `MemoryShrink` event.
    pub shrink_rate: f64,
    /// Elements removed from the effective `size_MEM` per shrink event
    /// (sticky: shrinks accumulate for the rest of the run).
    pub shrink_elements: u64,
}

impl Default for FaultModel {
    fn default() -> Self {
        FaultModel::none()
    }
}

/// The faults injected into one step, as drawn by
/// [`FaultModel::step_faults`]. Default = no faults.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct StepFaults {
    /// Failed load attempts: the load phase replays this many times.
    pub load_retries: u32,
    /// Extra cycles added to the step's DMA phase.
    pub dma_jitter: u64,
    /// Extra cycles added to the step's compute phase.
    pub compute_jitter: u64,
    /// Whether a `MemoryShrink` event fires at this step.
    pub shrink: bool,
}

impl StepFaults {
    /// True when this step is fault-free.
    pub fn is_clean(&self) -> bool {
        *self == StepFaults::default()
    }
}

impl FaultModel {
    /// The zero model: nothing fails, nothing jitters, nothing shrinks.
    pub fn none() -> Self {
        FaultModel {
            seed: 0,
            dma_fail_rate: 0.0,
            max_retries: 0,
            retry_penalty: 0,
            dma_jitter: 0,
            t_acc_jitter: 0,
            shrink_rate: 0.0,
            shrink_elements: 0,
        }
    }

    /// The same stream under a different seed (builder-style; what
    /// `--fault-seed` applies on top of `--faults`).
    pub fn with_seed(self, seed: u64) -> Self {
        FaultModel { seed, ..self }
    }

    /// The stage-`stage` view of this model: the same axes with the stage
    /// index golden-ratio-mixed into the seed, so different pipeline stages
    /// draw decorrelated streams (step 0 of every stage used to share one).
    /// The mix is a wrapping *add* — distinct from the per-step *xor*
    /// spreading in [`FaultModel::step_faults`], so the two cannot cancel.
    /// Stage 0 is the identity: single-stage traces are unchanged. The
    /// Python oracle mirrors this in `FaultModel.for_stage`.
    pub fn for_stage(self, stage: usize) -> Self {
        self.with_seed(self.seed.wrapping_add((stage as u64).wrapping_mul(GOLDEN)))
    }

    /// True when this model can inject anything at all.
    pub fn is_active(&self) -> bool {
        (self.dma_fail_rate > 0.0 && self.max_retries > 0)
            || self.dma_jitter > 0
            || self.t_acc_jitter > 0
            || (self.shrink_rate > 0.0 && self.shrink_elements > 0)
    }

    /// Draw the faults for step `index` of a run.
    ///
    /// The draw order is a cross-language contract (the Python oracle
    /// replays it verbatim): retries while the load keeps failing (capped at
    /// `max_retries`), then DMA jitter (only for steps that load or write),
    /// then compute jitter (only for compute steps), then the shrink event.
    /// Gating draws on the step shape keeps the stream stable when a
    /// neighbouring phase is empty (a flush step consumes no compute draw).
    pub fn step_faults(
        &self,
        index: u64,
        loaded_elements: u64,
        written_elements: u64,
        computed: bool,
    ) -> StepFaults {
        let mut f = StepFaults::default();
        if !self.is_active() {
            return f;
        }
        let mut rng = Rng::new(self.seed ^ index.wrapping_mul(GOLDEN));
        if self.dma_fail_rate > 0.0 && loaded_elements > 0 {
            for _ in 0..self.max_retries {
                if rng.chance(self.dma_fail_rate) {
                    f.load_retries += 1;
                } else {
                    break;
                }
            }
        }
        if self.dma_jitter > 0 && (loaded_elements > 0 || written_elements > 0) {
            f.dma_jitter = rng.below(self.dma_jitter + 1);
        }
        if self.t_acc_jitter > 0 && computed {
            f.compute_jitter = rng.below(self.t_acc_jitter + 1);
        }
        if self.shrink_rate > 0.0 && self.shrink_elements > 0 {
            f.shrink = rng.chance(self.shrink_rate);
        }
        f
    }

    /// Analytic worst-case makespan under at most `k` DMA faults.
    ///
    /// `fault_free_duration` is the Definition-3 sequential sum of the
    /// strategy, `n_steps`/`n_compute_steps` its step counts, and
    /// `max_load_cycles` the largest single-step load phase (cycles). The
    /// bound dominates **every** simulated trace with ≤ `k` retries, under
    /// both overlap modes:
    ///
    /// * the double-buffered makespan never exceeds the faulted sequential
    ///   sum (the §3.7 timeline property holds for arbitrary phase durations
    ///   and prefetch flags, so shrink-forced serialization is covered);
    /// * the faulted sequential sum is the fault-free sum plus per-step
    ///   jitters (each ≤ its `*_jitter` cap) plus replays (each ≤
    ///   `max_load_cycles + retry_penalty`, at most `k` of them).
    ///
    /// Monotone in `k` by construction. See `DESIGN.md` §3.9 for the proof
    /// sketch and `rust/tests/integration_faults.rs` for the empirical check
    /// against random traces.
    pub fn makespan_under_k_faults(
        &self,
        fault_free_duration: u64,
        n_steps: u64,
        n_compute_steps: u64,
        max_load_cycles: u64,
        k: u64,
    ) -> u64 {
        fault_free_duration
            + n_steps.saturating_mul(self.dma_jitter)
            + n_compute_steps.saturating_mul(self.t_acc_jitter)
            + k.saturating_mul(max_load_cycles + self.retry_penalty)
    }

    /// Parse a CLI fault spec: comma-separated `key=value` pairs.
    ///
    /// Keys: `dma` (fail rate), `retries`, `penalty`, `jitter` (DMA),
    /// `acc-jitter`, `shrink` (rate), `shrink-el` (elements per event),
    /// `seed`. Unset keys keep their defaults (`retries` defaults to 3 so
    /// `--faults dma=0.1` alone is already a live model). Rates must lie in
    /// `[0, 1]`.
    pub fn from_spec(spec: &str) -> Result<FaultModel, String> {
        let mut m = FaultModel { max_retries: 3, ..FaultModel::none() };
        for part in spec.split(',') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            let (key, value) = part
                .split_once('=')
                .ok_or_else(|| format!("fault spec '{part}': expected key=value"))?;
            let (key, value) = (key.trim(), value.trim());
            let rate = |v: &str| -> Result<f64, String> {
                let r: f64 = v
                    .parse()
                    .map_err(|_| format!("fault spec {key}: bad rate '{v}'"))?;
                if !(0.0..=1.0).contains(&r) {
                    return Err(format!("fault spec {key}: rate {r} outside [0, 1]"));
                }
                Ok(r)
            };
            let int = |v: &str| -> Result<u64, String> {
                v.parse()
                    .map_err(|_| format!("fault spec {key}: bad integer '{v}'"))
            };
            match key {
                "dma" => m.dma_fail_rate = rate(value)?,
                "retries" => m.max_retries = int(value)? as u32,
                "penalty" => m.retry_penalty = int(value)?,
                "jitter" => m.dma_jitter = int(value)?,
                "acc-jitter" | "acc_jitter" => m.t_acc_jitter = int(value)?,
                "shrink" => m.shrink_rate = rate(value)?,
                "shrink-el" | "shrink_el" => m.shrink_elements = int(value)?,
                "seed" => m.seed = int(value)?,
                other => {
                    return Err(format!(
                        "fault spec: unknown key '{other}' \
                         (dma|retries|penalty|jitter|acc-jitter|shrink|shrink-el|seed)"
                    ))
                }
            }
        }
        Ok(m)
    }

    /// Stable spec round-trip (the inverse of [`FaultModel::from_spec`]) —
    /// used by reports so a run's fault configuration is reproducible from
    /// its artifacts alone.
    pub fn to_spec(&self) -> String {
        format!(
            "dma={},retries={},penalty={},jitter={},acc-jitter={},shrink={},shrink-el={},seed={}",
            self.dma_fail_rate,
            self.max_retries,
            self.retry_penalty,
            self.dma_jitter,
            self.t_acc_jitter,
            self.shrink_rate,
            self.shrink_elements,
            self.seed,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_model_is_inactive_and_clean() {
        let m = FaultModel::none();
        assert!(!m.is_active());
        for i in 0..50 {
            assert!(m.step_faults(i, 100, 10, true).is_clean());
        }
        // A rate with no retry budget, or a shrink rate with no elements,
        // cannot inject anything either.
        assert!(!FaultModel { dma_fail_rate: 0.5, ..FaultModel::none() }.is_active());
        assert!(!FaultModel { shrink_rate: 0.5, ..FaultModel::none() }.is_active());
    }

    #[test]
    fn per_step_streams_are_stateless_and_order_free() {
        let m = FaultModel {
            seed: 13,
            dma_fail_rate: 0.4,
            max_retries: 4,
            retry_penalty: 3,
            dma_jitter: 7,
            t_acc_jitter: 5,
            shrink_rate: 0.1,
            shrink_elements: 8,
        };
        let forward: Vec<StepFaults> =
            (0..32).map(|i| m.step_faults(i, 50, 5, true)).collect();
        let backward: Vec<StepFaults> =
            (0..32).rev().map(|i| m.step_faults(i, 50, 5, true)).collect();
        let reversed: Vec<StepFaults> = backward.into_iter().rev().collect();
        assert_eq!(forward, reversed, "step streams must not share state");
        // And some step actually draws something at these rates.
        assert!(forward.iter().any(|f| !f.is_clean()));
    }

    #[test]
    fn draws_are_gated_on_the_step_shape() {
        let m = FaultModel {
            seed: 99,
            dma_fail_rate: 1.0,
            max_retries: 2,
            dma_jitter: 10,
            t_acc_jitter: 10,
            ..FaultModel::none()
        };
        // A flush step (no loads, no compute) draws neither retries nor
        // compute jitter; with writes it still draws DMA jitter.
        let flush = m.step_faults(3, 0, 4, false);
        assert_eq!(flush.load_retries, 0);
        assert_eq!(flush.compute_jitter, 0);
        // A pure compute step consumes no DMA draws.
        let compute_only = m.step_faults(3, 0, 0, true);
        assert_eq!(compute_only.dma_jitter, 0);
        // Retries max out at the cap under rate 1.
        let loaded = m.step_faults(3, 10, 0, true);
        assert_eq!(loaded.load_retries, 2);
    }

    /// Cross-language pin: these exact values are asserted by the Python
    /// oracle's RNG mirror (`python/tests/test_fault_oracle.py`). If this
    /// test and the Python one both pass, the two implementations of
    /// xoshiro256** + SplitMix64 + Lemire rejection are bit-identical.
    #[test]
    fn rng_cross_language_pins() {
        let mut r = Rng::new(42);
        assert_eq!(
            [r.next_u64(), r.next_u64(), r.next_u64(), r.next_u64(), r.next_u64()],
            [
                1546998764402558742,
                6990951692964543102,
                12544586762248559009,
                17057574109182124193,
                18295552978065317476,
            ]
        );
        let mut r = Rng::new(7);
        let below: Vec<u64> = (0..8).map(|_| r.below(100)).collect();
        assert_eq!(below, vec![70, 27, 83, 98, 99, 87, 6, 10]);
        let mut r = Rng::new(2026);
        let chances: Vec<bool> = (0..12).map(|_| r.chance(0.3)).collect();
        assert_eq!(
            chances,
            vec![
                false, true, false, false, false, false, false, false, false, true,
                false, false
            ]
        );
        // Derived per-step seeds, exactly as step_faults() builds them.
        let mut r = Rng::new(13 ^ 1u64.wrapping_mul(GOLDEN));
        assert_eq!(r.next_u64(), 13543073186684114632);
        assert_eq!(r.next_u64(), 8432558809597263448);
    }

    /// Cross-language pin for the stage decorrelation mix: the Python
    /// oracle's `TestStageDecorrelation.test_stage_seed_pins` asserts the
    /// same four seeds, so both sides route stage `i` through the same
    /// derived stream. Stage 0 must be the identity — single-stage traces
    /// (and every pinned baseline) are unchanged by the mixing.
    #[test]
    fn stage_seed_mixing_pins() {
        let m = FaultModel {
            seed: 13,
            dma_fail_rate: 0.35,
            max_retries: 3,
            retry_penalty: 9,
            dma_jitter: 4,
            t_acc_jitter: 3,
            shrink_rate: 0.15,
            shrink_elements: 32,
        };
        let seeds: Vec<u64> = (0..4).map(|i| m.for_stage(i).seed).collect();
        assert_eq!(
            seeds,
            vec![
                13,
                11400714819323198498,
                4354685564936845367,
                15755400384260043852,
            ]
        );
        assert_eq!(m.for_stage(0), m, "stage 0 must keep traces stable");
        // The fix this pin guards: step 0 of different stages used to draw
        // from one shared stream. Under the mix the draws diverge.
        let step0: Vec<StepFaults> =
            (0..8).map(|i| m.for_stage(i).step_faults(0, 500, 50, true)).collect();
        assert!(
            step0.iter().any(|f| f != &step0[0]),
            "stage mixing left every stage's step-0 draw identical"
        );
    }

    #[test]
    fn spec_round_trips_and_validates() {
        let m = FaultModel::from_spec(
            "dma=0.1,retries=5,penalty=4,jitter=2,acc-jitter=1,shrink=0.05,shrink-el=16,seed=9",
        )
        .unwrap();
        assert_eq!(m.dma_fail_rate, 0.1);
        assert_eq!(m.max_retries, 5);
        assert_eq!(m.retry_penalty, 4);
        assert_eq!(m.dma_jitter, 2);
        assert_eq!(m.t_acc_jitter, 1);
        assert_eq!(m.shrink_rate, 0.05);
        assert_eq!(m.shrink_elements, 16);
        assert_eq!(m.seed, 9);
        assert_eq!(FaultModel::from_spec(&m.to_spec()).unwrap(), m);
        // Defaults: retries pre-set so a bare rate is live.
        let bare = FaultModel::from_spec("dma=0.2").unwrap();
        assert_eq!(bare.max_retries, 3);
        assert!(bare.is_active());
        assert!(FaultModel::from_spec("dma=1.5").is_err());
        assert!(FaultModel::from_spec("dma").is_err());
        assert!(FaultModel::from_spec("bogus=1").is_err());
    }

    #[test]
    fn wcet_bound_is_monotone_in_k() {
        let m = FaultModel {
            retry_penalty: 5,
            dma_jitter: 3,
            t_acc_jitter: 2,
            ..FaultModel::none()
        };
        let mut prev = 0;
        for k in 0..20 {
            let b = m.makespan_under_k_faults(1000, 10, 9, 40, k);
            assert!(b >= prev);
            prev = b;
        }
        assert_eq!(m.makespan_under_k_faults(1000, 10, 9, 40, 0), 1000 + 30 + 18);
        assert_eq!(m.makespan_under_k_faults(1000, 10, 9, 40, 2), 1000 + 30 + 18 + 90);
    }
}

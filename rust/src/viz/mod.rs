//! Step-by-step strategy visualisation (the paper's Figure 9 panels).
//!
//! Renders, for each step, the input-pixel grid classified as
//! freed / loaded / kept-resident, plus the patch group — as ASCII for the
//! terminal and as SVG for reports.

mod ascii;
mod svg;

pub use ascii::{render_step_ascii, render_strategy_ascii, Legend};
pub use svg::render_strategy_svg;

use crate::conv::ConvLayer;
use crate::step::Step;
use crate::tensor::PixelSet;

/// Classification of each input pixel at one step (what Fig. 9 colours).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PixelClass {
    /// Not on chip before or after the step.
    Absent,
    /// Freed by `a_1` this step.
    Freed,
    /// Loaded by `a_4` this step.
    Loaded,
    /// Resident before and kept through the step (data reuse).
    Kept,
}

/// Per-step view used by the renderers.
#[derive(Debug, Clone)]
pub struct StepView {
    /// Step index (0-based).
    pub index: usize,
    /// Per-pixel classification, row-major over the input grid.
    pub classes: Vec<PixelClass>,
    /// Patch ids computed this step.
    pub group: Vec<u32>,
}

/// Replay a compiled strategy and classify every pixel at every step.
pub fn step_views(layer: &ConvLayer, steps: &[Step]) -> Vec<StepView> {
    let mut resident = PixelSet::empty(layer.n_pixels());
    let mut views = Vec::with_capacity(steps.len());
    for (index, st) in steps.iter().enumerate() {
        let mut classes = vec![PixelClass::Absent; layer.n_pixels()];
        for px in resident.iter() {
            classes[px as usize] = PixelClass::Kept;
        }
        for px in st.free_inp.iter() {
            classes[px as usize] = PixelClass::Freed;
        }
        for px in st.load_inp.iter() {
            classes[px as usize] = PixelClass::Loaded;
        }
        resident.subtract(&st.free_inp);
        resident.union_with(&st.load_inp);
        views.push(StepView { index, classes, group: st.group.clone() });
    }
    views
}

/// Sanity: replay of a memory-state trajectory matches the semantics.
#[cfg(test)]
mod tests {
    use super::*;
    use crate::platform::{Accelerator, MemoryState, Platform};
    use crate::strategy;

    #[test]
    fn views_track_residency() {
        let l = ConvLayer::new(2, 5, 5, 3, 3, 2, 1, 1).unwrap();
        let s = strategy::row_by_row(&l, 2);
        let steps = s.compile(&l);
        let views = step_views(&l, &steps);
        assert_eq!(views.len(), steps.len());
        // step 0: footprint loaded, nothing kept or freed
        assert!(views[0]
            .classes
            .iter()
            .all(|c| matches!(c, PixelClass::Absent | PixelClass::Loaded)));
        // step 1: some kept pixels (overlap), some freed, some loaded
        let counts = |v: &StepView, k: PixelClass| {
            v.classes.iter().filter(|&&c| c == k).count()
        };
        assert!(counts(&views[1], PixelClass::Kept) > 0);
        assert!(counts(&views[1], PixelClass::Loaded) > 0);
        assert!(counts(&views[1], PixelClass::Freed) > 0);
        // final flush frees everything: nothing loaded
        let flush = views.last().unwrap();
        assert_eq!(counts(flush, PixelClass::Loaded), 0);
        assert!(counts(flush, PixelClass::Freed) > 0);
    }

    #[test]
    fn replay_consistent_with_semantics() {
        let l = ConvLayer::new(1, 6, 6, 3, 3, 1, 1, 1).unwrap();
        let acc = Accelerator::for_group_size(&l, 2);
        let _p = Platform::new(acc);
        let s = strategy::zigzag(&l, 2);
        let steps = s.compile(&l);
        let views = step_views(&l, &steps);
        // Kept+Loaded at each view equals the post-a4 resident set size the
        // semantics would produce; cross-check via MemoryState.
        let mut mem = MemoryState::initial(&l);
        for (st, view) in steps.iter().zip(&views) {
            crate::step::apply(&l, &acc, &mut mem, st, true).unwrap();
            let resident_view = view
                .classes
                .iter()
                .filter(|&&c| matches!(c, PixelClass::Kept | PixelClass::Loaded))
                .count();
            assert_eq!(resident_view, mem.inp.len());
        }
    }
}

//! ASCII rendering of step views (terminal counterpart of Figure 9).

use crate::conv::ConvLayer;
use crate::step::Step;
use crate::viz::{step_views, PixelClass, StepView};

/// Glyphs used per pixel class.
#[derive(Debug, Clone, Copy)]
pub struct Legend {
    /// Glyph for pixels not on chip.
    pub absent: char,
    /// Glyph for pixels freed this step (`a_1`).
    pub freed: char,
    /// Glyph for pixels loaded this step (`a_4`).
    pub loaded: char,
    /// Glyph for pixels kept from the previous step (reuse).
    pub kept: char,
}

impl Default for Legend {
    fn default() -> Self {
        // '.' absent, 'x' freed, 'L' newly loaded, 'o' kept/reused
        Legend { absent: '.', freed: 'x', loaded: 'L', kept: 'o' }
    }
}

impl Legend {
    /// The glyph for a pixel class.
    pub fn glyph(&self, c: PixelClass) -> char {
        match c {
            PixelClass::Absent => self.absent,
            PixelClass::Freed => self.freed,
            PixelClass::Loaded => self.loaded,
            PixelClass::Kept => self.kept,
        }
    }

    /// Human-readable legend line.
    pub fn describe(&self) -> String {
        format!(
            "legend: '{}' absent  '{}' freed (a1)  '{}' loaded (a4)  '{}' kept/reused",
            self.absent, self.freed, self.loaded, self.kept
        )
    }
}

/// Render one step as an `H_in × W_in` character grid.
pub fn render_step_ascii(layer: &ConvLayer, view: &StepView, legend: &Legend) -> String {
    let mut out = String::new();
    let group_desc: Vec<String> = view
        .group
        .iter()
        .map(|&p| {
            let patch = layer.patch(p);
            format!("P({},{})", patch.i, patch.j)
        })
        .collect();
    out.push_str(&format!(
        "step {} — group {{{}}}\n",
        view.index + 1,
        group_desc.join(", ")
    ));
    for h in 0..layer.h_in {
        out.push_str("  ");
        for w in 0..layer.w_in {
            let px = crate::tensor::pixel_id(h, w, layer.w_in);
            out.push(legend.glyph(view.classes[px as usize]));
            out.push(' ');
        }
        out.push('\n');
    }
    out
}

/// Render the whole strategy (one grid per step) plus the legend.
pub fn render_strategy_ascii(layer: &ConvLayer, steps: &[Step]) -> String {
    let legend = Legend::default();
    let views = step_views(layer, steps);
    let mut out = String::new();
    out.push_str(&legend.describe());
    out.push('\n');
    for view in &views {
        out.push('\n');
        out.push_str(&render_step_ascii(layer, view, &legend));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::strategy;

    #[test]
    fn renders_grid_of_right_size() {
        let l = ConvLayer::new(2, 5, 5, 3, 3, 2, 1, 1).unwrap();
        let s = strategy::row_by_row(&l, 2);
        let steps = s.compile(&l);
        let text = render_strategy_ascii(&l, &steps);
        // one header + 5 rows per step; 5 compute steps + flush
        assert!(text.contains("step 1 — group {P(0,0), P(0,1)}"));
        assert!(text.contains("legend:"));
        let grids = text.matches("step ").count();
        assert_eq!(grids, steps.len());
        // first grid: 12 loaded pixels (footprint of first two patches)
        let first_grid: String = text
            .lines()
            .skip_while(|l| !l.starts_with("step 1"))
            .skip(1)
            .take(5)
            .collect();
        assert_eq!(first_grid.matches('L').count(), 12);
    }

    #[test]
    fn single_step_render_contains_rows() {
        let l = ConvLayer::new(1, 4, 4, 2, 2, 1, 1, 1).unwrap();
        let s = strategy::s1_baseline(&l);
        let steps = s.compile(&l);
        let views = step_views(&l, &steps);
        let text = render_step_ascii(&l, &views[0], &Legend::default());
        assert_eq!(text.lines().count(), 1 + 4);
    }
}

//! SVG rendering of step views — the report-quality counterpart of Fig. 9.

use crate::conv::ConvLayer;
use crate::step::Step;
use crate::viz::{step_views, PixelClass};

const CELL: usize = 18;
const GAP: usize = 26;
const MARGIN: usize = 10;

fn class_fill(c: PixelClass) -> &'static str {
    match c {
        PixelClass::Absent => "#f2f2f2",
        PixelClass::Freed => "#e74c3c",
        PixelClass::Loaded => "#2ecc71",
        PixelClass::Kept => "#3498db",
    }
}

/// Render every step of a compiled strategy side by side into one SVG
/// document, with per-step captions and a legend.
pub fn render_strategy_svg(layer: &ConvLayer, steps: &[Step], title: &str) -> String {
    let views = step_views(layer, steps);
    let grid_w = layer.w_in * CELL;
    let grid_h = layer.h_in * CELL;
    let per_col = grid_w + GAP;
    let width = MARGIN * 2 + views.len() * per_col;
    let height = MARGIN * 2 + grid_h + 64;

    let mut svg = String::new();
    svg.push_str(&format!(
        r##"<svg xmlns="http://www.w3.org/2000/svg" width="{width}" height="{height}" viewBox="0 0 {width} {height}">"##
    ));
    svg.push('\n');
    svg.push_str(&format!(
        r##"<text x="{MARGIN}" y="16" font-family="monospace" font-size="13">{title}</text>"##
    ));
    svg.push('\n');

    for (k, view) in views.iter().enumerate() {
        let ox = MARGIN + k * per_col;
        let oy = 28;
        svg.push_str(&format!(
            r##"<text x="{ox}" y="{}" font-family="monospace" font-size="11">step {}</text>"##,
            oy - 6,
            view.index + 1
        ));
        svg.push('\n');
        for h in 0..layer.h_in {
            for w in 0..layer.w_in {
                let px = crate::tensor::pixel_id(h, w, layer.w_in);
                let fill = class_fill(view.classes[px as usize]);
                svg.push_str(&format!(
                    r##"<rect x="{}" y="{}" width="{CELL}" height="{CELL}" fill="{fill}" stroke="#999" stroke-width="0.5"/>"##,
                    ox + w * CELL,
                    oy + h * CELL,
                ));
                svg.push('\n');
            }
        }
        // caption: group patches
        let caption: Vec<String> = view
            .group
            .iter()
            .map(|&p| {
                let patch = layer.patch(p);
                format!("P({},{})", patch.i, patch.j)
            })
            .collect();
        svg.push_str(&format!(
            r##"<text x="{ox}" y="{}" font-family="monospace" font-size="10">{}</text>"##,
            oy + grid_h + 14,
            caption.join(" ")
        ));
        svg.push('\n');
    }

    // legend
    let ly = 28 + grid_h + 30;
    for (i, (cls, label)) in [
        (PixelClass::Loaded, "loaded (a4)"),
        (PixelClass::Kept, "kept / reused"),
        (PixelClass::Freed, "freed (a1)"),
        (PixelClass::Absent, "absent"),
    ]
    .iter()
    .enumerate()
    {
        let lx = MARGIN + i * 130;
        svg.push_str(&format!(
            r##"<rect x="{lx}" y="{ly}" width="12" height="12" fill="{}" stroke="#999" stroke-width="0.5"/>"##,
            class_fill(*cls)
        ));
        svg.push_str(&format!(
            r##"<text x="{}" y="{}" font-family="monospace" font-size="10">{label}</text>"##,
            lx + 16,
            ly + 10
        ));
        svg.push('\n');
    }
    svg.push_str("</svg>\n");
    svg
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::strategy;

    #[test]
    fn svg_is_well_formed_enough() {
        let l = ConvLayer::new(2, 5, 5, 3, 3, 2, 1, 1).unwrap();
        let s = strategy::zigzag(&l, 2);
        let steps = s.compile(&l);
        let svg = render_strategy_svg(&l, &steps, "zigzag g=2");
        assert!(svg.starts_with("<svg"));
        assert!(svg.ends_with("</svg>\n"));
        // one rect per pixel per step (+4 legend swatches)
        let rects = svg.matches("<rect").count();
        assert_eq!(rects, steps.len() * l.n_pixels() + 4);
        assert_eq!(svg.matches("<svg").count(), 1);
    }
}

//! Tiny stable hashing (FNV-1a, 64-bit) for content-addressed filenames.
//!
//! `std::hash` offers no stability guarantee across releases or processes;
//! the strategy cache needs cache keys that survive both, so it hashes its
//! canonical key strings with this fixed function instead.

/// FNV-1a over a byte slice (64-bit offset basis / prime).
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= u64::from(b);
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// FNV-1a rendered as a fixed-width lowercase hex string (filename-safe).
pub fn fnv1a64_hex(bytes: &[u8]) -> String {
    format!("{:016x}", fnv1a64(bytes))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_vectors() {
        // Canonical FNV-1a test vectors.
        assert_eq!(fnv1a64(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a64(b"a"), 0xaf63_dc4c_8601_ec8c);
        assert_eq!(fnv1a64(b"foobar"), 0x8594_4171_f739_67e8);
    }

    #[test]
    fn hex_is_stable_and_fixed_width() {
        assert_eq!(fnv1a64_hex(b""), "cbf29ce484222325");
        assert_eq!(fnv1a64_hex(b"a").len(), 16);
        assert_ne!(fnv1a64_hex(b"key1"), fnv1a64_hex(b"key2"));
    }
}

//! Property-testing helper (offline proptest substitute).
//!
//! Provides deterministic random-case generation with failure shrinking for
//! the coordinator-invariant tests (`rust/tests/invariants.rs`): a property
//! is checked over N generated cases; on failure the harness re-runs the
//! property on progressively “smaller” cases derived by the caller-supplied
//! shrinker and reports the smallest failing case.

use crate::util::rng::Rng;

/// Configuration for a property run.
#[derive(Debug, Clone)]
pub struct Config {
    /// Number of random cases to generate.
    pub cases: usize,
    /// Base RNG seed (case `i` derives from it).
    pub seed: u64,
    /// Cap on shrink iterations after a failure.
    pub max_shrink_rounds: usize,
}

impl Default for Config {
    fn default() -> Self {
        // Seed overridable for reproduction of CI failures.
        let seed = std::env::var("CONVOFFLOAD_PT_SEED")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(0xC0FFEE);
        Config { cases: 64, seed, max_shrink_rounds: 200 }
    }
}

/// Outcome of a single property evaluation.
pub type PropResult = Result<(), String>;

/// Run `prop` on `cfg.cases` cases produced by `gen`; on failure, shrink with
/// `shrink` (which proposes smaller variants; return empty when minimal).
///
/// Panics with a readable report if a failing case survives shrinking.
pub fn check<T, G, S, P>(cfg: &Config, mut gen: G, shrink: S, prop: P)
where
    T: std::fmt::Debug + Clone,
    G: FnMut(&mut Rng) -> T,
    S: Fn(&T, &mut Rng) -> Vec<T>,
    P: Fn(&T) -> PropResult,
{
    let mut rng = Rng::new(cfg.seed);
    for case_idx in 0..cfg.cases {
        let case = gen(&mut rng);
        if let Err(msg) = prop(&case) {
            // Shrink.
            let mut best = case.clone();
            let mut best_msg = msg;
            let mut rounds = 0;
            'outer: while rounds < cfg.max_shrink_rounds {
                rounds += 1;
                let candidates = shrink(&best, &mut rng);
                if candidates.is_empty() {
                    break;
                }
                for cand in candidates {
                    if let Err(m) = prop(&cand) {
                        best = cand;
                        best_msg = m;
                        continue 'outer;
                    }
                }
                break; // no candidate still fails: minimal
            }
            panic!(
                "property failed (case {case_idx}, seed {:#x}):\n  {}\n  \
                 minimal failing case after {} shrink rounds:\n  {:?}",
                cfg.seed, best_msg, rounds, best
            );
        }
    }
}

/// Convenience: no shrinking.
pub fn check_no_shrink<T, G, P>(cfg: &Config, gen: G, prop: P)
where
    T: std::fmt::Debug + Clone,
    G: FnMut(&mut Rng) -> T,
    P: Fn(&T) -> PropResult,
{
    check(cfg, gen, |_, _| Vec::new(), prop);
}

/// Assert helper for property bodies.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !$cond {
            return Err(format!($($fmt)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        let cfg = Config { cases: 32, seed: 1, max_shrink_rounds: 10 };
        check_no_shrink(
            &cfg,
            |r| r.below(100),
            |&x| {
                if x < 100 {
                    Ok(())
                } else {
                    Err(format!("{x} out of range"))
                }
            },
        );
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn reports_failure() {
        let cfg = Config { cases: 64, seed: 2, max_shrink_rounds: 10 };
        check_no_shrink(
            &cfg,
            |r| r.below(100),
            |&x| if x < 90 { Ok(()) } else { Err(format!("{x} >= 90")) },
        );
    }

    #[test]
    #[should_panic(expected = "minimal failing case")]
    fn shrinks_towards_zero() {
        let cfg = Config { cases: 64, seed: 3, max_shrink_rounds: 100 };
        check(
            &cfg,
            |r| r.below(1000) + 500, // all cases fail (>= 500)
            |&x, _| if x > 0 { vec![x / 2, x - 1] } else { vec![] },
            |&x| if x < 500 { Ok(()) } else { Err(format!("{x} >= 500")) },
        );
    }
}

//! Criterion-style measurement harness for `cargo bench` (offline substitute).
//!
//! Each bench binary (`rust/benches/*.rs`, `harness = false`) builds a
//! [`BenchSuite`], registers closures, and calls [`BenchSuite::run`], which
//! warms up, runs timed batches until a target measurement time is reached,
//! and reports median / mean / p95 per iteration. A `--bench <filter>`
//! substring filter and `--quick` mode match the common criterion workflow;
//! `--json <path>` (or `CONVOFFLOAD_BENCH_JSON=<path>`) selects the
//! machine-readable output mode — bench binaries combine the returned
//! [`Measurement`]s with derived metrics and write them via
//! [`write_json_report`] so CI can track the perf trajectory as an artifact
//! (`BENCH_planner.json`; see EXPERIMENTS.md §Perf).

use std::hint::black_box;
use std::time::{Duration, Instant};

use crate::util::json::Json;

/// One measurement result.
#[derive(Debug, Clone)]
pub struct Measurement {
    /// Bench name (also the JSON report key).
    pub name: String,
    /// Iterations measured.
    pub iterations: u64,
    /// Median wall time per iteration.
    pub median: Duration,
    /// Mean wall time per iteration.
    pub mean: Duration,
    /// 95th-percentile wall time per iteration.
    pub p95: Duration,
}

impl Measurement {
    /// JSON form (canonical field order; durations in integer nanoseconds).
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("name", self.name.as_str())
            .set("iterations", self.iterations)
            .set("median_ns", self.median.as_nanos() as u64)
            .set("mean_ns", self.mean.as_nanos() as u64)
            .set("p95_ns", self.p95.as_nanos() as u64);
        o
    }

    /// One aligned line for the textual report.
    pub fn report_line(&self) -> String {
        format!(
            "{:<48} iters {:>9}  median {:>12}  mean {:>12}  p95 {:>12}",
            self.name,
            self.iterations,
            fmt_dur(self.median),
            fmt_dur(self.mean),
            fmt_dur(self.p95),
        )
    }
}

fn fmt_dur(d: Duration) -> String {
    let ns = d.as_nanos();
    if ns < 1_000 {
        format!("{ns} ns")
    } else if ns < 1_000_000 {
        format!("{:.2} µs", ns as f64 / 1e3)
    } else if ns < 1_000_000_000 {
        format!("{:.2} ms", ns as f64 / 1e6)
    } else {
        format!("{:.3} s", ns as f64 / 1e9)
    }
}

/// Re-export of `std::hint::black_box` under the name benches expect.
pub fn bb<T>(x: T) -> T {
    black_box(x)
}

/// Where `--json <path>` (or `CONVOFFLOAD_BENCH_JSON`) asks the bench
/// binary to write its machine-readable report; `None` = human output only.
/// A `--json` flag without a following path falls back to `default_path`.
pub fn json_output_path(default_path: &str) -> Option<std::path::PathBuf> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    for (i, a) in argv.iter().enumerate() {
        if a == "--json" {
            let p = argv
                .get(i + 1)
                .filter(|v| !v.starts_with("--"))
                .cloned()
                .unwrap_or_else(|| default_path.to_string());
            return Some(p.into());
        }
    }
    std::env::var("CONVOFFLOAD_BENCH_JSON").ok().map(Into::into)
}

/// Write a bench JSON report: `{suite, quick, measurements, ...extra}`.
/// `extra` lets a bench binary attach derived sections (e.g. the planner's
/// per-layer anneal iterations/sec) next to the raw measurements.
pub fn write_json_report(
    path: &std::path::Path,
    suite_name: &str,
    measurements: &[Measurement],
    extra: Json,
) -> std::io::Result<()> {
    let mut doc = match extra {
        Json::Obj(_) => extra,
        other => {
            let mut o = Json::obj();
            o.set("extra", other);
            o
        }
    };
    doc.set("suite", suite_name)
        .set("quick", quick_mode())
        .set(
            "measurements",
            Json::Arr(measurements.iter().map(Measurement::to_json).collect()),
        );
    std::fs::write(path, doc.to_string_pretty() + "\n")
}

/// True when `--quick` / `CONVOFFLOAD_BENCH_QUICK` shrinks the budgets.
pub fn quick_mode() -> bool {
    std::env::args().any(|a| a == "--quick")
        || std::env::var("CONVOFFLOAD_BENCH_QUICK").is_ok()
}

type BenchFn = Box<dyn FnMut() -> u64>;

/// A named set of benchmarks.
pub struct BenchSuite {
    suite_name: &'static str,
    warmup: Duration,
    measure: Duration,
    benches: Vec<(String, BenchFn)>,
}

impl BenchSuite {
    /// A new, empty suite with the given name.
    pub fn new(suite_name: &'static str) -> Self {
        // `cargo bench -- --quick` (or env) shrinks the budget; integration
        // tests exercising the harness use the env knob.
        let (warmup, measure) = if quick_mode() {
            (Duration::from_millis(20), Duration::from_millis(80))
        } else {
            (Duration::from_millis(300), Duration::from_millis(1500))
        };
        BenchSuite { suite_name, warmup, measure, benches: Vec::new() }
    }

    /// Register a benchmark. The closure runs one iteration and returns a
    /// value-dependent u64 (fed to black_box) so work cannot be elided.
    pub fn bench<F>(&mut self, name: &str, mut f: F)
    where
        F: FnMut() -> u64 + 'static,
    {
        self.benches
            .push((name.to_string(), Box::new(move || black_box(f()))));
    }

    /// Run all registered benchmarks (honouring `--bench`-style substring
    /// filters passed on the command line) and print a report.
    pub fn run(mut self) -> Vec<Measurement> {
        // Positional args are name filters; `--json` consumes its path
        // value so a report path is never mistaken for a filter.
        let argv: Vec<String> = std::env::args().skip(1).collect();
        let mut filters: Vec<String> = Vec::new();
        let mut i = 0;
        while i < argv.len() {
            if argv[i] == "--json" {
                i += 2;
                continue;
            }
            if !argv[i].starts_with("--") {
                filters.push(argv[i].clone());
            }
            i += 1;
        }
        println!("## bench suite: {}", self.suite_name);
        let mut out = Vec::new();
        for (name, f) in self.benches.iter_mut() {
            if !filters.is_empty()
                && !filters.iter().any(|flt| name.contains(flt.as_str()))
            {
                continue;
            }
            let m = measure_one(name, f, self.warmup, self.measure);
            println!("{}", m.report_line());
            out.push(m);
        }
        out
    }
}

fn measure_one(
    name: &str,
    f: &mut BenchFn,
    warmup: Duration,
    measure: Duration,
) -> Measurement {
    // Warm-up and iteration-count calibration.
    let w0 = Instant::now();
    let mut warm_iters: u64 = 0;
    while w0.elapsed() < warmup {
        black_box(f());
        warm_iters += 1;
    }
    let per_iter = warmup.as_nanos() as f64 / warm_iters.max(1) as f64;
    // Aim for ~50 samples over the measurement budget.
    let batch = ((measure.as_nanos() as f64 / 50.0 / per_iter.max(1.0))
        .ceil() as u64)
        .max(1);

    let mut samples: Vec<f64> = Vec::new(); // ns per iteration
    let mut total_iters = 0u64;
    let m0 = Instant::now();
    while m0.elapsed() < measure || samples.len() < 10 {
        let t = Instant::now();
        for _ in 0..batch {
            black_box(f());
        }
        let ns = t.elapsed().as_nanos() as f64 / batch as f64;
        samples.push(ns);
        total_iters += batch;
        if samples.len() > 5000 {
            break;
        }
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let median = samples[samples.len() / 2];
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    let p95 = samples[((samples.len() as f64 * 0.95) as usize).min(samples.len() - 1)];
    Measurement {
        name: name.to_string(),
        iterations: total_iters,
        median: Duration::from_nanos(median as u64),
        mean: Duration::from_nanos(mean as u64),
        p95: Duration::from_nanos(p95 as u64),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        std::env::set_var("CONVOFFLOAD_BENCH_QUICK", "1");
        let mut suite = BenchSuite::new("selftest");
        suite.bench("sum", || (0..100u64).sum::<u64>());
        let results = suite.run();
        assert_eq!(results.len(), 1);
        assert!(results[0].iterations > 0);
        assert!(results[0].median.as_nanos() > 0);
    }

    #[test]
    fn json_report_roundtrips() {
        let m = Measurement {
            name: "x".to_string(),
            iterations: 10,
            median: Duration::from_nanos(5),
            mean: Duration::from_nanos(6),
            p95: Duration::from_nanos(7),
        };
        assert_eq!(m.to_json().get("median_ns").unwrap().as_u64(), Some(5));

        let dir = std::env::temp_dir()
            .join(format!("convoffload-bench-json-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("report.json");
        let mut extra = Json::obj();
        extra.set("anneal", Json::Arr(Vec::new()));
        write_json_report(&path, "selftest", &[m], extra).unwrap();
        let parsed =
            crate::util::json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(parsed.get("suite").unwrap().as_str(), Some("selftest"));
        assert_eq!(parsed.get("measurements").unwrap().as_arr().unwrap().len(), 1);
        assert!(parsed.get("anneal").is_some());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn format_durations() {
        assert!(fmt_dur(Duration::from_nanos(500)).contains("ns"));
        assert!(fmt_dur(Duration::from_micros(500)).contains("µs"));
        assert!(fmt_dur(Duration::from_millis(500)).contains("ms"));
        assert!(fmt_dur(Duration::from_secs(2)).contains(" s"));
    }
}

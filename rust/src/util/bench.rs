//! Criterion-style measurement harness for `cargo bench` (offline substitute).
//!
//! Each bench binary (`rust/benches/*.rs`, `harness = false`) builds a
//! [`BenchSuite`], registers closures, and calls [`BenchSuite::run`], which
//! warms up, runs timed batches until a target measurement time is reached,
//! and reports median / mean / p95 per iteration. A `--bench <filter>`
//! substring filter and `--quick` mode match the common criterion workflow.

use std::hint::black_box;
use std::time::{Duration, Instant};

/// One measurement result.
#[derive(Debug, Clone)]
pub struct Measurement {
    pub name: String,
    pub iterations: u64,
    pub median: Duration,
    pub mean: Duration,
    pub p95: Duration,
}

impl Measurement {
    pub fn report_line(&self) -> String {
        format!(
            "{:<48} iters {:>9}  median {:>12}  mean {:>12}  p95 {:>12}",
            self.name,
            self.iterations,
            fmt_dur(self.median),
            fmt_dur(self.mean),
            fmt_dur(self.p95),
        )
    }
}

fn fmt_dur(d: Duration) -> String {
    let ns = d.as_nanos();
    if ns < 1_000 {
        format!("{ns} ns")
    } else if ns < 1_000_000 {
        format!("{:.2} µs", ns as f64 / 1e3)
    } else if ns < 1_000_000_000 {
        format!("{:.2} ms", ns as f64 / 1e6)
    } else {
        format!("{:.3} s", ns as f64 / 1e9)
    }
}

/// Re-export of `std::hint::black_box` under the name benches expect.
pub fn bb<T>(x: T) -> T {
    black_box(x)
}

type BenchFn = Box<dyn FnMut() -> u64>;

/// A named set of benchmarks.
pub struct BenchSuite {
    suite_name: &'static str,
    warmup: Duration,
    measure: Duration,
    benches: Vec<(String, BenchFn)>,
}

impl BenchSuite {
    pub fn new(suite_name: &'static str) -> Self {
        // `cargo bench -- --quick` (or env) shrinks the budget; integration
        // tests exercising the harness use the env knob.
        let quick = std::env::args().any(|a| a == "--quick")
            || std::env::var("CONVOFFLOAD_BENCH_QUICK").is_ok();
        let (warmup, measure) = if quick {
            (Duration::from_millis(20), Duration::from_millis(80))
        } else {
            (Duration::from_millis(300), Duration::from_millis(1500))
        };
        BenchSuite { suite_name, warmup, measure, benches: Vec::new() }
    }

    /// Register a benchmark. The closure runs one iteration and returns a
    /// value-dependent u64 (fed to black_box) so work cannot be elided.
    pub fn bench<F>(&mut self, name: &str, mut f: F)
    where
        F: FnMut() -> u64 + 'static,
    {
        self.benches
            .push((name.to_string(), Box::new(move || black_box(f()))));
    }

    /// Run all registered benchmarks (honouring `--bench`-style substring
    /// filters passed on the command line) and print a report.
    pub fn run(mut self) -> Vec<Measurement> {
        let filters: Vec<String> = std::env::args()
            .skip(1)
            .filter(|a| !a.starts_with("--"))
            .collect();
        println!("## bench suite: {}", self.suite_name);
        let mut out = Vec::new();
        for (name, f) in self.benches.iter_mut() {
            if !filters.is_empty()
                && !filters.iter().any(|flt| name.contains(flt.as_str()))
            {
                continue;
            }
            let m = measure_one(name, f, self.warmup, self.measure);
            println!("{}", m.report_line());
            out.push(m);
        }
        out
    }
}

fn measure_one(
    name: &str,
    f: &mut BenchFn,
    warmup: Duration,
    measure: Duration,
) -> Measurement {
    // Warm-up and iteration-count calibration.
    let w0 = Instant::now();
    let mut warm_iters: u64 = 0;
    while w0.elapsed() < warmup {
        black_box(f());
        warm_iters += 1;
    }
    let per_iter = warmup.as_nanos() as f64 / warm_iters.max(1) as f64;
    // Aim for ~50 samples over the measurement budget.
    let batch = ((measure.as_nanos() as f64 / 50.0 / per_iter.max(1.0))
        .ceil() as u64)
        .max(1);

    let mut samples: Vec<f64> = Vec::new(); // ns per iteration
    let mut total_iters = 0u64;
    let m0 = Instant::now();
    while m0.elapsed() < measure || samples.len() < 10 {
        let t = Instant::now();
        for _ in 0..batch {
            black_box(f());
        }
        let ns = t.elapsed().as_nanos() as f64 / batch as f64;
        samples.push(ns);
        total_iters += batch;
        if samples.len() > 5000 {
            break;
        }
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let median = samples[samples.len() / 2];
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    let p95 = samples[((samples.len() as f64 * 0.95) as usize).min(samples.len() - 1)];
    Measurement {
        name: name.to_string(),
        iterations: total_iters,
        median: Duration::from_nanos(median as u64),
        mean: Duration::from_nanos(mean as u64),
        p95: Duration::from_nanos(p95 as u64),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        std::env::set_var("CONVOFFLOAD_BENCH_QUICK", "1");
        let mut suite = BenchSuite::new("selftest");
        suite.bench("sum", || (0..100u64).sum::<u64>());
        let results = suite.run();
        assert_eq!(results.len(), 1);
        assert!(results[0].iterations > 0);
        assert!(results[0].median.as_nanos() > 0);
    }

    #[test]
    fn format_durations() {
        assert!(fmt_dur(Duration::from_nanos(500)).contains("ns"));
        assert!(fmt_dur(Duration::from_micros(500)).contains("µs"));
        assert!(fmt_dur(Duration::from_millis(500)).contains("ms"));
        assert!(fmt_dur(Duration::from_secs(2)).contains(" s"));
    }
}

//! Scoped parallel map (offline rayon substitute).
//!
//! The figure grids (Fig. 12/13) are embarrassingly parallel across cells;
//! [`parallel_map`] fans work out over `std::thread::scope` with a shared
//! atomic work index, preserving input order in the output.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Number of worker threads to use (`CONVOFFLOAD_THREADS` override).
pub fn default_threads() -> usize {
    if let Ok(v) = std::env::var("CONVOFFLOAD_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
        .min(16)
}

/// Apply `f` to every item in parallel, returning results in input order.
///
/// `f` must be `Sync` (called concurrently from many threads); items are
/// claimed with an atomic cursor so imbalanced work self-balances.
pub fn parallel_map<T, R, F>(items: &[T], threads: usize, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let threads = threads.clamp(1, n);
    if threads == 1 {
        return items.iter().map(|x| f(x)).collect();
    }
    let cursor = AtomicUsize::new(0);
    let results: Vec<Mutex<Option<R>>> =
        (0..n).map(|_| Mutex::new(None)).collect();

    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                let i = cursor.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let r = f(&items[i]);
                *results[i].lock().unwrap() = Some(r);
            });
        }
    });

    results
        .into_iter()
        .map(|m| m.into_inner().unwrap().expect("worker filled every slot"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order() {
        let items: Vec<u64> = (0..100).collect();
        let out = parallel_map(&items, 8, |&x| x * 2);
        assert_eq!(out, (0..100).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn single_thread_path() {
        let items = vec![1u32, 2, 3];
        assert_eq!(parallel_map(&items, 1, |&x| x + 1), vec![2, 3, 4]);
    }

    #[test]
    fn empty_input() {
        let items: Vec<u32> = vec![];
        assert!(parallel_map(&items, 4, |&x| x).is_empty());
    }

    #[test]
    fn imbalanced_work_completes() {
        let items: Vec<u64> = (0..32).collect();
        let out = parallel_map(&items, 4, |&x| {
            // wildly imbalanced busywork
            let mut acc = 0u64;
            for i in 0..(x * 1000) {
                acc = acc.wrapping_add(i);
            }
            std::hint::black_box(acc);
            x
        });
        assert_eq!(out, items);
    }

    #[test]
    fn default_threads_positive() {
        assert!(default_threads() >= 1);
    }
}

//! Scoped parallel map (offline rayon substitute).
//!
//! The figure grids (Fig. 12/13) are embarrassingly parallel across cells;
//! [`parallel_map`] fans work out over `std::thread::scope` with a shared
//! atomic work index, preserving input order in the output.
//!
//! Both entry points are **panic-hardened**: a panic inside `f` is caught at
//! the item that raised it, so one poisoned work item can never tear down
//! the scope and take every other item's result with it (the failure mode
//! that used to abort a whole `plan-batch` when a single portfolio lane
//! crashed). [`parallel_map`] preserves its historical contract by re-raising
//! the first panic *after* the scope joins cleanly; [`parallel_map_catch`]
//! converts panics into `None` slots for supervisors (the planner's recovery
//! layer) that want to keep the survivors.

use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Mutex;

/// Number of worker threads to use (`CONVOFFLOAD_THREADS` override).
pub fn default_threads() -> usize {
    if let Ok(v) = std::env::var("CONVOFFLOAD_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
        .min(16)
}

/// Lock a pool-internal mutex even if a previous holder panicked while it
/// held the guard: every value behind these locks is written in a single
/// assignment or push, so a poisoned lock still guards structurally sound
/// data — recover the guard and keep going.
fn lock_ignore_poison<T>(m: &Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
}

/// Apply `f` to every item in parallel, returning results in input order.
///
/// `f` must be `Sync` (called concurrently from many threads); items are
/// claimed with an atomic cursor so imbalanced work self-balances.
///
/// Panic semantics: if any `f(item)` panics, every *other* item still runs
/// to completion, the scope joins, and the first panic payload is re-raised
/// on the caller's thread — same observable contract as before hardening,
/// minus the collateral loss of sibling work (and of any unrelated caller
/// sharing the scope).
pub fn parallel_map<T, R, F>(items: &[T], threads: usize, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    let (results, mut panics) = parallel_map_catch(items, threads, f);
    if let Some(payload) = panics.pop() {
        resume_unwind(payload);
    }
    results
        .into_iter()
        .map(|r| r.expect("no panic implies every slot is filled"))
        .collect()
}

/// Panic-tolerant parallel map: apply `f` to every item, catching panics
/// per item. Returns the results in input order (`None` where `f` panicked)
/// plus the captured panic payloads in claim order.
///
/// This is the supervision primitive behind the planner's recovery layer: a
/// crashed portfolio lane costs one lane, not the batch.
pub fn parallel_map_catch<T, R, F>(
    items: &[T],
    threads: usize,
    f: F,
) -> (Vec<Option<R>>, Vec<Box<dyn std::any::Any + Send>>)
where
    T: Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    parallel_map_catch_cancel(items, threads, None, f)
}

/// [`parallel_map_catch`] with cooperative cancellation: when `cancel` is
/// `Some` and the flag is observed set, workers stop *claiming* new items —
/// unclaimed items are left as `None` slots. Items already running are not
/// interrupted here (long-running item bodies are expected to poll the same
/// flag themselves, as the annealers do), so a cancelled map still joins
/// cleanly and returns every result that finished.
///
/// With `cancel: None` (or a flag that never fires) the behaviour — claim
/// order, result order, panic capture — is exactly [`parallel_map_catch`].
pub fn parallel_map_catch_cancel<T, R, F>(
    items: &[T],
    threads: usize,
    cancel: Option<&AtomicBool>,
    f: F,
) -> (Vec<Option<R>>, Vec<Box<dyn std::any::Any + Send>>)
where
    T: Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    let n = items.len();
    if n == 0 {
        return (Vec::new(), Vec::new());
    }
    let threads = threads.clamp(1, n);
    let cancelled = || cancel.is_some_and(|flag| flag.load(Ordering::Relaxed));
    let panics: Mutex<Vec<Box<dyn std::any::Any + Send>>> = Mutex::new(Vec::new());
    let run_one = |i: usize, out: &Mutex<Option<R>>| {
        match catch_unwind(AssertUnwindSafe(|| f(&items[i]))) {
            Ok(r) => *lock_ignore_poison(out) = Some(r),
            Err(payload) => lock_ignore_poison(&panics).push(payload),
        }
    };
    let results: Vec<Mutex<Option<R>>> = (0..n).map(|_| Mutex::new(None)).collect();
    if threads == 1 {
        for (i, slot) in results.iter().enumerate() {
            if cancelled() {
                break;
            }
            run_one(i, slot);
        }
    } else {
        let cursor = AtomicUsize::new(0);
        std::thread::scope(|scope| {
            for _ in 0..threads {
                scope.spawn(|| loop {
                    if cancelled() {
                        break;
                    }
                    let i = cursor.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    run_one(i, &results[i]);
                });
            }
        });
    }
    (
        results
            .into_iter()
            .map(|m| m.into_inner().unwrap_or_else(|p| p.into_inner()))
            .collect(),
        panics.into_inner().unwrap_or_else(|p| p.into_inner()),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order() {
        let items: Vec<u64> = (0..100).collect();
        let out = parallel_map(&items, 8, |&x| x * 2);
        assert_eq!(out, (0..100).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn single_thread_path() {
        let items = vec![1u32, 2, 3];
        assert_eq!(parallel_map(&items, 1, |&x| x + 1), vec![2, 3, 4]);
    }

    #[test]
    fn empty_input() {
        let items: Vec<u32> = vec![];
        assert!(parallel_map(&items, 4, |&x| x).is_empty());
    }

    #[test]
    fn imbalanced_work_completes() {
        let items: Vec<u64> = (0..32).collect();
        let out = parallel_map(&items, 4, |&x| {
            // wildly imbalanced busywork
            let mut acc = 0u64;
            for i in 0..(x * 1000) {
                acc = acc.wrapping_add(i);
            }
            std::hint::black_box(acc);
            x
        });
        assert_eq!(out, items);
    }

    #[test]
    fn default_threads_positive() {
        assert!(default_threads() >= 1);
    }

    /// One panicking item loses exactly its own slot; all survivors land in
    /// order, and the payload is reported — on every thread-count path.
    #[test]
    fn catch_isolates_a_panicking_item() {
        let items: Vec<u64> = (0..32).collect();
        for threads in [1usize, 2, 8] {
            let (out, panics) = parallel_map_catch(&items, threads, |&x| {
                if x == 13 {
                    panic!("lane 13 crashed");
                }
                x * 10
            });
            assert_eq!(out.len(), 32, "threads={threads}");
            assert_eq!(panics.len(), 1, "threads={threads}");
            for (i, slot) in out.iter().enumerate() {
                if i == 13 {
                    assert!(slot.is_none(), "threads={threads}");
                } else {
                    assert_eq!(*slot, Some(i as u64 * 10), "threads={threads}");
                }
            }
            let msg = panics[0].downcast_ref::<&str>().copied().unwrap_or("");
            assert_eq!(msg, "lane 13 crashed");
        }
    }

    /// `parallel_map` still surfaces the panic to its caller — but only
    /// after every sibling item has completed (the scope joins cleanly).
    #[test]
    fn map_still_propagates_the_panic() {
        let items: Vec<u64> = (0..8).collect();
        let completed = AtomicUsize::new(0);
        let caught = std::panic::catch_unwind(AssertUnwindSafe(|| {
            parallel_map(&items, 4, |&x| {
                if x == 3 {
                    panic!("boom");
                }
                completed.fetch_add(1, Ordering::Relaxed);
                x
            })
        }));
        assert!(caught.is_err());
        assert_eq!(completed.load(Ordering::Relaxed), 7, "survivors ran to completion");
    }

    /// A pre-set cancel flag means no item is ever claimed; a `None` flag
    /// leaves the historical contract untouched.
    #[test]
    fn cancel_flag_skips_unclaimed_items() {
        let items: Vec<u64> = (0..16).collect();
        for threads in [1usize, 4] {
            let flag = AtomicBool::new(true);
            let (out, panics) =
                parallel_map_catch_cancel(&items, threads, Some(&flag), |&x| x * 2);
            assert!(panics.is_empty(), "threads={threads}");
            assert!(
                out.iter().all(Option::is_none),
                "pre-cancelled map must not claim work (threads={threads})"
            );

            let flag = AtomicBool::new(false);
            let (out, _) =
                parallel_map_catch_cancel(&items, threads, Some(&flag), |&x| x * 2);
            assert_eq!(
                out,
                (0..16).map(|x| Some(x * 2)).collect::<Vec<_>>(),
                "unfired flag must change nothing (threads={threads})"
            );
        }
    }

    /// A flag fired mid-run stops claims but keeps every finished result.
    /// Single-thread path so the cut point is exact and the test cannot race.
    #[test]
    fn cancel_mid_run_keeps_finished_results() {
        let items: Vec<u64> = (0..16).collect();
        let flag = AtomicBool::new(false);
        let (out, panics) = parallel_map_catch_cancel(&items, 1, Some(&flag), |&x| {
            if x == 3 {
                flag.store(true, Ordering::Relaxed);
            }
            x
        });
        assert!(panics.is_empty());
        for (i, slot) in out.iter().enumerate() {
            if i <= 3 {
                assert_eq!(*slot, Some(i as u64), "items before the cut finished");
            } else {
                assert!(slot.is_none(), "items after the cut were never claimed");
            }
        }
    }

    /// Many panics at once: every payload is captured, every survivor kept.
    #[test]
    fn catch_collects_multiple_panics() {
        let items: Vec<u64> = (0..64).collect();
        let (out, panics) = parallel_map_catch(&items, 8, |&x| {
            if x % 2 == 1 {
                panic!("odd lane");
            }
            x
        });
        assert_eq!(panics.len(), 32);
        assert_eq!(out.iter().filter(|s| s.is_some()).count(), 32);
        for (i, slot) in out.iter().enumerate() {
            assert_eq!(slot.is_some(), i % 2 == 0);
        }
    }
}

//! Minimal JSON value model, writer and parser.
//!
//! Used for simulation traces, the AOT artifact manifest
//! (`artifacts/manifest.json`), and strategy import/export. Supports the full
//! JSON grammar except `\u` surrogate pairs beyond the BMP (sufficient for
//! machine-generated files, which are all-ASCII here).

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Object keys are ordered (BTreeMap) so output is canonical.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// A number (stored as `f64`, like JSON itself).
    Num(f64),
    /// A string.
    Str(String),
    /// An array.
    Arr(Vec<Json>),
    /// An object (ordered keys → canonical output).
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// An empty JSON object (builder entry point for [`Json::set`]).
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    /// Insert into an object (panics if `self` is not an object).
    pub fn set(&mut self, key: &str, val: impl Into<Json>) -> &mut Self {
        match self {
            Json::Obj(m) => {
                m.insert(key.to_string(), val.into());
            }
            _ => panic!("Json::set on non-object"),
        }
        self
    }

    /// Member of an object by key (`None` on non-objects / absent keys).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Numeric value, if this is a number.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    /// Non-negative integer value, if this is a whole number.
    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().and_then(|x| {
            if x >= 0.0 && x.fract() == 0.0 {
                Some(x as u64)
            } else {
                None
            }
        })
    }

    /// [`Json::as_u64`] narrowed to `usize`.
    pub fn as_usize(&self) -> Option<usize> {
        self.as_u64().map(|x| x as usize)
    }

    /// String value, if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Boolean value, if this is a boolean.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Array items, if this is an array.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// Serialize compactly.
    pub fn to_string_compact(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Serialize with 2-space indentation.
    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 9e15 {
                    out.push_str(&format!("{}", *x as i64));
                } else {
                    out.push_str(&format!("{x}"));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                if !items.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !map.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(n) = indent {
        out.push('\n');
        for _ in 0..n * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32))
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

impl From<bool> for Json {
    fn from(b: bool) -> Json {
        Json::Bool(b)
    }
}
impl From<f64> for Json {
    fn from(x: f64) -> Json {
        Json::Num(x)
    }
}
impl From<u64> for Json {
    fn from(x: u64) -> Json {
        Json::Num(x as f64)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Json {
        Json::Num(x as f64)
    }
}
impl From<i64> for Json {
    fn from(x: i64) -> Json {
        Json::Num(x as f64)
    }
}
impl From<u32> for Json {
    fn from(x: u32) -> Json {
        Json::Num(x as f64)
    }
}
impl From<&str> for Json {
    fn from(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Json {
        Json::Str(s)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Json {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

/// Parse error with byte offset.
#[derive(Debug, Clone, PartialEq)]
pub struct ParseError {
    /// Byte offset the parse failed at.
    pub offset: usize,
    /// What went wrong.
    pub message: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.offset, self.message)
    }
}
impl std::error::Error for ParseError {}

/// Parse a JSON document (must consume the whole input, modulo whitespace).
pub fn parse(input: &str) -> Result<Json, ParseError> {
    let mut p = Parser { bytes: input.as_bytes(), pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(p.err("trailing characters"));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError { offset: self.pos, message: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), ParseError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, lit: &str, val: Json) -> Result<Json, ParseError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(val)
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{0008}'),
                        Some(b'f') => s.push('\u{000C}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.bytes.len() {
                                return Err(self.err("truncated \\u escape"));
                            }
                            let hex = std::str::from_utf8(
                                &self.bytes[self.pos + 1..self.pos + 5],
                            )
                            .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            s.push(
                                char::from_u32(cp)
                                    .ok_or_else(|| self.err("bad codepoint"))?,
                            );
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let rest = &self.bytes[self.pos..];
                    let text = std::str::from_utf8(rest)
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = text.chars().next().unwrap();
                    s.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalar() {
        for src in ["null", "true", "false", "0", "-12", "3.5", "\"hi\""] {
            let v = parse(src).unwrap();
            assert_eq!(parse(&v.to_string_compact()).unwrap(), v);
        }
    }

    #[test]
    fn roundtrip_nested() {
        let src = r#"{"a":[1,2,{"b":null}],"c":"x\ny","d":true}"#;
        let v = parse(src).unwrap();
        let out = v.to_string_compact();
        assert_eq!(parse(&out).unwrap(), v);
        // pretty output also round-trips
        assert_eq!(parse(&v.to_string_pretty()).unwrap(), v);
    }

    #[test]
    fn object_access() {
        let mut o = Json::obj();
        o.set("n", 42u64).set("s", "str").set("b", true);
        assert_eq!(o.get("n").unwrap().as_u64(), Some(42));
        assert_eq!(o.get("s").unwrap().as_str(), Some("str"));
        assert_eq!(o.get("b").unwrap().as_bool(), Some(true));
        assert!(o.get("missing").is_none());
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(parse("1 2").is_err());
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("\"unterminated").is_err());
    }

    #[test]
    fn escapes() {
        let v = Json::Str("a\"b\\c\nd\u{0001}".to_string());
        let s = v.to_string_compact();
        assert_eq!(parse(&s).unwrap(), v);
    }

    #[test]
    fn unicode_escape_parse() {
        assert_eq!(
            parse(r#""A""#).unwrap(),
            Json::Str("A".to_string())
        );
    }

    #[test]
    fn integers_print_without_fraction() {
        assert_eq!(Json::Num(5.0).to_string_compact(), "5");
        assert_eq!(Json::Num(5.25).to_string_compact(), "5.25");
    }
}

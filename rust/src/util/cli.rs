//! Declarative command-line flag parsing (offline clap substitute).
//!
//! Supports `--flag value`, `--flag=value`, boolean `--flag`, positional
//! arguments, per-subcommand help text, and typed accessors with defaults.

use std::collections::BTreeMap;

/// Description of one flag for parsing + help output.
#[derive(Debug, Clone)]
pub struct FlagSpec {
    /// Flag name (without the leading `--`).
    pub name: &'static str,
    /// One-line help text.
    pub help: &'static str,
    /// `true` if the flag takes a value, `false` for boolean switches.
    pub takes_value: bool,
    /// Default value, if any (only meaningful for value flags).
    pub default: Option<&'static str>,
}

/// Parsed arguments for one (sub)command.
#[derive(Debug, Clone, Default)]
pub struct Args {
    flags: BTreeMap<String, String>,
    bools: BTreeMap<String, bool>,
    /// Positional (non-flag) arguments, in order.
    pub positional: Vec<String>,
}

impl Args {
    /// Value of a value flag, if present (defaults pre-applied).
    pub fn get(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    /// True when the boolean switch was passed.
    pub fn get_bool(&self, name: &str) -> bool {
        self.bools.get(name).copied().unwrap_or(false)
    }

    /// Parse a flag's value as `usize` (malformed input is an error).
    pub fn get_usize(&self, name: &str) -> Result<Option<usize>, String> {
        match self.get(name) {
            None => Ok(None),
            Some(s) => s
                .parse::<usize>()
                .map(Some)
                .map_err(|_| format!("--{name}: expected an integer, got '{s}'")),
        }
    }

    /// Parse a flag's value as `u64` (malformed input is an error).
    pub fn get_u64(&self, name: &str) -> Result<Option<u64>, String> {
        match self.get(name) {
            None => Ok(None),
            Some(s) => s
                .parse::<u64>()
                .map(Some)
                .map_err(|_| format!("--{name}: expected an integer, got '{s}'")),
        }
    }

    /// Parse a flag's value as `f64` (malformed input is an error).
    pub fn get_f64(&self, name: &str) -> Result<Option<f64>, String> {
        match self.get(name) {
            None => Ok(None),
            Some(s) => s
                .parse::<f64>()
                .map(Some)
                .map_err(|_| format!("--{name}: expected a number, got '{s}'")),
        }
    }
}

/// Parse `argv` against `specs`. Unknown `--flags` are errors.
pub fn parse(
    argv: &[String],
    specs: &[FlagSpec],
) -> Result<Args, String> {
    let mut args = Args::default();
    for spec in specs {
        if let (true, Some(d)) = (spec.takes_value, spec.default) {
            args.flags.insert(spec.name.to_string(), d.to_string());
        }
    }
    let mut i = 0;
    while i < argv.len() {
        let a = &argv[i];
        if let Some(stripped) = a.strip_prefix("--") {
            let (name, inline_val) = match stripped.split_once('=') {
                Some((n, v)) => (n, Some(v.to_string())),
                None => (stripped, None),
            };
            let spec = specs
                .iter()
                .find(|s| s.name == name)
                .ok_or_else(|| format!("unknown flag --{name}"))?;
            if spec.takes_value {
                let val = match inline_val {
                    Some(v) => v,
                    None => {
                        i += 1;
                        argv.get(i)
                            .cloned()
                            .ok_or_else(|| format!("--{name} needs a value"))?
                    }
                };
                args.flags.insert(name.to_string(), val);
            } else {
                if inline_val.is_some() {
                    return Err(format!("--{name} takes no value"));
                }
                args.bools.insert(name.to_string(), true);
            }
        } else {
            args.positional.push(a.clone());
        }
        i += 1;
    }
    Ok(args)
}

/// Structured CLI failure, split by *whose fault it was* so `main` can map
/// each class to a distinct process exit code: malformed input (unknown
/// flags, unparseable values, invalid geometry/spec files — the caller can
/// fix the invocation) exits 2; runtime failures (I/O, simulation errors —
/// retrying the same invocation might work) exit 1. Every constructor site
/// is explicit: the blanket `From<String>` conversion used by `?` defaults
/// to [`CliError::Failure`], and input-validation sites opt in to
/// [`CliError::Invalid`] via [`invalid`] / `map_err(CliError::Invalid)`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum CliError {
    /// The invocation itself was malformed — bad flag, bad value, bad
    /// config/spec file contents. Maps to exit code 2.
    Invalid(String),
    /// The invocation was well-formed but the work failed. Exit code 1.
    Failure(String),
}

impl CliError {
    /// The process exit code this error class maps to.
    pub fn exit_code(&self) -> u8 {
        match self {
            CliError::Invalid(_) => 2,
            CliError::Failure(_) => 1,
        }
    }

    /// The human-readable message.
    pub fn message(&self) -> &str {
        match self {
            CliError::Invalid(m) | CliError::Failure(m) => m,
        }
    }
}

impl From<String> for CliError {
    fn from(m: String) -> Self {
        CliError::Failure(m)
    }
}

impl From<&str> for CliError {
    fn from(m: &str) -> Self {
        CliError::Failure(m.to_string())
    }
}

impl std::fmt::Display for CliError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CliError::Invalid(m) => write!(f, "invalid input: {m}"),
            CliError::Failure(m) => write!(f, "{m}"),
        }
    }
}

/// Shorthand for tagging a `Result<_, String>` as malformed input.
pub fn invalid<T>(r: Result<T, String>) -> Result<T, CliError> {
    r.map_err(CliError::Invalid)
}

/// Render a help block for a command.
pub fn help(command: &str, about: &str, specs: &[FlagSpec]) -> String {
    let mut out = format!("{command} — {about}\n\nFlags:\n");
    for s in specs {
        let arg = if s.takes_value { format!("--{} <v>", s.name) } else { format!("--{}", s.name) };
        let def = match s.default {
            Some(d) if s.takes_value => format!(" [default: {d}]"),
            _ => String::new(),
        };
        out.push_str(&format!("  {arg:<24} {}{def}\n", s.help));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn specs() -> Vec<FlagSpec> {
        vec![
            FlagSpec { name: "layer", help: "layer preset", takes_value: true, default: Some("lenet5-conv1") },
            FlagSpec { name: "group", help: "group size", takes_value: true, default: None },
            FlagSpec { name: "verbose", help: "chatty", takes_value: false, default: None },
        ]
    }

    fn argv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn defaults_apply() {
        let a = parse(&argv(&[]), &specs()).unwrap();
        assert_eq!(a.get("layer"), Some("lenet5-conv1"));
        assert_eq!(a.get("group"), None);
        assert!(!a.get_bool("verbose"));
    }

    #[test]
    fn space_and_equals_forms() {
        let a = parse(&argv(&["--group", "4", "--layer=x"]), &specs()).unwrap();
        assert_eq!(a.get_usize("group").unwrap(), Some(4));
        assert_eq!(a.get("layer"), Some("x"));
    }

    #[test]
    fn bool_and_positional() {
        let a = parse(&argv(&["--verbose", "pos1", "pos2"]), &specs()).unwrap();
        assert!(a.get_bool("verbose"));
        assert_eq!(a.positional, vec!["pos1", "pos2"]);
    }

    #[test]
    fn errors() {
        assert!(parse(&argv(&["--nope"]), &specs()).is_err());
        assert!(parse(&argv(&["--group"]), &specs()).is_err());
        assert!(parse(&argv(&["--verbose=1"]), &specs()).is_err());
        let a = parse(&argv(&["--group", "abc"]), &specs()).unwrap();
        assert!(a.get_usize("group").is_err());
    }

    #[test]
    fn help_renders() {
        let h = help("simulate", "run a strategy", &specs());
        assert!(h.contains("--layer"));
        assert!(h.contains("default: lenet5-conv1"));
    }

    #[test]
    fn cli_error_classes_map_to_exit_codes() {
        let bad = CliError::Invalid("bad flag".into());
        assert_eq!(bad.exit_code(), 2);
        assert_eq!(bad.to_string(), "invalid input: bad flag");
        let fail: CliError = String::from("disk on fire").into();
        assert_eq!(fail.exit_code(), 1);
        assert_eq!(fail, CliError::Failure("disk on fire".into()));
        assert_eq!(invalid::<()>(Err("x".into())), Err(CliError::Invalid("x".into())));
        assert_eq!(invalid(Ok(3)), Ok(3));
    }
}

//! CSV reading and writing (RFC-4180 subset: quoted fields, embedded commas,
//! quotes and newlines).
//!
//! Used for strategy import/export — the paper's simulator accepts “a strategy
//! that is user defined or from an ILP solver CSV file” (§6) — and for the
//! figure-series outputs under `figures/`.

/// Write rows to CSV text. Fields are quoted only when necessary.
pub fn write(rows: &[Vec<String>]) -> String {
    let mut out = String::new();
    for row in rows {
        for (i, field) in row.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            if field.contains([',', '"', '\n', '\r']) {
                out.push('"');
                for c in field.chars() {
                    if c == '"' {
                        out.push('"');
                    }
                    out.push(c);
                }
                out.push('"');
            } else {
                out.push_str(field);
            }
        }
        out.push('\n');
    }
    out
}

/// Parse CSV text into rows of fields. Accepts both `\n` and `\r\n` line ends;
/// skips a trailing empty line.
pub fn parse(text: &str) -> Result<Vec<Vec<String>>, String> {
    let mut rows = Vec::new();
    let mut row: Vec<String> = Vec::new();
    let mut field = String::new();
    let mut chars = text.chars().peekable();
    let mut in_quotes = false;
    let mut any = false;

    while let Some(c) = chars.next() {
        any = true;
        if in_quotes {
            match c {
                '"' => {
                    if chars.peek() == Some(&'"') {
                        chars.next();
                        field.push('"');
                    } else {
                        in_quotes = false;
                    }
                }
                c => field.push(c),
            }
        } else {
            match c {
                '"' => {
                    if !field.is_empty() {
                        return Err("quote inside unquoted field".to_string());
                    }
                    in_quotes = true;
                }
                ',' => {
                    row.push(std::mem::take(&mut field));
                }
                '\r' => {
                    if chars.peek() == Some(&'\n') {
                        chars.next();
                    }
                    row.push(std::mem::take(&mut field));
                    rows.push(std::mem::take(&mut row));
                }
                '\n' => {
                    row.push(std::mem::take(&mut field));
                    rows.push(std::mem::take(&mut row));
                }
                c => field.push(c),
            }
        }
    }
    if in_quotes {
        return Err("unterminated quoted field".to_string());
    }
    if any && (!field.is_empty() || !row.is_empty()) {
        row.push(field);
        rows.push(row);
    }
    Ok(rows)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rows(v: &[&[&str]]) -> Vec<Vec<String>> {
        v.iter()
            .map(|r| r.iter().map(|s| s.to_string()).collect())
            .collect()
    }

    #[test]
    fn simple_roundtrip() {
        let r = rows(&[&["a", "b", "c"], &["1", "2", "3"]]);
        assert_eq!(parse(&write(&r)).unwrap(), r);
    }

    #[test]
    fn quoting_roundtrip() {
        let r = rows(&[&["a,b", "c\"d", "e\nf", "plain"]]);
        assert_eq!(parse(&write(&r)).unwrap(), r);
    }

    #[test]
    fn crlf_lines() {
        let parsed = parse("a,b\r\nc,d\r\n").unwrap();
        assert_eq!(parsed, rows(&[&["a", "b"], &["c", "d"]]));
    }

    #[test]
    fn no_trailing_newline() {
        let parsed = parse("a,b\nc,d").unwrap();
        assert_eq!(parsed, rows(&[&["a", "b"], &["c", "d"]]));
    }

    #[test]
    fn empty_fields() {
        let parsed = parse("a,,c\n,,\n").unwrap();
        assert_eq!(parsed, rows(&[&["a", "", "c"], &["", "", ""]]));
    }

    #[test]
    fn rejects_bad_quotes() {
        assert!(parse("ab\"c,d\n").is_err());
        assert!(parse("\"unterminated\n").is_err());
    }

    #[test]
    fn empty_input() {
        assert_eq!(parse("").unwrap(), Vec::<Vec<String>>::new());
    }
}

//! In-tree utility substrates.
//!
//! The build is fully offline (only the `xla` crate closure is vendored), so
//! the small generic pieces a project would normally pull from crates.io are
//! implemented here instead:
//!
//! * [`rng`] — deterministic xorshift/SplitMix RNG (rand substitute) used by
//!   the local-search polisher and the property-test helper;
//! * [`json`] — minimal JSON value model, writer and parser (serde_json
//!   substitute) used for traces, manifests and strategy files;
//! * [`csv`] — CSV reader/writer used for strategy import/export (the paper's
//!   “ILP solver CSV file”) and the figure outputs;
//! * [`cli`] — a tiny declarative flag parser (clap substitute);
//! * [`fsio`] — crash-tolerant file writes (temp file + atomic rename) for
//!   the persistent strategy caches;
//! * [`bench`] — a criterion-style measurement harness for `cargo bench`;
//! * [`proptest`] — a property-testing helper (generators + shrinking-lite);
//! * [`hash`] — stable FNV-1a hashing for the strategy cache's filenames.

pub mod bench;
pub mod cli;
pub mod csv;
pub mod fsio;
pub mod hash;
pub mod json;
pub mod pool;
pub mod proptest;
pub mod rng;

//! Crash-tolerant file writes (temp file + atomic rename).
//!
//! The strategy caches persist planning results across processes; a plain
//! `std::fs::write` that dies mid-call leaves a truncated file behind, which
//! the next reader would see as corruption. [`atomic_write`] writes the full
//! contents to a sibling temporary file first and only then renames it over
//! the destination — on POSIX, `rename(2)` within one directory is atomic,
//! so readers observe either the old complete file or the new complete file,
//! never a prefix.

use std::io::Write;
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};

/// Process-wide counter so concurrent writers to the *same* destination use
/// distinct temp names (two threads racing `put` on one cache shard must not
/// truncate each other's temp file mid-write).
static TEMP_SEQ: AtomicU64 = AtomicU64::new(0);

/// Write `contents` to `path` atomically: full contents land in
/// `<path>.tmp-<pid>-<seq>` (same directory, so the rename cannot cross a
/// filesystem boundary), the file is flushed, then renamed over `path`.
///
/// Concurrent callers on the same path are safe: each uses a unique temp
/// file, and the last rename wins with a complete file either way.
pub fn atomic_write(path: &Path, contents: &str) -> Result<(), String> {
    let dir = path.parent().filter(|d| !d.as_os_str().is_empty());
    let file_name = path
        .file_name()
        .ok_or_else(|| format!("atomic write target has no file name: {}", path.display()))?;
    let seq = TEMP_SEQ.fetch_add(1, Ordering::Relaxed);
    let tmp_name = format!(
        ".{}.tmp-{}-{}",
        file_name.to_string_lossy(),
        std::process::id(),
        seq
    );
    let tmp = match dir {
        Some(d) => d.join(&tmp_name),
        None => std::path::PathBuf::from(&tmp_name),
    };

    let write_res = (|| -> std::io::Result<()> {
        let mut f = std::fs::File::create(&tmp)?;
        f.write_all(contents.as_bytes())?;
        // The rename only guarantees atomic *visibility*; sync_all makes the
        // data durable before the new name can point at it.
        f.sync_all()?;
        Ok(())
    })();
    if let Err(e) = write_res {
        let _ = std::fs::remove_file(&tmp);
        return Err(format!("write {}: {e}", tmp.display()));
    }
    std::fs::rename(&tmp, path).map_err(|e| {
        let _ = std::fs::remove_file(&tmp);
        format!("rename {} -> {}: {e}", tmp.display(), path.display())
    })?;
    // The rename made the new name visible, but the *directory entry* itself
    // is not durable until the directory is synced: a crash here could roll
    // the rename back and resurface the old file (or none). Best-effort —
    // some filesystems refuse fsync on directories, and a lost rename is a
    // stale-cache problem, not a corruption one, so errors are ignored.
    let dir_path = dir.map(Path::to_path_buf).unwrap_or_else(|| ".".into());
    if let Ok(d) = std::fs::File::open(&dir_path) {
        let _ = d.sync_all();
    }
    Ok(())
}

/// Remove orphaned `.{name}.tmp-{pid}-{seq}` siblings left in `dir` by
/// writers that died between `File::create` and the cleanup in
/// [`atomic_write`] — i.e. processes killed mid-write. Returns how many
/// temps were deleted.
///
/// A temp is an orphan when its embedded pid is not this process and (on
/// systems with `/proc`) that pid is no longer alive. Temps owned by the
/// current process are always kept: another thread may be mid-write.
/// Deletion failures are ignored — a concurrent sweeper may have won the
/// race, and a stale temp is harmless until the next sweep.
pub fn sweep_orphan_temps(dir: &Path) -> usize {
    let entries = match std::fs::read_dir(dir) {
        Ok(e) => e,
        Err(_) => return 0,
    };
    let own_pid = std::process::id();
    let mut removed = 0;
    for entry in entries.flatten() {
        let name = entry.file_name();
        let name = name.to_string_lossy();
        let Some(pid) = temp_owner_pid(&name) else { continue };
        if pid == own_pid || pid_is_alive(pid) {
            continue;
        }
        if std::fs::remove_file(entry.path()).is_ok() {
            removed += 1;
        }
    }
    removed
}

/// Parse the owner pid out of a `.{name}.tmp-{pid}-{seq}` temp file name;
/// `None` for anything that is not one of our temps.
fn temp_owner_pid(name: &str) -> Option<u32> {
    if !name.starts_with('.') {
        return None;
    }
    let tail = name.rsplit(".tmp-").next().filter(|t| *t != name)?;
    let (pid, seq) = tail.split_once('-')?;
    if seq.is_empty() || !seq.bytes().all(|b| b.is_ascii_digit()) {
        return None;
    }
    pid.parse::<u32>().ok()
}

/// Is `pid` a live process? Uses `/proc` where available; on systems
/// without it, conservatively reports alive (never delete a temp whose
/// owner we cannot rule out).
fn pid_is_alive(pid: u32) -> bool {
    let proc_root = Path::new("/proc");
    if !proc_root.is_dir() {
        return true;
    }
    proc_root.join(pid.to_string()).exists()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn tmp_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("convoffload-fsio-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn writes_and_overwrites() {
        let dir = tmp_dir("roundtrip");
        let path = dir.join("x.json");
        atomic_write(&path, "first").unwrap();
        assert_eq!(std::fs::read_to_string(&path).unwrap(), "first");
        atomic_write(&path, "second, longer contents").unwrap();
        assert_eq!(
            std::fs::read_to_string(&path).unwrap(),
            "second, longer contents"
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn leaves_no_temp_files_behind() {
        let dir = tmp_dir("no-temps");
        let path = dir.join("x.json");
        for i in 0..10 {
            atomic_write(&path, &format!("gen {i}")).unwrap();
        }
        let names: Vec<String> = std::fs::read_dir(&dir)
            .unwrap()
            .map(|e| e.unwrap().file_name().to_string_lossy().into_owned())
            .collect();
        assert_eq!(names, vec!["x.json".to_string()], "stray files: {names:?}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn concurrent_writers_end_with_a_complete_file() {
        let dir = tmp_dir("concurrent");
        let path = dir.join("shared.json");
        std::thread::scope(|scope| {
            for t in 0..8 {
                let path = &path;
                scope.spawn(move || {
                    for i in 0..25 {
                        let body = format!("writer-{t}-gen-{i}-{}", "y".repeat(64));
                        atomic_write(path, &body).unwrap();
                    }
                });
            }
        });
        // Whatever writer won, the file is one complete record — never a
        // truncated prefix or an interleaving.
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.starts_with("writer-"));
        assert!(text.ends_with(&"y".repeat(64)));
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// Regression for crash-orphaned temps: a temp planted with a dead
    /// foreign pid is swept; temps owned by this process and ordinary files
    /// survive.
    #[test]
    fn sweep_removes_only_dead_foreign_temps() {
        let dir = tmp_dir("sweep");
        std::fs::write(dir.join("shard-000.json"), "{}").unwrap();
        // Dead foreign writer: pid 4e6+ is far above any default pid_max.
        let stale = dir.join(".shard-000.json.tmp-4099999-0");
        std::fs::write(&stale, "truncat").unwrap();
        // Live local writer (this process): must be kept.
        let own = dir.join(format!(
            ".shard-001.json.tmp-{}-7",
            std::process::id()
        ));
        std::fs::write(&own, "mid-write").unwrap();
        // Not one of our temps: must be kept.
        std::fs::write(dir.join(".hidden.tmp-notapid-x"), "?").unwrap();

        assert_eq!(sweep_orphan_temps(&dir), 1);
        assert!(!stale.exists(), "dead-owner temp should be swept");
        assert!(own.exists(), "own temp must survive");
        assert!(dir.join("shard-000.json").exists());
        assert!(dir.join(".hidden.tmp-notapid-x").exists());
        // Idempotent.
        assert_eq!(sweep_orphan_temps(&dir), 0);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn temp_name_parsing_is_strict() {
        assert_eq!(temp_owner_pid(".x.json.tmp-123-4"), Some(123));
        assert_eq!(temp_owner_pid(".x.json.tmp-123-45"), Some(123));
        assert_eq!(temp_owner_pid("x.json.tmp-123-4"), None, "no leading dot");
        assert_eq!(temp_owner_pid(".x.json"), None, "no temp marker");
        assert_eq!(temp_owner_pid(".x.json.tmp-abc-4"), None, "non-numeric pid");
        assert_eq!(temp_owner_pid(".x.json.tmp-123-"), None, "empty seq");
        assert_eq!(temp_owner_pid(".x.json.tmp-123-4x"), None, "bad seq");
    }

    /// Regression for the missing parent-directory fsync after the rename:
    /// the write must still succeed (the sync is best-effort) on explicit
    /// parents, bare file names (implicit `.` parent), and read-only
    /// directories where opening for sync may be refused.
    #[test]
    fn rename_survives_unsyncable_and_implicit_parents() {
        let dir = tmp_dir("dirsync");
        atomic_write(&dir.join("a.json"), "with parent").unwrap();
        assert_eq!(std::fs::read_to_string(dir.join("a.json")).unwrap(), "with parent");

        // Bare relative name: parent is the implicit current directory.
        let old_cwd = std::env::current_dir().unwrap();
        std::env::set_current_dir(&dir).unwrap();
        let res = atomic_write(Path::new("bare.json"), "no parent component");
        std::env::set_current_dir(old_cwd).unwrap();
        res.unwrap();
        assert_eq!(
            std::fs::read_to_string(dir.join("bare.json")).unwrap(),
            "no parent component"
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn missing_directory_is_an_error_not_a_panic() {
        let path = std::env::temp_dir()
            .join(format!("convoffload-fsio-missing-{}", std::process::id()))
            .join("nope")
            .join("x.json");
        assert!(atomic_write(&path, "x").is_err());
    }
}

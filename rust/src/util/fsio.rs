//! Crash-tolerant file writes (temp file + atomic rename).
//!
//! The strategy caches persist planning results across processes; a plain
//! `std::fs::write` that dies mid-call leaves a truncated file behind, which
//! the next reader would see as corruption. [`atomic_write`] writes the full
//! contents to a sibling temporary file first and only then renames it over
//! the destination — on POSIX, `rename(2)` within one directory is atomic,
//! so readers observe either the old complete file or the new complete file,
//! never a prefix.

use std::io::Write;
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};

/// Process-wide counter so concurrent writers to the *same* destination use
/// distinct temp names (two threads racing `put` on one cache shard must not
/// truncate each other's temp file mid-write).
static TEMP_SEQ: AtomicU64 = AtomicU64::new(0);

/// Write `contents` to `path` atomically: full contents land in
/// `<path>.tmp-<pid>-<seq>` (same directory, so the rename cannot cross a
/// filesystem boundary), the file is flushed, then renamed over `path`.
///
/// Concurrent callers on the same path are safe: each uses a unique temp
/// file, and the last rename wins with a complete file either way.
pub fn atomic_write(path: &Path, contents: &str) -> Result<(), String> {
    let dir = path.parent().filter(|d| !d.as_os_str().is_empty());
    let file_name = path
        .file_name()
        .ok_or_else(|| format!("atomic write target has no file name: {}", path.display()))?;
    let seq = TEMP_SEQ.fetch_add(1, Ordering::Relaxed);
    let tmp_name = format!(
        ".{}.tmp-{}-{}",
        file_name.to_string_lossy(),
        std::process::id(),
        seq
    );
    let tmp = match dir {
        Some(d) => d.join(&tmp_name),
        None => std::path::PathBuf::from(&tmp_name),
    };

    let write_res = (|| -> std::io::Result<()> {
        let mut f = std::fs::File::create(&tmp)?;
        f.write_all(contents.as_bytes())?;
        // The rename only guarantees atomic *visibility*; sync_all makes the
        // data durable before the new name can point at it.
        f.sync_all()?;
        Ok(())
    })();
    if let Err(e) = write_res {
        let _ = std::fs::remove_file(&tmp);
        return Err(format!("write {}: {e}", tmp.display()));
    }
    std::fs::rename(&tmp, path).map_err(|e| {
        let _ = std::fs::remove_file(&tmp);
        format!("rename {} -> {}: {e}", tmp.display(), path.display())
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn tmp_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("convoffload-fsio-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn writes_and_overwrites() {
        let dir = tmp_dir("roundtrip");
        let path = dir.join("x.json");
        atomic_write(&path, "first").unwrap();
        assert_eq!(std::fs::read_to_string(&path).unwrap(), "first");
        atomic_write(&path, "second, longer contents").unwrap();
        assert_eq!(
            std::fs::read_to_string(&path).unwrap(),
            "second, longer contents"
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn leaves_no_temp_files_behind() {
        let dir = tmp_dir("no-temps");
        let path = dir.join("x.json");
        for i in 0..10 {
            atomic_write(&path, &format!("gen {i}")).unwrap();
        }
        let names: Vec<String> = std::fs::read_dir(&dir)
            .unwrap()
            .map(|e| e.unwrap().file_name().to_string_lossy().into_owned())
            .collect();
        assert_eq!(names, vec!["x.json".to_string()], "stray files: {names:?}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn concurrent_writers_end_with_a_complete_file() {
        let dir = tmp_dir("concurrent");
        let path = dir.join("shared.json");
        std::thread::scope(|scope| {
            for t in 0..8 {
                let path = &path;
                scope.spawn(move || {
                    for i in 0..25 {
                        let body = format!("writer-{t}-gen-{i}-{}", "y".repeat(64));
                        atomic_write(path, &body).unwrap();
                    }
                });
            }
        });
        // Whatever writer won, the file is one complete record — never a
        // truncated prefix or an interleaving.
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.starts_with("writer-"));
        assert!(text.ends_with(&"y".repeat(64)));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn missing_directory_is_an_error_not_a_panic() {
        let path = std::env::temp_dir()
            .join(format!("convoffload-fsio-missing-{}", std::process::id()))
            .join("nope")
            .join("x.json");
        assert!(atomic_write(&path, "x").is_err());
    }
}

//! Deterministic pseudo-random number generation.
//!
//! `xoshiro256**` seeded through SplitMix64 — the standard construction; fast,
//! high-quality, and fully reproducible across runs, which matters because the
//! “solution polishing” phase of the optimizer (see [`crate::solver::polish`])
//! must produce identical strategies for identical seeds so that figure
//! regeneration is deterministic.

/// xoshiro256** generator.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

/// SplitMix64 step, used for seeding and as a cheap standalone mixer.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Create a generator from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform value in `[0, bound)`. `bound` must be non-zero.
    /// Uses Lemire's multiply-shift rejection method (unbiased).
    #[inline]
    pub fn below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(bound as u128);
            let lo = m as u64;
            if lo >= bound || lo >= (u64::MAX - bound + 1) % bound {
                return (m >> 64) as u64;
            }
        }
    }

    /// Uniform usize in `[0, bound)`.
    #[inline]
    pub fn index(&mut self, bound: usize) -> usize {
        self.below(bound as u64) as usize
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Bernoulli draw with probability `p`.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Uniform value in the inclusive range `[lo, hi]`.
    #[inline]
    pub fn range(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(lo <= hi);
        lo + self.below((hi - lo + 1) as u64) as i64
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.index(i + 1);
            xs.swap(i, j);
        }
    }

    /// Pick a uniformly random element (panics on empty slice).
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.index(xs.len())]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn below_is_in_range() {
        let mut r = Rng::new(7);
        for bound in [1u64, 2, 3, 10, 1000, u64::MAX / 2] {
            for _ in 0..200 {
                assert!(r.below(bound) < bound);
            }
        }
    }

    #[test]
    fn below_covers_small_range() {
        let mut r = Rng::new(9);
        let mut seen = [false; 5];
        for _ in 0..500 {
            seen[r.below(5) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..1000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(11);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        // with overwhelming probability not identity
        assert_ne!(v, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn range_inclusive() {
        let mut r = Rng::new(13);
        let mut hit_lo = false;
        let mut hit_hi = false;
        for _ in 0..2000 {
            let x = r.range(-3, 3);
            assert!((-3..=3).contains(&x));
            hit_lo |= x == -3;
            hit_hi |= x == 3;
        }
        assert!(hit_lo && hit_hi);
    }
}

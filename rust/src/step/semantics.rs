//! Operational semantics of a step (Definition 2) with §2.3 assumption checks.

use crate::conv::ConvLayer;
use crate::platform::{Accelerator, MemoryState};
use crate::step::{Step, StepCost};

/// Why a step is invalid.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum StepError {
    /// `F^inp ⊄ M^inp`: freeing input pixels that are not resident.
    FreeInputNotResident,
    /// `F^ker ⊄ M^ker`.
    FreeKernelNotResident,
    /// `W ⊄ M^out`: writing back outputs that were never computed/held.
    WriteNotResident,
    /// `I^slice ∩ M^inp ≠ ∅` after a1: reloading data already on chip
    /// (wasted bandwidth — the formalism defines `I^slice` as the *missing*
    /// part, Definition 16).
    ReloadingResidentInput,
    /// `K^sub ∩ M^ker ≠ ∅` after a2.
    ReloadingResidentKernel,
    /// A patch in the group lacks input pixels on chip at compute time.
    GroupInputMissing { patch: u32 },
    /// A compute step ran without all kernels resident (S1 requires Λ).
    KernelsMissing,
    /// The group exceeds the accelerator's capacity:
    /// `ops > nbop_PE` (§2.3 third assumption).
    TooManyOps { ops: u64, nbop_pe: u64 },
    /// §2.3 second assumption: loaded data must be directly processed —
    /// `I^slice` must be within the group's footprint.
    LoadedDataNotProcessed,
    /// Peak occupancy exceeded `size_MEM` (Eq. 12 violated).
    MemoryOverflow { occupancy: u64, capacity: u64 },
    /// A patch was computed more than once across the strategy.
    PatchRecomputed { patch: u32 },
    /// Output patch already resident when recomputed into `M^out`.
    OutputCollision { patch: u32 },
}

impl std::fmt::Display for StepError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{self:?}")
    }
}

impl std::error::Error for StepError {}

/// Result of applying one step.
#[derive(Debug, Clone, PartialEq)]
pub struct StepOutcome {
    /// The step's Definition-3 cost decomposition.
    pub cost: StepCost,
    /// `size_i^step` — peak element occupancy during the step (the paper
    /// measures it after loads, with the step's output included).
    pub occupancy: u64,
}

/// Apply `step` to `mem` in action order `a_1..a_6`, mutating the memory
/// state, and return its cost and occupancy.
///
/// `strict` enables the full §2.3 assumption checking (recommended); with
/// `strict = false` only physical impossibilities (freeing or writing absent
/// data, overflowing memory) are errors, which allows exploring deliberately
/// wasteful strategies in the simulator.
pub fn apply(
    layer: &ConvLayer,
    acc: &Accelerator,
    mem: &mut MemoryState,
    step: &Step,
    strict: bool,
) -> Result<StepOutcome, StepError> {
    // a_1: Mt^inp = M^inp ∖ F^inp
    if !step.free_inp.is_subset_of(&mem.inp) {
        return Err(StepError::FreeInputNotResident);
    }
    mem.inp.subtract(&step.free_inp);

    // a_2: Mt^ker = M^ker ∖ F^ker
    if !step.free_ker.is_subset_of(&mem.ker) {
        return Err(StepError::FreeKernelNotResident);
    }
    mem.ker.subtract(&step.free_ker);

    // a_3: Mt^out = M^out ∖ W
    if !step.write.is_subset_of(&mem.out) {
        return Err(StepError::WriteNotResident);
    }
    mem.out.subtract(&step.write);

    // a_4: M^inp = Mt^inp ∪ I^slice
    if strict && !step.load_inp.is_disjoint_from(&mem.inp) {
        return Err(StepError::ReloadingResidentInput);
    }
    mem.inp.union_with(&step.load_inp);

    // a_5: M^ker = Mt^ker ∪ K^sub
    if strict && !step.load_ker.is_disjoint_from(&mem.ker) {
        return Err(StepError::ReloadingResidentKernel);
    }
    mem.ker.union_with(&step.load_ker);

    // a_6: compute the group; Out_i joins M^out.
    let mut macs = 0u64;
    if !step.group.is_empty() {
        // All kernels must be resident (S1 assumption / Property 1).
        if mem.ker.len() != layer.n_kernels {
            return Err(StepError::KernelsMissing);
        }
        // Each group patch must have its full input footprint resident
        // (allocation-free word-masked range checks — hot path).
        for &p in &step.group {
            if !layer.patch_resident(&mem.inp, p) {
                return Err(StepError::GroupInputMissing { patch: p });
            }
        }
        macs = (step.group.len() * layer.ops_per_patch()) as u64;
        if strict && macs > acc.nbop_pe {
            return Err(StepError::TooManyOps { ops: macs, nbop_pe: acc.nbop_pe });
        }
        // §2.3: loaded data must be directly processed in this step.
        if strict {
            let footprint = layer.group_pixels(&step.group);
            if !step.load_inp.is_subset_of(&footprint) {
                return Err(StepError::LoadedDataNotProcessed);
            }
        }
        for &p in &step.group {
            if mem.out.contains(p) {
                return Err(StepError::OutputCollision { patch: p });
            }
            mem.out.insert(p);
        }
    }

    // Occupancy after loads + compute = size_i^step (§2.2).
    let occupancy = mem.occupied_elements(layer);
    if occupancy > acc.size_mem {
        return Err(StepError::MemoryOverflow { occupancy, capacity: acc.size_mem });
    }

    let cost = StepCost {
        loaded_elements: (step.load_inp.len() * layer.c_in
            + step.load_ker.len() * layer.kernel_dims().len()) as u64,
        written_elements: (step.write.len() * layer.c_out()) as u64,
        computed: !step.group.is_empty(),
        macs,
    };
    Ok(StepOutcome { cost, occupancy })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::platform::MemoryState;
    use crate::tensor::PixelSet;

    fn layer() -> ConvLayer {
        ConvLayer::new(2, 5, 5, 3, 3, 2, 1, 1).unwrap()
    }

    fn acc() -> Accelerator {
        Accelerator { t_w: 1, ..Accelerator::paper_eval(200, 10_000) }
    }

    fn load_all_kernels(l: &ConvLayer) -> crate::platform::KernelSet {
        PixelSet::full(l.n_kernels)
    }

    #[test]
    fn first_step_loads_and_computes() {
        let l = layer();
        let mut mem = MemoryState::initial(&l);
        let mut s = Step::noop(l.n_pixels(), l.n_kernels, l.n_patches());
        s.load_inp = l.patch_pixels(0);
        s.load_ker = load_all_kernels(&l);
        s.group = vec![0];
        let out = apply(&l, &acc(), &mut mem, &s, true).unwrap();
        // loads: 9 px × 2 ch + 2 kernels × 18 = 18 + 36
        assert_eq!(out.cost.loaded_elements, 54);
        assert_eq!(out.cost.macs, 36);
        assert!(out.cost.computed);
        // occupancy: inputs 18 + kernels 36 + outputs 1×2
        assert_eq!(out.occupancy, 56);
        assert!(mem.out.contains(0));
    }

    /// Grouped layers shrink per-kernel storage: element accounting must use
    /// `kernel_dims` (C_in/G·H_K·W_K), not the dense C_in·H_K·W_K.
    #[test]
    fn grouped_kernel_element_accounting() {
        let l = ConvLayer::new(4, 5, 5, 3, 3, 4, 1, 1)
            .unwrap()
            .with_groups(4)
            .unwrap(); // depthwise: 4 kernels × 9 elements
        let mut mem = MemoryState::initial(&l);
        let mut s = Step::noop(l.n_pixels(), l.n_kernels, l.n_patches());
        s.load_inp = l.patch_pixels(0);
        s.load_ker = PixelSet::full(l.n_kernels);
        s.group = vec![0];
        let out = apply(&l, &acc(), &mut mem, &s, true).unwrap();
        // loads: 9 px × 4 ch + 4 kernels × 9 = 36 + 36
        assert_eq!(out.cost.loaded_elements, 72);
        // MACs: ops_per_patch = (4/4)·9·4
        assert_eq!(out.cost.macs, 36);
        // occupancy: inputs 36 + kernels 36 + outputs 1×4
        assert_eq!(out.occupancy, 76);
    }

    #[test]
    fn free_nonresident_fails() {
        let l = layer();
        let mut mem = MemoryState::initial(&l);
        let mut s = Step::noop(l.n_pixels(), l.n_kernels, l.n_patches());
        s.free_inp.insert(3);
        assert_eq!(
            apply(&l, &acc(), &mut mem, &s, true),
            Err(StepError::FreeInputNotResident)
        );
    }

    #[test]
    fn write_nonresident_fails() {
        let l = layer();
        let mut mem = MemoryState::initial(&l);
        let mut s = Step::noop(l.n_pixels(), l.n_kernels, l.n_patches());
        s.write.insert(0);
        assert_eq!(
            apply(&l, &acc(), &mut mem, &s, true),
            Err(StepError::WriteNotResident)
        );
    }

    #[test]
    fn compute_without_kernels_fails() {
        let l = layer();
        let mut mem = MemoryState::initial(&l);
        let mut s = Step::noop(l.n_pixels(), l.n_kernels, l.n_patches());
        s.load_inp = l.patch_pixels(0);
        s.group = vec![0];
        assert_eq!(
            apply(&l, &acc(), &mut mem, &s, true),
            Err(StepError::KernelsMissing)
        );
    }

    #[test]
    fn compute_with_missing_input_fails() {
        let l = layer();
        let mut mem = MemoryState::initial(&l);
        let mut s = Step::noop(l.n_pixels(), l.n_kernels, l.n_patches());
        s.load_ker = load_all_kernels(&l);
        s.load_inp = l.patch_pixels(0);
        s.group = vec![0, 1]; // patch 1's pixels not loaded
        assert_eq!(
            apply(&l, &acc(), &mut mem, &s, true),
            Err(StepError::GroupInputMissing { patch: 1 })
        );
    }

    #[test]
    fn too_many_ops_fails_strict_only() {
        let l = layer();
        let small = Accelerator { nbop_pe: 36, ..acc() }; // one patch worth
        let mut s = Step::noop(l.n_pixels(), l.n_kernels, l.n_patches());
        s.load_ker = load_all_kernels(&l);
        s.load_inp = l.group_pixels(&[0, 1]);
        s.group = vec![0, 1];
        let mut mem = MemoryState::initial(&l);
        assert_eq!(
            apply(&l, &small, &mut mem, &s, true),
            Err(StepError::TooManyOps { ops: 72, nbop_pe: 36 })
        );
        let mut mem2 = MemoryState::initial(&l);
        assert!(apply(&l, &small, &mut mem2, &s, false).is_ok());
    }

    #[test]
    fn reload_resident_fails_strict_only() {
        let l = layer();
        let mut mem = MemoryState::initial(&l);
        mem.inp = l.patch_pixels(0);
        let mut s = Step::noop(l.n_pixels(), l.n_kernels, l.n_patches());
        s.load_inp = l.patch_pixels(0);
        s.load_ker = load_all_kernels(&l);
        s.group = vec![0];
        assert_eq!(
            apply(&l, &acc(), &mut mem, &s.clone(), true),
            Err(StepError::ReloadingResidentInput)
        );
        let mut mem2 = MemoryState::initial(&l);
        mem2.inp = l.patch_pixels(0);
        assert!(apply(&l, &acc(), &mut mem2, &s, false).is_ok());
    }

    #[test]
    fn loaded_data_must_be_processed() {
        let l = layer();
        let mut mem = MemoryState::initial(&l);
        let mut s = Step::noop(l.n_pixels(), l.n_kernels, l.n_patches());
        s.load_ker = load_all_kernels(&l);
        s.load_inp = l.patch_pixels(0).union(&l.patch_pixels(8)); // extra data
        s.group = vec![0];
        assert_eq!(
            apply(&l, &acc(), &mut mem, &s, true),
            Err(StepError::LoadedDataNotProcessed)
        );
    }

    #[test]
    fn memory_overflow_detected() {
        let l = layer();
        let tiny = Accelerator { size_mem: 40, ..acc() };
        let mut mem = MemoryState::initial(&l);
        let mut s = Step::noop(l.n_pixels(), l.n_kernels, l.n_patches());
        s.load_ker = load_all_kernels(&l); // 36 elements
        s.load_inp = l.patch_pixels(0); // +18 = 54 > 40
        s.group = vec![0];
        match apply(&l, &tiny, &mut mem, &s, true) {
            Err(StepError::MemoryOverflow { occupancy, capacity }) => {
                assert_eq!(capacity, 40);
                assert!(occupancy > 40);
            }
            other => panic!("expected overflow, got {other:?}"),
        }
    }

    #[test]
    fn write_back_removes_outputs() {
        let l = layer();
        let mut mem = MemoryState::initial(&l);
        // step 1: compute patch 0
        let mut s1 = Step::noop(l.n_pixels(), l.n_kernels, l.n_patches());
        s1.load_inp = l.patch_pixels(0);
        s1.load_ker = load_all_kernels(&l);
        s1.group = vec![0];
        apply(&l, &acc(), &mut mem, &s1, true).unwrap();
        // step 2: write it back (2 output elements), free everything
        let mut s2 = Step::noop(l.n_pixels(), l.n_kernels, l.n_patches());
        s2.write.insert(0);
        s2.free_inp = mem.inp.clone();
        s2.free_ker = mem.ker.clone();
        let out = apply(&l, &acc(), &mut mem, &s2, true).unwrap();
        assert_eq!(out.cost.written_elements, 2);
        assert!(!out.cost.computed);
        assert!(mem.is_empty());
    }
}

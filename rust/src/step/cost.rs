//! Step duration (Definition 3), its decomposition, and the two-resource
//! overlapped timeline ([`OverlapTimeline`]) behind
//! [`crate::platform::OverlapMode::DoubleBuffered`].

use crate::platform::{Accelerator, StepFaults};

/// Cost of one step, broken into the terms of Definition 3:
/// `δ(s_i) = (|I^slice| + |K^sub|)·t_l + |W|·t_w + t_acc`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct StepCost {
    /// Elements loaded (inputs + kernels), i.e. `|I^slice| + |K^sub|`.
    pub loaded_elements: u64,
    /// Elements written back, i.e. `|W|`.
    pub written_elements: u64,
    /// Whether a compute action ran (charges `t_acc`).
    pub computed: bool,
    /// MAC operations performed by `a_6`.
    pub macs: u64,
}

impl StepCost {
    /// Duration in cycles under the given accelerator parameters.
    pub fn duration(&self, acc: &Accelerator) -> u64 {
        self.dma_cycles(acc) + self.compute_cycles(acc)
    }

    /// Cycles this step occupies the DMA channel: `|I|·t_l + |W|·t_w`.
    pub fn dma_cycles(&self, acc: &Accelerator) -> u64 {
        self.loaded_elements * acc.t_l + self.written_elements * acc.t_w
    }

    /// Cycles this step occupies the compute unit (`t_acc` or 0).
    pub fn compute_cycles(&self, acc: &Accelerator) -> u64 {
        if self.computed { acc.t_acc } else { 0 }
    }

    /// Cycles of the load phase alone: `|I|·t_l` (the quantity a DMA retry
    /// replays).
    pub fn load_cycles(&self, acc: &Accelerator) -> u64 {
        self.loaded_elements * acc.t_l
    }

    /// The retry-aware load phase: each failed attempt replays the load at
    /// full cost plus `retry_penalty`, and the drawn DMA jitter lands here
    /// (the phase that owns the bus first). With clean faults this is
    /// exactly [`StepCost::load_cycles`].
    pub fn faulted_load_cycles(
        &self,
        acc: &Accelerator,
        faults: &StepFaults,
        retry_penalty: u64,
    ) -> u64 {
        let load = self.load_cycles(acc);
        load + (faults.load_retries as u64) * (load + retry_penalty) + faults.dma_jitter
    }

    /// The jitter-aware compute phase (clean faults ⇒
    /// [`StepCost::compute_cycles`]).
    pub fn faulted_compute_cycles(&self, acc: &Accelerator, faults: &StepFaults) -> u64 {
        self.compute_cycles(acc) + faults.compute_jitter
    }

    /// Retry-aware Definition-3 step duration: faulted load phase + writes +
    /// faulted compute. The sequential recurrence under faults is the sum of
    /// these, and the double-buffered one places the same three phases on
    /// the [`OverlapTimeline`] — so the two semantics degrade consistently.
    pub fn faulted_duration(
        &self,
        acc: &Accelerator,
        faults: &StepFaults,
        retry_penalty: u64,
    ) -> u64 {
        self.faulted_load_cycles(acc, faults, retry_penalty)
            + self.written_elements * acc.t_w
            + self.faulted_compute_cycles(acc, faults)
    }

    /// Accumulate another step's cost (for strategy totals).
    pub fn add(&mut self, other: &StepCost) {
        self.loaded_elements += other.loaded_elements;
        self.written_elements += other.written_elements;
        self.macs += other.macs;
        // `computed` is per-step; totals track it via `n_compute_steps`
        // in the strategy-level report instead.
    }
}

/// Aggregate over a full n-step strategy:
/// `δ = Σ δ(s_i)` (Definition 3).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct StrategyCost {
    /// Element-level totals summed over all steps.
    pub total: StepCost,
    /// Steps executed (flush included).
    pub n_steps: u64,
    /// Steps that ran a compute action.
    pub n_compute_steps: u64,
}

impl StrategyCost {
    /// Accumulate one step.
    pub fn push(&mut self, step: &StepCost) {
        self.total.add(step);
        self.n_steps += 1;
        if step.computed {
            self.n_compute_steps += 1;
        }
    }

    /// Total duration: load/write terms plus `t_acc` per compute step.
    pub fn duration(&self, acc: &Accelerator) -> u64 {
        self.total.loaded_elements * acc.t_l
            + self.total.written_elements * acc.t_w
            + self.n_compute_steps * acc.t_acc
    }
}

/// Start/end instants of one step's phases on the two-resource timeline
/// (cycles since the start of the strategy).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct StepTiming {
    /// DMA: input/kernel load phase.
    pub load_start: u64,
    /// End of the load phase (`load_start + |I|·t_l`).
    pub load_end: u64,
    /// DMA: write-back phase (drains after the producing compute).
    pub write_start: u64,
    /// End of the write phase (`write_start + |W|·t_w`).
    pub write_end: u64,
    /// Compute phase start (after this step's loads and the previous
    /// step's compute).
    pub compute_start: u64,
    /// Compute phase end (`compute_start + t_acc` for compute steps).
    pub compute_end: u64,
    /// Whether the load phase was allowed to prefetch during the previous
    /// step's compute (the double-buffer residency condition held).
    pub prefetched: bool,
}

/// The §3.7 two-resource timeline: one DMA channel, one compute unit, steps
/// issued in order on both.
///
/// Per step, the DMA channel runs the load phase then the write phase; the
/// compute unit runs the compute phase. Dependencies:
///
/// * **load** waits for the channel; when the double-buffer residency
///   condition fails (`can_prefetch = false`) it additionally waits for the
///   previous step's compute (serialization fallback — the previous working
///   set must be released before the new inputs can be staged);
/// * **write** waits for the channel after the load phase *and* for the
///   previous step's compute (it drains outputs that compute produced);
/// * **compute** waits for this step's loads and the previous compute.
///
/// The makespan is the later of the two resource frontiers. It is always
/// ≤ the sequential (Definition 3) duration and ≥ `max(dma_busy,
/// compute_busy)` — both bounds are pinned by tests here, by the fuzz
/// property suite and by the Python oracle.
#[derive(Debug, Clone, Copy, Default)]
pub struct OverlapTimeline {
    dma_free: u64,
    comp_end: u64,
    dma_busy: u64,
    compute_busy: u64,
}

impl OverlapTimeline {
    /// An empty timeline (both resources free at cycle 0).
    pub fn new() -> Self {
        OverlapTimeline::default()
    }

    /// One step of the §3.7 recurrence as a **pure function** of the two
    /// resource frontiers — the single implementation of the dependency
    /// rules, shared by [`OverlapTimeline::push`] (simulator side) and the
    /// incremental duration objective
    /// ([`crate::optimizer::MakespanEval`]).
    pub fn place(
        dma_free: u64,
        comp_end: u64,
        load_cycles: u64,
        write_cycles: u64,
        compute_cycles: u64,
        can_prefetch: bool,
    ) -> StepTiming {
        let load_ready = if can_prefetch { 0 } else { comp_end };
        let load_start = dma_free.max(load_ready);
        let load_end = load_start + load_cycles;
        let write_start = load_end.max(comp_end);
        let write_end = write_start + write_cycles;
        let compute_start = load_end.max(comp_end);
        let compute_end = compute_start + compute_cycles;
        StepTiming {
            load_start,
            load_end,
            write_start,
            write_end,
            compute_start,
            compute_end,
            prefetched: can_prefetch,
        }
    }

    /// Schedule one step given its phase durations in cycles and the
    /// double-buffer residency verdict; returns the placed phases.
    pub fn push(
        &mut self,
        load_cycles: u64,
        write_cycles: u64,
        compute_cycles: u64,
        can_prefetch: bool,
    ) -> StepTiming {
        let t = Self::place(
            self.dma_free,
            self.comp_end,
            load_cycles,
            write_cycles,
            compute_cycles,
            can_prefetch,
        );
        self.dma_free = t.write_end;
        self.comp_end = t.compute_end;
        self.dma_busy += load_cycles + write_cycles;
        self.compute_busy += compute_cycles;
        t
    }

    /// Critical-path makespan so far: the later resource frontier.
    pub fn makespan(&self) -> u64 {
        self.dma_free.max(self.comp_end)
    }

    /// Total cycles the DMA channel was busy (loads + writes).
    pub fn dma_busy(&self) -> u64 {
        self.dma_busy
    }

    /// Total cycles the compute unit was busy.
    pub fn compute_busy(&self) -> u64 {
        self.compute_busy
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn acc() -> Accelerator {
        Accelerator {
            nbop_pe: 100,
            t_acc: 3,
            t_l: 2,
            t_w: 5,
            ..Accelerator::paper_eval(100, 1000)
        }
    }

    #[test]
    fn duration_formula() {
        let c = StepCost { loaded_elements: 10, written_elements: 4, computed: true, macs: 99 };
        assert_eq!(c.duration(&acc()), 10 * 2 + 4 * 5 + 3);
    }

    #[test]
    fn no_compute_no_tacc() {
        let c = StepCost { loaded_elements: 1, written_elements: 0, computed: false, macs: 0 };
        assert_eq!(c.duration(&acc()), 2);
    }

    #[test]
    fn strategy_cost_sums() {
        let mut total = StrategyCost::default();
        total.push(&StepCost { loaded_elements: 5, written_elements: 1, computed: true, macs: 10 });
        total.push(&StepCost { loaded_elements: 3, written_elements: 2, computed: true, macs: 10 });
        total.push(&StepCost { loaded_elements: 0, written_elements: 7, computed: false, macs: 0 });
        assert_eq!(total.n_steps, 3);
        assert_eq!(total.n_compute_steps, 2);
        // (5+3)·2 + (1+2+7)·5 + 2·3
        assert_eq!(total.duration(&acc()), 16 + 50 + 6);
        assert_eq!(total.total.macs, 20);
    }

    /// Hand-computed three-step overlapped chain (all prefetches allowed,
    /// one denied): every phase instant is checked, plus the two bounds the
    /// property suite asserts in bulk.
    #[test]
    fn overlap_timeline_hand_computed_chain() {
        let mut t = OverlapTimeline::new();
        // (L, W, C, prefetch)
        let s1 = t.push(10, 0, 5, true);
        assert_eq!((s1.load_start, s1.load_end), (0, 10));
        assert_eq!((s1.compute_start, s1.compute_end), (10, 15));
        let s2 = t.push(6, 2, 5, true);
        // load prefetches during step 1's compute: starts at DMA-free = 10
        assert_eq!((s2.load_start, s2.load_end), (10, 16));
        assert_eq!((s2.write_start, s2.write_end), (16, 18));
        assert_eq!((s2.compute_start, s2.compute_end), (16, 21));
        let s3 = t.push(6, 2, 5, false);
        // serialization fallback: load waits for step 2's compute (21)
        assert_eq!((s3.load_start, s3.load_end), (21, 27));
        assert_eq!((s3.write_start, s3.write_end), (27, 29));
        assert_eq!((s3.compute_start, s3.compute_end), (27, 32));
        let flush = t.push(0, 2, 0, true);
        assert_eq!((flush.write_start, flush.write_end), (32, 34));
        assert!(!s3.prefetched && flush.prefetched);

        assert_eq!(t.makespan(), 34);
        assert_eq!(t.dma_busy(), 28);
        assert_eq!(t.compute_busy(), 15);
        // overlapped ≤ sequential; ≥ per-resource lower bound
        let sequential = 15 + 13 + 13 + 2;
        assert!(t.makespan() <= sequential);
        assert!(t.makespan() >= t.dma_busy().max(t.compute_busy()));
    }

    /// With every prefetch denied the timeline degrades gracefully but a
    /// write can still drain during the next compute — the makespan never
    /// exceeds the sequential sum.
    #[test]
    fn overlap_timeline_serialized_never_exceeds_sequential() {
        let steps = [(10u64, 4u64, 3u64), (7, 4, 3), (5, 4, 3), (0, 4, 0)];
        let mut t = OverlapTimeline::new();
        let mut sequential = 0;
        for &(l, w, c) in &steps {
            t.push(l, w, c, false);
            sequential += l + w + c;
        }
        assert!(t.makespan() <= sequential);
        assert!(t.makespan() >= t.dma_busy().max(t.compute_busy()));
    }

    /// The retry recurrence: clean faults are the identity, each retry
    /// replays the full load + penalty, jitter adds linearly on both units.
    #[test]
    fn faulted_costs_reduce_to_clean_and_charge_retries() {
        let c = StepCost { loaded_elements: 10, written_elements: 4, computed: true, macs: 9 };
        let a = acc();
        let clean = StepFaults::default();
        assert_eq!(c.faulted_load_cycles(&a, &clean, 7), c.load_cycles(&a));
        assert_eq!(c.faulted_compute_cycles(&a, &clean), c.compute_cycles(&a));
        assert_eq!(c.faulted_duration(&a, &clean, 7), c.duration(&a));

        let f = StepFaults { load_retries: 2, dma_jitter: 3, compute_jitter: 5, shrink: false };
        // load 20 cycles, 2 replays of (20 + penalty 7), + 3 jitter
        assert_eq!(c.faulted_load_cycles(&a, &f, 7), 20 + 2 * 27 + 3);
        assert_eq!(c.faulted_compute_cycles(&a, &f), 3 + 5);
        assert_eq!(
            c.faulted_duration(&a, &f, 7),
            (20 + 54 + 3) + 4 * 5 + (3 + 5)
        );
    }

    #[test]
    fn step_cost_resource_split_sums_to_duration() {
        let c = StepCost { loaded_elements: 10, written_elements: 4, computed: true, macs: 9 };
        let a = acc();
        assert_eq!(c.dma_cycles(&a), 10 * 2 + 4 * 5);
        assert_eq!(c.compute_cycles(&a), 3);
        assert_eq!(c.duration(&a), c.dma_cycles(&a) + c.compute_cycles(&a));
    }

    #[test]
    fn paper_eval_costs_ignore_writes() {
        // §7.1: t_l = t_acc = 1, writes not charged → δ = Σ|I| + n
        let acc = Accelerator::paper_eval(120, 1000);
        let mut total = StrategyCost::default();
        for _ in 0..4 {
            total.push(&StepCost { loaded_elements: 6, written_elements: 4, computed: true, macs: 1 });
        }
        assert_eq!(total.duration(&acc), 4 * 6 + 4);
    }
}

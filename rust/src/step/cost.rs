//! Step duration (Definition 3) and its decomposition.

use crate::platform::Accelerator;

/// Cost of one step, broken into the terms of Definition 3:
/// `δ(s_i) = (|I^slice| + |K^sub|)·t_l + |W|·t_w + t_acc`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct StepCost {
    /// Elements loaded (inputs + kernels), i.e. `|I^slice| + |K^sub|`.
    pub loaded_elements: u64,
    /// Elements written back, i.e. `|W|`.
    pub written_elements: u64,
    /// Whether a compute action ran (charges `t_acc`).
    pub computed: bool,
    /// MAC operations performed by `a_6`.
    pub macs: u64,
}

impl StepCost {
    /// Duration in cycles under the given accelerator parameters.
    pub fn duration(&self, acc: &Accelerator) -> u64 {
        self.loaded_elements * acc.t_l
            + self.written_elements * acc.t_w
            + if self.computed { acc.t_acc } else { 0 }
    }

    /// Accumulate another step's cost (for strategy totals).
    pub fn add(&mut self, other: &StepCost) {
        self.loaded_elements += other.loaded_elements;
        self.written_elements += other.written_elements;
        self.macs += other.macs;
        // `computed` is per-step; totals track it via `n_compute_steps`
        // in the strategy-level report instead.
    }
}

/// Aggregate over a full n-step strategy:
/// `δ = Σ δ(s_i)` (Definition 3).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct StrategyCost {
    pub total: StepCost,
    pub n_steps: u64,
    pub n_compute_steps: u64,
}

impl StrategyCost {
    pub fn push(&mut self, step: &StepCost) {
        self.total.add(step);
        self.n_steps += 1;
        if step.computed {
            self.n_compute_steps += 1;
        }
    }

    /// Total duration: load/write terms plus `t_acc` per compute step.
    pub fn duration(&self, acc: &Accelerator) -> u64 {
        self.total.loaded_elements * acc.t_l
            + self.total.written_elements * acc.t_w
            + self.n_compute_steps * acc.t_acc
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn acc() -> Accelerator {
        Accelerator { nbop_pe: 100, t_acc: 3, size_mem: 1000, t_l: 2, t_w: 5 }
    }

    #[test]
    fn duration_formula() {
        let c = StepCost { loaded_elements: 10, written_elements: 4, computed: true, macs: 99 };
        assert_eq!(c.duration(&acc()), 10 * 2 + 4 * 5 + 3);
    }

    #[test]
    fn no_compute_no_tacc() {
        let c = StepCost { loaded_elements: 1, written_elements: 0, computed: false, macs: 0 };
        assert_eq!(c.duration(&acc()), 2);
    }

    #[test]
    fn strategy_cost_sums() {
        let mut total = StrategyCost::default();
        total.push(&StepCost { loaded_elements: 5, written_elements: 1, computed: true, macs: 10 });
        total.push(&StepCost { loaded_elements: 3, written_elements: 2, computed: true, macs: 10 });
        total.push(&StepCost { loaded_elements: 0, written_elements: 7, computed: false, macs: 0 });
        assert_eq!(total.n_steps, 3);
        assert_eq!(total.n_compute_steps, 2);
        // (5+3)·2 + (1+2+7)·5 + 2·3
        assert_eq!(total.duration(&acc()), 16 + 50 + 6);
        assert_eq!(total.total.macs, 20);
    }

    #[test]
    fn paper_eval_costs_ignore_writes() {
        // §7.1: t_l = t_acc = 1, writes not charged → δ = Σ|I| + n
        let acc = Accelerator::paper_eval(120, 1000);
        let mut total = StrategyCost::default();
        for _ in 0..4 {
            total.push(&StepCost { loaded_elements: 6, written_elements: 4, computed: true, macs: 1 });
        }
        assert_eq!(total.duration(&acc), 4 * 6 + 4);
    }
}

//! Step duration (Definition 3), its decomposition, and the multi-resource
//! overlapped timeline ([`OverlapTimeline`]) behind
//! [`crate::platform::OverlapMode::DoubleBuffered`] — k DMA channels ×
//! m compute units (§3.10), collapsing bit-exactly to the §3.7
//! two-resource recurrence at k = m = 1.

use crate::platform::{Accelerator, StepFaults};

/// Cost of one step, broken into the terms of Definition 3:
/// `δ(s_i) = (|I^slice| + |K^sub|)·t_l + |W|·t_w + t_acc`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct StepCost {
    /// Elements loaded (inputs + kernels), i.e. `|I^slice| + |K^sub|`.
    pub loaded_elements: u64,
    /// Elements written back, i.e. `|W|`.
    pub written_elements: u64,
    /// Whether a compute action ran (charges `t_acc`).
    pub computed: bool,
    /// MAC operations performed by `a_6`.
    pub macs: u64,
}

impl StepCost {
    /// Duration in cycles under the given accelerator parameters.
    pub fn duration(&self, acc: &Accelerator) -> u64 {
        self.dma_cycles(acc) + self.compute_cycles(acc)
    }

    /// Cycles this step occupies the DMA channel: `|I|·t_l + |W|·t_w`.
    pub fn dma_cycles(&self, acc: &Accelerator) -> u64 {
        self.loaded_elements * acc.t_l + self.written_elements * acc.t_w
    }

    /// Cycles this step occupies the compute unit (`t_acc` or 0).
    pub fn compute_cycles(&self, acc: &Accelerator) -> u64 {
        if self.computed { acc.t_acc } else { 0 }
    }

    /// Cycles of the load phase alone: `|I|·t_l` (the quantity a DMA retry
    /// replays).
    pub fn load_cycles(&self, acc: &Accelerator) -> u64 {
        self.loaded_elements * acc.t_l
    }

    /// The retry-aware load phase: each failed attempt replays the load at
    /// full cost plus `retry_penalty`, and the drawn DMA jitter lands here
    /// (the phase that owns the bus first). With clean faults this is
    /// exactly [`StepCost::load_cycles`].
    pub fn faulted_load_cycles(
        &self,
        acc: &Accelerator,
        faults: &StepFaults,
        retry_penalty: u64,
    ) -> u64 {
        let load = self.load_cycles(acc);
        load + (faults.load_retries as u64) * (load + retry_penalty) + faults.dma_jitter
    }

    /// The jitter-aware compute phase (clean faults ⇒
    /// [`StepCost::compute_cycles`]).
    pub fn faulted_compute_cycles(&self, acc: &Accelerator, faults: &StepFaults) -> u64 {
        self.compute_cycles(acc) + faults.compute_jitter
    }

    /// Retry-aware Definition-3 step duration: faulted load phase + writes +
    /// faulted compute. The sequential recurrence under faults is the sum of
    /// these, and the double-buffered one places the same three phases on
    /// the [`OverlapTimeline`] — so the two semantics degrade consistently.
    pub fn faulted_duration(
        &self,
        acc: &Accelerator,
        faults: &StepFaults,
        retry_penalty: u64,
    ) -> u64 {
        self.faulted_load_cycles(acc, faults, retry_penalty)
            + self.written_elements * acc.t_w
            + self.faulted_compute_cycles(acc, faults)
    }

    /// Accumulate another step's cost (for strategy totals).
    pub fn add(&mut self, other: &StepCost) {
        self.loaded_elements += other.loaded_elements;
        self.written_elements += other.written_elements;
        self.macs += other.macs;
        // `computed` is per-step; totals track it via `n_compute_steps`
        // in the strategy-level report instead.
    }
}

/// Aggregate over a full n-step strategy:
/// `δ = Σ δ(s_i)` (Definition 3).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct StrategyCost {
    /// Element-level totals summed over all steps.
    pub total: StepCost,
    /// Steps executed (flush included).
    pub n_steps: u64,
    /// Steps that ran a compute action.
    pub n_compute_steps: u64,
}

impl StrategyCost {
    /// Accumulate one step.
    pub fn push(&mut self, step: &StepCost) {
        self.total.add(step);
        self.n_steps += 1;
        if step.computed {
            self.n_compute_steps += 1;
        }
    }

    /// Total duration: load/write terms plus `t_acc` per compute step.
    pub fn duration(&self, acc: &Accelerator) -> u64 {
        self.total.loaded_elements * acc.t_l
            + self.total.written_elements * acc.t_w
            + self.n_compute_steps * acc.t_acc
    }
}

/// Start/end instants of one step's phases on the overlap timeline
/// (cycles since the start of the strategy), plus the resource each phase
/// was assigned to by the list scheduler.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct StepTiming {
    /// DMA: input/kernel load phase.
    pub load_start: u64,
    /// End of the load phase (`load_start + |I|·t_l`).
    pub load_end: u64,
    /// DMA: write-back phase (drains after the producing compute).
    pub write_start: u64,
    /// End of the write phase (`write_start + |W|·t_w`).
    pub write_end: u64,
    /// Compute phase start (after this step's loads and the previous
    /// step's compute).
    pub compute_start: u64,
    /// Compute phase end (`compute_start + t_acc` for compute steps).
    pub compute_end: u64,
    /// Whether the load phase was allowed to prefetch during the previous
    /// step's compute (the double-buffer residency condition held).
    pub prefetched: bool,
    /// DMA channel the load phase ran on (0 at k = 1).
    pub load_channel: usize,
    /// DMA channel the write phase ran on (0 at k = 1).
    pub write_channel: usize,
    /// Compute unit the compute phase ran on (0 at m = 1).
    pub compute_unit: usize,
}

/// Index of the earliest-free resource (lowest index on ties) — the list
/// scheduler's only placement rule.
fn earliest(frontiers: &[u64]) -> usize {
    let mut best = 0;
    for (i, &f) in frontiers.iter().enumerate().skip(1) {
        if f < frontiers[best] {
            best = i;
        }
    }
    best
}

/// The §3.10 multi-resource timeline: k DMA channels × m compute units,
/// steps issued in order, each phase list-scheduled onto the earliest-free
/// resource of its class (lowest index on ties).
///
/// Per step, a DMA channel runs the load phase, a DMA channel (re-picked
/// after the load is placed) runs the write phase, and a compute unit runs
/// the compute phase. Dependencies — identical to §3.7, anchored on the
/// *issue-order previous* compute (`prev_comp_end`, the step that produced
/// the outputs in flight):
///
/// * **load** waits for its channel; when the double-buffer residency
///   condition fails (`can_prefetch = false`) it additionally waits for the
///   previous step's compute (serialization fallback — the previous working
///   set must be released before the new inputs can be staged);
/// * **write** waits for its channel *and* for the previous step's compute
///   (it drains outputs that compute produced — at m > 1 the producing
///   step's frontier and "the busy unit" stop coinciding, which is why the
///   gate is `prev_comp_end` and not a unit frontier);
/// * **compute** waits for its unit, this step's loads and the previous
///   compute (within one image the steps form a dependency chain; extra
///   units only pay off across batched images, see
///   [`OverlapTimeline::begin_image`]).
///
/// Channels are in-order queues: a gated phase stalls its channel (the
/// frontier advances through the wait), exactly as the §3.7 single channel
/// does — so at k = m = 1 every placement is bit-identical to
/// [`OverlapTimeline::place`], the legacy scalar recurrence kept as the
/// collapse reference.
///
/// The makespan is the latest resource frontier. It is always ≤ the
/// sequential (Definition 3) duration and ≥ `max(⌈dma_busy/k⌉,
/// ⌈compute_busy/m⌉)` — both bounds are pinned by tests here, by the fuzz
/// property suite and by the Python oracle.
#[derive(Debug, Clone)]
pub struct OverlapTimeline {
    /// Flattened frontiers: `k` DMA channels, `m` compute units, then the
    /// previous step's compute end (see [`OverlapTimeline::state_len`]).
    state: Vec<u64>,
    dma_channels: usize,
    dma_busy_per: Vec<u64>,
    compute_busy_per: Vec<u64>,
}

impl Default for OverlapTimeline {
    fn default() -> Self {
        OverlapTimeline::new()
    }
}

impl OverlapTimeline {
    /// An empty §3.7 timeline (1 DMA channel, 1 compute unit, free at 0).
    pub fn new() -> Self {
        OverlapTimeline::with_resources(1, 1)
    }

    /// An empty timeline over `dma_channels` × `compute_units` resources
    /// (each clamped to ≥ 1).
    pub fn with_resources(dma_channels: usize, compute_units: usize) -> Self {
        let k = dma_channels.max(1);
        let m = compute_units.max(1);
        OverlapTimeline {
            state: vec![0; Self::state_len(k, m)],
            dma_channels: k,
            dma_busy_per: vec![0; k],
            compute_busy_per: vec![0; m],
        }
    }

    /// Length of the flattened state vector for a k × m timeline:
    /// `k` channel frontiers + `m` unit frontiers + the previous compute
    /// end.
    pub fn state_len(dma_channels: usize, compute_units: usize) -> usize {
        dma_channels + compute_units + 1
    }

    /// One step of the §3.7 two-resource recurrence as a **pure function**
    /// of the two scalar frontiers — kept as the documented k = m = 1
    /// reference; the collapse property tests replay strategies through
    /// both this and [`OverlapTimeline::place_on`] and assert bit-equality.
    pub fn place(
        dma_free: u64,
        comp_end: u64,
        load_cycles: u64,
        write_cycles: u64,
        compute_cycles: u64,
        can_prefetch: bool,
    ) -> StepTiming {
        let load_ready = if can_prefetch { 0 } else { comp_end };
        let load_start = dma_free.max(load_ready);
        let load_end = load_start + load_cycles;
        let write_start = load_end.max(comp_end);
        let write_end = write_start + write_cycles;
        let compute_start = load_end.max(comp_end);
        let compute_end = compute_start + compute_cycles;
        StepTiming {
            load_start,
            load_end,
            write_start,
            write_end,
            compute_start,
            compute_end,
            prefetched: can_prefetch,
            ..StepTiming::default()
        }
    }

    /// One step of the generalized recurrence as a **pure function** of a
    /// flattened state slice (`dma_channels` channel frontiers, then the
    /// unit frontiers, then the previous compute end) — the single
    /// implementation of the dependency rules, shared by
    /// [`OverlapTimeline::push`] (simulator side) and the incremental
    /// duration objective ([`crate::optimizer::MakespanEval`]). Mutates
    /// `state` in place and returns the placed phases.
    pub fn place_on(
        state: &mut [u64],
        dma_channels: usize,
        load_cycles: u64,
        write_cycles: u64,
        compute_cycles: u64,
        can_prefetch: bool,
    ) -> StepTiming {
        let k = dma_channels;
        let m = state.len() - k - 1;
        debug_assert!(k >= 1 && m >= 1, "state slice too short for k={k}");
        let prev_comp_end = state[k + m];
        let gate = if can_prefetch { 0 } else { prev_comp_end };
        let (dma, comp) = state.split_at_mut(k);
        let load_channel = earliest(dma);
        let load_start = dma[load_channel].max(gate);
        let load_end = load_start + load_cycles;
        dma[load_channel] = load_end;
        let write_channel = earliest(dma);
        let write_start = dma[write_channel].max(prev_comp_end);
        let write_end = write_start + write_cycles;
        dma[write_channel] = write_end;
        let compute_unit = earliest(&comp[..m]);
        let compute_start = comp[compute_unit].max(load_end).max(prev_comp_end);
        let compute_end = compute_start + compute_cycles;
        comp[compute_unit] = compute_end;
        comp[m] = compute_end;
        StepTiming {
            load_start,
            load_end,
            write_start,
            write_end,
            compute_start,
            compute_end,
            prefetched: can_prefetch,
            load_channel,
            write_channel,
            compute_unit,
        }
    }

    /// Schedule one step given its phase durations in cycles and the
    /// double-buffer residency verdict; returns the placed phases.
    pub fn push(
        &mut self,
        load_cycles: u64,
        write_cycles: u64,
        compute_cycles: u64,
        can_prefetch: bool,
    ) -> StepTiming {
        let t = Self::place_on(
            &mut self.state,
            self.dma_channels,
            load_cycles,
            write_cycles,
            compute_cycles,
            can_prefetch,
        );
        self.dma_busy_per[t.load_channel] += load_cycles;
        self.dma_busy_per[t.write_channel] += write_cycles;
        self.compute_busy_per[t.compute_unit] += compute_cycles;
        t
    }

    /// Start the next image of a batch: steps of different images carry no
    /// data dependency, so only the issue-order compute gate resets —
    /// resource frontiers persist (the hardware is still busy), which is
    /// what lets consecutive images' phases pipeline onto free units.
    pub fn begin_image(&mut self) {
        let last = self.state.len() - 1;
        self.state[last] = 0;
    }

    /// Critical-path makespan so far: the latest resource frontier.
    pub fn makespan(&self) -> u64 {
        let n = self.state.len() - 1;
        self.state[..n].iter().copied().max().unwrap_or(0)
    }

    /// Total cycles all DMA channels were busy (loads + writes).
    pub fn dma_busy(&self) -> u64 {
        self.dma_busy_per.iter().sum()
    }

    /// Total cycles all compute units were busy.
    pub fn compute_busy(&self) -> u64 {
        self.compute_busy_per.iter().sum()
    }

    /// Per-channel DMA busy cycles (length k).
    pub fn dma_busy_per(&self) -> &[u64] {
        &self.dma_busy_per
    }

    /// Per-unit compute busy cycles (length m).
    pub fn compute_busy_per(&self) -> &[u64] {
        &self.compute_busy_per
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn acc() -> Accelerator {
        Accelerator {
            nbop_pe: 100,
            t_acc: 3,
            t_l: 2,
            t_w: 5,
            ..Accelerator::paper_eval(100, 1000)
        }
    }

    #[test]
    fn duration_formula() {
        let c = StepCost { loaded_elements: 10, written_elements: 4, computed: true, macs: 99 };
        assert_eq!(c.duration(&acc()), 10 * 2 + 4 * 5 + 3);
    }

    #[test]
    fn no_compute_no_tacc() {
        let c = StepCost { loaded_elements: 1, written_elements: 0, computed: false, macs: 0 };
        assert_eq!(c.duration(&acc()), 2);
    }

    #[test]
    fn strategy_cost_sums() {
        let mut total = StrategyCost::default();
        total.push(&StepCost { loaded_elements: 5, written_elements: 1, computed: true, macs: 10 });
        total.push(&StepCost { loaded_elements: 3, written_elements: 2, computed: true, macs: 10 });
        total.push(&StepCost { loaded_elements: 0, written_elements: 7, computed: false, macs: 0 });
        assert_eq!(total.n_steps, 3);
        assert_eq!(total.n_compute_steps, 2);
        // (5+3)·2 + (1+2+7)·5 + 2·3
        assert_eq!(total.duration(&acc()), 16 + 50 + 6);
        assert_eq!(total.total.macs, 20);
    }

    /// Hand-computed three-step overlapped chain (all prefetches allowed,
    /// one denied): every phase instant is checked, plus the two bounds the
    /// property suite asserts in bulk.
    #[test]
    fn overlap_timeline_hand_computed_chain() {
        let mut t = OverlapTimeline::new();
        // (L, W, C, prefetch)
        let s1 = t.push(10, 0, 5, true);
        assert_eq!((s1.load_start, s1.load_end), (0, 10));
        assert_eq!((s1.compute_start, s1.compute_end), (10, 15));
        let s2 = t.push(6, 2, 5, true);
        // load prefetches during step 1's compute: starts at DMA-free = 10
        assert_eq!((s2.load_start, s2.load_end), (10, 16));
        assert_eq!((s2.write_start, s2.write_end), (16, 18));
        assert_eq!((s2.compute_start, s2.compute_end), (16, 21));
        let s3 = t.push(6, 2, 5, false);
        // serialization fallback: load waits for step 2's compute (21)
        assert_eq!((s3.load_start, s3.load_end), (21, 27));
        assert_eq!((s3.write_start, s3.write_end), (27, 29));
        assert_eq!((s3.compute_start, s3.compute_end), (27, 32));
        let flush = t.push(0, 2, 0, true);
        assert_eq!((flush.write_start, flush.write_end), (32, 34));
        assert!(!s3.prefetched && flush.prefetched);

        assert_eq!(t.makespan(), 34);
        assert_eq!(t.dma_busy(), 28);
        assert_eq!(t.compute_busy(), 15);
        // overlapped ≤ sequential; ≥ per-resource lower bound
        let sequential = 15 + 13 + 13 + 2;
        assert!(t.makespan() <= sequential);
        assert!(t.makespan() >= t.dma_busy().max(t.compute_busy()));
    }

    /// With every prefetch denied the timeline degrades gracefully but a
    /// write can still drain during the next compute — the makespan never
    /// exceeds the sequential sum.
    #[test]
    fn overlap_timeline_serialized_never_exceeds_sequential() {
        let steps = [(10u64, 4u64, 3u64), (7, 4, 3), (5, 4, 3), (0, 4, 0)];
        let mut t = OverlapTimeline::new();
        let mut sequential = 0;
        for &(l, w, c) in &steps {
            t.push(l, w, c, false);
            sequential += l + w + c;
        }
        assert!(t.makespan() <= sequential);
        assert!(t.makespan() >= t.dma_busy().max(t.compute_busy()));
    }

    /// The same four pushes as `overlap_timeline_hand_computed_chain`, on
    /// (k=2, m=1) — every phase instant hand-computed, mirrored verbatim by
    /// `TestHandComputedPin::test_k2_m1_schedule` in
    /// `python/tests/test_multi_resource.py`.
    #[test]
    fn overlap_timeline_multi_hand_computed_k2() {
        let mut t = OverlapTimeline::with_resources(2, 1);
        let s1 = t.push(10, 0, 5, true);
        assert_eq!((s1.load_channel, s1.load_start, s1.load_end), (0, 0, 10));
        assert_eq!((s1.write_channel, s1.write_end), (1, 0));
        assert_eq!((s1.compute_start, s1.compute_end), (10, 15));
        let s2 = t.push(6, 2, 5, true);
        // channel 1 is free at 0: the load prefetches there immediately,
        // but the write still waits for compute 1 — the producer gate.
        assert_eq!((s2.load_channel, s2.load_start, s2.load_end), (1, 0, 6));
        assert_eq!((s2.write_channel, s2.write_start, s2.write_end), (1, 15, 17));
        assert_eq!((s2.compute_start, s2.compute_end), (15, 20));
        let s3 = t.push(6, 2, 5, false);
        // serialization fallback: the load waits for compute 2 (ends 20)
        // even though channel 0 frees at 10.
        assert_eq!((s3.load_channel, s3.load_start, s3.load_end), (0, 20, 26));
        assert_eq!((s3.write_channel, s3.write_start, s3.write_end), (1, 20, 22));
        assert_eq!((s3.compute_start, s3.compute_end), (26, 31));
        let flush = t.push(0, 2, 0, true);
        assert_eq!((flush.write_channel, flush.write_start, flush.write_end), (1, 31, 33));

        assert_eq!(t.makespan(), 33); // vs 34 on the single channel
        assert_eq!(t.dma_busy_per(), &[16, 12]);
        assert_eq!(t.compute_busy_per(), &[15]);
        assert_eq!(t.dma_busy(), 28);
        assert_eq!(t.compute_busy(), 15);
    }

    /// k = m = 1 collapse: `place_on` must reproduce the legacy scalar
    /// `place` recurrence bit-exactly, phase instant by phase instant,
    /// across a serialization-heavy mixed chain.
    #[test]
    fn multi_place_collapses_to_legacy_at_1x1() {
        let pushes = [
            (10u64, 0u64, 5u64, true),
            (6, 2, 5, true),
            (6, 2, 5, false),
            (3, 1, 4, false),
            (0, 0, 2, true),
            (0, 2, 0, true),
        ];
        let mut state = vec![0u64; OverlapTimeline::state_len(1, 1)];
        let (mut dma_free, mut comp_end) = (0u64, 0u64);
        for &(l, w, c, p) in &pushes {
            let legacy = OverlapTimeline::place(dma_free, comp_end, l, w, c, p);
            dma_free = legacy.write_end;
            comp_end = legacy.compute_end;
            let multi = OverlapTimeline::place_on(&mut state, 1, l, w, c, p);
            assert_eq!(multi, legacy);
            assert_eq!(state, vec![dma_free, comp_end, comp_end]);
        }
    }

    /// Batched images on one unit serialize; `begin_image` only resets the
    /// issue-order gate, so frontiers (and busy totals) accumulate.
    #[test]
    fn begin_image_resets_only_the_compute_gate() {
        let mut t = OverlapTimeline::with_resources(1, 1);
        t.push(10, 0, 5, true);
        let before = t.makespan();
        t.begin_image();
        assert_eq!(t.makespan(), before);
        // The next image's load prefetches (gate reset), so it starts at
        // the channel frontier, not after the previous compute.
        let s = t.push(4, 0, 5, true);
        assert_eq!((s.load_start, s.load_end), (10, 14));
        assert_eq!((s.compute_start, s.compute_end), (15, 20));
        assert_eq!(t.dma_busy(), 14);
    }

    /// The retry recurrence: clean faults are the identity, each retry
    /// replays the full load + penalty, jitter adds linearly on both units.
    #[test]
    fn faulted_costs_reduce_to_clean_and_charge_retries() {
        let c = StepCost { loaded_elements: 10, written_elements: 4, computed: true, macs: 9 };
        let a = acc();
        let clean = StepFaults::default();
        assert_eq!(c.faulted_load_cycles(&a, &clean, 7), c.load_cycles(&a));
        assert_eq!(c.faulted_compute_cycles(&a, &clean), c.compute_cycles(&a));
        assert_eq!(c.faulted_duration(&a, &clean, 7), c.duration(&a));

        let f = StepFaults { load_retries: 2, dma_jitter: 3, compute_jitter: 5, shrink: false };
        // load 20 cycles, 2 replays of (20 + penalty 7), + 3 jitter
        assert_eq!(c.faulted_load_cycles(&a, &f, 7), 20 + 2 * 27 + 3);
        assert_eq!(c.faulted_compute_cycles(&a, &f), 3 + 5);
        assert_eq!(
            c.faulted_duration(&a, &f, 7),
            (20 + 54 + 3) + 4 * 5 + (3 + 5)
        );
    }

    #[test]
    fn step_cost_resource_split_sums_to_duration() {
        let c = StepCost { loaded_elements: 10, written_elements: 4, computed: true, macs: 9 };
        let a = acc();
        assert_eq!(c.dma_cycles(&a), 10 * 2 + 4 * 5);
        assert_eq!(c.compute_cycles(&a), 3);
        assert_eq!(c.duration(&a), c.dma_cycles(&a) + c.compute_cycles(&a));
    }

    #[test]
    fn paper_eval_costs_ignore_writes() {
        // §7.1: t_l = t_acc = 1, writes not charged → δ = Σ|I| + n
        let acc = Accelerator::paper_eval(120, 1000);
        let mut total = StrategyCost::default();
        for _ in 0..4 {
            total.push(&StepCost { loaded_elements: 6, written_elements: 4, computed: true, macs: 1 });
        }
        assert_eq!(total.duration(&acc), 4 * 6 + 4);
    }
}

//! Steps and their semantics (Definitions 1–3).
//!
//! A step `s_i = (F_i^inp, F_i^ker, W_i, I_i^slice, K_i^sub)` executes the
//! action sequence `a_1..a_6`:
//!
//! 1. `a_1` free input pixels `F^inp`;
//! 2. `a_2` free kernels `F^ker`;
//! 3. `a_3` write back outputs `W`;
//! 4. `a_4` load input slice `I^slice`;
//! 5. `a_5` load kernels `K^sub`;
//! 6. `a_6` compute the group's outputs `Out_i`.
//!
//! [`apply`] implements exactly that sequence on a [`MemoryState`], checking
//! the §2.3 assumptions as it goes, and returns the step's cost (Definition
//! 3) plus its peak occupancy (`size_i^step`).

mod cost;
mod semantics;

pub use cost::{OverlapTimeline, StepCost, StepTiming, StrategyCost};
pub use semantics::{apply, StepError, StepOutcome};

use crate::conv::PatchId;
use crate::platform::{KernelSet, OutputSet};
use crate::tensor::PixelSet;

/// One offloading step.
///
/// Sets are spatial-pixel / kernel-id / patch-id bitsets; see
/// [`crate::platform::MemoryState`] for the granularity conventions.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Step {
    /// `F_i^inp` — input pixels freed by `a_1`.
    pub free_inp: PixelSet,
    /// `F_i^ker` — kernels freed by `a_2`.
    pub free_ker: KernelSet,
    /// `W_i` — output patches written back by `a_3`.
    pub write: OutputSet,
    /// `I_i^slice` — input pixels loaded by `a_4`.
    pub load_inp: PixelSet,
    /// `K_i^sub` — kernels loaded by `a_5`.
    pub load_ker: KernelSet,
    /// `g_i` — the patch group computed by `a_6` (empty for pure
    /// housekeeping steps such as a final flush).
    pub group: Vec<PatchId>,
}

impl Step {
    /// A step that does nothing (useful as a builder base).
    pub fn noop(n_pixels: usize, n_kernels: usize, n_patches: usize) -> Self {
        Step {
            free_inp: PixelSet::empty(n_pixels),
            free_ker: KernelSet::empty(n_kernels),
            write: OutputSet::empty(n_patches),
            load_inp: PixelSet::empty(n_pixels),
            load_ker: KernelSet::empty(n_kernels),
            group: Vec::new(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn noop_is_empty() {
        let s = Step::noop(25, 2, 9);
        assert!(s.free_inp.is_empty());
        assert!(s.free_ker.is_empty());
        assert!(s.write.is_empty());
        assert!(s.load_inp.is_empty());
        assert!(s.load_ker.is_empty());
        assert!(s.group.is_empty());
    }
}

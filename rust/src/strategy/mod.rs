//! Strategies (§4): S1-baseline, grouped S1 and its orderings, plus
//! serialization and §2.3 validation.
//!
//! A [`GroupedStrategy`] is *data* — an ordered partition of the patch set
//! `X` into groups `g_1..g_n` plus a write-back policy. Generators
//! ([`s1_baseline`], [`row_by_row`], [`zigzag`], …) produce that data;
//! [`GroupedStrategy::compile`] lowers it to concrete [`crate::step::Step`]s
//! per Definition 16; the simulator executes them; the optimizer emits the
//! same type, so every strategy in the system is simulatable, checkable and
//! serializable in exactly one way.

mod grouped;
mod io;
pub mod multipass;
mod orderings;
mod validate;

pub use grouped::{GroupedStrategy, WritebackPolicy};
pub use multipass::{MultiPassReport, MultiPassStrategy};
pub use io::{
    strategy_from_csv, strategy_from_json, strategy_from_json_value, strategy_to_csv,
    strategy_to_json,
};
pub use orderings::{
    diagonal_order, hilbert_order, order_to_groups, row_major_order, zigzag_order, Ordering,
};
pub use validate::{validate, ValidationReport, Violation};

use crate::conv::ConvLayer;

/// Anything that can produce a grouped strategy for a layer.
///
/// Implemented by the built-in ordering generators and by the optimizer; lets
/// callers (CLI, figure harness) treat “a strategy source” uniformly.
pub trait Strategy {
    /// Human-readable name used in reports and figures.
    fn name(&self) -> String;
    /// Produce the strategy for `layer` with the given group-size bound.
    fn build(&self, layer: &ConvLayer, group_size: usize) -> GroupedStrategy;
}

/// S1-baseline (Definition 12, from Siu et al.): one patch per step in
/// row-major order, all kernels resident throughout.
pub fn s1_baseline(layer: &ConvLayer) -> GroupedStrategy {
    let order = row_major_order(layer);
    let mut s = order_to_groups(layer, &order, 1);
    s.name = "s1-baseline".to_string();
    s
}

/// Row-by-Row (§7.2): group `group_size` consecutive patches left→right,
/// row after row.
pub fn row_by_row(layer: &ConvLayer, group_size: usize) -> GroupedStrategy {
    let order = row_major_order(layer);
    let mut s = order_to_groups(layer, &order, group_size);
    s.name = format!("row-by-row-g{group_size}");
    s
}

/// ZigZag (§7.2): even rows left→right, odd rows right→left.
pub fn zigzag(layer: &ConvLayer, group_size: usize) -> GroupedStrategy {
    let order = zigzag_order(layer);
    let mut s = order_to_groups(layer, &order, group_size);
    s.name = format!("zigzag-g{group_size}");
    s
}

/// Hilbert-curve ordering (an extension heuristic; see DESIGN.md §8).
pub fn hilbert(layer: &ConvLayer, group_size: usize) -> GroupedStrategy {
    let order = hilbert_order(layer);
    let mut s = order_to_groups(layer, &order, group_size);
    s.name = format!("hilbert-g{group_size}");
    s
}

/// Anti-diagonal ordering (extension heuristic).
pub fn diagonal(layer: &ConvLayer, group_size: usize) -> GroupedStrategy {
    let order = diagonal_order(layer);
    let mut s = order_to_groups(layer, &order, group_size);
    s.name = format!("diagonal-g{group_size}");
    s
}

/// Build a grouped strategy from any [`Ordering`] — the uniform entry point
/// the planner's portfolio race uses to enumerate the ordering heuristics.
///
/// # Examples
///
/// ```
/// use convoffload::conv::ConvLayer;
/// use convoffload::strategy::{self, Ordering};
///
/// let layer = ConvLayer::new(1, 6, 6, 3, 3, 1, 1, 1).unwrap();
/// let s = strategy::from_ordering(&layer, Ordering::ZigZag, 2);
/// assert_eq!(s.n_steps(), 8); // 16 patches in groups of 2
/// let steps = s.compile(&layer);
/// assert_eq!(steps.len(), s.n_steps() + 1); // + terminal flush
/// ```
pub fn from_ordering(
    layer: &ConvLayer,
    ordering: Ordering,
    group_size: usize,
) -> GroupedStrategy {
    let order = ordering.order(layer);
    let mut s = order_to_groups(layer, &order, group_size);
    s.name = format!("{}-g{group_size}", ordering.as_str());
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn s1_baseline_is_one_patch_per_step() {
        let l = ConvLayer::square(2, 5, 3, 2);
        let s = s1_baseline(&l);
        assert_eq!(s.groups.len(), l.n_patches());
        assert!(s.groups.iter().all(|g| g.len() == 1));
    }

    #[test]
    fn builders_cover_all_patches_once() {
        let l = ConvLayer::square(1, 8, 3, 1);
        for s in [
            s1_baseline(&l),
            row_by_row(&l, 4),
            zigzag(&l, 4),
            hilbert(&l, 4),
            diagonal(&l, 4),
        ] {
            let mut seen: Vec<u32> = s.groups.iter().flatten().copied().collect();
            seen.sort();
            assert_eq!(
                seen,
                l.all_patches().collect::<Vec<_>>(),
                "strategy {} must cover X exactly once",
                s.name
            );
        }
    }
}

//! Strategy serialization: the CSV format accepted by the paper's simulator
//! (“user defined or from an ILP solver CSV file”, §6) and a JSON form.
//!
//! CSV schema (header required):
//! ```text
//! step,patches,writeback
//! 1,0;1,every_step
//! 2,2;3,
//! ```
//! `patches` is `;`-separated patch ids; `writeback` is only read from the
//! first row (blank = every_step).

use crate::conv::PatchId;
use crate::strategy::{GroupedStrategy, WritebackPolicy};
use crate::util::{csv, json::Json};

/// Serialize to CSV.
pub fn strategy_to_csv(s: &GroupedStrategy) -> String {
    let mut rows = vec![vec![
        "step".to_string(),
        "patches".to_string(),
        "writeback".to_string(),
    ]];
    for (i, g) in s.groups.iter().enumerate() {
        rows.push(vec![
            (i + 1).to_string(),
            g.iter()
                .map(|p| p.to_string())
                .collect::<Vec<_>>()
                .join(";"),
            if i == 0 { s.writeback.as_str().to_string() } else { String::new() },
        ]);
    }
    csv::write(&rows)
}

/// Parse from CSV (inverse of [`strategy_to_csv`]).
pub fn strategy_from_csv(name: &str, text: &str) -> Result<GroupedStrategy, String> {
    let rows = csv::parse(text)?;
    if rows.is_empty() {
        return Err("empty strategy CSV".into());
    }
    let header = &rows[0];
    if header.len() < 2 || header[0] != "step" || header[1] != "patches" {
        return Err("strategy CSV must start with 'step,patches[,writeback]'".into());
    }
    let mut groups = Vec::new();
    let mut writeback = WritebackPolicy::EveryStep;
    for (ridx, row) in rows[1..].iter().enumerate() {
        if row.len() < 2 {
            return Err(format!("row {}: expected at least 2 fields", ridx + 2));
        }
        let expect_step: usize = ridx + 1;
        let step: usize = row[0]
            .parse()
            .map_err(|_| format!("row {}: bad step index '{}'", ridx + 2, row[0]))?;
        if step != expect_step {
            return Err(format!(
                "row {}: steps must be consecutive from 1 (got {step}, expected {expect_step})",
                ridx + 2
            ));
        }
        let mut group = Vec::new();
        for tok in row[1].split(';').filter(|t| !t.is_empty()) {
            let p: PatchId = tok
                .parse()
                .map_err(|_| format!("row {}: bad patch id '{tok}'", ridx + 2))?;
            group.push(p);
        }
        if group.is_empty() {
            return Err(format!("row {}: empty group", ridx + 2));
        }
        if ridx == 0 && row.len() >= 3 && !row[2].is_empty() {
            writeback = WritebackPolicy::from_str(&row[2])?;
        }
        groups.push(group);
    }
    if groups.is_empty() {
        return Err("strategy CSV has no steps".into());
    }
    let mut s = GroupedStrategy::new(name, groups);
    s.writeback = writeback;
    Ok(s)
}

/// Serialize to JSON.
pub fn strategy_to_json(s: &GroupedStrategy) -> String {
    let mut o = Json::obj();
    o.set("name", s.name.as_str())
        .set("writeback", s.writeback.as_str())
        .set(
            "groups",
            Json::Arr(
                s.groups
                    .iter()
                    .map(|g| Json::Arr(g.iter().map(|&p| Json::from(p)).collect()))
                    .collect(),
            ),
        );
    o.to_string_pretty()
}

/// Parse from JSON text (inverse of [`strategy_to_json`]).
pub fn strategy_from_json(text: &str) -> Result<GroupedStrategy, String> {
    let v = crate::util::json::parse(text).map_err(|e| e.to_string())?;
    strategy_from_json_value(&v)
}

/// Parse from an already-parsed JSON value — avoids a re-serialize/re-parse
/// round trip when the strategy is a subtree of a larger document (the
/// planner's cache files).
pub fn strategy_from_json_value(v: &Json) -> Result<GroupedStrategy, String> {
    let name = v
        .get("name")
        .and_then(Json::as_str)
        .ok_or("missing 'name'")?
        .to_string();
    let writeback = match v.get("writeback").and_then(Json::as_str) {
        Some(w) => WritebackPolicy::from_str(w)?,
        None => WritebackPolicy::EveryStep,
    };
    let groups_json = v
        .get("groups")
        .and_then(Json::as_arr)
        .ok_or("missing 'groups' array")?;
    let mut groups = Vec::with_capacity(groups_json.len());
    for (i, g) in groups_json.iter().enumerate() {
        let arr = g.as_arr().ok_or(format!("group {i} is not an array"))?;
        let mut group = Vec::with_capacity(arr.len());
        for p in arr {
            group.push(
                p.as_u64().ok_or(format!("group {i}: bad patch id"))? as PatchId
            );
        }
        if group.is_empty() {
            return Err(format!("group {i} is empty"));
        }
        groups.push(group);
    }
    let mut s = GroupedStrategy::new(name, groups);
    s.writeback = writeback;
    Ok(s)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::conv::ConvLayer;

    fn sample() -> GroupedStrategy {
        let l = ConvLayer::square(1, 5, 3, 1);
        let mut s = crate::strategy::zigzag(&l, 2);
        s.writeback = WritebackPolicy::AtEnd;
        s
    }

    #[test]
    fn csv_roundtrip() {
        let s = sample();
        let text = strategy_to_csv(&s);
        let back = strategy_from_csv(&s.name, &text).unwrap();
        assert_eq!(back.groups, s.groups);
        assert_eq!(back.writeback, s.writeback);
    }

    #[test]
    fn json_roundtrip() {
        let s = sample();
        let back = strategy_from_json(&strategy_to_json(&s)).unwrap();
        assert_eq!(back, s);
    }

    #[test]
    fn csv_rejects_malformed() {
        assert!(strategy_from_csv("x", "").is_err());
        assert!(strategy_from_csv("x", "bogus,header\n1,0\n").is_err());
        assert!(strategy_from_csv("x", "step,patches\n2,0\n").is_err());
        assert!(strategy_from_csv("x", "step,patches\n1,\n").is_err());
        assert!(strategy_from_csv("x", "step,patches\n1,a;b\n").is_err());
    }

    #[test]
    fn json_rejects_malformed() {
        assert!(strategy_from_json("{}").is_err());
        assert!(strategy_from_json(r#"{"name":"x"}"#).is_err());
        assert!(strategy_from_json(r#"{"name":"x","groups":[[]]}"#).is_err());
        assert!(strategy_from_json(r#"{"name":"x","groups":[[1.5]]}"#).is_err());
    }

    #[test]
    fn csv_default_writeback() {
        let text = "step,patches\n1,0;1\n2,2\n";
        let s = strategy_from_csv("t", text).unwrap();
        assert_eq!(s.writeback, WritebackPolicy::EveryStep);
        assert_eq!(s.groups, vec![vec![0, 1], vec![2]]);
    }
}

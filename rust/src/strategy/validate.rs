//! Strategy validation against the formalism and the §2.3 assumptions.
//!
//! [`validate`] dry-runs the compiled steps through the step semantics and
//! additionally checks the strategy-level assumptions the per-step semantics
//! cannot see:
//!
//! * every patch of `X` is computed exactly once;
//! * every pixel is loaded at most `nb_data_reload` times (§2.3, fixed to 2
//!   in the paper);
//! * the on-chip memory is empty after the final step and all outputs have
//!   been written back.

use crate::conv::ConvLayer;
use crate::platform::{Accelerator, MemoryState};
use crate::step::{self, StepError};
use crate::strategy::GroupedStrategy;

/// A violated assumption.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Violation {
    /// The step semantics rejected step `index`.
    Step { index: usize, error: StepError },
    /// Patch never computed.
    PatchMissing { patch: u32 },
    /// Patch computed more than once.
    PatchDuplicated { patch: u32 },
    /// Pixel loaded more than the reload bound.
    PixelReloaded { pixel: u32, loads: u32, bound: u32 },
    /// Memory not empty after the final step.
    MemoryNotEmpty,
    /// Outputs missing from DRAM at the end.
    OutputsNotWritten { missing: usize },
    /// A group exceeds the accelerator's patch capacity.
    GroupTooLarge { step: usize, len: usize, max: usize },
}

impl std::fmt::Display for Violation {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{self:?}")
    }
}

/// Outcome of validation.
#[derive(Debug, Clone)]
pub struct ValidationReport {
    /// Every violation found (empty means the strategy is valid).
    pub violations: Vec<Violation>,
    /// Per-pixel load counts (diagnostic; index = pixel id).
    pub pixel_loads: Vec<u32>,
    /// Peak on-chip occupancy over the whole strategy.
    pub peak_occupancy: u64,
}

impl ValidationReport {
    /// True when no violation was found.
    pub fn is_valid(&self) -> bool {
        self.violations.is_empty()
    }
}

/// Validate `strategy` for `layer` on `acc` with the given reload bound
/// (`nb_data_reload`; the paper fixes 2).
pub fn validate(
    layer: &ConvLayer,
    acc: &Accelerator,
    strategy: &GroupedStrategy,
    nb_data_reload: u32,
) -> ValidationReport {
    let steps = strategy.compile(layer);
    let mut violations = Vec::new();
    let mut mem = MemoryState::initial(layer);
    let mut pixel_loads = vec![0u32; layer.n_pixels()];
    let mut computed = vec![0u32; layer.n_patches()];
    let mut written = vec![false; layer.n_patches()];
    let mut peak = 0u64;

    let max_group = acc.max_patches_per_step(layer);

    for (i, st) in steps.iter().enumerate() {
        if !st.group.is_empty() && max_group > 0 && st.group.len() > max_group {
            violations.push(Violation::GroupTooLarge {
                step: i,
                len: st.group.len(),
                max: max_group,
            });
        }
        for px in st.load_inp.iter() {
            pixel_loads[px as usize] += 1;
        }
        for p in st.write.iter() {
            written[p as usize] = true;
        }
        for &p in &st.group {
            computed[p as usize] += 1;
        }
        match step::apply(layer, acc, &mut mem, st, true) {
            Ok(out) => peak = peak.max(out.occupancy),
            Err(e) => {
                violations.push(Violation::Step { index: i, error: e });
                // semantics already mutated `mem` partially; stop here — the
                // remaining trajectory is undefined.
                break;
            }
        }
    }

    for (p, &c) in computed.iter().enumerate() {
        if c == 0 {
            violations.push(Violation::PatchMissing { patch: p as u32 });
        } else if c > 1 {
            violations.push(Violation::PatchDuplicated { patch: p as u32 });
        }
    }
    for (px, &loads) in pixel_loads.iter().enumerate() {
        if loads > nb_data_reload {
            violations.push(Violation::PixelReloaded {
                pixel: px as u32,
                loads,
                bound: nb_data_reload,
            });
        }
    }
    if !mem.is_empty() {
        violations.push(Violation::MemoryNotEmpty);
    }
    let missing = written.iter().filter(|&&w| !w).count();
    if missing > 0 {
        violations.push(Violation::OutputsNotWritten { missing });
    }

    ValidationReport { violations, pixel_loads, peak_occupancy: peak }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::strategy;

    fn layer() -> ConvLayer {
        ConvLayer::new(2, 5, 5, 3, 3, 2, 1, 1).unwrap()
    }

    #[test]
    fn builtin_strategies_validate() {
        // NOTE: the reload bound is H_K, not the paper's 2 — linear-scan
        // heuristics intrinsically load interior pixels once per kernel row
        // (see `heuristics_exceed_paper_reload_bound` below).
        let l = layer();
        for group in 1..=4usize {
            let acc = Accelerator::for_group_size(&l, group);
            for s in [
                strategy::row_by_row(&l, group),
                strategy::zigzag(&l, group),
                strategy::hilbert(&l, group),
                strategy::diagonal(&l, group),
            ] {
                let r = validate(&l, &acc, &s, l.h_k as u32);
                assert!(
                    r.is_valid(),
                    "strategy {} group {group}: {:?}",
                    s.name,
                    r.violations
                );
            }
        }
    }

    #[test]
    fn s1_baseline_validates() {
        let l = layer();
        let acc = Accelerator::for_group_size(&l, 1);
        let r = validate(&l, &acc, &strategy::s1_baseline(&l), l.h_k as u32);
        assert!(r.is_valid(), "{:?}", r.violations);
    }

    /// A reproduction finding (recorded in EXPERIMENTS.md): the paper fixes
    /// `nb_data_reload = 2` (§2.3) but its own Row-by-Row / ZigZag baselines
    /// load interior pixels once per kernel row — 3 times for 3×3 kernels —
    /// whenever the group is smaller than an output row. The bound therefore
    /// only constrains the ILP strategies, not the heuristics.
    #[test]
    fn heuristics_exceed_paper_reload_bound() {
        let l = layer(); // 3x3 kernels
        let acc = Accelerator::for_group_size(&l, 1);
        let r = validate(&l, &acc, &strategy::row_by_row(&l, 1), 2);
        assert!(r
            .violations
            .iter()
            .any(|v| matches!(v, Violation::PixelReloaded { loads: 3, .. })));
        // With full-row groups the scan becomes 2-load and satisfies it...
        let acc3 = Accelerator::for_group_size(&l, 3);
        let r3 = validate(&l, &acc3, &strategy::row_by_row(&l, 3), 2);
        assert!(r3.is_valid(), "{:?}", r3.violations);
    }

    #[test]
    fn detects_missing_patch() {
        let l = layer();
        let acc = Accelerator::for_group_size(&l, 2);
        let mut s = strategy::row_by_row(&l, 2);
        s.groups.pop(); // drop the last group (patch 8)
        let r = validate(&l, &acc, &s, 2);
        assert!(r
            .violations
            .iter()
            .any(|v| matches!(v, Violation::PatchMissing { patch: 8 })));
    }

    #[test]
    fn detects_duplicate_patch() {
        let l = layer();
        let acc = Accelerator::for_group_size(&l, 2);
        let mut s = strategy::row_by_row(&l, 2);
        s.groups.push(vec![0]); // recompute patch 0
        let r = validate(&l, &acc, &s, 2);
        // duplicate shows up either as a semantics error (output collision)
        // or as the strategy-level duplicate count
        assert!(!r.is_valid());
    }

    #[test]
    fn detects_group_too_large() {
        let l = layer();
        let acc = Accelerator::for_group_size(&l, 2);
        let s = strategy::row_by_row(&l, 4); // groups of 4 > max 2
        let r = validate(&l, &acc, &s, 2);
        assert!(r
            .violations
            .iter()
            .any(|v| matches!(v, Violation::GroupTooLarge { .. })));
    }

    #[test]
    fn detects_reload_bound() {
        let l = layer();
        let acc = Accelerator::for_group_size(&l, 1);
        // Pathological order: bounce between far corners so the centre
        // overlap pixels get reloaded many times.
        let order: Vec<u32> = vec![0, 8, 1, 7, 2, 6, 3, 5, 4];
        let s = strategy::order_to_groups(&l, &order, 1);
        let r = validate(&l, &acc, &s, 1);
        assert!(r
            .violations
            .iter()
            .any(|v| matches!(v, Violation::PixelReloaded { .. })));
    }

    #[test]
    fn reports_peak_occupancy() {
        let l = layer();
        let acc = Accelerator::for_group_size(&l, 2);
        let r = validate(&l, &acc, &strategy::row_by_row(&l, 2), 2);
        assert!(r.is_valid());
        assert!(r.peak_occupancy > 0);
        assert!(r.peak_occupancy <= acc.size_mem);
    }
}

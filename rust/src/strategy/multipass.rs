//! Multi-pass strategies: kernels that do NOT all fit on chip.
//!
//! The paper's §9 future work drops S1's "all kernels resident" assumption.
//! The natural generalization keeps the formalism intact: partition
//! `Λ` into chunks of `kernels_per_pass`; each pass runs a full S1-style
//! grouped strategy over the input with only its kernel chunk resident,
//! computing that chunk's output channels for every patch.
//!
//! Trade-off surfaced (and benchmarked in `bench_ablation`): fewer resident
//! kernels shrink the kernel footprint by `(1 − 1/P)·|Λ|` elements but
//! reload the *input* `P` times, multiplying the `Σ|I_slice|` term — the
//! exact bandwidth-vs-capacity tension Siu et al. explore across their four
//! strategies.
//!
//! Execution composes with everything already in the repo: each pass is an
//! ordinary [`GroupedStrategy`] over a *sub-layer* whose kernel set is the
//! chunk, so the simulator, optimizer and PJRT runtime all apply per pass.

use crate::conv::{ConvLayer, PatchId};
use crate::platform::Accelerator;
use crate::sim::{ComputeBackend, SimError, Simulator};
use crate::step::StrategyCost;
use crate::strategy::GroupedStrategy;

/// A multi-pass plan: one grouped strategy per kernel chunk.
#[derive(Debug, Clone, PartialEq)]
pub struct MultiPassStrategy {
    /// Plan name used in reports.
    pub name: String,
    /// Kernel ids per pass (a partition of `0..N`).
    pub kernel_chunks: Vec<Vec<usize>>,
    /// The patch grouping executed in each pass.
    pub per_pass: GroupedStrategy,
}

/// Aggregate report over all passes.
#[derive(Debug, Clone)]
pub struct MultiPassReport {
    /// Duration of each pass in cycles.
    pub per_pass_duration: Vec<u64>,
    /// Total duration over all passes in cycles.
    pub duration: u64,
    /// Peak on-chip occupancy across passes (elements).
    pub peak_occupancy: u64,
    /// Aggregated loads / writes / MACs over all passes.
    pub totals: StrategyCost,
    /// Functional output `[C_out, H_out, W_out]` (functional mode only).
    pub output: Option<Vec<f32>>,
    /// Worst |output − reference| across passes (functional mode).
    pub max_abs_error: Option<f32>,
}

impl MultiPassStrategy {
    /// Split `Λ` into ⌈N / kernels_per_pass⌉ chunks and pair each with the
    /// given per-pass patch grouping.
    pub fn new(
        layer: &ConvLayer,
        kernels_per_pass: usize,
        per_pass: GroupedStrategy,
    ) -> Result<Self, String> {
        if kernels_per_pass == 0 {
            return Err("kernels_per_pass must be ≥ 1".into());
        }
        // Arbitrary kernel chunks would cut across channel groups and the
        // pass sub-layer (n_kernels = chunk size) would no longer divide by
        // `groups`; gate until chunking is made group-aligned.
        if layer.groups > 1 {
            return Err(format!(
                "multi-pass strategies do not support grouped layers yet (groups = {})",
                layer.groups
            ));
        }
        let chunks: Vec<Vec<usize>> = (0..layer.n_kernels)
            .collect::<Vec<_>>()
            .chunks(kernels_per_pass)
            .map(<[usize]>::to_vec)
            .collect();
        Ok(MultiPassStrategy {
            name: format!("{}-x{}passes", per_pass.name, chunks.len()),
            kernel_chunks: chunks,
            per_pass,
        })
    }

    /// Number of kernel-chunk passes.
    pub fn n_passes(&self) -> usize {
        self.kernel_chunks.len()
    }

    /// The sub-layer a pass runs on: same geometry, chunk-sized kernel set.
    pub fn pass_layer(&self, layer: &ConvLayer, pass: usize) -> ConvLayer {
        let mut sub = *layer;
        sub.n_kernels = self.kernel_chunks[pass].len();
        sub
    }

    /// Accelerator for a pass: same machine; the op bound applies to the
    /// chunk-sized patch compute.
    fn pass_accelerator(&self, acc: &Accelerator) -> Accelerator {
        *acc
    }

    /// Logical simulation of all passes (duration adds, peak maxes).
    pub fn run(
        &self,
        layer: &ConvLayer,
        acc: &Accelerator,
    ) -> Result<MultiPassReport, SimError> {
        let mut report = MultiPassReport {
            per_pass_duration: Vec::new(),
            duration: 0,
            peak_occupancy: 0,
            totals: StrategyCost::default(),
            output: None,
            max_abs_error: None,
        };
        for pass in 0..self.n_passes() {
            let sub = self.pass_layer(layer, pass);
            let sim = Simulator::new(
                sub,
                crate::platform::Platform::new(self.pass_accelerator(acc)),
            );
            let r = sim.run(&self.per_pass)?;
            report.per_pass_duration.push(r.duration);
            report.duration += r.duration;
            report.peak_occupancy = report.peak_occupancy.max(r.peak_occupancy);
            for s in &r.steps {
                report.totals.push(&s.cost);
            }
        }
        Ok(report)
    }

    /// Functional simulation: each pass computes its chunk's output channels
    /// on `backend`; the full output tensor is assembled across passes and
    /// checked against the whole-layer reference.
    pub fn run_functional(
        &self,
        layer: &ConvLayer,
        acc: &Accelerator,
        input: &[f32],
        kernels: &[f32],
        backend: &mut dyn ComputeBackend,
    ) -> Result<MultiPassReport, SimError> {
        let mut report = MultiPassReport {
            per_pass_duration: Vec::new(),
            duration: 0,
            peak_occupancy: 0,
            totals: StrategyCost::default(),
            output: None,
            max_abs_error: None,
        };
        let (h_out, w_out) = (layer.h_out(), layer.w_out());
        let mut output = vec![f32::NAN; layer.output_dims().len()];
        let kernel_len = layer.kernel_dims().len();

        for pass in 0..self.n_passes() {
            let sub = self.pass_layer(layer, pass);
            // Kernel values of this chunk, contiguous per sub-layer layout.
            let mut chunk_kernels = Vec::with_capacity(
                self.kernel_chunks[pass].len() * kernel_len,
            );
            for &kid in &self.kernel_chunks[pass] {
                chunk_kernels
                    .extend_from_slice(&kernels[kid * kernel_len..(kid + 1) * kernel_len]);
            }
            let sim = Simulator::new(
                sub,
                crate::platform::Platform::new(self.pass_accelerator(acc)),
            );
            let r = sim.run_functional(&self.per_pass, input, &chunk_kernels, backend)?;
            report.per_pass_duration.push(r.duration);
            report.duration += r.duration;
            report.peak_occupancy = report.peak_occupancy.max(r.peak_occupancy);
            for s in &r.steps {
                report.totals.push(&s.cost);
            }
            // Scatter the pass's channels into the full output.
            let pass_out = r.output.expect("functional mode fills output");
            for (ci, &kid) in self.kernel_chunks[pass].iter().enumerate() {
                let src = &pass_out[ci * h_out * w_out..(ci + 1) * h_out * w_out];
                output[kid * h_out * w_out..(kid + 1) * h_out * w_out]
                    .copy_from_slice(src);
            }
        }

        let reference = crate::conv::reference::conv2d(layer, input, kernels);
        let max_err = output
            .iter()
            .zip(&reference)
            .map(|(a, b)| (a - b).abs())
            .fold(0f32, f32::max);
        report.output = Some(output);
        report.max_abs_error = Some(max_err);
        Ok(report)
    }

    /// Peak kernel-memory saving vs single-pass S1, in elements.
    pub fn kernel_memory_saving(&self, layer: &ConvLayer) -> u64 {
        let per_kernel = layer.kernel_dims().len() as u64;
        let max_chunk = self
            .kernel_chunks
            .iter()
            .map(Vec::len)
            .max()
            .unwrap_or(0) as u64;
        (layer.n_kernels as u64 - max_chunk) * per_kernel
    }
}

/// All patch ids of the layer in the per-pass strategy (sanity helper).
pub fn covers_all_patches(layer: &ConvLayer, s: &GroupedStrategy) -> bool {
    let mut seen: Vec<PatchId> = s.groups.iter().flatten().copied().collect();
    seen.sort();
    seen == layer.all_patches().collect::<Vec<_>>()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::conv::reference;
    use crate::sim::RustOracleBackend;
    use crate::strategy;

    fn layer() -> ConvLayer {
        // 4 kernels so multi-pass is meaningful
        ConvLayer::new(2, 6, 6, 3, 3, 4, 1, 1).unwrap()
    }

    #[test]
    fn splits_kernels_into_chunks() {
        let l = layer();
        let mp = MultiPassStrategy::new(&l, 3, strategy::zigzag(&l, 2)).unwrap();
        assert_eq!(mp.n_passes(), 2);
        assert_eq!(mp.kernel_chunks, vec![vec![0, 1, 2], vec![3]]);
        assert_eq!(mp.pass_layer(&l, 0).n_kernels, 3);
        assert_eq!(mp.pass_layer(&l, 1).n_kernels, 1);
        assert!(MultiPassStrategy::new(&l, 0, strategy::zigzag(&l, 2)).is_err());
    }

    /// Grouped layers are rejected: a kernel chunk need not align with the
    /// channel groups, so the pass sub-layer would be invalid.
    #[test]
    fn grouped_layers_are_gated() {
        let l = ConvLayer::new(2, 6, 6, 3, 3, 4, 1, 1)
            .unwrap()
            .with_groups(2)
            .unwrap();
        let err = MultiPassStrategy::new(&l, 3, strategy::zigzag(&l, 2));
        assert!(err.is_err());
        assert!(err.unwrap_err().contains("grouped"));
    }

    #[test]
    fn duration_scales_with_passes() {
        let l = layer();
        // accelerator sized for the 2-kernel sub-layer (the larger chunk)
        let sub = MultiPassStrategy::new(&l, 2, strategy::zigzag(&l, 2))
            .unwrap()
            .pass_layer(&l, 0);
        let acc = Accelerator::for_group_size(&sub, 2);
        let two_pass = MultiPassStrategy::new(&l, 2, strategy::zigzag(&sub, 2)).unwrap();
        let r = two_pass.run(&l, &acc).unwrap();
        assert_eq!(r.per_pass_duration.len(), 2);
        // both passes identical → duration exactly doubles one pass
        assert_eq!(r.per_pass_duration[0], r.per_pass_duration[1]);
        assert_eq!(r.duration, 2 * r.per_pass_duration[0]);
    }

    #[test]
    fn kernel_memory_saving_vs_input_reload_tradeoff() {
        let l = layer();
        let sub2 = {
            let mut s = l;
            s.n_kernels = 2;
            s
        };
        let acc = Accelerator::for_group_size(&sub2, 2);
        let single_layer_acc = Accelerator::for_group_size(&l, 2);

        let single = Simulator::new(
            l,
            crate::platform::Platform::new(single_layer_acc),
        )
        .run(&strategy::zigzag(&l, 2))
        .unwrap();

        let mp = MultiPassStrategy::new(&l, 2, strategy::zigzag(&sub2, 2)).unwrap();
        let multi = mp.run(&l, &acc).unwrap();

        // the multi-pass loads the input twice → more total loads …
        assert!(multi.totals.total.loaded_elements > single.total_loaded());
        // … but peaks lower on-chip (half the kernels resident)
        assert!(multi.peak_occupancy < single.peak_occupancy);
        assert_eq!(mp.kernel_memory_saving(&l), 2 * 18);
    }

    #[test]
    fn functional_multipass_matches_reference() {
        let l = layer();
        let sub2 = {
            let mut s = l;
            s.n_kernels = 2;
            s
        };
        let input = reference::synth_tensor(l.input_dims().len(), 91);
        let kernels = reference::synth_tensor(l.kernel_elements(), 92);
        for kpp in [1usize, 2, 3, 4] {
            let mp =
                MultiPassStrategy::new(&l, kpp, strategy::zigzag(&sub2, 2)).unwrap();
            // accelerator sized for the largest chunk's sub-layer
            let acc = Accelerator::for_group_size(&mp.pass_layer(&l, 0), 2);
            // pass layers with ≤ kpp kernels: per-pass strategy geometry is
            // kernel-count independent, so reuse is fine
            let mut backend = RustOracleBackend;
            let r = mp
                .run_functional(&l, &acc, &input, &kernels, &mut backend)
                .unwrap();
            let err = r.max_abs_error.unwrap();
            assert!(err < 1e-4, "kpp={kpp}: err {err}");
            assert!(r.output.unwrap().iter().all(|v| !v.is_nan()));
        }
    }

    #[test]
    fn covers_all_patches_helper() {
        let l = layer();
        assert!(covers_all_patches(&l, &strategy::zigzag(&l, 2)));
        let mut broken = strategy::zigzag(&l, 2);
        broken.groups.pop();
        assert!(!covers_all_patches(&l, &broken));
    }
}

//! Patch orderings: row-major, zigzag (§7.2), Hilbert and anti-diagonal
//! (extension heuristics), plus the order→groups chunker.

use crate::conv::{ConvLayer, PatchId};
use crate::strategy::GroupedStrategy;

/// Built-in ordering kinds (CLI / config selectable).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Ordering {
    /// Left→right, row after row (§7.2).
    RowByRow,
    /// Even rows left→right, odd rows right→left (§7.2).
    ZigZag,
    /// Hilbert space-filling curve (extension heuristic).
    Hilbert,
    /// Anti-diagonal sweep (extension heuristic).
    Diagonal,
}

impl Ordering {
    /// Stable ordering name (CLI values, cache files, reports).
    pub fn as_str(&self) -> &'static str {
        match self {
            Ordering::RowByRow => "row-by-row",
            Ordering::ZigZag => "zigzag",
            Ordering::Hilbert => "hilbert",
            Ordering::Diagonal => "diagonal",
        }
    }

    /// Parse an ordering name.
    pub fn from_str(s: &str) -> Result<Self, String> {
        match s {
            "row-by-row" | "row" => Ok(Ordering::RowByRow),
            "zigzag" => Ok(Ordering::ZigZag),
            "hilbert" => Ok(Ordering::Hilbert),
            "diagonal" => Ok(Ordering::Diagonal),
            other => Err(format!("unknown ordering '{other}'")),
        }
    }

    /// The patch visit order this ordering induces on `layer`.
    pub fn order(&self, layer: &ConvLayer) -> Vec<PatchId> {
        match self {
            Ordering::RowByRow => row_major_order(layer),
            Ordering::ZigZag => zigzag_order(layer),
            Ordering::Hilbert => hilbert_order(layer),
            Ordering::Diagonal => diagonal_order(layer),
        }
    }

    /// Every built-in ordering, in the fixed portfolio order.
    pub fn all() -> [Ordering; 4] {
        [Ordering::RowByRow, Ordering::ZigZag, Ordering::Hilbert, Ordering::Diagonal]
    }
}

/// Left→right, top→bottom (the paper's Row-by-Row basis).
pub fn row_major_order(layer: &ConvLayer) -> Vec<PatchId> {
    layer.all_patches().collect()
}

/// Boustrophedon: even output rows left→right, odd rows right→left.
pub fn zigzag_order(layer: &ConvLayer) -> Vec<PatchId> {
    let (h_out, w_out) = (layer.h_out(), layer.w_out());
    let mut order = Vec::with_capacity(h_out * w_out);
    for i in 0..h_out {
        if i % 2 == 0 {
            for j in 0..w_out {
                order.push(layer.patch_id(i, j));
            }
        } else {
            for j in (0..w_out).rev() {
                order.push(layer.patch_id(i, j));
            }
        }
    }
    order
}

/// Hilbert-curve order over the output grid (locality-preserving extension).
///
/// Computed on the enclosing power-of-two square, filtered to the real grid.
pub fn hilbert_order(layer: &ConvLayer) -> Vec<PatchId> {
    let (h_out, w_out) = (layer.h_out(), layer.w_out());
    let side = h_out.max(w_out).next_power_of_two().max(1);
    let mut order = Vec::with_capacity(h_out * w_out);
    for d in 0..side * side {
        let (x, y) = hilbert_d2xy(side, d);
        if y < h_out && x < w_out {
            order.push(layer.patch_id(y, x));
        }
    }
    order
}

/// Convert a Hilbert distance to (x, y) on a `side × side` grid
/// (standard bit-twiddling construction).
fn hilbert_d2xy(side: usize, d: usize) -> (usize, usize) {
    let (mut x, mut y) = (0usize, 0usize);
    let mut t = d;
    let mut s = 1usize;
    while s < side {
        let rx = 1 & (t / 2);
        let ry = 1 & (t ^ rx);
        // rotate quadrant
        if ry == 0 {
            if rx == 1 {
                x = s - 1 - x;
                y = s - 1 - y;
            }
            std::mem::swap(&mut x, &mut y);
        }
        x += s * rx;
        y += s * ry;
        t /= 4;
        s *= 2;
    }
    (x, y)
}

/// Anti-diagonal sweep: patches ordered by `i + j`, then by `i`.
pub fn diagonal_order(layer: &ConvLayer) -> Vec<PatchId> {
    let (h_out, w_out) = (layer.h_out(), layer.w_out());
    let mut order = Vec::with_capacity(h_out * w_out);
    for d in 0..(h_out + w_out - 1) {
        for i in 0..h_out {
            if d >= i && d - i < w_out {
                order.push(layer.patch_id(i, d - i));
            }
        }
    }
    order
}

/// Chunk a patch order into groups of at most `group_size` — the grouped-S1
/// construction of §4.2 applied to a linear ordering.
pub fn order_to_groups(
    layer: &ConvLayer,
    order: &[PatchId],
    group_size: usize,
) -> GroupedStrategy {
    assert!(group_size >= 1, "group size must be at least 1");
    debug_assert_eq!(order.len(), layer.n_patches());
    let groups = order.chunks(group_size).map(<[PatchId]>::to_vec).collect();
    GroupedStrategy::new("custom-order", groups)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn is_permutation(layer: &ConvLayer, order: &[PatchId]) -> bool {
        let mut v = order.to_vec();
        v.sort();
        v == layer.all_patches().collect::<Vec<_>>()
    }

    #[test]
    fn all_orderings_are_permutations() {
        for (h, w) in [(5, 5), (6, 9), (9, 6), (4, 4), (12, 12)] {
            let l = ConvLayer::new(1, h + 2, w + 2, 3, 3, 1, 1, 1).unwrap();
            assert_eq!(l.h_out(), h);
            assert_eq!(l.w_out(), w);
            for o in Ordering::all() {
                assert!(
                    is_permutation(&l, &o.order(&l)),
                    "{} on {h}x{w}",
                    o.as_str()
                );
            }
        }
    }

    #[test]
    fn zigzag_reverses_odd_rows() {
        let l = ConvLayer::new(1, 5, 5, 3, 3, 1, 1, 1).unwrap(); // 3x3 out
        let order = zigzag_order(&l);
        assert_eq!(
            order,
            vec![0, 1, 2, /* row1 reversed */ 5, 4, 3, /* row2 */ 6, 7, 8]
        );
    }

    #[test]
    fn zigzag_equals_row_for_single_row() {
        let l = ConvLayer::new(1, 3, 12, 3, 3, 1, 1, 1).unwrap(); // 1x10 out
        assert_eq!(zigzag_order(&l), row_major_order(&l));
    }

    #[test]
    fn diagonal_order_small() {
        let l = ConvLayer::new(1, 5, 5, 3, 3, 1, 1, 1).unwrap(); // 3x3 out
        // anti-diagonals: (0,0) | (0,1),(1,0) | (0,2),(1,1),(2,0) | ...
        assert_eq!(diagonal_order(&l), vec![0, 1, 3, 2, 4, 6, 5, 7, 8]);
    }

    #[test]
    fn hilbert_is_locality_preserving() {
        let l = ConvLayer::new(1, 10, 10, 3, 3, 1, 1, 1).unwrap(); // 8x8 out
        let order = hilbert_order(&l);
        // consecutive patches on a Hilbert curve over a full pow2 grid are
        // grid neighbours (distance 1)
        for pair in order.windows(2) {
            let a = l.patch(pair[0]);
            let b = l.patch(pair[1]);
            assert_eq!(a.grid_distance(&b), 1);
        }
    }

    #[test]
    fn order_to_groups_chunks() {
        let l = ConvLayer::new(1, 5, 5, 3, 3, 1, 1, 1).unwrap();
        let s = order_to_groups(&l, &row_major_order(&l), 4);
        assert_eq!(s.groups.len(), 3); // 9 patches → 4+4+1
        assert_eq!(s.groups[2].len(), 1);
    }

    #[test]
    fn ordering_str_roundtrip() {
        for o in Ordering::all() {
            assert_eq!(Ordering::from_str(o.as_str()), Ok(o));
        }
        assert!(Ordering::from_str("nope").is_err());
    }
}

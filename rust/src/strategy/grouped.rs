//! [`GroupedStrategy`] — the n-step strategy of S1 (Definition 16) as data,
//! and its lowering to concrete steps.

use crate::conv::{ConvLayer, PatchId};
use crate::step::Step;
use crate::tensor::PixelSet;

/// When computed outputs are written back to DRAM.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WritebackPolicy {
    /// Outputs of step `i` are written back at step `i+1` (the Example-2 /
    /// §7.1 assumption: “each output result is written back at the next
    /// step”), with remaining outputs flushed after the last step.
    EveryStep,
    /// All outputs stay on chip and are written back only by the final
    /// flush. Uses more on-chip memory; fewer but larger write bursts.
    AtEnd,
}

impl WritebackPolicy {
    /// Stable serialization name (`every_step`, `at_end`).
    pub fn as_str(&self) -> &'static str {
        match self {
            WritebackPolicy::EveryStep => "every_step",
            WritebackPolicy::AtEnd => "at_end",
        }
    }

    /// Parse a serialized policy name.
    pub fn from_str(s: &str) -> Result<Self, String> {
        match s {
            "every_step" => Ok(WritebackPolicy::EveryStep),
            "at_end" => Ok(WritebackPolicy::AtEnd),
            other => Err(format!("unknown writeback policy '{other}'")),
        }
    }
}

/// An S1-family strategy: an ordered partition of `X` into groups.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct GroupedStrategy {
    /// Strategy name used in reports and figures.
    pub name: String,
    /// `g_1 .. g_n` — each group is the patch set computed by one step.
    pub groups: Vec<Vec<PatchId>>,
    /// When computed outputs are written back to DRAM.
    pub writeback: WritebackPolicy,
}

impl GroupedStrategy {
    /// A named strategy over `groups` with the every-step write-back policy.
    pub fn new(name: impl Into<String>, groups: Vec<Vec<PatchId>>) -> Self {
        GroupedStrategy {
            name: name.into(),
            groups,
            writeback: WritebackPolicy::EveryStep,
        }
    }

    /// Number of compute steps `n`.
    pub fn n_steps(&self) -> usize {
        self.groups.len()
    }

    /// Largest group cardinality.
    pub fn max_group_len(&self) -> usize {
        self.groups.iter().map(Vec::len).max().unwrap_or(0)
    }

    /// Lower to concrete steps per Definition 16:
    ///
    /// * `I_1^slice = pix(g_1)`, `K_1^sub = Λ`;
    /// * for `i > 1`: `I_i^slice = pix(g_i) ∖ M_{i-1}^inp`,
    ///   `F_i^inp = M_{i-1}^inp ∖ pix(g_i)`;
    /// * kernels stay resident until the end;
    /// * `W_i` follows the write-back policy;
    /// * a terminal flush step (no compute) frees all inputs + kernels and
    ///   writes the remaining outputs, realizing “after the very last step
    ///   the on-chip memory has to be empty and the results written back”.
    ///   (The paper's `F_n^ker = Λ` cannot precede the step-n compute under
    ///   the a1..a6 action order, so the flush carries it.)
    pub fn compile(&self, layer: &ConvLayer) -> Vec<Step> {
        let n_px = layer.n_pixels();
        let n_k = layer.n_kernels;
        let n_p = layer.n_patches();
        let mut steps = Vec::with_capacity(self.groups.len() + 1);

        // Rolling state mirrors of M^inp and M^out.
        let mut resident = PixelSet::empty(n_px);
        let mut pending_out = PixelSet::empty(n_p);

        for (i, group) in self.groups.iter().enumerate() {
            let mut step = Step::noop(n_px, n_k, n_p);
            let footprint = layer.group_pixels(group);

            // a_1: free whatever the new group does not reuse.
            step.free_inp = resident.difference(&footprint);
            // a_3: write back per policy.
            if self.writeback == WritebackPolicy::EveryStep {
                step.write = pending_out.clone();
                pending_out.clear();
            }
            // a_4: load the missing part of the footprint.
            step.load_inp = footprint.difference(&resident);
            // a_5: all kernels on the first step only.
            if i == 0 {
                step.load_ker = PixelSet::full(n_k);
            }
            // a_6: compute.
            step.group = group.clone();
            for &p in group {
                pending_out.insert(p);
            }
            resident = footprint;
            steps.push(step);
        }

        // Terminal flush.
        let mut flush = Step::noop(n_px, n_k, n_p);
        flush.free_inp = resident;
        flush.free_ker = PixelSet::full(n_k);
        flush.write = pending_out;
        steps.push(flush);
        steps
    }

    /// Flat patch order (concatenation of groups) — the inverse of
    /// [`crate::strategy::order_to_groups`].
    pub fn flat_order(&self) -> Vec<PatchId> {
        self.groups.iter().flatten().copied().collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn layer() -> ConvLayer {
        ConvLayer::new(2, 5, 5, 3, 3, 2, 1, 1).unwrap()
    }

    #[test]
    fn compile_shape() {
        let l = layer();
        let s = crate::strategy::row_by_row(&l, 2);
        let steps = s.compile(&l);
        assert_eq!(steps.len(), s.n_steps() + 1); // + flush
        // first step loads kernels, later steps don't
        assert_eq!(steps[0].load_ker.len(), l.n_kernels);
        assert!(steps[1..].iter().all(|st| st.load_ker.is_empty()));
        // flush has no compute and frees all kernels
        let flush = steps.last().unwrap();
        assert!(flush.group.is_empty());
        assert_eq!(flush.free_ker.len(), l.n_kernels);
    }

    #[test]
    fn first_step_loads_entire_footprint() {
        let l = layer();
        let s = crate::strategy::row_by_row(&l, 2);
        let steps = s.compile(&l);
        assert_eq!(steps[0].load_inp, l.group_pixels(&s.groups[0]));
        assert!(steps[0].free_inp.is_empty());
        assert!(steps[0].write.is_empty());
    }

    #[test]
    fn consecutive_steps_reuse_overlap() {
        let l = layer();
        let s = crate::strategy::row_by_row(&l, 2);
        let steps = s.compile(&l);
        let g0 = l.group_pixels(&s.groups[0]);
        let g1 = l.group_pixels(&s.groups[1]);
        // I_2 = pix(g_2) \ pix(g_1); F_2 = pix(g_1) \ pix(g_2)
        assert_eq!(steps[1].load_inp, g1.difference(&g0));
        assert_eq!(steps[1].free_inp, g0.difference(&g1));
    }

    #[test]
    fn every_step_policy_writes_previous_outputs() {
        let l = layer();
        let s = crate::strategy::row_by_row(&l, 2);
        let steps = s.compile(&l);
        // step 2 writes exactly step 1's group
        assert_eq!(
            steps[1].write.to_vec(),
            s.groups[0].iter().copied().collect::<Vec<_>>()
        );
        // flush writes the final group
        let flush = steps.last().unwrap();
        assert_eq!(
            flush.write.to_vec(),
            s.groups.last().unwrap().iter().copied().collect::<Vec<_>>()
        );
    }

    #[test]
    fn at_end_policy_defers_all_writes() {
        let l = layer();
        let mut s = crate::strategy::row_by_row(&l, 2);
        s.writeback = WritebackPolicy::AtEnd;
        let steps = s.compile(&l);
        for st in &steps[..steps.len() - 1] {
            assert!(st.write.is_empty());
        }
        assert_eq!(steps.last().unwrap().write.len(), l.n_patches());
    }

    #[test]
    fn flat_order_roundtrip() {
        let l = layer();
        let s = crate::strategy::zigzag(&l, 2);
        let order = s.flat_order();
        assert_eq!(order.len(), l.n_patches());
    }

    #[test]
    fn writeback_policy_str_roundtrip() {
        for p in [WritebackPolicy::EveryStep, WritebackPolicy::AtEnd] {
            assert_eq!(WritebackPolicy::from_str(p.as_str()), Ok(p));
        }
        assert!(WritebackPolicy::from_str("bogus").is_err());
    }
}

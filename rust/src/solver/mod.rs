//! MILP solving substrate (offline CPLEX substitute — the solving half).
//!
//! Mirrors the paper's CPLEX workflow (§7.1):
//!
//! * [`simplex`] — a dense two-phase primal simplex used as the LP
//!   relaxation;
//! * [`branch_bound`] — 0-1 branch & bound with **MIP start** (the paper
//!   seeds CPLEX with the best heuristic strategy) and node/time budgets;
//! * the paper's **solution polishing** (CPLEX switches to a genetic
//!   algorithm after 60 s) is realized by the structure-aware annealing in
//!   [`crate::optimizer::search`], which operates directly on patch
//!   groupings rather than on the linearized model.
//!
//! The dense simplex targets the *small* instances the exact phase is used
//! for (it validates the §5 encoding and cross-checks the specialized
//! search); large instances go through the polishing path, exactly as the
//! paper's own large instances effectively did.

mod branch_bound;
mod simplex;

pub use branch_bound::{solve_milp, BranchBoundOptions};
pub use simplex::{solve_lp, LpOutcome};

//! Dense two-phase primal simplex over a [`Model`]'s LP relaxation.
//!
//! Construction:
//! * variables are shifted to `x' = x − lo ≥ 0`; finite upper bounds become
//!   explicit `x' ≤ hi − lo` rows (the models here are overwhelmingly 0-1,
//!   so `u = 1`);
//! * `≤` rows get slacks, `≥` rows get surpluses + artificials, `=` rows get
//!   artificials; phase 1 minimizes the artificial sum, phase 2 the true
//!   objective;
//! * Dantzig pricing with a Bland's-rule fallback after a degeneracy streak
//!   guarantees termination.

use crate::ilp::{Cmp, Model, VarId};

/// LP solve outcome.
#[derive(Debug, Clone, PartialEq)]
pub enum LpOutcome {
    /// Optimal solution for the relaxation (assignment over the *original*
    /// model variables) and its objective value.
    Optimal { assignment: Vec<f64>, objective: f64 },
    /// No feasible assignment exists.
    Infeasible,
    /// The relaxation is unbounded below.
    Unbounded,
}

/// Solve the LP relaxation of `model`, with `overrides` optionally tightening
/// variable bounds (used by branch & bound to fix binaries without rebuilding
/// the model). `overrides[i] = Some((lo, hi))`.
pub fn solve_lp(model: &Model, overrides: &[Option<(f64, f64)>]) -> LpOutcome {
    let n = model.n_vars();
    assert!(overrides.len() == n || overrides.is_empty());

    // Effective bounds.
    let mut lo = vec![0f64; n];
    let mut hi = vec![0f64; n];
    for i in 0..n {
        let (l, h) = model.bounds(VarId(i));
        let (l, h) = match overrides.get(i).copied().flatten() {
            Some((ol, oh)) => (l.max(ol), h.min(oh)),
            None => (l, h),
        };
        if l > h {
            return LpOutcome::Infeasible;
        }
        assert!(l.is_finite(), "simplex requires finite lower bounds");
        lo[i] = l;
        hi[i] = h;
    }

    // Rows: (coeffs over n structural vars, cmp, rhs) after the lo-shift.
    let mut rows: Vec<(Vec<f64>, Cmp, f64)> = Vec::new();
    for c in &model.constraints {
        let mut a = vec![0f64; n];
        let mut shift = 0f64;
        for &(v, coeff) in c.expr.terms() {
            a[v.0] += coeff;
            shift += coeff * lo[v.0];
        }
        rows.push((a, c.cmp, c.rhs - shift));
    }
    // Upper-bound rows for finite ranges (skip fixed vars: range 0).
    for i in 0..n {
        let u = hi[i] - lo[i];
        if u.is_finite() {
            if u < 0.0 {
                return LpOutcome::Infeasible;
            }
            let mut a = vec![0f64; n];
            a[i] = 1.0;
            rows.push((a, Cmp::Le, u));
        }
    }

    // Normalize rows to b >= 0.
    for (a, cmp, b) in rows.iter_mut() {
        if *b < 0.0 {
            for x in a.iter_mut() {
                *x = -*x;
            }
            *b = -*b;
            *cmp = match *cmp {
                Cmp::Le => Cmp::Ge,
                Cmp::Ge => Cmp::Le,
                Cmp::Eq => Cmp::Eq,
            };
        }
    }

    let m = rows.len();
    // Column layout: [structural n][slack/surplus][artificial], then RHS.
    let n_slack = rows
        .iter()
        .filter(|(_, cmp, _)| matches!(cmp, Cmp::Le | Cmp::Ge))
        .count();
    let n_art = rows
        .iter()
        .filter(|(_, cmp, _)| matches!(cmp, Cmp::Ge | Cmp::Eq))
        .count();
    let total = n + n_slack + n_art;

    let mut t = vec![vec![0f64; total + 1]; m]; // tableau rows
    let mut basis = vec![usize::MAX; m];
    let mut s_idx = n;
    let mut a_idx = n + n_slack;
    for (r, (a, cmp, b)) in rows.iter().enumerate() {
        t[r][..n].copy_from_slice(a);
        t[r][total] = *b;
        match cmp {
            Cmp::Le => {
                t[r][s_idx] = 1.0;
                basis[r] = s_idx;
                s_idx += 1;
            }
            Cmp::Ge => {
                t[r][s_idx] = -1.0;
                s_idx += 1;
                t[r][a_idx] = 1.0;
                basis[r] = a_idx;
                a_idx += 1;
            }
            Cmp::Eq => {
                t[r][a_idx] = 1.0;
                basis[r] = a_idx;
                a_idx += 1;
            }
        }
    }

    // Phase 1: minimize sum of artificials.
    if n_art > 0 {
        let mut cost1 = vec![0f64; total];
        for c in cost1.iter_mut().take(n + n_slack + n_art).skip(n + n_slack) {
            *c = 1.0;
        }
        let opt = run_simplex(&mut t, &mut basis, &cost1, total);
        match opt {
            SimplexEnd::Optimal(v) => {
                if v > 1e-7 {
                    return LpOutcome::Infeasible;
                }
            }
            SimplexEnd::Unbounded => unreachable!("phase-1 objective is bounded below by 0"),
        }
        // Drive any leftover artificials out of the basis (degenerate rows).
        for r in 0..m {
            if basis[r] >= n + n_slack {
                // Find a non-artificial column with nonzero coeff to pivot in.
                if let Some(col) = (0..n + n_slack).find(|&c| t[r][c].abs() > 1e-9) {
                    pivot(&mut t, &mut basis, r, col, total);
                }
                // else: row is all-zero over real vars — redundant, ignore.
            }
        }
    }

    // Phase 2: original objective over the shifted vars (constant offset from
    // the shift does not affect the argmin; we evaluate the true objective at
    // the end on the unshifted assignment).
    let mut cost2 = vec![0f64; total];
    for &(v, c) in model.objective.terms() {
        cost2[v.0] += c;
    }
    // Forbid artificials from re-entering.
    let art_cols = (n + n_slack)..total;
    match run_simplex_restricted(&mut t, &mut basis, &cost2, total, art_cols) {
        SimplexEnd::Optimal(_) => {}
        SimplexEnd::Unbounded => return LpOutcome::Unbounded,
    }

    // Extract assignment.
    let mut x = lo.clone();
    for r in 0..m {
        if basis[r] < n {
            x[basis[r]] = lo[basis[r]] + t[r][total];
        }
    }
    let objective = model.objective_value(&x);
    LpOutcome::Optimal { assignment: x, objective }
}

enum SimplexEnd {
    Optimal(f64),
    Unbounded,
}

fn run_simplex(
    t: &mut [Vec<f64>],
    basis: &mut [usize],
    cost: &[f64],
    total: usize,
) -> SimplexEnd {
    run_simplex_restricted(t, basis, cost, total, total..total)
}

/// Primal simplex iterations with reduced costs computed directly from the
/// tableau; `banned` columns may not enter the basis.
fn run_simplex_restricted(
    t: &mut [Vec<f64>],
    basis: &mut [usize],
    cost: &[f64],
    total: usize,
    banned: std::ops::Range<usize>,
) -> SimplexEnd {
    let m = t.len();
    let mut iters = 0usize;
    let max_iters = 50 * (total + m) + 1000;
    // Hoisted basis-cost vector: only rows with a non-zero basic cost
    // contribute to pricing, and on these models (phase 1: artificials only;
    // phase 2: objective touches few vars) that is a small subset — pricing
    // drops from O(m·n) over all rows to O(|nz|·n). (§Perf L3, EXPERIMENTS.md)
    let mut cb_nz: Vec<(usize, f64)> = Vec::with_capacity(m);
    loop {
        iters += 1;
        let use_bland = iters > max_iters / 2;
        cb_nz.clear();
        for r in 0..m {
            let cb = cost[basis[r]];
            if cb != 0.0 {
                cb_nz.push((r, cb));
            }
        }
        // Reduced costs: r_j = c_j - c_B' B^-1 A_j = c_j - Σ_r c_basis[r]·t[r][j]
        let mut enter = usize::MAX;
        let mut best = -1e-9;
        for j in 0..total {
            if banned.contains(&j) {
                continue;
            }
            let mut rj = cost[j];
            for &(r, cb) in &cb_nz {
                rj -= cb * t[r][j];
            }
            if rj < best {
                if use_bland {
                    // Bland: first improving column
                    enter = j;
                    break;
                }
                best = rj;
                enter = j;
            }
        }
        if enter == usize::MAX {
            // optimal
            let mut obj = 0f64;
            for r in 0..m {
                obj += cost[basis[r]] * t[r][total];
            }
            return SimplexEnd::Optimal(obj);
        }
        // Ratio test.
        let mut leave = usize::MAX;
        let mut best_ratio = f64::INFINITY;
        for r in 0..m {
            let a = t[r][enter];
            if a > 1e-9 {
                let ratio = t[r][total] / a;
                if ratio < best_ratio - 1e-12
                    || (use_bland
                        && (ratio - best_ratio).abs() <= 1e-12
                        && leave != usize::MAX
                        && basis[r] < basis[leave])
                {
                    best_ratio = ratio;
                    leave = r;
                }
            }
        }
        if leave == usize::MAX {
            return SimplexEnd::Unbounded;
        }
        pivot(t, basis, leave, enter, total);
        if iters > max_iters {
            // Should not happen with Bland's rule active; fail safe.
            let mut obj = 0f64;
            for r in 0..m {
                obj += cost[basis[r]] * t[r][total];
            }
            return SimplexEnd::Optimal(obj);
        }
    }
}

fn pivot(t: &mut [Vec<f64>], basis: &mut [usize], row: usize, col: usize, total: usize) {
    let p = t[row][col];
    debug_assert!(p.abs() > 1e-12, "pivot on ~zero element");
    for j in 0..=total {
        t[row][j] /= p;
    }
    for r in 0..t.len() {
        if r != row {
            let f = t[r][col];
            if f != 0.0 {
                for j in 0..=total {
                    t[r][j] -= f * t[row][j];
                }
            }
        }
    }
    basis[row] = col;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ilp::{LinExpr, VarKind};

    fn lp(model: &Model) -> LpOutcome {
        solve_lp(model, &[])
    }

    #[test]
    fn simple_max_as_min() {
        // max x + y s.t. x + 2y <= 4, 3x + y <= 6, 0<=x,y<=10
        // == min -(x+y); optimum at intersection (8/5, 6/5), value -14/5
        let mut m = Model::minimize();
        let x = m.var("x", 0.0, 10.0, VarKind::Continuous);
        let y = m.var("y", 0.0, 10.0, VarKind::Continuous);
        let mut c1 = LinExpr::new();
        c1.add(x, 1.0).add(y, 2.0);
        m.constrain(c1, Cmp::Le, 4.0);
        let mut c2 = LinExpr::new();
        c2.add(x, 3.0).add(y, 1.0);
        m.constrain(c2, Cmp::Le, 6.0);
        let mut obj = LinExpr::new();
        obj.add(x, -1.0).add(y, -1.0);
        m.set_objective(obj);
        match lp(&m) {
            LpOutcome::Optimal { assignment, objective } => {
                assert!((objective + 14.0 / 5.0).abs() < 1e-6, "{objective}");
                assert!((assignment[0] - 1.6).abs() < 1e-6);
                assert!((assignment[1] - 1.2).abs() < 1e-6);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn equality_and_ge_constraints() {
        // min x + y s.t. x + y = 3, x >= 1, y >= 0.5 → obj 3 at e.g. (2.5,0.5)
        let mut m = Model::minimize();
        let x = m.var("x", 0.0, 100.0, VarKind::Continuous);
        let y = m.var("y", 0.0, 100.0, VarKind::Continuous);
        let mut c = LinExpr::new();
        c.add(x, 1.0).add(y, 1.0);
        m.constrain(c, Cmp::Eq, 3.0);
        m.constrain(LinExpr::term(x, 1.0), Cmp::Ge, 1.0);
        m.constrain(LinExpr::term(y, 1.0), Cmp::Ge, 0.5);
        let mut obj = LinExpr::new();
        obj.add(x, 1.0).add(y, 1.0);
        m.set_objective(obj);
        match lp(&m) {
            LpOutcome::Optimal { objective, .. } => {
                assert!((objective - 3.0).abs() < 1e-6)
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn detects_infeasible() {
        let mut m = Model::minimize();
        let x = m.var("x", 0.0, 1.0, VarKind::Continuous);
        m.constrain(LinExpr::term(x, 1.0), Cmp::Ge, 2.0);
        assert_eq!(lp(&m), LpOutcome::Infeasible);
    }

    #[test]
    fn detects_unbounded() {
        let mut m = Model::minimize();
        let x = m.var("x", 0.0, f64::INFINITY, VarKind::Continuous);
        m.set_objective(LinExpr::term(x, -1.0));
        assert_eq!(lp(&m), LpOutcome::Unbounded);
    }

    #[test]
    fn respects_upper_bounds() {
        // min -x, x <= 0.7 via bounds only
        let mut m = Model::minimize();
        let x = m.var("x", 0.0, 0.7, VarKind::Continuous);
        m.set_objective(LinExpr::term(x, -1.0));
        match lp(&m) {
            LpOutcome::Optimal { assignment, .. } => {
                assert!((assignment[0] - 0.7).abs() < 1e-6)
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn respects_nonzero_lower_bounds() {
        // min x + y with x ∈ [2, 5], y ∈ [1, 4], x + y >= 4 → obj 4 (x=2.. y=2 or x=3,y=1)
        let mut m = Model::minimize();
        let x = m.var("x", 2.0, 5.0, VarKind::Continuous);
        let y = m.var("y", 1.0, 4.0, VarKind::Continuous);
        let mut c = LinExpr::new();
        c.add(x, 1.0).add(y, 1.0);
        m.constrain(c, Cmp::Ge, 4.0);
        let mut obj = LinExpr::new();
        obj.add(x, 1.0).add(y, 1.0);
        m.set_objective(obj);
        match lp(&m) {
            LpOutcome::Optimal { objective, assignment } => {
                assert!((objective - 4.0).abs() < 1e-6);
                assert!(assignment[0] >= 2.0 - 1e-9);
                assert!(assignment[1] >= 1.0 - 1e-9);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn override_bounds_fix_variables() {
        // min -x - y, x,y ∈ [0,1]; fix x = 0 via override → obj -1
        let mut m = Model::minimize();
        let x = m.var("x", 0.0, 1.0, VarKind::Continuous);
        let y = m.var("y", 0.0, 1.0, VarKind::Continuous);
        let mut obj = LinExpr::new();
        obj.add(x, -1.0).add(y, -1.0);
        m.set_objective(obj);
        let overrides = vec![Some((0.0, 0.0)), None];
        match solve_lp(&m, &overrides) {
            LpOutcome::Optimal { assignment, objective } => {
                assert!(assignment[0].abs() < 1e-9);
                assert!((objective + 1.0).abs() < 1e-6);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn contradictory_override_is_infeasible() {
        let mut m = Model::minimize();
        let _x = m.var("x", 0.0, 1.0, VarKind::Continuous);
        let overrides = vec![Some((2.0, 3.0))];
        assert_eq!(solve_lp(&m, &overrides), LpOutcome::Infeasible);
    }

    #[test]
    fn degenerate_lp_terminates() {
        // Classic degeneracy: multiple redundant constraints through origin.
        let mut m = Model::minimize();
        let x = m.var("x", 0.0, 10.0, VarKind::Continuous);
        let y = m.var("y", 0.0, 10.0, VarKind::Continuous);
        for k in 1..=4 {
            let mut c = LinExpr::new();
            c.add(x, k as f64).add(y, 1.0);
            m.constrain(c, Cmp::Le, 0.0);
        }
        let mut obj = LinExpr::new();
        obj.add(x, -1.0).add(y, -1.0);
        m.set_objective(obj);
        match lp(&m) {
            LpOutcome::Optimal { objective, .. } => {
                assert!(objective.abs() < 1e-6)
            }
            other => panic!("{other:?}"),
        }
    }
}

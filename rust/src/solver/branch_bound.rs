//! 0-1 branch & bound over the LP relaxation, with MIP start and budgets —
//! the exact phase of the paper's solve pipeline (§7.1).

use std::time::{Duration, Instant};

use crate::ilp::{Model, Solution, SolveStatus, VarKind};
use crate::solver::{solve_lp, LpOutcome};

/// Solve options mirroring the paper's OPL setup: a time budget (the paper
/// used 0.5–5 h), a node budget, and a MIP start injected from the best
/// heuristic strategy.
#[derive(Debug, Clone)]
pub struct BranchBoundOptions {
    /// Wall-clock budget (the search returns its incumbent on expiry).
    pub time_budget: Duration,
    /// Maximum number of explored B&B nodes.
    pub node_budget: u64,
    /// Feasible starting assignment (full, over all model vars).
    pub mip_start: Option<Vec<f64>>,
    /// Absolute optimality gap below which search stops.
    pub gap_tolerance: f64,
}

impl Default for BranchBoundOptions {
    fn default() -> Self {
        BranchBoundOptions {
            time_budget: Duration::from_secs(30),
            node_budget: 200_000,
            mip_start: None,
            gap_tolerance: 1e-6,
        }
    }
}

struct Node {
    /// Bound overrides per var (None = free).
    fixes: Vec<Option<(f64, f64)>>,
    /// LP bound of the parent (priority).
    bound: f64,
}

/// Best-first 0-1 branch & bound.
///
/// Integer variables must be binaries (all the §5 models are); general
/// integers would need rounding-direction branching which this substrate
/// does not implement.
pub fn solve_milp(model: &Model, opts: &BranchBoundOptions) -> Solution {
    let n = model.n_vars();
    let start = Instant::now();

    // Incumbent from the MIP start, if valid.
    let mut best_obj = f64::INFINITY;
    let mut best_assign: Option<Vec<f64>> = None;
    if let Some(ref s) = opts.mip_start {
        if model.is_feasible(s, 1e-6) {
            best_obj = model.objective_value(s);
            best_assign = Some(s.clone());
        }
    }

    // Priority queue ordered by LP bound (best-first).
    let mut heap: Vec<Node> = vec![Node { fixes: vec![None; n], bound: f64::NEG_INFINITY }];
    let mut nodes = 0u64;
    let mut proven_lower = f64::NEG_INFINITY;
    let mut exhausted = true;

    while let Some(pos) = pop_best(&heap) {
        let node = heap.swap_remove(pos);
        if nodes >= opts.node_budget || start.elapsed() > opts.time_budget {
            exhausted = false;
            break;
        }
        nodes += 1;

        if node.bound >= best_obj - opts.gap_tolerance {
            continue; // pruned by bound
        }

        let lp = solve_lp(model, &node.fixes);
        let (assignment, lp_obj) = match lp {
            LpOutcome::Optimal { assignment, objective } => (assignment, objective),
            LpOutcome::Infeasible => continue,
            LpOutcome::Unbounded => {
                // Binary models are always bounded; treat as failure to bound.
                (vec![], f64::NEG_INFINITY)
            }
        };
        if assignment.is_empty() {
            exhausted = false;
            continue;
        }
        if lp_obj >= best_obj - opts.gap_tolerance {
            continue;
        }

        // Most-fractional branching among integer vars (closest to 0.5).
        let mut branch_var = usize::MAX;
        let mut best_dist = f64::INFINITY;
        for i in 0..n {
            if model.kind(crate::ilp::VarId(i)) != VarKind::Integer {
                continue;
            }
            let f = (assignment[i] - assignment[i].round()).abs();
            if f > 1e-6 {
                let dist = (f - 0.5).abs();
                if dist < best_dist {
                    best_dist = dist;
                    branch_var = i;
                }
            }
        }

        if branch_var == usize::MAX {
            // LP solution is integral → candidate incumbent.
            if model.is_feasible(&assignment, 1e-6) && lp_obj < best_obj {
                best_obj = lp_obj;
                best_assign = Some(assignment);
            }
            continue;
        }

        // Branch down (fix 0) and up (fix 1).
        for &(flo, fhi) in &[(0.0, 0.0), (1.0, 1.0)] {
            let mut fixes = node.fixes.clone();
            fixes[branch_var] = Some((flo, fhi));
            heap.push(Node { fixes, bound: lp_obj });
        }
    }

    if exhausted {
        proven_lower = best_obj;
    } else if let Some(min_open) = heap
        .iter()
        .map(|nd| nd.bound)
        .fold(None::<f64>, |acc, b| Some(acc.map_or(b, |a| a.min(b))))
    {
        proven_lower = min_open.max(proven_lower);
    }

    match best_assign {
        Some(assignment) => Solution {
            status: if exhausted { SolveStatus::Optimal } else { SolveStatus::Feasible },
            objective: best_obj,
            lower_bound: proven_lower,
            assignment,
            nodes,
        },
        None => Solution {
            status: if exhausted { SolveStatus::Infeasible } else { SolveStatus::Unknown },
            objective: f64::INFINITY,
            lower_bound: proven_lower,
            assignment: vec![],
            nodes,
        },
    }
}

fn pop_best(heap: &[Node]) -> Option<usize> {
    if heap.is_empty() {
        return None;
    }
    let mut best = 0;
    for (i, n) in heap.iter().enumerate() {
        if n.bound < heap[best].bound {
            best = i;
        }
    }
    Some(best)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ilp::{Cmp, LinExpr, Model};

    /// 0-1 knapsack: max Σ v_i x_i s.t. Σ w_i x_i ≤ W.
    fn knapsack(values: &[f64], weights: &[f64], cap: f64) -> (Model, Vec<crate::ilp::BoolVar>) {
        let mut m = Model::minimize();
        let vars: Vec<_> = (0..values.len())
            .map(|i| m.bool_var(&format!("x{i}")))
            .collect();
        let mut w = LinExpr::new();
        let mut obj = LinExpr::new();
        for (i, v) in vars.iter().enumerate() {
            w.add(v.0, weights[i]);
            obj.add(v.0, -values[i]); // maximize → minimize negative
        }
        m.constrain(w, Cmp::Le, cap);
        m.set_objective(obj);
        (m, vars)
    }

    #[test]
    fn knapsack_optimal() {
        // values 10,13,7,8; weights 5,6,4,3; cap 10 → best = {1,3} = 21
        let (m, _) = knapsack(&[10., 13., 7., 8.], &[5., 6., 4., 3.], 10.0);
        let sol = solve_milp(&m, &BranchBoundOptions::default());
        assert_eq!(sol.status, SolveStatus::Optimal);
        assert!((sol.objective + 21.0).abs() < 1e-6, "{}", sol.objective);
        assert_eq!(sol.assignment.iter().map(|&x| x.round() as u32).collect::<Vec<_>>(), vec![0, 1, 0, 1]);
    }

    #[test]
    fn knapsack_larger_matches_dp() {
        // Cross-check a 12-item instance against an exhaustive search.
        let values: Vec<f64> = vec![4., 2., 10., 1., 2., 7., 8., 3., 6., 5., 9., 4.];
        let weights: Vec<f64> = vec![3., 1., 6., 1., 2., 5., 4., 2., 3., 4., 5., 3.];
        let cap = 15.0;
        let mut best = 0f64;
        for mask in 0u32..(1 << 12) {
            let (mut v, mut w) = (0f64, 0f64);
            for i in 0..12 {
                if mask >> i & 1 == 1 {
                    v += values[i];
                    w += weights[i];
                }
            }
            if w <= cap {
                best = best.max(v);
            }
        }
        let (m, _) = knapsack(&values, &weights, cap);
        let sol = solve_milp(&m, &BranchBoundOptions::default());
        assert_eq!(sol.status, SolveStatus::Optimal);
        assert!((sol.objective + best).abs() < 1e-6, "got {} want {}", -sol.objective, best);
    }

    #[test]
    fn assignment_problem_exact() {
        // 3x3 assignment: min Σ c_ij x_ij, rows/cols sum to 1.
        let costs = [[4., 1., 3.], [2., 0., 5.], [3., 2., 2.]];
        let mut m = Model::minimize();
        let mut vars = [[None; 3]; 3];
        for (i, row) in costs.iter().enumerate() {
            for j in 0..row.len() {
                vars[i][j] = Some(m.bool_var(&format!("x{i}{j}")));
            }
        }
        let mut obj = LinExpr::new();
        for i in 0..3 {
            let mut rs = LinExpr::new();
            let mut cs = LinExpr::new();
            for j in 0..3 {
                rs.add(vars[i][j].unwrap().0, 1.0);
                cs.add(vars[j][i].unwrap().0, 1.0);
                obj.add(vars[i][j].unwrap().0, costs[i][j]);
            }
            m.constrain(rs, Cmp::Eq, 1.0);
            m.constrain(cs, Cmp::Eq, 1.0);
        }
        m.set_objective(obj);
        let sol = solve_milp(&m, &BranchBoundOptions::default());
        assert_eq!(sol.status, SolveStatus::Optimal);
        // optimal: (0,1)=1, (1,0)=2, (2,2)=2 → 5
        assert!((sol.objective - 5.0).abs() < 1e-6, "{}", sol.objective);
    }

    #[test]
    fn infeasible_model_detected() {
        let mut m = Model::minimize();
        let x = m.bool_var("x");
        m.constrain(LinExpr::term(x.0, 1.0), Cmp::Ge, 2.0);
        let sol = solve_milp(&m, &BranchBoundOptions::default());
        assert_eq!(sol.status, SolveStatus::Infeasible);
    }

    #[test]
    fn mip_start_bounds_search() {
        // MIP start gives the solver an incumbent immediately; with a zero
        // node budget the incumbent must be returned as Feasible.
        let (m, _) = knapsack(&[10., 13., 7., 8.], &[5., 6., 4., 3.], 10.0);
        let start = vec![1.0, 0.0, 0.0, 1.0]; // value 18, feasible
        let opts = BranchBoundOptions {
            node_budget: 0,
            mip_start: Some(start.clone()),
            ..Default::default()
        };
        let sol = solve_milp(&m, &opts);
        assert_eq!(sol.status, SolveStatus::Feasible);
        assert!((sol.objective + 18.0).abs() < 1e-6);
        assert_eq!(sol.assignment, start);
    }

    #[test]
    fn invalid_mip_start_is_ignored() {
        let (m, _) = knapsack(&[10., 13.], &[5., 6.], 10.0);
        let opts = BranchBoundOptions {
            mip_start: Some(vec![1.0, 1.0]), // weight 11 > 10: infeasible
            ..Default::default()
        };
        let sol = solve_milp(&m, &opts);
        assert_eq!(sol.status, SolveStatus::Optimal);
        assert!((sol.objective + 13.0).abs() < 1e-6);
    }

    #[test]
    fn optimal_proves_bound() {
        let (m, _) = knapsack(&[5., 4.], &[2., 3.], 4.0);
        let sol = solve_milp(&m, &BranchBoundOptions::default());
        assert_eq!(sol.status, SolveStatus::Optimal);
        assert!((sol.lower_bound - sol.objective).abs() < 1e-6);
    }
}

//! `convoffload` — CLI for the offloading simulator, optimizer and the
//! paper-figure harness.
//!
//! Subcommands:
//! * `simulate`      — run a strategy on a layer, print the per-step report;
//! * `optimize`      — find an optimized strategy (exact / polished), export CSV;
//! * `plan-network`  — plan every layer of a network preset (portfolio race
//!   + strategy cache) and report the end-to-end simulated duration;
//! * `certify`       — analytic communication lower bounds and per-stage
//!   optimality gaps for a planned network; `--exact` adds budgeted exact
//!   solves (node-capped, clean `unsolved` on exhaustion — never hangs);
//! * `plan-batch`    — plan several networks (presets and/or TOML layer
//!   files) in one call: cross-network dedup, one shared race pool, sharded
//!   persistent strategy cache;
//! * `figures`       — regenerate the paper's Figures 11/12/13 into `figures/`;
//! * `viz`           — render a strategy's step grids (ASCII or SVG);
//! * `plan-server`   — long-lived planning service over TCP: one warm
//!   strategy cache, admission control, per-request deadlines, crash-safe
//!   request journal;
//! * `e2e`           — functional end-to-end run through the PJRT runtime;
//! * `perf`          — print the L1 kernel VMEM/MXU estimates;
//! * `presets`       — list layer and network presets.
//!
//! Exit codes: 0 success, 1 runtime failure, 2 malformed invocation
//! (unknown flags/commands, unparseable values, invalid geometry or spec
//! files — see [`cli::CliError`]).

use std::process::ExitCode;

use convoffload::config::{
    layer_preset, list_network_presets, list_presets, network_preset, ExperimentConfig,
    NetworkPreset, NetworkStagePreset,
};
use convoffload::conv::ConvLayer;
use convoffload::optimizer::{OptimizeOptions, Optimizer};
use convoffload::planner::{
    batch_to_json, format_batch_table, format_plan_table, plan_to_json, AcceleratorSpec,
    BatchPlanner, NetworkPlanner, PlanOptions, ShardedStrategyCache, StrategyCache,
};
use convoffload::planner::ChaosSpec;
use convoffload::platform::{Accelerator, FaultModel, OverlapMode, Platform};
use convoffload::sim::{FunctionalBackend, RustOracleBackend, Simulator};
use convoffload::server::{PlanServer, ServerConfig};
use convoffload::strategy::{self, GroupedStrategy};
use convoffload::util::cli::{self, invalid, CliError, FlagSpec};

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let Some((cmd, rest)) = args.split_first() else {
        print_usage();
        return ExitCode::FAILURE;
    };
    let result = match cmd.as_str() {
        "simulate" => cmd_simulate(rest),
        "optimize" => cmd_optimize(rest),
        "plan-network" => cmd_plan_network(rest),
        "certify" => cmd_certify(rest),
        "plan-batch" => cmd_plan_batch(rest),
        "plan-server" => cmd_plan_server(rest),
        "figures" => cmd_figures(rest),
        "viz" => cmd_viz(rest),
        "e2e" => cmd_e2e(rest),
        "perf" => cmd_perf(rest),
        "presets" => cmd_presets(),
        "help" | "--help" | "-h" => {
            print_usage();
            Ok(())
        }
        other => Err(CliError::Invalid(format!(
            "unknown command '{other}' (try `convoffload help`)"
        ))),
    };
    match result {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e}");
            ExitCode::from(e.exit_code())
        }
    }
}

fn print_usage() {
    println!(
        "convoffload — predictable offloading of convolutions to an accelerator\n\n\
         commands:\n\
         \x20 simulate      run a strategy on a layer and report δ / memory\n\
         \x20 optimize      search for an optimal strategy (§5 problem)\n\
         \x20 plan-network  plan every layer of a network preset (cached portfolio race)\n\
         \x20 certify       communication lower bounds + optimality gaps for a plan (--exact: budgeted proofs)\n\
         \x20 plan-batch    plan several networks at once (dedup + sharded strategy cache)\n\
         \x20 plan-server   long-lived planning service (warm cache, deadlines, crash-safe journal)\n\
         \x20 figures       regenerate the paper's Figures 11/12/13 under figures/\n\
         \x20 viz           render a strategy step by step (ascii/svg)\n\
         \x20 e2e           functional end-to-end run (PJRT or rust oracle)\n\
         \x20 perf          L1 kernel VMEM/MXU estimates for a layer\n\
         \x20 presets       list built-in layer and network presets\n\n\
         run `convoffload <command> --help` for flags"
    );
}

// ---------------------------------------------------------------- shared

fn layer_flags() -> Vec<FlagSpec> {
    vec![
        FlagSpec { name: "layer", help: "layer preset name", takes_value: true, default: Some("example1") },
        FlagSpec { name: "config", help: "TOML experiment file (overrides --layer)", takes_value: true, default: None },
        FlagSpec { name: "group", help: "group size (nb_patches_max_S1)", takes_value: true, default: Some("2") },
        FlagSpec { name: "overlap", help: "DMA/compute overlap: sequential (default) or double-buffered", takes_value: true, default: None },
        FlagSpec { name: "dma-channels", help: "DMA channels k for the double-buffered timeline (default 1)", takes_value: true, default: None },
        FlagSpec { name: "compute-units", help: "compute units m for the double-buffered timeline (default 1)", takes_value: true, default: None },
        FlagSpec { name: "help", help: "show help", takes_value: false, default: None },
    ]
}

struct Setup {
    layer: ConvLayer,
    acc: Accelerator,
    group: usize,
    faults: Option<FaultModel>,
}

/// The two fault flags shared by `simulate` and `plan-batch`.
fn fault_flags() -> Vec<FlagSpec> {
    vec![
        FlagSpec { name: "faults", help: "fault spec: dma=RATE,retries=N,penalty=CYC,jitter=CYC,acc-jitter=CYC,shrink=RATE,shrink-el=N,seed=S", takes_value: true, default: None },
        FlagSpec { name: "fault-seed", help: "override the fault stream seed (applies on --faults or a [faults] config section)", takes_value: true, default: None },
    ]
}

/// Merge the CLI fault flags on top of whatever the config file supplied:
/// `--faults` replaces the model, `--fault-seed` re-seeds it.
fn faults_from_args(
    args: &cli::Args,
    base: Option<FaultModel>,
) -> Result<Option<FaultModel>, CliError> {
    let mut faults = base;
    if let Some(spec) = args.get("faults") {
        faults = Some(invalid(FaultModel::from_spec(spec))?);
    }
    if let Some(seed) = invalid(args.get_u64("fault-seed"))? {
        let m = faults.unwrap_or_else(|| FaultModel { max_retries: 3, ..FaultModel::none() });
        faults = Some(m.with_seed(seed));
    }
    Ok(faults)
}

fn setup_from(args: &cli::Args) -> Result<Setup, CliError> {
    // `--overlap`, `--dma-channels` and `--compute-units` apply on top of
    // either source (preset or TOML); the TOML file may also set the same
    // keys in its `[accelerator]` section.
    let overlap = match args.get("overlap") {
        Some(s) => Some(invalid(OverlapMode::from_str(s))?),
        None => None,
    };
    let mut setup = if let Some(path) = args.get("config") {
        let text = std::fs::read_to_string(path).map_err(|e| format!("{path}: {e}"))?;
        let cfg = invalid(ExperimentConfig::from_toml(&text))?;
        let acc = match overlap {
            Some(o) => cfg.accelerator.with_overlap(o),
            None => cfg.accelerator,
        };
        Setup {
            layer: cfg.layer,
            acc,
            group: cfg.group_size,
            faults: cfg.faults,
        }
    } else {
        let name = args.get("layer").unwrap_or("example1");
        let preset = layer_preset(name).ok_or_else(|| {
            CliError::Invalid(format!("unknown preset '{name}' (see `convoffload presets`)"))
        })?;
        let group = invalid(args.get_usize("group"))?.unwrap_or(2).max(1);
        let acc = Accelerator::for_group_size(&preset.layer, group)
            .with_overlap(overlap.unwrap_or_default());
        Setup { layer: preset.layer, acc, group, faults: None }
    };
    if let Some(k) = invalid(args.get_usize("dma-channels"))? {
        setup.acc.dma_channels = k.max(1);
    }
    if let Some(m) = invalid(args.get_usize("compute-units"))? {
        setup.acc.compute_units = m.max(1);
    }
    Ok(setup)
}

fn build_strategy(
    name: &str,
    layer: &ConvLayer,
    group: usize,
) -> Result<GroupedStrategy, CliError> {
    match name {
        "s1-baseline" => Ok(strategy::s1_baseline(layer)),
        "row-by-row" | "row" => Ok(strategy::row_by_row(layer, group)),
        "zigzag" => Ok(strategy::zigzag(layer, group)),
        "hilbert" => Ok(strategy::hilbert(layer, group)),
        "diagonal" => Ok(strategy::diagonal(layer, group)),
        path if path.ends_with(".csv") => {
            let text = std::fs::read_to_string(path).map_err(|e| format!("{path}: {e}"))?;
            invalid(strategy::strategy_from_csv(path, &text))
        }
        path if path.ends_with(".json") => {
            let text = std::fs::read_to_string(path).map_err(|e| format!("{path}: {e}"))?;
            invalid(strategy::strategy_from_json(&text))
        }
        other => Err(CliError::Invalid(format!(
            "unknown strategy '{other}' (builtin: s1-baseline, row-by-row, zigzag, hilbert, diagonal; or a .csv/.json file)"
        ))),
    }
}

// ---------------------------------------------------------------- simulate

fn cmd_simulate(argv: &[String]) -> Result<(), CliError> {
    let mut specs = layer_flags();
    specs.push(FlagSpec { name: "strategy", help: "strategy name or CSV/JSON file", takes_value: true, default: Some("zigzag") });
    specs.push(FlagSpec { name: "batch", help: "images to stream through the strategy (kernels load once)", takes_value: true, default: Some("1") });
    specs.push(FlagSpec { name: "steps", help: "print the per-step table", takes_value: false, default: None });
    specs.extend(fault_flags());
    let args = invalid(cli::parse(argv, &specs))?;
    if args.get_bool("help") {
        println!("{}", cli::help("simulate", "run a strategy on a layer", &specs));
        return Ok(());
    }
    let setup = setup_from(&args)?;
    let s = build_strategy(args.get("strategy").unwrap(), &setup.layer, setup.group)?;
    let faults = faults_from_args(&args, setup.faults)?;
    let mut sim = Simulator::new(setup.layer, Platform::new(setup.acc))
        .with_batch(invalid(args.get_usize("batch"))?.unwrap_or(1).max(1));
    if let Some(m) = faults {
        sim = sim.with_faults(m);
    }
    let report = sim.run(&s).map_err(|e| e.to_string())?;
    println!("layer: {}", setup.layer);
    println!("accelerator: {:?}", setup.acc);
    if let Some(m) = faults.filter(FaultModel::is_active) {
        println!("faults: {}", m.to_spec());
    }
    println!("{}", convoffload::sim::summary_line(&report, &setup.acc));
    if args.get_bool("steps") {
        println!("\n step | loaded | written | macs | duration | occupancy | resident");
        for st in &report.steps {
            println!(
                "{:>5} | {:>6} | {:>7} | {:>4} | {:>8} | {:>9} | {:>8}",
                st.index + 1,
                st.cost.loaded_elements,
                st.cost.written_elements,
                st.cost.macs,
                st.duration,
                st.occupancy,
                st.resident_input_elements
            );
        }
    }
    Ok(())
}

// ---------------------------------------------------------------- optimize

fn cmd_optimize(argv: &[String]) -> Result<(), CliError> {
    let mut specs = layer_flags();
    specs.push(FlagSpec { name: "seed", help: "polish RNG seed", takes_value: true, default: Some("2026") });
    specs.push(FlagSpec { name: "iters", help: "polish iterations", takes_value: true, default: Some("200000") });
    specs.push(FlagSpec { name: "neighbor-bias", help: "probability of overlap-graph-guided anneal proposals (0 = legacy stream)", takes_value: true, default: Some("0") });
    specs.push(FlagSpec { name: "out", help: "write the strategy CSV here", takes_value: true, default: None });
    let args = invalid(cli::parse(argv, &specs))?;
    if args.get_bool("help") {
        println!("{}", cli::help("optimize", "search for an optimal strategy", &specs));
        return Ok(());
    }
    let setup = setup_from(&args)?;
    let neighbor_bias = invalid(args.get_f64("neighbor-bias"))?
        .unwrap_or(0.0)
        .clamp(0.0, 1.0);
    // Loud rather than silent: the duration-domain annealer has no
    // graph-guided proposal path, so the flag would be a no-op.
    if neighbor_bias > 0.0 && setup.acc.overlap == OverlapMode::DoubleBuffered {
        return Err(CliError::Invalid(
            "--neighbor-bias applies to the sequential objective only; \
             the double-buffered annealer does not support graph-guided proposals"
                .into(),
        ));
    }
    let opt = Optimizer::new(OptimizeOptions {
        group_size: setup.group,
        seed: invalid(args.get_u64("seed"))?.unwrap_or(2026),
        anneal_iters: invalid(args.get_u64("iters"))?.unwrap_or(200_000),
        neighbor_bias,
        ..Default::default()
    });
    let res = opt.optimize(&setup.layer, &setup.acc);
    println!("layer: {}", setup.layer);
    println!("method: {:?}", res.method);
    println!("best heuristic δ: {}", res.mip_start_duration);
    println!("optimized      δ: {}", res.duration);
    println!("gain: {:.2}%", res.gain_over_heuristics() * 100.0);
    if let Some(path) = args.get("out") {
        std::fs::write(path, strategy::strategy_to_csv(&res.strategy))
            .map_err(|e| format!("{path}: {e}"))?;
        println!("wrote {path}");
    }
    Ok(())
}

// ---------------------------------------------------------------- plan-network

fn cmd_plan_network(argv: &[String]) -> Result<(), CliError> {
    let specs = vec![
        FlagSpec { name: "group", help: "per-layer group size bound", takes_value: true, default: Some("4") },
        FlagSpec { name: "seed", help: "portfolio base seed", takes_value: true, default: Some("2026") },
        FlagSpec { name: "iters", help: "anneal iterations per lane", takes_value: true, default: Some("50000") },
        FlagSpec { name: "thorough", help: "3x the anneal budget (delta evaluation makes it ~the old wall time; changes results, opt-in)", takes_value: false, default: None },
        FlagSpec { name: "starts", help: "number of anneal lanes", takes_value: true, default: Some("3") },
        FlagSpec { name: "overlap", help: "DMA/compute overlap: sequential or double-buffered (races the makespan objective)", takes_value: true, default: Some("sequential") },
        FlagSpec { name: "dma-channels", help: "DMA channels k for the double-buffered objective (default 1)", takes_value: true, default: Some("1") },
        FlagSpec { name: "compute-units", help: "compute units m for the double-buffered objective (default 1)", takes_value: true, default: Some("1") },
        FlagSpec { name: "threads", help: "worker threads (0 = auto)", takes_value: true, default: Some("0") },
        FlagSpec { name: "cache-dir", help: "strategy cache directory", takes_value: true, default: Some(".strategy-cache") },
        FlagSpec { name: "no-cache", help: "disable the strategy cache", takes_value: false, default: None },
        FlagSpec { name: "json", help: "emit the plan as JSON instead of a table", takes_value: false, default: None },
        FlagSpec { name: "help", help: "show help", takes_value: false, default: None },
    ];
    let args = invalid(cli::parse(argv, &specs))?;
    if args.get_bool("help") || args.positional.is_empty() {
        println!(
            "{}",
            cli::help(
                "plan-network <network>",
                "plan every layer of a network preset and simulate it end to end",
                &specs
            )
        );
        println!("networks:");
        for p in list_network_presets() {
            println!("  {:<10} {} ({} stages)", p.name, p.description, p.stages.len());
        }
        return if args.get_bool("help") {
            Ok(())
        } else {
            Err(CliError::Invalid(
                "missing network name (e.g. `plan-network lenet5`)".into(),
            ))
        };
    }
    let name = &args.positional[0];
    let preset = network_preset(name).ok_or_else(|| {
        CliError::Invalid(format!(
            "unknown network '{name}' (see `convoffload plan-network --help`)"
        ))
    })?;
    // `--thorough` spends the delta-evaluation speedup on search quality:
    // 3× the per-lane budget at roughly the old wall time. It is opt-in
    // because a different budget is a different (cache-keyed) problem —
    // default plans stay bit-identical per seed across releases.
    let budget_scale = if args.get_bool("thorough") { 3 } else { 1 };
    let options = PlanOptions {
        accelerator: AcceleratorSpec::PerLayerGroup(
            invalid(args.get_usize("group"))?.unwrap_or(4).max(1),
        ),
        seed: invalid(args.get_u64("seed"))?.unwrap_or(2026),
        anneal_iters: invalid(args.get_u64("iters"))?.unwrap_or(50_000) * budget_scale,
        anneal_starts: invalid(args.get_usize("starts"))?.unwrap_or(3).max(1),
        threads: invalid(args.get_usize("threads"))?.unwrap_or(0),
        overlap: invalid(OverlapMode::from_str(args.get("overlap").unwrap_or("sequential")))?,
        dma_channels: invalid(args.get_usize("dma-channels"))?.unwrap_or(1).max(1),
        compute_units: invalid(args.get_usize("compute-units"))?.unwrap_or(1).max(1),
    };
    let planner = if args.get_bool("no-cache") {
        NetworkPlanner::new(options)
    } else {
        let dir = std::path::Path::new(args.get("cache-dir").unwrap());
        NetworkPlanner::with_cache(options, StrategyCache::open(dir)?)
    };
    let plan = planner.plan(&preset)?;
    if args.get_bool("json") {
        println!("{}", plan_to_json(&plan).to_string_pretty());
    } else {
        print!("{}", format_plan_table(&plan));
    }
    Ok(())
}

// ---------------------------------------------------------------- certify

fn cmd_certify(argv: &[String]) -> Result<(), CliError> {
    use convoffload::planner::{certify_network, certify_to_json, format_certify_table, CertifyOptions};
    let specs = vec![
        FlagSpec { name: "group", help: "per-layer group size bound", takes_value: true, default: Some("4") },
        FlagSpec { name: "seed", help: "portfolio base seed", takes_value: true, default: Some("2026") },
        FlagSpec { name: "iters", help: "anneal iterations per lane", takes_value: true, default: Some("50000") },
        FlagSpec { name: "starts", help: "number of anneal lanes", takes_value: true, default: Some("3") },
        FlagSpec { name: "overlap", help: "DMA/compute overlap: sequential or double-buffered", takes_value: true, default: Some("sequential") },
        FlagSpec { name: "dma-channels", help: "DMA channels k for the double-buffered objective (default 1)", takes_value: true, default: Some("1") },
        FlagSpec { name: "compute-units", help: "compute units m for the double-buffered objective (default 1)", takes_value: true, default: Some("1") },
        FlagSpec { name: "threads", help: "worker threads (0 = auto)", takes_value: true, default: Some("0") },
        FlagSpec { name: "exact", help: "attempt budgeted exact solves on small stages (clean 'unsolved' on budget exhaustion)", takes_value: false, default: None },
        FlagSpec { name: "max-patches", help: "largest n_patches the exact search is attempted on", takes_value: true, default: Some("12") },
        FlagSpec { name: "nodes", help: "deterministic node budget for the exact search", takes_value: true, default: Some("2000000") },
        FlagSpec { name: "json", help: "emit the certification report as JSON", takes_value: false, default: None },
        FlagSpec { name: "help", help: "show help", takes_value: false, default: None },
    ];
    let args = invalid(cli::parse(argv, &specs))?;
    if args.get_bool("help") || args.positional.is_empty() {
        println!(
            "{}",
            cli::help(
                "certify <network>",
                "communication lower bounds and optimality gaps for a planned network",
                &specs
            )
        );
        println!("networks:");
        for p in list_network_presets() {
            println!("  {:<14} {} ({} stages)", p.name, p.description, p.stages.len());
        }
        return if args.get_bool("help") {
            Ok(())
        } else {
            Err(CliError::Invalid(
                "missing network name (e.g. `certify lenet5_micro --exact --group 2`)".into(),
            ))
        };
    }
    let name = &args.positional[0];
    let preset = network_preset(name).ok_or_else(|| {
        CliError::Invalid(format!(
            "unknown network '{name}' (see `convoffload certify --help`)"
        ))
    })?;
    let options = PlanOptions {
        accelerator: AcceleratorSpec::PerLayerGroup(
            invalid(args.get_usize("group"))?.unwrap_or(4).max(1),
        ),
        seed: invalid(args.get_u64("seed"))?.unwrap_or(2026),
        anneal_iters: invalid(args.get_u64("iters"))?.unwrap_or(50_000),
        anneal_starts: invalid(args.get_usize("starts"))?.unwrap_or(3).max(1),
        threads: invalid(args.get_usize("threads"))?.unwrap_or(0),
        overlap: invalid(OverlapMode::from_str(args.get("overlap").unwrap_or("sequential")))?,
        dma_channels: invalid(args.get_usize("dma-channels"))?.unwrap_or(1).max(1),
        compute_units: invalid(args.get_usize("compute-units"))?.unwrap_or(1).max(1),
    };
    // Certification is read-only w.r.t. search: plan fresh (no cache), then
    // bound / prove the winners.
    let plan = NetworkPlanner::new(options).plan(&preset)?;
    let certify_options = CertifyOptions {
        exact: args.get_bool("exact"),
        exact_max_patches: invalid(args.get_usize("max-patches"))?.unwrap_or(12),
        node_budget: invalid(args.get_u64("nodes"))?.unwrap_or(2_000_000),
        ..CertifyOptions::default()
    };
    let report = certify_network(&plan, &certify_options);
    if args.get_bool("json") {
        println!("{}", certify_to_json(&report).to_string_pretty());
    } else {
        print!("{}", format_certify_table(&report));
    }
    Ok(())
}

// ---------------------------------------------------------------- plan-batch

/// Resolve one `plan-batch` request: a network preset name, or a path to a
/// single-layer TOML experiment file (wrapped as a one-stage network — the
/// geometry comes from the file; the platform derivation stays batch-wide so
/// every request shares one cache-key convention).
fn batch_request(arg: &str) -> Result<NetworkPreset, CliError> {
    if arg.ends_with(".toml") {
        let text = std::fs::read_to_string(arg).map_err(|e| format!("{arg}: {e}"))?;
        let cfg = invalid(ExperimentConfig::from_toml(&text))?;
        let stem = std::path::Path::new(arg)
            .file_stem()
            .map(|s| s.to_string_lossy().into_owned())
            .unwrap_or_else(|| arg.to_string());
        return Ok(NetworkPreset {
            name: stem,
            description: format!("single-layer TOML experiment ({arg})"),
            stages: vec![NetworkStagePreset {
                name: "conv".into(),
                layer: cfg.layer,
                pool_after: false,
                pad_after: 0,
            }],
        });
    }
    network_preset(arg).ok_or_else(|| {
        CliError::Invalid(format!(
            "unknown network '{arg}' (preset name or a .toml file; see `convoffload presets`)"
        ))
    })
}

fn cmd_plan_batch(argv: &[String]) -> Result<(), CliError> {
    let specs = vec![
        FlagSpec { name: "group", help: "per-layer group size bound (batch-wide)", takes_value: true, default: Some("4") },
        FlagSpec { name: "seed", help: "portfolio base seed", takes_value: true, default: Some("2026") },
        FlagSpec { name: "iters", help: "anneal iterations per lane", takes_value: true, default: Some("50000") },
        FlagSpec { name: "starts", help: "number of anneal lanes", takes_value: true, default: Some("3") },
        FlagSpec { name: "overlap", help: "DMA/compute overlap: sequential or double-buffered", takes_value: true, default: Some("sequential") },
        FlagSpec { name: "dma-channels", help: "DMA channels k for the double-buffered objective (default 1)", takes_value: true, default: Some("1") },
        FlagSpec { name: "compute-units", help: "compute units m for the double-buffered objective (default 1)", takes_value: true, default: Some("1") },
        FlagSpec { name: "threads", help: "worker threads shared by the whole batch (0 = auto)", takes_value: true, default: Some("0") },
        FlagSpec { name: "cache-dir", help: "sharded strategy cache directory", takes_value: true, default: Some(".strategy-cache-sharded") },
        FlagSpec { name: "shards", help: "lock stripes / shard files (existing dirs keep their count)", takes_value: true, default: Some("16") },
        FlagSpec { name: "no-cache", help: "disable persistence (cross-network dedup still applies)", takes_value: false, default: None },
        FlagSpec { name: "json", help: "emit the batch report as JSON instead of tables", takes_value: false, default: None },
        FlagSpec { name: "chaos-lane", help: "crash this portfolio lane in every race (resilience drill; e.g. greedy)", takes_value: true, default: None },
        FlagSpec { name: "help", help: "show help", takes_value: false, default: None },
    ];
    let mut specs = specs;
    specs.extend(fault_flags());
    let args = invalid(cli::parse(argv, &specs))?;
    if args.get_bool("help") || args.positional.is_empty() {
        println!(
            "{}",
            cli::help(
                "plan-batch <network|file.toml>...",
                "plan several networks in one call: cross-network dedup, one shared race pool, sharded persistent cache",
                &specs
            )
        );
        println!("networks:");
        for p in list_network_presets() {
            println!("  {:<14} {} ({} stages)", p.name, p.description, p.stages.len());
        }
        return if args.get_bool("help") {
            Ok(())
        } else {
            Err(CliError::Invalid(
                "missing requests (e.g. `plan-batch lenet5 lenet5 resnet8`)".into(),
            ))
        };
    }
    let presets = args
        .positional
        .iter()
        .map(|a| batch_request(a))
        .collect::<Result<Vec<_>, _>>()?;
    let options = PlanOptions {
        accelerator: AcceleratorSpec::PerLayerGroup(
            invalid(args.get_usize("group"))?.unwrap_or(4).max(1),
        ),
        seed: invalid(args.get_u64("seed"))?.unwrap_or(2026),
        anneal_iters: invalid(args.get_u64("iters"))?.unwrap_or(50_000),
        anneal_starts: invalid(args.get_usize("starts"))?.unwrap_or(3).max(1),
        threads: invalid(args.get_usize("threads"))?.unwrap_or(0),
        overlap: invalid(OverlapMode::from_str(args.get("overlap").unwrap_or("sequential")))?,
        dma_channels: invalid(args.get_usize("dma-channels"))?.unwrap_or(1).max(1),
        compute_units: invalid(args.get_usize("compute-units"))?.unwrap_or(1).max(1),
    };
    let mut planner = if args.get_bool("no-cache") {
        BatchPlanner::new(options)
    } else {
        let dir = std::path::Path::new(args.get("cache-dir").unwrap());
        let shards = invalid(args.get_usize("shards"))?.unwrap_or(16).max(1);
        BatchPlanner::with_cache(
            options,
            ShardedStrategyCache::open_with(
                dir,
                shards,
                convoffload::planner::DEFAULT_SHARD_CAPACITY,
            )?,
        )
    };
    let faults = faults_from_args(&args, None)?;
    if let Some(m) = faults {
        planner = planner.with_faults(m);
    }
    if let Some(lane) = args.get("chaos-lane") {
        planner = planner.with_chaos(ChaosSpec { panic_lane: Some(lane.to_string()) });
    }
    let report = planner.plan_batch(&presets)?;
    if let Some(m) = faults.filter(FaultModel::is_active) {
        eprintln!("faults: {}", m.to_spec());
    }
    if args.get_bool("json") {
        println!("{}", batch_to_json(&report).to_string_pretty());
    } else {
        print!("{}", format_batch_table(&report));
    }
    Ok(())
}

// ---------------------------------------------------------------- plan-server

fn cmd_plan_server(argv: &[String]) -> Result<(), CliError> {
    let specs = vec![
        FlagSpec { name: "addr", help: "bind address (port 0 picks a free port)", takes_value: true, default: Some("127.0.0.1:7461") },
        FlagSpec { name: "queue-depth", help: "bounded request-queue capacity (beyond it: overloaded)", takes_value: true, default: Some("16") },
        FlagSpec { name: "max-request-kb", help: "maximum request line size in KiB", takes_value: true, default: Some("64") },
        FlagSpec { name: "read-timeout-ms", help: "per-connection read/idle timeout", takes_value: true, default: Some("5000") },
        FlagSpec { name: "state-dir", help: "journal + warm strategy cache directory", takes_value: true, default: Some(".plan-server") },
        FlagSpec { name: "shards", help: "strategy cache shard count", takes_value: true, default: Some("16") },
        FlagSpec { name: "group", help: "per-layer group size bound", takes_value: true, default: Some("4") },
        FlagSpec { name: "seed", help: "portfolio base seed", takes_value: true, default: Some("2026") },
        FlagSpec { name: "iters", help: "anneal iterations per lane (full rung)", takes_value: true, default: Some("50000") },
        FlagSpec { name: "starts", help: "number of anneal lanes (full rung)", takes_value: true, default: Some("3") },
        FlagSpec { name: "overlap", help: "DMA/compute overlap: sequential or double-buffered", takes_value: true, default: Some("sequential") },
        FlagSpec { name: "threads", help: "race worker threads (0 = auto)", takes_value: true, default: Some("0") },
        FlagSpec { name: "help", help: "show help", takes_value: false, default: None },
    ];
    let args = invalid(cli::parse(argv, &specs))?;
    if args.get_bool("help") {
        println!(
            "{}",
            cli::help(
                "plan-server",
                "long-lived planning service: line-delimited JSON over TCP \
                 (ops: plan, simulate, health, stats, shutdown)",
                &specs
            )
        );
        return Ok(());
    }
    let options = PlanOptions {
        accelerator: AcceleratorSpec::PerLayerGroup(
            invalid(args.get_usize("group"))?.unwrap_or(4).max(1),
        ),
        seed: invalid(args.get_u64("seed"))?.unwrap_or(2026),
        anneal_iters: invalid(args.get_u64("iters"))?.unwrap_or(50_000),
        anneal_starts: invalid(args.get_usize("starts"))?.unwrap_or(3).max(1),
        threads: invalid(args.get_usize("threads"))?.unwrap_or(0),
        overlap: invalid(OverlapMode::from_str(args.get("overlap").unwrap_or("sequential")))?,
        dma_channels: 1,
        compute_units: 1,
    };
    let config = ServerConfig {
        addr: args.get("addr").unwrap_or("127.0.0.1:7461").to_string(),
        queue_capacity: invalid(args.get_usize("queue-depth"))?.unwrap_or(16).max(1),
        max_request_bytes: invalid(args.get_usize("max-request-kb"))?.unwrap_or(64).max(1) * 1024,
        read_timeout_ms: invalid(args.get_u64("read-timeout-ms"))?.unwrap_or(5_000).max(1),
        state_dir: std::path::PathBuf::from(args.get("state-dir").unwrap_or(".plan-server")),
        shards: invalid(args.get_usize("shards"))?.unwrap_or(16).max(1),
        options,
    };
    let handle = PlanServer::start(config)?;
    println!("plan-server listening on {}", handle.local_addr);
    handle.wait();
    println!("plan-server stopped (cache flushed, journal compacted)");
    Ok(())
}

// ---------------------------------------------------------------- figures

fn cmd_figures(argv: &[String]) -> Result<(), CliError> {
    let specs = vec![
        FlagSpec { name: "fig", help: "which figure: 11, 12, 13 or all", takes_value: true, default: Some("all") },
        FlagSpec { name: "out-dir", help: "output directory", takes_value: true, default: Some("figures") },
        FlagSpec { name: "seed", help: "optimizer seed", takes_value: true, default: Some("2026") },
        FlagSpec { name: "quick", help: "smaller grids (CI mode)", takes_value: false, default: None },
        FlagSpec { name: "help", help: "show help", takes_value: false, default: None },
    ];
    let args = invalid(cli::parse(argv, &specs))?;
    if args.get_bool("help") {
        println!("{}", cli::help("figures", "regenerate the paper's figures", &specs));
        return Ok(());
    }
    let out_dir = std::path::PathBuf::from(args.get("out-dir").unwrap());
    let which = args.get("fig").unwrap().to_string();
    let seed = invalid(args.get_u64("seed"))?.unwrap_or(2026);
    let quick = args.get_bool("quick");

    use convoffload::bench_harness as bh;
    if which == "11" || which == "all" {
        let layer = layer_preset("lenet5-conv1").unwrap().layer;
        let max_g = if quick { 12 } else { layer.w_out() + 6 };
        let sizes: Vec<usize> = (1..=max_g).collect();
        let rows = bh::fig11(&layer, &sizes);
        let ascii = bh::fig11::to_ascii(&layer, &rows);
        bh::write_outputs(&out_dir, "fig11", &bh::fig11::to_csv(&rows), &ascii)
            .map_err(|e| e.to_string())?;
        println!("{ascii}");
        println!("wrote {}/fig11.csv", out_dir.display());
    }
    if which == "12" || which == "all" {
        let inputs: Vec<usize> = if quick { (4..=8).collect() } else { (4..=12).collect() };
        let rows = bh::fig12(&inputs, 4, seed);
        let ascii = bh::fig12::to_ascii(4, &rows);
        bh::write_outputs(&out_dir, "fig12", &bh::fig12::to_csv(&rows), &ascii)
            .map_err(|e| e.to_string())?;
        println!("{ascii}");
        println!("wrote {}/fig12.csv", out_dir.display());
    }
    if which == "13" || which == "all" {
        let inputs: Vec<usize> = if quick { vec![4, 6, 8] } else { (4..=12).collect() };
        let groups: Vec<usize> = if quick { vec![2, 4, 8] } else { (2..=10).collect() };
        let cells = bh::fig13(&inputs, &groups, seed);
        let ascii = bh::fig13::to_ascii(&inputs, &groups, &cells);
        bh::write_outputs(&out_dir, "fig13", &bh::fig13::to_csv(&cells), &ascii)
            .map_err(|e| e.to_string())?;
        println!("{ascii}");
        println!("wrote {}/fig13.csv", out_dir.display());
    }
    Ok(())
}

// ---------------------------------------------------------------- viz

fn cmd_viz(argv: &[String]) -> Result<(), CliError> {
    let mut specs = layer_flags();
    specs.push(FlagSpec { name: "strategy", help: "strategy name or file", takes_value: true, default: Some("zigzag") });
    specs.push(FlagSpec { name: "svg", help: "write an SVG here instead of ASCII", takes_value: true, default: None });
    let args = invalid(cli::parse(argv, &specs))?;
    if args.get_bool("help") {
        println!("{}", cli::help("viz", "render a strategy step by step", &specs));
        return Ok(());
    }
    let setup = setup_from(&args)?;
    let s = build_strategy(args.get("strategy").unwrap(), &setup.layer, setup.group)?;
    let steps = s.compile(&setup.layer);
    match args.get("svg") {
        Some(path) => {
            let svg = convoffload::viz::render_strategy_svg(
                &setup.layer,
                &steps,
                &format!("{} on {}", s.name, setup.layer),
            );
            std::fs::write(path, svg).map_err(|e| format!("{path}: {e}"))?;
            println!("wrote {path}");
        }
        None => {
            println!(
                "{}",
                convoffload::viz::render_strategy_ascii(&setup.layer, &steps)
            );
        }
    }
    Ok(())
}

// ---------------------------------------------------------------- e2e

fn cmd_e2e(argv: &[String]) -> Result<(), CliError> {
    let mut specs = layer_flags();
    specs.push(FlagSpec { name: "strategy", help: "strategy name or file", takes_value: true, default: Some("zigzag") });
    specs.push(FlagSpec { name: "backend", help: "rust-oracle or pjrt", takes_value: true, default: Some("pjrt") });
    specs.push(FlagSpec { name: "seed", help: "tensor seed", takes_value: true, default: Some("7") });
    let args = invalid(cli::parse(argv, &specs))?;
    if args.get_bool("help") {
        println!("{}", cli::help("e2e", "functional end-to-end run", &specs));
        return Ok(());
    }
    let setup = setup_from(&args)?;
    let s = build_strategy(args.get("strategy").unwrap(), &setup.layer, setup.group)?;
    let seed = invalid(args.get_u64("seed"))?.unwrap_or(7);
    let input =
        convoffload::conv::reference::synth_tensor(setup.layer.input_dims().len(), seed);
    let kernels =
        convoffload::conv::reference::synth_tensor(setup.layer.kernel_elements(), seed + 1);
    let sim = Simulator::new(setup.layer, Platform::new(setup.acc));

    let backend = invalid(FunctionalBackend::from_str(args.get("backend").unwrap()))?;
    let report = match backend {
        FunctionalBackend::RustOracle => {
            let mut b = RustOracleBackend;
            sim.run_functional(&s, &input, &kernels, &mut b)
        }
        FunctionalBackend::Pjrt => {
            let mut b = convoffload::runtime::PjrtBackend::from_default_dir()
                .map_err(|e| e.to_string())?;
            sim.run_functional(&s, &input, &kernels, &mut b)
        }
    }
    .map_err(|e| e.to_string())?;

    println!("layer: {}", setup.layer);
    println!("backend: {}", backend.as_str());
    println!("{}", convoffload::sim::summary_line(&report, &setup.acc));
    let err = report.max_abs_error.unwrap();
    let ok = report.functional_ok(1e-4).unwrap();
    println!("functional check: max |err| = {err:.2e} → {}", if ok { "OK" } else { "FAILED" });
    if !ok {
        return Err("functional check failed".into());
    }
    Ok(())
}

// ---------------------------------------------------------------- perf

fn cmd_perf(argv: &[String]) -> Result<(), CliError> {
    let mut specs = layer_flags();
    specs.push(FlagSpec { name: "tile", help: "group tile size", takes_value: true, default: Some("8") });
    let args = invalid(cli::parse(argv, &specs))?;
    if args.get_bool("help") {
        println!("{}", cli::help("perf", "L1 kernel VMEM/MXU estimates", &specs));
        return Ok(());
    }
    let setup = setup_from(&args)?;
    let tile = invalid(args.get_usize("tile"))?.unwrap_or(8);
    let tpu = convoffload::metrics::TpuModel::default();
    let est = convoffload::metrics::estimate_step_kernel(&setup.layer, tile, &tpu);
    println!("{}", convoffload::metrics::format_estimate(&setup.layer, tile, &est));
    Ok(())
}

// ---------------------------------------------------------------- presets

fn cmd_presets() -> Result<(), CliError> {
    println!("layers:");
    for p in list_presets() {
        println!("  {:<16} {}  [{}]", p.name, p.layer, p.description);
    }
    println!("\nnetworks (for `plan-network`):");
    for p in list_network_presets() {
        let stages: Vec<&str> = p.stages.iter().map(|s| s.name.as_str()).collect();
        println!("  {:<16} {}  [{}]", p.name, stages.join(" -> "), p.description);
    }
    Ok(())
}

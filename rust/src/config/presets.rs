//! Layer presets: the workloads of the paper's evaluation.
//!
//! §7.2 compares strategies “on the convolutional layers of ResNet8 and
//! LeNet-5”; §7.1 sweeps square layers `H_in = W_in ∈ [4, 12]` with 3×3
//! kernels. Inputs are pre-padded per Remark 2 (so ResNet-8's same-padded
//! 3×3 layers get `H_in + 2` here).

use crate::conv::ConvLayer;

/// A named layer preset.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LayerPreset {
    /// Preset name (CLI value).
    pub name: &'static str,
    /// One-line description for listings.
    pub description: &'static str,
    /// The preset layer.
    pub layer: ConvLayer,
    /// Name of the AOT step-artifact family for this layer, if emitted.
    pub artifact_hint: Option<&'static str>,
}

fn all() -> Vec<LayerPreset> {
    vec![
        LayerPreset {
            name: "example1",
            description: "Example 1/2 of the paper: 2x5x5 input, two 3x3 kernels",
            layer: ConvLayer::new(2, 5, 5, 3, 3, 2, 1, 1).unwrap(),
            artifact_hint: Some("step_example1_g8"),
        },
        LayerPreset {
            name: "lenet5-conv1",
            description: "LeNet-5 conv1: 1x32x32 input, six 5x5 kernels (Fig. 11's layer)",
            layer: ConvLayer::new(1, 32, 32, 5, 5, 6, 1, 1).unwrap(),
            artifact_hint: Some("step_lenet1_g8"),
        },
        LayerPreset {
            name: "lenet5-conv2",
            description: "LeNet-5 conv2: 6x14x14 input, sixteen 5x5 kernels",
            layer: ConvLayer::new(6, 14, 14, 5, 5, 16, 1, 1).unwrap(),
            artifact_hint: Some("step_lenet2_g8"),
        },
        LayerPreset {
            name: "resnet8-conv1",
            description: "ResNet-8 first 3x3 stage on 32x32 (pre-padded to 34x34), 16 kernels",
            layer: ConvLayer::new(3, 34, 34, 3, 3, 16, 1, 1).unwrap(),
            artifact_hint: None,
        },
        LayerPreset {
            name: "resnet8-conv2",
            description: "ResNet-8 stage-2 3x3 block on 16x16 (pre-padded to 18x18), 16 kernels",
            layer: ConvLayer::new(16, 18, 18, 3, 3, 16, 1, 1).unwrap(),
            artifact_hint: None,
        },
        LayerPreset {
            name: "mobilenet-dw3",
            description: "MobileNet-style depthwise 3x3 stride-2 stage: 4x18x18, groups = c_in = 4",
            layer: ConvLayer::new(4, 18, 18, 3, 3, 4, 2, 2)
                .unwrap()
                .with_groups(4)
                .unwrap(),
            artifact_hint: None,
        },
        LayerPreset {
            name: "dilated-3x3-d2",
            description: "Dilated 3x3 (d=2, span 5) context stage: 8x12x12, 8 kernels",
            layer: ConvLayer::new(8, 12, 12, 3, 3, 8, 1, 1)
                .unwrap()
                .with_dilation(2, 2)
                .unwrap(),
            artifact_hint: None,
        },
        LayerPreset {
            name: "paper-sweep-8",
            description: "§7.1 sweep member: 1x8x8 input, one 3x3 kernel",
            layer: ConvLayer::new(1, 8, 8, 3, 3, 1, 1, 1).unwrap(),
            artifact_hint: Some("step_paper_g8"),
        },
        LayerPreset {
            name: "paper-sweep-12",
            description: "§7.1 sweep member: 1x12x12 input, one 3x3 kernel",
            layer: ConvLayer::new(1, 12, 12, 3, 3, 1, 1, 1).unwrap(),
            artifact_hint: Some("step_paper_g8"),
        },
    ]
}

/// Look up a preset by name.
pub fn layer_preset(name: &str) -> Option<LayerPreset> {
    all().into_iter().find(|p| p.name == name)
}

/// All presets (for `--layer list` style CLI output).
pub fn list_presets() -> Vec<LayerPreset> {
    all()
}

/// The §7.1 sweep family: square `H_in = W_in ∈ [4, 12]`, one 3×3 kernel,
/// stride 1 (the paper sets `N = 1` because it “does not affect the
/// optimization of the S1 strategy”).
pub fn paper_sweep_layer(h_in: usize) -> ConvLayer {
    ConvLayer::square(1, h_in, 3, 1)
}

// ---------------------------------------------------------------- networks

/// One stage of a network preset: a conv layer plus the inter-stage plumbing
/// (pooling / re-padding) that connects it to the next stage.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct NetworkStagePreset {
    /// Stage name within the network.
    pub name: String,
    /// The stage's layer.
    pub layer: ConvLayer,
    /// Apply 2×2 stride-2 mean pooling after this stage (LeNet subsampling).
    pub pool_after: bool,
    /// Zero-pad the (pooled) output by this many pixels per spatial side
    /// before the next stage — the Remark-2 pre-padding for same-padded
    /// successors (ResNet-8's 3×3 blocks).
    pub pad_after: usize,
}

/// A whole-network preset — the §7.2 evaluation targets, expressed as the
/// layer sequences the network planner optimizes end to end.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct NetworkPreset {
    /// Preset name (CLI value). Owned, because networks also arrive from
    /// TOML files at runtime (`plan-batch` wraps single-layer experiment
    /// configs as one-stage networks), not only from the static table here.
    pub name: String,
    /// One-line description for listings.
    pub description: String,
    /// The stages in execution order.
    pub stages: Vec<NetworkStagePreset>,
}

fn all_networks() -> Vec<NetworkPreset> {
    vec![
        NetworkPreset {
            name: "lenet5".into(),
            description: "LeNet-5 convolutional trunk: conv1 -> 2x2 pool -> conv2".into(),
            stages: vec![
                NetworkStagePreset {
                    name: "conv1".into(),
                    layer: ConvLayer::new(1, 32, 32, 5, 5, 6, 1, 1).unwrap(),
                    pool_after: true,
                    pad_after: 0,
                },
                NetworkStagePreset {
                    name: "conv2".into(),
                    layer: ConvLayer::new(6, 14, 14, 5, 5, 16, 1, 1).unwrap(),
                    pool_after: false,
                    pad_after: 0,
                },
            ],
        },
        NetworkPreset {
            name: "resnet8".into(),
            description:
                "ResNet-8 3x3 trunk: conv1 -> pool + pad -> stage-2 block (two same-padded convs)"
                    .into(),
            stages: vec![
                NetworkStagePreset {
                    name: "conv1".into(),
                    layer: ConvLayer::new(3, 34, 34, 3, 3, 16, 1, 1).unwrap(),
                    pool_after: true,
                    pad_after: 1,
                },
                NetworkStagePreset {
                    name: "conv2a".into(),
                    layer: ConvLayer::new(16, 18, 18, 3, 3, 16, 1, 1).unwrap(),
                    pool_after: false,
                    pad_after: 1,
                },
                NetworkStagePreset {
                    name: "conv2b".into(),
                    layer: ConvLayer::new(16, 18, 18, 3, 3, 16, 1, 1).unwrap(),
                    pool_after: false,
                    pad_after: 0,
                },
            ],
        },
        NetworkPreset {
            name: "mobilenet_slim".into(),
            description:
                "Depthwise-separable trunk: 3x3 depthwise s2 -> 1x1 pointwise -> 3x3 dilated (d=2)"
                    .into(),
            stages: vec![
                NetworkStagePreset {
                    name: "dw3".into(),
                    layer: ConvLayer::new(4, 18, 18, 3, 3, 4, 2, 2)
                        .unwrap()
                        .with_groups(4)
                        .unwrap(),
                    pool_after: false,
                    pad_after: 0,
                },
                NetworkStagePreset {
                    name: "pw1".into(),
                    layer: ConvLayer::new(4, 8, 8, 1, 1, 8, 1, 1).unwrap(),
                    pool_after: false,
                    // Remark-2 pre-padding for the dilated successor: span 5
                    // needs 2 pixels per side to keep the 8×8 spatial size.
                    pad_after: 2,
                },
                NetworkStagePreset {
                    name: "dil3".into(),
                    layer: ConvLayer::new(8, 12, 12, 3, 3, 8, 1, 1)
                        .unwrap()
                        .with_dilation(2, 2)
                        .unwrap(),
                    pool_after: false,
                    pad_after: 0,
                },
            ],
        },
        NetworkPreset {
            name: "lenet5_micro".into(),
            description:
                "LeNet-5 trunk at micro scale (4-patch stages) for exact certification"
                    .into(),
            stages: vec![
                NetworkStagePreset {
                    name: "c1".into(),
                    layer: ConvLayer::new(1, 6, 6, 5, 5, 6, 1, 1).unwrap(),
                    pool_after: false,
                    pad_after: 1,
                },
                NetworkStagePreset {
                    name: "c2".into(),
                    layer: ConvLayer::new(6, 4, 4, 3, 3, 16, 1, 1).unwrap(),
                    pool_after: false,
                    pad_after: 0,
                },
            ],
        },
    ]
}

/// Look up a network preset by name (`lenet5`, `resnet8`, `mobilenet_slim`,
/// `lenet5_micro`).
pub fn network_preset(name: &str) -> Option<NetworkPreset> {
    all_networks().into_iter().find(|p| p.name == name)
}

/// All network presets (for CLI listings).
pub fn list_network_presets() -> Vec<NetworkPreset> {
    all_networks()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_resolve_and_validate() {
        for p in list_presets() {
            assert!(p.layer.validate().is_ok(), "{}", p.name);
            assert_eq!(layer_preset(p.name).as_ref(), Some(&p));
        }
        assert!(layer_preset("bogus").is_none());
    }

    #[test]
    fn lenet1_dimensions() {
        let p = layer_preset("lenet5-conv1").unwrap();
        assert_eq!(p.layer.h_out(), 28);
        assert_eq!(p.layer.w_out(), 28);
        assert_eq!(p.layer.n_patches(), 784);
        assert_eq!(p.layer.ops_per_patch(), 25 * 6);
    }

    #[test]
    fn sweep_layers_match_paper_grid() {
        for h in 4..=12 {
            let l = paper_sweep_layer(h);
            assert_eq!(l.h_out(), h - 2);
            assert_eq!(l.c_in, 1);
            assert_eq!(l.n_kernels, 1);
        }
    }

    #[test]
    fn network_presets_resolve() {
        for p in list_network_presets() {
            assert!(!p.stages.is_empty(), "{}", p.name);
            assert_eq!(network_preset(&p.name).as_ref(), Some(&p));
            for s in &p.stages {
                assert!(s.layer.validate().is_ok(), "{}/{}", p.name, s.name);
            }
        }
        assert!(network_preset("bogus").is_none());
    }

    #[test]
    fn mobilenet_slim_stage_geometry() {
        let p = network_preset("mobilenet_slim").unwrap();
        assert_eq!(p.stages.len(), 3);
        let dims = |l: &ConvLayer| {
            let d = l.output_dims();
            (d.c, d.h, d.w)
        };
        let dw = &p.stages[0].layer;
        assert_eq!(dw.groups, dw.c_in, "stage 1 is depthwise");
        assert_eq!((dw.s_h, dw.s_w), (2, 2));
        assert_eq!(dims(dw), (4, 8, 8));
        let pw = &p.stages[1].layer;
        assert_eq!((pw.h_k, pw.w_k), (1, 1), "stage 2 is pointwise");
        assert_eq!(dims(pw), (8, 8, 8));
        let dil = &p.stages[2].layer;
        assert_eq!((dil.d_h, dil.d_w), (2, 2), "stage 3 is dilated");
        assert_eq!((dil.h_span(), dil.w_span()), (5, 5));
        assert_eq!(dims(dil), (8, 8, 8));
    }

    /// Stage dimensions must chain: next input = previous output, pooled and
    /// re-padded per the stage's plumbing flags (the same rule
    /// `sim::network::Network::push` enforces).
    #[test]
    fn network_presets_chain_dimensionally() {
        for p in list_network_presets() {
            for win in p.stages.windows(2) {
                let (prev, next) = (&win[0], &win[1]);
                let dims = crate::sim::network::next_stage_dims(
                    &prev.layer,
                    prev.pool_after,
                    prev.pad_after,
                );
                assert_eq!(
                    (next.layer.c_in, next.layer.h_in, next.layer.w_in),
                    (dims.c, dims.h, dims.w),
                    "{}: {} -> {}",
                    p.name,
                    prev.name,
                    next.name
                );
            }
        }
    }
}

//! Layer presets: the workloads of the paper's evaluation.
//!
//! §7.2 compares strategies “on the convolutional layers of ResNet8 and
//! LeNet-5”; §7.1 sweeps square layers `H_in = W_in ∈ [4, 12]` with 3×3
//! kernels. Inputs are pre-padded per Remark 2 (so ResNet-8's same-padded
//! 3×3 layers get `H_in + 2` here).

use crate::conv::ConvLayer;

/// A named layer preset.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LayerPreset {
    pub name: &'static str,
    pub description: &'static str,
    pub layer: ConvLayer,
    /// Name of the AOT step-artifact family for this layer, if emitted.
    pub artifact_hint: Option<&'static str>,
}

fn all() -> Vec<LayerPreset> {
    vec![
        LayerPreset {
            name: "example1",
            description: "Example 1/2 of the paper: 2x5x5 input, two 3x3 kernels",
            layer: ConvLayer::new(2, 5, 5, 3, 3, 2, 1, 1).unwrap(),
            artifact_hint: Some("step_example1_g8"),
        },
        LayerPreset {
            name: "lenet5-conv1",
            description: "LeNet-5 conv1: 1x32x32 input, six 5x5 kernels (Fig. 11's layer)",
            layer: ConvLayer::new(1, 32, 32, 5, 5, 6, 1, 1).unwrap(),
            artifact_hint: Some("step_lenet1_g8"),
        },
        LayerPreset {
            name: "lenet5-conv2",
            description: "LeNet-5 conv2: 6x14x14 input, sixteen 5x5 kernels",
            layer: ConvLayer::new(6, 14, 14, 5, 5, 16, 1, 1).unwrap(),
            artifact_hint: Some("step_lenet2_g8"),
        },
        LayerPreset {
            name: "resnet8-conv1",
            description: "ResNet-8 first 3x3 stage on 32x32 (pre-padded to 34x34), 16 kernels",
            layer: ConvLayer::new(3, 34, 34, 3, 3, 16, 1, 1).unwrap(),
            artifact_hint: None,
        },
        LayerPreset {
            name: "resnet8-conv2",
            description: "ResNet-8 stage-2 3x3 block on 16x16 (pre-padded to 18x18), 16 kernels",
            layer: ConvLayer::new(16, 18, 18, 3, 3, 16, 1, 1).unwrap(),
            artifact_hint: None,
        },
        LayerPreset {
            name: "paper-sweep-8",
            description: "§7.1 sweep member: 1x8x8 input, one 3x3 kernel",
            layer: ConvLayer::new(1, 8, 8, 3, 3, 1, 1, 1).unwrap(),
            artifact_hint: Some("step_paper_g8"),
        },
        LayerPreset {
            name: "paper-sweep-12",
            description: "§7.1 sweep member: 1x12x12 input, one 3x3 kernel",
            layer: ConvLayer::new(1, 12, 12, 3, 3, 1, 1, 1).unwrap(),
            artifact_hint: Some("step_paper_g8"),
        },
    ]
}

/// Look up a preset by name.
pub fn layer_preset(name: &str) -> Option<LayerPreset> {
    all().into_iter().find(|p| p.name == name)
}

/// All presets (for `--layer list` style CLI output).
pub fn list_presets() -> Vec<LayerPreset> {
    all()
}

/// The §7.1 sweep family: square `H_in = W_in ∈ [4, 12]`, one 3×3 kernel,
/// stride 1 (the paper sets `N = 1` because it “does not affect the
/// optimization of the S1 strategy”).
pub fn paper_sweep_layer(h_in: usize) -> ConvLayer {
    ConvLayer::square(1, h_in, 3, 1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_resolve_and_validate() {
        for p in list_presets() {
            assert!(p.layer.validate().is_ok(), "{}", p.name);
            assert_eq!(layer_preset(p.name).as_ref(), Some(&p));
        }
        assert!(layer_preset("bogus").is_none());
    }

    #[test]
    fn lenet1_dimensions() {
        let p = layer_preset("lenet5-conv1").unwrap();
        assert_eq!(p.layer.h_out(), 28);
        assert_eq!(p.layer.w_out(), 28);
        assert_eq!(p.layer.n_patches(), 784);
        assert_eq!(p.layer.ops_per_patch(), 25 * 6);
    }

    #[test]
    fn sweep_layers_match_paper_grid() {
        for h in 4..=12 {
            let l = paper_sweep_layer(h);
            assert_eq!(l.h_out(), h - 2);
            assert_eq!(l.c_in, 1);
            assert_eq!(l.n_kernels, 1);
        }
    }
}

//! TOML-subset parser (offline substitute for the `toml` crate).
//!
//! Supports the subset experiment files need: `[section]` headers,
//! `key = value` pairs with quoted strings, integers, floats and booleans,
//! `#` comments and blank lines. No arrays, nested tables or multi-line
//! strings — configs stay flat by design.

use std::collections::BTreeMap;

/// A parsed document: `(section, key) → raw value`.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct TomlDoc {
    values: BTreeMap<(String, String), Value>,
}

#[derive(Debug, Clone, PartialEq)]
/// A parsed TOML-subset value.
pub enum Value {
    /// A quoted string.
    Str(String),
    /// An integer.
    Int(i64),
    /// A float.
    Float(f64),
    /// A boolean.
    Bool(bool),
}

impl TomlDoc {
    /// Parse a document (errors carry 1-based line numbers).
    pub fn parse(text: &str) -> Result<Self, String> {
        let mut doc = TomlDoc::default();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(rest) = line.strip_prefix('[') {
                let name = rest
                    .strip_suffix(']')
                    .ok_or_else(|| format!("line {}: unterminated section", lineno + 1))?
                    .trim();
                if name.is_empty() || name.contains('[') {
                    return Err(format!("line {}: bad section name", lineno + 1));
                }
                section = name.to_string();
                continue;
            }
            let (key, value) = line
                .split_once('=')
                .ok_or_else(|| format!("line {}: expected 'key = value'", lineno + 1))?;
            let key = key.trim();
            if key.is_empty() {
                return Err(format!("line {}: empty key", lineno + 1));
            }
            let value = parse_value(value.trim())
                .ok_or_else(|| format!("line {}: bad value '{}'", lineno + 1, value.trim()))?;
            doc.values
                .insert((section.clone(), key.to_string()), value);
        }
        Ok(doc)
    }

    /// Raw value at `(section, key)`, if present.
    pub fn get(&self, section: &str, key: &str) -> Option<&Value> {
        self.values.get(&(section.to_string(), key.to_string()))
    }

    /// String value at `(section, key)`, if present and a string.
    pub fn get_str(&self, section: &str, key: &str) -> Option<&str> {
        match self.get(section, key) {
            Some(Value::Str(s)) => Some(s),
            _ => None,
        }
    }

    /// Integer value at `(section, key)`, if present and an integer.
    pub fn get_int(&self, section: &str, key: &str) -> Option<i64> {
        match self.get(section, key) {
            Some(Value::Int(v)) => Some(*v),
            _ => None,
        }
    }

    /// Float value at `(section, key)` (integers promote).
    pub fn get_float(&self, section: &str, key: &str) -> Option<f64> {
        match self.get(section, key) {
            Some(Value::Float(v)) => Some(*v),
            Some(Value::Int(v)) => Some(*v as f64),
            _ => None,
        }
    }

    /// Boolean value at `(section, key)`, if present and a boolean.
    pub fn get_bool(&self, section: &str, key: &str) -> Option<bool> {
        match self.get(section, key) {
            Some(Value::Bool(b)) => Some(*b),
            _ => None,
        }
    }

    /// All `(section, key)` pairs (for diagnostics).
    pub fn keys(&self) -> impl Iterator<Item = (&str, &str)> {
        self.values.keys().map(|(s, k)| (s.as_str(), k.as_str()))
    }
}

fn strip_comment(line: &str) -> &str {
    // respect '#' inside quoted strings
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Option<Value> {
    if let Some(rest) = s.strip_prefix('"') {
        let inner = rest.strip_suffix('"')?;
        if inner.contains('"') {
            return None; // no escapes in the subset
        }
        return Some(Value::Str(inner.to_string()));
    }
    match s {
        "true" => return Some(Value::Bool(true)),
        "false" => return Some(Value::Bool(false)),
        _ => {}
    }
    if let Ok(v) = s.parse::<i64>() {
        return Some(Value::Int(v));
    }
    if let Ok(v) = s.parse::<f64>() {
        return Some(Value::Float(v));
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_types() {
        let doc = TomlDoc::parse(
            r#"
name = "top"        # a comment
count = 42

[sec]
ratio = 1.5
flag = true
label = "x # not a comment"
"#,
        )
        .unwrap();
        assert_eq!(doc.get_str("", "name"), Some("top"));
        assert_eq!(doc.get_int("", "count"), Some(42));
        assert_eq!(doc.get_float("sec", "ratio"), Some(1.5));
        assert_eq!(doc.get_bool("sec", "flag"), Some(true));
        assert_eq!(doc.get_str("sec", "label"), Some("x # not a comment"));
        assert_eq!(doc.keys().count(), 5);
    }

    #[test]
    fn int_promotes_to_float() {
        let doc = TomlDoc::parse("x = 3\n").unwrap();
        assert_eq!(doc.get_float("", "x"), Some(3.0));
        assert_eq!(doc.get_str("", "x"), None);
    }

    #[test]
    fn rejects_malformed() {
        assert!(TomlDoc::parse("[unterminated\n").is_err());
        assert!(TomlDoc::parse("keyonly\n").is_err());
        assert!(TomlDoc::parse("x = \"unclosed\n").is_err());
        assert!(TomlDoc::parse("x = what\n").is_err());
        assert!(TomlDoc::parse(" = 3\n").is_err());
    }

    #[test]
    fn later_values_override() {
        let doc = TomlDoc::parse("x = 1\nx = 2\n").unwrap();
        assert_eq!(doc.get_int("", "x"), Some(2));
    }
}

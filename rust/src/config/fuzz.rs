//! Seeded random-network generation — the scenario fuzzer.
//!
//! The ROADMAP's "as many scenarios as you can imagine" axis needs inputs no
//! preset covers: layer chains mixing stride, dilation, channel groups
//! (including depthwise), pooling and re-padding. [`random_network`] samples
//! such a chain deterministically from a seed — valid **by construction**
//! (every stage is sampled against the previous stage's output dimensions,
//! the same rule `sim::network::Network::push` enforces) — together with a
//! concrete per-stage strategy, so one seed pins one end-to-end simulation.
//!
//! Consumers:
//! * the property tests (`rust/tests/invariants.rs`) check the formalism's
//!   invariants over hundreds of seeds;
//! * the differential harness (`rust/tests/differential.rs`) simulates a
//!   fixed seed set and emits `target/differential_cases.json`, which
//!   `python/tests/test_differential.py` replays through the independent
//!   Python oracle simulator and compares durations / loaded elements;
//! * [`network_to_json`] is that interchange format (versioned; layers carry
//!   dilation + groups explicitly).

use crate::conv::ConvLayer;
use crate::platform::Accelerator;
use crate::sim::{Network, Stage};
use crate::strategy::{self, GroupedStrategy, Ordering};
use crate::util::json::Json;
use crate::util::rng::Rng;

/// One sampled pipeline stage: the layer plus its inter-stage plumbing and
/// the concrete strategy the simulation runs.
#[derive(Debug, Clone)]
pub struct FuzzStage {
    /// Stage name (`s0`, `s1`, …).
    pub name: String,
    /// The sampled layer.
    pub layer: ConvLayer,
    /// 2×2 mean pooling after this stage.
    pub pool_after: bool,
    /// Zero-padding per spatial side before the next stage.
    pub pad_after: usize,
    /// The ordering the strategy was built from.
    pub ordering: Ordering,
    /// Group-size bound of the strategy.
    pub group_size: usize,
    /// The concrete strategy the simulation runs.
    pub strategy: GroupedStrategy,
    /// The accelerator sized for this stage.
    pub accelerator: Accelerator,
}

/// A sampled network: a chain of [`FuzzStage`]s, valid by construction.
#[derive(Debug, Clone)]
pub struct FuzzNetwork {
    /// The seed the network was sampled from.
    pub seed: u64,
    /// The sampled stages, dimensionally chained.
    pub stages: Vec<FuzzStage>,
    /// DMA channels (k ∈ 1..=3) for the §3.10 multi-resource replay of this
    /// network. The per-stage accelerators stay at 1×1 so every historical
    /// baseline is untouched; the differential harness applies this shape to
    /// its own roomy variants.
    pub dma_channels: usize,
    /// Compute units (m ∈ 1..=3) for the multi-resource replay.
    pub compute_units: usize,
    /// Images per run (1 or 4) for the multi-resource replay.
    pub batch: usize,
}

impl FuzzNetwork {
    /// Materialize as a simulatable [`Network`]. Cannot fail: stage chaining
    /// is enforced during sampling (`push` re-checks it).
    pub fn to_network(&self) -> Network {
        let mut net = Network::default();
        for s in &self.stages {
            net.push(Stage {
                name: s.name.clone(),
                layer: s.layer,
                accelerator: s.accelerator,
                strategy: s.strategy.clone(),
                pool_after: s.pool_after,
                pad_after: s.pad_after,
            })
            .expect("fuzz stages chain by construction");
        }
        net
    }

    /// Feature summary, used by coverage assertions: (any stride > 1, any
    /// dilation > 1, any groups > 1, any pooling).
    pub fn features(&self) -> (bool, bool, bool, bool) {
        (
            self.stages.iter().any(|s| s.layer.s_h > 1 || s.layer.s_w > 1),
            self.stages.iter().any(|s| s.layer.d_h > 1 || s.layer.d_w > 1),
            self.stages.iter().any(|s| s.layer.groups > 1),
            self.stages.iter().any(|s| s.pool_after),
        )
    }
}

/// Sample one layer for an input of `c × h × w` — strides {1, 2}, dilation
/// {1, 2, 3}, groups from the divisors of `c` (including depthwise `c`),
/// kernels 1–3 per axis; falls back to a 1×1 dense layer when `h`/`w` leave
/// no room (always valid for positive dims).
pub fn random_layer(rng: &mut Rng, c: usize, h: usize, w: usize) -> ConvLayer {
    for _ in 0..32 {
        let h_k = 1 + rng.index(3);
        let w_k = 1 + rng.index(3);
        let s_h = 1 + rng.index(2);
        let s_w = 1 + rng.index(2);
        // Dilation only matters for k > 1; keep d = 1 common.
        let d_h = if h_k > 1 && rng.chance(0.4) { 2 + rng.index(2) } else { 1 };
        let d_w = if w_k > 1 && rng.chance(0.4) { 2 + rng.index(2) } else { 1 };
        if (h_k - 1) * d_h + 1 > h || (w_k - 1) * d_w + 1 > w {
            continue; // dilated span does not fit; resample
        }
        let divisors: Vec<usize> = (1..=c).filter(|g| c % g == 0).collect();
        let groups = if rng.chance(0.5) { *rng.choose(&divisors) } else { 1 };
        let n_kernels = groups * (1 + rng.index(2));
        let layer = ConvLayer::new(c, h, w, h_k, w_k, n_kernels, s_h, s_w)
            .and_then(|l| l.with_dilation(d_h, d_w))
            .and_then(|l| l.with_groups(groups));
        if let Ok(l) = layer {
            return l;
        }
    }
    ConvLayer::new(c, h, w, 1, 1, 1, 1, 1).expect("1x1 layer on positive dims")
}

/// Deterministically sample a whole network from `seed`: 1–3 stages over a
/// random initial tensor, each with a random ordering strategy and group
/// bound, pooling/padding plumbed so the chain stays dimensionally valid.
pub fn random_network(seed: u64) -> FuzzNetwork {
    let mut rng = Rng::new(seed.wrapping_mul(0x9E37_79B9_7F4A_7C15).wrapping_add(seed));
    let want_stages = 1 + rng.index(3);
    let mut c = 1 + rng.index(4);
    let mut h = 8 + rng.index(9);
    let mut w = 8 + rng.index(9);

    let mut stages = Vec::with_capacity(want_stages);
    for si in 0..want_stages {
        let layer = random_layer(&mut rng, c, h, w);
        let group_size = 1 + rng.index(4);
        let ordering = *rng.choose(&Ordering::all());
        let strategy = strategy::from_ordering(&layer, ordering, group_size);
        let accelerator = Accelerator::for_group_size(&layer, group_size);

        let out = layer.output_dims();
        let mut pool_after = false;
        let mut pad_after = 0;
        let last = si + 1 == want_stages;
        if !last {
            pool_after = out.h >= 2 && out.w >= 2 && rng.chance(0.35);
            pad_after = rng.index(3);
        }
        let dims = crate::sim::network::next_stage_dims(&layer, pool_after, pad_after);
        stages.push(FuzzStage {
            name: format!("s{si}"),
            layer,
            pool_after,
            pad_after,
            ordering,
            group_size,
            strategy,
            accelerator,
        });
        if last || dims.h < 1 || dims.w < 1 {
            break;
        }
        (c, h, w) = (dims.c, dims.h, dims.w);
    }
    // Resource shape + batch for the §3.10 replay — drawn AFTER the stage
    // loop so every pre-existing draw (and therefore every pinned layer,
    // strategy and baseline) stays bit-stable.
    let dma_channels = 1 + rng.index(3);
    let compute_units = 1 + rng.index(3);
    let batch = if rng.chance(0.5) { 4 } else { 1 };
    FuzzNetwork { seed, stages, dma_channels, compute_units, batch }
}

// ------------------------------------------------------------ interchange

/// JSON form of a layer (all geometry fields explicit).
pub fn layer_to_json(l: &ConvLayer) -> Json {
    let mut o = Json::obj();
    o.set("c_in", l.c_in)
        .set("h_in", l.h_in)
        .set("w_in", l.w_in)
        .set("h_k", l.h_k)
        .set("w_k", l.w_k)
        .set("n_kernels", l.n_kernels)
        .set("s_h", l.s_h)
        .set("s_w", l.s_w)
        .set("d_h", l.d_h)
        .set("d_w", l.d_w)
        .set("groups", l.groups);
    o
}

/// JSON form of a whole fuzz network (the differential interchange): every
/// stage carries its layer, accelerator, explicit strategy groups and
/// plumbing flags, so an independent simulator needs nothing else.
pub fn network_to_json(n: &FuzzNetwork) -> Json {
    let stages: Vec<Json> = n
        .stages
        .iter()
        .map(|s| {
            let mut acc = Json::obj();
            acc.set("nbop_pe", s.accelerator.nbop_pe)
                .set("t_acc", s.accelerator.t_acc)
                .set("size_mem", s.accelerator.size_mem)
                .set("t_l", s.accelerator.t_l)
                .set("t_w", s.accelerator.t_w);
            let groups: Vec<Json> = s
                .strategy
                .groups
                .iter()
                .map(|g| Json::Arr(g.iter().map(|&p| Json::from(p)).collect()))
                .collect();
            let mut st = Json::obj();
            st.set("name", s.name.as_str())
                .set("layer", layer_to_json(&s.layer))
                .set("accelerator", acc)
                .set("ordering", s.ordering.as_str())
                .set("group_size", s.group_size)
                .set("strategy_groups", Json::Arr(groups))
                .set("writeback", s.strategy.writeback.as_str())
                .set("pool_after", s.pool_after)
                .set("pad_after", s.pad_after);
            st
        })
        .collect();
    let mut o = Json::obj();
    o.set("seed", n.seed)
        .set("dma_channels", n.dma_channels)
        .set("compute_units", n.compute_units)
        .set("batch", n.batch)
        .set("stages", Json::Arr(stages));
    o
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generation_is_deterministic_per_seed() {
        for seed in [0u64, 1, 7, 42, 1000] {
            let a = random_network(seed);
            let b = random_network(seed);
            assert_eq!(a.stages.len(), b.stages.len(), "seed {seed}");
            for (x, y) in a.stages.iter().zip(&b.stages) {
                assert_eq!(x.layer, y.layer);
                assert_eq!(x.strategy, y.strategy);
                assert_eq!(x.accelerator, y.accelerator);
                assert_eq!((x.pool_after, x.pad_after), (y.pool_after, y.pad_after));
            }
        }
    }

    #[test]
    fn networks_chain_and_simulate() {
        for seed in 0..40u64 {
            let net = random_network(seed);
            assert!(!net.stages.is_empty(), "seed {seed}");
            let sim_net = net.to_network(); // push() re-validates chaining
            let report = sim_net.run().unwrap_or_else(|e| {
                panic!("seed {seed}: simulation failed: {e}")
            });
            assert_eq!(report.per_stage.len(), net.stages.len());
            assert!(report.total_duration > 0);
        }
    }

    #[test]
    fn strategies_cover_each_layer_exactly_once() {
        for seed in 0..40u64 {
            let net = random_network(seed);
            for s in &net.stages {
                let mut all: Vec<u32> =
                    s.strategy.groups.iter().flatten().copied().collect();
                all.sort_unstable();
                assert_eq!(
                    all,
                    s.layer.all_patches().collect::<Vec<_>>(),
                    "seed {seed} stage {}",
                    s.name
                );
                assert!(s.strategy.groups.iter().all(|g| g.len() <= s.group_size));
            }
        }
    }

    /// The seed range used by the differential harness must cover every
    /// feature axis (stride, dilation, groups, pooling) — the acceptance
    /// bar for scenario diversity.
    #[test]
    fn seed_range_covers_all_feature_axes() {
        let (mut st, mut di, mut gr, mut po) = (false, false, false, false);
        for seed in 1..=24u64 {
            let (s, d, g, p) = random_network(seed).features();
            st |= s;
            di |= d;
            gr |= g;
            po |= p;
        }
        assert!(st, "no strided case in the seed range");
        assert!(di, "no dilated case in the seed range");
        assert!(gr, "no grouped case in the seed range");
        assert!(po, "no pooled case in the seed range");
    }

    /// The same seed range must also exercise every §3.10 resource axis:
    /// multiple DMA channels, multiple compute units and a real batch
    /// (the Python differential suite asserts the same of the emitted
    /// cases). Shapes stay within the sampled bounds.
    #[test]
    fn seed_range_covers_the_resource_axes() {
        let (mut multi_k, mut multi_m, mut batched) = (false, false, false);
        for seed in 1..=24u64 {
            let net = random_network(seed);
            assert!((1..=3).contains(&net.dma_channels), "seed {seed}");
            assert!((1..=3).contains(&net.compute_units), "seed {seed}");
            assert!(net.batch == 1 || net.batch == 4, "seed {seed}");
            multi_k |= net.dma_channels > 1;
            multi_m |= net.compute_units > 1;
            batched |= net.batch > 1;
        }
        assert!(multi_k, "no multi-channel case in the seed range");
        assert!(multi_m, "no multi-unit case in the seed range");
        assert!(batched, "no batched case in the seed range");
    }

    #[test]
    fn json_interchange_is_parseable_and_complete() {
        let net = random_network(3);
        let j = network_to_json(&net);
        let text = j.to_string_pretty();
        let back = crate::util::json::parse(&text).unwrap();
        assert_eq!(back.get("seed").and_then(Json::as_u64), Some(3));
        assert_eq!(
            back.get("dma_channels").and_then(Json::as_usize),
            Some(net.dma_channels)
        );
        assert_eq!(
            back.get("compute_units").and_then(Json::as_usize),
            Some(net.compute_units)
        );
        assert_eq!(back.get("batch").and_then(Json::as_usize), Some(net.batch));
        let stages = back.get("stages").and_then(Json::as_arr).unwrap();
        assert_eq!(stages.len(), net.stages.len());
        for (js, s) in stages.iter().zip(&net.stages) {
            let l = js.get("layer").unwrap();
            assert_eq!(l.get("d_h").and_then(Json::as_usize), Some(s.layer.d_h));
            assert_eq!(
                l.get("groups").and_then(Json::as_usize),
                Some(s.layer.groups)
            );
            let groups = js.get("strategy_groups").and_then(Json::as_arr).unwrap();
            assert_eq!(groups.len(), s.strategy.groups.len());
        }
    }
}

//! Configuration system: layer/accelerator presets and TOML-subset files.
//!
//! Presets cover the workloads of the paper's evaluation (§7): the LeNet-5
//! and ResNet-8 convolution layers, the Example-1/2 layer, and the §7.1
//! square-sweep family. Experiment files use a TOML subset parsed by
//! [`toml`] (offline substitute — `[section]`s, `key = value` with strings,
//! integers, booleans).

pub mod fuzz;
pub mod presets;
pub mod toml;

pub use presets::{
    layer_preset, list_network_presets, list_presets, network_preset, LayerPreset,
    NetworkPreset, NetworkStagePreset,
};
pub use toml::TomlDoc;

use crate::conv::ConvLayer;
use crate::platform::{Accelerator, FaultModel};

/// A fully described experiment: a layer, an accelerator and the strategy
/// parameters, loadable from a TOML-subset file.
#[derive(Debug, Clone, PartialEq)]
pub struct ExperimentConfig {
    /// Experiment name (reports / trace files).
    pub name: String,
    /// The convolution layer under test.
    pub layer: ConvLayer,
    /// The accelerator (overlap mode included; `[accelerator] overlap =
    /// "double-buffered"` selects the §3.7 timeline).
    pub accelerator: Accelerator,
    /// Group-size bound `nb_patches_max_S1`.
    pub group_size: usize,
    /// `nb_data_reload` bound for strategy validation (§2.3).
    pub nb_data_reload: u32,
    /// Fault-injection model from the `[faults]` section (`None` when the
    /// section is absent; a present-but-all-zero section is `Some` but
    /// inactive).
    pub faults: Option<FaultModel>,
}

/// Parse a `[faults]` section into a [`FaultModel`] (`Ok(None)` when the
/// document has no such section). Flat keys mirror the struct fields:
/// `seed`, `dma_fail_rate`, `max_retries`, `retry_penalty`, `dma_jitter`,
/// `t_acc_jitter`, `shrink_rate`, `shrink_elements`. Rates must lie in
/// `[0, 1]`; `max_retries` defaults to 3 so `dma_fail_rate` alone is a live
/// model, matching the CLI spec syntax.
pub fn fault_model_from_doc(doc: &TomlDoc) -> Result<Option<FaultModel>, String> {
    const KEYS: [&str; 8] = [
        "seed",
        "dma_fail_rate",
        "max_retries",
        "retry_penalty",
        "dma_jitter",
        "t_acc_jitter",
        "shrink_rate",
        "shrink_elements",
    ];
    let mut present = false;
    for (section, key) in doc.keys() {
        if section != "faults" {
            continue;
        }
        if !KEYS.contains(&key) {
            return Err(format!("[faults]: unknown key '{key}'"));
        }
        present = true;
    }
    if !present {
        return Ok(None);
    }
    let int = |key: &str, default: u64| -> Result<u64, String> {
        match doc.get_int("faults", key) {
            Some(v) if v >= 0 => Ok(v as u64),
            Some(v) => Err(format!("[faults] {key}: negative value {v}")),
            None => Ok(default),
        }
    };
    let rate = |key: &str| -> Result<f64, String> {
        match doc.get_float("faults", key) {
            Some(r) if (0.0..=1.0).contains(&r) => Ok(r),
            Some(r) => Err(format!("[faults] {key}: rate {r} outside [0, 1]")),
            None => Ok(0.0),
        }
    };
    Ok(Some(FaultModel {
        seed: int("seed", 0)?,
        dma_fail_rate: rate("dma_fail_rate")?,
        max_retries: int("max_retries", 3)? as u32,
        retry_penalty: int("retry_penalty", 0)?,
        dma_jitter: int("dma_jitter", 0)?,
        t_acc_jitter: int("t_acc_jitter", 0)?,
        shrink_rate: rate("shrink_rate")?,
        shrink_elements: int("shrink_elements", 0)?,
    }))
}

impl ExperimentConfig {
    /// Parse from TOML-subset text, e.g.:
    ///
    /// ```toml
    /// name = "lenet1-g4"
    ///
    /// [layer]
    /// preset = "lenet5-conv1"   # or explicit c_in/h_in/w_in/h_k/w_k/n/s_h/s_w
    ///                           # (optional: d_h/d_w dilation, groups)
    ///
    /// [accelerator]
    /// group_size = 4            # derives nbop_PE and size_MEM per §7.1
    /// t_l = 1
    /// t_acc = 1
    /// t_w = 0
    ///
    /// [strategy]
    /// nb_data_reload = 2
    /// ```
    pub fn from_toml(text: &str) -> Result<Self, String> {
        let doc = TomlDoc::parse(text)?;
        let name = doc
            .get_str("", "name")
            .unwrap_or("unnamed-experiment")
            .to_string();

        // Checked `[section] key` integer reads. TOML integers arrive as
        // i64; a plain `as usize`/`as u64` cast would wrap a negative value
        // to a huge unsigned and sail through every later bound check, so
        // geometry and platform fields reject non-positive (or, where zero
        // is meaningful, negative) values with a structured error instead.
        let positive = |section: &str, key: &str| -> Result<Option<usize>, String> {
            match doc.get_int(section, key) {
                None => Ok(None),
                Some(v) if v >= 1 => Ok(Some(v as usize)),
                Some(v) => Err(format!(
                    "[{section}] {key}: expected a positive integer, got {v}"
                )),
            }
        };
        let non_negative = |section: &str, key: &str| -> Result<Option<u64>, String> {
            match doc.get_int(section, key) {
                None => Ok(None),
                Some(v) if v >= 0 => Ok(Some(v as u64)),
                Some(v) => Err(format!(
                    "[{section}] {key}: expected a non-negative integer, got {v}"
                )),
            }
        };

        let base = if let Some(preset) = doc.get_str("layer", "preset") {
            layer_preset(preset)
                .ok_or_else(|| format!("unknown layer preset '{preset}'"))?
                .layer
        } else {
            let g = |k: &str| -> Result<usize, String> {
                positive("layer", k)?.ok_or_else(|| format!("[layer] missing '{k}'"))
            };
            ConvLayer::new(
                g("c_in")?,
                g("h_in")?,
                g("w_in")?,
                g("h_k")?,
                g("w_k")?,
                g("n")?,
                positive("layer", "s_h")?.unwrap_or(1),
                positive("layer", "s_w")?.unwrap_or(1),
            )?
        };
        // Optional generalization keys apply to both branches, so
        // `preset = …` + `groups = …` overrides the preset instead of being
        // silently ignored (validated against the resulting geometry).
        let layer = base
            .with_dilation(
                positive("layer", "d_h")?.unwrap_or(base.d_h),
                positive("layer", "d_w")?.unwrap_or(base.d_w),
            )?
            .with_groups(positive("layer", "groups")?.unwrap_or(base.groups))?;

        let group_size = positive("accelerator", "group_size")?.unwrap_or(4);
        let mut accelerator = Accelerator::for_group_size(&layer, group_size);
        if let Some(v) = non_negative("accelerator", "t_l")? {
            accelerator.t_l = v;
        }
        if let Some(v) = non_negative("accelerator", "t_w")? {
            accelerator.t_w = v;
        }
        if let Some(v) = non_negative("accelerator", "t_acc")? {
            accelerator.t_acc = v;
        }
        if let Some(v) = positive("accelerator", "nbop_pe")? {
            accelerator.nbop_pe = v as u64;
        }
        if let Some(v) = positive("accelerator", "size_mem")? {
            accelerator.size_mem = v as u64;
        }
        if let Some(s) = doc.get_str("accelerator", "overlap") {
            accelerator.overlap = crate::platform::OverlapMode::from_str(s)?;
        }
        if let Some(v) = positive("accelerator", "dma_channels")? {
            accelerator.dma_channels = v;
        }
        if let Some(v) = positive("accelerator", "compute_units")? {
            accelerator.compute_units = v;
        }

        let nb_data_reload =
            non_negative("strategy", "nb_data_reload")?.unwrap_or(2) as u32;

        let faults = fault_model_from_doc(&doc)?;

        Ok(ExperimentConfig {
            name,
            layer,
            accelerator,
            group_size,
            nb_data_reload,
            faults,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_preset_experiment() {
        let text = r#"
name = "demo"

[layer]
preset = "example1"

[accelerator]
group_size = 2
t_w = 1
"#;
        let cfg = ExperimentConfig::from_toml(text).unwrap();
        assert_eq!(cfg.name, "demo");
        assert_eq!(cfg.layer.c_in, 2);
        assert_eq!(cfg.layer.h_in, 5);
        assert_eq!(cfg.group_size, 2);
        assert_eq!(cfg.accelerator.t_w, 1);
        assert_eq!(cfg.accelerator.max_patches_per_step(&cfg.layer), 2);
        assert_eq!(cfg.nb_data_reload, 2);
        assert_eq!(cfg.accelerator.overlap, crate::platform::OverlapMode::Sequential);
    }

    /// `[accelerator] overlap` selects the duration semantics; bad values
    /// are loud errors.
    #[test]
    fn parses_overlap_mode() {
        let text = "[layer]\npreset = \"example1\"\n[accelerator]\noverlap = \"double-buffered\"\n";
        let cfg = ExperimentConfig::from_toml(text).unwrap();
        assert_eq!(
            cfg.accelerator.overlap,
            crate::platform::OverlapMode::DoubleBuffered
        );
        let bad = text.replace("double-buffered", "triple-buffered");
        assert!(ExperimentConfig::from_toml(&bad).is_err());
    }

    /// `[accelerator] dma_channels`/`compute_units` set the §3.10 resource
    /// shape; both default to 1 and reject values below 1.
    #[test]
    fn parses_resource_shape() {
        let base = "[layer]\npreset = \"example1\"\n[accelerator]\n";
        let cfg = ExperimentConfig::from_toml(base).unwrap();
        assert_eq!(
            (cfg.accelerator.dma_channels, cfg.accelerator.compute_units),
            (1, 1)
        );
        let text = format!("{base}dma_channels = 2\ncompute_units = 3\n");
        let cfg = ExperimentConfig::from_toml(&text).unwrap();
        assert_eq!(
            (cfg.accelerator.dma_channels, cfg.accelerator.compute_units),
            (2, 3)
        );
        assert!(
            ExperimentConfig::from_toml(&format!("{base}dma_channels = 0\n")).is_err()
        );
        assert!(
            ExperimentConfig::from_toml(&format!("{base}compute_units = -1\n")).is_err()
        );
    }

    #[test]
    fn parses_explicit_layer() {
        let text = r#"
[layer]
c_in = 3
h_in = 9
w_in = 7
h_k = 3
w_k = 3
n = 4
s_h = 2

[accelerator]
group_size = 3
nbop_pe = 999
"#;
        let cfg = ExperimentConfig::from_toml(text).unwrap();
        assert_eq!(cfg.layer.c_in, 3);
        assert_eq!(cfg.layer.s_h, 2);
        assert_eq!(cfg.layer.s_w, 1);
        assert_eq!(cfg.layer.d_h, 1);
        assert_eq!(cfg.layer.groups, 1);
        assert_eq!(cfg.accelerator.nbop_pe, 999);
    }

    /// The generalization keys must not be silently ignored when a preset
    /// supplies the base geometry.
    #[test]
    fn preset_accepts_generalization_overrides() {
        let text = "[layer]\npreset = \"example1\"\ngroups = 2\n";
        let cfg = ExperimentConfig::from_toml(text).unwrap();
        assert_eq!(cfg.layer.groups, 2);
        assert_eq!(cfg.layer.c_in, 2); // preset geometry preserved
        // an invalid override is a loud error, not the dense preset
        assert!(
            ExperimentConfig::from_toml("[layer]\npreset = \"example1\"\ngroups = 3\n")
                .is_err()
        );
    }

    #[test]
    fn parses_dilation_and_groups() {
        let text = r#"
[layer]
c_in = 4
h_in = 12
w_in = 12
h_k = 3
w_k = 3
n = 4
d_h = 2
d_w = 2
groups = 4
"#;
        let cfg = ExperimentConfig::from_toml(text).unwrap();
        assert_eq!((cfg.layer.d_h, cfg.layer.d_w), (2, 2));
        assert_eq!(cfg.layer.groups, 4);
        assert_eq!(cfg.layer.h_out(), 8); // span 5 on 12
        // invalid combinations are rejected through the layer validator
        let bad = text.replace("groups = 4", "groups = 3");
        assert!(ExperimentConfig::from_toml(&bad).is_err());
    }

    #[test]
    fn rejects_bad_configs() {
        assert!(ExperimentConfig::from_toml("[layer]\npreset = \"nope\"\n").is_err());
        assert!(ExperimentConfig::from_toml("[layer]\nc_in = 1\n").is_err());
    }

    /// Regression for the negative-integer wrap: `-3 as usize` is a huge
    /// number that used to sail through every later bound check. Zero and
    /// negative geometry/platform integers must be structured errors that
    /// name the offending key — never a wrap, never a panic.
    #[test]
    fn rejects_zero_and_negative_integers_loudly() {
        let dims = "[layer]\nc_in = 1\nh_in = 8\nw_in = 8\nh_k = 3\nw_k = 3\nn = 4\n";
        for bad in [
            "[layer]\nc_in = -3\nh_in = 8\nw_in = 8\nh_k = 3\nw_k = 3\nn = 4\n",
            "[layer]\nc_in = 0\nh_in = 8\nw_in = 8\nh_k = 3\nw_k = 3\nn = 4\n",
            "[layer]\nc_in = 1\nh_in = 8\nw_in = 8\nh_k = 3\nw_k = 3\nn = -1\n",
        ] {
            let err = ExperimentConfig::from_toml(bad).unwrap_err();
            assert!(err.contains("[layer]"), "error must name the section: {err}");
        }
        for (suffix, key) in [
            ("s_h = 0\n", "s_h"),
            ("s_w = -2\n", "s_w"),
            ("d_h = 0\n", "d_h"),
            ("groups = -1\n", "groups"),
        ] {
            let err = ExperimentConfig::from_toml(&format!("{dims}{suffix}")).unwrap_err();
            assert!(err.contains(key), "error must name '{key}': {err}");
        }
        for acc in [
            "[accelerator]\ngroup_size = -4\n",
            "[accelerator]\nt_l = -1\n",
            "[accelerator]\nsize_mem = 0\n",
            "[accelerator]\nnbop_pe = -8\n",
        ] {
            let text = format!("[layer]\npreset = \"example1\"\n{acc}");
            assert!(
                ExperimentConfig::from_toml(&text).is_err(),
                "must reject: {acc}"
            );
        }
        // Zero stays legal where it is meaningful (t_w = 0 is the paper's
        // own example platform).
        let ok = ExperimentConfig::from_toml(
            "[layer]\npreset = \"example1\"\n[accelerator]\nt_w = 0\n",
        )
        .unwrap();
        assert_eq!(ok.accelerator.t_w, 0);
    }

    /// `[faults]` parses into a live model; absence means `None`; bad keys
    /// and out-of-range rates are loud errors.
    #[test]
    fn parses_faults_section() {
        let base = "[layer]\npreset = \"example1\"\n";
        assert_eq!(ExperimentConfig::from_toml(base).unwrap().faults, None);

        let text = format!(
            "{base}[faults]\nseed = 9\ndma_fail_rate = 0.1\nretry_penalty = 4\n\
             dma_jitter = 2\nt_acc_jitter = 1\nshrink_rate = 0.05\nshrink_elements = 16\n"
        );
        let cfg = ExperimentConfig::from_toml(&text).unwrap();
        let m = cfg.faults.unwrap();
        assert_eq!(m.seed, 9);
        assert_eq!(m.dma_fail_rate, 0.1);
        assert_eq!(m.max_retries, 3, "defaulted so a bare rate is live");
        assert_eq!(m.retry_penalty, 4);
        assert_eq!((m.dma_jitter, m.t_acc_jitter), (2, 1));
        assert_eq!(m.shrink_rate, 0.05);
        assert_eq!(m.shrink_elements, 16);
        assert!(m.is_active());

        assert!(ExperimentConfig::from_toml(&format!(
            "{base}[faults]\ndma_fail_rate = 1.5\n"
        ))
        .is_err());
        assert!(ExperimentConfig::from_toml(&format!(
            "{base}[faults]\nbogus = 1\n"
        ))
        .is_err());
        assert!(ExperimentConfig::from_toml(&format!(
            "{base}[faults]\nmax_retries = -2\n"
        ))
        .is_err());
    }
}

//! The crash-safe request journal: append-only JSON lines, fsync'd per
//! record, replayed on warm restart.
//!
//! Every admitted `plan` request appends a `recv` record *before* execution
//! and a `done` record after its response is complete; a crash between the
//! two leaves a recv-without-done pair that the next start replays (the
//! replay re-runs the plan, warming the strategy cache — responses went to a
//! connection that no longer exists, so the *cache effect* is what restart
//! recovers). Record grammar, one JSON object per line:
//!
//! ```text
//! {"e":"recv","id":N,"req":{...},"v":1}
//! {"e":"done","id":N,"v":1}
//! ```
//!
//! Torn-write tolerance: a malformed **last** line (the classic torn tail of
//! a crash mid-append) is dropped and counted; a malformed *interior* line
//! means the file cannot be trusted and the whole journal is quarantined
//! (renamed aside) — the server starts cold rather than replaying garbage.
//! The replay decision logic ([`replay_lines`]) is pure and mirrored
//! bit-exactly by `python/oracle_sim.py`.

use std::fs::{File, OpenOptions};
use std::io::Write;
use std::path::{Path, PathBuf};

use crate::util::fsio::atomic_write;
use crate::util::json::{self, Json};

/// Journal format version stamped on every record.
pub const JOURNAL_VERSION: u64 = 1;

/// The outcome of replaying a journal's lines.
#[derive(Debug, Clone, PartialEq)]
pub struct JournalReplay {
    /// Requests received but never completed, in receive order.
    pub pending: Vec<(u64, Json)>,
    /// True when a malformed final line (torn append) was dropped.
    pub torn_tail: bool,
    /// One past the highest request id seen (0 on an empty journal) — the
    /// restarted server continues ids from here.
    pub next_id: u64,
}

/// Replay journal lines: pair `recv` records with their `done` records and
/// return what is still pending. Pure — mirrored by the Python oracle.
///
/// Rules: blank lines are skipped; a malformed last line is dropped as a
/// torn tail; a malformed interior line is an error (the caller
/// quarantines); a duplicate `recv` id is an error; a `done` without a
/// matching `recv` is ignored (its `recv` was compacted away).
pub fn replay_lines(lines: &[&str]) -> Result<JournalReplay, String> {
    let mut pending: Vec<(u64, Json)> = Vec::new();
    let mut torn_tail = false;
    let mut next_id = 0u64;
    let last = lines.len().saturating_sub(1);
    for (i, line) in lines.iter().enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        let parsed = parse_record(line);
        let (event, id, req) = match parsed {
            Ok(rec) => rec,
            Err(e) => {
                if i == last {
                    torn_tail = true;
                    continue;
                }
                return Err(format!("journal corrupt at line {}: {e}", i + 1));
            }
        };
        next_id = next_id.max(id + 1);
        match event {
            Event::Recv => {
                if pending.iter().any(|(p, _)| *p == id) {
                    return Err(format!("journal corrupt at line {}: duplicate recv id {id}", i + 1));
                }
                pending.push((id, req.expect("recv carries req")));
            }
            Event::Done => {
                pending.retain(|(p, _)| *p != id);
            }
        }
    }
    Ok(JournalReplay { pending, torn_tail, next_id })
}

enum Event {
    Recv,
    Done,
}

fn parse_record(line: &str) -> Result<(Event, u64, Option<Json>), String> {
    let v = json::parse(line).map_err(|e| e.to_string())?;
    if v.get("v").and_then(Json::as_u64) != Some(JOURNAL_VERSION) {
        return Err("bad or missing journal version".into());
    }
    let id = v
        .get("id")
        .and_then(Json::as_u64)
        .ok_or("bad or missing record id")?;
    match v.get("e").and_then(Json::as_str) {
        Some("recv") => {
            let req = v.get("req").ok_or("recv record without req")?;
            if !matches!(req, Json::Obj(_)) {
                return Err("recv req is not an object".into());
            }
            Ok((Event::Recv, id, Some(req.clone())))
        }
        Some("done") => Ok((Event::Done, id, None)),
        _ => Err("unknown record event".into()),
    }
}

fn recv_line(id: u64, req: &Json) -> String {
    let mut o = Json::obj();
    o.set("v", JOURNAL_VERSION).set("e", "recv").set("id", id).set("req", req.clone());
    o.to_string_compact()
}

fn done_line(id: u64) -> String {
    let mut o = Json::obj();
    o.set("v", JOURNAL_VERSION).set("e", "done").set("id", id);
    o.to_string_compact()
}

/// The result of [`Journal::open`]: the writable journal plus everything the
/// replay learned.
pub struct JournalOpen {
    /// The journal, positioned for appending.
    pub journal: Journal,
    /// Requests to replay (recv without done), in receive order.
    pub pending: Vec<(u64, Json)>,
    /// A torn final line was dropped.
    pub torn_tail: bool,
    /// The previous journal was unreadable and was renamed aside.
    pub quarantined: bool,
    /// First request id the restarted server should issue.
    pub next_id: u64,
}

/// The append-only journal file.
pub struct Journal {
    path: PathBuf,
    file: File,
}

impl Journal {
    /// Open (or create) the journal at `path`, replaying whatever a prior
    /// process left behind. An unreadable journal (interior corruption) is
    /// renamed to `<path>.quarantined` and the server starts cold — losing
    /// warm state is recoverable, replaying garbage is not.
    pub fn open(path: &Path) -> Result<JournalOpen, String> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)
                .map_err(|e| format!("{}: {e}", parent.display()))?;
        }
        let text = match std::fs::read_to_string(path) {
            Ok(t) => t,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => String::new(),
            Err(e) => return Err(format!("{}: {e}", path.display())),
        };
        let lines: Vec<&str> = if text.is_empty() { Vec::new() } else { text.lines().collect() };
        let (replay, quarantined) = match replay_lines(&lines) {
            Ok(r) => (r, false),
            Err(_) => {
                let aside = path.with_extension("quarantined");
                std::fs::rename(path, &aside)
                    .map_err(|e| format!("quarantine {}: {e}", path.display()))?;
                (JournalReplay { pending: Vec::new(), torn_tail: false, next_id: 0 }, true)
            }
        };
        let file = OpenOptions::new()
            .create(true)
            .append(true)
            .open(path)
            .map_err(|e| format!("{}: {e}", path.display()))?;
        Ok(JournalOpen {
            journal: Journal { path: path.to_path_buf(), file },
            pending: replay.pending,
            torn_tail: replay.torn_tail,
            quarantined,
            next_id: replay.next_id,
        })
    }

    /// Append a `recv` record and fsync it — once this returns, a crash
    /// before the matching [`record_done`](Self::record_done) will replay
    /// the request.
    pub fn record_recv(&mut self, id: u64, req: &Json) -> Result<(), String> {
        self.append(&recv_line(id, req))
    }

    /// Append a `done` record and fsync it.
    pub fn record_done(&mut self, id: u64) -> Result<(), String> {
        self.append(&done_line(id))
    }

    fn append(&mut self, line: &str) -> Result<(), String> {
        self.file
            .write_all(format!("{line}\n").as_bytes())
            .and_then(|()| self.file.sync_data())
            .map_err(|e| format!("{}: {e}", self.path.display()))
    }

    /// Rewrite the journal to hold exactly `pending` (as fresh `recv`
    /// records), dropping completed pairs. Atomic (temp + rename + dir
    /// fsync); run after replay and on clean shutdown so the journal stays
    /// proportional to in-flight work, not to history.
    pub fn compact(&mut self, pending: &[(u64, Json)]) -> Result<(), String> {
        let mut text = String::new();
        for (id, req) in pending {
            text.push_str(&recv_line(*id, req));
            text.push('\n');
        }
        atomic_write(&self.path, &text)?;
        self.file = OpenOptions::new()
            .append(true)
            .open(&self.path)
            .map_err(|e| format!("{}: {e}", self.path.display()))?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "convoffload-journal-{tag}-{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        dir.join("journal.jsonl")
    }

    fn req(n: u64) -> Json {
        let mut o = Json::obj();
        o.set("op", "plan").set("n", n);
        o
    }

    #[test]
    fn replay_pairs_recv_with_done() {
        let l1 = recv_line(0, &req(0));
        let l2 = recv_line(1, &req(1));
        let l3 = done_line(0);
        let lines = [l1.as_str(), l2.as_str(), l3.as_str()];
        let r = replay_lines(&lines).unwrap();
        assert_eq!(r.pending.len(), 1);
        assert_eq!(r.pending[0].0, 1);
        assert!(!r.torn_tail);
        assert_eq!(r.next_id, 2);
    }

    #[test]
    fn torn_tail_is_dropped_but_interior_corruption_is_fatal() {
        let l1 = recv_line(3, &req(3));
        let torn = [l1.as_str(), r#"{"v":1,"e":"recv","id":4,"req":{"op""#];
        let r = replay_lines(&torn).unwrap();
        assert!(r.torn_tail);
        assert_eq!(r.pending.len(), 1, "only the intact record replays");
        assert_eq!(r.next_id, 4);

        let interior = ["garbage", l1.as_str()];
        let err = replay_lines(&interior).unwrap_err();
        assert!(err.contains("line 1"), "{err}");

        let dup = [l1.as_str(), l1.as_str(), done_line(9).as_str()];
        assert!(replay_lines(&dup).unwrap_err().contains("duplicate"));

        // a done whose recv was compacted away is harmless
        let orphan_done = [done_line(7).as_str()];
        let r = replay_lines(&orphan_done).unwrap();
        assert!(r.pending.is_empty());
        assert_eq!(r.next_id, 8);
    }

    #[test]
    fn journal_survives_reopen_and_compacts() {
        let path = tmp("roundtrip");
        let mut open = Journal::open(&path).unwrap();
        assert_eq!(open.next_id, 0);
        assert!(open.pending.is_empty());
        open.journal.record_recv(0, &req(0)).unwrap();
        open.journal.record_done(0).unwrap();
        open.journal.record_recv(1, &req(1)).unwrap();
        drop(open);

        let mut again = Journal::open(&path).unwrap();
        assert_eq!(again.next_id, 2);
        assert_eq!(again.pending.len(), 1, "request 1 was in flight");
        assert_eq!(again.pending[0].0, 1);
        assert!(!again.quarantined);

        let pending = again.pending.clone();
        again.journal.compact(&pending).unwrap();
        again.journal.record_done(1).unwrap();
        drop(again);

        let clean = Journal::open(&path).unwrap();
        assert!(clean.pending.is_empty());
        let _ = std::fs::remove_dir_all(path.parent().unwrap());
    }

    #[test]
    fn corrupt_journal_is_quarantined_not_replayed() {
        let path = tmp("quarantine");
        std::fs::create_dir_all(path.parent().unwrap()).unwrap();
        std::fs::write(&path, format!("not json at all\n{}\n", recv_line(5, &req(5)))).unwrap();
        let open = Journal::open(&path).unwrap();
        assert!(open.quarantined);
        assert!(open.pending.is_empty(), "cold start, no garbage replay");
        assert_eq!(open.next_id, 0);
        assert!(path.with_extension("quarantined").exists());
        let _ = std::fs::remove_dir_all(path.parent().unwrap());
    }

    #[test]
    fn torn_tail_file_replays_the_intact_prefix() {
        let path = tmp("torn");
        std::fs::create_dir_all(path.parent().unwrap()).unwrap();
        // a crash mid-append: intact recv, then half a record with no newline
        std::fs::write(
            &path,
            format!("{}\n{}", recv_line(2, &req(2)), r#"{"v":1,"e":"do"#),
        )
        .unwrap();
        let open = Journal::open(&path).unwrap();
        assert!(!open.quarantined);
        assert!(open.torn_tail);
        assert_eq!(open.pending.len(), 1);
        assert_eq!(open.pending[0].0, 2);
        let _ = std::fs::remove_dir_all(path.parent().unwrap());
    }
}

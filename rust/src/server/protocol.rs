//! The plan-server wire protocol: line-delimited JSON, strictly validated.
//!
//! One request per line, one response per line. Every request is an object
//! with an `"op"` field; every response is an object with `"ok"`. Errors
//! carry a machine-readable kind:
//!
//! ```text
//! -> {"op":"plan","networks":["lenet5","resnet8"],"deadline_ms":500}
//! <- {"ok":true,"report":{...},"degraded":{"cause":"load","rung":"reduced"}}
//! -> {"op":"nope"}
//! <- {"ok":false,"error":{"kind":"malformed","message":"unknown op 'nope'"}}
//! ```
//!
//! Validation is strict and happens **before** admission: unknown ops,
//! unknown preset names, non-builtin strategies, and zero/absurd integers
//! are all `malformed` — a request that is admitted can always be executed.
//! The same preset/strategy validators back the CLI (`util::cli` callers),
//! so a name the CLI rejects is rejected here with the same message.

use crate::config::{layer_preset, network_preset};
use crate::util::json::{self, Json};

/// Machine-readable error class of a failed request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ErrorKind {
    /// The request line is not valid JSON or fails schema validation.
    Malformed,
    /// The request line exceeds the configured size bound.
    TooLarge,
    /// The server shed the request (queue full, or cache-only rung missed).
    Overloaded,
    /// The server failed while executing a valid request.
    Internal,
}

impl ErrorKind {
    /// Stable wire name.
    pub fn as_str(self) -> &'static str {
        match self {
            ErrorKind::Malformed => "malformed",
            ErrorKind::TooLarge => "too-large",
            ErrorKind::Overloaded => "overloaded",
            ErrorKind::Internal => "internal",
        }
    }
}

/// A rejected request: its class plus a human-readable message.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ProtoError {
    /// Machine-readable class.
    pub kind: ErrorKind,
    /// Human-readable detail.
    pub message: String,
}

impl ProtoError {
    /// A `malformed` error.
    pub fn malformed(message: impl Into<String>) -> Self {
        ProtoError { kind: ErrorKind::Malformed, message: message.into() }
    }
}

/// A validated request — everything in here is guaranteed executable.
#[derive(Debug, Clone, PartialEq)]
pub enum Request {
    /// Plan a batch of networks, optionally under a deadline.
    Plan {
        /// Network preset names (validated against the preset table).
        networks: Vec<String>,
        /// Time budget in milliseconds; `None` means no deadline.
        deadline_ms: Option<u64>,
    },
    /// Simulate one builtin strategy on one layer preset.
    Simulate {
        /// Layer preset name (validated).
        layer: String,
        /// Builtin strategy name (validated; file paths are refused — the
        /// server never reads client-named paths).
        strategy: String,
        /// Group-size bound (≥ 1).
        group: usize,
        /// Images streamed through the strategy (≥ 1).
        batch: usize,
    },
    /// Liveness probe.
    Health,
    /// Counter snapshot.
    Stats,
    /// Graceful shutdown (flush cache, compact journal, exit).
    Shutdown,
}

/// The builtin strategy names `simulate` accepts over the wire (the CLI's
/// set minus file paths).
pub const WIRE_STRATEGIES: [&str; 6] =
    ["s1-baseline", "row-by-row", "row", "zigzag", "hilbert", "diagonal"];

/// Parse and validate one request line.
pub fn parse_request(line: &str) -> Result<Request, ProtoError> {
    let v = json::parse(line)
        .map_err(|e| ProtoError::malformed(format!("invalid JSON: {e}")))?;
    request_from_json(&v)
}

/// Validate an already-parsed request object (the journal replay path).
pub fn request_from_json(v: &Json) -> Result<Request, ProtoError> {
    if !matches!(v, Json::Obj(_)) {
        return Err(ProtoError::malformed("request must be a JSON object"));
    }
    let op = v
        .get("op")
        .and_then(Json::as_str)
        .ok_or_else(|| ProtoError::malformed("missing string field 'op'"))?;
    match op {
        "plan" => {
            let arr = v
                .get("networks")
                .and_then(Json::as_arr)
                .ok_or_else(|| ProtoError::malformed("'plan' needs an array field 'networks'"))?;
            if arr.is_empty() {
                return Err(ProtoError::malformed("'networks' must not be empty"));
            }
            let mut networks = Vec::with_capacity(arr.len());
            for n in arr {
                let name = n
                    .as_str()
                    .ok_or_else(|| ProtoError::malformed("'networks' entries must be strings"))?;
                if network_preset(name).is_none() {
                    return Err(ProtoError::malformed(format!(
                        "unknown network preset '{name}' (see `convoffload presets`)"
                    )));
                }
                networks.push(name.to_string());
            }
            let deadline_ms = match v.get("deadline_ms") {
                None | Some(Json::Null) => None,
                Some(d) => Some(d.as_u64().ok_or_else(|| {
                    ProtoError::malformed("'deadline_ms' must be a non-negative integer")
                })?),
            };
            Ok(Request::Plan { networks, deadline_ms })
        }
        "simulate" => {
            let layer = v
                .get("layer")
                .and_then(Json::as_str)
                .ok_or_else(|| ProtoError::malformed("'simulate' needs a string field 'layer'"))?;
            if layer_preset(layer).is_none() {
                return Err(ProtoError::malformed(format!(
                    "unknown preset '{layer}' (see `convoffload presets`)"
                )));
            }
            let strategy = v
                .get("strategy")
                .and_then(Json::as_str)
                .unwrap_or("zigzag");
            if !WIRE_STRATEGIES.contains(&strategy) {
                return Err(ProtoError::malformed(format!(
                    "unknown strategy '{strategy}' (wire accepts: {})",
                    WIRE_STRATEGIES.join(", ")
                )));
            }
            let group = positive_usize(v, "group", 2)?;
            let batch = positive_usize(v, "batch", 1)?;
            Ok(Request::Simulate {
                layer: layer.to_string(),
                strategy: strategy.to_string(),
                group,
                batch,
            })
        }
        "health" => Ok(Request::Health),
        "stats" => Ok(Request::Stats),
        "shutdown" => Ok(Request::Shutdown),
        other => Err(ProtoError::malformed(format!("unknown op '{other}'"))),
    }
}

fn positive_usize(v: &Json, field: &str, default: usize) -> Result<usize, ProtoError> {
    match v.get(field) {
        None | Some(Json::Null) => Ok(default),
        Some(n) => match n.as_usize() {
            Some(u) if u >= 1 => Ok(u),
            _ => Err(ProtoError::malformed(format!(
                "'{field}' must be a positive integer"
            ))),
        },
    }
}

/// Serialize a request back to its canonical JSON object — the journal
/// records this form, so replay goes through [`request_from_json`] and a
/// journaled request round-trips exactly.
pub fn request_to_json(req: &Request) -> Json {
    let mut o = Json::obj();
    match req {
        Request::Plan { networks, deadline_ms } => {
            o.set("op", "plan").set(
                "networks",
                Json::Arr(networks.iter().map(|n| Json::Str(n.clone())).collect()),
            );
            if let Some(ms) = deadline_ms {
                o.set("deadline_ms", *ms);
            }
        }
        Request::Simulate { layer, strategy, group, batch } => {
            o.set("op", "simulate")
                .set("layer", layer.as_str())
                .set("strategy", strategy.as_str())
                .set("group", *group)
                .set("batch", *batch);
        }
        Request::Health => {
            o.set("op", "health");
        }
        Request::Stats => {
            o.set("op", "stats");
        }
        Request::Shutdown => {
            o.set("op", "shutdown");
        }
    }
    o
}

/// Render an error response line.
pub fn error_line(kind: ErrorKind, message: &str) -> String {
    let mut err = Json::obj();
    err.set("kind", kind.as_str()).set("message", message);
    let mut o = Json::obj();
    o.set("ok", false).set("error", err);
    o.to_string_compact()
}

/// Render a success response line: `{"ok":true, ...body fields...}`.
pub fn ok_line(body: Json) -> String {
    let mut o = body;
    o.set("ok", true);
    o.to_string_compact()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn valid_requests_parse_and_round_trip() {
        let cases = [
            r#"{"op":"plan","networks":["lenet5","resnet8"]}"#,
            r#"{"op":"plan","networks":["mobilenet_slim"],"deadline_ms":500}"#,
            r#"{"op":"simulate","layer":"example1","strategy":"zigzag","group":2,"batch":4}"#,
            r#"{"op":"health"}"#,
            r#"{"op":"stats"}"#,
            r#"{"op":"shutdown"}"#,
        ];
        for line in cases {
            let req = parse_request(line).unwrap_or_else(|e| panic!("{line}: {e:?}"));
            let back = request_from_json(&request_to_json(&req)).unwrap();
            assert_eq!(back, req, "journal round-trip must be exact: {line}");
        }
    }

    #[test]
    fn simulate_defaults_are_filled_in() {
        let req = parse_request(r#"{"op":"simulate","layer":"example1"}"#).unwrap();
        assert_eq!(
            req,
            Request::Simulate {
                layer: "example1".into(),
                strategy: "zigzag".into(),
                group: 2,
                batch: 1,
            }
        );
    }

    /// The malformed-input regression table: every rejected shape, with its
    /// error kind pinned. Shared intent with the CLI validation tests —
    /// same unknown-preset message text.
    #[test]
    fn malformed_requests_are_rejected_with_reasons() {
        let cases: [(&str, &str); 10] = [
            ("not json at all", "invalid JSON"),
            (r#"[1,2,3]"#, "must be a JSON object"),
            (r#"{"networks":["lenet5"]}"#, "missing string field 'op'"),
            (r#"{"op":"conquer"}"#, "unknown op 'conquer'"),
            (r#"{"op":"plan"}"#, "needs an array field 'networks'"),
            (r#"{"op":"plan","networks":[]}"#, "must not be empty"),
            (r#"{"op":"plan","networks":["vgg99"]}"#, "unknown network preset 'vgg99'"),
            (r#"{"op":"plan","networks":["lenet5"],"deadline_ms":-5}"#, "non-negative integer"),
            (r#"{"op":"simulate","layer":"example1","strategy":"../../etc/passwd"}"#, "unknown strategy"),
            (r#"{"op":"simulate","layer":"example1","group":0}"#, "positive integer"),
        ];
        for (line, want) in cases {
            let err = parse_request(line).expect_err(line);
            assert_eq!(err.kind, ErrorKind::Malformed, "{line}");
            assert!(err.message.contains(want), "{line}: got '{}'", err.message);
        }
    }

    #[test]
    fn response_lines_have_the_documented_shape() {
        let e = error_line(ErrorKind::Overloaded, "queue full");
        assert_eq!(
            e,
            r#"{"error":{"kind":"overloaded","message":"queue full"},"ok":false}"#
        );
        let mut body = Json::obj();
        body.set("alive", true);
        assert_eq!(ok_line(body), r#"{"alive":true,"ok":true}"#);
    }
}

//! Admission control: the bounded request queue and the graceful-degradation
//! ladder.
//!
//! Both halves are deliberately small and deterministic. The queue is a
//! mutex-guarded ring with a hard capacity — `try_enqueue` never blocks and
//! never grows the queue past its bound, so an overloaded server says
//! `overloaded` instead of accumulating unbounded latency. The ladder is a
//! **pure function** from measured pressure (queue backlog, per-request time
//! budget) to a portfolio effort level; it is mirrored bit-exactly by the
//! Python oracle (`oracle_sim.select_rung`), so the server's load-shedding
//! decisions are cross-checkable without a Rust toolchain.

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};

/// One level of the graceful-degradation ladder, ordered from full effort to
/// cache-only. `Ord` follows degradation: `Full < Reduced < Heuristic <
/// CacheOnly`, so combining two pressure signals is `max`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Rung {
    /// The full configured portfolio (all anneal lanes, full budget).
    Full,
    /// One annealing lane at a quarter of the budget.
    Reduced,
    /// Heuristic lanes only (orderings + greedy, zero annealing).
    Heuristic,
    /// No race at all: serve only if every stage hits the cache at the
    /// originally-requested key, else reject `overloaded`.
    CacheOnly,
}

impl Rung {
    /// Stable wire name (used in `degraded` response tags).
    pub fn as_str(self) -> &'static str {
        match self {
            Rung::Full => "full",
            Rung::Reduced => "reduced",
            Rung::Heuristic => "heuristic",
            Rung::CacheOnly => "cache-only",
        }
    }
}

/// Select the ladder rung for a request, from the measured queue backlog and
/// the request's remaining time budget.
///
/// Queue pressure: an idle queue runs the full portfolio; a backlog at or
/// below half capacity drops to one reduced anneal lane; below capacity,
/// heuristics only; at capacity, cache-only. Budget pressure: no deadline or
/// ≥ 1 s runs full; ≥ 100 ms reduced; ≥ 10 ms heuristics; under 10 ms
/// cache-only. The final rung is the **more degraded** of the two signals.
///
/// Pure and total — mirrored bit-exactly by `python/oracle_sim.py`.
pub fn select_rung(
    queue_depth: usize,
    queue_capacity: usize,
    budget_ms: Option<u64>,
) -> Rung {
    let by_queue = if queue_depth == 0 {
        Rung::Full
    } else if queue_depth * 2 <= queue_capacity {
        Rung::Reduced
    } else if queue_depth < queue_capacity {
        Rung::Heuristic
    } else {
        Rung::CacheOnly
    };
    let by_budget = match budget_ms {
        None => Rung::Full,
        Some(ms) if ms >= 1_000 => Rung::Full,
        Some(ms) if ms >= 100 => Rung::Reduced,
        Some(ms) if ms >= 10 => Rung::Heuristic,
        Some(_) => Rung::CacheOnly,
    };
    by_queue.max(by_budget)
}

/// The portfolio budget a rung runs: `Some((anneal_starts, anneal_iters))`
/// for the racing rungs, `None` for [`Rung::CacheOnly`] (no race is
/// admitted at all). Pure — mirrored by `python/oracle_sim.py`.
pub fn rung_budgets(rung: Rung, starts: usize, iters: u64) -> Option<(usize, u64)> {
    match rung {
        Rung::Full => Some((starts, iters)),
        Rung::Reduced => Some((1, iters / 4)),
        Rung::Heuristic => Some((0, 0)),
        Rung::CacheOnly => None,
    }
}

struct QueueState<T> {
    items: VecDeque<T>,
    closed: bool,
    /// While set, [`AdmissionQueue::dequeue`] withholds items (admission
    /// still runs) — the deterministic backlog hook for overload tests.
    /// Ignored once the queue is closed, so shutdown always drains.
    paused: bool,
}

/// A bounded MPSC request queue: producers `try_enqueue` (never blocking,
/// rejecting at capacity), the single worker blocks on [`dequeue`].
///
/// [`dequeue`]: AdmissionQueue::dequeue
pub struct AdmissionQueue<T> {
    state: Mutex<QueueState<T>>,
    ready: Condvar,
    capacity: usize,
}

impl<T> AdmissionQueue<T> {
    /// An empty queue holding at most `capacity` requests (clamped ≥ 1).
    pub fn new(capacity: usize) -> Self {
        AdmissionQueue {
            state: Mutex::new(QueueState {
                items: VecDeque::new(),
                closed: false,
                paused: false,
            }),
            ready: Condvar::new(),
            capacity: capacity.max(1),
        }
    }

    /// The hard capacity.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Requests currently waiting.
    pub fn depth(&self) -> usize {
        self.state.lock().map(|s| s.items.len()).unwrap_or(0)
    }

    /// Admit a request, or hand it back when the queue is full or closed.
    pub fn try_enqueue(&self, item: T) -> Result<(), T> {
        let mut s = match self.state.lock() {
            Ok(s) => s,
            Err(_) => return Err(item),
        };
        if s.closed || s.items.len() >= self.capacity {
            return Err(item);
        }
        s.items.push_back(item);
        drop(s);
        self.ready.notify_one();
        Ok(())
    }

    /// Block until a request is available (FIFO) or the queue is closed and
    /// drained; `None` means "no more work, ever". While paused (and not
    /// closed), items are withheld even when present.
    pub fn dequeue(&self) -> Option<T> {
        let mut s = self.state.lock().ok()?;
        loop {
            if s.closed || !s.paused {
                if let Some(item) = s.items.pop_front() {
                    return Some(item);
                }
                if s.closed {
                    return None;
                }
            }
            s = self.ready.wait(s).ok()?;
        }
    }

    /// Withhold items from [`dequeue`](Self::dequeue) while still admitting
    /// — backlog builds deterministically (overload tests; a real operator
    /// pausing a worker for maintenance).
    pub fn pause(&self) {
        if let Ok(mut s) = self.state.lock() {
            s.paused = true;
        }
        self.ready.notify_all();
    }

    /// Release a [`pause`](Self::pause).
    pub fn resume(&self) {
        if let Ok(mut s) = self.state.lock() {
            s.paused = false;
        }
        self.ready.notify_all();
    }

    /// Close the queue: no further admissions; the worker drains what is
    /// left (a pause no longer withholds) and then sees `None`.
    pub fn close(&self) {
        if let Ok(mut s) = self.state.lock() {
            s.closed = true;
        }
        self.ready.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The full decision table of the ladder — every threshold edge, both
    /// pressure signals, and the max-combination. Pinned against the Python
    /// oracle mirror (`test_server_oracle.py` pins the same table).
    #[test]
    fn rung_decision_table_is_pinned() {
        // queue pressure alone (no deadline)
        assert_eq!(select_rung(0, 16, None), Rung::Full);
        assert_eq!(select_rung(1, 16, None), Rung::Reduced);
        assert_eq!(select_rung(8, 16, None), Rung::Reduced);
        assert_eq!(select_rung(9, 16, None), Rung::Heuristic);
        assert_eq!(select_rung(15, 16, None), Rung::Heuristic);
        assert_eq!(select_rung(16, 16, None), Rung::CacheOnly);
        assert_eq!(select_rung(40, 16, None), Rung::CacheOnly);
        // budget pressure alone (idle queue)
        assert_eq!(select_rung(0, 16, Some(5_000)), Rung::Full);
        assert_eq!(select_rung(0, 16, Some(1_000)), Rung::Full);
        assert_eq!(select_rung(0, 16, Some(999)), Rung::Reduced);
        assert_eq!(select_rung(0, 16, Some(100)), Rung::Reduced);
        assert_eq!(select_rung(0, 16, Some(99)), Rung::Heuristic);
        assert_eq!(select_rung(0, 16, Some(10)), Rung::Heuristic);
        assert_eq!(select_rung(0, 16, Some(9)), Rung::CacheOnly);
        assert_eq!(select_rung(0, 16, Some(0)), Rung::CacheOnly);
        // combination: the more degraded signal wins
        assert_eq!(select_rung(8, 16, Some(5)), Rung::CacheOnly);
        assert_eq!(select_rung(16, 16, Some(5_000)), Rung::CacheOnly);
        assert_eq!(select_rung(1, 16, Some(50)), Rung::Heuristic);
        // tiny capacity: any backlog is already at capacity
        assert_eq!(select_rung(1, 1, None), Rung::CacheOnly);
    }

    #[test]
    fn rung_budgets_are_pinned() {
        assert_eq!(rung_budgets(Rung::Full, 3, 50_000), Some((3, 50_000)));
        assert_eq!(rung_budgets(Rung::Reduced, 3, 50_000), Some((1, 12_500)));
        assert_eq!(rung_budgets(Rung::Heuristic, 3, 50_000), Some((0, 0)));
        assert_eq!(rung_budgets(Rung::CacheOnly, 3, 50_000), None);
    }

    #[test]
    fn queue_admits_to_capacity_and_rejects_past_it() {
        let q = AdmissionQueue::new(2);
        assert_eq!(q.capacity(), 2);
        assert!(q.try_enqueue(1).is_ok());
        assert!(q.try_enqueue(2).is_ok());
        assert_eq!(q.try_enqueue(3), Err(3), "full queue hands the item back");
        assert_eq!(q.depth(), 2);
        assert_eq!(q.dequeue(), Some(1), "FIFO");
        assert!(q.try_enqueue(4).is_ok(), "freed slot re-admits");
        assert_eq!(q.dequeue(), Some(2));
        assert_eq!(q.dequeue(), Some(4));
    }

    #[test]
    fn closed_queue_drains_then_ends() {
        let q = AdmissionQueue::new(4);
        q.try_enqueue(1).unwrap();
        q.close();
        assert_eq!(q.try_enqueue(2), Err(2), "closed queue admits nothing");
        assert_eq!(q.dequeue(), Some(1), "but drains what it holds");
        assert_eq!(q.dequeue(), None);
    }

    #[test]
    fn paused_queue_admits_but_withholds_until_resume() {
        let q = std::sync::Arc::new(AdmissionQueue::new(2));
        q.pause();
        q.try_enqueue(1).unwrap();
        q.try_enqueue(2).unwrap();
        assert_eq!(q.try_enqueue(3), Err(3), "capacity still enforced");
        assert_eq!(q.depth(), 2, "admission unaffected by pause");
        let q2 = std::sync::Arc::clone(&q);
        let t = std::thread::spawn(move || q2.dequeue());
        std::thread::sleep(std::time::Duration::from_millis(30));
        assert_eq!(q.depth(), 2, "paused dequeue must withhold");
        q.resume();
        assert_eq!(t.join().unwrap(), Some(1));
        // close overrides pause: shutdown always drains
        q.pause();
        q.close();
        assert_eq!(q.dequeue(), Some(2));
        assert_eq!(q.dequeue(), None);
    }

    #[test]
    fn dequeue_blocks_until_an_item_arrives() {
        let q = std::sync::Arc::new(AdmissionQueue::new(1));
        let q2 = std::sync::Arc::clone(&q);
        let t = std::thread::spawn(move || q2.dequeue());
        std::thread::sleep(std::time::Duration::from_millis(20));
        q.try_enqueue(7usize).unwrap();
        assert_eq!(t.join().unwrap(), Some(7));
    }
}

//! The hardened plan-server: a long-lived planning service over TCP.
//!
//! `convoffload plan-server` keeps **one** warm [`ShardedStrategyCache`]
//! across requests, so a fleet of clients shares every strategy any of them
//! ever raced. The protocol is line-delimited JSON
//! ([`protocol`]); robustness is layered:
//!
//! - **Admission control** ([`admission`]): a bounded queue that rejects
//!   with an explicit `overloaded` error instead of queueing unbounded
//!   latency; per-connection read timeouts and a max request size, so a
//!   stalled or hostile client cannot wedge the acceptor.
//! - **Deadlines** ([`deadline`]): a per-request time budget becomes a
//!   cooperative cancel flag threaded down to the annealing inner loops
//!   ([`BatchPlanner::plan_batch_cancellable`]); an expired request returns
//!   best-so-far, tagged `degraded`.
//! - **Load shedding** ([`admission::select_rung`]): measured queue depth
//!   and the request's budget select a rung of the degradation ladder
//!   (full portfolio → one reduced anneal lane → heuristics only →
//!   cache-only), so the server sheds *effort* before it sheds requests.
//! - **Crash safety** ([`journal`]): every admitted request is journaled
//!   (fsync before execution); a restart replays requests that were in
//!   flight when the process died — re-warming the cache they would have
//!   filled — and reopens the shards warm.
//!
//! Zero-pressure identity: a `plan` with no deadline on an idle queue runs
//! the **exact** batch the `plan-batch` CLI runs — same options, same cache
//! keys, bit-identical report.
//!
//! Threading: connection threads validate, journal and enqueue; **one**
//! worker executes requests serially (determinism needs no further
//! argument: one warm planner, FIFO order); `health`/`stats`/`shutdown`
//! are answered inline so they work even when the queue is full.

pub mod admission;
pub mod deadline;
pub mod journal;
pub mod protocol;

use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use crate::config::{layer_preset, network_preset, NetworkPreset};
use crate::metrics::ServerCounters;
use crate::planner::{
    batch_to_json, BatchPlanner, PlanOptions, ShardedStrategyCache, DEFAULT_SHARD_CAPACITY,
};
use crate::platform::{Accelerator, Platform};
use crate::sim::Simulator;
use crate::strategy;
use crate::util::json::Json;

use admission::{rung_budgets, select_rung, AdmissionQueue, Rung};
use deadline::DeadlineWatcher;
use journal::Journal;
use protocol::{
    error_line, ok_line, parse_request, request_from_json, request_to_json, ErrorKind, Request,
};

/// Server configuration (the `plan-server` CLI flags).
#[derive(Debug, Clone)]
pub struct ServerConfig {
    /// Bind address; port 0 picks a free port (tests).
    pub addr: String,
    /// Bounded request-queue capacity.
    pub queue_capacity: usize,
    /// Maximum request line size in bytes.
    pub max_request_bytes: usize,
    /// Per-connection read/idle timeout in milliseconds.
    pub read_timeout_ms: u64,
    /// Directory holding the journal and the sharded strategy cache.
    pub state_dir: PathBuf,
    /// Shard count for the strategy cache.
    pub shards: usize,
    /// Planner options (the zero-pressure request runs exactly these).
    pub options: PlanOptions,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            addr: "127.0.0.1:7461".into(),
            queue_capacity: 16,
            max_request_bytes: 64 * 1024,
            read_timeout_ms: 5_000,
            state_dir: PathBuf::from(".plan-server"),
            shards: crate::planner::DEFAULT_SHARDS,
            options: PlanOptions::default(),
        }
    }
}

/// One admitted unit of work: journaled id, validated request, reply slot.
struct Job {
    id: u64,
    request: Request,
    reply: mpsc::Sender<String>,
}

/// Everything the threads share.
struct Shared {
    config: ServerConfig,
    queue: AdmissionQueue<Job>,
    journal: Mutex<Journal>,
    next_id: AtomicU64,
    counters: ServerCounters,
    stopping: AtomicBool,
}

/// The running server.
pub struct PlanServer;

/// Handle to a started server: address, lifecycle, test hooks.
pub struct Handle {
    /// The bound address (resolves port 0).
    pub local_addr: SocketAddr,
    shared: Arc<Shared>,
    acceptor: JoinHandle<()>,
    worker: JoinHandle<()>,
}

impl Handle {
    /// Withhold queued work from the worker while still admitting — backlog
    /// builds deterministically (overload tests, operator maintenance).
    pub fn pause(&self) {
        self.shared.queue.pause();
    }

    /// Release a [`pause`](Self::pause).
    pub fn resume(&self) {
        self.shared.queue.resume();
    }

    /// Request shutdown from outside the protocol (Ctrl-C path).
    pub fn shutdown(&self) {
        initiate_shutdown(&self.shared, self.local_addr);
    }

    /// Block until the acceptor and worker exit (clean shutdown: cache
    /// flushed, journal compacted).
    pub fn wait(self) {
        let _ = self.acceptor.join();
        let _ = self.worker.join();
    }
}

fn initiate_shutdown(shared: &Arc<Shared>, addr: SocketAddr) {
    shared.stopping.store(true, Ordering::SeqCst);
    // Close overrides any pause: the worker drains and exits.
    shared.queue.close();
    // The acceptor blocks in `accept`; a throwaway connection wakes it so
    // it can observe `stopping` and exit.
    let _ = TcpStream::connect(addr);
}

impl PlanServer {
    /// Start the server: open (and replay) the journal, reopen the cache
    /// warm, bind the listener, spawn acceptor + worker.
    pub fn start(config: ServerConfig) -> Result<Handle, String> {
        std::fs::create_dir_all(&config.state_dir)
            .map_err(|e| format!("{}: {e}", config.state_dir.display()))?;
        let opened = Journal::open(&config.state_dir.join("journal.jsonl"))?;
        let cache = ShardedStrategyCache::open_with(
            &config.state_dir.join("cache"),
            config.shards,
            DEFAULT_SHARD_CAPACITY,
        )?;
        cache.warm_load();
        let planner = BatchPlanner::with_cache(config.options.clone(), cache);
        let counters = ServerCounters::new();

        // Replay before accepting traffic: requests that were in flight at
        // the crash re-run at full effort (their responses have no reader —
        // the *cache fill* is what restart recovers), then the journal is
        // compacted to empty.
        let mut journal = opened.journal;
        for (_id, req_json) in &opened.pending {
            if let Ok(req) = request_from_json(req_json) {
                counters.journal_replayed.fetch_add(1, Ordering::Relaxed);
                let _ = execute(&planner, &config, &req, Rung::Full, None);
            }
        }
        journal.compact(&[])?;

        let listener = TcpListener::bind(&config.addr)
            .map_err(|e| format!("bind {}: {e}", config.addr))?;
        let local_addr = listener
            .local_addr()
            .map_err(|e| format!("local_addr: {e}"))?;

        let queue_capacity = config.queue_capacity;
        let shared = Arc::new(Shared {
            config,
            queue: AdmissionQueue::new(queue_capacity),
            journal: Mutex::new(journal),
            next_id: AtomicU64::new(opened.next_id),
            counters,
            stopping: AtomicBool::new(false),
        });

        let worker = {
            let shared = Arc::clone(&shared);
            std::thread::Builder::new()
                .name("plan-worker".into())
                .spawn(move || worker_loop(&shared, planner))
                .map_err(|e| format!("spawn worker: {e}"))?
        };
        let acceptor = {
            let shared = Arc::clone(&shared);
            std::thread::Builder::new()
                .name("plan-acceptor".into())
                .spawn(move || acceptor_loop(&shared, &listener, local_addr))
                .map_err(|e| format!("spawn acceptor: {e}"))?
        };
        Ok(Handle { local_addr, shared, acceptor, worker })
    }
}

// ------------------------------------------------------------- acceptor

fn acceptor_loop(shared: &Arc<Shared>, listener: &TcpListener, local_addr: SocketAddr) {
    for stream in listener.incoming() {
        if shared.stopping.load(Ordering::SeqCst) {
            return;
        }
        let Ok(stream) = stream else { continue };
        let shared = Arc::clone(shared);
        let _ = std::thread::Builder::new()
            .name("plan-conn".into())
            .spawn(move || connection_loop(&shared, stream, local_addr));
    }
}

enum ReadOutcome {
    Line(String),
    Eof,
    TooLarge,
    Err,
}

/// Read one `\n`-terminated line without ever buffering more than `max`
/// bytes — an unbounded line is reported as [`ReadOutcome::TooLarge`]
/// instead of exhausting memory.
fn read_line_limited(reader: &mut BufReader<TcpStream>, max: usize) -> ReadOutcome {
    let mut line = Vec::new();
    loop {
        let available = match reader.fill_buf() {
            Ok(b) => b,
            Err(_) => return ReadOutcome::Err, // timeout or reset
        };
        if available.is_empty() {
            return if line.is_empty() {
                ReadOutcome::Eof
            } else {
                // final line without a newline: still a request
                match String::from_utf8(line) {
                    Ok(s) => ReadOutcome::Line(s),
                    Err(_) => ReadOutcome::Err,
                }
            };
        }
        match available.iter().position(|&b| b == b'\n') {
            Some(pos) => {
                if line.len() + pos > max {
                    reader.consume(pos + 1);
                    return ReadOutcome::TooLarge;
                }
                line.extend_from_slice(&available[..pos]);
                reader.consume(pos + 1);
                if line.last() == Some(&b'\r') {
                    line.pop();
                }
                return match String::from_utf8(line) {
                    Ok(s) => ReadOutcome::Line(s),
                    Err(_) => ReadOutcome::Err,
                };
            }
            None => {
                let n = available.len();
                if line.len() + n > max {
                    reader.consume(n);
                    return ReadOutcome::TooLarge;
                }
                line.extend_from_slice(available);
                reader.consume(n);
            }
        }
    }
}

fn send_line(stream: &mut TcpStream, line: &str) -> bool {
    stream.write_all(line.as_bytes()).is_ok() && stream.write_all(b"\n").is_ok()
}

fn connection_loop(shared: &Arc<Shared>, stream: TcpStream, local_addr: SocketAddr) {
    let _ = stream.set_read_timeout(Some(Duration::from_millis(
        shared.config.read_timeout_ms.max(1),
    )));
    let mut writer = match stream.try_clone() {
        Ok(w) => w,
        Err(_) => return,
    };
    let mut reader = BufReader::new(stream);
    loop {
        let line = match read_line_limited(&mut reader, shared.config.max_request_bytes) {
            ReadOutcome::Line(l) => l,
            ReadOutcome::Eof | ReadOutcome::Err => return,
            ReadOutcome::TooLarge => {
                shared.counters.rejected_malformed.fetch_add(1, Ordering::Relaxed);
                let _ = send_line(
                    &mut writer,
                    &error_line(ErrorKind::TooLarge, "request exceeds size bound"),
                );
                return; // framing is lost; drop the connection
            }
        };
        if line.trim().is_empty() {
            continue;
        }
        let request = match parse_request(&line) {
            Ok(r) => r,
            Err(e) => {
                shared.counters.rejected_malformed.fetch_add(1, Ordering::Relaxed);
                if !send_line(&mut writer, &error_line(e.kind, &e.message)) {
                    return;
                }
                continue;
            }
        };
        match request {
            // Control ops answer inline: they must work under full load.
            Request::Health => {
                let mut body = Json::obj();
                body.set("alive", true)
                    .set("queue_depth", shared.queue.depth())
                    .set("queue_capacity", shared.queue.capacity());
                if !send_line(&mut writer, &ok_line(body)) {
                    return;
                }
            }
            Request::Stats => {
                let mut body = Json::obj();
                body.set("stats", shared.counters.snapshot().to_json())
                    .set("queue_depth", shared.queue.depth())
                    .set("queue_capacity", shared.queue.capacity());
                if !send_line(&mut writer, &ok_line(body)) {
                    return;
                }
            }
            Request::Shutdown => {
                let mut body = Json::obj();
                body.set("stopping", true);
                let _ = send_line(&mut writer, &ok_line(body));
                initiate_shutdown(shared, local_addr);
                return;
            }
            req @ (Request::Plan { .. } | Request::Simulate { .. }) => {
                let (tx, rx) = mpsc::channel();
                // The journal lock is held across record + enqueue so the
                // journal's recv order equals the queue's FIFO order.
                let admitted = {
                    let mut journal = match shared.journal.lock() {
                        Ok(j) => j,
                        Err(_) => return,
                    };
                    let id = shared.next_id.fetch_add(1, Ordering::SeqCst);
                    if journal.record_recv(id, &request_to_json(&req)).is_err() {
                        let _ = send_line(
                            &mut writer,
                            &error_line(ErrorKind::Internal, "journal write failed"),
                        );
                        continue;
                    }
                    match shared.queue.try_enqueue(Job { id, request: req, reply: tx }) {
                        Ok(()) => {
                            shared.counters.accepted.fetch_add(1, Ordering::Relaxed);
                            true
                        }
                        Err(_) => {
                            // Never admitted — retire the journal entry so a
                            // crash does not replay a request we rejected.
                            let _ = journal.record_done(id);
                            false
                        }
                    }
                };
                if !admitted {
                    shared
                        .counters
                        .rejected_overloaded
                        .fetch_add(1, Ordering::Relaxed);
                    if !send_line(
                        &mut writer,
                        &error_line(ErrorKind::Overloaded, "request queue is full"),
                    ) {
                        return;
                    }
                    continue;
                }
                // The worker always replies exactly once per admitted job.
                match rx.recv() {
                    Ok(response) => {
                        if !send_line(&mut writer, &response) {
                            return;
                        }
                    }
                    Err(_) => return,
                }
            }
        }
    }
}

// --------------------------------------------------------------- worker

fn worker_loop(shared: &Arc<Shared>, planner: BatchPlanner) {
    let watcher = DeadlineWatcher::start();
    loop {
        let Some(job) = shared.queue.dequeue() else { break };
        // Pressure is measured *now*: the backlog behind this request.
        let depth = shared.queue.depth();
        let budget_ms = match &job.request {
            Request::Plan { deadline_ms, .. } => *deadline_ms,
            _ => None,
        };
        let rung = select_rung(depth, shared.queue.capacity(), budget_ms);
        let flag = budget_ms.map(|ms| watcher.arm(Duration::from_millis(ms)));
        let response = match execute(&planner, &shared.config, &job.request, rung, flag.as_deref())
        {
            Ok(mut body) => {
                let fired = flag
                    .as_ref()
                    .is_some_and(|f| f.load(Ordering::Relaxed));
                if fired {
                    shared.counters.deadline_expired.fetch_add(1, Ordering::Relaxed);
                }
                if rung != Rung::Full || fired {
                    shared.counters.degraded.fetch_add(1, Ordering::Relaxed);
                    let mut tag = Json::obj();
                    tag.set("cause", if fired { "deadline" } else { "load" })
                        .set("rung", rung.as_str());
                    body.set("degraded", tag);
                }
                ok_line(body)
            }
            Err(e) => {
                if e.kind == ErrorKind::Overloaded {
                    shared
                        .counters
                        .rejected_overloaded
                        .fetch_add(1, Ordering::Relaxed);
                }
                error_line(e.kind, &e.message)
            }
        };
        let _ = job.reply.send(response);
        if let Ok(mut journal) = shared.journal.lock() {
            let _ = journal.record_done(job.id);
        }
    }
    // Clean exit: everything admitted has been answered and marked done —
    // flush the cache to disk and shrink the journal to empty.
    if let Some(cache) = planner.cache() {
        let _ = cache.flush();
    }
    if let Ok(mut journal) = shared.journal.lock() {
        let _ = journal.compact(&[]);
    }
    watcher.shutdown();
}

/// Execute one validated request at one ladder rung. Pure with respect to
/// the server state (counters and tagging stay in the caller); also the
/// journal-replay entry point.
fn execute(
    planner: &BatchPlanner,
    config: &ServerConfig,
    request: &Request,
    rung: Rung,
    cancel: Option<&std::sync::atomic::AtomicBool>,
) -> Result<Json, protocol::ProtoError> {
    match request {
        Request::Plan { networks, .. } => {
            let presets: Vec<NetworkPreset> = networks
                .iter()
                .map(|n| {
                    network_preset(n).ok_or_else(|| {
                        protocol::ProtoError::malformed(format!("unknown network preset '{n}'"))
                    })
                })
                .collect::<Result<_, _>>()?;
            let report = match rung_budgets(
                rung,
                config.options.anneal_starts,
                config.options.anneal_iters,
            ) {
                Some((starts, iters))
                    if starts == config.options.anneal_starts
                        && iters == config.options.anneal_iters =>
                {
                    // Full rung: the zero-pressure path — exactly the CLI's
                    // batch, bit-identical report.
                    planner.plan_batch_cancellable(&presets, cancel)
                }
                Some((starts, iters)) => {
                    let mut options = config.options.clone();
                    options.anneal_starts = starts;
                    options.anneal_iters = iters;
                    let reduced = match planner.cache() {
                        Some(c) => BatchPlanner::with_cache(options, c.clone()),
                        None => BatchPlanner::new(options),
                    };
                    reduced.plan_batch_cancellable(&presets, cancel)
                }
                None => {
                    // Cache-only: serve if (and only if) zero races needed.
                    if !planner.fully_cached(&presets) {
                        return Err(protocol::ProtoError {
                            kind: ErrorKind::Overloaded,
                            message: "cache-only rung: not fully cached, try later".into(),
                        });
                    }
                    planner.plan_batch(&presets)
                }
            }
            .map_err(|e| protocol::ProtoError {
                kind: ErrorKind::Internal,
                message: e,
            })?;
            let mut body = Json::obj();
            body.set("report", batch_to_json(&report));
            Ok(body)
        }
        Request::Simulate { layer, strategy: strat, group, batch } => {
            let preset = layer_preset(layer).ok_or_else(|| {
                protocol::ProtoError::malformed(format!("unknown preset '{layer}'"))
            })?;
            let l = preset.layer;
            let s = match strat.as_str() {
                "s1-baseline" => strategy::s1_baseline(&l),
                "row-by-row" | "row" => strategy::row_by_row(&l, *group),
                "zigzag" => strategy::zigzag(&l, *group),
                "hilbert" => strategy::hilbert(&l, *group),
                "diagonal" => strategy::diagonal(&l, *group),
                other => {
                    return Err(protocol::ProtoError::malformed(format!(
                        "unknown strategy '{other}'"
                    )))
                }
            };
            let acc = Accelerator::for_group_size(&l, *group);
            let report = Simulator::new(l, Platform::new(acc))
                .with_batch(*batch)
                .run(&s)
                .map_err(|e| protocol::ProtoError {
                    kind: ErrorKind::Internal,
                    message: e.to_string(),
                })?;
            let mut body = Json::obj();
            body.set("layer", layer.as_str())
                .set("strategy", report.strategy_name.as_str())
                .set("n_steps", report.steps.len())
                .set("duration", report.duration)
                .set("sequential_duration", report.sequential_duration)
                .set("loaded_elements", report.totals.loaded_elements)
                .set("peak_occupancy", report.peak_occupancy);
            Ok(body)
        }
        // Control ops never reach the worker.
        Request::Health | Request::Stats | Request::Shutdown => Err(protocol::ProtoError {
            kind: ErrorKind::Internal,
            message: "control op routed to worker".into(),
        }),
    }
}

//! Per-request deadlines: one watcher thread flips cancel flags on expiry.
//!
//! A request's deadline becomes a plain `Arc<AtomicBool>` — the same shape
//! the planner and optimizer accept
//! ([`crate::planner::BatchPlanner::plan_batch_cancellable`],
//! [`crate::optimizer::search::anneal_cancellable`]) — so the lower layers
//! stay free of any server dependency. The watcher is a single thread
//! sleeping until the earliest armed deadline; arming is O(n) in the number
//! of in-flight deadlines, which for a single-worker server is at most the
//! queue capacity.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

struct WatchState {
    /// Armed deadlines still pending: (expiry, flag to set).
    pending: Vec<(Instant, Arc<AtomicBool>)>,
    shutdown: bool,
}

struct Inner {
    state: Mutex<WatchState>,
    changed: Condvar,
}

/// The deadline watcher service. Dropping it (or calling
/// [`shutdown`](DeadlineWatcher::shutdown)) stops the thread; flags already
/// armed but not yet expired are simply never set, which fails safe — the
/// request runs to completion.
pub struct DeadlineWatcher {
    inner: Arc<Inner>,
    thread: Option<JoinHandle<()>>,
}

impl DeadlineWatcher {
    /// Start the watcher thread.
    pub fn start() -> Self {
        let inner = Arc::new(Inner {
            state: Mutex::new(WatchState { pending: Vec::new(), shutdown: false }),
            changed: Condvar::new(),
        });
        let run = Arc::clone(&inner);
        let thread = std::thread::Builder::new()
            .name("deadline-watcher".into())
            .spawn(move || watcher_loop(&run))
            .expect("spawn deadline watcher");
        DeadlineWatcher { inner, thread: Some(thread) }
    }

    /// Arm a deadline `timeout` from now; the returned flag flips to `true`
    /// when it expires. A zero timeout fires on the watcher's next wakeup
    /// (effectively immediately).
    pub fn arm(&self, timeout: Duration) -> Arc<AtomicBool> {
        let flag = Arc::new(AtomicBool::new(false));
        let expires = Instant::now() + timeout;
        if let Ok(mut s) = self.inner.state.lock() {
            s.pending.push((expires, Arc::clone(&flag)));
        }
        self.inner.changed.notify_all();
        flag
    }

    /// Stop the watcher thread and join it.
    pub fn shutdown(mut self) {
        self.stop();
    }

    fn stop(&mut self) {
        if let Ok(mut s) = self.inner.state.lock() {
            s.shutdown = true;
        }
        self.inner.changed.notify_all();
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

impl Drop for DeadlineWatcher {
    fn drop(&mut self) {
        self.stop();
    }
}

fn watcher_loop(inner: &Inner) {
    let mut s = match inner.state.lock() {
        Ok(s) => s,
        Err(_) => return,
    };
    loop {
        // Fire everything already expired.
        let now = Instant::now();
        s.pending.retain(|(expiry, flag)| {
            if *expiry <= now {
                flag.store(true, Ordering::Relaxed);
                false
            } else {
                true
            }
        });
        if s.shutdown {
            return;
        }
        // Sleep until the earliest pending expiry (or until armed/shutdown).
        let next = s.pending.iter().map(|(e, _)| *e).min();
        s = match next {
            Some(e) => {
                let wait = e.saturating_duration_since(Instant::now());
                match inner.changed.wait_timeout(s, wait) {
                    Ok((s, _)) => s,
                    Err(_) => return,
                }
            }
            None => match inner.changed.wait(s) {
                Ok(s) => s,
                Err(_) => return,
            },
        };
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn wait_for(flag: &AtomicBool, limit: Duration) -> bool {
        let start = Instant::now();
        while start.elapsed() < limit {
            if flag.load(Ordering::Relaxed) {
                return true;
            }
            std::thread::sleep(Duration::from_millis(2));
        }
        flag.load(Ordering::Relaxed)
    }

    #[test]
    fn short_deadline_fires_and_long_one_does_not() {
        let w = DeadlineWatcher::start();
        let soon = w.arm(Duration::from_millis(10));
        let later = w.arm(Duration::from_secs(3600));
        assert!(wait_for(&soon, Duration::from_secs(5)), "10ms deadline must fire");
        assert!(!later.load(Ordering::Relaxed), "distant deadline must not fire");
        w.shutdown();
        assert!(!later.load(Ordering::Relaxed), "shutdown fails safe: flag stays unset");
    }

    #[test]
    fn zero_timeout_fires_immediately() {
        let w = DeadlineWatcher::start();
        let flag = w.arm(Duration::ZERO);
        assert!(wait_for(&flag, Duration::from_secs(5)));
    }
}

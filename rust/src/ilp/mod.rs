//! ILP modeling substrate (offline CPLEX/OPL substitute — the modeling half).
//!
//! Provides the linear-model vocabulary §5 needs: bounded continuous/integer
//! variables, linear expressions, `≤ / ≥ / =` constraints, a minimization
//! objective, and the standard boolean linearizations (∨, ∧, ∧¬) of
//! Luenberger & Ye that the paper cites for Eqs. 6–8. The solving half lives
//! in [`crate::solver`].

mod linexpr;
mod model;

pub use linexpr::LinExpr;
pub use model::{BoolVar, Cmp, Constraint, Model, Solution, SolveStatus, VarId, VarKind};

/// Add constraints enforcing `out = v_1 ∨ v_2 ∨ … ∨ v_n` over binaries:
/// `out ≥ v_i` for all `i`, and `out ≤ Σ v_i`.
pub fn linearize_or(model: &mut Model, out: BoolVar, inputs: &[BoolVar]) {
    for &v in inputs {
        // out - v >= 0
        let mut e = LinExpr::new();
        e.add(out.0, 1.0);
        e.add(v.0, -1.0);
        model.constrain(e, Cmp::Ge, 0.0);
    }
    // out - Σ v_i <= 0
    let mut e = LinExpr::new();
    e.add(out.0, 1.0);
    for &v in inputs {
        e.add(v.0, -1.0);
    }
    model.constrain(e, Cmp::Le, 0.0);
}

/// Add constraints enforcing `out = a ∧ b`:
/// `out ≤ a`, `out ≤ b`, `out ≥ a + b − 1`.
pub fn linearize_and(model: &mut Model, out: BoolVar, a: BoolVar, b: BoolVar) {
    let mut e1 = LinExpr::new();
    e1.add(out.0, 1.0);
    e1.add(a.0, -1.0);
    model.constrain(e1, Cmp::Le, 0.0);

    let mut e2 = LinExpr::new();
    e2.add(out.0, 1.0);
    e2.add(b.0, -1.0);
    model.constrain(e2, Cmp::Le, 0.0);

    let mut e3 = LinExpr::new();
    e3.add(out.0, 1.0);
    e3.add(a.0, -1.0);
    e3.add(b.0, -1.0);
    model.constrain(e3, Cmp::Ge, -1.0);
}

/// Add constraints enforcing `out = a ∧ ¬b` (Eq. 8's shape):
/// `out ≤ a`, `out ≤ 1 − b`, `out ≥ a − b`.
pub fn linearize_and_not(model: &mut Model, out: BoolVar, a: BoolVar, b: BoolVar) {
    let mut e1 = LinExpr::new();
    e1.add(out.0, 1.0);
    e1.add(a.0, -1.0);
    model.constrain(e1, Cmp::Le, 0.0);

    let mut e2 = LinExpr::new();
    e2.add(out.0, 1.0);
    e2.add(b.0, 1.0);
    model.constrain(e2, Cmp::Le, 1.0);

    let mut e3 = LinExpr::new();
    e3.add(out.0, 1.0);
    e3.add(a.0, -1.0);
    e3.add(b.0, 1.0);
    model.constrain(e3, Cmp::Ge, 0.0);
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Exhaustively check a linearization over all boolean assignments:
    /// for forced inputs, the only feasible `out` value is the gate value.
    fn check_gate<F, G>(n_inputs: usize, build: F, gate: G)
    where
        F: Fn(&mut Model, BoolVar, &[BoolVar]),
        G: Fn(&[f64]) -> f64,
    {
        for mask in 0..(1u32 << n_inputs) {
            let mut m = Model::minimize();
            let inputs: Vec<BoolVar> =
                (0..n_inputs).map(|i| m.bool_var(&format!("v{i}"))).collect();
            let out = m.bool_var("out");
            build(&mut m, out, &inputs);
            let vals: Vec<f64> = (0..n_inputs)
                .map(|i| ((mask >> i) & 1) as f64)
                .collect();
            let expect = gate(&vals);
            for out_val in [0.0, 1.0] {
                let mut assign = vals.clone();
                assign.push(out_val);
                let feasible = m.is_feasible(&assign, 1e-9);
                assert_eq!(
                    feasible,
                    (out_val - expect).abs() < 1e-9,
                    "mask {mask:b}, out {out_val}"
                );
            }
        }
    }

    #[test]
    fn or_gate_exact() {
        for n in 1..=4 {
            check_gate(
                n,
                |m, out, ins| linearize_or(m, out, ins),
                |vals| {
                    if vals.iter().any(|&v| v > 0.5) {
                        1.0
                    } else {
                        0.0
                    }
                },
            );
        }
    }

    #[test]
    fn and_gate_exact() {
        check_gate(
            2,
            |m, out, ins| linearize_and(m, out, ins[0], ins[1]),
            |vals| {
                if vals[0] > 0.5 && vals[1] > 0.5 {
                    1.0
                } else {
                    0.0
                }
            },
        );
    }

    #[test]
    fn and_not_gate_exact() {
        check_gate(
            2,
            |m, out, ins| linearize_and_not(m, out, ins[0], ins[1]),
            |vals| {
                if vals[0] > 0.5 && vals[1] < 0.5 {
                    1.0
                } else {
                    0.0
                }
            },
        );
    }
}

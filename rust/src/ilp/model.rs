//! The ILP model container.

use crate::ilp::LinExpr;

/// Variable identifier (dense index into the model's variable table).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct VarId(pub usize);

/// Typed handle for a `{0,1}` variable (what the §5 model is made of).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BoolVar(pub VarId);

/// Variable domain kind.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum VarKind {
    /// Real-valued variable.
    Continuous,
    /// Integer variable (the §5 model uses only `{0,1}`).
    Integer,
}

/// Comparison operator of a constraint.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Cmp {
    /// `lhs ≤ rhs`.
    Le,
    /// `lhs ≥ rhs`.
    Ge,
    /// `lhs = rhs`.
    Eq,
}

/// `expr  cmp  rhs`.
#[derive(Debug, Clone)]
pub struct Constraint {
    /// Left-hand side.
    pub expr: LinExpr,
    /// Comparison operator.
    pub cmp: Cmp,
    /// Right-hand side constant.
    pub rhs: f64,
}

impl Constraint {
    /// True when `assign` satisfies the constraint within `tol`.
    pub fn holds(&self, assign: &[f64], tol: f64) -> bool {
        let lhs = self.expr.eval(assign);
        match self.cmp {
            Cmp::Le => lhs <= self.rhs + tol,
            Cmp::Ge => lhs >= self.rhs - tol,
            Cmp::Eq => (lhs - self.rhs).abs() <= tol,
        }
    }
}

#[derive(Debug, Clone)]
pub(crate) struct VarDef {
    pub name: String,
    pub lo: f64,
    pub hi: f64,
    pub kind: VarKind,
}

/// A minimization MILP.
#[derive(Debug, Clone, Default)]
pub struct Model {
    pub(crate) vars: Vec<VarDef>,
    /// All constraints, in insertion order.
    pub constraints: Vec<Constraint>,
    /// The linear objective (minimized).
    pub objective: LinExpr,
}

impl Model {
    /// An empty minimization model.
    pub fn minimize() -> Self {
        Model::default()
    }

    /// New bounded variable.
    pub fn var(&mut self, name: &str, lo: f64, hi: f64, kind: VarKind) -> VarId {
        assert!(lo <= hi, "variable '{name}': lo > hi");
        self.vars.push(VarDef { name: name.to_string(), lo, hi, kind });
        VarId(self.vars.len() - 1)
    }

    /// New `{0,1}` variable.
    pub fn bool_var(&mut self, name: &str) -> BoolVar {
        BoolVar(self.var(name, 0.0, 1.0, VarKind::Integer))
    }

    /// Number of variables.
    pub fn n_vars(&self) -> usize {
        self.vars.len()
    }

    /// `(lo, hi)` bounds of a variable.
    pub fn bounds(&self, v: VarId) -> (f64, f64) {
        (self.vars[v.0].lo, self.vars[v.0].hi)
    }

    /// Domain kind of a variable.
    pub fn kind(&self, v: VarId) -> VarKind {
        self.vars[v.0].kind
    }

    /// Name of a variable (diagnostics).
    pub fn name(&self, v: VarId) -> &str {
        &self.vars[v.0].name
    }

    /// Add a constraint `expr cmp rhs`.
    pub fn constrain(&mut self, expr: LinExpr, cmp: Cmp, rhs: f64) {
        self.constraints.push(Constraint { expr, cmp, rhs });
    }

    /// Set the (minimization) objective.
    pub fn set_objective(&mut self, objective: LinExpr) {
        self.objective = objective;
    }

    /// Feasibility of a full assignment: bounds, integrality, constraints.
    pub fn is_feasible(&self, assign: &[f64], tol: f64) -> bool {
        if assign.len() != self.vars.len() {
            return false;
        }
        for (i, def) in self.vars.iter().enumerate() {
            let x = assign[i];
            if x < def.lo - tol || x > def.hi + tol {
                return false;
            }
            if def.kind == VarKind::Integer && (x - x.round()).abs() > tol {
                return false;
            }
        }
        self.constraints.iter().all(|c| c.holds(assign, tol))
    }

    /// Objective value of an assignment.
    pub fn objective_value(&self, assign: &[f64]) -> f64 {
        self.objective.eval(assign)
    }

    /// Summary string (var/constraint counts) for logs.
    pub fn dims(&self) -> String {
        let n_int = self
            .vars
            .iter()
            .filter(|v| v.kind == VarKind::Integer)
            .count();
        format!(
            "{} vars ({} integer), {} constraints",
            self.vars.len(),
            n_int,
            self.constraints.len()
        )
    }
}

/// Status of a MILP solve.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SolveStatus {
    /// Proven optimal.
    Optimal,
    /// Feasible incumbent, search truncated (node/time budget).
    Feasible,
    /// Proven infeasible.
    Infeasible,
    /// Budget exhausted with no incumbent.
    Unknown,
}

/// Result of a MILP solve.
#[derive(Debug, Clone)]
pub struct Solution {
    /// Solve status (optimal / feasible / infeasible / budget).
    pub status: SolveStatus,
    /// Assignment (empty unless status is Optimal/Feasible).
    pub assignment: Vec<f64>,
    /// Objective value of the incumbent, if any.
    pub objective: f64,
    /// Best LP lower bound proven.
    pub lower_bound: f64,
    /// Branch-and-bound nodes explored.
    pub nodes: u64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn feasibility_checks_bounds_and_integrality() {
        let mut m = Model::minimize();
        let x = m.var("x", 0.0, 5.0, VarKind::Integer);
        let y = m.var("y", 0.0, 2.0, VarKind::Continuous);
        let mut e = LinExpr::new();
        e.add(x, 1.0).add(y, 1.0);
        m.constrain(e, Cmp::Le, 4.0);

        assert!(m.is_feasible(&[2.0, 1.5], 1e-9));
        assert!(!m.is_feasible(&[2.5, 1.0], 1e-9)); // x not integer
        assert!(!m.is_feasible(&[6.0, 0.0], 1e-9)); // x out of bounds
        assert!(!m.is_feasible(&[3.0, 1.5], 1e-9)); // constraint violated
        assert!(!m.is_feasible(&[3.0], 1e-9)); // wrong length
    }

    #[test]
    fn constraint_operators() {
        let mut m = Model::minimize();
        let x = m.var("x", -10.0, 10.0, VarKind::Continuous);
        m.constrain(LinExpr::term(x, 1.0), Cmp::Ge, 2.0);
        m.constrain(LinExpr::term(x, 2.0), Cmp::Eq, 6.0);
        assert!(m.is_feasible(&[3.0], 1e-9));
        assert!(!m.is_feasible(&[2.0], 1e-9));
        assert!(!m.is_feasible(&[4.0], 1e-9));
    }

    #[test]
    fn objective_eval() {
        let mut m = Model::minimize();
        let x = m.var("x", 0.0, 1.0, VarKind::Continuous);
        let y = m.var("y", 0.0, 1.0, VarKind::Continuous);
        let mut obj = LinExpr::new();
        obj.add(x, 3.0).add(y, -1.0);
        m.set_objective(obj);
        assert_eq!(m.objective_value(&[1.0, 1.0]), 2.0);
    }

    #[test]
    fn dims_string() {
        let mut m = Model::minimize();
        m.bool_var("b");
        m.var("c", 0.0, 1.0, VarKind::Continuous);
        assert_eq!(m.dims(), "2 vars (1 integer), 0 constraints");
    }
}

//! Sparse linear expressions `Σ c_i · x_i`.

use crate::ilp::model::VarId;

/// A sparse linear expression. Terms are kept sorted by variable id with
/// coefficients merged, so expressions have a canonical form.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct LinExpr {
    terms: Vec<(VarId, f64)>,
}

impl LinExpr {
    /// The zero expression.
    pub fn new() -> Self {
        LinExpr { terms: Vec::new() }
    }

    /// Single-term expression `c · x`.
    pub fn term(var: VarId, coeff: f64) -> Self {
        let mut e = LinExpr::new();
        e.add(var, coeff);
        e
    }

    /// Add `coeff · var` (merging with an existing term).
    pub fn add(&mut self, var: VarId, coeff: f64) -> &mut Self {
        match self.terms.binary_search_by_key(&var, |t| t.0) {
            Ok(i) => {
                self.terms[i].1 += coeff;
                if self.terms[i].1 == 0.0 {
                    self.terms.remove(i);
                }
            }
            Err(i) => self.terms.insert(i, (var, coeff)),
        }
        self
    }

    /// Append another expression scaled by `scale`.
    pub fn add_expr(&mut self, other: &LinExpr, scale: f64) -> &mut Self {
        for &(v, c) in &other.terms {
            self.add(v, c * scale);
        }
        self
    }

    /// The `(variable, coefficient)` terms.
    pub fn terms(&self) -> &[(VarId, f64)] {
        &self.terms
    }

    /// True when there are no terms.
    pub fn is_empty(&self) -> bool {
        self.terms.is_empty()
    }

    /// Number of terms.
    pub fn len(&self) -> usize {
        self.terms.len()
    }

    /// Evaluate under an assignment (indexed by variable id).
    pub fn eval(&self, assign: &[f64]) -> f64 {
        self.terms.iter().map(|&(v, c)| c * assign[v.0]).sum()
    }

    /// Coefficient of a variable (0 if absent).
    pub fn coeff(&self, var: VarId) -> f64 {
        match self.terms.binary_search_by_key(&var, |t| t.0) {
            Ok(i) => self.terms[i].1,
            Err(_) => 0.0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn v(i: usize) -> VarId {
        VarId(i)
    }

    #[test]
    fn add_merges_terms() {
        let mut e = LinExpr::new();
        e.add(v(2), 1.0).add(v(0), 2.0).add(v(2), 3.0);
        assert_eq!(e.terms(), &[(v(0), 2.0), (v(2), 4.0)]);
        assert_eq!(e.coeff(v(2)), 4.0);
        assert_eq!(e.coeff(v(1)), 0.0);
    }

    #[test]
    fn zero_coefficients_vanish() {
        let mut e = LinExpr::term(v(1), 5.0);
        e.add(v(1), -5.0);
        assert!(e.is_empty());
    }

    #[test]
    fn eval_and_scale() {
        let mut a = LinExpr::new();
        a.add(v(0), 1.0).add(v(1), 2.0);
        let mut b = LinExpr::term(v(1), 1.0);
        b.add_expr(&a, 2.0); // b = 2x0 + 5x1
        assert_eq!(b.eval(&[3.0, 4.0]), 6.0 + 20.0);
        assert_eq!(b.len(), 2);
    }
}

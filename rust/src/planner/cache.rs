//! Content-addressed strategy cache.
//!
//! A planned strategy depends only on the layer geometry, the accelerator
//! parameters, the grouping bounds and the portfolio configuration — not on
//! which network the layer appeared in. The cache therefore keys on exactly
//! those fields ([`CacheKey`]): planning LeNet-5 then ResNet-8 reuses any
//! shared shapes, and re-planning the same network is free.
//!
//! Entries are one JSON file each under the cache directory, named by the
//! FNV-1a hash of the canonical key string; the full key is stored inside
//! the file and verified on read, so a hash collision degrades to a cache
//! miss rather than a wrong strategy. The payload itself is *not* trusted
//! either: the planner re-validates every hit structurally against the layer
//! it is about to drive ([`CachedStrategy::validate_for`]) *and* recomputes
//! the stored objective, re-racing on any mismatch.

use std::path::{Path, PathBuf};

use crate::conv::{ConvLayer, PatchId};
use crate::platform::{Accelerator, OverlapMode};
use crate::strategy::{self, GroupedStrategy};
use crate::util::fsio::atomic_write;
use crate::util::hash::fnv1a64_hex;
use crate::util::json::{self, Json};

/// The persistence interface the planner races against: both the one-file-
/// per-key [`StrategyCache`] and the sharded
/// [`crate::planner::ShardedStrategyCache`] implement it, so the batch
/// resolution machinery is backend-agnostic.
///
/// Implementations must treat any malformed, truncated or mismatched stored
/// state as a miss — never an error, never a panic — because the planner
/// re-races misses and overwrites; a poisoned store would otherwise take the
/// whole service down over one bad file.
pub trait StrategyStore {
    /// Look up a key; `None` for both "absent" and "unreadable".
    fn load(&self, key: &CacheKey) -> Option<CachedStrategy>;
    /// Persist a planning result under its key (overwrites).
    fn store(&self, key: &CacheKey, entry: &CachedStrategy) -> Result<(), String>;
}

/// Canonical description of one planning problem.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CacheKey {
    canonical: String,
}

impl CacheKey {
    /// Build the key from everything the planned strategy depends on.
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        layer: &ConvLayer,
        acc: &Accelerator,
        group_size: usize,
        k: usize,
        seed: u64,
        anneal_iters: u64,
        anneal_starts: usize,
    ) -> CacheKey {
        // v4: the accelerator's resource shape (k DMA channels × m compute
        // units) joined the key — the makespan objective replays the
        // generalized timeline, so a strategy raced on a 2×1 machine is a
        // different planning problem than on 1×1 (v3 added the overlap
        // mode, v2 dilation + channel groups).
        let canonical = format!(
            "v4|in:{}x{}x{}|ker:{}x{}x{}|stride:{}x{}|dil:{}x{}|grp:{}|acc:{},{},{},{},{}|ovl:{}|ch:{}x{}|g:{}|k:{}|anneal:{}x{}@{}",
            layer.c_in,
            layer.h_in,
            layer.w_in,
            layer.n_kernels,
            layer.h_k,
            layer.w_k,
            layer.s_h,
            layer.s_w,
            layer.d_h,
            layer.d_w,
            layer.groups,
            acc.nbop_pe,
            acc.t_acc,
            acc.size_mem,
            acc.t_l,
            acc.t_w,
            acc.overlap.as_str(),
            acc.dma_channels,
            acc.compute_units,
            group_size,
            k,
            anneal_starts,
            anneal_iters,
            seed,
        );
        CacheKey { canonical }
    }

    /// The canonical key string (stored in, and verified against, the file).
    pub fn canonical(&self) -> &str {
        &self.canonical
    }

    /// Content-addressed filename for this key.
    pub fn filename(&self) -> String {
        format!("{}.json", fnv1a64_hex(self.canonical.as_bytes()))
    }
}

/// A cached planning result.
#[derive(Debug, Clone, PartialEq)]
pub struct CachedStrategy {
    /// The winning strategy.
    pub strategy: GroupedStrategy,
    /// The loaded-pixels objective the winner achieved (the race metric in
    /// sequential mode; recomputed on every hit).
    pub loaded_pixels: u64,
    /// The §3.7 overlapped makespan the winner achieved — present exactly
    /// when the key's accelerator was double-buffered (recomputed on hits
    /// in that mode).
    pub makespan: Option<u64>,
    /// Which portfolio lane won (provenance for reports).
    pub winner: String,
}

impl CachedStrategy {
    /// Structural check before a cache hit is trusted: the strategy must be
    /// an ordered partition of the layer's patch set into non-empty groups
    /// within the group bound. A stale or hand-edited file that fails this
    /// is treated as a miss by the planner.
    pub fn validate_for(&self, layer: &ConvLayer, group_size: usize) -> bool {
        if !self
            .strategy
            .groups
            .iter()
            .all(|g| !g.is_empty() && g.len() <= group_size)
        {
            return false;
        }
        let mut all: Vec<PatchId> =
            self.strategy.groups.iter().flatten().copied().collect();
        all.sort_unstable();
        all == layer.all_patches().collect::<Vec<_>>()
    }
}

/// On-disk strategy cache (one JSON file per key).
#[derive(Debug, Clone)]
pub struct StrategyCache {
    dir: PathBuf,
}

impl StrategyCache {
    /// Open (creating if needed) a cache directory.
    pub fn open(dir: &Path) -> Result<StrategyCache, String> {
        std::fs::create_dir_all(dir)
            .map_err(|e| format!("create cache dir {}: {e}", dir.display()))?;
        Ok(StrategyCache { dir: dir.to_path_buf() })
    }

    /// The directory backing this cache.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Look up a key. Any malformed / mismatched file reads as a miss.
    pub fn get(&self, key: &CacheKey) -> Option<CachedStrategy> {
        let text = std::fs::read_to_string(self.dir.join(key.filename())).ok()?;
        let v = json::parse(&text).ok()?;
        let (stored_key, entry) = entry_from_json(&v)?;
        if stored_key != key.canonical() {
            return None;
        }
        Some(entry)
    }

    /// Store a planning result under its key (overwrites). The write goes
    /// through temp-file + atomic rename ([`atomic_write`]): a crash
    /// mid-write leaves the previous complete file, never a truncated one.
    pub fn put(&self, key: &CacheKey, entry: &CachedStrategy) -> Result<(), String> {
        let o = entry_to_json(key.canonical(), entry)?;
        atomic_write(&self.dir.join(key.filename()), &o.to_string_pretty())
    }
}

impl StrategyStore for StrategyCache {
    fn load(&self, key: &CacheKey) -> Option<CachedStrategy> {
        self.get(key)
    }

    fn store(&self, key: &CacheKey, entry: &CachedStrategy) -> Result<(), String> {
        self.put(key, entry)
    }
}

/// Serialize one cache entry (the canonical key travels inside the record so
/// every reader can verify it). Shared by the per-key files here and the
/// sharded cache's entry arrays.
pub(crate) fn entry_to_json(
    canonical_key: &str,
    entry: &CachedStrategy,
) -> Result<Json, String> {
    let strategy_json = json::parse(&strategy::strategy_to_json(&entry.strategy))
        .map_err(|e| format!("serialize strategy: {e}"))?;
    let mut o = Json::obj();
    o.set("key", canonical_key)
        .set("winner", entry.winner.as_str())
        .set("loaded_pixels", entry.loaded_pixels)
        .set("strategy", strategy_json);
    if let Some(m) = entry.makespan {
        o.set("makespan", m);
    }
    Ok(o)
}

/// Parse one cache entry; `None` on any structural problem (the callers all
/// degrade to a miss).
pub(crate) fn entry_from_json(v: &Json) -> Option<(String, CachedStrategy)> {
    let key = v.get("key").and_then(Json::as_str)?.to_string();
    let winner = v.get("winner").and_then(Json::as_str)?.to_string();
    let loaded_pixels = v.get("loaded_pixels").and_then(Json::as_u64)?;
    let makespan = v.get("makespan").and_then(Json::as_u64);
    let strategy = strategy::strategy_from_json_value(v.get("strategy")?).ok()?;
    Some((key, CachedStrategy { strategy, loaded_pixels, makespan, winner }))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "convoffload-cache-test-{tag}-{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    fn sample_key(seed: u64) -> (ConvLayer, CacheKey) {
        let l = ConvLayer::square(1, 6, 3, 1);
        let acc = Accelerator::for_group_size(&l, 2);
        let key = CacheKey::new(&l, &acc, 2, 8, seed, 1_000, 2);
        (l, key)
    }

    #[test]
    fn roundtrip() {
        let dir = tmp_dir("roundtrip");
        let cache = StrategyCache::open(&dir).unwrap();
        let (l, key) = sample_key(1);
        assert!(cache.get(&key).is_none());
        let entry = CachedStrategy {
            strategy: strategy::zigzag(&l, 2),
            loaded_pixels: 57,
            makespan: None,
            winner: "zigzag".to_string(),
        };
        cache.put(&key, &entry).unwrap();
        assert_eq!(cache.get(&key), Some(entry));
        // makespan survives the roundtrip when present (double-buffered
        // planning problems store their race metric too)
        let (l2, key2) = sample_key(9);
        let entry2 = CachedStrategy {
            strategy: strategy::zigzag(&l2, 2),
            loaded_pixels: 57,
            makespan: Some(123),
            winner: "zigzag".to_string(),
        };
        cache.put(&key2, &entry2).unwrap();
        assert_eq!(cache.get(&key2), Some(entry2));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn key_mismatch_is_a_miss() {
        let dir = tmp_dir("mismatch");
        let cache = StrategyCache::open(&dir).unwrap();
        let (l, key) = sample_key(2);
        let entry = CachedStrategy {
            strategy: strategy::zigzag(&l, 2),
            loaded_pixels: 57,
            makespan: None,
            winner: "zigzag".to_string(),
        };
        cache.put(&key, &entry).unwrap();
        // same filename, different stored key → treated as a miss
        let text = std::fs::read_to_string(dir.join(key.filename())).unwrap();
        let tampered = text.replace("v4|", "v0|");
        std::fs::write(dir.join(key.filename()), tampered).unwrap();
        assert!(cache.get(&key).is_none());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn distinct_problems_get_distinct_files() {
        let (_, a) = sample_key(1);
        let (_, b) = sample_key(2);
        assert_ne!(a.canonical(), b.canonical());
        assert_ne!(a.filename(), b.filename());
    }

    /// Dilation and groups are layer geometry: same dense shape with either
    /// set must be a different planning problem.
    #[test]
    fn dilation_and_groups_are_part_of_the_key() {
        let dense = ConvLayer::new(4, 12, 12, 3, 3, 4, 1, 1).unwrap();
        let acc = Accelerator::for_group_size(&dense, 2);
        let base = CacheKey::new(&dense, &acc, 2, 8, 1, 100, 1);
        let dilated = CacheKey::new(
            &dense.with_dilation(2, 2).unwrap(),
            &acc,
            2,
            8,
            1,
            100,
            1,
        );
        let grouped =
            CacheKey::new(&dense.with_groups(4).unwrap(), &acc, 2, 8, 1, 100, 1);
        assert_ne!(base.canonical(), dilated.canonical());
        assert_ne!(base.canonical(), grouped.canonical());
        assert_ne!(dilated.canonical(), grouped.canonical());
    }

    /// The overlap mode and the resource shape are part of the planning
    /// problem: the same shape on the same machine under other duration
    /// semantics — or with more channels/units — must be a different key
    /// (CacheKey v4).
    #[test]
    fn overlap_mode_and_resource_shape_are_part_of_the_key() {
        let l = ConvLayer::square(1, 6, 3, 1);
        let acc = Accelerator::for_group_size(&l, 2);
        let seq = CacheKey::new(&l, &acc, 2, 8, 1, 100, 1);
        let db = CacheKey::new(
            &l,
            &acc.with_overlap(OverlapMode::DoubleBuffered),
            2,
            8,
            1,
            100,
            1,
        );
        assert_ne!(seq.canonical(), db.canonical());
        assert_ne!(seq.filename(), db.filename());
        assert!(seq.canonical().starts_with("v4|"));
        assert!(seq.canonical().contains("|ovl:sequential|"));
        assert!(seq.canonical().contains("|ch:1x1|"));
        assert!(db.canonical().contains("|ovl:double-buffered|"));
        let wide = CacheKey::new(&l, &acc.with_channels(2, 3), 2, 8, 1, 100, 1);
        assert_ne!(seq.canonical(), wide.canonical());
        assert!(wide.canonical().contains("|ch:2x3|"));
    }

    #[test]
    fn validate_for_rejects_broken_payloads() {
        let l = ConvLayer::square(1, 6, 3, 1); // 16 patches
        let good = CachedStrategy {
            strategy: strategy::zigzag(&l, 2),
            loaded_pixels: 1,
            makespan: None,
            winner: "zigzag".to_string(),
        };
        assert!(good.validate_for(&l, 2));
        // group over the bound
        assert!(!good.validate_for(&l, 1));
        // out-of-range patch id
        let mut bad = good.clone();
        bad.strategy.groups[0][0] = 999_999;
        assert!(!bad.validate_for(&l, 2));
        // missing coverage (drop one group)
        let mut short = good.clone();
        short.strategy.groups.pop();
        assert!(!short.validate_for(&l, 2));
    }

    /// Regression for the in-place-write bug: a partial write (here: a
    /// truncated file, as a crashed `std::fs::write` would leave) must read
    /// as a miss, and a subsequent `put` must atomically restore a complete
    /// entry without leaving temp files behind.
    #[test]
    fn partial_write_reads_as_miss_and_put_recovers_atomically() {
        let dir = tmp_dir("partial");
        let cache = StrategyCache::open(&dir).unwrap();
        let (l, key) = sample_key(5);
        let entry = CachedStrategy {
            strategy: strategy::zigzag(&l, 2),
            loaded_pixels: 57,
            makespan: None,
            winner: "zigzag".to_string(),
        };
        cache.put(&key, &entry).unwrap();
        // Simulate a crash mid-write of a non-atomic writer: truncate the
        // entry file to a prefix.
        let path = dir.join(key.filename());
        let full = std::fs::read_to_string(&path).unwrap();
        std::fs::write(&path, &full[..full.len() / 2]).unwrap();
        assert!(cache.get(&key).is_none(), "truncated entry must be a miss");
        // Re-planning overwrites through the atomic path and recovers.
        cache.put(&key, &entry).unwrap();
        assert_eq!(cache.get(&key), Some(entry));
        let stray: Vec<String> = std::fs::read_dir(&dir)
            .unwrap()
            .map(|e| e.unwrap().file_name().to_string_lossy().into_owned())
            .filter(|n| n.contains(".tmp-"))
            .collect();
        assert!(stray.is_empty(), "temp residue: {stray:?}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn corrupt_file_is_a_miss() {
        let dir = tmp_dir("corrupt");
        let cache = StrategyCache::open(&dir).unwrap();
        let (_, key) = sample_key(3);
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join(key.filename()), "not json").unwrap();
        assert!(cache.get(&key).is_none());
        let _ = std::fs::remove_dir_all(&dir);
    }
}

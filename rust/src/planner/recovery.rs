//! The planner's recovery layer: bounded-retry I/O, deterministic chaos
//! hooks, and degraded-mode replanning after `MemoryShrink` faults.
//!
//! Three failure domains, three disciplines:
//!
//! * **Crashed portfolio lanes.** The batch race runs every lane under
//!   [`crate::util::pool::parallel_map_catch`]; a panicking lane loses
//!   exactly its own result and is skipped by the (still deterministic)
//!   reduction. [`ChaosSpec`] injects such a crash on purpose — by lane
//!   label, so the failure is replayable — for the recovery tests and the
//!   CI chaos job.
//! * **Transient cache I/O failures.** [`retry_io`] wraps shard persistence
//!   in a bounded retry with exponential backoff; a persistently failing
//!   disk still surfaces the final error.
//! * **Mid-execution memory shrink.** When a fault-injected simulation
//!   reports `MemoryShrink` events, the planned strategy may no longer fit
//!   the reduced `size_MEM`. [`degrade_for_shrink`] re-validates against
//!   the shrunk budget ([`crate::optimizer::degraded_accelerator`]) and
//!   degrades in two deterministic stages: a local **re-grouping** (split
//!   each visit-order group into chunks that fit — cheap, preserves the
//!   winner's ordering structure), then a full inline **re-race** of the
//!   portfolio under the reduced budget. Degraded entries are *never*
//!   written back to the strategy store: the cache key describes the
//!   healthy platform, and the shrink is a per-run event.

use std::time::Duration;

use crate::conv::ConvLayer;
use crate::optimizer::{grouping_loads, grouping_makespan};
use crate::platform::{Accelerator, OverlapMode, Platform};
use crate::sim::Simulator;
use crate::strategy::GroupedStrategy;

use super::cache::CachedStrategy;
use super::portfolio::{portfolio_entries, run_entry, PortfolioResult};
use super::PlanOptions;

/// Deterministic chaos injection for [`super::BatchPlanner`] — replayable
/// failures for the recovery tests and the CI chaos job. Inactive by
/// default; production paths never construct an active spec.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct ChaosSpec {
    /// Portfolio lane label (e.g. `"greedy"`, `"anneal-s7"`) whose worker
    /// panics mid-race. Every racing problem loses that one lane; the
    /// survivors still produce a plan for every network.
    pub panic_lane: Option<String>,
}

impl ChaosSpec {
    /// Is any chaos configured?
    pub fn is_active(&self) -> bool {
        self.panic_lane.is_some()
    }
}

/// What [`degrade_for_shrink`] had to do to keep a plan executable under a
/// reduced memory budget.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DegradeOutcome {
    /// The planned strategy still fits the shrunk budget as-is.
    Unchanged,
    /// Groups were split into chunks of at most the new bound; the winner's
    /// visit order survived.
    Regrouped,
    /// The portfolio re-raced from scratch under the reduced budget.
    Reraced,
}

impl DegradeOutcome {
    /// Stable report label.
    pub fn as_str(&self) -> &'static str {
        match self {
            DegradeOutcome::Unchanged => "unchanged",
            DegradeOutcome::Regrouped => "regrouped",
            DegradeOutcome::Reraced => "reraced",
        }
    }
}

/// Run `op` up to `attempts` times, sleeping `base_delay · 2^i` between
/// failures (exponential backoff). Returns the first success or the last
/// error. `attempts` is clamped to ≥ 1.
pub fn retry_io<T>(
    attempts: u32,
    base_delay: Duration,
    mut op: impl FnMut() -> Result<T, String>,
) -> Result<T, String> {
    let attempts = attempts.max(1);
    let mut delay = base_delay;
    let mut last_err = String::new();
    for attempt in 0..attempts {
        match op() {
            Ok(v) => return Ok(v),
            Err(e) => last_err = e,
        }
        if attempt + 1 < attempts && !delay.is_zero() {
            std::thread::sleep(delay);
            delay = delay.saturating_mul(2);
        }
    }
    Err(format!("after {attempts} attempts: {last_err}"))
}

/// The sleep schedule [`retry_io_jittered`] follows: for each of the
/// `attempts - 1` possible waits, the exponential base delay
/// (`base_delay · 2^i`) plus a seeded uniform jitter in `[0, base·2^i]`
/// drawn from [`crate::util::rng::Rng`] via Lemire `below`. Pure — the
/// schedule is a function of `(attempts, base_delay, seed)` alone, so tests
/// (and the Python oracle) can pin it bit-exactly while production callers
/// seeded differently (e.g. per shard index) desynchronize instead of
/// thundering-herding a contended shard file.
pub fn backoff_schedule(attempts: u32, base_delay: Duration, seed: u64) -> Vec<Duration> {
    let attempts = attempts.max(1);
    let mut rng = crate::util::rng::Rng::new(seed);
    let mut delay = base_delay;
    let mut schedule = Vec::with_capacity(attempts.saturating_sub(1) as usize);
    for _ in 1..attempts {
        let span = delay.as_micros().min(u128::from(u64::MAX - 1)) as u64;
        let jitter = Duration::from_micros(rng.below(span + 1));
        schedule.push(delay + jitter);
        delay = delay.saturating_mul(2);
    }
    schedule
}

/// [`retry_io`] with seeded backoff jitter: sleeps follow
/// [`backoff_schedule`]`(attempts, base_delay, seed)` exactly. Deterministic
/// under a fixed seed; a zero `base_delay` never sleeps (the schedule is all
/// zeros because the jitter span collapses too).
pub fn retry_io_jittered<T>(
    attempts: u32,
    base_delay: Duration,
    seed: u64,
    mut op: impl FnMut() -> Result<T, String>,
) -> Result<T, String> {
    let attempts = attempts.max(1);
    let schedule = backoff_schedule(attempts, base_delay, seed);
    let mut last_err = String::new();
    for attempt in 0..attempts {
        match op() {
            Ok(v) => return Ok(v),
            Err(e) => last_err = e,
        }
        if let Some(delay) = schedule.get(attempt as usize) {
            if !delay.is_zero() {
                std::thread::sleep(*delay);
            }
        }
    }
    Err(format!("after {attempts} attempts: {last_err}"))
}

/// Would `strategy` execute on `acc` under the strict step semantics
/// (including the `MemoryOverflow` check)? Errors — not just overflow —
/// all read as "does not fit"; the caller degrades further.
fn feasible(layer: &ConvLayer, acc: &Accelerator, strategy: &GroupedStrategy) -> bool {
    Simulator::new(*layer, Platform::new(*acc)).run(strategy).is_ok()
}

/// Largest group bound whose §7.1 working set (kernels + `g` input patches
/// + `g` outputs) fits `acc.size_mem`, additionally capped by the compute
/// bound `nb_patches_max_S1`; at least 1.
pub fn memory_group_bound(layer: &ConvLayer, acc: &Accelerator) -> usize {
    let per_patch = (layer.input_elements_per_patch() + layer.c_out()) as u64;
    let spare = acc.size_mem.saturating_sub(layer.kernel_elements() as u64);
    let by_mem = (spare / per_patch.max(1)) as usize;
    by_mem.min(acc.max_patches_per_step(layer)).max(1)
}

/// Re-validate a planned strategy against a **shrunk** accelerator and
/// degrade as little as possible (see the module docs for the ladder).
/// Deterministic: same inputs, same outcome, no RNG beyond the portfolio's
/// own seeded lanes.
///
/// `degraded` is the reduced-budget accelerator (from
/// [`crate::optimizer::degraded_accelerator`]); `group` is the original
/// race's group bound; `opts` supplies the portfolio configuration for the
/// re-race stage.
pub fn degrade_for_shrink(
    layer: &ConvLayer,
    degraded: &Accelerator,
    group: usize,
    entry: &CachedStrategy,
    opts: &PlanOptions,
) -> (CachedStrategy, DegradeOutcome) {
    // Stage 0: the plan may survive the shrink untouched (slack memory).
    if feasible(layer, degraded, &entry.strategy) {
        return (entry.clone(), DegradeOutcome::Unchanged);
    }

    let overlapped = degraded.overlap == OverlapMode::DoubleBuffered;
    let bound = memory_group_bound(layer, degraded).min(group.max(1));

    // Stage 1: local re-grouping — split every visit-order group into
    // chunks of at most the reduced bound. Keeps the winner's ordering
    // structure (and most of its overlap savings) at zero search cost.
    let mut chunks: Vec<Vec<_>> = Vec::new();
    for g in &entry.strategy.groups {
        for c in g.chunks(bound) {
            chunks.push(c.to_vec());
        }
    }
    let regrouped =
        GroupedStrategy::new(format!("{}+regroup", entry.strategy.name), chunks);
    if feasible(layer, degraded, &regrouped) {
        let loaded_pixels = grouping_loads(layer, &regrouped.groups);
        let makespan =
            overlapped.then(|| grouping_makespan(layer, degraded, &regrouped.groups));
        let winner = format!("{}+regroup", entry.winner);
        return (
            CachedStrategy { strategy: regrouped, loaded_pixels, makespan, winner },
            DegradeOutcome::Regrouped,
        );
    }

    // Stage 2: full inline re-race under the reduced budget. Same portfolio,
    // same deterministic strictly-less reduction as the batch resolver;
    // lanes that still don't fit the shrunk memory are skipped.
    let entries = portfolio_entries(opts.seed, opts.anneal_iters, opts.anneal_starts);
    let k = layer.n_patches().div_ceil(bound);
    let mut best: Option<PortfolioResult> = None;
    for e in &entries {
        let r = run_entry(layer, degraded, bound, k, e);
        if !feasible(layer, degraded, &r.strategy) {
            continue;
        }
        let better = match &best {
            None => true,
            Some(b) => {
                if overlapped {
                    (r.makespan, r.loaded_pixels) < (b.makespan, b.loaded_pixels)
                } else {
                    r.loaded_pixels < b.loaded_pixels
                }
            }
        };
        if better {
            best = Some(r);
        }
    }
    match best {
        Some(b) => {
            let winner = format!("{}+rerace", b.label);
            (
                CachedStrategy {
                    strategy: b.strategy,
                    loaded_pixels: b.loaded_pixels,
                    makespan: b.makespan,
                    winner,
                },
                DegradeOutcome::Reraced,
            )
        }
        // Every lane infeasible: the budget floor guarantees a single-patch
        // step fits, so fall back to the regrouped plan (bound 1 chunks of
        // the winner) rather than failing the batch.
        None => {
            let mut singles: Vec<Vec<_>> = Vec::new();
            for g in &entry.strategy.groups {
                for c in g.chunks(1) {
                    singles.push(c.to_vec());
                }
            }
            let strategy =
                GroupedStrategy::new(format!("{}+serialize", entry.strategy.name), singles);
            let loaded_pixels = grouping_loads(layer, &strategy.groups);
            let makespan =
                overlapped.then(|| grouping_makespan(layer, degraded, &strategy.groups));
            let winner = format!("{}+serialize", entry.winner);
            (
                CachedStrategy { strategy, loaded_pixels, makespan, winner },
                DegradeOutcome::Reraced,
            )
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optimizer::degraded_accelerator;
    use crate::strategy;

    #[test]
    fn retry_io_returns_first_success() {
        let mut calls = 0;
        let r = retry_io(5, Duration::ZERO, || {
            calls += 1;
            if calls < 3 { Err(format!("transient {calls}")) } else { Ok(calls) }
        });
        assert_eq!(r, Ok(3));
        assert_eq!(calls, 3);
    }

    #[test]
    fn retry_io_surfaces_the_last_error() {
        let mut calls = 0;
        let r: Result<(), String> = retry_io(3, Duration::ZERO, || {
            calls += 1;
            Err(format!("fail {calls}"))
        });
        assert_eq!(calls, 3);
        let msg = r.unwrap_err();
        assert!(msg.contains("after 3 attempts"), "{msg}");
        assert!(msg.contains("fail 3"), "{msg}");
    }

    #[test]
    fn retry_io_clamps_zero_attempts_to_one() {
        let mut calls = 0;
        let _: Result<(), String> = retry_io(0, Duration::ZERO, || {
            calls += 1;
            Err("x".into())
        });
        assert_eq!(calls, 1);
    }

    /// The jittered schedule is a pure function of (attempts, base, seed) —
    /// pinned bit-exactly here and in the Python oracle
    /// (`test_server_oracle.py`), so concurrent clients seeded differently
    /// provably desynchronize while any one client stays deterministic.
    #[test]
    fn backoff_schedule_is_pinned_per_seed() {
        let s = backoff_schedule(4, Duration::from_micros(2000), 42);
        assert_eq!(
            s,
            vec![
                Duration::from_micros(2167),
                Duration::from_micros(5516),
                Duration::from_micros(13441),
            ]
        );
        let s = backoff_schedule(3, Duration::from_micros(500), 7);
        assert_eq!(s, vec![Duration::from_micros(850), Duration::from_micros(1279)]);
        // Jitter is bounded by one extra base step: base·2^i ≤ d_i ≤ base·2^(i+1).
        for (i, d) in backoff_schedule(6, Duration::from_micros(100), 99)
            .iter()
            .enumerate()
        {
            let lo = 100u64 << i;
            assert!(d.as_micros() as u64 >= lo && d.as_micros() as u64 <= 2 * lo);
        }
        // Different seeds give different schedules (the whole point).
        assert_ne!(
            backoff_schedule(4, Duration::from_micros(2000), 1),
            backoff_schedule(4, Duration::from_micros(2000), 2)
        );
        // Zero base delay never sleeps.
        assert!(backoff_schedule(4, Duration::ZERO, 42).iter().all(Duration::is_zero));
    }

    #[test]
    fn retry_io_jittered_retries_and_surfaces_errors() {
        let mut calls = 0;
        let r = retry_io_jittered(5, Duration::ZERO, 42, || {
            calls += 1;
            if calls < 3 { Err(format!("transient {calls}")) } else { Ok(calls) }
        });
        assert_eq!(r, Ok(3));
        let mut calls = 0;
        let r: Result<(), String> = retry_io_jittered(3, Duration::ZERO, 42, || {
            calls += 1;
            Err(format!("fail {calls}"))
        });
        assert_eq!(calls, 3);
        assert!(r.unwrap_err().contains("after 3 attempts"));
    }

    #[test]
    fn chaos_spec_defaults_inactive() {
        assert!(!ChaosSpec::default().is_active());
        let c = ChaosSpec { panic_lane: Some("greedy".into()) };
        assert!(c.is_active());
    }

    fn sample_entry(layer: &ConvLayer, group: usize) -> CachedStrategy {
        let s = strategy::zigzag(layer, group);
        let loaded_pixels = grouping_loads(layer, &s.groups);
        CachedStrategy {
            strategy: s,
            loaded_pixels,
            makespan: None,
            winner: "zigzag".to_string(),
        }
    }

    /// No shrink (or slack memory): the plan is returned untouched.
    #[test]
    fn slack_memory_keeps_the_plan_unchanged() {
        let l = ConvLayer::square(1, 6, 3, 1);
        let acc = Accelerator {
            size_mem: Accelerator::for_group_size(&l, 2).size_mem + 100,
            ..Accelerator::for_group_size(&l, 2)
        };
        let entry = sample_entry(&l, 2);
        let degraded = degraded_accelerator(&l, &acc, 50); // still ≥ the g=2 set
        let (out, outcome) = degrade_for_shrink(&l, &degraded, 2, &entry, &quick_opts());
        assert_eq!(outcome, DegradeOutcome::Unchanged);
        assert_eq!(out, entry);
    }

    fn quick_opts() -> PlanOptions {
        PlanOptions {
            anneal_iters: 200,
            anneal_starts: 1,
            ..PlanOptions::default()
        }
    }

    /// A shrink below the planned working set degrades deterministically to
    /// a feasible strategy covering every patch, and never writes back.
    #[test]
    fn shrink_below_working_set_degrades_to_a_feasible_plan() {
        let l = ConvLayer::square(1, 6, 3, 1);
        let acc = Accelerator::for_group_size(&l, 4); // sized exactly for g=4
        let entry = sample_entry(&l, 4);
        assert!(feasible(&l, &acc, &entry.strategy), "healthy plan runs");
        // Shrink by two patches' worth: g=4 groups no longer fit.
        let shrink = 2 * (l.input_elements_per_patch() + l.c_out()) as u64;
        let degraded = degraded_accelerator(&l, &acc, shrink);
        assert!(!feasible(&l, &degraded, &entry.strategy), "shrink must bite");
        let (out, outcome) = degrade_for_shrink(&l, &degraded, 4, &entry, &quick_opts());
        assert_ne!(outcome, DegradeOutcome::Unchanged);
        assert!(feasible(&l, &degraded, &out.strategy), "degraded plan fits");
        let mut all: Vec<u32> = out.strategy.groups.iter().flatten().copied().collect();
        all.sort();
        assert_eq!(all, l.all_patches().collect::<Vec<_>>(), "coverage survives");
        assert_eq!(out.loaded_pixels, grouping_loads(&l, &out.strategy.groups));
        assert!(
            out.winner.contains("+regroup") || out.winner.contains("+rerace") ||
            out.winner.contains("+serialize"),
            "provenance records the degrade: {}",
            out.winner
        );
        // Determinism: the degrade ladder is a pure function of its inputs.
        let (again, outcome2) = degrade_for_shrink(&l, &degraded, 4, &entry, &quick_opts());
        assert_eq!(out, again);
        assert_eq!(outcome, outcome2);
    }

    /// A worst-case shrink (budget at the single-patch floor) still yields
    /// an executable plan.
    #[test]
    fn shrink_to_the_floor_still_plans() {
        let l = ConvLayer::square(1, 6, 3, 1);
        let acc = Accelerator::for_group_size(&l, 4);
        let entry = sample_entry(&l, 4);
        let degraded = degraded_accelerator(&l, &acc, u64::MAX);
        assert_eq!(degraded.size_mem, Accelerator::for_group_size(&l, 1).size_mem);
        let (out, outcome) = degrade_for_shrink(&l, &degraded, 4, &entry, &quick_opts());
        assert_ne!(outcome, DegradeOutcome::Unchanged);
        assert!(feasible(&l, &degraded, &out.strategy), "floor plan executes");
        assert!(out.strategy.groups.iter().all(|g| g.len() == 1));
    }

    /// The memory bound honours both the memory and the compute cap.
    #[test]
    fn memory_group_bound_is_capped_by_both_resources() {
        let l = ConvLayer::square(1, 6, 3, 1);
        let acc = Accelerator::for_group_size(&l, 4);
        assert_eq!(memory_group_bound(&l, &acc), 4);
        // Double the memory: still capped by nbop_PE at 4.
        let roomy = Accelerator { size_mem: acc.size_mem * 2, ..acc };
        assert_eq!(memory_group_bound(&l, &roomy), 4);
        // Shrink one patch's worth: memory caps it at 3.
        let per = (l.input_elements_per_patch() + l.c_out()) as u64;
        let tight = Accelerator { size_mem: acc.size_mem - per, ..acc };
        assert_eq!(memory_group_bound(&l, &tight), 3);
        // Pathologically tiny memory: floored at 1.
        let tiny = Accelerator { size_mem: 1, ..acc };
        assert_eq!(memory_group_bound(&l, &tiny), 1);
    }
}

//! The per-layer portfolio race.
//!
//! Each layer is optimized by racing a fixed, ordered list of candidate
//! generators ([`PortfolioEntry`]): the four §4.2 patch orderings, the greedy
//! construction, and `anneal_starts` simulated-annealing lanes under
//! consecutive seeds. Every lane is self-contained (no cross-lane data flow),
//! so lanes can run on any thread in any order; the planner reduces the
//! results by `(loaded pixels, entry index)` — never by completion order —
//! which makes the race deterministic under arbitrary scheduling.
//!
//! The greedy and annealing lanes run on the delta-evaluated search engine
//! (`optimizer::search` propose-score-commit over the order-invariant
//! [`crate::optimizer::GroupingEval`]): far cheaper per iteration, with RNG
//! streams and trajectories bit-identical to the pre-delta implementation —
//! the same `(seed, iters)` still yields the same strategy, so cached plans
//! and the determinism contract survive the engine swap. Spend the speedup
//! on search quality by raising `iters` (`plan-network --thorough` = 3×).

use std::sync::atomic::AtomicBool;

use crate::conv::ConvLayer;
use crate::optimizer::{grouping_loads, grouping_makespan, search};
use crate::platform::{Accelerator, OverlapMode};
use crate::strategy::{self, GroupedStrategy, Ordering};

/// One lane of the race.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum PortfolioEntry {
    /// One of the built-in patch orderings, chunked to the group bound.
    Ordering(Ordering),
    /// The greedy max-overlap construction ([`search::greedy`]).
    Greedy,
    /// Annealing polish ([`search::anneal`]) from the best *ordering* start
    /// (recomputed in-lane so the lane stays independent; the greedy start
    /// races in its own lane).
    Anneal { seed: u64, iters: u64 },
}

impl PortfolioEntry {
    /// Stable human-readable lane label (used in reports and cache files).
    pub fn label(&self) -> String {
        match self {
            PortfolioEntry::Ordering(o) => o.as_str().to_string(),
            PortfolioEntry::Greedy => "greedy".to_string(),
            PortfolioEntry::Anneal { seed, .. } => format!("anneal-s{seed}"),
        }
    }
}

/// The fixed portfolio: Row-by-Row, ZigZag, Hilbert, diagonal, greedy, then
/// `anneal_starts` annealing lanes seeded `seed`, `seed + 1`, ….
///
/// The order is part of the planner's determinism contract: ties in the
/// race's reduction break toward the lower index in *this* list.
pub fn portfolio_entries(seed: u64, iters: u64, anneal_starts: usize) -> Vec<PortfolioEntry> {
    let mut entries: Vec<PortfolioEntry> = Ordering::all()
        .into_iter()
        .map(PortfolioEntry::Ordering)
        .collect();
    entries.push(PortfolioEntry::Greedy);
    for i in 0..anneal_starts {
        entries.push(PortfolioEntry::Anneal { seed: seed + i as u64, iters });
    }
    entries
}

/// Outcome of one lane.
#[derive(Debug, Clone)]
pub struct PortfolioResult {
    /// The lane's strategy.
    pub strategy: GroupedStrategy,
    /// The sequential race objective: total spatial input pixels loaded
    /// (Eq. 15's bandwidth term divided by `t_l · C_in`).
    pub loaded_pixels: u64,
    /// The §3.7 overlapped makespan of the strategy — computed exactly when
    /// the accelerator is double-buffered (the primary race metric then,
    /// with `loaded_pixels` as the tie-break).
    pub makespan: Option<u64>,
    /// Stable lane label (provenance for reports and cache files).
    pub label: String,
    /// Annealing iterations this lane executed (0 for heuristic lanes).
    pub anneal_iters: u64,
}

/// Run one lane to completion. Pure function of its arguments — safe to call
/// from any worker thread.
///
/// The accelerator's [`OverlapMode`] selects the lane objective: sequential
/// machines race loaded pixels (annealing with [`search::anneal`], streams
/// bit-identical to every earlier release); double-buffered machines race
/// the overlapped makespan (annealing with [`search::anneal_duration`], the
/// start picked by makespan too), and `makespan` is filled for the
/// planner's reduction.
pub fn run_entry(
    layer: &ConvLayer,
    acc: &Accelerator,
    group_size: usize,
    k: usize,
    entry: &PortfolioEntry,
) -> PortfolioResult {
    run_entry_cancel(layer, acc, group_size, k, entry, None)
}

/// [`run_entry`] with a cooperative cancel flag (a deadline token).
///
/// Annealing lanes poll the flag every [`search::CANCEL_CHECK_PERIOD`]
/// iterations and return their best-so-far grouping when it fires, with
/// `anneal_iters` reporting the iterations actually executed. The polls sit
/// *before* any RNG draw, so a lane whose flag never fires is bit-identical
/// to [`run_entry`] — including its RNG stream. Heuristic lanes are cheap
/// and always run to completion.
pub fn run_entry_cancel(
    layer: &ConvLayer,
    acc: &Accelerator,
    group_size: usize,
    k: usize,
    entry: &PortfolioEntry,
    cancel: Option<&AtomicBool>,
) -> PortfolioResult {
    let overlapped = acc.overlap == OverlapMode::DoubleBuffered;
    let (strategy, anneal_iters) = match entry {
        PortfolioEntry::Ordering(o) => (strategy::from_ordering(layer, *o, group_size), 0),
        PortfolioEntry::Greedy => (
            GroupedStrategy::new("greedy", search::greedy(layer, group_size, k)),
            0,
        ),
        PortfolioEntry::Anneal { seed, iters } => {
            let start = Ordering::all()
                .into_iter()
                .map(|o| {
                    let s = strategy::from_ordering(layer, o, group_size);
                    let d = if overlapped {
                        grouping_makespan(layer, acc, &s.groups)
                    } else {
                        grouping_loads(layer, &s.groups)
                    };
                    (s, d)
                })
                .min_by_key(|&(_, d)| d)
                .expect("at least one ordering");
            let (groups, ran) = match (overlapped, cancel) {
                (true, Some(flag)) => search::anneal_duration_cancellable(
                    layer,
                    acc,
                    group_size,
                    k,
                    &start.0.groups,
                    *iters,
                    *seed,
                    flag,
                ),
                (true, None) => (
                    search::anneal_duration(
                        layer,
                        acc,
                        group_size,
                        k,
                        &start.0.groups,
                        *iters,
                        *seed,
                    ),
                    *iters,
                ),
                (false, Some(flag)) => search::anneal_cancellable(
                    layer,
                    group_size,
                    k,
                    &start.0.groups,
                    *iters,
                    *seed,
                    flag,
                ),
                (false, None) => (
                    search::anneal(layer, group_size, k, &start.0.groups, *iters, *seed),
                    *iters,
                ),
            };
            (GroupedStrategy::new(format!("anneal-s{seed}"), groups), ran)
        }
    };
    let loaded_pixels = grouping_loads(layer, &strategy.groups);
    let makespan = overlapped.then(|| grouping_makespan(layer, acc, &strategy.groups));
    PortfolioResult {
        strategy,
        loaded_pixels,
        makespan,
        label: entry.label(),
        anneal_iters,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn portfolio_order_is_stable() {
        let entries = portfolio_entries(100, 10, 2);
        let labels: Vec<String> = entries.iter().map(PortfolioEntry::label).collect();
        assert_eq!(
            labels,
            vec![
                "row-by-row",
                "zigzag",
                "hilbert",
                "diagonal",
                "greedy",
                "anneal-s100",
                "anneal-s101"
            ]
        );
    }

    #[test]
    fn every_lane_produces_a_valid_strategy() {
        let l = ConvLayer::square(1, 7, 3, 1); // 25 patches
        let g = 3;
        let k = l.n_patches().div_ceil(g);
        let acc = Accelerator::for_group_size(&l, g);
        for entry in portfolio_entries(7, 500, 1) {
            let r = run_entry(&l, &acc, g, k, &entry);
            let mut all: Vec<u32> = r.strategy.groups.iter().flatten().copied().collect();
            all.sort();
            assert_eq!(all, l.all_patches().collect::<Vec<_>>(), "{}", r.label);
            assert!(r.strategy.groups.iter().all(|gr| gr.len() <= g));
            assert_eq!(r.loaded_pixels, grouping_loads(&l, &r.strategy.groups));
            assert_eq!(r.makespan, None, "sequential lanes carry no makespan");
        }
    }

    /// Double-buffered lanes fill the makespan metric, stay valid, and the
    /// annealing lane never loses to its own ordering starts in that metric.
    #[test]
    fn double_buffered_lanes_race_the_makespan() {
        let l = ConvLayer::square(1, 7, 3, 1);
        let g = 3;
        let k = l.n_patches().div_ceil(g);
        let acc = Accelerator { t_acc: 4, ..Accelerator::for_group_size(&l, g) }
            .with_overlap(OverlapMode::DoubleBuffered);
        let mut ordering_best = u64::MAX;
        let mut anneal_makespan = u64::MAX;
        for entry in portfolio_entries(7, 2_000, 1) {
            let r = run_entry(&l, &acc, g, k, &entry);
            let m = r.makespan.expect("double-buffered lanes carry a makespan");
            assert_eq!(m, grouping_makespan(&l, &acc, &r.strategy.groups), "{}", r.label);
            let mut all: Vec<u32> = r.strategy.groups.iter().flatten().copied().collect();
            all.sort();
            assert_eq!(all, l.all_patches().collect::<Vec<_>>(), "{}", r.label);
            match entry {
                PortfolioEntry::Ordering(_) => ordering_best = ordering_best.min(m),
                PortfolioEntry::Anneal { .. } => anneal_makespan = m,
                PortfolioEntry::Greedy => {}
            }
        }
        assert!(
            anneal_makespan <= ordering_best,
            "anneal lane ({anneal_makespan}) must not lose to its ordering starts ({ordering_best})"
        );
    }

    /// The heuristic lanes must stay in lock-step with
    /// [`crate::optimizer::heuristic_pool`] (same candidates, same order):
    /// the optimizer's seed phase and the planner's race — and therefore the
    /// cache keys and the determinism contract — all assume it.
    #[test]
    fn first_lanes_match_the_optimizer_heuristic_pool() {
        let l = ConvLayer::square(1, 7, 3, 1); // 25 patches
        let (g, k) = (3usize, 9usize);
        let acc = Accelerator::for_group_size(&l, g);
        let pool = crate::optimizer::heuristic_pool(&l, g, k);
        let entries = portfolio_entries(1, 10, 0); // heuristic lanes only
        assert_eq!(entries.len(), pool.len());
        for (e, want) in entries.iter().zip(&pool) {
            assert_eq!(&run_entry(&l, &acc, g, k, e).strategy, want, "{}", e.label());
        }
    }

    /// An unfired cancel flag leaves every lane bit-identical to the plain
    /// path; a pre-fired flag cuts the annealing lanes to zero iterations
    /// while still returning a valid (normalized-start) strategy.
    #[test]
    fn cancel_flag_degrades_anneal_lanes_gracefully() {
        use std::sync::atomic::Ordering as AtomicOrdering;
        let l = ConvLayer::square(1, 7, 3, 1);
        let g = 3;
        let k = l.n_patches().div_ceil(g);
        let acc = Accelerator::for_group_size(&l, g);
        let unfired = AtomicBool::new(false);
        let fired = AtomicBool::new(true);
        for entry in portfolio_entries(7, 3_000, 2) {
            let plain = run_entry(&l, &acc, g, k, &entry);
            let same = run_entry_cancel(&l, &acc, g, k, &entry, Some(&unfired));
            assert_eq!(plain.strategy, same.strategy, "{}", plain.label);
            assert_eq!(plain.anneal_iters, same.anneal_iters);

            let cut = run_entry_cancel(&l, &acc, g, k, &entry, Some(&fired));
            if matches!(entry, PortfolioEntry::Anneal { .. }) {
                assert_eq!(cut.anneal_iters, 0, "{}", cut.label);
            } else {
                assert_eq!(cut.strategy, plain.strategy, "{}", cut.label);
            }
            let mut all: Vec<u32> = cut.strategy.groups.iter().flatten().copied().collect();
            all.sort();
            assert_eq!(all, l.all_patches().collect::<Vec<_>>(), "{}", cut.label);
            assert_eq!(cut.loaded_pixels, grouping_loads(&l, &cut.strategy.groups));
        }
        assert!(!unfired.load(AtomicOrdering::Relaxed), "lanes never set the flag");
    }

    #[test]
    fn anneal_lane_is_deterministic() {
        let l = ConvLayer::square(1, 6, 3, 1);
        let g = 2;
        let k = l.n_patches().div_ceil(g);
        let e = PortfolioEntry::Anneal { seed: 42, iters: 2_000 };
        for acc in [
            Accelerator::for_group_size(&l, g),
            Accelerator::for_group_size(&l, g).with_overlap(OverlapMode::DoubleBuffered),
        ] {
            let a = run_entry(&l, &acc, g, k, &e);
            let b = run_entry(&l, &acc, g, k, &e);
            assert_eq!(a.strategy, b.strategy, "{}", acc.overlap.as_str());
            assert_eq!(a.loaded_pixels, b.loaded_pixels);
            assert_eq!(a.makespan, b.makespan);
            assert_eq!(a.anneal_iters, 2_000);
        }
    }
}

//! Sharded, lock-striped, persistent strategy cache — the service-grade
//! backend behind [`crate::planner::BatchPlanner`].
//!
//! The one-file-per-key [`StrategyCache`](super::StrategyCache) is fine for
//! one CLI process, but a batch/service workload hammers the cache from many
//! worker threads at once. This cache stripes the key space over `N` shards
//! (FNV-1a of the canonical key, modulo `N`): each shard is one `Mutex`
//! around an in-memory entry map plus one JSON file on disk, so concurrent
//! lookups of *different* shards never contend and a write locks 1/N of the
//! key space instead of all of it.
//!
//! **Locking discipline.** Exactly one shard mutex is ever held at a time —
//! there is no operation spanning two shards, so lock ordering (and with it
//! deadlock) cannot arise by construction. The hit/miss/eviction counters
//! ([`CacheCounters`]) are relaxed atomics updated outside any lock; the
//! meta file is written once under `open`'s directory-creation path only.
//!
//! **Persistence.** Every `put` rewrites its shard file through temp-file +
//! atomic rename ([`atomic_write`]), so a crash mid-write leaves the
//! previous complete shard, never a truncated one. Loads are
//! corruption-tolerant at two granularities: an unreadable shard *file*
//! degrades to an empty shard (counted in `corrupt_shards`) without touching
//! any other shard, and a malformed *entry* inside an otherwise readable
//! shard is skipped while its neighbours survive.
//!
//! **Shard count.** Default 16: enough stripes that the portfolio pool's
//! default ≤ 16 workers rarely collide on one mutex, while keeping the
//! directory at a glanceable file count and each shard file large enough to
//! amortize the rewrite-on-put. The count is recorded in `cache-meta.json`
//! and re-read on open, so a directory keeps its geometry even when later
//! callers ask for a different one (re-routing keys across a different
//! modulus would orphan every existing entry).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::Ordering;
use std::sync::{Arc, Mutex};

use crate::metrics::{CacheCounterSnapshot, CacheCounters};
use crate::util::fsio::{atomic_write, sweep_orphan_temps};
use crate::util::hash::fnv1a64;
use crate::util::json::{self, Json};

use super::cache::{entry_from_json, entry_to_json, CacheKey, CachedStrategy, StrategyStore};
use super::recovery::retry_io_jittered;

/// Default number of lock stripes / shard files.
pub const DEFAULT_SHARDS: usize = 16;

/// Default per-shard entry capacity (FIFO eviction beyond it). 512 entries ×
/// 16 shards comfortably covers every preset zoo and fuzz corpus in-tree;
/// a service deployment can raise it via [`ShardedStrategyCache::open_with`].
pub const DEFAULT_SHARD_CAPACITY: usize = 512;

/// One stored entry plus its insertion sequence (FIFO eviction order).
#[derive(Debug, Clone)]
struct Stored {
    entry: CachedStrategy,
    seq: u64,
}

/// The state behind one shard's mutex.
#[derive(Debug, Default)]
struct ShardState {
    /// Canonical key → stored entry. Loaded lazily from the shard file on
    /// first access.
    entries: BTreeMap<String, Stored>,
    /// Monotonic insertion counter feeding `Stored::seq`.
    next_seq: u64,
    /// Whether the shard file has been read (or found absent/corrupt).
    loaded: bool,
}

#[derive(Debug)]
struct Inner {
    dir: PathBuf,
    shards: Vec<Mutex<ShardState>>,
    capacity: usize,
    counters: Arc<CacheCounters>,
}

/// Sharded, lock-striped strategy cache with per-shard file persistence.
///
/// Cloning is cheap and shares the stripes *and* the counters — hand clones
/// to every planner/worker that should see one coherent cache.
#[derive(Debug, Clone)]
pub struct ShardedStrategyCache {
    inner: Arc<Inner>,
}

impl ShardedStrategyCache {
    /// Open (creating if needed) a sharded cache directory with the default
    /// geometry ([`DEFAULT_SHARDS`] × [`DEFAULT_SHARD_CAPACITY`]).
    pub fn open(dir: &Path) -> Result<ShardedStrategyCache, String> {
        Self::open_with(dir, DEFAULT_SHARDS, DEFAULT_SHARD_CAPACITY)
    }

    /// Open with an explicit shard count (clamped to 1..=256) and per-shard
    /// capacity (≥ 1). If the directory already carries a `cache-meta.json`,
    /// its recorded shard count wins — the on-disk layout is authoritative,
    /// because re-routing keys under a different modulus would orphan every
    /// existing entry.
    pub fn open_with(
        dir: &Path,
        shards: usize,
        capacity: usize,
    ) -> Result<ShardedStrategyCache, String> {
        std::fs::create_dir_all(dir)
            .map_err(|e| format!("create cache dir {}: {e}", dir.display()))?;
        // Writers killed between temp-create and rename leave
        // `.shard-NNN.json.tmp-*` orphans behind; sweep the dead ones so the
        // directory stays one-file-per-shard across crashes.
        sweep_orphan_temps(dir);
        let requested = shards.clamp(1, 256);
        let meta_path = dir.join("cache-meta.json");
        let n = match std::fs::read_to_string(&meta_path)
            .ok()
            .and_then(|t| json::parse(&t).ok())
            .and_then(|v| v.get("shards").and_then(Json::as_usize))
        {
            Some(existing) => existing.clamp(1, 256),
            None => {
                let mut meta = Json::obj();
                meta.set("version", "sharded-cache-v1").set("shards", requested);
                atomic_write(&meta_path, &meta.to_string_pretty())?;
                requested
            }
        };
        let inner = Inner {
            dir: dir.to_path_buf(),
            shards: (0..n).map(|_| Mutex::new(ShardState::default())).collect(),
            capacity: capacity.max(1),
            counters: Arc::new(CacheCounters::new()),
        };
        Ok(ShardedStrategyCache { inner: Arc::new(inner) })
    }

    /// The directory backing this cache.
    pub fn dir(&self) -> &Path {
        &self.inner.dir
    }

    /// Number of lock stripes / shard files.
    pub fn shard_count(&self) -> usize {
        self.inner.shards.len()
    }

    /// Live counters (shared by all clones of this cache).
    pub fn counters(&self) -> Arc<CacheCounters> {
        Arc::clone(&self.inner.counters)
    }

    /// Point-in-time counter snapshot for reports.
    pub fn stats(&self) -> CacheCounterSnapshot {
        self.inner.counters.snapshot()
    }

    /// Total entries currently resident (forces every shard to load).
    pub fn len(&self) -> usize {
        (0..self.shard_count())
            .map(|i| {
                let mut s = self.lock_shard(i);
                self.ensure_loaded(i, &mut s);
                s.entries.len()
            })
            .sum()
    }

    /// True when no shard holds an entry.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    fn shard_index(&self, key: &CacheKey) -> usize {
        (fnv1a64(key.canonical().as_bytes()) % self.shard_count() as u64) as usize
    }

    fn shard_path(&self, index: usize) -> PathBuf {
        self.inner.dir.join(format!("shard-{index:03}.json"))
    }

    /// Lock shard `index`, recovering from lock poisoning. A poisoned mutex
    /// means some holder panicked mid-mutation, so its in-memory map may be
    /// half-updated: quarantine it — discard the state and mark the shard
    /// unloaded so the next access rebuilds it from the persisted file
    /// (which is always a complete generation thanks to [`atomic_write`]).
    /// The event is tallied in `quarantined_shards`.
    fn lock_shard(&self, index: usize) -> std::sync::MutexGuard<'_, ShardState> {
        match self.inner.shards[index].lock() {
            Ok(guard) => guard,
            Err(poisoned) => {
                let mut guard = poisoned.into_inner();
                *guard = ShardState::default();
                self.inner.shards[index].clear_poison();
                self.inner
                    .counters
                    .quarantined_shards
                    .fetch_add(1, Ordering::Relaxed);
                guard
            }
        }
    }

    /// Chaos hook: poison shard `index`'s mutex exactly the way a crashed
    /// planner worker would — a helper thread takes the lock and panics
    /// while holding it. Used by the recovery tests and the CI chaos job;
    /// harmless in production (the next [`Self::lock_shard`] quarantines
    /// and reloads the shard).
    pub fn chaos_poison_shard(&self, index: usize) {
        let index = index % self.shard_count();
        let cache = self.clone();
        let handle = std::thread::spawn(move || {
            let _guard = cache.inner.shards[index]
                .lock()
                .unwrap_or_else(|p| p.into_inner());
            panic!("chaos: poisoning shard {index}");
        });
        let _ = handle.join();
    }

    /// Load the shard file into `state` if not yet done. An unreadable file
    /// (missing is fine and silent; malformed counts as corrupt) yields an
    /// empty shard; a malformed entry inside a readable file is skipped.
    fn ensure_loaded(&self, index: usize, state: &mut ShardState) {
        if state.loaded {
            return;
        }
        state.loaded = true;
        let path = self.shard_path(index);
        let text = match std::fs::read_to_string(&path) {
            Ok(t) => t,
            Err(_) => return, // absent: a fresh shard, not corruption
        };
        let parsed = json::parse(&text).ok().filter(|v| {
            v.get("version").and_then(Json::as_str) == Some("sharded-cache-v1")
        });
        let Some(doc) = parsed else {
            self.inner.counters.corrupt_shards.fetch_add(1, Ordering::Relaxed);
            return;
        };
        let Some(arr) = doc.get("entries").and_then(Json::as_arr) else {
            self.inner.counters.corrupt_shards.fetch_add(1, Ordering::Relaxed);
            return;
        };
        for item in arr {
            // Per-entry tolerance: skip what does not parse, keep the rest.
            if let Some((key, entry)) = entry_from_json(item) {
                let seq = state.next_seq;
                state.next_seq += 1;
                state.entries.insert(key, Stored { entry, seq });
            }
        }
    }

    /// Serialize `state` (entries in insertion order, so FIFO age survives a
    /// round-trip) and persist it atomically. The write is retried with
    /// bounded backoff plus seeded jitter ([`retry_io_jittered`], seeded by
    /// the shard index) — shard files sit on real filesystems where transient
    /// `EAGAIN`-class failures are a fact of life, and concurrent clients
    /// retrying the *same* contended shard back off on the same (replayable)
    /// schedule instead of thundering-herding in lock-step.
    fn persist(&self, index: usize, state: &ShardState) -> Result<(), String> {
        let mut ordered: Vec<(&String, &Stored)> = state.entries.iter().collect();
        ordered.sort_by_key(|(_, s)| s.seq);
        let mut rows = Vec::with_capacity(ordered.len());
        for (key, stored) in ordered {
            rows.push(entry_to_json(key, &stored.entry)?);
        }
        let mut doc = Json::obj();
        doc.set("version", "sharded-cache-v1")
            .set("shard", index)
            .set("entries", Json::Arr(rows));
        let text = doc.to_string_pretty();
        let path = self.shard_path(index);
        retry_io_jittered(3, std::time::Duration::from_millis(2), index as u64, || {
            atomic_write(&path, &text)
        })
    }

    /// Force every shard to load from disk now (a warm reopen): the
    /// long-lived server calls this once at startup so the first request
    /// after a restart pays no lazy-load latency and `stats` reflects the
    /// persisted population. Returns the number of resident entries.
    pub fn warm_load(&self) -> usize {
        self.len()
    }

    /// Persist every *loaded* shard to disk and report how many were
    /// written. Every `put` already writes through, so under normal
    /// operation this is a re-assertion of durability, not a correctness
    /// requirement — the server runs it on `shutdown` (and after journal
    /// replay) so a following crash cannot lose the warm state.
    pub fn flush(&self) -> Result<usize, String> {
        let mut written = 0;
        for i in 0..self.shard_count() {
            let state = self.lock_shard(i);
            if state.loaded && !state.entries.is_empty() {
                self.persist(i, &state)?;
                written += 1;
            }
        }
        Ok(written)
    }

    /// Look up a key; any unreadable state degrades to a miss.
    pub fn get(&self, key: &CacheKey) -> Option<CachedStrategy> {
        let i = self.shard_index(key);
        let mut state = self.lock_shard(i);
        self.ensure_loaded(i, &mut state);
        match state.entries.get(key.canonical()) {
            Some(stored) => {
                self.inner.counters.hits.fetch_add(1, Ordering::Relaxed);
                Some(stored.entry.clone())
            }
            None => {
                self.inner.counters.misses.fetch_add(1, Ordering::Relaxed);
                None
            }
        }
    }

    /// Insert (or overwrite) an entry and persist its shard atomically.
    /// Concurrent writers to the same key converge: the shard mutex
    /// serializes them and the last insertion wins with a complete file.
    pub fn put(&self, key: &CacheKey, entry: &CachedStrategy) -> Result<(), String> {
        let i = self.shard_index(key);
        let mut state = self.lock_shard(i);
        self.ensure_loaded(i, &mut state);
        let seq = state.next_seq;
        state.next_seq += 1;
        state
            .entries
            .insert(key.canonical().to_string(), Stored { entry: entry.clone(), seq });
        while state.entries.len() > self.inner.capacity {
            // FIFO eviction: drop the oldest insertion.
            let oldest = state
                .entries
                .iter()
                .min_by_key(|(_, s)| s.seq)
                .map(|(k, _)| k.clone())
                .expect("non-empty shard over capacity");
            state.entries.remove(&oldest);
            self.inner.counters.evictions.fetch_add(1, Ordering::Relaxed);
        }
        self.persist(i, &state)
    }
}

impl StrategyStore for ShardedStrategyCache {
    fn load(&self, key: &CacheKey) -> Option<CachedStrategy> {
        self.get(key)
    }

    fn store(&self, key: &CacheKey, entry: &CachedStrategy) -> Result<(), String> {
        self.put(key, entry)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::conv::ConvLayer;
    use crate::platform::{Accelerator, OverlapMode};
    use crate::strategy;

    fn tmp_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "convoffload-shard-test-{tag}-{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    fn sample(seed: u64) -> (ConvLayer, CacheKey, CachedStrategy) {
        let l = ConvLayer::square(1, 6, 3, 1);
        let acc = Accelerator::for_group_size(&l, 2);
        let key = CacheKey::new(&l, &acc, 2, 8, seed, 1_000, 2);
        let entry = CachedStrategy {
            strategy: strategy::zigzag(&l, 2),
            loaded_pixels: 57,
            makespan: None,
            winner: "zigzag".to_string(),
        };
        (l, key, entry)
    }

    #[test]
    fn roundtrip_within_and_across_opens() {
        let dir = tmp_dir("roundtrip");
        let cache = ShardedStrategyCache::open(&dir).unwrap();
        let (_, key, entry) = sample(1);
        assert!(cache.get(&key).is_none());
        cache.put(&key, &entry).unwrap();
        assert_eq!(cache.get(&key), Some(entry.clone()));
        // A fresh open over the same directory reads the persisted shard.
        let reopened = ShardedStrategyCache::open(&dir).unwrap();
        assert_eq!(reopened.get(&key), Some(entry));
        assert_eq!(reopened.stats().hits, 1);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn keys_spread_over_multiple_shards() {
        let dir = tmp_dir("spread");
        let cache = ShardedStrategyCache::open(&dir).unwrap();
        for seed in 0..64 {
            let (_, key, entry) = sample(seed);
            cache.put(&key, &entry).unwrap();
        }
        assert_eq!(cache.len(), 64);
        let shard_files = std::fs::read_dir(&dir)
            .unwrap()
            .filter(|e| {
                e.as_ref()
                    .unwrap()
                    .file_name()
                    .to_string_lossy()
                    .starts_with("shard-")
            })
            .count();
        assert!(shard_files > 1, "64 keys must stripe over > 1 shard file");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn meta_pins_the_shard_count_across_opens() {
        let dir = tmp_dir("meta");
        let a = ShardedStrategyCache::open_with(&dir, 4, 64).unwrap();
        assert_eq!(a.shard_count(), 4);
        let (_, key, entry) = sample(3);
        a.put(&key, &entry).unwrap();
        // Asking for a different count later must not re-route keys.
        let b = ShardedStrategyCache::open_with(&dir, 32, 64).unwrap();
        assert_eq!(b.shard_count(), 4, "meta file wins over the request");
        assert_eq!(b.get(&key), Some(entry));
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// A truncated / corrupted shard file loads as a miss — never a panic —
    /// and never poisons the other shards.
    #[test]
    fn corrupt_shard_degrades_to_empty_without_poisoning_others() {
        let dir = tmp_dir("corrupt");
        {
            let cache = ShardedStrategyCache::open_with(&dir, 4, 64).unwrap();
            for seed in 0..32 {
                let (_, key, entry) = sample(seed);
                cache.put(&key, &entry).unwrap();
            }
        }
        // Truncate exactly one shard file (simulated partial write).
        let (_, victim_key, _) = sample(0);
        let victim_cache = ShardedStrategyCache::open_with(&dir, 4, 64).unwrap();
        let victim_shard = victim_cache.shard_index(&victim_key);
        let victim_path = victim_cache.shard_path(victim_shard);
        let full = std::fs::read_to_string(&victim_path).unwrap();
        std::fs::write(&victim_path, &full[..full.len() / 3]).unwrap();

        let cache = ShardedStrategyCache::open_with(&dir, 4, 64).unwrap();
        let mut hits = 0;
        let mut misses = 0;
        for seed in 0..32 {
            let (_, key, _) = sample(seed);
            match cache.get(&key) {
                Some(_) => hits += 1,
                None => misses += 1,
            }
        }
        assert!(cache.get(&victim_key).is_none(), "victim shard reads as a miss");
        assert!(misses > 0, "victim shard lost its entries");
        assert!(
            hits >= 32 - misses && hits > 0,
            "other shards survive the corruption ({hits} hits, {misses} misses)"
        );
        assert_eq!(cache.stats().corrupt_shards, 1, "exactly one shard was corrupt");
        // A put into the corrupt shard rewrites it whole and recovers.
        let (_, key0, entry0) = sample(0);
        cache.put(&key0, &entry0).unwrap();
        assert_eq!(cache.get(&key0), Some(entry0));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn eviction_is_fifo_and_counted() {
        let dir = tmp_dir("evict");
        // 1 shard, capacity 4: the 5th insert evicts the 1st.
        let cache = ShardedStrategyCache::open_with(&dir, 1, 4).unwrap();
        let keys: Vec<CacheKey> = (0..5).map(|s| sample(s).1).collect();
        for seed in 0..5 {
            let (_, key, entry) = sample(seed);
            cache.put(&key, &entry).unwrap();
        }
        assert_eq!(cache.len(), 4);
        assert_eq!(cache.stats().evictions, 1);
        assert!(cache.get(&keys[0]).is_none(), "oldest entry was evicted");
        assert!(cache.get(&keys[4]).is_some(), "newest entry survives");
        // FIFO age survives persistence: reopen and push one more.
        let reopened = ShardedStrategyCache::open_with(&dir, 1, 4).unwrap();
        let (_, k5, e5) = sample(5);
        reopened.put(&k5, &e5).unwrap();
        assert!(reopened.get(&keys[1]).is_none(), "next-oldest evicted after reopen");
        assert!(reopened.get(&keys[2]).is_some());
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// Concurrent writers to the same key converge on one complete entry;
    /// concurrent writers to different keys in one shard all land.
    #[test]
    fn concurrent_writers_converge() {
        let dir = tmp_dir("concurrent");
        let cache = ShardedStrategyCache::open_with(&dir, 2, 256).unwrap();
        let (_, shared_key, shared_entry) = sample(7);
        std::thread::scope(|scope| {
            for t in 0..8u64 {
                let cache = cache.clone();
                let shared_key = shared_key.clone();
                let shared_entry = shared_entry.clone();
                scope.spawn(move || {
                    for i in 0..8 {
                        cache.put(&shared_key, &shared_entry).unwrap();
                        let (_, own_key, own_entry) = sample(100 + t * 10 + i);
                        cache.put(&own_key, &own_entry).unwrap();
                    }
                });
            }
        });
        assert_eq!(cache.get(&shared_key), Some(shared_entry.clone()));
        for t in 0..8u64 {
            for i in 0..8 {
                let (_, key, entry) = sample(100 + t * 10 + i);
                assert_eq!(cache.get(&key), Some(entry));
            }
        }
        // And the files on disk are complete: a cold open sees the same.
        let reopened = ShardedStrategyCache::open_with(&dir, 2, 256).unwrap();
        assert_eq!(reopened.get(&shared_key), Some(shared_entry));
        assert_eq!(reopened.len(), 1 + 64);
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// Sequential and double-buffered keys for the same geometry live in
    /// (potentially) different shards and never alias.
    #[test]
    fn overlap_modes_are_isolated() {
        let dir = tmp_dir("modes");
        let cache = ShardedStrategyCache::open(&dir).unwrap();
        let l = ConvLayer::square(1, 6, 3, 1);
        let acc = Accelerator::for_group_size(&l, 2);
        let seq_key = CacheKey::new(&l, &acc, 2, 8, 1, 100, 1);
        let db_key = CacheKey::new(
            &l,
            &acc.with_overlap(OverlapMode::DoubleBuffered),
            2,
            8,
            1,
            100,
            1,
        );
        let (_, _, mut entry) = sample(1);
        cache.put(&seq_key, &entry).unwrap();
        assert!(cache.get(&db_key).is_none(), "cross-mode lookup must miss");
        entry.makespan = Some(99);
        cache.put(&db_key, &entry).unwrap();
        let seq_hit = cache.get(&seq_key).unwrap();
        assert_eq!(seq_hit.makespan, None, "sequential entry untouched");
        assert_eq!(cache.get(&db_key).unwrap().makespan, Some(99));
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// A poisoned shard mutex is quarantined (counter tick) and its state
    /// rebuilt from the persisted file — no panic, no lost entries.
    #[test]
    fn poisoned_shard_is_quarantined_and_rebuilt_from_disk() {
        let dir = tmp_dir("poison");
        let cache = ShardedStrategyCache::open_with(&dir, 2, 64).unwrap();
        let (_, key, entry) = sample(11);
        cache.put(&key, &entry).unwrap();
        let victim = cache.shard_index(&key);
        cache.chaos_poison_shard(victim);
        // First post-poison access recovers: entry reloads from disk.
        assert_eq!(cache.get(&key), Some(entry.clone()));
        assert_eq!(cache.stats().quarantined_shards, 1);
        // The mutex is healthy again: no further quarantines.
        assert_eq!(cache.get(&key), Some(entry.clone()));
        cache.put(&key, &entry).unwrap();
        assert_eq!(cache.stats().quarantined_shards, 1);
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// Regression: a stale temp planted by a dead writer is swept on open.
    #[test]
    fn open_sweeps_crash_orphaned_temps() {
        let dir = tmp_dir("orphan");
        std::fs::create_dir_all(&dir).unwrap();
        let stale = dir.join(".shard-000.json.tmp-4099998-3");
        std::fs::write(&stale, "{trunc").unwrap();
        let cache = ShardedStrategyCache::open_with(&dir, 2, 64).unwrap();
        assert!(!stale.exists(), "dead writer's temp swept on open");
        let (_, key, entry) = sample(1);
        cache.put(&key, &entry).unwrap();
        assert_eq!(cache.get(&key), Some(entry));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn counters_tally_hits_and_misses() {
        let dir = tmp_dir("counters");
        let cache = ShardedStrategyCache::open(&dir).unwrap();
        let (_, key, entry) = sample(1);
        assert!(cache.get(&key).is_none());
        cache.put(&key, &entry).unwrap();
        cache.get(&key).unwrap();
        cache.get(&key).unwrap();
        let s = cache.stats();
        assert_eq!((s.hits, s.misses, s.evictions), (2, 1, 0));
        // Clones share counters.
        cache.clone().get(&key).unwrap();
        assert_eq!(cache.stats().hits, 3);
        let _ = std::fs::remove_dir_all(&dir);
    }
}

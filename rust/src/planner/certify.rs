//! Optimality certification (DESIGN.md §3.12): analytic communication lower
//! bounds plus budgeted exact solves over a finished plan.
//!
//! Two independent certificates, both **read-only with respect to search**
//! (they never influence which portfolio lane wins, so every pinned
//! baseline stays bit-identical):
//!
//! * [`comm_lower_bound`] — the layer-level memory-traffic floor of
//!   *Communication Lower Bound in Convolution Accelerators* (arxiv
//!   1911.05662), adapted to this codebase's patch/grouping model. In the
//!   Eq. 15 cost model (`Σ_k |pix(g_k) ∖ pix(g_{k−1})|`) the floor has two
//!   terms: the exact **cold floor** `|U|` (every pixel tapped by some
//!   patch is loaded at least once — consecutive-group reuse frees
//!   everything else) and the paper's **memory-dependent** term (forced
//!   reloads once the per-patch private areas exceed the on-chip pixel
//!   capacity), kept in its conservative variant so it degrades gracefully
//!   under stride / dilation / channel groups. The bound is monotone
//!   non-increasing in `size_MEM`, as the property suites in both languages
//!   pin.
//! * [`certify_network`] — for small stages, a **proven optimum**: the
//!   specialized branch & bound ([`crate::optimizer::exact`]) run to
//!   completion under a deterministic node budget, cross-checked on micro
//!   instances by the generic §5 MILP
//!   ([`crate::optimizer::model_builder`] + [`crate::solver`]) with a
//!   vacuous reload bound (`nb_data_reload = k`), so the two encodings
//!   search the same space and must land on the same optimum.
//!
//! Budget discipline: the exact path is bounded by **nodes first** (checked
//! every node, so runs are reproducible across machines) with wall-clock as
//! a coarse safety net; an exhausted budget is a clean
//! [`ExactStatus::Unsolved`], never a hang — CI can run `certify --exact`
//! unconditionally.

use std::time::Duration;

use crate::conv::ConvLayer;
use crate::ilp::SolveStatus;
use crate::optimizer::exact::{solve_exact_with, ExactLimits};
use crate::optimizer::model_builder::{build_s1_model, encode_mip_start};
use crate::optimizer::objective::grouping_loads;
use crate::platform::Accelerator;
use crate::solver::{solve_milp, BranchBoundOptions};
use crate::tensor::PixelSet;
use crate::util::json::Json;

use super::{LayerPlan, NetworkPlan};

/// The analytic per-layer communication floor, in both domains.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CommLowerBound {
    /// `|U|`: distinct input pixels tapped by any patch — exact under
    /// stride / dilation / groups because it is computed from the actual
    /// dilated tap lattices, not a closed form.
    pub cold_pixels: u64,
    /// The 1911.05662-style memory-dependent term: with at most
    /// `P_cap = (size_MEM − kernel_elements) / C_in` resident pixels,
    /// reloads are forced once the per-patch private areas
    /// (`min(s_h, h_span) × min(s_w, w_span)` each) exceed capacity;
    /// conservative divisor 2 keeps it a true floor for every grouping.
    pub memory_pixels: u64,
    /// `max(cold_pixels, memory_pixels)` — the pixel-domain floor on the
    /// planner's race objective (`loaded_pixels`).
    pub bound_pixels: u64,
    /// `bound_pixels × C_in` — input element traffic floor.
    pub input_element_floor: u64,
    /// One-time kernel load (step 1 of any strategy).
    pub kernel_elements: u64,
    /// `input_element_floor + kernel_elements` — floor on a stage's
    /// `loaded_elements` (the simulator's element-domain load counter).
    pub load_element_floor: u64,
    /// `n_patches × C_out` — every output value leaves the chip exactly
    /// once.
    pub write_element_floor: u64,
    /// `⌈n_patches / max_patches_per_step⌉` — no strategy computes in fewer
    /// steps than the PE budget admits.
    pub min_compute_steps: u64,
}

/// Compute the communication floor of `layer` on `acc`.
pub fn comm_lower_bound(layer: &ConvLayer, acc: &Accelerator) -> CommLowerBound {
    let n = layer.n_patches() as u64;
    let mut union = PixelSet::empty(layer.n_pixels());
    for p in layer.all_patches() {
        layer.add_patch_pixels(&mut union, p);
    }
    let cold = union.len() as u64;

    let a = layer.s_h.min(layer.h_span()) as u64;
    let b = layer.s_w.min(layer.w_span()) as u64;
    let kernel_elements = layer.kernel_elements() as u64;
    let cap_el = acc.size_mem.saturating_sub(kernel_elements);
    let p_cap = if layer.c_in > 0 { cap_el / layer.c_in as u64 } else { cap_el };
    let memory_px = (n * a * b).saturating_sub(p_cap) / 2;

    let bound_px = cold.max(memory_px);
    let input_floor = bound_px * layer.c_in as u64;
    let max_pps = acc.max_patches_per_step(layer).max(1) as u64;
    CommLowerBound {
        cold_pixels: cold,
        memory_pixels: memory_px,
        bound_pixels: bound_px,
        input_element_floor: input_floor,
        kernel_elements,
        load_element_floor: input_floor + kernel_elements,
        write_element_floor: n * layer.c_out() as u64,
        min_compute_steps: n.div_ceil(max_pps),
    }
}

/// `(achieved − bound) / bound` as an IEEE double; `0.0` when the bound is
/// zero or already met. Both languages divide the same two exact integers,
/// so the value is bit-identical cross-language.
pub fn optimality_gap(achieved: u64, bound: u64) -> f64 {
    if bound == 0 {
        return 0.0;
    }
    achieved.saturating_sub(bound) as f64 / bound as f64
}

/// What the exact path concluded for one stage.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ExactStatus {
    /// Exact solve not attempted (bound-only run, or the stage is above
    /// `exact_max_patches`).
    Skipped,
    /// Budget exhausted before the search space was proven empty — the
    /// stage carries no exact certificate (and never hangs CI).
    Unsolved,
    /// The search completed: `exact_optimum` is the proven minimum.
    Certified,
}

impl ExactStatus {
    /// Stable lower-case label (JSON / tables).
    pub fn as_str(&self) -> &'static str {
        match self {
            ExactStatus::Skipped => "skipped",
            ExactStatus::Unsolved => "unsolved",
            ExactStatus::Certified => "certified",
        }
    }
}

/// Knobs for [`certify_network`].
#[derive(Debug, Clone)]
pub struct CertifyOptions {
    /// Attempt exact solves (bound-only otherwise).
    pub exact: bool,
    /// Largest `n_patches` the specialized branch & bound is attempted on.
    pub exact_max_patches: usize,
    /// Largest `n_patches` the generic §5 MILP cross-check is attempted on
    /// (its variable count grows as `k·(3·n_pixels + n)`).
    pub ilp_max_patches: usize,
    /// Largest `n_pixels` for the MILP cross-check.
    pub ilp_max_pixels: usize,
    /// Deterministic node cap for the specialized exact search.
    pub node_budget: u64,
    /// Node cap for the MILP branch & bound.
    pub ilp_node_budget: u64,
    /// Wall-clock safety net for either solver.
    pub time_budget: Duration,
}

impl Default for CertifyOptions {
    fn default() -> Self {
        CertifyOptions {
            exact: false,
            exact_max_patches: 12,
            ilp_max_patches: 4,
            ilp_max_pixels: 40,
            node_budget: 2_000_000,
            ilp_node_budget: 50_000,
            time_budget: Duration::from_secs(30),
        }
    }
}

/// Certificate for one planned stage.
#[derive(Debug, Clone)]
pub struct StageCertificate {
    /// Stage name within the network.
    pub stage: String,
    /// `|X|` of the stage's layer.
    pub n_patches: usize,
    /// Group-size bound the plan used.
    pub group_size: usize,
    /// The portfolio lane that won the race.
    pub winner: String,
    /// The winner's loaded pixels (the race objective).
    pub achieved_pixels: u64,
    /// The analytic floor.
    pub bound: CommLowerBound,
    /// `(achieved_pixels − bound_pixels) / bound_pixels`.
    pub optimality_gap: f64,
    /// What the exact path concluded.
    pub exact_status: ExactStatus,
    /// Proven minimum loaded pixels over every valid grouping (set iff
    /// `Certified`).
    pub exact_optimum: Option<u64>,
    /// True iff the portfolio winner achieves the proven optimum.
    pub exact_matches_winner: Option<bool>,
    /// Micro instances only: whether the independent §5 MILP landed on the
    /// same optimum (`None` when the cross-check was out of scope or hit
    /// its budget).
    pub ilp_agrees: Option<bool>,
    /// Nodes the specialized exact search expanded.
    pub exact_nodes: u64,
}

/// Certification of a whole [`NetworkPlan`].
#[derive(Debug, Clone)]
pub struct CertifyReport {
    /// The plan's network name.
    pub network: String,
    /// Per-stage certificates in pipeline order.
    pub stages: Vec<StageCertificate>,
    /// Largest per-stage gap (0.0 for an empty network).
    pub worst_gap: f64,
    /// Stages whose exact status is `Certified`.
    pub certified_exactly: usize,
}

/// Certify every stage of `plan`: bounds always, exact solves when
/// `opts.exact` and the stage is small enough.
pub fn certify_network(plan: &NetworkPlan, opts: &CertifyOptions) -> CertifyReport {
    let stages: Vec<StageCertificate> =
        plan.layers.iter().map(|lp| certify_stage(lp, opts)).collect();
    CertifyReport {
        network: plan.network.clone(),
        worst_gap: stages.iter().map(|s| s.optimality_gap).fold(0.0, f64::max),
        certified_exactly: stages
            .iter()
            .filter(|s| s.exact_status == ExactStatus::Certified)
            .count(),
        stages,
    }
}

fn certify_stage(lp: &LayerPlan, opts: &CertifyOptions) -> StageCertificate {
    let bound = comm_lower_bound(&lp.layer, &lp.accelerator);
    let mut cert = StageCertificate {
        stage: lp.stage.clone(),
        n_patches: lp.layer.n_patches(),
        group_size: lp.group_size,
        winner: lp.winner.clone(),
        achieved_pixels: lp.loaded_pixels,
        optimality_gap: optimality_gap(lp.loaded_pixels, bound.bound_pixels),
        bound,
        exact_status: ExactStatus::Skipped,
        exact_optimum: None,
        exact_matches_winner: None,
        ilp_agrees: None,
        exact_nodes: 0,
    };
    if opts.exact && lp.layer.n_patches() <= opts.exact_max_patches {
        certify_exact(lp, opts, &mut cert);
    }
    cert
}

/// The exact ladder: specialized branch & bound first (the certifying
/// engine), then — on micro instances — the generic §5 MILP as an
/// independent cross-check of the encoding.
fn certify_exact(lp: &LayerPlan, opts: &CertifyOptions, cert: &mut StageCertificate) {
    let g = lp.group_size.max(1);
    let k = lp.strategy.groups.len();
    let limits = ExactLimits { time: opts.time_budget, nodes: opts.node_budget };
    let r = solve_exact_with(&lp.layer, g, k, limits, Some(&lp.strategy.groups));
    cert.exact_nodes = r.nodes;
    let best = match (r.complete, r.groups) {
        (true, Some(best)) => best,
        // Budget hit, or (unreachable with a valid winner) proven empty.
        _ => {
            cert.exact_status = ExactStatus::Unsolved;
            return;
        }
    };
    let exact_px = grouping_loads(&lp.layer, &best);
    cert.exact_optimum = Some(exact_px);
    cert.exact_matches_winner = Some(exact_px == lp.loaded_pixels);
    cert.exact_status = ExactStatus::Certified;

    // MILP cross-check. Scope guards: (a) model size, (b) the §5 memory
    // constraint (Eq. 12) must admit every ≤ g group — true by construction
    // for `for_group_size` machines — otherwise the MILP searches a strict
    // subset of the DFS space and a mismatch would be scope, not a bug.
    let micro = lp.layer.n_patches() <= opts.ilp_max_patches
        && lp.layer.n_pixels() <= opts.ilp_max_pixels;
    let mem_admits_any_group =
        Accelerator::for_group_size(&lp.layer, g).size_mem <= lp.accelerator.size_mem;
    if micro && mem_admits_any_group {
        // `nb_data_reload = k` makes Eq. 9 vacuous (a pixel cannot load
        // more than once per step), so both encodings minimize the same
        // objective over the same groupings.
        let (model, info) = build_s1_model(&lp.layer, &lp.accelerator, k, k as u32);
        let start = encode_mip_start(&lp.layer, &info, &best, model.n_vars());
        let sol = solve_milp(
            &model,
            &BranchBoundOptions {
                time_budget: opts.time_budget,
                node_budget: opts.ilp_node_budget,
                mip_start: Some(start),
                gap_tolerance: 1e-6,
            },
        );
        if sol.status == SolveStatus::Optimal {
            let expect =
                (lp.accelerator.t_l * lp.layer.c_in as u64) as f64 * exact_px as f64;
            cert.ilp_agrees = Some((sol.objective - expect).abs() < 1e-6);
        }
    }
}

/// JSON form of a [`CertifyReport`] (the `certify --json` payload).
pub fn certify_to_json(report: &CertifyReport) -> Json {
    let stages: Vec<Json> = report
        .stages
        .iter()
        .map(|s| {
            let mut o = Json::obj();
            o.set("stage", s.stage.as_str())
                .set("n_patches", s.n_patches)
                .set("group_size", s.group_size)
                .set("winner", s.winner.as_str())
                .set("achieved_pixels", s.achieved_pixels)
                .set("comm_lower_bound", s.bound.bound_pixels)
                .set("cold_pixels", s.bound.cold_pixels)
                .set("memory_pixels", s.bound.memory_pixels)
                .set("load_element_floor", s.bound.load_element_floor)
                .set("write_element_floor", s.bound.write_element_floor)
                .set("min_compute_steps", s.bound.min_compute_steps)
                .set("optimality_gap", s.optimality_gap)
                .set("exact_status", s.exact_status.as_str())
                .set("exact_nodes", s.exact_nodes);
            if let Some(opt) = s.exact_optimum {
                o.set("exact_optimum", opt);
            }
            if let Some(m) = s.exact_matches_winner {
                o.set("exact_matches_winner", m);
            }
            if let Some(a) = s.ilp_agrees {
                o.set("ilp_agrees", a);
            }
            o
        })
        .collect();
    let mut o = Json::obj();
    o.set("network", report.network.as_str())
        .set("worst_gap", report.worst_gap)
        .set("certified_exactly", report.certified_exactly)
        .set("stages", Json::Arr(stages));
    o
}

/// Human-readable table form of a [`CertifyReport`].
pub fn format_certify_table(report: &CertifyReport) -> String {
    let mut out = String::new();
    out.push_str(&format!("network: {}\n", report.network));
    out.push_str(
        " stage    | patches |  g | bound px | achieved |    gap | exact\n",
    );
    out.push_str(
        "----------+---------+----+----------+----------+--------+-------------------\n",
    );
    for s in &report.stages {
        let exact = match s.exact_status {
            ExactStatus::Skipped => "skipped".to_string(),
            ExactStatus::Unsolved => {
                format!("unsolved ({} nodes)", s.exact_nodes)
            }
            ExactStatus::Certified => {
                let mut t = format!(
                    "certified (opt {}{})",
                    s.exact_optimum.unwrap_or(0),
                    if s.exact_matches_winner == Some(true) {
                        ", winner optimal"
                    } else {
                        ", winner above optimum"
                    }
                );
                match s.ilp_agrees {
                    Some(true) => t.push_str(" [ilp ok]"),
                    Some(false) => t.push_str(" [ILP DISAGREES]"),
                    None => {}
                }
                t
            }
        };
        out.push_str(&format!(
            " {:<8} | {:>7} | {:>2} | {:>8} | {:>8} | {:>6.4} | {}\n",
            s.stage,
            s.n_patches,
            s.group_size,
            s.bound.bound_pixels,
            s.achieved_pixels,
            s.optimality_gap,
            exact
        ));
    }
    out.push_str(&format!(
        "worst gap: {:.4} | certified exactly: {}/{}\n",
        report.worst_gap,
        report.certified_exactly,
        report.stages.len()
    ));
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::strategy;

    #[test]
    fn cold_floor_matches_hand_computed_unions() {
        // Dense 5x5 kernel on 32x32: every input pixel is tapped.
        let l = ConvLayer::square(1, 32, 5, 6);
        let b = comm_lower_bound(&l, &Accelerator::for_group_size(&l, 4));
        assert_eq!(b.cold_pixels, 1024);
        assert_eq!(b.bound_pixels, 1024);

        // Stride-2 depthwise 3x3 on 18x18: patch origins 0,2,..,14, span 3
        // → rows/cols 0..=16 tapped, row/col 17 never → 17×17.
        let dw = ConvLayer::new(4, 18, 18, 3, 3, 4, 2, 2)
            .unwrap()
            .with_groups(4)
            .unwrap();
        let b = comm_lower_bound(&dw, &Accelerator::for_group_size(&dw, 4));
        assert_eq!(b.cold_pixels, 289);

        // Dilated 3x3 (d = 2) on 12x12: span 5, origins 0..=7 — the dilated
        // lattices of the patch *set* still tap every pixel.
        let dil = ConvLayer::new(8, 12, 12, 3, 3, 8, 1, 1)
            .unwrap()
            .with_dilation(2, 2)
            .unwrap();
        let b = comm_lower_bound(&dil, &Accelerator::for_group_size(&dil, 4));
        assert_eq!(b.cold_pixels, 144);
    }

    #[test]
    fn element_floors_follow_the_pixel_bound() {
        let l = ConvLayer::square(2, 6, 3, 3); // 16 patches, c_in = 2
        let acc = Accelerator::for_group_size(&l, 4);
        let b = comm_lower_bound(&l, &acc);
        assert_eq!(b.input_element_floor, b.bound_pixels * 2);
        assert_eq!(b.load_element_floor, b.input_element_floor + b.kernel_elements);
        assert_eq!(b.write_element_floor, 16 * 3);
        assert_eq!(b.min_compute_steps, 4); // ceil(16 / 4)
    }

    #[test]
    fn bound_is_monotone_non_increasing_in_memory() {
        let l = ConvLayer::square(1, 8, 3, 2);
        let base = Accelerator::for_group_size(&l, 2);
        let mut prev = u64::MAX;
        for mem in [0u64, 16, 64, 256, 1024, 1 << 20] {
            let b = comm_lower_bound(&l, &Accelerator { size_mem: mem, ..base });
            assert!(b.bound_pixels <= prev, "bound grew at size_mem={mem}");
            prev = b.bound_pixels;
        }
    }

    #[test]
    fn bound_never_exceeds_any_ordering() {
        let layers = [
            ConvLayer::square(1, 6, 3, 2),
            ConvLayer::new(2, 8, 6, 3, 3, 2, 2, 1).unwrap(),
            ConvLayer::new(1, 9, 9, 3, 3, 1, 1, 1)
                .unwrap()
                .with_dilation(2, 2)
                .unwrap(),
        ];
        for l in layers {
            let acc = Accelerator::for_group_size(&l, 3);
            let b = comm_lower_bound(&l, &acc);
            for o in strategy::Ordering::all() {
                let s = strategy::from_ordering(&l, o, 3);
                let achieved = grouping_loads(&l, &s.groups);
                assert!(
                    b.bound_pixels <= achieved,
                    "{}: bound {} above achieved {}",
                    o.as_str(),
                    b.bound_pixels,
                    achieved
                );
            }
        }
    }

    #[test]
    fn gap_edge_cases() {
        assert_eq!(optimality_gap(10, 0), 0.0);
        assert_eq!(optimality_gap(5, 10), 0.0); // saturates, never negative
        assert_eq!(optimality_gap(10, 10), 0.0);
        assert_eq!(optimality_gap(15, 10), 0.5);
    }

    #[test]
    fn certify_report_renders_both_forms() {
        use crate::config::network_preset;
        use crate::planner::{NetworkPlanner, PlanOptions};

        let preset = network_preset("lenet5_micro").unwrap();
        let planner = NetworkPlanner::new(PlanOptions {
            accelerator: crate::planner::AcceleratorSpec::PerLayerGroup(2),
            anneal_iters: 200,
            anneal_starts: 1,
            ..PlanOptions::default()
        });
        let plan = planner.plan(&preset).unwrap();
        let report = certify_network(
            &plan,
            &CertifyOptions { exact: true, ..CertifyOptions::default() },
        );
        assert_eq!(report.stages.len(), 2);
        assert_eq!(report.certified_exactly, 2);
        for s in &report.stages {
            assert_eq!(s.exact_status, ExactStatus::Certified);
            let opt = s.exact_optimum.unwrap();
            assert!(opt >= s.bound.bound_pixels);
            assert!(opt <= s.achieved_pixels);
        }
        let j = certify_to_json(&report);
        assert_eq!(j.get("network").and_then(Json::as_str), Some("lenet5_micro"));
        assert_eq!(j.get("certified_exactly").and_then(Json::as_u64), Some(2));
        let table = format_certify_table(&report);
        assert!(table.contains("certified"));
        assert!(table.contains("worst gap"));
    }
}

//! Concurrent multi-network planning — `plan-batch` / [`BatchPlanner`].
//!
//! A planning service receives many networks at once (a compiler planning a
//! model zoo, CI re-planning every preset). Planning them one
//! [`NetworkPlanner::plan`](super::NetworkPlanner::plan) call at a time
//! wastes work twice over: identical planning problems recur *across*
//! networks (two LeNets share every shape; ResNet-8's twin 3×3 blocks share
//! one), and each call spins its own worker pool while the others idle.
//!
//! This module fixes both. [`BatchPlanner::plan_batch`] canonicalizes every
//! stage of every request to its [`CacheKey`] — (geometry, platform,
//! overlap-mode) — **dedupes identical problems across the whole batch
//! before any search**, consults the backing [`StrategyStore`] once per
//! unique problem, and races the entire residual portfolio set on **one**
//! shared pool ([`pool::parallel_map`]'s scoped threads pull (problem, lane)
//! pairs off a single atomic work cursor, so workers that finish one
//! network's lanes immediately steal the next network's). Determinism is
//! inherited from the per-layer race: lanes are pure and the reduction is by
//! `(objective, lane index)`, never completion order, so a batch plans
//! bit-identically under any thread schedule — and identically to planning
//! each network alone with the same options.
//!
//! The single-network planner is a thin wrapper over the same machinery
//! ([`stage_contexts`] → [`resolve`] → [`assemble_network`]), so the two
//! paths cannot drift.

use std::collections::{BTreeMap, BTreeSet};

use crate::config::NetworkPreset;
use crate::conv::ConvLayer;
use crate::metrics::CacheCounterSnapshot;
use crate::optimizer::{grouping_loads, grouping_makespan};
use crate::platform::{Accelerator, OverlapMode};
use crate::sim::{Network, Stage};
use crate::util::pool;

use super::cache::{CacheKey, CachedStrategy, StrategyStore};
use super::portfolio::{portfolio_entries, run_entry};
use super::shard::ShardedStrategyCache;
use super::{LayerPlan, NetworkPlan, PlanOptions};

/// Everything the resolver needs to know about one stage of one request:
/// its place in the batch plus the canonical planning problem it poses.
#[derive(Debug, Clone)]
pub(crate) struct StageCtx {
    /// Index of the request (network) within the batch.
    pub net: usize,
    /// Index of the stage within its network.
    pub stage: usize,
    /// The accelerator the stage runs on (overlap mode applied).
    pub acc: Accelerator,
    /// Group-size bound `nb_patches_max_S1` for the race.
    pub group: usize,
    /// Steps bound for the race.
    pub k: usize,
    /// Canonical (geometry, platform, overlap, portfolio-config) key.
    pub key: CacheKey,
}

/// Derive the per-layer accelerator and group bound from the options (the
/// single source shared by the single-network and batch paths).
pub(crate) fn stage_accelerator(
    o: &PlanOptions,
    layer: &ConvLayer,
) -> (Accelerator, usize) {
    let (acc, group) = match o.accelerator {
        super::AcceleratorSpec::PerLayerGroup(g) => {
            let g = g.max(1);
            (Accelerator::for_group_size(layer, g), g)
        }
        super::AcceleratorSpec::Fixed(acc) => {
            (acc, acc.max_patches_per_step(layer).max(1))
        }
    };
    (acc.with_overlap(o.overlap), group)
}

/// Canonicalize every stage of every request into a flat, batch-ordered
/// context list.
pub(crate) fn stage_contexts(
    o: &PlanOptions,
    presets: &[&NetworkPreset],
) -> Vec<StageCtx> {
    let mut ctxs = Vec::new();
    for (net, preset) in presets.iter().enumerate() {
        for (stage, s) in preset.stages.iter().enumerate() {
            let (acc, group) = stage_accelerator(o, &s.layer);
            let k = acc.k_min(&s.layer);
            let key = CacheKey::new(
                &s.layer,
                &acc,
                group,
                k,
                o.seed,
                o.anneal_iters,
                o.anneal_starts,
            );
            ctxs.push(StageCtx { net, stage, acc, group, k, key });
        }
    }
    ctxs
}

/// The outcome of resolving a batch's planning problems.
#[derive(Debug)]
pub(crate) struct Resolution {
    /// Canonical key → planning result, covering every stage in the batch.
    pub resolved: BTreeMap<String, CachedStrategy>,
    /// Context indices that represented a fresh race (the first occurrence
    /// of a key that the store could not serve).
    pub raced: BTreeSet<usize>,
    /// Unique problems served by the persistent store (validated hits).
    pub store_hits: usize,
    /// Stages whose problem was already planned (or queued) earlier in the
    /// batch — intra-batch deduplication, any network.
    pub dedup_hits: usize,
    /// The subset of `dedup_hits` whose first occurrence was in a
    /// *different* network of the batch.
    pub cross_network_dedup_hits: usize,
    /// Annealing iterations executed, attributed to the network whose stage
    /// represented the race.
    pub anneal_per_net: Vec<u64>,
}

/// Resolve every distinct planning problem in the batch: dedupe by canonical
/// key across all requests, consult the store once per unique problem, then
/// race the residual (problem × portfolio-lane) set on one shared pool.
pub(crate) fn resolve(
    presets: &[&NetworkPreset],
    ctxs: &[StageCtx],
    o: &PlanOptions,
    store: Option<&dyn StrategyStore>,
) -> Result<Resolution, String> {
    let mut resolved: BTreeMap<String, CachedStrategy> = BTreeMap::new();
    let mut jobs: Vec<usize> = Vec::new(); // ctx index of each racing representative
    let mut first_net: BTreeMap<&str, usize> = BTreeMap::new();
    let mut store_hits = 0usize;
    let mut dedup_hits = 0usize;
    let mut cross_network_dedup_hits = 0usize;

    for (ci, ctx) in ctxs.iter().enumerate() {
        if let Some(&net0) = first_net.get(ctx.key.canonical()) {
            // Problem already planned (or queued) this batch.
            dedup_hits += 1;
            if net0 != ctx.net {
                cross_network_dedup_hits += 1;
            }
            continue;
        }
        first_net.insert(ctx.key.canonical(), ctx.net);
        if let Some(store) = store {
            // A hit must survive structural validation against the layer it
            // will drive, and its stored objectives must match the
            // recomputed ones (cheap next to a race); anything stale
            // re-races and overwrites.
            let layer = &presets[ctx.net].stages[ctx.stage].layer;
            if let Some(hit) = store.load(&ctx.key).filter(|h| {
                h.validate_for(layer, ctx.group)
                    && h.loaded_pixels == grouping_loads(layer, &h.strategy.groups)
                    && (o.overlap == OverlapMode::Sequential
                        || h.makespan
                            == Some(grouping_makespan(layer, &ctx.acc, &h.strategy.groups)))
            }) {
                resolved.insert(ctx.key.canonical().to_string(), hit);
                store_hits += 1;
                continue;
            }
        }
        jobs.push(ci);
    }

    // The shared race: every (unique problem, lane) pair across the whole
    // batch goes onto one work list served by one scoped-thread pool —
    // workers drain an atomic cursor, so a thread finishing one network's
    // lanes immediately picks up the next network's. Results come back in
    // work-list order, so the reduction below is independent of scheduling.
    let entries = portfolio_entries(o.seed, o.anneal_iters, o.anneal_starts);
    let mut anneal_per_net = vec![0u64; presets.len()];
    if !jobs.is_empty() {
        let work: Vec<(usize, usize)> = jobs
            .iter()
            .flat_map(|&ci| (0..entries.len()).map(move |ei| (ci, ei)))
            .collect();
        let threads = if o.threads == 0 { pool::default_threads() } else { o.threads };
        let results = pool::parallel_map(&work, threads, |&(ci, ei)| {
            let ctx = &ctxs[ci];
            run_entry(
                &presets[ctx.net].stages[ctx.stage].layer,
                &ctx.acc,
                ctx.group,
                ctx.k,
                &entries[ei],
            )
        });

        for (ji, &ci) in jobs.iter().enumerate() {
            let ctx = &ctxs[ci];
            let lanes = &results[ji * entries.len()..(ji + 1) * entries.len()];
            // Deterministic reduction: strictly-less keeps the earliest lane
            // on ties. Sequential mode races loaded pixels; double-buffered
            // races the overlapped makespan with loaded pixels as tie-break.
            let mut best = &lanes[0];
            for lane in &lanes[1..] {
                let better = match o.overlap {
                    OverlapMode::Sequential => lane.loaded_pixels < best.loaded_pixels,
                    OverlapMode::DoubleBuffered => {
                        (lane.makespan, lane.loaded_pixels)
                            < (best.makespan, best.loaded_pixels)
                    }
                };
                if better {
                    best = lane;
                }
            }
            anneal_per_net[ctx.net] +=
                lanes.iter().map(|l| l.anneal_iters).sum::<u64>();
            let entry = CachedStrategy {
                strategy: best.strategy.clone(),
                loaded_pixels: best.loaded_pixels,
                makespan: best.makespan,
                winner: best.label.clone(),
            };
            if let Some(store) = store {
                store.store(&ctx.key, &entry)?;
            }
            resolved.insert(ctx.key.canonical().to_string(), entry);
        }
    }

    Ok(Resolution {
        resolved,
        raced: jobs.into_iter().collect(),
        store_hits,
        dedup_hits,
        cross_network_dedup_hits,
        anneal_per_net,
    })
}

/// Assemble one network's plan from the batch resolution: push every stage
/// into the simulator, mark hit/miss provenance, and fill simulated
/// durations.
///
/// A stage counts as a cache **miss** exactly when it was the racing
/// representative of its problem; dedup'd repeats and store hits both count
/// as hits — identical to the single-network planner's historical
/// semantics.
pub(crate) fn assemble_network(
    preset: &NetworkPreset,
    net: usize,
    ctxs: &[StageCtx],
    res: &Resolution,
    overlap: OverlapMode,
) -> Result<NetworkPlan, String> {
    let mut network = Network::default();
    let mut layers: Vec<LayerPlan> = Vec::with_capacity(preset.stages.len());
    let mut cache_hits = 0usize;
    let mut cache_misses = 0usize;
    for (ci, ctx) in ctxs.iter().enumerate() {
        if ctx.net != net {
            continue;
        }
        let sp = &preset.stages[ctx.stage];
        let entry = res
            .resolved
            .get(ctx.key.canonical())
            .expect("every stage key resolved");
        let hit = !res.raced.contains(&ci);
        if hit {
            cache_hits += 1;
        } else {
            cache_misses += 1;
        }
        network.push(Stage {
            name: sp.name.to_string(),
            layer: sp.layer,
            accelerator: ctx.acc,
            strategy: entry.strategy.clone(),
            pool_after: sp.pool_after,
            pad_after: sp.pad_after,
        })?;
        layers.push(LayerPlan {
            stage: sp.name.to_string(),
            layer: sp.layer,
            accelerator: ctx.acc,
            group_size: ctx.group,
            strategy: entry.strategy.clone(),
            winner: entry.winner.clone(),
            loaded_pixels: entry.loaded_pixels,
            duration: 0, // filled from the simulation below
            sequential_duration: 0,
            cache_hit: hit,
        });
    }
    let report = network.run().map_err(|e| e.to_string())?;
    for (lp, sr) in layers.iter_mut().zip(&report.per_stage) {
        lp.duration = sr.duration;
        lp.sequential_duration = sr.sequential_duration;
    }
    Ok(NetworkPlan {
        network: preset.name.to_string(),
        layers,
        total_duration: report.total_duration,
        total_sequential_duration: report.total_sequential_duration,
        overlap,
        peak_occupancy: report.peak_occupancy,
        cache_hits,
        cache_misses,
        anneal_iters_run: res.anneal_per_net[net],
    })
}

/// Batch-level accounting surfaced by `plan-batch` and the bench suite.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct BatchStats {
    /// Requests (networks) in the batch.
    pub networks: usize,
    /// Stages across all requests.
    pub stages_total: usize,
    /// Distinct planning problems after cross-network deduplication.
    pub unique_problems: usize,
    /// Stages whose problem was already planned (or queued) earlier in the
    /// batch — any network.
    pub dedup_hits: usize,
    /// The subset of `dedup_hits` first seen in a *different* network.
    pub cross_network_dedup_hits: usize,
    /// Unique problems served by the persistent store (validated hits).
    pub store_hits: usize,
    /// Unique problems that required a fresh portfolio race.
    pub store_misses: usize,
    /// Annealing iterations executed across the whole batch — 0 when every
    /// problem came from the store.
    pub anneal_iters_run: u64,
    /// Raw counters of the backing sharded cache (zeros when the planner
    /// runs without persistence).
    pub cache: CacheCounterSnapshot,
    /// Shard count of the backing cache (0 without persistence).
    pub shard_count: usize,
}

/// The result of one batch: per-request plans (input order) plus the
/// batch-level accounting.
#[derive(Debug, Clone)]
pub struct BatchReport {
    /// One plan per request, in input order.
    pub plans: Vec<NetworkPlan>,
    /// Batch-level dedup / cache / effort accounting.
    pub stats: BatchStats,
}

/// The batch planning facade: [`NetworkPlanner`](super::NetworkPlanner) for
/// many networks at once, with cross-network deduplication and a shared
/// race pool, optionally backed by a [`ShardedStrategyCache`].
#[derive(Debug, Clone)]
pub struct BatchPlanner {
    /// Planner configuration shared by every request in a batch (the
    /// overlap mode and portfolio budgets are part of every cache key).
    pub options: PlanOptions,
    cache: Option<ShardedStrategyCache>,
}

impl BatchPlanner {
    /// Batch planner without persistence (cross-network dedup still works;
    /// every unique problem races once per call).
    pub fn new(options: PlanOptions) -> Self {
        BatchPlanner { options, cache: None }
    }

    /// Batch planner backed by a sharded on-disk strategy cache.
    pub fn with_cache(options: PlanOptions, cache: ShardedStrategyCache) -> Self {
        BatchPlanner { options, cache: Some(cache) }
    }

    /// The backing sharded cache, if any.
    pub fn cache(&self) -> Option<&ShardedStrategyCache> {
        self.cache.as_ref()
    }

    /// Plan every network of the batch.
    ///
    /// Identical problems are planned **once** for the whole batch; the
    /// plans are bit-identical to planning each network alone with the same
    /// options (determinism is by construction: pure lanes, order-fixed
    /// reduction).
    ///
    /// # Examples
    ///
    /// ```
    /// use convoffload::config::network_preset;
    /// use convoffload::planner::{BatchPlanner, PlanOptions};
    ///
    /// let lenet = network_preset("lenet5").unwrap();
    /// let planner = BatchPlanner::new(PlanOptions {
    ///     anneal_iters: 200, // tiny budget: doc-test speed
    ///     anneal_starts: 1,
    ///     ..PlanOptions::default()
    /// });
    /// let report = planner.plan_batch(&[lenet.clone(), lenet]).unwrap();
    /// assert_eq!(report.plans.len(), 2);
    /// // the twin network re-used every shape of the first
    /// assert_eq!(report.stats.unique_problems, 2);
    /// assert_eq!(report.stats.cross_network_dedup_hits, 2);
    /// ```
    pub fn plan_batch(&self, presets: &[NetworkPreset]) -> Result<BatchReport, String> {
        let o = &self.options;
        let refs: Vec<&NetworkPreset> = presets.iter().collect();
        let ctxs = stage_contexts(o, &refs);
        let store = self.cache.as_ref().map(|c| c as &dyn StrategyStore);
        let res = resolve(&refs, &ctxs, o, store)?;

        let mut plans = Vec::with_capacity(presets.len());
        for (net, preset) in presets.iter().enumerate() {
            plans.push(assemble_network(preset, net, &ctxs, &res, o.overlap)?);
        }
        let unique_problems = ctxs.len() - res.dedup_hits;
        let stats = BatchStats {
            networks: presets.len(),
            stages_total: ctxs.len(),
            unique_problems,
            dedup_hits: res.dedup_hits,
            cross_network_dedup_hits: res.cross_network_dedup_hits,
            store_hits: res.store_hits,
            store_misses: res.raced.len(),
            anneal_iters_run: res.anneal_per_net.iter().sum(),
            cache: self
                .cache
                .as_ref()
                .map(|c| c.stats())
                .unwrap_or_default(),
            shard_count: self.cache.as_ref().map_or(0, |c| c.shard_count()),
        };
        Ok(BatchReport { plans, stats })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::NetworkStagePreset;
    use crate::planner::{AcceleratorSpec, NetworkPlanner};

    fn tiny(name: &str) -> NetworkPreset {
        NetworkPreset {
            name: name.to_string(),
            description: "1x8x8 conv -> pool -> 2x3x3 conv".into(),
            stages: vec![
                NetworkStagePreset {
                    name: "c1".into(),
                    layer: ConvLayer::new(1, 8, 8, 3, 3, 2, 1, 1).unwrap(),
                    pool_after: true,
                    pad_after: 0,
                },
                NetworkStagePreset {
                    name: "c2".into(),
                    layer: ConvLayer::new(2, 3, 3, 3, 3, 1, 1, 1).unwrap(),
                    pool_after: false,
                    pad_after: 0,
                },
            ],
        }
    }

    fn other() -> NetworkPreset {
        NetworkPreset {
            name: "other".into(),
            description: "one distinct stage".into(),
            stages: vec![NetworkStagePreset {
                name: "c1".into(),
                layer: ConvLayer::new(1, 6, 6, 3, 3, 1, 1, 1).unwrap(),
                pool_after: false,
                pad_after: 0,
            }],
        }
    }

    fn quick_options() -> PlanOptions {
        PlanOptions {
            accelerator: AcceleratorSpec::PerLayerGroup(2),
            seed: 7,
            anneal_iters: 1_000,
            anneal_starts: 2,
            threads: 0,
            overlap: OverlapMode::Sequential,
        }
    }

    /// The batch plans match planning each network alone with the same
    /// options — the batch machinery changes scheduling, never results.
    #[test]
    fn batch_matches_solo_plans() {
        let nets = [tiny("a"), other()];
        let report = BatchPlanner::new(quick_options())
            .plan_batch(&nets)
            .unwrap();
        for (preset, plan) in nets.iter().zip(&report.plans) {
            let solo = NetworkPlanner::new(quick_options()).plan(preset).unwrap();
            assert_eq!(plan.total_duration, solo.total_duration, "{}", preset.name);
            assert_eq!(plan.network, solo.network);
            for (a, b) in plan.layers.iter().zip(&solo.layers) {
                assert_eq!(a.strategy, b.strategy);
                assert_eq!(a.winner, b.winner);
                assert_eq!(a.loaded_pixels, b.loaded_pixels);
                assert_eq!(a.duration, b.duration);
            }
        }
    }

    /// Twin networks dedupe across the batch: every shape of the second is
    /// a cross-network dedup hit and races zero extra iterations.
    #[test]
    fn twin_networks_dedupe_across_the_batch() {
        let report = BatchPlanner::new(quick_options())
            .plan_batch(&[tiny("a"), tiny("b")])
            .unwrap();
        let s = &report.stats;
        assert_eq!(s.networks, 2);
        assert_eq!(s.stages_total, 4);
        assert_eq!(s.unique_problems, 2);
        assert_eq!(s.dedup_hits, 2);
        assert_eq!(s.cross_network_dedup_hits, 2);
        assert_eq!(s.store_misses, 2, "no persistence: every unique problem races");
        assert_eq!(s.store_hits, 0);
        // the first network raced, the twin rode the results
        assert_eq!(report.plans[0].cache_misses, 2);
        assert_eq!(report.plans[1].cache_hits, 2);
        assert_eq!(report.plans[1].anneal_iters_run, 0);
        assert_eq!(
            report.plans[0].total_duration,
            report.plans[1].total_duration
        );
    }

    /// Batch determinism: same options, any thread count, same everything.
    #[test]
    fn same_seed_same_batch_any_thread_count() {
        let nets = [tiny("a"), other(), tiny("c")];
        let mut opts = quick_options();
        let base = BatchPlanner::new(opts.clone()).plan_batch(&nets).unwrap();
        for threads in [1usize, 2, 8] {
            opts.threads = threads;
            let again = BatchPlanner::new(opts.clone()).plan_batch(&nets).unwrap();
            assert_eq!(again.stats, base.stats, "threads={threads}");
            for (a, b) in base.plans.iter().zip(&again.plans) {
                assert_eq!(a.total_duration, b.total_duration, "threads={threads}");
                for (la, lb) in a.layers.iter().zip(&b.layers) {
                    assert_eq!(la.strategy, lb.strategy, "threads={threads}");
                    assert_eq!(la.winner, lb.winner);
                }
            }
        }
    }

    /// Warm path: a second identical batch over the same sharded cache is
    /// all store hits and performs zero annealing iterations.
    #[test]
    fn second_identical_batch_runs_zero_anneal_iterations() {
        let dir = std::env::temp_dir().join(format!(
            "convoffload-batch-warm-{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        let nets = [tiny("a"), tiny("b"), other()];
        let cache = ShardedStrategyCache::open(&dir).unwrap();
        let planner = BatchPlanner::with_cache(quick_options(), cache);

        let cold = planner.plan_batch(&nets).unwrap();
        assert_eq!(cold.stats.unique_problems, 3);
        assert_eq!(cold.stats.store_misses, 3);
        assert_eq!(cold.stats.store_hits, 0);
        assert!(cold.stats.anneal_iters_run > 0);
        assert_eq!(cold.stats.shard_count, super::super::shard::DEFAULT_SHARDS);

        let warm = planner.plan_batch(&nets).unwrap();
        assert_eq!(warm.stats.store_hits, 3, "all unique problems served warm");
        assert_eq!(warm.stats.store_misses, 0);
        assert_eq!(warm.stats.anneal_iters_run, 0, "warm batch must not anneal");
        for plan in &warm.plans {
            assert_eq!(plan.cache_misses, 0);
            assert_eq!(plan.anneal_iters_run, 0);
        }
        // and the results did not drift
        for (a, b) in cold.plans.iter().zip(&warm.plans) {
            assert_eq!(a.total_duration, b.total_duration);
        }
        // cache counters flowed into the stats (≥ 3 hits from the warm pass)
        assert!(warm.stats.cache.hits >= 3);
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// Overlap modes stay isolated under batch planning: a sequential batch
    /// then a double-buffered batch over one cache directory never serve
    /// each other's entries.
    #[test]
    fn batch_overlap_modes_do_not_share_entries() {
        let dir = std::env::temp_dir().join(format!(
            "convoffload-batch-modes-{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        let nets = [tiny("a"), other()];
        let cache = ShardedStrategyCache::open(&dir).unwrap();
        let seq = BatchPlanner::with_cache(quick_options(), cache.clone())
            .plan_batch(&nets)
            .unwrap();
        assert_eq!(seq.stats.store_misses, 3);
        let mut opts = quick_options();
        opts.overlap = OverlapMode::DoubleBuffered;
        let db = BatchPlanner::with_cache(opts, cache)
            .plan_batch(&nets)
            .unwrap();
        assert_eq!(db.stats.store_misses, 3, "other mode must not hit");
        assert_eq!(db.stats.store_hits, 0);
        for plan in &db.plans {
            assert!(plan.total_duration <= plan.total_sequential_duration);
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// An empty batch is a valid no-op, not an error.
    #[test]
    fn empty_batch_is_a_noop() {
        let report = BatchPlanner::new(quick_options()).plan_batch(&[]).unwrap();
        assert!(report.plans.is_empty());
        assert_eq!(report.stats.stages_total, 0);
        assert_eq!(report.stats.unique_problems, 0);
    }
}

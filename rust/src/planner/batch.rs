//! Concurrent multi-network planning — `plan-batch` / [`BatchPlanner`].
//!
//! A planning service receives many networks at once (a compiler planning a
//! model zoo, CI re-planning every preset). Planning them one
//! [`NetworkPlanner::plan`](super::NetworkPlanner::plan) call at a time
//! wastes work twice over: identical planning problems recur *across*
//! networks (two LeNets share every shape; ResNet-8's twin 3×3 blocks share
//! one), and each call spins its own worker pool while the others idle.
//!
//! This module fixes both. [`BatchPlanner::plan_batch`] canonicalizes every
//! stage of every request to its [`CacheKey`] — (geometry, platform,
//! overlap-mode) — **dedupes identical problems across the whole batch
//! before any search**, consults the backing [`StrategyStore`] once per
//! unique problem, and races the entire residual portfolio set on **one**
//! shared pool ([`pool::parallel_map`]'s scoped threads pull (problem, lane)
//! pairs off a single atomic work cursor, so workers that finish one
//! network's lanes immediately steal the next network's). Determinism is
//! inherited from the per-layer race: lanes are pure and the reduction is by
//! `(objective, lane index)`, never completion order, so a batch plans
//! bit-identically under any thread schedule — and identically to planning
//! each network alone with the same options.
//!
//! The single-network planner is a thin wrapper over the same machinery
//! ([`stage_contexts`] → [`resolve`] → [`assemble_network`]), so the two
//! paths cannot drift.

use std::collections::{BTreeMap, BTreeSet};
use std::sync::atomic::{AtomicBool, Ordering as AtomicOrdering};

use crate::config::NetworkPreset;
use crate::conv::ConvLayer;
use crate::metrics::CacheCounterSnapshot;
use crate::optimizer::{degraded_accelerator, grouping_loads, grouping_makespan};
use crate::platform::{Accelerator, FaultModel, OverlapMode};
use crate::sim::{Network, Stage};
use crate::util::pool;

use super::cache::{CacheKey, CachedStrategy, StrategyStore};
use super::certify;
use super::portfolio::{portfolio_entries, run_entry_cancel, PortfolioEntry};
use super::recovery::{degrade_for_shrink, ChaosSpec, DegradeOutcome};
use super::shard::ShardedStrategyCache;
use super::{LayerPlan, NetworkPlan, PlanOptions};

/// Everything the resolver needs to know about one stage of one request:
/// its place in the batch plus the canonical planning problem it poses.
#[derive(Debug, Clone)]
pub(crate) struct StageCtx {
    /// Index of the request (network) within the batch.
    pub net: usize,
    /// Index of the stage within its network.
    pub stage: usize,
    /// The accelerator the stage runs on (overlap mode applied).
    pub acc: Accelerator,
    /// Group-size bound `nb_patches_max_S1` for the race.
    pub group: usize,
    /// Steps bound for the race.
    pub k: usize,
    /// Canonical (geometry, platform, overlap, portfolio-config) key.
    pub key: CacheKey,
}

/// Derive the per-layer accelerator and group bound from the options (the
/// single source shared by the single-network and batch paths).
pub(crate) fn stage_accelerator(
    o: &PlanOptions,
    layer: &ConvLayer,
) -> (Accelerator, usize) {
    let (acc, group) = match o.accelerator {
        super::AcceleratorSpec::PerLayerGroup(g) => {
            let g = g.max(1);
            (Accelerator::for_group_size(layer, g), g)
        }
        super::AcceleratorSpec::Fixed(acc) => {
            (acc, acc.max_patches_per_step(layer).max(1))
        }
    };
    (
        acc.with_overlap(o.overlap).with_channels(o.dma_channels, o.compute_units),
        group,
    )
}

/// Canonicalize every stage of every request into a flat, batch-ordered
/// context list.
pub(crate) fn stage_contexts(
    o: &PlanOptions,
    presets: &[&NetworkPreset],
) -> Vec<StageCtx> {
    let mut ctxs = Vec::new();
    for (net, preset) in presets.iter().enumerate() {
        for (stage, s) in preset.stages.iter().enumerate() {
            let (acc, group) = stage_accelerator(o, &s.layer);
            let k = acc.k_min(&s.layer);
            let key = CacheKey::new(
                &s.layer,
                &acc,
                group,
                k,
                o.seed,
                o.anneal_iters,
                o.anneal_starts,
            );
            ctxs.push(StageCtx { net, stage, acc, group, k, key });
        }
    }
    ctxs
}

/// The outcome of resolving a batch's planning problems.
#[derive(Debug)]
pub(crate) struct Resolution {
    /// Canonical key → planning result, covering every stage in the batch.
    pub resolved: BTreeMap<String, CachedStrategy>,
    /// Context indices that represented a fresh race (the first occurrence
    /// of a key that the store could not serve).
    pub raced: BTreeSet<usize>,
    /// Unique problems served by the persistent store (validated hits).
    pub store_hits: usize,
    /// Stages whose problem was already planned (or queued) earlier in the
    /// batch — intra-batch deduplication, any network.
    pub dedup_hits: usize,
    /// The subset of `dedup_hits` whose first occurrence was in a
    /// *different* network of the batch.
    pub cross_network_dedup_hits: usize,
    /// Annealing iterations executed, attributed to the network whose stage
    /// represented the race.
    pub anneal_per_net: Vec<u64>,
    /// Portfolio lanes that panicked during the race (each lost exactly its
    /// own result; the reduction skipped them).
    pub panicked_lanes: usize,
    /// Problems whose *every* lane was skipped by a fired cancel flag and
    /// that were therefore served by the inline deterministic fallback
    /// (first-ordering lane, winner tagged `+deadline`). Always 0 without a
    /// cancel flag.
    pub deadline_starved: usize,
}

/// [`resolve_chaos`] without chaos — the production path.
pub(crate) fn resolve(
    presets: &[&NetworkPreset],
    ctxs: &[StageCtx],
    o: &PlanOptions,
    store: Option<&dyn StrategyStore>,
) -> Result<Resolution, String> {
    resolve_chaos(presets, ctxs, o, store, &ChaosSpec::default())
}

/// [`resolve_chaos_cancel`] without a cancel flag.
pub(crate) fn resolve_chaos(
    presets: &[&NetworkPreset],
    ctxs: &[StageCtx],
    o: &PlanOptions,
    store: Option<&dyn StrategyStore>,
    chaos: &ChaosSpec,
) -> Result<Resolution, String> {
    resolve_chaos_cancel(presets, ctxs, o, store, chaos, None)
}

/// Resolve every distinct planning problem in the batch: dedupe by canonical
/// key across all requests, consult the store once per unique problem, then
/// race the residual (problem × portfolio-lane) set on one shared pool.
///
/// The race is panic-tolerant: a lane that panics (a crashed worker, or a
/// [`ChaosSpec`] injection) loses exactly its own result; the deterministic
/// reduction runs over the surviving lanes. Only when **every** lane of a
/// problem is lost does the batch fail.
///
/// The race is also deadline-tolerant when `cancel` is supplied: annealing
/// lanes return best-so-far when the flag fires, unclaimed (problem, lane)
/// pairs are skipped entirely, and a problem that lost *all* its lanes to
/// the skip is served by an inline deterministic fallback (the first
/// ordering lane, winner tagged `+deadline`) instead of failing the batch.
/// Results computed under a fired flag are never written back to the store —
/// a cut-short anneal must not pollute the full-budget entry for its key.
/// With `cancel == None` (or an unfired flag) this path is bit-identical to
/// the historical race.
/// A store hit must survive structural validation against the layer it will
/// drive, and its stored objectives must match the recomputed ones (cheap
/// next to a race); anything stale re-races and overwrites. Shared by the
/// batch resolution and [`BatchPlanner::fully_cached`] so the two can never
/// drift.
fn validated_hit(
    store: &dyn StrategyStore,
    ctx: &StageCtx,
    layer: &ConvLayer,
    o: &PlanOptions,
) -> Option<CachedStrategy> {
    store.load(&ctx.key).filter(|h| {
        h.validate_for(layer, ctx.group)
            && h.loaded_pixels == grouping_loads(layer, &h.strategy.groups)
            && (o.overlap == OverlapMode::Sequential
                || h.makespan == Some(grouping_makespan(layer, &ctx.acc, &h.strategy.groups)))
    })
}

pub(crate) fn resolve_chaos_cancel(
    presets: &[&NetworkPreset],
    ctxs: &[StageCtx],
    o: &PlanOptions,
    store: Option<&dyn StrategyStore>,
    chaos: &ChaosSpec,
    cancel: Option<&AtomicBool>,
) -> Result<Resolution, String> {
    let mut resolved: BTreeMap<String, CachedStrategy> = BTreeMap::new();
    let mut jobs: Vec<usize> = Vec::new(); // ctx index of each racing representative
    let mut first_net: BTreeMap<&str, usize> = BTreeMap::new();
    let mut store_hits = 0usize;
    let mut dedup_hits = 0usize;
    let mut cross_network_dedup_hits = 0usize;

    for (ci, ctx) in ctxs.iter().enumerate() {
        if let Some(&net0) = first_net.get(ctx.key.canonical()) {
            // Problem already planned (or queued) this batch.
            dedup_hits += 1;
            if net0 != ctx.net {
                cross_network_dedup_hits += 1;
            }
            continue;
        }
        first_net.insert(ctx.key.canonical(), ctx.net);
        if let Some(store) = store {
            let layer = &presets[ctx.net].stages[ctx.stage].layer;
            if let Some(hit) = validated_hit(store, ctx, layer, o) {
                resolved.insert(ctx.key.canonical().to_string(), hit);
                store_hits += 1;
                continue;
            }
        }
        jobs.push(ci);
    }

    // The shared race: every (unique problem, lane) pair across the whole
    // batch goes onto one work list served by one scoped-thread pool —
    // workers drain an atomic cursor, so a thread finishing one network's
    // lanes immediately picks up the next network's. Results come back in
    // work-list order, so the reduction below is independent of scheduling.
    let entries = portfolio_entries(o.seed, o.anneal_iters, o.anneal_starts);
    let mut anneal_per_net = vec![0u64; presets.len()];
    let mut panicked_lanes = 0usize;
    let mut deadline_starved = 0usize;
    if !jobs.is_empty() {
        let work: Vec<(usize, usize)> = jobs
            .iter()
            .flat_map(|&ci| (0..entries.len()).map(move |ei| (ci, ei)))
            .collect();
        let threads = if o.threads == 0 { pool::default_threads() } else { o.threads };
        let (results, panics) =
            pool::parallel_map_catch_cancel(&work, threads, cancel, |&(ci, ei)| {
                let ctx = &ctxs[ci];
                let entry = &entries[ei];
                if chaos.panic_lane.as_deref() == Some(entry.label().as_str()) {
                    panic!("chaos: portfolio lane {} crashed", entry.label());
                }
                run_entry_cancel(
                    &presets[ctx.net].stages[ctx.stage].layer,
                    &ctx.acc,
                    ctx.group,
                    ctx.k,
                    entry,
                    cancel,
                )
            });
        panicked_lanes = panics.len();
        let fired = cancel.is_some_and(|flag| flag.load(AtomicOrdering::Relaxed));

        for (ji, &ci) in jobs.iter().enumerate() {
            let ctx = &ctxs[ci];
            let lanes = &results[ji * entries.len()..(ji + 1) * entries.len()];
            // Deterministic reduction over the *surviving* lanes:
            // strictly-less keeps the earliest lane on ties. Sequential mode
            // races loaded pixels; double-buffered races the overlapped
            // makespan with loaded pixels as tie-break. Panicked lanes are
            // `None` slots and simply don't compete — losing a lane can cost
            // plan quality, never determinism (survivor order is fixed).
            let mut best: Option<&_> = None;
            for lane in lanes.iter().flatten() {
                let better = match &best {
                    None => true,
                    Some(b) => match o.overlap {
                        OverlapMode::Sequential => lane.loaded_pixels < b.loaded_pixels,
                        OverlapMode::DoubleBuffered => {
                            (lane.makespan, lane.loaded_pixels)
                                < (b.makespan, b.loaded_pixels)
                        }
                    },
                };
                if better {
                    best = Some(lane);
                }
            }
            anneal_per_net[ctx.net] +=
                lanes.iter().flatten().map(|l| l.anneal_iters).sum::<u64>();
            let entry = match best {
                Some(best) => {
                    // Write back only results whose every lane ran to its full
                    // budget: a fired flag may have cut an anneal short, and a
                    // reduced-effort winner stored under the full-budget key
                    // would poison every future lookup. Unreachable when
                    // `cancel` is None (the historical path always stores).
                    let complete = cancel.is_none()
                        || lanes.iter().zip(&entries).all(|(lane, e)| {
                            lane.as_ref().is_some_and(|l| match e {
                                PortfolioEntry::Anneal { iters, .. } => l.anneal_iters == *iters,
                                _ => true,
                            })
                        });
                    let entry = CachedStrategy {
                        strategy: best.strategy.clone(),
                        loaded_pixels: best.loaded_pixels,
                        makespan: best.makespan,
                        winner: best.label.clone(),
                    };
                    if complete {
                        if let Some(store) = store {
                            store.store(&ctx.key, &entry)?;
                        }
                    }
                    entry
                }
                None if fired => {
                    // Every lane of this problem was skipped by the deadline:
                    // serve the cheapest deterministic lane inline (the first
                    // portfolio entry — row-by-row ordering, no annealing) and
                    // tag the winner so the degradation is visible in every
                    // report. Never stored: it is a property of this request's
                    // deadline, not of the planning problem.
                    deadline_starved += 1;
                    let fb = run_entry_cancel(
                        &presets[ctx.net].stages[ctx.stage].layer,
                        &ctx.acc,
                        ctx.group,
                        ctx.k,
                        &entries[0],
                        None,
                    );
                    CachedStrategy {
                        strategy: fb.strategy,
                        loaded_pixels: fb.loaded_pixels,
                        makespan: fb.makespan,
                        winner: format!("{}+deadline", fb.label),
                    }
                }
                None => {
                    return Err(format!(
                        "all portfolio lanes failed for problem {}",
                        ctx.key.canonical()
                    ));
                }
            };
            resolved.insert(ctx.key.canonical().to_string(), entry);
        }
    }

    Ok(Resolution {
        resolved,
        raced: jobs.into_iter().collect(),
        store_hits,
        dedup_hits,
        cross_network_dedup_hits,
        anneal_per_net,
        panicked_lanes,
        deadline_starved,
    })
}

/// Assemble one network's plan from the batch resolution: push every stage
/// into the simulator, mark hit/miss provenance, and fill simulated
/// durations.
///
/// A stage counts as a cache **miss** exactly when it was the racing
/// representative of its problem; dedup'd repeats and store hits both count
/// as hits — identical to the single-network planner's historical
/// semantics.
pub(crate) fn assemble_network(
    preset: &NetworkPreset,
    net: usize,
    ctxs: &[StageCtx],
    res: &Resolution,
    overlap: OverlapMode,
) -> Result<NetworkPlan, String> {
    let mut network = Network::default();
    let mut layers: Vec<LayerPlan> = Vec::with_capacity(preset.stages.len());
    let mut cache_hits = 0usize;
    let mut cache_misses = 0usize;
    for (ci, ctx) in ctxs.iter().enumerate() {
        if ctx.net != net {
            continue;
        }
        let sp = &preset.stages[ctx.stage];
        let entry = res
            .resolved
            .get(ctx.key.canonical())
            .expect("every stage key resolved");
        let hit = !res.raced.contains(&ci);
        if hit {
            cache_hits += 1;
        } else {
            cache_misses += 1;
        }
        network.push(Stage {
            name: sp.name.to_string(),
            layer: sp.layer,
            accelerator: ctx.acc,
            strategy: entry.strategy.clone(),
            pool_after: sp.pool_after,
            pad_after: sp.pad_after,
        })?;
        let lb = certify::comm_lower_bound(&sp.layer, &ctx.acc);
        layers.push(LayerPlan {
            stage: sp.name.to_string(),
            layer: sp.layer,
            accelerator: ctx.acc,
            group_size: ctx.group,
            strategy: entry.strategy.clone(),
            winner: entry.winner.clone(),
            loaded_pixels: entry.loaded_pixels,
            comm_lower_bound: lb.bound_pixels,
            optimality_gap: certify::optimality_gap(
                entry.loaded_pixels,
                lb.bound_pixels,
            ),
            duration: 0, // filled from the simulation below
            sequential_duration: 0,
            cache_hit: hit,
        });
    }
    let report = network.run().map_err(|e| e.to_string())?;
    for (lp, sr) in layers.iter_mut().zip(&report.per_stage) {
        lp.duration = sr.duration;
        lp.sequential_duration = sr.sequential_duration;
    }
    Ok(NetworkPlan {
        network: preset.name.to_string(),
        total_comm_lower_bound: layers.iter().map(|l| l.comm_lower_bound).sum(),
        worst_optimality_gap: layers
            .iter()
            .map(|l| l.optimality_gap)
            .fold(0.0, f64::max),
        layers,
        total_duration: report.total_duration,
        total_sequential_duration: report.total_sequential_duration,
        overlap,
        peak_occupancy: report.peak_occupancy,
        cache_hits,
        cache_misses,
        anneal_iters_run: res.anneal_per_net[net],
    })
}

/// [`assemble_network`] under an active fault model: degraded-mode
/// replanning.
///
/// The resolved (fault-free) strategies are simulated once under the fault
/// stream; any stage that saw a `MemoryShrink` verdict gets its plan
/// re-validated against the reduced budget via
/// [`degrade_for_shrink`] (local re-grouping first, inline re-race second).
/// Degraded plans drive a second faulted simulation for the reported
/// durations. Degraded entries are **never** written back to the store —
/// the shrink is a property of this run's fault stream, not of the
/// planning problem.
///
/// The zero-fault path never enters this function, so its plans stay
/// bit-identical to [`assemble_network`]'s.
pub(crate) fn assemble_network_faulted(
    preset: &NetworkPreset,
    net: usize,
    ctxs: &[StageCtx],
    res: &Resolution,
    o: &PlanOptions,
    faults: &FaultModel,
) -> Result<(NetworkPlan, usize), String> {
    // Gather this network's stages with their resolved entries.
    let mut stages: Vec<(&StageCtx, CachedStrategy, bool)> = Vec::new();
    for (ci, ctx) in ctxs.iter().enumerate() {
        if ctx.net != net {
            continue;
        }
        let entry = res
            .resolved
            .get(ctx.key.canonical())
            .expect("every stage key resolved")
            .clone();
        stages.push((ctx, entry, !res.raced.contains(&ci)));
    }

    let build = |stages: &[(&StageCtx, CachedStrategy, bool)]| -> Result<Network, String> {
        let mut network = Network::default();
        for (ctx, entry, _) in stages {
            let sp = &preset.stages[ctx.stage];
            network.push(Stage {
                name: sp.name.to_string(),
                layer: sp.layer,
                accelerator: ctx.acc,
                strategy: entry.strategy.clone(),
                pool_after: sp.pool_after,
                pad_after: sp.pad_after,
            })?;
        }
        Ok(network)
    };

    // Pass 1: simulate the fault-free plans under the fault stream and
    // collect per-stage shrink verdicts.
    let mut report = build(&stages)?
        .run_with_faults(Some(faults))
        .map_err(|e| e.to_string())?;
    let mut degraded_stages = 0usize;
    for (i, (ctx, entry, _)) in stages.iter_mut().enumerate() {
        let events = report.per_stage[i].mem_shrink_events;
        if events == 0 {
            continue;
        }
        let sp = &preset.stages[ctx.stage];
        let shrunk = events.saturating_mul(faults.shrink_elements);
        let degraded = degraded_accelerator(&sp.layer, &ctx.acc, shrunk);
        let (replanned, outcome) =
            degrade_for_shrink(&sp.layer, &degraded, ctx.group, entry, o);
        if outcome != DegradeOutcome::Unchanged {
            *entry = replanned;
            degraded_stages += 1;
        }
    }

    // Pass 2: only when something degraded — re-run the degraded plans on
    // the *original* accelerators under the same fault stream for the final
    // reported durations (the shrink re-applies deterministically).
    if degraded_stages > 0 {
        report = build(&stages)?
            .run_with_faults(Some(faults))
            .map_err(|e| e.to_string())?;
    }

    let mut layers: Vec<LayerPlan> = Vec::with_capacity(stages.len());
    let mut cache_hits = 0usize;
    let mut cache_misses = 0usize;
    for ((ctx, entry, hit), sr) in stages.iter().zip(&report.per_stage) {
        let sp = &preset.stages[ctx.stage];
        if *hit {
            cache_hits += 1;
        } else {
            cache_misses += 1;
        }
        let lb = certify::comm_lower_bound(&sp.layer, &ctx.acc);
        layers.push(LayerPlan {
            stage: sp.name.to_string(),
            layer: sp.layer,
            accelerator: ctx.acc,
            group_size: ctx.group,
            strategy: entry.strategy.clone(),
            winner: entry.winner.clone(),
            loaded_pixels: entry.loaded_pixels,
            comm_lower_bound: lb.bound_pixels,
            optimality_gap: certify::optimality_gap(
                entry.loaded_pixels,
                lb.bound_pixels,
            ),
            duration: sr.duration,
            sequential_duration: sr.sequential_duration,
            cache_hit: *hit,
        });
    }
    Ok((
        NetworkPlan {
            network: preset.name.to_string(),
            total_comm_lower_bound: layers
                .iter()
                .map(|l| l.comm_lower_bound)
                .sum(),
            worst_optimality_gap: layers
                .iter()
                .map(|l| l.optimality_gap)
                .fold(0.0, f64::max),
            layers,
            total_duration: report.total_duration,
            total_sequential_duration: report.total_sequential_duration,
            overlap: o.overlap,
            peak_occupancy: report.peak_occupancy,
            cache_hits,
            cache_misses,
            anneal_iters_run: res.anneal_per_net[net],
        },
        degraded_stages,
    ))
}

/// Batch-level accounting surfaced by `plan-batch` and the bench suite.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct BatchStats {
    /// Requests (networks) in the batch.
    pub networks: usize,
    /// Stages across all requests.
    pub stages_total: usize,
    /// Distinct planning problems after cross-network deduplication.
    pub unique_problems: usize,
    /// Stages whose problem was already planned (or queued) earlier in the
    /// batch — any network.
    pub dedup_hits: usize,
    /// The subset of `dedup_hits` first seen in a *different* network.
    pub cross_network_dedup_hits: usize,
    /// Unique problems served by the persistent store (validated hits).
    pub store_hits: usize,
    /// Unique problems that required a fresh portfolio race.
    pub store_misses: usize,
    /// Annealing iterations executed across the whole batch — 0 when every
    /// problem came from the store.
    pub anneal_iters_run: u64,
    /// Raw counters of the backing sharded cache (zeros when the planner
    /// runs without persistence).
    pub cache: CacheCounterSnapshot,
    /// Shard count of the backing cache (0 without persistence).
    pub shard_count: usize,
    /// Portfolio lanes lost to worker panics during the shared race (each
    /// lost exactly its own result; the batch completed on the survivors).
    pub panicked_lanes: usize,
    /// Stages whose plan was degraded (re-grouped or re-raced) after a
    /// `MemoryShrink` fault verdict — always 0 without an active fault
    /// model.
    pub degraded_stages: usize,
    /// Unique problems served by the inline deadline fallback because a
    /// fired cancel flag skipped *every* portfolio lane (winner tagged
    /// `+deadline`) — always 0 without a cancel flag.
    pub deadline_starved: usize,
}

/// The result of one batch: per-request plans (input order) plus the
/// batch-level accounting.
#[derive(Debug, Clone)]
pub struct BatchReport {
    /// One plan per request, in input order.
    pub plans: Vec<NetworkPlan>,
    /// Largest per-stage pixel-domain optimality gap across every plan
    /// (0.0 for an empty batch) — the batch-level certification headline.
    pub worst_gap: f64,
    /// Batch-level dedup / cache / effort accounting.
    pub stats: BatchStats,
}

/// The batch planning facade: [`NetworkPlanner`](super::NetworkPlanner) for
/// many networks at once, with cross-network deduplication and a shared
/// race pool, optionally backed by a [`ShardedStrategyCache`].
#[derive(Debug, Clone)]
pub struct BatchPlanner {
    /// Planner configuration shared by every request in a batch (the
    /// overlap mode and portfolio budgets are part of every cache key).
    pub options: PlanOptions,
    cache: Option<ShardedStrategyCache>,
    faults: Option<FaultModel>,
    chaos: ChaosSpec,
}

impl BatchPlanner {
    /// Batch planner without persistence (cross-network dedup still works;
    /// every unique problem races once per call).
    pub fn new(options: PlanOptions) -> Self {
        BatchPlanner {
            options,
            cache: None,
            faults: None,
            chaos: ChaosSpec::default(),
        }
    }

    /// Batch planner backed by a sharded on-disk strategy cache.
    pub fn with_cache(options: PlanOptions, cache: ShardedStrategyCache) -> Self {
        BatchPlanner { cache: Some(cache), ..BatchPlanner::new(options) }
    }

    /// Simulate every planned network under `faults` and replan degraded
    /// stages (see [`assemble_network_faulted`]). An inactive model is
    /// ignored: the zero-fault path stays bit-identical to the default.
    /// The fault model never enters cache keys — planning problems are
    /// fault-free by definition; only execution is faulted.
    pub fn with_faults(mut self, faults: FaultModel) -> Self {
        self.faults = Some(faults);
        self
    }

    /// Inject deterministic chaos into the shared race (test / drill hook).
    pub fn with_chaos(mut self, chaos: ChaosSpec) -> Self {
        self.chaos = chaos;
        self
    }

    /// The backing sharded cache, if any.
    pub fn cache(&self) -> Option<&ShardedStrategyCache> {
        self.cache.as_ref()
    }

    /// True when **every** unique planning problem of `presets` would be a
    /// validated store hit — i.e. a subsequent [`plan_batch`](Self::plan_batch)
    /// runs zero portfolio races. The cache-only rung of a loaded planning
    /// service uses this to decide between serving warm and rejecting
    /// `overloaded`; it shares the hit-validation predicate with the batch
    /// resolution, so the answer cannot drift from what `plan_batch` does.
    /// Always false without a backing cache.
    pub fn fully_cached(&self, presets: &[NetworkPreset]) -> bool {
        let Some(cache) = self.cache.as_ref() else {
            return false;
        };
        let o = &self.options;
        let refs: Vec<&NetworkPreset> = presets.iter().collect();
        let ctxs = stage_contexts(o, &refs);
        let mut seen: BTreeSet<&str> = BTreeSet::new();
        ctxs.iter().all(|ctx| {
            if !seen.insert(ctx.key.canonical()) {
                return true; // dedup hit: planned once for the batch
            }
            let layer = &refs[ctx.net].stages[ctx.stage].layer;
            validated_hit(cache, ctx, layer, o).is_some()
        })
    }

    /// Plan every network of the batch.
    ///
    /// Identical problems are planned **once** for the whole batch; the
    /// plans are bit-identical to planning each network alone with the same
    /// options (determinism is by construction: pure lanes, order-fixed
    /// reduction).
    ///
    /// # Examples
    ///
    /// ```
    /// use convoffload::config::network_preset;
    /// use convoffload::planner::{BatchPlanner, PlanOptions};
    ///
    /// let lenet = network_preset("lenet5").unwrap();
    /// let planner = BatchPlanner::new(PlanOptions {
    ///     anneal_iters: 200, // tiny budget: doc-test speed
    ///     anneal_starts: 1,
    ///     ..PlanOptions::default()
    /// });
    /// let report = planner.plan_batch(&[lenet.clone(), lenet]).unwrap();
    /// assert_eq!(report.plans.len(), 2);
    /// // the twin network re-used every shape of the first
    /// assert_eq!(report.stats.unique_problems, 2);
    /// assert_eq!(report.stats.cross_network_dedup_hits, 2);
    /// ```
    pub fn plan_batch(&self, presets: &[NetworkPreset]) -> Result<BatchReport, String> {
        self.plan_batch_cancellable(presets, None)
    }

    /// [`plan_batch`](Self::plan_batch) with a cooperative cancel flag — the
    /// deadline token a planning service threads into a request.
    ///
    /// While the flag is unset this is bit-identical to `plan_batch` (the
    /// polls sit before any RNG draw). Once it fires, running annealing
    /// lanes return best-so-far, unclaimed lanes are skipped, problems that
    /// lost every lane are served by the deterministic `+deadline` fallback
    /// (counted in [`BatchStats::deadline_starved`]), and nothing computed
    /// under the fired flag is written back to the persistent store.
    pub fn plan_batch_cancellable(
        &self,
        presets: &[NetworkPreset],
        cancel: Option<&AtomicBool>,
    ) -> Result<BatchReport, String> {
        let o = &self.options;
        let refs: Vec<&NetworkPreset> = presets.iter().collect();
        let ctxs = stage_contexts(o, &refs);
        let store = self.cache.as_ref().map(|c| c as &dyn StrategyStore);
        let res = resolve_chaos_cancel(&refs, &ctxs, o, store, &self.chaos, cancel)?;

        let faults = self.faults.as_ref().filter(|f| f.is_active());
        let mut plans = Vec::with_capacity(presets.len());
        let mut degraded_stages = 0usize;
        for (net, preset) in presets.iter().enumerate() {
            match faults {
                // The zero-fault path goes through the historical assembly
                // untouched, so its plans stay bit-identical.
                None => plans.push(assemble_network(preset, net, &ctxs, &res, o.overlap)?),
                Some(m) => {
                    let (plan, degraded) =
                        assemble_network_faulted(preset, net, &ctxs, &res, o, m)?;
                    degraded_stages += degraded;
                    plans.push(plan);
                }
            }
        }
        let unique_problems = ctxs.len() - res.dedup_hits;
        let stats = BatchStats {
            networks: presets.len(),
            stages_total: ctxs.len(),
            unique_problems,
            dedup_hits: res.dedup_hits,
            cross_network_dedup_hits: res.cross_network_dedup_hits,
            store_hits: res.store_hits,
            store_misses: res.raced.len(),
            anneal_iters_run: res.anneal_per_net.iter().sum(),
            cache: self
                .cache
                .as_ref()
                .map(|c| c.stats())
                .unwrap_or_default(),
            shard_count: self.cache.as_ref().map_or(0, |c| c.shard_count()),
            panicked_lanes: res.panicked_lanes,
            degraded_stages,
            deadline_starved: res.deadline_starved,
        };
        let worst_gap = plans
            .iter()
            .map(|p| p.worst_optimality_gap)
            .fold(0.0, f64::max);
        Ok(BatchReport { plans, worst_gap, stats })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::NetworkStagePreset;
    use crate::planner::{AcceleratorSpec, NetworkPlanner};

    fn tiny(name: &str) -> NetworkPreset {
        NetworkPreset {
            name: name.to_string(),
            description: "1x8x8 conv -> pool -> 2x3x3 conv".into(),
            stages: vec![
                NetworkStagePreset {
                    name: "c1".into(),
                    layer: ConvLayer::new(1, 8, 8, 3, 3, 2, 1, 1).unwrap(),
                    pool_after: true,
                    pad_after: 0,
                },
                NetworkStagePreset {
                    name: "c2".into(),
                    layer: ConvLayer::new(2, 3, 3, 3, 3, 1, 1, 1).unwrap(),
                    pool_after: false,
                    pad_after: 0,
                },
            ],
        }
    }

    fn other() -> NetworkPreset {
        NetworkPreset {
            name: "other".into(),
            description: "one distinct stage".into(),
            stages: vec![NetworkStagePreset {
                name: "c1".into(),
                layer: ConvLayer::new(1, 6, 6, 3, 3, 1, 1, 1).unwrap(),
                pool_after: false,
                pad_after: 0,
            }],
        }
    }

    fn quick_options() -> PlanOptions {
        PlanOptions {
            accelerator: AcceleratorSpec::PerLayerGroup(2),
            seed: 7,
            anneal_iters: 1_000,
            anneal_starts: 2,
            threads: 0,
            overlap: OverlapMode::Sequential,
            dma_channels: 1,
            compute_units: 1,
        }
    }

    /// The batch plans match planning each network alone with the same
    /// options — the batch machinery changes scheduling, never results.
    #[test]
    fn batch_matches_solo_plans() {
        let nets = [tiny("a"), other()];
        let report = BatchPlanner::new(quick_options())
            .plan_batch(&nets)
            .unwrap();
        for (preset, plan) in nets.iter().zip(&report.plans) {
            let solo = NetworkPlanner::new(quick_options()).plan(preset).unwrap();
            assert_eq!(plan.total_duration, solo.total_duration, "{}", preset.name);
            assert_eq!(plan.network, solo.network);
            for (a, b) in plan.layers.iter().zip(&solo.layers) {
                assert_eq!(a.strategy, b.strategy);
                assert_eq!(a.winner, b.winner);
                assert_eq!(a.loaded_pixels, b.loaded_pixels);
                assert_eq!(a.duration, b.duration);
            }
        }
    }

    /// Twin networks dedupe across the batch: every shape of the second is
    /// a cross-network dedup hit and races zero extra iterations.
    #[test]
    fn twin_networks_dedupe_across_the_batch() {
        let report = BatchPlanner::new(quick_options())
            .plan_batch(&[tiny("a"), tiny("b")])
            .unwrap();
        let s = &report.stats;
        assert_eq!(s.networks, 2);
        assert_eq!(s.stages_total, 4);
        assert_eq!(s.unique_problems, 2);
        assert_eq!(s.dedup_hits, 2);
        assert_eq!(s.cross_network_dedup_hits, 2);
        assert_eq!(s.store_misses, 2, "no persistence: every unique problem races");
        assert_eq!(s.store_hits, 0);
        // the first network raced, the twin rode the results
        assert_eq!(report.plans[0].cache_misses, 2);
        assert_eq!(report.plans[1].cache_hits, 2);
        assert_eq!(report.plans[1].anneal_iters_run, 0);
        assert_eq!(
            report.plans[0].total_duration,
            report.plans[1].total_duration
        );
    }

    /// Batch determinism: same options, any thread count, same everything.
    #[test]
    fn same_seed_same_batch_any_thread_count() {
        let nets = [tiny("a"), other(), tiny("c")];
        let mut opts = quick_options();
        let base = BatchPlanner::new(opts.clone()).plan_batch(&nets).unwrap();
        for threads in [1usize, 2, 8] {
            opts.threads = threads;
            let again = BatchPlanner::new(opts.clone()).plan_batch(&nets).unwrap();
            assert_eq!(again.stats, base.stats, "threads={threads}");
            for (a, b) in base.plans.iter().zip(&again.plans) {
                assert_eq!(a.total_duration, b.total_duration, "threads={threads}");
                for (la, lb) in a.layers.iter().zip(&b.layers) {
                    assert_eq!(la.strategy, lb.strategy, "threads={threads}");
                    assert_eq!(la.winner, lb.winner);
                }
            }
        }
    }

    /// Warm path: a second identical batch over the same sharded cache is
    /// all store hits and performs zero annealing iterations.
    #[test]
    fn second_identical_batch_runs_zero_anneal_iterations() {
        let dir = std::env::temp_dir().join(format!(
            "convoffload-batch-warm-{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        let nets = [tiny("a"), tiny("b"), other()];
        let cache = ShardedStrategyCache::open(&dir).unwrap();
        let planner = BatchPlanner::with_cache(quick_options(), cache);

        let cold = planner.plan_batch(&nets).unwrap();
        assert_eq!(cold.stats.unique_problems, 3);
        assert_eq!(cold.stats.store_misses, 3);
        assert_eq!(cold.stats.store_hits, 0);
        assert!(cold.stats.anneal_iters_run > 0);
        assert_eq!(cold.stats.shard_count, super::super::shard::DEFAULT_SHARDS);

        let warm = planner.plan_batch(&nets).unwrap();
        assert_eq!(warm.stats.store_hits, 3, "all unique problems served warm");
        assert_eq!(warm.stats.store_misses, 0);
        assert_eq!(warm.stats.anneal_iters_run, 0, "warm batch must not anneal");
        for plan in &warm.plans {
            assert_eq!(plan.cache_misses, 0);
            assert_eq!(plan.anneal_iters_run, 0);
        }
        // and the results did not drift
        for (a, b) in cold.plans.iter().zip(&warm.plans) {
            assert_eq!(a.total_duration, b.total_duration);
        }
        // cache counters flowed into the stats (≥ 3 hits from the warm pass)
        assert!(warm.stats.cache.hits >= 3);
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// Overlap modes stay isolated under batch planning: a sequential batch
    /// then a double-buffered batch over one cache directory never serve
    /// each other's entries.
    #[test]
    fn batch_overlap_modes_do_not_share_entries() {
        let dir = std::env::temp_dir().join(format!(
            "convoffload-batch-modes-{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        let nets = [tiny("a"), other()];
        let cache = ShardedStrategyCache::open(&dir).unwrap();
        let seq = BatchPlanner::with_cache(quick_options(), cache.clone())
            .plan_batch(&nets)
            .unwrap();
        assert_eq!(seq.stats.store_misses, 3);
        let mut opts = quick_options();
        opts.overlap = OverlapMode::DoubleBuffered;
        let db = BatchPlanner::with_cache(opts, cache)
            .plan_batch(&nets)
            .unwrap();
        assert_eq!(db.stats.store_misses, 3, "other mode must not hit");
        assert_eq!(db.stats.store_hits, 0);
        for plan in &db.plans {
            assert!(plan.total_duration <= plan.total_sequential_duration);
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// An unfired cancel flag is invisible: the batch is bit-identical to
    /// the plain path, stats included.
    #[test]
    fn unfired_cancel_flag_is_bit_identical() {
        let nets = [tiny("a"), other()];
        let planner = BatchPlanner::new(quick_options());
        let base = planner.plan_batch(&nets).unwrap();
        let flag = AtomicBool::new(false);
        let same = planner.plan_batch_cancellable(&nets, Some(&flag)).unwrap();
        assert_eq!(same.stats, base.stats);
        assert_eq!(same.stats.deadline_starved, 0);
        for (a, b) in base.plans.iter().zip(&same.plans) {
            assert_eq!(a.total_duration, b.total_duration);
            for (la, lb) in a.layers.iter().zip(&b.layers) {
                assert_eq!(la.strategy, lb.strategy);
                assert_eq!(la.winner, lb.winner);
            }
        }
    }

    /// A pre-fired cancel flag (deadline already blown on entry) starves
    /// every unique problem: each is served by the deterministic `+deadline`
    /// fallback, zero annealing iterations run, every plan stays complete
    /// and valid, and nothing is written to the persistent store.
    #[test]
    fn pre_fired_cancel_serves_deadline_fallbacks() {
        let dir = std::env::temp_dir().join(format!(
            "convoffload-batch-deadline-{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        let nets = [tiny("a"), other()];
        let cache = ShardedStrategyCache::open(&dir).unwrap();
        let planner = BatchPlanner::with_cache(quick_options(), cache);

        let flag = AtomicBool::new(true);
        let starved = planner.plan_batch_cancellable(&nets, Some(&flag)).unwrap();
        assert_eq!(starved.stats.deadline_starved, 3, "all unique problems starved");
        assert_eq!(starved.stats.anneal_iters_run, 0, "no budget left to spend");
        for (plan, preset) in starved.plans.iter().zip(&nets) {
            assert_eq!(plan.layers.len(), preset.stages.len(), "no stage lost");
            for lp in &plan.layers {
                assert!(lp.winner.ends_with("+deadline"), "winner: {}", lp.winner);
                let mut all: Vec<u32> =
                    lp.strategy.groups.iter().flatten().copied().collect();
                all.sort();
                assert_eq!(all, lp.layer.all_patches().collect::<Vec<_>>());
            }
        }
        // deterministic: the same starved batch twice
        let again = planner.plan_batch_cancellable(&nets, Some(&flag)).unwrap();
        assert_eq!(again.stats.deadline_starved, 3);
        for (a, b) in starved.plans.iter().zip(&again.plans) {
            assert_eq!(a.total_duration, b.total_duration);
        }
        // fallbacks were never stored: a later full-budget batch misses cold
        let cold = planner.plan_batch(&nets).unwrap();
        assert_eq!(cold.stats.store_hits, 0, "deadline fallbacks must not be cached");
        assert_eq!(cold.stats.store_misses, 3);
        assert_eq!(cold.stats.deadline_starved, 0);
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// `fully_cached` agrees with what `plan_batch` actually does: false on
    /// a cold cache (a batch would race), true after warming (a batch would
    /// run zero anneal iterations), and always false without persistence.
    #[test]
    fn fully_cached_tracks_the_store() {
        let dir = std::env::temp_dir().join(format!(
            "convoffload-batch-fullycached-{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        let nets = [tiny("a"), tiny("b"), other()];
        let cache = ShardedStrategyCache::open(&dir).unwrap();
        let planner = BatchPlanner::with_cache(quick_options(), cache);

        assert!(!planner.fully_cached(&nets), "cold cache would race");
        planner.plan_batch(&nets).unwrap();
        assert!(planner.fully_cached(&nets), "warm cache serves without racing");
        // a shape the cache has never seen flips the answer back
        let more = [tiny("a"), NetworkPreset {
            name: "fresh".into(),
            description: "unseen shape".into(),
            stages: vec![NetworkStagePreset {
                name: "c1".into(),
                layer: ConvLayer::new(1, 12, 12, 3, 3, 1, 1, 1).unwrap(),
                pool_after: false,
                pad_after: 0,
            }],
        }];
        assert!(!planner.fully_cached(&more));
        assert!(
            !BatchPlanner::new(quick_options()).fully_cached(&nets),
            "no persistence, nothing is cached"
        );
        assert!(planner.fully_cached(&[]), "an empty batch needs nothing");
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// An empty batch is a valid no-op, not an error.
    #[test]
    fn empty_batch_is_a_noop() {
        let report = BatchPlanner::new(quick_options()).plan_batch(&[]).unwrap();
        assert!(report.plans.is_empty());
        assert_eq!(report.stats.stages_total, 0);
        assert_eq!(report.stats.unique_problems, 0);
    }

    /// A portfolio lane that panics loses exactly its own result: the batch
    /// completes on the surviving lanes, counts the losses, and stays
    /// deterministic.
    #[test]
    fn crashed_lane_loses_one_lane_not_the_batch() {
        let nets = [tiny("a"), other()];
        let chaos = ChaosSpec { panic_lane: Some("greedy".into()) };
        let report = BatchPlanner::new(quick_options())
            .with_chaos(chaos.clone())
            .plan_batch(&nets)
            .unwrap();
        // 3 unique problems × 1 crashed lane each
        assert_eq!(report.stats.panicked_lanes, 3);
        assert_eq!(report.plans.len(), 2, "every network still planned");
        for plan in &report.plans {
            assert!(!plan.layers.is_empty());
            for lp in &plan.layers {
                assert_ne!(lp.winner, "greedy", "crashed lane cannot win");
            }
        }
        // chaos is deterministic: same spec, same results
        let again = BatchPlanner::new(quick_options())
            .with_chaos(chaos)
            .plan_batch(&nets)
            .unwrap();
        assert_eq!(again.stats, report.stats);
        for (a, b) in report.plans.iter().zip(&again.plans) {
            assert_eq!(a.total_duration, b.total_duration);
        }
    }

    /// An *inactive* fault model leaves the batch bit-identical to the
    /// default path, and an active one surfaces its accounting without
    /// losing any stage.
    #[test]
    fn faulted_batch_covers_every_stage() {
        let nets = [tiny("a"), other()];
        let base = BatchPlanner::new(quick_options()).plan_batch(&nets).unwrap();

        let inert = BatchPlanner::new(quick_options())
            .with_faults(FaultModel::none())
            .plan_batch(&nets)
            .unwrap();
        assert_eq!(inert.stats, base.stats);
        for (a, b) in base.plans.iter().zip(&inert.plans) {
            assert_eq!(a.total_duration, b.total_duration);
            assert_eq!(a.layers.len(), b.layers.len());
        }

        let model = FaultModel {
            dma_fail_rate: 0.5,
            max_retries: 3,
            retry_penalty: 4,
            dma_jitter: 2,
            ..FaultModel::none().with_seed(13)
        };
        let faulted = BatchPlanner::new(quick_options())
            .with_faults(model)
            .plan_batch(&nets)
            .unwrap();
        assert_eq!(faulted.plans.len(), 2);
        for (plan, preset) in faulted.plans.iter().zip(&nets) {
            assert_eq!(plan.layers.len(), preset.stages.len());
        }
        // retries only ever lengthen the timeline
        for (a, b) in base.plans.iter().zip(&faulted.plans) {
            assert!(b.total_duration >= a.total_duration);
        }
        // strategies are planned fault-free; the fault model only affects
        // execution, so winners match the baseline (no shrink configured)
        assert_eq!(faulted.stats.degraded_stages, 0);
        for (a, b) in base.plans.iter().zip(&faulted.plans) {
            for (la, lb) in a.layers.iter().zip(&b.layers) {
                assert_eq!(la.strategy, lb.strategy);
            }
        }
    }

    /// A shrink-heavy fault stream forces degraded-mode replanning: the
    /// batch still returns a plan for every stage, counts the degradations,
    /// and marks the replanned winners' provenance.
    #[test]
    fn shrink_faults_degrade_and_still_plan() {
        let nets = [tiny("a"), other()];
        let model = FaultModel {
            shrink_rate: 1.0, // every step shrinks: the budget collapses fast
            shrink_elements: 8,
            ..FaultModel::none().with_seed(7)
        };
        let report = BatchPlanner::new(quick_options())
            .with_faults(model)
            .plan_batch(&nets)
            .unwrap();
        assert_eq!(report.plans.len(), 2);
        assert!(report.stats.degraded_stages > 0, "shrink must bite");
        let mut saw_degraded_winner = false;
        for (plan, preset) in report.plans.iter().zip(&nets) {
            assert_eq!(plan.layers.len(), preset.stages.len(), "no stage lost");
            for lp in &plan.layers {
                if lp.winner.contains("+regroup")
                    || lp.winner.contains("+rerace")
                    || lp.winner.contains("+serialize")
                {
                    saw_degraded_winner = true;
                }
            }
        }
        assert!(saw_degraded_winner, "degraded plans carry their provenance");
        // deterministic: same model, same degraded batch
        let again = BatchPlanner::new(quick_options())
            .with_faults(model)
            .plan_batch(&nets)
            .unwrap();
        assert_eq!(again.stats, report.stats);
        for (a, b) in report.plans.iter().zip(&again.plans) {
            assert_eq!(a.total_duration, b.total_duration);
            for (la, lb) in a.layers.iter().zip(&b.layers) {
                assert_eq!(la.strategy, lb.strategy);
                assert_eq!(la.winner, lb.winner);
            }
        }
    }
}

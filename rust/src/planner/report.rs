//! Plan rendering: the CLI table and a JSON form for tooling.

use crate::planner::{LayerPlan, NetworkPlan};
use crate::platform::OverlapMode;
use crate::util::json::Json;

/// Fixed-width per-layer table plus the end-to-end summary — the output of
/// `convoffload plan-network`.
pub fn format_plan_table(plan: &NetworkPlan) -> String {
    let mut out = format!("network: {}\n\n", plan.network);
    out.push_str(
        " stage    | layer                                     |  g | steps | winner        | loaded px | duration | cache\n",
    );
    out.push_str(
        "----------+-------------------------------------------+----+-------+---------------+-----------+----------+------\n",
    );
    for lp in &plan.layers {
        let layer = lp.layer.to_string();
        out.push_str(&format!(
            " {:<8} | {:<41} | {:>2} | {:>5} | {:<13} | {:>9} | {:>8} | {}\n",
            lp.stage,
            layer,
            lp.group_size,
            lp.strategy.n_steps(),
            lp.winner,
            lp.loaded_pixels,
            lp.duration,
            if lp.cache_hit { "hit" } else { "miss" },
        ));
    }
    out.push_str(&format!(
        "\ntotal simulated duration: {} cycles  (peak on-chip occupancy {} elements)\n",
        plan.total_duration, plan.peak_occupancy,
    ));
    if plan.overlap == OverlapMode::DoubleBuffered {
        out.push_str(&format!(
            "double-buffered: sequential duration {} cycles, {} cycles of transfer hidden behind compute\n",
            plan.total_sequential_duration,
            plan.total_sequential_duration - plan.total_duration,
        ));
    }
    out.push_str(&format!(
        "cache: {} hits / {} misses  |  anneal iterations run: {}\n",
        plan.cache_hits, plan.cache_misses, plan.anneal_iters_run,
    ));
    out
}

fn layer_to_json(lp: &LayerPlan) -> Json {
    let mut o = Json::obj();
    o.set("stage", lp.stage.as_str())
        .set("layer", lp.layer.to_string())
        .set("group_size", lp.group_size)
        .set("n_steps", lp.strategy.n_steps())
        .set("winner", lp.winner.as_str())
        .set("loaded_pixels", lp.loaded_pixels)
        .set("duration", lp.duration)
        .set("sequential_duration", lp.sequential_duration)
        .set("cache_hit", lp.cache_hit);
    o
}

/// Serialize a plan (without the raw group lists) for traces and tooling.
pub fn plan_to_json(plan: &NetworkPlan) -> Json {
    let mut o = Json::obj();
    o.set("network", plan.network.as_str())
        .set("total_duration", plan.total_duration)
        .set("total_sequential_duration", plan.total_sequential_duration)
        .set("overlap", plan.overlap.as_str())
        .set("peak_occupancy", plan.peak_occupancy)
        .set("cache_hits", plan.cache_hits)
        .set("cache_misses", plan.cache_misses)
        .set("anneal_iters_run", plan.anneal_iters_run)
        .set(
            "layers",
            Json::Arr(plan.layers.iter().map(layer_to_json).collect()),
        );
    o
}

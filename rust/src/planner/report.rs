//! Plan rendering: the CLI tables and JSON forms for tooling — single
//! network (`plan-network`) and batch (`plan-batch`).

use crate::planner::{BatchReport, LayerPlan, NetworkPlan};
use crate::platform::OverlapMode;
use crate::util::json::Json;

/// Fixed-width per-layer table plus the end-to-end summary — the output of
/// `convoffload plan-network`.
pub fn format_plan_table(plan: &NetworkPlan) -> String {
    let mut out = format!("network: {}\n\n", plan.network);
    out.push_str(
        " stage    | layer                                     |  g | steps | winner        | loaded px | bound px |    gap | duration | cache\n",
    );
    out.push_str(
        "----------+-------------------------------------------+----+-------+---------------+-----------+----------+--------+----------+------\n",
    );
    for lp in &plan.layers {
        let layer = lp.layer.to_string();
        out.push_str(&format!(
            " {:<8} | {:<41} | {:>2} | {:>5} | {:<13} | {:>9} | {:>8} | {:>6.4} | {:>8} | {}\n",
            lp.stage,
            layer,
            lp.group_size,
            lp.strategy.n_steps(),
            lp.winner,
            lp.loaded_pixels,
            lp.comm_lower_bound,
            lp.optimality_gap,
            lp.duration,
            if lp.cache_hit { "hit" } else { "miss" },
        ));
    }
    out.push_str(&format!(
        "\ntotal simulated duration: {} cycles  (peak on-chip occupancy {} elements)\n",
        plan.total_duration, plan.peak_occupancy,
    ));
    out.push_str(&format!(
        "certified floor: {} pixels  |  worst stage gap: {:.4}\n",
        plan.total_comm_lower_bound, plan.worst_optimality_gap,
    ));
    if plan.overlap == OverlapMode::DoubleBuffered {
        out.push_str(&format!(
            "double-buffered: sequential duration {} cycles, {} cycles of transfer hidden behind compute\n",
            plan.total_sequential_duration,
            plan.total_sequential_duration - plan.total_duration,
        ));
    }
    out.push_str(&format!(
        "cache: {} hits / {} misses  |  anneal iterations run: {}\n",
        plan.cache_hits, plan.cache_misses, plan.anneal_iters_run,
    ));
    out
}

fn layer_to_json(lp: &LayerPlan) -> Json {
    let mut o = Json::obj();
    o.set("stage", lp.stage.as_str())
        .set("layer", lp.layer.to_string())
        .set("group_size", lp.group_size)
        .set("n_steps", lp.strategy.n_steps())
        .set("winner", lp.winner.as_str())
        .set("loaded_pixels", lp.loaded_pixels)
        .set("comm_lower_bound", lp.comm_lower_bound)
        .set("optimality_gap", lp.optimality_gap)
        .set("duration", lp.duration)
        .set("sequential_duration", lp.sequential_duration)
        .set("cache_hit", lp.cache_hit);
    o
}

/// Serialize a plan (without the raw group lists) for traces and tooling.
pub fn plan_to_json(plan: &NetworkPlan) -> Json {
    let mut o = Json::obj();
    o.set("network", plan.network.as_str())
        .set("total_duration", plan.total_duration)
        .set("total_sequential_duration", plan.total_sequential_duration)
        .set("overlap", plan.overlap.as_str())
        .set("peak_occupancy", plan.peak_occupancy)
        .set("cache_hits", plan.cache_hits)
        .set("cache_misses", plan.cache_misses)
        .set("anneal_iters_run", plan.anneal_iters_run)
        .set("total_comm_lower_bound", plan.total_comm_lower_bound)
        .set("worst_optimality_gap", plan.worst_optimality_gap)
        .set(
            "layers",
            Json::Arr(plan.layers.iter().map(layer_to_json).collect()),
        );
    o
}

/// The `plan-batch` output: every per-network table followed by the
/// batch-level dedup / cache accounting.
pub fn format_batch_table(report: &BatchReport) -> String {
    let mut out = String::new();
    for plan in &report.plans {
        out.push_str(&format_plan_table(plan));
        out.push('\n');
    }
    let s = &report.stats;
    out.push_str(&format!(
        "batch: {} networks, {} stages -> {} unique planning problems\n",
        s.networks, s.stages_total, s.unique_problems,
    ));
    out.push_str(&format!(
        "dedup: {} hits ({} cross-network)  |  store: {} hits / {} misses\n",
        s.dedup_hits, s.cross_network_dedup_hits, s.store_hits, s.store_misses,
    ));
    out.push_str(&format!(
        "anneal iterations run: {}\n",
        s.anneal_iters_run,
    ));
    out.push_str(&format!(
        "worst optimality gap: {:.4}\n",
        report.worst_gap,
    ));
    if s.shard_count > 0 {
        out.push_str(&format!(
            "{} ({} shards)\n",
            s.cache.summary_line(),
            s.shard_count,
        ));
    }
    if s.panicked_lanes > 0 || s.degraded_stages > 0 {
        out.push_str(&format!(
            "resilience: {} portfolio lanes lost to panics, {} stages replanned after memory shrink\n",
            s.panicked_lanes, s.degraded_stages,
        ));
    }
    if s.deadline_starved > 0 {
        out.push_str(&format!(
            "deadline: {} problems served by the inline fallback (+deadline winners)\n",
            s.deadline_starved,
        ));
    }
    out
}

/// Serialize a batch report (plans plus accounting) for tooling and the
/// bench artifacts.
pub fn batch_to_json(report: &BatchReport) -> Json {
    let s = &report.stats;
    let mut stats = Json::obj();
    stats
        .set("networks", s.networks)
        .set("stages_total", s.stages_total)
        .set("unique_problems", s.unique_problems)
        .set("dedup_hits", s.dedup_hits)
        .set("cross_network_dedup_hits", s.cross_network_dedup_hits)
        .set("store_hits", s.store_hits)
        .set("store_misses", s.store_misses)
        .set("anneal_iters_run", s.anneal_iters_run)
        .set("shard_count", s.shard_count)
        .set("panicked_lanes", s.panicked_lanes)
        .set("degraded_stages", s.degraded_stages)
        .set("deadline_starved", s.deadline_starved)
        .set("worst_gap", report.worst_gap)
        .set("cache", s.cache.to_json());
    let mut o = Json::obj();
    o.set(
        "plans",
        Json::Arr(report.plans.iter().map(plan_to_json).collect()),
    )
    .set("stats", stats);
    o
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::network_preset;
    use crate::planner::{AcceleratorSpec, BatchPlanner, PlanOptions};

    #[test]
    fn batch_report_renders_both_forms() {
        let lenet = network_preset("lenet5").unwrap();
        let report = BatchPlanner::new(PlanOptions {
            accelerator: AcceleratorSpec::PerLayerGroup(2),
            anneal_iters: 200,
            anneal_starts: 1,
            ..PlanOptions::default()
        })
        .plan_batch(&[lenet.clone(), lenet])
        .unwrap();

        let table = format_batch_table(&report);
        assert!(table.contains("batch: 2 networks, 4 stages -> 2 unique planning problems"));
        assert!(table.contains("dedup: 2 hits (2 cross-network)"));
        assert!(
            !table.contains("resilience:"),
            "clean batches stay quiet about resilience"
        );

        let j = batch_to_json(&report);
        let stats = j.get("stats").unwrap();
        assert_eq!(stats.get("unique_problems").unwrap().as_u64(), Some(2));
        assert_eq!(
            stats.get("cross_network_dedup_hits").unwrap().as_u64(),
            Some(2)
        );
        assert_eq!(stats.get("panicked_lanes").unwrap().as_u64(), Some(0));
        assert_eq!(stats.get("degraded_stages").unwrap().as_u64(), Some(0));
        assert_eq!(j.get("plans").unwrap().as_arr().unwrap().len(), 2);

        // Certification threads through both forms.
        assert!(table.contains("worst optimality gap:"));
        assert_eq!(
            stats.get("worst_gap").unwrap().as_f64(),
            Some(report.worst_gap)
        );
        let plan0 = &j.get("plans").unwrap().as_arr().unwrap()[0];
        let layer0 = &plan0.get("layers").unwrap().as_arr().unwrap()[0];
        let bound = layer0.get("comm_lower_bound").unwrap().as_u64().unwrap();
        let loaded = layer0.get("loaded_pixels").unwrap().as_u64().unwrap();
        assert!(bound > 0 && bound <= loaded);
        assert!(layer0.get("optimality_gap").unwrap().as_f64().is_some());
        assert!(plan0.get("total_comm_lower_bound").unwrap().as_u64().unwrap() > 0);
    }

    #[test]
    fn chaotic_batch_surfaces_its_resilience_line() {
        use crate::planner::ChaosSpec;
        let lenet = network_preset("lenet5").unwrap();
        let report = BatchPlanner::new(PlanOptions {
            accelerator: AcceleratorSpec::PerLayerGroup(2),
            anneal_iters: 200,
            anneal_starts: 1,
            ..PlanOptions::default()
        })
        .with_chaos(ChaosSpec { panic_lane: Some("greedy".into()) })
        .plan_batch(&[lenet])
        .unwrap();
        assert!(report.stats.panicked_lanes > 0);
        let table = format_batch_table(&report);
        assert!(table.contains("resilience:"));
        assert!(table.contains("portfolio lanes lost to panics"));
    }
}

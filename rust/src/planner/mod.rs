//! Network-level strategy planning — the level above §5's per-layer problem.
//!
//! The paper optimizes one convolutional layer at a time, but its evaluation
//! targets whole networks (LeNet-5, ResNet-8). This module closes that gap:
//! given a [`NetworkPreset`] and an accelerator description, the
//! [`NetworkPlanner`] finds a strategy for **every** layer and reports the
//! end-to-end simulated duration through [`crate::sim::Network`].
//!
//! Per layer it runs a **portfolio race** (`portfolio`): the four §4.2
//! orderings, the greedy construction and several seeded annealing lanes all
//! run concurrently (scoped threads via [`crate::util::pool::parallel_map`]),
//! and the strategy with the fewest loaded pixels wins. The race is
//! deterministic by construction — lanes are pure functions of their inputs
//! and the reduction breaks ties by `(loaded pixels, portfolio-entry index)`,
//! never by completion order — so the same seed yields the same plan under
//! any thread schedule.
//!
//! Results land in a content-addressed [`StrategyCache`] keyed by layer
//! geometry + accelerator parameters + portfolio configuration (`cache`),
//! so repeated planning of shared shapes (within one network, across
//! networks, or across processes) is free.
//!
//! The level above a single network is the `batch` module: a
//! [`BatchPlanner`] plans many networks in one call, deduplicating identical
//! planning problems *across* requests before any search and racing the
//! residual set on one shared pool, optionally backed by the lock-striped,
//! persistent [`ShardedStrategyCache`] (`shard`). The single-network
//! planner here is a thin wrapper over the same machinery, so the two paths
//! cannot drift.

mod batch;
mod cache;
pub mod certify;
mod portfolio;
mod recovery;
mod report;
mod shard;

pub use batch::{BatchPlanner, BatchReport, BatchStats};
pub use certify::{
    certify_network, certify_to_json, comm_lower_bound, format_certify_table,
    optimality_gap, CertifyOptions, CertifyReport, CommLowerBound, ExactStatus,
    StageCertificate,
};
pub use cache::{CacheKey, CachedStrategy, StrategyCache, StrategyStore};
pub use portfolio::{
    portfolio_entries, run_entry, run_entry_cancel, PortfolioEntry, PortfolioResult,
};
pub use recovery::{
    backoff_schedule, degrade_for_shrink, memory_group_bound, retry_io,
    retry_io_jittered, ChaosSpec, DegradeOutcome,
};
pub use report::{batch_to_json, format_batch_table, format_plan_table, plan_to_json};
pub use shard::{ShardedStrategyCache, DEFAULT_SHARDS, DEFAULT_SHARD_CAPACITY};

use crate::config::NetworkPreset;
use crate::conv::ConvLayer;
use crate::platform::{Accelerator, OverlapMode};
use crate::strategy::GroupedStrategy;

/// How per-layer accelerators are derived from the planner's input.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AcceleratorSpec {
    /// §7.1 convention: each layer gets an accelerator sized for this group
    /// bound via [`Accelerator::for_group_size`].
    PerLayerGroup(usize),
    /// One fixed accelerator shared by every layer; the per-layer group
    /// bound is its `nb_patches_max_S1` (clamped to ≥ 1). Its overlap mode
    /// is overridden by [`PlanOptions::overlap`] (the planner-level knob is
    /// authoritative, so plans and cache keys depend on one source).
    Fixed(Accelerator),
}

/// Planner configuration.
#[derive(Debug, Clone)]
pub struct PlanOptions {
    /// How per-layer accelerators are derived.
    pub accelerator: AcceleratorSpec,
    /// Base RNG seed; annealing lane `i` uses `seed + i`.
    pub seed: u64,
    /// Iteration budget per annealing lane. Since the delta-evaluation
    /// rewrite an iteration is ≥ 3× cheaper, so this budget can be scaled
    /// up at equal wall time (`plan-network --thorough`); the default stays
    /// put because the budget is part of the cache key and the per-seed
    /// bit-identity contract.
    pub anneal_iters: u64,
    /// Number of annealing lanes in the portfolio.
    pub anneal_starts: usize,
    /// Worker threads for the race (`0` = [`crate::util::pool::default_threads`]).
    pub threads: usize,
    /// Duration semantics every stage accelerator runs under. Sequential
    /// (the default) races loaded pixels and keeps all historical plans
    /// bit-stable; double-buffered races the §3.7 overlapped makespan
    /// (`plan-network --overlap double-buffered`). Part of the cache key.
    pub overlap: OverlapMode,
    /// DMA channels every stage accelerator gets (k ≥ 1; the default 1
    /// reproduces the two-resource recurrence and keeps historical plans
    /// bit-stable). Part of the cache key (v4).
    pub dma_channels: usize,
    /// Compute units every stage accelerator gets (m ≥ 1; see
    /// `dma_channels`). Part of the cache key (v4).
    pub compute_units: usize,
}

impl Default for PlanOptions {
    fn default() -> Self {
        PlanOptions {
            accelerator: AcceleratorSpec::PerLayerGroup(4),
            seed: 2026,
            anneal_iters: 50_000,
            anneal_starts: 3,
            threads: 0,
            overlap: OverlapMode::Sequential,
            dma_channels: 1,
            compute_units: 1,
        }
    }
}

/// The chosen strategy (plus provenance) for one stage.
#[derive(Debug, Clone)]
pub struct LayerPlan {
    /// Stage name within the network preset.
    pub stage: String,
    /// The layer this plan drives.
    pub layer: ConvLayer,
    /// The accelerator the stage runs on (overlap mode included).
    pub accelerator: Accelerator,
    /// Group-size bound `nb_patches_max_S1` the race used.
    pub group_size: usize,
    /// The winning strategy.
    pub strategy: GroupedStrategy,
    /// Which portfolio lane won.
    pub winner: String,
    /// The sequential race objective achieved (spatial input pixels loaded).
    pub loaded_pixels: u64,
    /// Analytic floor on `loaded_pixels` for *any* valid grouping of this
    /// stage ([`certify::comm_lower_bound`], pixel domain). Certification is
    /// read-only with respect to the race: the bound never influences which
    /// lane wins.
    pub comm_lower_bound: u64,
    /// `(loaded_pixels − comm_lower_bound) / comm_lower_bound` — how far the
    /// winner provably is from communication-optimal (0.0 = bound met).
    pub optimality_gap: f64,
    /// Simulated stage duration in cycles (from the network run; the
    /// overlapped makespan when the accelerator is double-buffered).
    pub duration: u64,
    /// The stage's Definition-3 sequential duration (equals `duration` on
    /// sequential accelerators).
    pub sequential_duration: u64,
    /// True when the strategy came from the cache (or a shape already
    /// planned earlier in the same call) rather than a fresh race.
    pub cache_hit: bool,
}

/// A full network plan plus the end-to-end simulation aggregates.
#[derive(Debug, Clone)]
pub struct NetworkPlan {
    /// Network preset name.
    pub network: String,
    /// Per-stage plans in pipeline order.
    pub layers: Vec<LayerPlan>,
    /// Total simulated duration of the planned network in cycles.
    pub total_duration: u64,
    /// Total Definition-3 sequential duration — `total_duration` equals it
    /// on sequential accelerators; the difference is the transfer time the
    /// double-buffered timeline hides.
    pub total_sequential_duration: u64,
    /// The overlap semantics the plan was raced and simulated under.
    pub overlap: OverlapMode,
    /// Peak on-chip occupancy across all stages (elements).
    pub peak_occupancy: u64,
    /// Stages served from the strategy cache (or an earlier identical shape).
    pub cache_hits: usize,
    /// Stages that required a fresh portfolio race.
    pub cache_misses: usize,
    /// Annealing iterations actually executed while planning — 0 when every
    /// layer came from the cache.
    pub anneal_iters_run: u64,
    /// Sum of the per-stage communication lower bounds (pixel domain).
    pub total_comm_lower_bound: u64,
    /// Largest per-stage `optimality_gap` in the plan (0.0 for an empty
    /// network).
    pub worst_optimality_gap: f64,
}

/// The planner facade.
#[derive(Debug, Clone)]
pub struct NetworkPlanner {
    /// Planner configuration (accelerator spec, seeds, budgets, overlap).
    pub options: PlanOptions,
    cache: Option<StrategyCache>,
}

impl NetworkPlanner {
    /// Planner without persistence (every call races every distinct shape).
    pub fn new(options: PlanOptions) -> Self {
        NetworkPlanner { options, cache: None }
    }

    /// Planner backed by an on-disk strategy cache.
    pub fn with_cache(options: PlanOptions, cache: StrategyCache) -> Self {
        NetworkPlanner { options, cache: Some(cache) }
    }

    /// Plan every layer of `preset` and simulate the planned network.
    ///
    /// # Examples
    ///
    /// ```
    /// use convoffload::config::network_preset;
    /// use convoffload::planner::{NetworkPlanner, PlanOptions};
    ///
    /// let preset = network_preset("lenet5").unwrap();
    /// let planner = NetworkPlanner::new(PlanOptions {
    ///     anneal_iters: 200, // tiny budget: doc-test speed
    ///     anneal_starts: 1,
    ///     ..PlanOptions::default()
    /// });
    /// let plan = planner.plan(&preset).unwrap();
    /// assert_eq!(plan.layers.len(), 2);
    /// // the heuristic lanes alone already reach the analytic baseline
    /// assert!(plan.total_duration <= 7100);
    /// ```
    pub fn plan(&self, preset: &NetworkPreset) -> Result<NetworkPlan, String> {
        // One-network batch through the shared machinery: canonicalize,
        // resolve (persistent cache first, then one shared race over the
        // remaining problems), assemble + simulate. Hit/miss semantics are
        // the historical ones: a stage is a miss exactly when it was the
        // racing representative of its problem.
        let refs = [preset];
        let ctxs = batch::stage_contexts(&self.options, &refs);
        let store = self.cache.as_ref().map(|c| c as &dyn StrategyStore);
        let res = batch::resolve(&refs, &ctxs, &self.options, store)?;
        batch::assemble_network(preset, 0, &ctxs, &res, self.options.overlap)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::NetworkStagePreset;

    /// A small two-stage network (same topology family as LeNet) that keeps
    /// unit tests fast; the real presets are exercised by the integration
    /// tests and the CLI.
    fn tiny_preset() -> NetworkPreset {
        NetworkPreset {
            name: "tiny".into(),
            description: "1x8x8 conv -> pool -> 2x3x3 conv".into(),
            stages: vec![
                NetworkStagePreset {
                    name: "c1".into(),
                    layer: ConvLayer::new(1, 8, 8, 3, 3, 2, 1, 1).unwrap(),
                    pool_after: true,
                    pad_after: 0,
                },
                NetworkStagePreset {
                    name: "c2".into(),
                    layer: ConvLayer::new(2, 3, 3, 3, 3, 1, 1, 1).unwrap(),
                    pool_after: false,
                    pad_after: 0,
                },
            ],
        }
    }

    fn quick_options() -> PlanOptions {
        PlanOptions {
            accelerator: AcceleratorSpec::PerLayerGroup(2),
            seed: 7,
            anneal_iters: 1_000,
            anneal_starts: 2,
            threads: 0,
            overlap: OverlapMode::Sequential,
            dma_channels: 1,
            compute_units: 1,
        }
    }

    #[test]
    fn plan_covers_every_stage() {
        let plan = NetworkPlanner::new(quick_options())
            .plan(&tiny_preset())
            .unwrap();
        assert_eq!(plan.layers.len(), 2);
        assert_eq!(plan.cache_misses, 2);
        assert_eq!(plan.cache_hits, 0);
        assert!(plan.total_duration > 0);
        assert_eq!(
            plan.total_duration,
            plan.layers.iter().map(|l| l.duration).sum::<u64>()
        );
        for lp in &plan.layers {
            let mut all: Vec<u32> =
                lp.strategy.groups.iter().flatten().copied().collect();
            all.sort();
            assert_eq!(all, lp.layer.all_patches().collect::<Vec<_>>());
            assert!(!lp.winner.is_empty());
            assert!(!lp.cache_hit);
        }
    }

    #[test]
    fn same_seed_same_plan_any_thread_count() {
        let preset = tiny_preset();
        let mut opts = quick_options();
        let base = NetworkPlanner::new(opts.clone()).plan(&preset).unwrap();
        for threads in [1usize, 2, 8] {
            opts.threads = threads;
            let plan = NetworkPlanner::new(opts.clone()).plan(&preset).unwrap();
            for (a, b) in base.layers.iter().zip(&plan.layers) {
                assert_eq!(a.strategy, b.strategy, "threads={threads}");
                assert_eq!(a.winner, b.winner, "threads={threads}");
                assert_eq!(a.loaded_pixels, b.loaded_pixels);
            }
            assert_eq!(base.total_duration, plan.total_duration);
        }
    }

    #[test]
    fn plan_carries_a_true_lower_bound_per_stage() {
        let plan = NetworkPlanner::new(quick_options())
            .plan(&tiny_preset())
            .unwrap();
        let mut total = 0u64;
        let mut worst = 0.0f64;
        for lp in &plan.layers {
            assert!(lp.comm_lower_bound > 0, "{}", lp.stage);
            assert!(
                lp.comm_lower_bound <= lp.loaded_pixels,
                "{}: bound {} above achieved {}",
                lp.stage,
                lp.comm_lower_bound,
                lp.loaded_pixels
            );
            assert_eq!(
                lp.optimality_gap,
                certify::optimality_gap(lp.loaded_pixels, lp.comm_lower_bound)
            );
            total += lp.comm_lower_bound;
            worst = worst.max(lp.optimality_gap);
        }
        assert_eq!(plan.total_comm_lower_bound, total);
        assert_eq!(plan.worst_optimality_gap, worst);
    }

    #[test]
    fn winner_is_never_worse_than_the_orderings() {
        let plan = NetworkPlanner::new(quick_options())
            .plan(&tiny_preset())
            .unwrap();
        for lp in &plan.layers {
            for o in crate::strategy::Ordering::all() {
                let s = crate::strategy::from_ordering(&lp.layer, o, lp.group_size);
                let d = crate::optimizer::grouping_loads(&lp.layer, &s.groups);
                assert!(
                    lp.loaded_pixels <= d,
                    "{}: {} > {} ({})",
                    lp.stage,
                    lp.loaded_pixels,
                    d,
                    o.as_str()
                );
            }
        }
    }

    #[test]
    fn shared_shapes_are_planned_once() {
        // Two stages with identical geometry chained by re-padding: the
        // second must ride the first's result even without a disk cache.
        let conv = ConvLayer::new(1, 6, 6, 3, 3, 1, 1, 1).unwrap();
        let preset = NetworkPreset {
            name: "twins".into(),
            description: "same-padded twin stages".into(),
            stages: vec![
                NetworkStagePreset {
                    name: "a".into(),
                    layer: conv,
                    pool_after: false,
                    pad_after: 1,
                },
                NetworkStagePreset {
                    name: "b".into(),
                    layer: conv,
                    pool_after: false,
                    pad_after: 0,
                },
            ],
        };
        let plan = NetworkPlanner::new(quick_options()).plan(&preset).unwrap();
        assert_eq!(plan.cache_misses, 1);
        assert_eq!(plan.cache_hits, 1);
        assert!(!plan.layers[0].cache_hit);
        assert!(plan.layers[1].cache_hit);
        assert_eq!(plan.layers[0].strategy, plan.layers[1].strategy);
    }

    /// Double-buffered planning: every stage accelerator carries the mode,
    /// stage durations are makespans (≤ their own sequential durations),
    /// and the plan is deterministic across thread counts like the
    /// sequential one.
    #[test]
    fn double_buffered_plan_hides_transfer_time() {
        let preset = tiny_preset();
        let seq = NetworkPlanner::new(quick_options()).plan(&preset).unwrap();
        assert_eq!(seq.overlap, OverlapMode::Sequential);
        assert_eq!(seq.total_duration, seq.total_sequential_duration);

        let mut opts = quick_options();
        opts.overlap = OverlapMode::DoubleBuffered;
        let db = NetworkPlanner::new(opts.clone()).plan(&preset).unwrap();
        assert_eq!(db.overlap, OverlapMode::DoubleBuffered);
        assert!(db.total_duration <= db.total_sequential_duration);
        for lp in &db.layers {
            assert_eq!(lp.accelerator.overlap, OverlapMode::DoubleBuffered);
            assert!(lp.duration <= lp.sequential_duration, "{}", lp.stage);
        }
        // determinism under any thread schedule, same as sequential
        for threads in [1usize, 8] {
            opts.threads = threads;
            let again = NetworkPlanner::new(opts.clone()).plan(&preset).unwrap();
            assert_eq!(again.total_duration, db.total_duration, "threads={threads}");
            for (a, b) in db.layers.iter().zip(&again.layers) {
                assert_eq!(a.strategy, b.strategy, "threads={threads}");
                assert_eq!(a.winner, b.winner);
            }
        }
    }

    /// The two modes are distinct cache keys: planning one then the other
    /// over the same directory must not serve a cross-mode hit.
    #[test]
    fn overlap_modes_do_not_share_cache_entries() {
        let dir = std::env::temp_dir().join(format!(
            "convoffload-planner-overlap-cache-{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        let preset = tiny_preset();
        let cache = StrategyCache::open(&dir).unwrap();
        let seq = NetworkPlanner::with_cache(quick_options(), cache.clone())
            .plan(&preset)
            .unwrap();
        assert_eq!(seq.cache_misses, 2);
        let mut opts = quick_options();
        opts.overlap = OverlapMode::DoubleBuffered;
        let db = NetworkPlanner::with_cache(opts.clone(), cache.clone())
            .plan(&preset)
            .unwrap();
        assert_eq!(db.cache_misses, 2, "other mode must not hit");
        // replanning each mode hits its own entries
        let seq2 = NetworkPlanner::with_cache(quick_options(), cache.clone())
            .plan(&preset)
            .unwrap();
        assert_eq!(seq2.cache_hits, 2);
        let db2 = NetworkPlanner::with_cache(opts, cache).plan(&preset).unwrap();
        assert_eq!(db2.cache_hits, 2);
        assert_eq!(db2.total_duration, db.total_duration);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn fixed_platform_derives_group_from_nbop() {
        let conv = ConvLayer::new(1, 8, 8, 3, 3, 2, 1, 1).unwrap();
        let acc = Accelerator::for_group_size(&conv, 3);
        let opts = PlanOptions {
            accelerator: AcceleratorSpec::Fixed(acc),
            anneal_iters: 500,
            anneal_starts: 1,
            ..PlanOptions::default()
        };
        let preset = NetworkPreset {
            name: "single".into(),
            description: "one stage".into(),
            stages: vec![NetworkStagePreset {
                name: "c1".into(),
                layer: conv,
                pool_after: false,
                pad_after: 0,
            }],
        };
        let plan = NetworkPlanner::new(opts).plan(&preset).unwrap();
        assert_eq!(plan.layers[0].group_size, 3);
        assert_eq!(plan.layers[0].accelerator, acc);
    }
}

//! Patches (Definition 10) and their identifiers.

/// Row-major patch identifier (Remark 4): `id = i · W_out + j`.
pub type PatchId = u32;

/// A patch `P_{i,j}` — the input slice feeding output spatial position
/// `(i, j)` across all output channels.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Patch {
    /// Row-major linearized id.
    pub id: PatchId,
    /// Output row index `i`.
    pub i: usize,
    /// Output column index `j`.
    pub j: usize,
}

impl Patch {
    /// Manhattan distance between patch grid positions (used by ordering
    /// heuristics to reason about locality).
    pub fn grid_distance(&self, other: &Patch) -> usize {
        self.i.abs_diff(other.i) + self.j.abs_diff(other.j)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::conv::ConvLayer;

    #[test]
    fn grid_distance() {
        let l = ConvLayer::square(1, 6, 3, 1);
        let a = l.patch(l.patch_id(0, 0));
        let b = l.patch(l.patch_id(2, 3));
        assert_eq!(a.grid_distance(&b), 5);
        assert_eq!(b.grid_distance(&a), 5);
        assert_eq!(a.grid_distance(&a), 0);
    }
}

//! Pure-Rust convolution oracle and im2col.
//!
//! Serves three roles:
//! 1. reference output for the *functional simulation* (the simulator checks
//!    that a strategy's stepwise computation reproduces the whole-layer
//!    convolution, §6);
//! 2. the host-side compute backend when PJRT artifacts are not built;
//! 3. cross-check for the AOT Pallas kernel executed through the runtime.
//!
//! Tensors are `f32` in channel-major layout (Remark 5):
//! input `[C_in, H_in, W_in]`, kernels `[N, C_in, H_K, W_K]`,
//! output `[C_out, H_out, W_out]`.

use crate::conv::{ConvLayer, PatchId};

/// Full-layer convolution: `O[l,i,j] = Σ_{c,h,w} I[c, i·s_h+h, j·s_w+w] · K^l[c,h,w]`.
pub fn conv2d(layer: &ConvLayer, input: &[f32], kernels: &[f32]) -> Vec<f32> {
    assert_eq!(input.len(), layer.input_dims().len(), "input size mismatch");
    assert_eq!(
        kernels.len(),
        layer.kernel_elements(),
        "kernel size mismatch"
    );
    let (h_out, w_out) = (layer.h_out(), layer.w_out());
    let mut out = vec![0f32; layer.output_dims().len()];
    for l in 0..layer.c_out() {
        for i in 0..h_out {
            for j in 0..w_out {
                out[(l * h_out + i) * w_out + j] =
                    dot_patch_kernel(layer, input, kernels, l, i, j);
            }
        }
    }
    out
}

/// One output value (Definition 13's `nb_op_value` MACs).
pub fn output_value(
    layer: &ConvLayer,
    input: &[f32],
    kernels: &[f32],
    l: usize,
    i: usize,
    j: usize,
) -> f32 {
    dot_patch_kernel(layer, input, kernels, l, i, j)
}

#[inline]
fn dot_patch_kernel(
    layer: &ConvLayer,
    input: &[f32],
    kernels: &[f32],
    l: usize,
    i: usize,
    j: usize,
) -> f32 {
    let (h_in, w_in) = (layer.h_in, layer.w_in);
    let (h_k, w_k) = (layer.h_k, layer.w_k);
    let mut acc = 0f32;
    for c in 0..layer.c_in {
        let in_base = c * h_in * w_in;
        let k_base = (l * layer.c_in + c) * h_k * w_k;
        for h in 0..h_k {
            let row = in_base + (i * layer.s_h + h) * w_in + j * layer.s_w;
            let krow = k_base + h * w_k;
            for w in 0..w_k {
                acc += input[row + w] * kernels[krow + w];
            }
        }
    }
    acc
}

/// Gather one patch's values as an im2col row of length `C_in·H_K·W_K`
/// (channel-major: all of channel 0's window, then channel 1's, …).
pub fn im2col_row(layer: &ConvLayer, input: &[f32], patch: PatchId, out: &mut [f32]) {
    let p = layer.patch(patch);
    let (h_in, w_in) = (layer.h_in, layer.w_in);
    let mut idx = 0;
    for c in 0..layer.c_in {
        let base = c * h_in * w_in;
        for h in 0..layer.h_k {
            let row = base + (p.i * layer.s_h + h) * w_in + p.j * layer.s_w;
            out[idx..idx + layer.w_k].copy_from_slice(&input[row..row + layer.w_k]);
            idx += layer.w_k;
        }
    }
    debug_assert_eq!(idx, layer.ops_per_output_value());
}

/// im2col matrix for a group of patches: `[len(group), C_in·H_K·W_K]`
/// row-major. The GeMM `patches @ kernelsᵀ` then yields `[group, C_out]` —
/// exactly the per-step compute of strategy S1 (Property 1).
pub fn im2col_group(layer: &ConvLayer, input: &[f32], group: &[PatchId]) -> Vec<f32> {
    let d = layer.ops_per_output_value();
    let mut m = vec![0f32; group.len() * d];
    for (r, &p) in group.iter().enumerate() {
        im2col_row(layer, input, p, &mut m[r * d..(r + 1) * d]);
    }
    m
}

/// Kernels flattened to a `[C_in·H_K·W_K, N]` column-major-by-kernel matrix
/// (i.e. `K_mat[d, l] = K^l[d]` with `d` channel-major) so that
/// `im2col_group(..) @ kernel_matrix(..)` is a plain row-major GEMM.
pub fn kernel_matrix(layer: &ConvLayer, kernels: &[f32]) -> Vec<f32> {
    let d = layer.ops_per_output_value();
    let n = layer.n_kernels;
    let mut m = vec![0f32; d * n];
    for l in 0..n {
        for e in 0..d {
            m[e * n + l] = kernels[l * d + e];
        }
    }
    m
}

/// Row-major GEMM: `a [rows×inner] @ b [inner×cols] → [rows×cols]`.
pub fn gemm(a: &[f32], b: &[f32], rows: usize, inner: usize, cols: usize) -> Vec<f32> {
    assert_eq!(a.len(), rows * inner);
    assert_eq!(b.len(), inner * cols);
    let mut out = vec![0f32; rows * cols];
    for r in 0..rows {
        for k in 0..inner {
            let av = a[r * inner + k];
            if av == 0.0 {
                continue;
            }
            let brow = &b[k * cols..(k + 1) * cols];
            let orow = &mut out[r * cols..(r + 1) * cols];
            for c in 0..cols {
                orow[c] += av * brow[c];
            }
        }
    }
    out
}

/// Per-step compute of S1 as the accelerator performs it: the group's patches
/// against **all** kernels, returning `[len(group), C_out]` row-major.
pub fn step_compute(
    layer: &ConvLayer,
    input: &[f32],
    kernels: &[f32],
    group: &[PatchId],
) -> Vec<f32> {
    let d = layer.ops_per_output_value();
    let pm = im2col_group(layer, input, group);
    let km = kernel_matrix(layer, kernels);
    gemm(&pm, &km, group.len(), d, layer.n_kernels)
}

/// Deterministic pseudo-random tensor fill (for tests / examples): values in
/// `[-1, 1)` from a seeded generator.
pub fn synth_tensor(len: usize, seed: u64) -> Vec<f32> {
    let mut rng = crate::util::rng::Rng::new(seed);
    (0..len).map(|_| (rng.f64() * 2.0 - 1.0) as f32).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::conv::ConvLayer;

    fn example1() -> ConvLayer {
        ConvLayer::new(2, 5, 5, 3, 3, 2, 1, 1).unwrap()
    }

    /// Hand-computed identity check: a kernel that is a delta at (0,0,0)
    /// copies the corresponding input window value.
    #[test]
    fn delta_kernel_copies_input() {
        let l = ConvLayer::new(1, 4, 4, 2, 2, 1, 1, 1).unwrap();
        let input: Vec<f32> = (0..16).map(|x| x as f32).collect();
        let mut kernels = vec![0f32; 4];
        kernels[0] = 1.0; // delta at top-left of the window
        let out = conv2d(&l, &input, &kernels);
        // O[i,j] = I[i,j]
        let expect: Vec<f32> = (0..3)
            .flat_map(|i| (0..3).map(move |j| (i * 4 + j) as f32))
            .collect();
        assert_eq!(out, expect);
    }

    #[test]
    fn ones_kernel_sums_window() {
        let l = ConvLayer::new(1, 3, 3, 3, 3, 1, 1, 1).unwrap();
        let input = vec![1f32; 9];
        let kernels = vec![1f32; 9];
        assert_eq!(conv2d(&l, &input, &kernels), vec![9.0]);
    }

    #[test]
    fn multichannel_accumulates() {
        let l = ConvLayer::new(2, 3, 3, 3, 3, 1, 1, 1).unwrap();
        let input = vec![1f32; 18];
        let kernels = vec![1f32; 18];
        assert_eq!(conv2d(&l, &input, &kernels), vec![18.0]);
    }

    #[test]
    fn strided_conv() {
        let l = ConvLayer::new(1, 5, 5, 3, 3, 1, 2, 2).unwrap();
        let input = vec![1f32; 25];
        let kernels = vec![1f32; 9];
        assert_eq!(conv2d(&l, &input, &kernels), vec![9.0; 4]);
    }

    #[test]
    fn step_compute_matches_conv2d() {
        let l = example1();
        let input = synth_tensor(l.input_dims().len(), 1);
        let kernels = synth_tensor(l.kernel_elements(), 2);
        let full = conv2d(&l, &input, &kernels);
        let group: Vec<_> = l.all_patches().collect();
        let step = step_compute(&l, &input, &kernels, &group);
        // step rows are per-patch [C_out]; full is [C_out, H_out, W_out]
        let (h_out, w_out) = (l.h_out(), l.w_out());
        for (r, &p) in group.iter().enumerate() {
            let patch = l.patch(p);
            for ch in 0..l.c_out() {
                let a = step[r * l.c_out() + ch];
                let b = full[(ch * h_out + patch.i) * w_out + patch.j];
                assert!((a - b).abs() < 1e-4, "patch {p} ch {ch}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn step_compute_partial_groups() {
        let l = example1();
        let input = synth_tensor(l.input_dims().len(), 3);
        let kernels = synth_tensor(l.kernel_elements(), 4);
        let full = conv2d(&l, &input, &kernels);
        for group in [vec![0u32], vec![4, 8], vec![2, 6, 7]] {
            let step = step_compute(&l, &input, &kernels, &group);
            for (r, &p) in group.iter().enumerate() {
                let patch = l.patch(p);
                for ch in 0..l.c_out() {
                    let a = step[r * l.c_out() + ch];
                    let b = full[(ch * l.h_out() + patch.i) * l.w_out() + patch.j];
                    assert!((a - b).abs() < 1e-4);
                }
            }
        }
    }

    #[test]
    fn gemm_small_known() {
        // [1 2; 3 4] @ [5 6; 7 8] = [19 22; 43 50]
        let a = vec![1., 2., 3., 4.];
        let b = vec![5., 6., 7., 8.];
        assert_eq!(gemm(&a, &b, 2, 2, 2), vec![19., 22., 43., 50.]);
    }

    #[test]
    fn im2col_row_layout() {
        let l = ConvLayer::new(2, 3, 3, 2, 2, 1, 1, 1).unwrap();
        let input: Vec<f32> = (0..18).map(|x| x as f32).collect();
        let mut row = vec![0f32; l.ops_per_output_value()];
        im2col_row(&l, &input, l.patch_id(0, 0), &mut row);
        // channel 0 window then channel 1 window, each row-major
        assert_eq!(row, vec![0., 1., 3., 4., 9., 10., 12., 13.]);
    }

    #[test]
    fn synth_tensor_deterministic() {
        assert_eq!(synth_tensor(16, 7), synth_tensor(16, 7));
        assert_ne!(synth_tensor(16, 7), synth_tensor(16, 8));
        assert!(synth_tensor(100, 1).iter().all(|x| (-1.0..1.0).contains(x)));
    }
}

//! Pure-Rust convolution oracle and im2col.
//!
//! Serves three roles:
//! 1. reference output for the *functional simulation* (the simulator checks
//!    that a strategy's stepwise computation reproduces the whole-layer
//!    convolution, §6);
//! 2. the host-side compute backend when PJRT artifacts are not built;
//! 3. cross-check for the AOT Pallas kernel executed through the runtime.
//!
//! Tensors are `f32` in channel-major layout (Remark 5):
//! input `[C_in, H_in, W_in]`, kernels `[N, C_in/G, H_K, W_K]`,
//! output `[C_out, H_out, W_out]`.
//!
//! Dilation taps the input at `i·s_h + h·d_h` / `j·s_w + w·d_w`. Grouped
//! convolutions restrict kernel `l` to its group's channel slice; the im2col
//! path keeps a *single* GEMM by zero-expanding the kernel matrix to the full
//! `C_in·H_K·W_K` contraction width ([`kernel_matrix`]), so the step compute
//! shape is uniform across `G` (the zero rows multiply channels outside the
//! kernel's group).

use crate::conv::{ConvLayer, PatchId};

/// Full-layer convolution:
/// `O[l,i,j] = Σ_{c ∈ grp(l)} Σ_{h,w} I[c, i·s_h+h·d_h, j·s_w+w·d_w] · K^l[c,h,w]`.
pub fn conv2d(layer: &ConvLayer, input: &[f32], kernels: &[f32]) -> Vec<f32> {
    assert_eq!(input.len(), layer.input_dims().len(), "input size mismatch");
    assert_eq!(
        kernels.len(),
        layer.kernel_elements(),
        "kernel size mismatch"
    );
    let (h_out, w_out) = (layer.h_out(), layer.w_out());
    let mut out = vec![0f32; layer.output_dims().len()];
    for l in 0..layer.c_out() {
        for i in 0..h_out {
            for j in 0..w_out {
                out[(l * h_out + i) * w_out + j] =
                    dot_patch_kernel(layer, input, kernels, l, i, j);
            }
        }
    }
    out
}

/// One output value (Definition 13's `nb_op_value` MACs).
pub fn output_value(
    layer: &ConvLayer,
    input: &[f32],
    kernels: &[f32],
    l: usize,
    i: usize,
    j: usize,
) -> f32 {
    dot_patch_kernel(layer, input, kernels, l, i, j)
}

#[inline]
fn dot_patch_kernel(
    layer: &ConvLayer,
    input: &[f32],
    kernels: &[f32],
    l: usize,
    i: usize,
    j: usize,
) -> f32 {
    let (h_in, w_in) = (layer.h_in, layer.w_in);
    let (h_k, w_k) = (layer.h_k, layer.w_k);
    let cpg = layer.channels_per_group();
    let c0 = layer.group_of_kernel(l) * cpg; // first input channel of l's group
    let mut acc = 0f32;
    for ck in 0..cpg {
        let in_base = (c0 + ck) * h_in * w_in;
        let k_base = (l * cpg + ck) * h_k * w_k;
        for h in 0..h_k {
            let row = in_base + (i * layer.s_h + h * layer.d_h) * w_in + j * layer.s_w;
            let krow = k_base + h * w_k;
            for w in 0..w_k {
                acc += input[row + w * layer.d_w] * kernels[krow + w];
            }
        }
    }
    acc
}

/// Gather one patch's values as an im2col row of length `C_in·H_K·W_K`
/// (channel-major: all of channel 0's window, then channel 1's, …). The row
/// always spans *all* input channels — grouped layers pair it with the
/// zero-expanded [`kernel_matrix`].
pub fn im2col_row(layer: &ConvLayer, input: &[f32], patch: PatchId, out: &mut [f32]) {
    let p = layer.patch(patch);
    let (h_in, w_in) = (layer.h_in, layer.w_in);
    let mut idx = 0;
    for c in 0..layer.c_in {
        let base = c * h_in * w_in;
        for h in 0..layer.h_k {
            let row = base + (p.i * layer.s_h + h * layer.d_h) * w_in + p.j * layer.s_w;
            if layer.d_w == 1 {
                out[idx..idx + layer.w_k].copy_from_slice(&input[row..row + layer.w_k]);
                idx += layer.w_k;
            } else {
                for w in 0..layer.w_k {
                    out[idx] = input[row + w * layer.d_w];
                    idx += 1;
                }
            }
        }
    }
    debug_assert_eq!(idx, layer.im2col_width());
}

/// im2col matrix for a group of patches: `[len(group), C_in·H_K·W_K]`
/// row-major. The GeMM `patches @ kernel_matrix` then yields `[group, C_out]`
/// — exactly the per-step compute of strategy S1 (Property 1).
pub fn im2col_group(layer: &ConvLayer, input: &[f32], group: &[PatchId]) -> Vec<f32> {
    let d = layer.im2col_width();
    let mut m = vec![0f32; group.len() * d];
    for (r, &p) in group.iter().enumerate() {
        im2col_row(layer, input, p, &mut m[r * d..(r + 1) * d]);
    }
    m
}

/// Kernels expanded to a `[C_in·H_K·W_K, N]` matrix so that
/// `im2col_group(..) @ kernel_matrix(..)` is a plain row-major GEMM for any
/// `G`: entry `(e, l)` is kernel `l`'s weight when flat index `e` falls on a
/// channel of `l`'s group, 0 otherwise (dense layers have no zero rows).
pub fn kernel_matrix(layer: &ConvLayer, kernels: &[f32]) -> Vec<f32> {
    let d = layer.im2col_width();
    let n = layer.n_kernels;
    let cpg = layer.channels_per_group();
    let khw = layer.h_k * layer.w_k;
    let mut m = vec![0f32; d * n];
    for l in 0..n {
        let c0 = layer.group_of_kernel(l) * cpg;
        for ck in 0..cpg {
            for t in 0..khw {
                let e = (c0 + ck) * khw + t; // row in the full-width matrix
                m[e * n + l] = kernels[(l * cpg + ck) * khw + t];
            }
        }
    }
    m
}

/// Row-major GEMM: `a [rows×inner] @ b [inner×cols] → [rows×cols]`.
pub fn gemm(a: &[f32], b: &[f32], rows: usize, inner: usize, cols: usize) -> Vec<f32> {
    assert_eq!(a.len(), rows * inner);
    assert_eq!(b.len(), inner * cols);
    let mut out = vec![0f32; rows * cols];
    for r in 0..rows {
        for k in 0..inner {
            let av = a[r * inner + k];
            if av == 0.0 {
                continue;
            }
            let brow = &b[k * cols..(k + 1) * cols];
            let orow = &mut out[r * cols..(r + 1) * cols];
            for c in 0..cols {
                orow[c] += av * brow[c];
            }
        }
    }
    out
}

/// Per-step compute of S1 as the accelerator performs it: the group's patches
/// against **all** kernels, returning `[len(group), C_out]` row-major.
pub fn step_compute(
    layer: &ConvLayer,
    input: &[f32],
    kernels: &[f32],
    group: &[PatchId],
) -> Vec<f32> {
    let d = layer.im2col_width();
    let pm = im2col_group(layer, input, group);
    let km = kernel_matrix(layer, kernels);
    gemm(&pm, &km, group.len(), d, layer.n_kernels)
}

/// Deterministic pseudo-random tensor fill (for tests / examples): values in
/// `[-1, 1)` from a seeded generator.
pub fn synth_tensor(len: usize, seed: u64) -> Vec<f32> {
    let mut rng = crate::util::rng::Rng::new(seed);
    (0..len).map(|_| (rng.f64() * 2.0 - 1.0) as f32).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::conv::ConvLayer;

    fn example1() -> ConvLayer {
        ConvLayer::new(2, 5, 5, 3, 3, 2, 1, 1).unwrap()
    }

    fn check_step_matches_conv2d(l: &ConvLayer, seed: u64) {
        let input = synth_tensor(l.input_dims().len(), seed);
        let kernels = synth_tensor(l.kernel_elements(), seed + 1);
        let full = conv2d(l, &input, &kernels);
        let group: Vec<_> = l.all_patches().collect();
        let step = step_compute(l, &input, &kernels, &group);
        let (h_out, w_out) = (l.h_out(), l.w_out());
        for (r, &p) in group.iter().enumerate() {
            let patch = l.patch(p);
            for ch in 0..l.c_out() {
                let a = step[r * l.c_out() + ch];
                let b = full[(ch * h_out + patch.i) * w_out + patch.j];
                assert!((a - b).abs() < 1e-4, "{l} patch {p} ch {ch}: {a} vs {b}");
            }
        }
    }

    /// Hand-computed identity check: a kernel that is a delta at (0,0,0)
    /// copies the corresponding input window value.
    #[test]
    fn delta_kernel_copies_input() {
        let l = ConvLayer::new(1, 4, 4, 2, 2, 1, 1, 1).unwrap();
        let input: Vec<f32> = (0..16).map(|x| x as f32).collect();
        let mut kernels = vec![0f32; 4];
        kernels[0] = 1.0; // delta at top-left of the window
        let out = conv2d(&l, &input, &kernels);
        // O[i,j] = I[i,j]
        let expect: Vec<f32> = (0..3)
            .flat_map(|i| (0..3).map(move |j| (i * 4 + j) as f32))
            .collect();
        assert_eq!(out, expect);
    }

    #[test]
    fn ones_kernel_sums_window() {
        let l = ConvLayer::new(1, 3, 3, 3, 3, 1, 1, 1).unwrap();
        let input = vec![1f32; 9];
        let kernels = vec![1f32; 9];
        assert_eq!(conv2d(&l, &input, &kernels), vec![9.0]);
    }

    #[test]
    fn multichannel_accumulates() {
        let l = ConvLayer::new(2, 3, 3, 3, 3, 1, 1, 1).unwrap();
        let input = vec![1f32; 18];
        let kernels = vec![1f32; 18];
        assert_eq!(conv2d(&l, &input, &kernels), vec![18.0]);
    }

    #[test]
    fn strided_conv() {
        let l = ConvLayer::new(1, 5, 5, 3, 3, 1, 2, 2).unwrap();
        let input = vec![1f32; 25];
        let kernels = vec![1f32; 9];
        assert_eq!(conv2d(&l, &input, &kernels), vec![9.0; 4]);
    }

    /// Dilated delta kernel: a delta at tap (h, w) reads the input at
    /// `(i + h·d, j + w·d)` — hand check against the raw tensor.
    #[test]
    fn dilated_delta_kernel_reads_the_lattice() {
        let l = ConvLayer::new(1, 5, 5, 3, 3, 1, 1, 1)
            .unwrap()
            .with_dilation(2, 2)
            .unwrap(); // span 5, 1x1 output
        let input: Vec<f32> = (0..25).map(|x| x as f32).collect();
        // delta at tap (1, 2) → reads I[0 + 1·2, 0 + 2·2] = I[2, 4] = 14
        let mut kernels = vec![0f32; 9];
        kernels[1 * 3 + 2] = 1.0;
        assert_eq!(conv2d(&l, &input, &kernels), vec![14.0]);
    }

    #[test]
    fn dilated_ones_kernel_sums_the_lattice() {
        let l = ConvLayer::new(1, 5, 5, 2, 2, 1, 1, 1)
            .unwrap()
            .with_dilation(2, 2)
            .unwrap(); // taps {0,2}×{0,2}, 3x3 output
        let input: Vec<f32> = (0..25).map(|x| x as f32).collect();
        let kernels = vec![1f32; 4];
        let out = conv2d(&l, &input, &kernels);
        // O[0,0] = I[0,0]+I[0,2]+I[2,0]+I[2,2] = 0+2+10+12 = 24
        assert_eq!(out[0], 24.0);
        assert_eq!(out.len(), 9);
    }

    /// A grouped conv must equal the concatenation of G independent dense
    /// convs over the channel slices.
    #[test]
    fn grouped_conv_equals_per_group_dense_convs() {
        let g = 2usize;
        let l = ConvLayer::new(4, 6, 6, 3, 3, 6, 1, 1)
            .unwrap()
            .with_groups(g)
            .unwrap();
        let input = synth_tensor(l.input_dims().len(), 10);
        let kernels = synth_tensor(l.kernel_elements(), 11);
        let full = conv2d(&l, &input, &kernels);

        let sub = ConvLayer::new(2, 6, 6, 3, 3, 3, 1, 1).unwrap();
        let px = 36;
        for gi in 0..g {
            let sub_input = &input[gi * 2 * px..(gi + 1) * 2 * px];
            let sub_kernels =
                &kernels[gi * 3 * sub.kernel_dims().len()..(gi + 1) * 3 * sub.kernel_dims().len()];
            let sub_out = conv2d(&sub, sub_input, sub_kernels);
            let out_len = sub.output_dims().len();
            let want = &full[gi * out_len..(gi + 1) * out_len];
            for (a, b) in sub_out.iter().zip(want) {
                assert!((a - b).abs() < 1e-5, "group {gi}: {a} vs {b}");
            }
        }
    }

    /// Depthwise: each kernel sees exactly one channel.
    #[test]
    fn depthwise_conv_per_channel() {
        let l = ConvLayer::new(2, 4, 4, 2, 2, 2, 1, 1)
            .unwrap()
            .with_groups(2)
            .unwrap();
        let input: Vec<f32> = (0..32).map(|x| x as f32).collect();
        // kernel 0 = ones over channel 0; kernel 1 = delta over channel 1
        let kernels = vec![1.0, 1.0, 1.0, 1.0, 1.0, 0.0, 0.0, 0.0];
        let out = conv2d(&l, &input, &kernels);
        // O[0,0,0] = I[0,{0,1,4,5}] summed = 0+1+4+5 = 10
        assert_eq!(out[0], 10.0);
        // O[1,0,0] = I[1,0,0] = 16
        assert_eq!(out[9], 16.0);
    }

    #[test]
    fn step_compute_matches_conv2d() {
        check_step_matches_conv2d(&example1(), 1);
    }

    #[test]
    fn step_compute_matches_conv2d_generalized() {
        // dilated
        check_step_matches_conv2d(
            &ConvLayer::new(2, 9, 9, 3, 3, 2, 1, 1)
                .unwrap()
                .with_dilation(2, 2)
                .unwrap(),
            20,
        );
        // grouped
        check_step_matches_conv2d(
            &ConvLayer::new(4, 6, 6, 3, 3, 4, 1, 1)
                .unwrap()
                .with_groups(2)
                .unwrap(),
            30,
        );
        // depthwise + stride
        check_step_matches_conv2d(
            &ConvLayer::new(3, 7, 7, 3, 3, 3, 2, 2)
                .unwrap()
                .with_groups(3)
                .unwrap(),
            40,
        );
        // dilated + grouped + anisotropic stride
        check_step_matches_conv2d(
            &ConvLayer::new(4, 9, 8, 3, 2, 8, 2, 1)
                .unwrap()
                .with_dilation(2, 2)
                .unwrap()
                .with_groups(4)
                .unwrap(),
            50,
        );
    }

    #[test]
    fn step_compute_partial_groups() {
        let l = example1();
        let input = synth_tensor(l.input_dims().len(), 3);
        let kernels = synth_tensor(l.kernel_elements(), 4);
        let full = conv2d(&l, &input, &kernels);
        for group in [vec![0u32], vec![4, 8], vec![2, 6, 7]] {
            let step = step_compute(&l, &input, &kernels, &group);
            for (r, &p) in group.iter().enumerate() {
                let patch = l.patch(p);
                for ch in 0..l.c_out() {
                    let a = step[r * l.c_out() + ch];
                    let b = full[(ch * l.h_out() + patch.i) * l.w_out() + patch.j];
                    assert!((a - b).abs() < 1e-4);
                }
            }
        }
    }

    #[test]
    fn gemm_small_known() {
        // [1 2; 3 4] @ [5 6; 7 8] = [19 22; 43 50]
        let a = vec![1., 2., 3., 4.];
        let b = vec![5., 6., 7., 8.];
        assert_eq!(gemm(&a, &b, 2, 2, 2), vec![19., 22., 43., 50.]);
    }

    #[test]
    fn im2col_row_layout() {
        let l = ConvLayer::new(2, 3, 3, 2, 2, 1, 1, 1).unwrap();
        let input: Vec<f32> = (0..18).map(|x| x as f32).collect();
        let mut row = vec![0f32; l.im2col_width()];
        im2col_row(&l, &input, l.patch_id(0, 0), &mut row);
        // channel 0 window then channel 1 window, each row-major
        assert_eq!(row, vec![0., 1., 3., 4., 9., 10., 12., 13.]);
    }

    #[test]
    fn im2col_row_dilated_layout() {
        let l = ConvLayer::new(1, 5, 5, 2, 2, 1, 1, 1)
            .unwrap()
            .with_dilation(2, 2)
            .unwrap();
        let input: Vec<f32> = (0..25).map(|x| x as f32).collect();
        let mut row = vec![0f32; l.im2col_width()];
        im2col_row(&l, &input, l.patch_id(0, 0), &mut row);
        // taps (0,0) (0,2) (2,0) (2,2)
        assert_eq!(row, vec![0., 2., 10., 12.]);
    }

    #[test]
    fn kernel_matrix_zero_expands_groups() {
        let l = ConvLayer::new(2, 4, 4, 2, 2, 2, 1, 1)
            .unwrap()
            .with_groups(2)
            .unwrap();
        let kernels: Vec<f32> = (1..=8).map(|x| x as f32).collect();
        let m = kernel_matrix(&l, &kernels); // [2·4, 2]
        assert_eq!(m.len(), 16);
        // kernel 0 occupies rows 0..4 (channel 0), zero elsewhere
        for e in 0..4 {
            assert_eq!(m[e * 2], (e + 1) as f32);
            assert_eq!(m[(e + 4) * 2], 0.0);
        }
        // kernel 1 occupies rows 4..8 (channel 1)
        for e in 0..4 {
            assert_eq!(m[(e + 4) * 2 + 1], (e + 5) as f32);
            assert_eq!(m[e * 2 + 1], 0.0);
        }
    }

    #[test]
    fn synth_tensor_deterministic() {
        assert_eq!(synth_tensor(16, 7), synth_tensor(16, 7));
        assert_ne!(synth_tensor(16, 7), synth_tensor(16, 8));
        assert!(synth_tensor(100, 1).iter().all(|x| (-1.0..1.0).contains(x)));
    }
}
